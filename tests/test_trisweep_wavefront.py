"""Level-scheduled (wavefront) triangular sweeps: level-schedule oracles on
random elimination DAGs, bit-identity of the wavefront kernels vs the
sequential sweep across backends, dense-algebra solves, and the routing gate
through the SSOR/IC(0) preconditioners."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.trisweep.ops import sweep, wavefront_from_schedule
from repro.precond.blocktri import (TriPart, _ell_pack, dag_levels,
                                    level_schedule, wavefront_favorable,
                                    wavefront_pair)
from repro.sparse.matrices import build_problem

BACKENDS = ("jnp", "interpret")


def _random_dag_part(nbr: int, b: int, density: float, seed: int,
                     reverse: bool = False):
    """Random strictly-triangular blocked structure (lower for forward
    sweeps, upper for reverse) + well-conditioned diagonal inverses."""
    rng = np.random.default_rng(seed)
    br_l, bc_l, blk_l = [], [], []
    for i in range(nbr):
        pool = range(i + 1, nbr) if reverse else range(i)
        deps = [j for j in pool if rng.random() < density]
        for j in sorted(deps):
            br_l.append(i)
            bc_l.append(j)
            blk_l.append(rng.standard_normal((b, b)))
    br = np.asarray(br_l, np.int64)
    bc = np.asarray(bc_l, np.int64)
    blk = np.stack(blk_l) if blk_l else np.empty((0, b, b))
    order = np.lexsort((bc, br))
    part = _ell_pack(br[order], bc[order], blk[order], nbr, b, np.float64)
    dinv = np.linalg.inv(rng.standard_normal((nbr, b, b)) + 4 * np.eye(b))
    return part, dinv


# --------------------------------------------------------------------------- #
# level-schedule oracles on random DAGs
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("reverse", (False, True))
@pytest.mark.parametrize("seed,density", [(0, 0.05), (1, 0.15), (2, 0.4),
                                          (3, 0.0)])
def test_dag_levels_valid_and_minimal(seed, density, reverse):
    """Every row's level is exactly 1 + max of its dependencies' levels
    (0 with no deps) — the longest-path property that makes rows within a
    level mutually independent."""
    part, _ = _random_dag_part(30, 2, density, seed, reverse)
    lev = dag_levels(part.idx, part.n, reverse=reverse)
    for i in range(30):
        deps = part.idx[i, :int(part.n[i])]
        expect = int(lev[deps].max()) + 1 if deps.size else 0
        assert lev[i] == expect, (i, lev[i], expect)


@pytest.mark.parametrize("reverse", (False, True))
def test_level_schedule_partitions_rows(reverse):
    """The packed schedule is a permutation of all block rows: every row
    appears exactly once, padding slots point at the scratch row nbr, and
    per-level populations match the level histogram."""
    nbr = 25
    part, dinv = _random_dag_part(nbr, 3, 0.2, 4, reverse)
    sched = level_schedule(part, dinv, reverse=reverse)
    lev = dag_levels(part.idx, part.n, reverse=reverse)
    seen = sched.rows[sched.rows < nbr]
    assert sorted(seen.tolist()) == list(range(nbr))
    np.testing.assert_array_equal(
        sched.nrows, np.bincount(lev, minlength=sched.n_levels))
    for t in range(sched.n_levels):
        valid = sched.rows[t, :sched.nrows[t]]
        assert np.all(lev[valid] == t)
        assert np.all(sched.rows[t, sched.nrows[t]:] == nbr)


# --------------------------------------------------------------------------- #
# wavefront sweep == sequential sweep, bit-for-bit, on every backend
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("reverse", (False, True))
@pytest.mark.parametrize("seed,density,b", [(5, 0.1, 4), (6, 0.3, 2),
                                            (7, 0.02, 8)])
def test_wavefront_bit_identical_to_sequential(backend, reverse, seed,
                                               density, b):
    nbr = 20
    part, dinv = _random_dag_part(nbr, b, density, seed, reverse)
    sched = level_schedule(part, dinv, reverse=reverse)
    wf = wavefront_from_schedule(sched)
    rng = np.random.default_rng(seed + 100)
    args = (jnp.asarray(part.idx), jnp.asarray(part.n),
            jnp.asarray(part.data), jnp.asarray(dinv))
    for _ in range(3):
        r = jnp.asarray(rng.standard_normal(nbr * b))
        y_seq = sweep(*args, r, reverse=reverse, backend="jnp")
        y_wf = sweep(*args, r, reverse=reverse, backend=backend,
                     schedule=wf)
        np.testing.assert_array_equal(np.asarray(y_seq), np.asarray(y_wf))


def test_wavefront_solves_triangular_system():
    """Dense oracle: (D̂ + T) y = r."""
    nbr, b = 16, 3
    part, dinv = _random_dag_part(nbr, b, 0.25, 8)
    sched = level_schedule(part, dinv, reverse=False)
    wf = wavefront_from_schedule(sched)
    rng = np.random.default_rng(9)
    r = rng.standard_normal(nbr * b)
    y = np.asarray(sweep(None, None, None, None, jnp.asarray(r),
                         backend="jnp", schedule=wf))
    dense = np.zeros((nbr * b, nbr * b))
    for i in range(nbr):
        dense[i * b:(i + 1) * b, i * b:(i + 1) * b] = np.linalg.inv(dinv[i])
        for k in range(int(part.n[i])):
            j = part.idx[i, k]
            dense[i * b:(i + 1) * b, j * b:(j + 1) * b] = part.data[i, k]
    np.testing.assert_allclose(y, np.linalg.solve(dense, r), rtol=1e-11,
                               atol=1e-12)


# --------------------------------------------------------------------------- #
# the routing gate
# --------------------------------------------------------------------------- #
def test_favorability_gate():
    """Chain DAGs (every row depends on its predecessor — the Poisson-slab
    regime at block granularity) keep the sequential kernel; sparse DAGs and
    block-diagonal structures go wavefront."""
    nbr, b = 24, 2
    rng = np.random.default_rng(10)
    chain = _ell_pack(np.arange(1, nbr), np.arange(nbr - 1),
                      rng.standard_normal((nbr - 1, b, b)), nbr, b,
                      np.float64)
    dinv = np.broadcast_to(np.eye(b), (nbr, b, b)).copy()
    assert not wavefront_favorable(
        level_schedule(chain, dinv, reverse=False), nbr)
    empty = _ell_pack(np.empty(0, np.int64), np.empty(0, np.int64),
                      np.empty((0, b, b)), nbr, b, np.float64)
    sched = level_schedule(empty, dinv, reverse=False)
    assert sched.n_levels == 1 and wavefront_favorable(sched, nbr)


def test_wavefront_pair_modes():
    nbr, b = 12, 2
    part, dinv = _random_dag_part(nbr, b, 0.05, 11)
    up, _ = _random_dag_part(nbr, b, 0.05, 12, reverse=True)
    lo_wf, up_wf = wavefront_pair(part, up, dinv, dinv, nbr, "sequential")
    assert lo_wf is None and up_wf is None
    lo_wf, up_wf = wavefront_pair(part, up, dinv, dinv, nbr, "wavefront")
    assert lo_wf is not None and up_wf is not None
    with pytest.raises(ValueError, match="sweep_mode"):
        wavefront_pair(part, up, dinv, dinv, nbr, "nope")


@pytest.mark.parametrize("name", ("ssor", "ic0"))
@pytest.mark.parametrize("backend", BACKENDS)
def test_forced_wavefront_apply_bit_identical(name, backend):
    """z = P r through the forced-wavefront sweeps equals the sequential
    apply bit-for-bit on a real problem (poisson3d couples beyond the
    tridiagonal, so the sweeps do real work)."""
    p_seq = build_problem("poisson3d", n_nodes=2, nx=6, precond=name,
                          precond_opts={"sweep_mode": "sequential"})
    p_wf = build_problem("poisson3d", n_nodes=2, nx=6, precond=name,
                         precond_opts={"sweep_mode": "wavefront"})
    assert p_seq.precond.lo_wf is None
    assert p_wf.precond.lo_wf is not None
    rng = np.random.default_rng(13)
    for _ in range(2):
        r = jnp.asarray(rng.standard_normal(p_seq.m))
        np.testing.assert_array_equal(
            np.asarray(p_seq.precond.apply(r, backend="jnp")),
            np.asarray(p_wf.precond.apply(r, backend=backend)))


def test_auto_mixed_routing_keeps_backends_bit_identical():
    """With sweep_mode="auto" on a favorable DAG the jnp reference keeps the
    sequential sweep while interpret runs the wavefront grid — and the two
    backends must still agree bit-for-bit (the mixed-routing invariant the
    per-backend dispatch relies on)."""
    p = build_problem("poisson2d", n_nodes=8, nx=40, precond="ssor",
                      precond_opts={"node_local": True})
    assert p.precond.lo_wf is not None        # favorable: wavefront built
    rng = np.random.default_rng(14)
    for _ in range(2):
        r = jnp.asarray(rng.standard_normal(p.m))
        np.testing.assert_array_equal(
            np.asarray(p.precond.apply(r, backend="jnp")),
            np.asarray(p.precond.apply(r, backend="interpret")))


def test_node_local_structure_is_wavefront_favorable():
    """The additive-Schwarz restriction makes the elimination DAG favorable
    automatically: each node's slab is an independent chain, so the level
    count collapses to the slab depth and the width to the node count —
    exactly how a single device exploits the node-local parallelism."""
    p = build_problem("poisson2d", n_nodes=8, nx=40, precond="ssor",
                      precond_opts={"node_local": True})
    pc = p.precond
    assert pc.lo_wf is not None
    nbr = p.m // p.precond_block
    assert pc.lo_wf.rows.shape[0] <= nbr // 8 + 1     # levels ≤ slab depth
    # a genuine chain stays sequential: poisson3d at block 10 couples every
    # block row to its predecessor (nx not a block multiple), so the global
    # elimination DAG has depth ≈ nbr
    p_chain = build_problem("poisson3d", n_nodes=2, nx=8, precond="ssor")
    assert p_chain.precond.lo_wf is None
