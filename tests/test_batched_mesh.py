"""Batched solves on the 8-device mesh (subprocess suite).

One mesh dispatch advances B independent RHS members of the same operator;
a multi-node ``FailureEvent`` hits all B members at once and ONE Alg. 2
reconstruction pass (batched line-5/6/8 solves over the shared f-slab)
recovers them together. Asserted bit-identically in f64:

  * every member of the batched sharded solve (device-resident
    ``ShardedFailureRuntime``, batched redundancy-queue ppermutes) rejoins
    its own single-system (B=1) mesh-mirror reference;
  * the batched mesh run equals the batched single-device mesh-mirror run;
  * recovery copies were read from surviving devices' queue shards.
"""
import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
jax.config.update("jax_enable_x64", True)
import numpy as np, jax.numpy as jnp

from repro.comm.shard import (ShardedFailureRuntime, mesh_mirror_ops,
                              nodes_mesh, place_problem, sharded_solver_ops)
from repro.core.driver import solve_resilient
from repro.core.failures import FailureEvent
from repro.sparse.matrices import build_problem

B = 3
mesh = nodes_mesh(8)
problem = build_problem("poisson2d", n_nodes=8, nx=40, ny=40)
placed = place_problem(problem, mesh)
mirror_b = mesh_mirror_ops(problem, 8, batch=B)
mirror1 = mesh_mirror_ops(problem, 8)
with mesh:
    ops_b = sharded_solver_ops(placed, mesh, batch=B)

rng = np.random.default_rng(7)
rhs = rng.standard_normal((B, problem.m))
rhs[1] *= 40.0

scen = [FailureEvent(45, (2, 5))]

# batched sharded solve with the device-resident runtime: one phi=2
# multi-node event strikes all B members, one Alg. 2 pass recovers them
frt = ShardedFailureRuntime(placed, mesh, batch=B)
with mesh:
    reps = solve_resilient(placed, strategy="esrp", T=20, phi=2, rtol=1e-10,
                           ops=ops_b, scenario=list(scen),
                           failure_runtime=frt, rhs=jnp.asarray(rhs))
assert isinstance(reps, list) and len(reps) == B
assert all(r.converged for r in reps)
assert len(reps[0].events) == 1          # ONE recovery pass for the batch
for e in reps[0].events:
    assert e.queue_src_nodes and not set(e.queue_src_nodes) & set(e.nodes), e
print("batched citers:", [r.converged_iter for r in reps])

# per-member single-system mesh-mirror references (B=1, same scenario)
for k in range(B):
    rm = solve_resilient(problem, strategy="esrp", T=20, phi=2, rtol=1e-10,
                         ops=mirror1, scenario=list(scen),
                         rhs=jnp.asarray(rhs[k]))
    assert reps[k].converged_iter == rm.converged_iter, (
        k, reps[k].converged_iter, rm.converged_iter)
    assert (np.asarray(reps[k].x) == np.asarray(rm.x)).all(), \
        f"member {k} did not rejoin its single-system reference bitwise"
print("SINGLE_SYSTEM_REJOIN_OK")

# batched mesh-mirror reference (single-device batched ops, same scenario)
reps_m = solve_resilient(problem, strategy="esrp", T=20, phi=2, rtol=1e-10,
                         ops=mirror_b, scenario=list(scen),
                         rhs=jnp.asarray(rhs))
for k in range(B):
    assert (np.asarray(reps[k].x) == np.asarray(reps_m[k].x)).all(), k
print("MESH_MIRROR_BATCHED_OK")
print("BATCHED_MESH_OK")
"""


@pytest.mark.slow
def test_batched_mesh_eight_devices():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    out = subprocess.run([sys.executable, "-c", _SCRIPT], cwd=".",
                         env=env, capture_output=True, text=True,
                         timeout=560)
    assert out.returncode == 0, out.stderr[-4000:]
    for tag in ("SINGLE_SYSTEM_REJOIN_OK", "MESH_MIRROR_BATCHED_OK",
                "BATCHED_MESH_OK"):
        assert tag in out.stdout, (tag, out.stdout)
