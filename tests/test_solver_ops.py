"""SolverOps execution layer: cross-backend trajectory bit-identity, cond
gating of the storage/replacement bookkeeping, and the driver's sync-free
convergence protocol.

The load-bearing property: the Pallas-backed bundle (interpret mode on CI)
must be *bit-identical* in f64 to the jnp reference bundle — iteration by
iteration, through storage stages and a mid-stage failure/recovery — so the
kernels can be swapped into the paper's experiments without perturbing the
trajectory-identity argument.
"""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from tests._hypo import given, settings, st

from repro.analysis import walker
from repro.core import esrp
from repro.core.driver import solve_resilient
from repro.core.ops import make_closure_ops, pick_rows
from repro.sparse.matrices import build_problem


@pytest.fixture(scope="module")
def problems():
    return {
        "poisson2d": build_problem("poisson2d", n_nodes=4, nx=16, ny=16),
        "poisson3d": build_problem("poisson3d", n_nodes=4, nx=8),
    }


def _assert_tree_equal(a, b):
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


# --------------------------------------------------------------------------- #
# cross-backend bit-identity
# --------------------------------------------------------------------------- #
@settings(max_examples=6, deadline=None)
@given(kind=st.sampled_from(["poisson2d", "poisson3d"]),
       T=st.sampled_from([1, 20]), n_iters=st.integers(10, 30))
def test_trajectory_bit_identical_across_backends(problems, kind, T, n_iters):
    p = problems[kind]
    ops_jnp = p.solver_ops("jnp")
    ops_pal = p.solver_ops("interpret")
    thresh = jnp.asarray(0.0, p.b.dtype)

    s_j = esrp.esrp_init(ops_jnp.matvec, ops_jnp.precond, p.b)
    s_p = esrp.esrp_init(ops_pal.matvec, ops_pal.precond, p.b)
    _assert_tree_equal(s_j, s_p)
    s_j, norms_j = esrp.run_chunk(s_j, ops_jnp, T, n_iters, thresh)
    s_p, norms_p = esrp.run_chunk(s_p, ops_pal, T, n_iters, thresh)
    np.testing.assert_array_equal(np.asarray(norms_j), np.asarray(norms_p))
    _assert_tree_equal(s_j, s_p)


def test_failure_recovery_bit_identical_across_backends(problems):
    """Mid-stage failure (right after the first push of a stage) + Alg. 2
    reconstruction must leave both backends on the same bit-exact state."""
    p = problems["poisson2d"]
    ref = solve_resilient(p, strategy="none", rtol=1e-9, backend="jnp")
    reports = {}
    for backend in ("jnp", "interpret"):
        reports[backend] = solve_resilient(
            p, strategy="esrp", T=5, phi=1, rtol=1e-9, chunk=16,
            fail_at=15, failed_nodes=[2], backend=backend)
    rj, rp = reports["jnp"], reports["interpret"]
    assert rj.converged_iter == rp.converged_iter == ref.converged_iter
    assert rj.rel_residual == rp.rel_residual
    assert rj.target_iter == rp.target_iter
    assert rj.rel_residual < 1e-9


def test_closure_ops_match_seed_numerics(problems):
    """The closure bundle (arbitrary matvec/precond) reproduces the seed's
    unfused op order: solving through it must be bit-identical to the jnp
    einsum path it wraps."""
    p = problems["poisson2d"]
    ops = make_closure_ops(p.a.matvec, p.apply_precond)
    thresh = jnp.asarray(0.0, p.b.dtype)
    s = esrp.esrp_init(ops.matvec, ops.precond, p.b)
    s, norms = esrp.run_chunk(s, ops, 20, 20, thresh)
    # independent replay of Alg. 1 in the seed op order
    x = jnp.zeros_like(p.b)
    r = p.b - p.a.matvec(x)
    z = p.apply_precond(r)
    pv, rz = z, r @ z
    for _ in range(20):
        q = p.a.matvec(pv)
        alpha = rz / (pv @ q)
        x = x + alpha * pv
        r = r - alpha * q
        z = p.apply_precond(r)
        rz_new = r @ z
        pv = z + (rz_new / rz) * pv
        rz = rz_new
    # eager replay vs jitted scan: same op order, but XLA fuses FMA inside
    # the jit — compare to fp noise, not bitwise
    np.testing.assert_allclose(np.asarray(s.pcg.x), np.asarray(x),
                               rtol=1e-12, atol=1e-12)
    np.testing.assert_allclose(np.asarray(s.pcg.r), np.asarray(r),
                               rtol=1e-10, atol=1e-12)


# --------------------------------------------------------------------------- #
# cond gating (traversal shared with the static analyzer: repro.analysis)
# --------------------------------------------------------------------------- #
def _dots(jaxpr):
    """Count dot_general eqns executed unconditionally: recurses through
    sub-jaxprs (pjit bodies etc.) but NOT into cond branches."""
    return walker.count_primitives(jaxpr, "dot_general", into_conds=False)


def test_cond_gates_storage_and_replacement(problems):
    """gated=True must hoist the queue rotation, star capture, and the
    residual-replacement SpMV+precond into lax.cond branches: no extra
    SpMV/dot executes on non-replacement iterations. gated=False (the seed
    path) keeps them inline in the main trace."""
    p = problems["poisson2d"]
    ops = p.solver_ops("jnp")
    s0 = esrp.esrp_init(ops.matvec, ops.precond, p.b)

    def step(gated):
        return jax.make_jaxpr(
            lambda s: esrp.esrp_step(s, ops, 20, b=p.b, rr_every=10,
                                     gated=gated))(s0).jaxpr

    gated, ungated = step(True), step(False)
    conds = [e for e in gated.eqns if e.primitive.name == "cond"]
    assert len(conds) >= 3          # queue push, star capture, replacement
    # inline (unconditionally executed) dots: gated must have strictly fewer
    # — the replacement SpMV (kmax dots) + precond dot moved under cond
    top_gated = _dots(gated)
    top_ungated = _dots(ungated)
    assert top_gated < top_ungated, (top_gated, top_ungated)
    kmax = p.a.kmax
    # the replacement branch is one SpMV (kmax slot dots) + precond einsum
    # + the rᵀz dot — all inline when ungated, all under cond when gated
    assert top_ungated - top_gated == kmax + 2, (top_gated, top_ungated)


def test_gated_trajectory_matches_ungated(problems):
    """cond-gating is a pure execution change: jnp.where-selected and
    cond-branched bookkeeping must produce bit-identical trajectories."""
    p = problems["poisson2d"]
    ops = p.solver_ops("jnp")
    thresh = jnp.asarray(0.0, p.b.dtype)
    out = {}
    for gated in (True, False):
        s = esrp.esrp_init(ops.matvec, ops.precond, p.b)
        s, norms = esrp.run_chunk(s, ops, 5, 25, thresh, 8, gated, p.b)
        out[gated] = (s, norms)
    np.testing.assert_array_equal(np.asarray(out[True][1]),
                                  np.asarray(out[False][1]))
    _assert_tree_equal(out[True][0], out[False][0])


# --------------------------------------------------------------------------- #
# driver protocol
# --------------------------------------------------------------------------- #
def test_driver_never_reruns_final_chunk(problems):
    """The convergence freeze makes each chunk dispatch exactly once: the
    number of run() invocations is the chunk count needed to cover the
    converged iteration — not one extra for the re-run tail."""
    p = problems["poisson2d"]
    for chunk in (16, 64):
        r = solve_resilient(p, strategy="none", rtol=1e-9, chunk=chunk)
        # seed protocol used ceil(C/chunk) + 1 (tail re-run); the overlap
        # protocol may dispatch at most one speculative chunk past
        # convergence, and never re-runs.
        needed = math.ceil(r.converged_iter / chunk)
        assert needed <= r.run_calls <= needed + 1, (r.run_calls, needed)
        assert r.rel_residual < 1e-9


def test_driver_report_consistent_with_and_without_failure(problems):
    p = problems["poisson2d"]
    ref = solve_resilient(p, strategy="none", rtol=1e-9, chunk=32)
    r = solve_resilient(p, strategy="esrp", T=5, phi=1, rtol=1e-9, chunk=32,
                        fail_at=max(4, ref.converged_iter // 2),
                        failed_nodes=[1])
    assert r.converged_iter == ref.converged_iter
    assert r.rel_residual < 1e-9


def test_pick_rows_divides():
    for m, b in ((320, 10), (1280, 10), (1024, 4), (512, 8)):
        rows = pick_rows(m, b)
        assert m % rows == 0 and rows % b == 0 and rows <= 512
