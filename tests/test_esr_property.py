"""Property test: ESR/ESRP recovery is exact for random failure scenarios.

Sweeps (T, phi, failure iteration, failed-node block) — every combination
must converge to the reference trajectory's iteration count with the target
residual, covering all phases of the storage cycle (first push, second push,
plain iterations, pre-stage worst case).
"""
import jax
import numpy as np
import pytest

from tests._hypo import given, settings, st

from repro.core.driver import solve_resilient
from repro.sparse.matrices import build_problem


@pytest.fixture(scope="module")
def setup():
    problem = build_problem("poisson2d", n_nodes=8, nx=32, ny=32)
    ref = solve_resilient(problem, strategy="none", rtol=1e-9)
    return problem, ref


@settings(max_examples=12, deadline=None)
@given(T=st.sampled_from([1, 5, 20]), phi=st.integers(1, 3),
       frac=st.floats(0.3, 0.9), start=st.integers(0, 7))
def test_recovery_exact_random_scenarios(setup, T, phi, frac, start):
    problem, ref = setup
    fail_at = max(4, int(ref.converged_iter * frac))
    failed = [(start + i) % 8 for i in range(phi)]
    r = solve_resilient(problem, strategy="esrp", T=T, phi=phi, rtol=1e-9,
                        fail_at=fail_at, failed_nodes=failed)
    assert r.rel_residual < 1e-9
    assert r.converged_iter == ref.converged_iter   # trajectory preserved
    if T > 1 and r.target_iter >= 0:
        assert 0 <= r.wasted_iters <= T + 1
