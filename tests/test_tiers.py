"""Redundancy-storage tiers (core.tiers + driver accounting).

The tier is a COST MODEL layered behind the queue: the data path is
bit-identical across tiers (assert so), only the recovery-time accounting
changes. Under test:

  * read_s/write_s = latency + bytes / bandwidth, and the three built-in
    tiers order as device-neighbour < replicated-host < simulated-nvram;
  * push_bytes: the device-neighbour tier ships only the EXTRA tiles of
    the augmented SpMV (tot − nat — the natural traffic is the SpMV's
    own); full-slab tiers ship the whole vector;
  * the driver threads the tier through SolveReport (push_count ×
    per-push volume, model seconds) and per-event fetch accounting;
  * push_count replays the Alg. 3 storage schedule over the executed
    ranges — a rollback re-executes a stretch, so its pushes recount.
"""
import numpy as np
import pytest

from repro.core.aspmv import build_plan
from repro.core.driver import solve_resilient, _count_pushes
from repro.core.failures import FailureEvent
from repro.core.tiers import (DEVICE_NEIGHBOUR, REPLICATED_HOST,
                              SIMULATED_NVRAM, TIERS, StorageTier,
                              resolve_tier)
from repro.sparse.matrices import build_problem


@pytest.fixture(scope="module")
def problem():
    return build_problem("poisson2d", n_nodes=4, nx=24, ny=24)


def test_cost_model_arithmetic():
    t = StorageTier(name="t", read_gbps=2.0, write_gbps=1.0,
                    latency_s=1e-3, full_slab_push=True)
    nbytes = 2_000_000_000
    assert t.read_s(nbytes) == pytest.approx(1e-3 + 1.0)
    assert t.write_s(nbytes) == pytest.approx(1e-3 + 2.0)
    assert t.fetch_bytes(100, 8) == 2 * 100 * 8    # the p^(j-1)/p^(j) pair


def test_builtin_tiers_order():
    nbytes = 1 << 20
    costs = [tier.write_s(nbytes) for tier in
             (DEVICE_NEIGHBOUR, REPLICATED_HOST, SIMULATED_NVRAM)]
    assert costs == sorted(costs)
    assert set(TIERS) == {"device-neighbour", "replicated-host",
                          "simulated-nvram"}


def test_resolve_tier():
    assert resolve_tier("replicated-host") is REPLICATED_HOST
    assert resolve_tier(DEVICE_NEIGHBOUR) is DEVICE_NEIGHBOUR
    with pytest.raises(ValueError, match="unknown storage tier"):
        resolve_tier("floppy-disk")


def test_push_bytes_extra_vs_full_slab(problem):
    plan = build_plan(problem.a, problem.part, phi=1)
    nat, tot = plan.bytes_per_aspmv(8)
    m_bytes = problem.part.m * 8
    assert DEVICE_NEIGHBOUR.push_bytes(plan, problem.part.m, 8) == tot - nat
    assert REPLICATED_HOST.push_bytes(plan, problem.part.m, 8) == m_bytes
    # without a plan (e.g. strategy "none") the neighbour tier degrades to
    # the full slab too
    assert DEVICE_NEIGHBOUR.push_bytes(None, problem.part.m, 8) == m_bytes


def test_count_pushes_replays_schedule():
    # T=10: pushes at j % 10 in {0, 1}, j > 2
    assert _count_pushes([(0, 25)], 10) == 4        # 10, 11, 20, 21
    assert _count_pushes([(0, 25), (20, 25)], 10) == 6   # re-executed 20, 21
    assert _count_pushes([(0, 3)], 1) == 0          # j > 2 gate
    assert _count_pushes([(3, 6)], 1) == 3          # ESR: every iteration


@pytest.mark.parametrize("tier", ["device-neighbour", "replicated-host",
                                  "simulated-nvram"])
def test_driver_threads_tier_accounting(problem, tier):
    rep = solve_resilient(problem, strategy="esrp", T=10, rtol=1e-10,
                          storage_tier=tier,
                          scenario=[FailureEvent(iter=35, nodes=(2,))])
    assert rep.converged and rep.tier == tier
    assert rep.push_count > 0
    per_push = rep.push_bytes // rep.push_count
    t = resolve_tier(tier)
    assert rep.push_s_model == pytest.approx(
        rep.push_count * t.write_s(per_push))
    (ev,) = rep.events
    assert ev.tier == tier
    assert ev.fetch_bytes == 2 * problem.part.rows_per_node * 8
    assert ev.fetch_s_model == pytest.approx(t.read_s(ev.fetch_bytes))
    assert rep.fetch_s_model == pytest.approx(ev.fetch_s_model)


def test_tier_is_cost_model_only(problem):
    """The trajectory must be bit-identical across tiers — placement is
    accounting, not arithmetic."""
    xs = []
    for tier in TIERS:
        rep = solve_resilient(problem, strategy="esrp", T=10, rtol=1e-10,
                              storage_tier=tier,
                              scenario=[FailureEvent(iter=35, nodes=(1,))])
        xs.append(np.asarray(rep.x))
    np.testing.assert_array_equal(xs[0], xs[1])
    np.testing.assert_array_equal(xs[0], xs[2])


def test_rollback_recounts_pushes(problem):
    """An event mid-stage (35) rolls back to the stage boundary it just
    left — no push is re-executed, so the counts match the clean run. An
    event at 40 strikes right AFTER the new stage's first push, whose pair
    is not yet consecutive: recovery falls back to the previous stage (31)
    and iteration 40's push physically re-executes on the way back up."""
    clean = solve_resilient(problem, strategy="esrp", T=10, rtol=1e-10)
    mid = solve_resilient(problem, strategy="esrp", T=10, rtol=1e-10,
                          scenario=[FailureEvent(iter=35, nodes=(1,))])
    assert mid.push_count == clean.push_count
    boundary = solve_resilient(problem, strategy="esrp", T=10, rtol=1e-10,
                               scenario=[FailureEvent(iter=40, nodes=(1,))])
    assert boundary.events[0].target_iter == 31
    assert boundary.push_count > clean.push_count


def test_calibration_round_trip(tmp_path):
    """A scripts/calibrate_tiers.py record overwrites the constants (with
    measured provenance) but never the placement semantics; unknown tier
    names are rejected."""
    import json

    from repro.core.tiers import load_calibration

    doc = dict(
        provenance=dict(host="ci", backend="cpu", date="2026-08-08"),
        tiers={"replicated-host": dict(read_gbps=21.0, write_gbps=7.5,
                                       latency_s=3e-5)})
    path = tmp_path / "tiers.json"
    path.write_text(json.dumps(doc))
    cal = load_calibration(str(path))
    t = cal["replicated-host"]
    assert t.read_gbps == 21.0 and t.write_gbps == 7.5
    assert t.latency_s == 3e-5
    assert t.full_slab_push == REPLICATED_HOST.full_slab_push
    assert t.provenance.startswith("measured host=ci")
    assert REPLICATED_HOST.provenance == "placeholder"   # builtin untouched

    doc["tiers"]["no-such-tier"] = doc["tiers"]["replicated-host"]
    path.write_text(json.dumps(doc))
    with pytest.raises(ValueError, match="unknown tier"):
        load_calibration(str(path))
