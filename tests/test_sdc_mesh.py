"""SDC detection + repair on the 8-device mesh runtime.

Subprocess suite (``--xla_force_host_platform_device_count=8``, same
pattern as test_sharded_scenarios):

  * every SDC target (p, r, x, z, queue) injected on the mesh is detected
    within one check period and repaired — the run rejoins the clean
    sharded reference trajectory (norm-wise; the rollback re-executes a
    stretch whose mesh reductions may re-associate);
  * queue corruption on the mesh also corrupts the *physical holder
    devices'* ``rq`` rows; the read-time checksum in ``assemble_pair``
    excludes the corrupted holder from the copy sources when a fail-stop
    recovery reads the queue BEFORE any invariant check ran — and the
    stored (mismatched) checksum survives the recovery restack, so the
    next check still flags and invalidates the corrupted slot.
"""
import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
jax.config.update("jax_enable_x64", True)
import numpy as np, jax.numpy as jnp

from repro.comm.shard import (ShardedFailureRuntime, nodes_mesh,
                              place_problem, sharded_solver_ops)
from repro.core.driver import solve_resilient
from repro.core.failures import FailureEvent, SDCEvent
from repro.sparse.matrices import build_problem

mesh = nodes_mesh(8)
problem = build_problem("poisson2d", n_nodes=8, nx=40, ny=40)
placed = place_problem(problem, mesh)
with mesh:
    ops = sharded_solver_ops(placed, mesh)
    ref = solve_resilient(placed, strategy="esrp", T=10, phi=2, rtol=1e-10,
                          ops=ops)
xref = np.asarray(ref.x)
xscale = max(float(np.linalg.norm(xref)), 1.0)

# --- 1) every target detected + repaired on the mesh ----------------------
for tgt in ("p", "r", "x", "z", "queue"):
    frt = ShardedFailureRuntime(placed, mesh)
    with mesh:
        rep = solve_resilient(placed, strategy="esrp", T=10, phi=2,
                              rtol=1e-10, ops=ops, failure_runtime=frt,
                              scenario=[SDCEvent(iter=33, nodes=(2,),
                                                 target=tgt)])
    reps = [e for e in rep.events if e.kind == "sdc-repair"]
    assert rep.converged, tgt
    assert rep.converged_iter == ref.converged_iter, (
        tgt, rep.converged_iter, ref.converged_iter)
    assert len(reps) == 1, (tgt, [e.detector for e in rep.events])
    er = reps[0]
    assert 0 < er.detect_latency <= 16, (tgt, er.detect_latency)
    err = float(np.linalg.norm(np.asarray(rep.x) - xref))
    assert err <= 1e-10 * xscale, (tgt, err)
    if tgt == "queue":
        assert er.detector == "queue-checksum", er.detector
        assert er.wasted_iters == 0
print("MESH_SDC_TARGETS_OK")

# --- 2) read-time checksum: a fail-stop that reads a corrupted holder -----
# Corrupt holder device 3's physical rq rows at 33 (no check boundary
# before 35 with check_every=16 and the stage gap), then fail node 2 at 35:
# assemble_pair must EXCLUDE holder 3 (phi=2 provides another copy), and
# the stored mismatched checksum must survive the recovery restack so the
# next check (40) still flags + invalidates the corrupted slot.
from repro.core.sdc import SDCPolicy
frt = ShardedFailureRuntime(placed, mesh)
with mesh:
    rep = solve_resilient(placed, strategy="esrp", T=10, phi=2, rtol=1e-10,
                          ops=ops, failure_runtime=frt,
                          sdc_policy=SDCPolicy(check_every=16),
                          scenario=[SDCEvent(iter=33, nodes=(3,),
                                             target="queue"),
                                    FailureEvent(iter=35, nodes=(2,))])
assert rep.converged
kinds = [e.kind for e in rep.events]
assert kinds.count("fail-stop") == 1, kinds
fs = next(e for e in rep.events if e.kind == "fail-stop")
assert fs.queue_src_nodes, "mesh recovery must name its physical sources"
assert 3 not in fs.queue_src_nodes, fs.queue_src_nodes
qreps = [e for e in rep.events
         if e.kind == "sdc-repair" and e.detector == "queue-checksum"]
assert len(qreps) == 1, kinds
err = float(np.linalg.norm(np.asarray(rep.x) - xref))
assert err <= 1e-10 * xscale, err
print("READ_TIME_CHECKSUM_OK")

# --- 3) multi-node SDC on the mesh ----------------------------------------
frt = ShardedFailureRuntime(placed, mesh)
with mesh:
    rep = solve_resilient(placed, strategy="esrp", T=10, phi=2, rtol=1e-10,
                          ops=ops, failure_runtime=frt,
                          scenario=[SDCEvent(iter=45, nodes=(1, 4, 6),
                                             target="r")])
assert rep.converged and rep.converged_iter == ref.converged_iter
err = float(np.linalg.norm(np.asarray(rep.x) - xref))
assert err <= 1e-10 * xscale, err
print("MESH_MULTI_NODE_SDC_OK")

print("SDC_MESH_OK")
"""


@pytest.mark.slow
def test_sdc_on_eight_device_mesh():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    out = subprocess.run([sys.executable, "-c", _SCRIPT], cwd=".",
                         env=env, capture_output=True, text=True,
                         timeout=560)
    assert out.returncode == 0, out.stderr[-4000:]
    for tag in ("MESH_SDC_TARGETS_OK", "READ_TIME_CHECKSUM_OK",
                "MESH_MULTI_NODE_SDC_OK", "SDC_MESH_OK"):
        assert tag in out.stdout, (tag, out.stdout)
