"""Per-arch smoke tests (reduced configs): fwd + 1 train step on CPU, shape
and finiteness checks; decode-vs-full-forward consistency; MoE invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, smoke_config
from repro.configs.shapes import SHAPES, Shape, applicable, concrete_batch
from repro.models.lm import LM, PAD_MULTIPLE
from repro.train.optimizer import AdamWConfig, init_opt_state
from repro.train.train_step import make_train_step

SMOKE_SHAPE = Shape("smoke", 32, 2, "train")


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_smoke_forward_and_train_step(arch):
    cfg = smoke_config(arch)
    model = LM(cfg)
    params, specs = model.init(jax.random.PRNGKey(0))
    batch = concrete_batch(cfg, SMOKE_SHAPE)
    logits, _ = model.forward(params, batch)
    s_total = SMOKE_SHAPE.seq_len if cfg.frontend != "vlm" else \
        SMOKE_SHAPE.seq_len
    assert logits.shape == (SMOKE_SHAPE.global_batch, s_total,
                            cfg.padded_vocab(PAD_MULTIPLE))
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())

    step = make_train_step(model, AdamWConfig(warmup_steps=2))
    opt = init_opt_state(params)
    p2, o2, metrics = jax.jit(step)(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    assert float(metrics["grad_norm"]) > 0
    # params actually moved
    moved = any(float(jnp.abs(a - b).max()) > 0
                for a, b in zip(jax.tree.leaves(params),
                                jax.tree.leaves(p2)))
    assert moved


@pytest.mark.parametrize("arch", ["internlm2_1_8b", "gemma3_27b",
                                  "qwen2_moe_a2_7b", "zamba2_7b",
                                  "xlstm_125m"])
def test_decode_matches_full_forward(arch):
    cfg = smoke_config(arch)
    model = LM(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 8), 0, cfg.vocab,
                              dtype=jnp.int32)
    full = np.asarray(model.forward(params, {"tokens": toks})[0], np.float32)
    caches = model.init_cache(1, 16)
    lp, caches = model.prefill(params, {"tokens": toks[:, :4]}, caches)
    np.testing.assert_allclose(
        np.asarray(lp, np.float32)[0, 3], full[0, 3], atol=2e-2)
    for t in range(4, 8):
        lg, caches = model.decode_step(params, toks[:, t:t + 1], caches,
                                       jnp.asarray(t, jnp.int32))
        np.testing.assert_allclose(np.asarray(lg, np.float32)[0, 0],
                                   full[0, t], atol=2e-2)


def test_full_configs_abstract_init_param_counts():
    expected = {
        "command_r_plus_104b": (100e9, 110e9),
        "glm4_9b": (9e9, 10e9),
        "gemma3_27b": (27e9, 29e9),
        "qwen2_moe_a2_7b": (14e9, 16e9),
        "zamba2_7b": (6e9, 7.5e9),
        "xlstm_125m": (0.12e9, 0.2e9),
    }
    for arch, (lo, hi) in expected.items():
        model = LM(get_config(arch))
        shapes, specs = model.abstract_init(jax.random.PRNGKey(0))
        n = sum(int(np.prod(a.shape)) for a in jax.tree.leaves(shapes))
        assert lo < n < hi, (arch, n)
        # every param has a logical spec of matching rank
        flat_s = jax.tree.leaves(
            specs, is_leaf=lambda x: hasattr(x, "_normalized_spec")
            or type(x).__name__ == "PartitionSpec")
        assert len(flat_s) == len(jax.tree.leaves(shapes))


def test_moe_capacity_drops_are_bounded():
    """With capacity_factor >= 1 and uniform-ish routing most tokens keep
    all top-k slots; the layer output must stay finite and nonzero."""
    cfg = smoke_config("qwen2_moe_a2_7b")
    model = LM(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    batch = concrete_batch(cfg, Shape("s", 64, 2, "train"))
    logits, _ = model.forward(params, batch)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())


def test_vocab_padding_never_predicted():
    """Padded vocab rows exist but CE only reads real labels; logits for
    padded ids are finite (no masking needed at train time)."""
    cfg = smoke_config("granite_moe_1b_a400m")       # vocab=259, pad to 272
    model = LM(cfg)
    assert model.v_pad == 272 and cfg.vocab == 259
    params, _ = model.init(jax.random.PRNGKey(0))
    loss = model.loss(params, concrete_batch(cfg, SMOKE_SHAPE))
    assert np.isfinite(float(loss))


def test_long_500k_applicability_table():
    subq = {a for a in ARCHS
            if applicable(get_config(a), "long_500k")}
    assert subq == {"gemma3_27b", "zamba2_7b", "xlstm_125m"} or \
        subq == {"gemma3-27b", "zamba2-7b", "xlstm-125m"}


def test_all_cells_have_input_specs():
    from repro.configs.shapes import input_specs
    n = 0
    for arch in ARCHS:
        cfg = get_config(arch)
        for name, shape in SHAPES.items():
            if not applicable(cfg, name):
                continue
            specs = input_specs(cfg, shape)
            assert specs, (arch, name)
            n += 1
    assert n == 33
