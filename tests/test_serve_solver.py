"""Streaming resilient solve service: micro-batcher, padding, per-request
accounting, failure injection under load, and the serve report contract.
"""
import numpy as np
import pytest

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp

from repro.core.driver import solve_resilient
from repro.core.failures import FailureEvent
from repro.obs import chrome_trace, validate_chrome_trace
from repro.obs.validate import check_report_batch_fields
from repro.serve.serve_step import make_solve_step
from repro.serve.solver_service import SolverService
from repro.sparse.matrices import build_problem


@pytest.fixture(scope="module")
def problem():
    return build_problem("poisson2d", n_nodes=4, nx=20)


@pytest.fixture(scope="module")
def requests(problem):
    rng = np.random.default_rng(17)
    return rng.standard_normal((6, problem.part.m))


def test_make_solve_step_returns_member_reports(problem, requests):
    step = make_solve_step(problem, strategy="esrp", T=10, rtol=1e-8)
    reports = step(jnp.asarray(requests[:3]))
    assert len(reports) == 3 and all(r.converged for r in reports)
    assert [r.batch_index for r in reports] == [0, 1, 2]


def test_service_pads_partial_microbatches(problem, requests):
    svc = SolverService(problem, batch=4, strategy="esrp", T=10, rtol=1e-8)
    ids = [svc.submit(r) for r in requests]          # 6 requests, B=4
    res = svc.run()
    assert len(res) == 6 and svc.pending() == 0
    fills = {r.batch_seq: r.batch_fill for r in res}
    assert fills == {0: 4, 1: 2}                     # 4 + padded 2
    for rid in ids:
        r = svc.results[rid]
        assert r.report.converged
        assert r.report.batch_size == 4              # padded to full width
        assert r.latency_s >= r.queue_wait_s >= 0.0
    st = svc.stats()
    assert st["requests"] == 6 and st["microbatches"] == 2
    assert st["all_converged"] and st["mean_fill"] == pytest.approx(10 / 3)


def test_service_exact_mode_matches_b1_reference(problem, requests):
    """fused=False runs the exact per-member bundle: every served result is
    bit-identical to its own B=1 solve (padding members included)."""
    svc = SolverService(problem, batch=4, strategy="esrp", T=10, rtol=1e-8,
                        fused=False)
    for r in requests[:4]:
        svc.submit(r)
    svc.run()
    for k in range(4):
        solo = solve_resilient(problem, strategy="esrp", T=10, rtol=1e-8,
                               rhs=jnp.asarray(requests[k]))
        got = np.asarray(svc.results[k].report.x)
        assert (got == np.asarray(solo.x)).all(), k


def test_service_failures_under_load(problem, requests):
    """fail_every=2 lands the scenario in every second micro-batch: struck
    batches recover (events recorded) and still converge; clean batches
    carry no events."""
    svc = SolverService(problem, batch=2, strategy="esrp", T=10, rtol=1e-8,
                        scenario=[FailureEvent(15, (1,))], fail_every=2)
    for r in requests:
        svc.submit(r)
    res = svc.run()
    assert all(r.report.converged for r in res)
    for r in res:
        struck = r.batch_seq % 2 == 0
        assert bool(r.report.events) == struck, r.batch_seq
        if struck:
            assert tuple(r.report.events[0].nodes) == (1,)


def test_service_tracer_spans_and_report_schema(problem, requests):
    svc = SolverService(problem, batch=2, strategy="esrp", T=10, rtol=1e-8,
                        obs=True)
    for r in requests[:4]:
        svc.submit(r)
    svc.run()
    tr = svc.tracer
    assert tr is not None
    doc = chrome_trace(tr)
    assert validate_chrome_trace(doc) == []
    names = [e["name"] for e in doc["traceEvents"]]
    assert names.count("microbatch") >= 2 * 2       # B/E pairs per dispatch
    assert names.count("request") >= 4 * 2
    # per-member reports serialize with their placement and pass the CI gate
    import json
    lines = [json.dumps({"type": "solve_report",
                         "data": svc.results[k].report.to_json()})
             for k in range(4)]
    assert check_report_batch_fields(lines) == []
    bad = [json.dumps({"type": "solve_report",
                       "data": {"schema_version": 2, "batch_index": 5,
                                "batch_size": 2}})]
    assert check_report_batch_fields(bad) != []


def test_service_input_validation(problem):
    with pytest.raises(ValueError, match="batch must be"):
        SolverService(problem, batch=0)
    svc = SolverService(problem, batch=2)
    with pytest.raises(ValueError, match="rhs shape"):
        svc.submit(np.ones(3))
