"""Streaming resilient solve service: micro-batcher, padding, per-request
accounting, failure injection under load, and the serve report contract —
plus the deadline-aware front-end (partial dispatch on queue-wait timeout,
per-request deadlines, bounded retry, elastic degradation).
"""
import numpy as np
import pytest

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp

from repro.core.driver import solve_resilient
from repro.core.failures import FailureEvent
from repro.obs import chrome_trace, validate_chrome_trace
from repro.obs.validate import check_report_batch_fields
from repro.serve.serve_step import make_solve_step
from repro.serve.solver_service import SolverService
from repro.sparse.matrices import build_problem


@pytest.fixture(scope="module")
def problem():
    return build_problem("poisson2d", n_nodes=4, nx=20)


@pytest.fixture(scope="module")
def requests(problem):
    rng = np.random.default_rng(17)
    return rng.standard_normal((6, problem.part.m))


def test_make_solve_step_returns_member_reports(problem, requests):
    step = make_solve_step(problem, strategy="esrp", T=10, rtol=1e-8)
    reports = step(jnp.asarray(requests[:3]))
    assert len(reports) == 3 and all(r.converged for r in reports)
    assert [r.batch_index for r in reports] == [0, 1, 2]


def test_service_pads_partial_microbatches(problem, requests):
    svc = SolverService(problem, batch=4, strategy="esrp", T=10, rtol=1e-8)
    ids = [svc.submit(r) for r in requests]          # 6 requests, B=4
    res = svc.run()
    assert len(res) == 6 and svc.pending() == 0
    fills = {r.batch_seq: r.batch_fill for r in res}
    assert fills == {0: 4, 1: 2}                     # 4 + padded 2
    for rid in ids:
        r = svc.results[rid]
        assert r.report.converged
        assert r.report.batch_size == 4              # padded to full width
        assert r.latency_s >= r.queue_wait_s >= 0.0
    st = svc.stats()
    assert st["requests"] == 6 and st["microbatches"] == 2
    assert st["all_converged"] and st["mean_fill"] == pytest.approx(10 / 3)


def test_service_exact_mode_matches_b1_reference(problem, requests):
    """fused=False runs the exact per-member bundle: every served result is
    bit-identical to its own B=1 solve (padding members included)."""
    svc = SolverService(problem, batch=4, strategy="esrp", T=10, rtol=1e-8,
                        fused=False)
    for r in requests[:4]:
        svc.submit(r)
    svc.run()
    for k in range(4):
        solo = solve_resilient(problem, strategy="esrp", T=10, rtol=1e-8,
                               rhs=jnp.asarray(requests[k]))
        got = np.asarray(svc.results[k].report.x)
        assert (got == np.asarray(solo.x)).all(), k


def test_service_failures_under_load(problem, requests):
    """fail_every=2 lands the scenario in every second micro-batch: struck
    batches recover (events recorded) and still converge; clean batches
    carry no events."""
    svc = SolverService(problem, batch=2, strategy="esrp", T=10, rtol=1e-8,
                        scenario=[FailureEvent(15, (1,))], fail_every=2)
    for r in requests:
        svc.submit(r)
    res = svc.run()
    assert all(r.report.converged for r in res)
    for r in res:
        struck = r.batch_seq % 2 == 0
        assert bool(r.report.events) == struck, r.batch_seq
        if struck:
            assert tuple(r.report.events[0].nodes) == (1,)


def test_service_tracer_spans_and_report_schema(problem, requests):
    svc = SolverService(problem, batch=2, strategy="esrp", T=10, rtol=1e-8,
                        obs=True)
    for r in requests[:4]:
        svc.submit(r)
    svc.run()
    tr = svc.tracer
    assert tr is not None
    doc = chrome_trace(tr)
    assert validate_chrome_trace(doc) == []
    names = [e["name"] for e in doc["traceEvents"]]
    assert names.count("microbatch") >= 2 * 2       # B/E pairs per dispatch
    assert names.count("request") >= 4 * 2
    # per-member reports serialize with their placement and pass the CI gate
    import json
    lines = [json.dumps({"type": "solve_report",
                         "data": svc.results[k].report.to_json()})
             for k in range(4)]
    assert check_report_batch_fields(lines) == []
    bad = [json.dumps({"type": "solve_report",
                       "data": {"schema_version": 2, "batch_index": 5,
                                "batch_size": 2}})]
    assert check_report_batch_fields(bad) != []
    # v3: the deadline-aware serving fields are required
    bad_v3 = [json.dumps({"type": "solve_report",
                          "data": {"schema_version": 3, "batch_index": 0,
                                   "batch_size": 2, "retries": 0,
                                   "final_n_nodes": 4}})]
    errs = check_report_batch_fields(bad_v3)
    assert errs and "deadline_missed" in errs[0]
    bad_v3 = [json.dumps({"type": "solve_report",
                          "data": {"schema_version": 3, "batch_index": 0,
                                   "batch_size": 2,
                                   "deadline_missed": False,
                                   "retries": -1, "final_n_nodes": 4}})]
    assert any("retries" in e for e in check_report_batch_fields(bad_v3))


def test_service_input_validation(problem):
    with pytest.raises(ValueError, match="batch must be"):
        SolverService(problem, batch=0)
    with pytest.raises(ValueError, match="max_queue_wait_s"):
        SolverService(problem, batch=2, max_queue_wait_s=-1.0)
    with pytest.raises(ValueError, match="max_retries"):
        SolverService(problem, batch=2, max_retries=-1)
    svc = SolverService(problem, batch=2)
    with pytest.raises(ValueError, match="rhs shape"):
        svc.submit(np.ones(3))


# --------------------------------------------------------------------------- #
# deadline-aware front-end (ISSUE 9)
# --------------------------------------------------------------------------- #
def test_partial_dispatch_on_queue_wait_timeout(problem, requests):
    """With max_queue_wait_s set, step() holds a below-width queue until the
    oldest request has waited it out — then ships a partial batch."""
    svc = SolverService(problem, batch=4, strategy="esrp", T=10, rtol=1e-8,
                        max_queue_wait_s=30.0)
    svc.submit(requests[0])
    svc.submit(requests[1])
    assert not svc.ready()
    assert svc.step() == []            # 2 < B and nobody waited 30 s yet
    assert svc.pending() == 2
    # a full batch dispatches immediately regardless of wait
    svc.submit(requests[2])
    svc.submit(requests[3])
    assert svc.ready()
    res = svc.step()
    assert len(res) == 4 and all(r.status == "ok" for r in res)
    assert svc.partial_dispatches == 0

    # wait bound 0: the oldest request has always waited long enough
    svc = SolverService(problem, batch=4, strategy="esrp", T=10, rtol=1e-8,
                        max_queue_wait_s=0.0)
    svc.submit(requests[0])
    svc.submit(requests[1])
    assert svc.ready()
    res = svc.step()
    assert len(res) == 2 and all(r.status == "ok" for r in res)
    assert res[0].batch_fill == 2
    assert svc.partial_dispatches == 1
    assert svc.stats()["partial_dispatches"] == 1


def test_expired_request_dropped_as_deadline_missed(problem, requests):
    """A request whose deadline lapses while queued is dropped before the
    dispatch — terminal state deadline_missed, never a failure, and it
    does not occupy a batch slot."""
    svc = SolverService(problem, batch=2, strategy="esrp", T=10, rtol=1e-8)
    dead = svc.submit(requests[0], deadline_s=-1.0)     # already expired
    live = svc.submit(requests[1])
    res = svc.run()
    assert len(res) == 2
    dropped = svc.results[dead]
    assert dropped.status == "deadline_missed"
    assert dropped.report is None and dropped.batch_seq == -1
    served = svc.results[live]
    assert served.status == "ok" and served.report.converged
    assert served.batch_fill == 1      # the dropped request freed its slot
    st = svc.stats()
    assert st["deadline_missed"] == 1 and st["failed"] == 0
    assert st["deadline_miss_rate"] == pytest.approx(0.5)


def test_late_completion_marked_missed_not_failed(problem, requests):
    """A deadline that expires mid-solve keeps its (numerically valid)
    report but lands deadline_missed — not mischaracterized as a failure."""
    import time

    svc = SolverService(problem, batch=2, strategy="esrp", T=10, rtol=1e-8)
    # make the dispatch provably outlast the deadline (a warm jit cache can
    # finish the real solve in microseconds): pad the solve step itself
    real_step = svc._step
    svc._step = lambda rhs, **kw: (time.sleep(0.1), real_step(rhs, **kw))[1]
    # generous enough to survive the queue pop, far shorter than the solve
    rid = svc.submit(requests[0], deadline_s=0.05)
    res = svc.run()
    assert len(res) == 1
    r = svc.results[rid]
    assert r.status == "deadline_missed"
    assert r.report is not None and r.report.converged
    assert r.report.deadline_missed is True
    st = svc.stats()
    assert st["failed"] == 0 and st["deadline_missed"] == 1


def test_bounded_retry_on_unsurvivable_event(problem, requests):
    """phi=1 cannot survive a 2-node simultaneous loss: the solve raises.
    With retries the micro-batch re-dispatches (scenario cleared) and
    serves; without, the requests land status="failed"."""
    scen = [FailureEvent(15, (1, 2))]
    svc = SolverService(problem, batch=2, strategy="esrp", T=10, phi=1,
                        rtol=1e-8, scenario=scen, max_retries=1,
                        retry_backoff_s=0.0)
    ids = [svc.submit(r) for r in requests[:2]]
    res = svc.run()
    assert all(r.status == "ok" for r in res)
    for rid in ids:
        r = svc.results[rid]
        assert r.retries == 1 and r.report.retries == 1
        assert r.report.converged
    assert svc.stats()["retries_total"] == 2

    svc = SolverService(problem, batch=2, strategy="esrp", T=10, phi=1,
                        rtol=1e-8, scenario=scen, max_retries=0)
    svc.submit(requests[0])
    res = svc.run()
    assert len(res) == 1 and res[0].status == "failed"
    assert res[0].report is None
    st = svc.stats()
    assert st["failed"] == 1 and st["deadline_missed"] == 0


def test_degraded_service_keeps_serving_after_shrink(problem, requests):
    """degrade=True: an unreplaced node loss shrinks the mesh elastically,
    the service adopts the shrunk problem, and later micro-batches keep
    serving on the survivors (events aimed at amputated nodes dropped)."""
    svc = SolverService(problem, batch=2, strategy="esrp", T=10, rtol=1e-8,
                        scenario=[FailureEvent(15, (3,))], fail_every=1,
                        degrade=True)
    ids = [svc.submit(r) for r in requests[:4]]
    res = svc.run()
    assert len(res) == 4 and all(r.status == "ok" for r in res)
    assert svc.n_nodes == 3
    for rid in ids:
        r = svc.results[rid]
        assert r.report.converged and r.final_n_nodes == 3
        assert r.report.final_n_nodes == 3
    # the second micro-batch ran on the adopted shrunk problem: no event
    # could strike (node 3 no longer exists) and none was injected
    second = [svc.results[i] for i in ids if svc.results[i].batch_seq == 1]
    assert second and all(not r.report.events for r in second)
    assert svc.stats()["final_n_nodes"] == 3
