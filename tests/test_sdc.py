"""Silent-data-corruption detection + repair (core.sdc + driver routing).

The contract under test (beyond fail-stop — ISSUE 6 tentpole):
  * every SDCEvent target (p, r, x, z, queue) × kind (bitflip, perturb),
    single- and multi-node, is DETECTED within one invariant-check period
    and REPAIRED through the same Alg. 2 reconstruction fail-stop uses —
    the run rejoins the clean reference trajectory (same converged
    iteration; solution matches within a norm-wise tolerance, since the
    rollback re-executes a stretch whose reductions may re-associate);
  * detection is attributed: EventReport records the detector, the
    detection iteration, the latency, and the measured violation vs the
    recorded tolerance it was compared against;
  * queue corruption never perturbs the trajectory — repair is slot
    invalidation, not rollback;
  * the detectors NEVER fire on a clean run: failure-free solves across
    every preconditioner, the jnp and interpret backends, and a cadence
    sweep report zero detections (the false-positive floor);
  * validation: SDC composes with esrp/none only, needs T >= 2 under esrp,
    and a "queue" target is meaningless without a queue.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import sdc
from repro.core.driver import solve_resilient
from repro.core.failures import SDCEvent
from repro.sparse.matrices import build_problem


@pytest.fixture(scope="module")
def problem():
    return build_problem("poisson2d", n_nodes=4, nx=24, ny=24)


@pytest.fixture(scope="module")
def reference(problem):
    return solve_resilient(problem, strategy="esrp", T=10, rtol=1e-10)


def _repairs(rep):
    return [e for e in rep.events if e.kind == "sdc-repair"]


def _assert_rejoined(rep, reference, tol=1e-10):
    assert rep.converged
    assert rep.converged_iter == reference.converged_iter
    err = float(jnp.linalg.norm(rep.x - reference.x))
    scale = float(jnp.linalg.norm(reference.x))
    assert err <= tol * max(scale, 1.0), err


# --------------------------------------------------------------------------- #
# detect + repair, every target
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("target", ["p", "r", "x", "z", "queue"])
@pytest.mark.parametrize("it", [30, 33])   # 30: a storage iteration (T=10),
#                                            so the very next count is a
#                                            check-before-store boundary;
#                                            33: mid-stage, caught by the
#                                            cadence/next-storage check
def test_sdc_detected_and_repaired(problem, reference, target, it):
    rep = solve_resilient(
        problem, strategy="esrp", T=10, rtol=1e-10,
        scenario=[SDCEvent(iter=it, nodes=(1,), target=target)])
    inj = [e for e in rep.events if e.kind == "sdc-inject"]
    reps = _repairs(rep)
    assert len(inj) == 1 and inj[0].sdc_target == target
    assert len(reps) == 1, [e.detector for e in reps]
    er = reps[0]
    # detected within one invariant-check period (checks also run at every
    # storage iteration, so the bound here is min(check_every, stage gap))
    assert 0 < er.detect_latency <= sdc.SDCPolicy().check_every
    assert er.detect_iter == it + er.detect_latency
    assert er.detector in ("residual", "orthogonality", "z-invariant",
                           "queue-checksum")
    assert not (er.sdc_violation <= er.sdc_tol)   # NaN-safe: it really fired
    _assert_rejoined(rep, reference)


def test_p_corruption_needs_the_orthogonality_invariant(problem, reference):
    """x and r are updated with the SAME corrupted direction, so r ≡ b − Ax
    is preserved and the residual detector is blind to p corruption — the
    rᵀp = rz identity is what catches it."""
    rep = solve_resilient(
        problem, strategy="esrp", T=10, rtol=1e-10,
        scenario=[SDCEvent(iter=33, nodes=(2,), target="p")])
    (er,) = _repairs(rep)
    assert er.detector == "orthogonality"
    _assert_rejoined(rep, reference)


def test_queue_corruption_never_perturbs_the_trajectory(problem, reference):
    """The corrupted copies ARE the redundancy: repair invalidates their
    slot (no rollback, zero wasted iterations) and the live trajectory is
    bit-identical to the reference."""
    rep = solve_resilient(
        problem, strategy="esrp", T=10, rtol=1e-10,
        scenario=[SDCEvent(iter=33, nodes=(1,), target="queue")])
    (er,) = _repairs(rep)
    assert er.detector == "queue-checksum"
    assert er.wasted_iters == 0
    assert rep.converged_iter == reference.converged_iter
    np.testing.assert_array_equal(np.asarray(rep.x),
                                  np.asarray(reference.x))


@pytest.mark.parametrize("kind,count", [("bitflip", 1), ("perturb", 4)])
def test_multi_node_corruption(problem, reference, kind, count):
    rep = solve_resilient(
        problem, strategy="esrp", T=10, rtol=1e-10,
        scenario=[SDCEvent(iter=33, nodes=(1, 3), target="r", kind=kind,
                           count=count, scale=1e-3)])
    assert len(_repairs(rep)) == 1
    _assert_rejoined(rep, reference)


def test_low_order_bitflip_below_detection_floor_is_harmless(problem,
                                                             reference):
    """A mantissa-tail flip (bit 0) sits below every invariant tolerance:
    undetectable by design, and numerically harmless — the run still
    converges to the reference solution at the solve tolerance."""
    rep = solve_resilient(
        problem, strategy="esrp", T=10, rtol=1e-10,
        scenario=[SDCEvent(iter=33, nodes=(1,), target="x", bit=0)])
    assert rep.converged
    assert _repairs(rep) == []
    err = float(jnp.linalg.norm(rep.x - reference.x))
    assert err <= 1e-8 * float(jnp.linalg.norm(reference.x))


def test_none_strategy_detects_and_restarts(problem):
    """strategy="none" has no queue to rebuild from: a detected corruption
    is repaired by a clean restart (target_iter = -1), still converging."""
    rep = solve_resilient(
        problem, strategy="none", rtol=1e-10,
        scenario=[SDCEvent(iter=33, nodes=(2,), target="x")])
    (er,) = _repairs(rep)
    assert er.target_iter == -1
    assert rep.converged


def test_staggered_failstop_then_sdc(problem, reference):
    from repro.core.failures import FailureEvent
    rep = solve_resilient(
        problem, strategy="esrp", T=10, rtol=1e-10,
        scenario=[FailureEvent(iter=25, nodes=(3,)),
                  SDCEvent(iter=45, nodes=(0,), target="r")])
    kinds = [e.kind for e in rep.events]
    assert kinds.count("fail-stop") == 1
    assert kinds.count("sdc-repair") == 1
    _assert_rejoined(rep, reference)


def test_max_repairs_guard(problem):
    """A zero-tolerance policy fires on reduction noise every check: the
    repair loop must hard-stop instead of spinning forever."""
    pol = sdc.SDCPolicy(check_every=4, res_rtol=0.0, max_repairs=2)
    with pytest.raises(RuntimeError, match="repair fired"):
        solve_resilient(problem, strategy="esrp", T=10, rtol=1e-10,
                        sdc_policy=pol)


# --------------------------------------------------------------------------- #
# false positives (satellite: the detectors never fire on a clean run)
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("precond", ["jacobi", "ssor", "chebyshev", "ic0"])
@pytest.mark.parametrize("backend", ["jnp", "interpret"])
def test_no_false_positives_clean_run(precond, backend):
    p = build_problem("poisson2d", n_nodes=4, nx=16, ny=16, precond=precond)
    for check_every in (5, 16):
        rep = solve_resilient(
            p, strategy="esrp", T=10, rtol=1e-9, backend=backend,
            sdc_policy=sdc.SDCPolicy(check_every=check_every))
        assert rep.converged
        if rep.converged_iter > check_every:   # ic0 can converge in 1 iter
            assert rep.sdc_checks > 0
        assert rep.sdc_check_every == check_every
        assert _repairs(rep) == [], (precond, backend, check_every,
                                     [e.detector for e in _repairs(rep)])


# --------------------------------------------------------------------------- #
# validation + unit pieces
# --------------------------------------------------------------------------- #
def test_sdc_validation(problem):
    ev = [SDCEvent(iter=30, nodes=(1,), target="p")]
    with pytest.raises(ValueError, match="esrp and none"):
        solve_resilient(problem, strategy="imcr", scenario=ev)
    with pytest.raises(ValueError, match="T=1"):
        solve_resilient(problem, strategy="esrp", T=1, scenario=ev)
    with pytest.raises(ValueError, match="no .*queue"):
        solve_resilient(problem, strategy="none",
                        scenario=[SDCEvent(iter=30, nodes=(1,),
                                           target="queue")])
    with pytest.raises(ValueError, match="check_every"):
        sdc.SDCPolicy(check_every=0)
    with pytest.raises(ValueError, match="target"):
        SDCEvent(iter=3, nodes=(0,), target="q")
    with pytest.raises(ValueError, match="kind"):
        SDCEvent(iter=3, nodes=(0,), kind="zap")
    with pytest.raises(ValueError, match="bit"):
        SDCEvent(iter=3, nodes=(0,), bit=64)


def test_detect_latency_lands_in_the_trace(problem, reference):
    """obs=on: the ``sdc_detect`` instant carries the SAME attributed
    latency as the EventReport, bounded by the check cadence (ISSUE 7
    satellite — latency is a first-class trace signal)."""
    rep = solve_resilient(
        problem, strategy="esrp", T=10, rtol=1e-10,
        scenario=[SDCEvent(iter=33, nodes=(1,), target="r")], obs=True)
    (er,) = _repairs(rep)
    instants = [e for e in rep.trace.events
                if e["name"] == "sdc_detect" and e["ph"] == "i"]
    assert len(instants) == 1
    a = instants[0]["args"]
    assert a["latency"] == er.detect_latency
    assert 0 < a["latency"] <= sdc.SDCPolicy().check_every
    assert a["detector"] == er.detector
    assert a["iter"] == er.detect_iter
    # it really fired: a non-finite violation serializes to None (jsonable)
    assert a["violation"] is None or not (a["violation"] <= a["tol"])
    # the repair event span follows the instant and nests the recovery
    from repro.obs import span_tree, walk_spans
    reps = [n for n in walk_spans(span_tree(rep.trace.events))
            if n["name"] == "event:sdc-repair"]
    assert len(reps) == 1
    assert reps[0]["args"]["detector"] == er.detector
    _assert_rejoined(rep, reference)


def test_bitflip_is_an_involution():
    v = jnp.asarray(np.random.default_rng(0).standard_normal(32))
    idx = np.asarray([3, 17])
    flipped = sdc._flip(v, idx, 62)
    assert float(jnp.max(jnp.abs(flipped - v))) > 0
    np.testing.assert_array_equal(np.asarray(sdc._flip(flipped, idx, 62)),
                                  np.asarray(v))
    # untouched entries are bit-identical
    mask = np.ones(32, bool)
    mask[idx] = False
    np.testing.assert_array_equal(np.asarray(flipped)[mask],
                                  np.asarray(v)[mask])


def test_overflowed_direction_norm_still_fires(problem):
    """‖p‖ overflowing to inf must FIRE the orthogonality detector, not
    hide the violation behind huge/inf → 0 (regression: a bit-62 exponent
    flip produced exactly this)."""
    ops = problem.solver_ops("jnp")
    from repro.core import esrp
    st = esrp.esrp_init(ops.matvec, ops.precond, problem.b, dot=ops.dot,
                        n_slabs=4)
    for _ in range(12):
        st, _ = esrp.run_chunk(st, ops, 10, 1, jnp.asarray(0.0), 0, True,
                               problem.b)
    huge = st.pcg.p.at[5].set(8.7e303)
    st = st._replace(pcg=st.pcg._replace(p=huge))
    det = sdc.run_checks(ops, st, problem.b, problem.part,
                         float(jnp.linalg.norm(problem.b)),
                         sdc.SDCPolicy())
    assert det is not None and det.detector == "orthogonality"
    assert det.violation == float("inf")
