"""Elastic shrunk-mesh recovery (core.elastic + driver integration).

When no replacement node exists, the run reconstructs on the original
layout (Alg. 2 — queue and plan are still valid for N nodes), then
re-partitions onto the survivors and continues. Under test:

  * ``shrunk_partition`` re-pads to the new divisibility unit, and the
    appended rows are decoupled identity rows (b = 0 there), so the shrunk
    system's solution restricted to the first M entries IS the original
    solution;
  * a multi-node simultaneous event (φ = 2) shrinks 4 → 2 and still
    converges to the reference solution, for EVERY preconditioner;
  * staggered shrinks (4 → 3 → 2) chain — each event re-partitions again;
  * the report records the shrink (EventReport.elastic_n_nodes,
    SolveReport.final_n_nodes) and elastic composes with SDC checks on the
    shrunk mesh;
  * validation: elastic needs esrp and the default problem-built ops, and
    an event naming a node beyond the shrunk mesh raises.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import elastic
from repro.core.driver import solve_resilient
from repro.core.failures import FailureEvent, SDCEvent
from repro.sparse.matrices import build_problem
from repro.sparse.partition import shrunk_partition


@pytest.fixture(scope="module")
def problem():
    return build_problem("poisson2d", n_nodes=4, nx=24, ny=24)


@pytest.fixture(scope="module")
def reference(problem):
    return solve_resilient(problem, strategy="esrp", T=10, rtol=1e-10)


def _assert_matches(rep, problem, reference, tol=1e-9):
    assert rep.converged
    m = problem.part.m
    x = np.asarray(rep.x)
    # padding rows are decoupled identities with b = 0: they stay exactly 0
    np.testing.assert_array_equal(x[m:], 0.0)
    err = float(np.linalg.norm(x[:m] - np.asarray(reference.x)))
    assert err <= tol * max(float(jnp.linalg.norm(reference.x)), 1.0), err


# --------------------------------------------------------------------------- #
def test_shrunk_partition_padding_rule():
    from repro.sparse.partition import Partition
    part = Partition(m=576, n_nodes=4, bm=8, bn=8)
    p3 = shrunk_partition(part, 3)            # lcm(8,8)·3 = 24 | 576
    assert (p3.m, p3.n_nodes) == (576, 3)
    p3b = shrunk_partition(part, 3, precond_block=5)   # unit 120 ∤ 576
    assert p3b.m == 600 and p3b.m % (3 * 40) == 0
    with pytest.raises(ValueError, match="1 <= n_new"):
        shrunk_partition(part, 4)
    with pytest.raises(ValueError, match="1 <= n_new"):
        shrunk_partition(part, 0)


def test_shrink_problem_appends_identity_rows(problem):
    shrunk = elastic.shrink_problem(problem, 3)
    m, m_new = problem.part.m, shrunk.part.m
    assert shrunk.part.n_nodes == 3 and m_new >= m
    # same system on the first m entries, identity + zero rhs on the pad
    np.testing.assert_array_equal(np.asarray(shrunk.b)[:m],
                                  np.asarray(problem.b))
    np.testing.assert_array_equal(np.asarray(shrunk.b)[m:], 0.0)
    rows, cols, vals = shrunk.coo
    pad = rows >= m
    np.testing.assert_array_equal(rows[pad], cols[pad])
    np.testing.assert_array_equal(vals[pad], 1.0)
    assert shrunk.precond_name == problem.precond_name
    # cached: the second shrink to the same count is the same object
    assert elastic.shrink_problem(problem, 3) is shrunk


def test_elastic_single_node_shrink(problem, reference):
    rep = solve_resilient(problem, strategy="esrp", T=10, rtol=1e-10,
                          elastic=True,
                          scenario=[FailureEvent(iter=35, nodes=(2,))])
    assert rep.final_n_nodes == 3
    assert rep.events[0].elastic_n_nodes == 3
    _assert_matches(rep, problem, reference)


@pytest.mark.parametrize("precond,nx,T,fail_iter", [
    ("jacobi", 24, 10, 15), ("ssor", 24, 10, 15), ("chebyshev", 24, 10, 15),
    ("ic0", 64, 4, 8),     # ic0 converges in ~6 iterations on the 24² grid —
    #                        too fast for any completed storage stage; the
    #                        64² grid takes ~15, so the T=4 stage (stars at
    #                        j=5) completes before the event at 8
])
def test_elastic_multi_node_per_preconditioner(precond, nx, T, fail_iter):
    """≥1 multi-node scenario per preconditioner: φ=2 sustains a 2-node
    simultaneous loss; the run continues 4 → 2 and converges."""
    p = build_problem("poisson2d", n_nodes=4, nx=nx, ny=nx, precond=precond)
    ref = solve_resilient(p, strategy="esrp", T=T, phi=2, rtol=1e-10)
    rep = solve_resilient(p, strategy="esrp", T=T, phi=2, rtol=1e-10,
                          elastic=True,
                          scenario=[FailureEvent(iter=fail_iter,
                                                 nodes=(1, 2))])
    assert rep.converged
    assert rep.final_n_nodes == 2
    m = p.part.m
    err = float(np.linalg.norm(np.asarray(rep.x)[:m] - np.asarray(ref.x)))
    assert err <= 1e-9 * max(float(jnp.linalg.norm(ref.x)), 1.0), (precond,
                                                                   err)


def test_elastic_staggered_chain(problem, reference):
    """4 → 3 → 2 across two events; the second event's node id must refer
    to the SHRUNK mesh."""
    rep = solve_resilient(problem, strategy="esrp", T=10, rtol=1e-10,
                          elastic=True,
                          scenario=[FailureEvent(iter=20, nodes=(3,)),
                                    FailureEvent(iter=50, nodes=(1,))])
    assert [e.elastic_n_nodes for e in rep.events] == [3, 2]
    assert rep.final_n_nodes == 2
    _assert_matches(rep, problem, reference)


def test_elastic_with_sdc_on_shrunk_mesh(problem, reference):
    rep = solve_resilient(problem, strategy="esrp", T=10, rtol=1e-10,
                          elastic=True,
                          scenario=[FailureEvent(iter=20, nodes=(3,)),
                                    SDCEvent(iter=45, nodes=(0,),
                                             target="p")])
    assert rep.final_n_nodes == 3
    assert [e.kind for e in rep.events].count("sdc-repair") == 1
    _assert_matches(rep, problem, reference)


def test_elastic_validation(problem):
    with pytest.raises(ValueError, match="esrp strategy"):
        solve_resilient(problem, strategy="imcr", elastic=True,
                        scenario=[FailureEvent(iter=10, nodes=(1,))])
    with pytest.raises(ValueError, match="default problem-built ops"):
        solve_resilient(problem, strategy="esrp", elastic=True,
                        matvec=lambda v: v,
                        scenario=[FailureEvent(iter=10, nodes=(1,))])
    # node id beyond the shrunk mesh: valid at scenario-build time (4
    # nodes), detected at fire time (3 nodes left)
    with pytest.raises(ValueError, match="outside the current"):
        solve_resilient(problem, strategy="esrp", T=10, elastic=True,
                        scenario=[FailureEvent(iter=10, nodes=(0,)),
                                  FailureEvent(iter=30, nodes=(3,))])
