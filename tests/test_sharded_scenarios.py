"""Device-resident failure runtime on the 8-device mesh.

Slow suite (subprocess, ``--xla_force_host_platform_device_count=8``):

  * the physical redundancy queue (``redundancy_queue``) delivers every
    copy the plan says a node holds — values checked tile by tile;
  * multi-event / multi-node scenarios (simultaneous φ=2, staggered,
    burst-before-the-next-storage-stage, IMCR staggered, SSOR with twin
    adoption + reload accounting, Chebyshev) each rejoin the single-device
    ``mesh_mirror_ops`` reference trajectory **bit-identically in f64**;
  * the consumed recovery copies are read from *surviving devices'* queue
    shards: ``EventReport.queue_src_nodes`` is non-empty and disjoint from
    the failed set, and a burst whose only physical copy was wiped by the
    previous event raises — while the host-side static plan calls the same
    scenario survivable (the device-resident vs static-plan gap);
  * twin adoption invalidates ``_sharded_ops_cache`` entries built on an
    equal-size mesh before the adoption (regression).

Fast host-side tests cover ``RedundancyPlan.copy_sources`` and the
per-preconditioner ``static_reload_bytes`` accounting.
"""
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core.aspmv import build_plan
from repro.precond.local import static_reload_bytes
from repro.sparse.matrices import build_problem
from repro.sparse.partition import neighbor


# --------------------------------------------------------------------------- #
# host-side: copy sourcing + reload accounting
# --------------------------------------------------------------------------- #
def test_copy_sources_reads_surviving_holders():
    p = build_problem("poisson2d", n_nodes=8, nx=32)
    plan = build_plan(p.a, p.part, phi=1)
    tiles, src = plan.copy_sources([3])
    lo, hi = p.part.node_col_tiles(3)
    np.testing.assert_array_equal(tiles, np.arange(lo, hi))
    assert (src != 3).all()
    for t, d in zip(tiles, src):
        assert plan.holders[t, d], (t, d)
    # the designated neighbour d_{3,1} = 4 serves every tile it holds
    d1 = neighbor(3, 1, 8)
    held_by_d1 = plan.holders[tiles, d1]
    np.testing.assert_array_equal(src[held_by_d1], d1)


def test_copy_sources_stale_copy_is_not_a_source():
    """A holder whose physical entry was wiped (valid=False) must not be
    chosen; if it was the only copy, the event is physically unrecoverable
    even though the static plan (check_event) calls it survivable."""
    p = build_problem("poisson2d", n_nodes=8, nx=32)
    plan = build_plan(p.a, p.part, phi=1)
    plan.check_event([3])                      # static plan: survivable
    valid = np.ones(8, bool)
    valid[2] = False                           # node 2's copies are stale
    with pytest.raises(RuntimeError, match="dead or stale"):
        plan.copy_sources([3], valid)
    # with node 2 fresh the same event sources fine
    tiles, src = plan.copy_sources([3], np.ones(8, bool))
    assert 2 in set(src.tolist())              # the boundary tile needs it


def test_copy_sources_multi_node_union():
    p = build_problem("poisson2d", n_nodes=8, nx=32)
    plan = build_plan(p.a, p.part, phi=2)
    tiles, src = plan.copy_sources([2, 5])
    assert tiles.size == 2 * p.part.col_tiles_per_node
    assert not set(src.tolist()) & {2, 5}      # only survivors serve copies


def test_static_reload_bytes_per_preconditioner():
    item = 8                                   # f64
    pj = build_problem("poisson2d", n_nodes=8, nx=32)
    desc, nb = static_reload_bytes(pj, [1, 4])
    blocks = 2 * pj.part.rows_per_node // pj.precond_block
    assert nb == blocks * pj.precond_block ** 2 * item
    assert "jacobi" in desc

    pc = build_problem("poisson2d", n_nodes=8, nx=32, precond="chebyshev")
    desc, nb = static_reload_bytes(pc, [1])
    assert nb == 0 and "replicated" in desc

    ps = build_problem("poisson2d", n_nodes=8, nx=32, precond="ssor",
                       precond_opts={"node_local": True})
    desc, nb = static_reload_bytes(ps, [3])
    assert nb > 0 and "ssor" in desc
    # two failed slabs reload twice the strips of one (equal slabs)
    _, nb2 = static_reload_bytes(ps, [3, 5])
    assert nb2 == pytest.approx(2 * nb, rel=0.2)

    pg = build_problem("poisson2d", n_nodes=8, nx=32, precond="ssor")
    with pytest.raises(RuntimeError, match="node-local twin"):
        static_reload_bytes(pg, [3])           # global strips span slabs


# --------------------------------------------------------------------------- #
# 8-device parity suite
# --------------------------------------------------------------------------- #
_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
jax.config.update("jax_enable_x64", True)
import numpy as np, jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.comm.shard import (ShardedFailureRuntime, mesh_mirror_ops,
                              nodes_mesh, place_problem, redundancy_queue,
                              sharded_solver_ops)
from repro.core.aspmv import build_plan
from repro.core.driver import solve_resilient
from repro.core.failures import FailureEvent
from repro.sparse.matrices import build_problem

mesh = nodes_mesh(8)
problem = build_problem("poisson2d", n_nodes=8, nx=40, ny=40)
placed = place_problem(problem, mesh)
mirror = mesh_mirror_ops(problem, 8)
with mesh:
    ops = sharded_solver_ops(placed, mesh)

# --- 0) the physical queue: every plan-held copy is delivered verbatim ----
plan = build_plan(problem.a, problem.part, phi=2)
hold_idx, push = redundancy_queue(plan, problem.part, mesh)
rng = np.random.default_rng(0)
x = rng.standard_normal(problem.m)
xs = jax.device_put(jnp.asarray(x), NamedSharding(mesh, P("nodes")))
with mesh:
    entry = np.asarray(push(xs))
xt = x.reshape(-1, problem.part.bn)
owner = problem.part.owner_of_col_tile(np.arange(problem.part.col_tiles))
checked = 0
for d in range(8):
    held = set()
    for slot, t in enumerate(hold_idx[d]):
        if t < 0:
            continue
        np.testing.assert_array_equal(entry[d, slot], xt[t])
        assert plan.holders[t, d] and owner[t] != d
        held.add(int(t))
        checked += 1
    # completeness: every copy the plan assigns node d is physically present
    assert held == set(np.nonzero(plan.holders[:, d] & (owner != d))[0])
assert checked > 100, checked
print("QUEUE_OK", checked)

def run_pair(scenario, strategy="esrp", T=20, phi=1, prob=problem,
             plc=placed, op=ops, mir=mirror, rtol=1e-10):
    frt = ShardedFailureRuntime(plc, mesh)
    with mesh:
        r = solve_resilient(plc, strategy=strategy, T=T, phi=phi, rtol=rtol,
                            ops=op, scenario=list(scenario),
                            failure_runtime=frt)
    rm = solve_resilient(prob, strategy=strategy, T=T, phi=phi, rtol=rtol,
                         ops=mir, scenario=list(scenario))
    assert r.converged_iter == rm.converged_iter, (r.converged_iter,
                                                   rm.converged_iter)
    assert (np.asarray(r.x) == np.asarray(rm.x)).all(), \
        "sharded run did not rejoin the mesh-mirror trajectory bitwise"
    for e in r.events:
        if e.target_iter >= 0 and strategy == "esrp":
            # consumed copies came from surviving devices' shards (IMCR
            # recovers from buddy checkpoints, not the ESRP queue)
            assert e.queue_src_nodes, e
            assert not set(e.queue_src_nodes) & set(e.nodes), e
    return r, rm

ref = solve_resilient(problem, strategy="none", rtol=1e-10, ops=mirror)
C = ref.converged_iter

# --- 1) simultaneous phi=2 multi-node ---
r, rm = run_pair([FailureEvent(C // 2, (2, 5))], phi=2)
assert r.converged_iter == C
print("SIMULTANEOUS_OK", r.events[0].queue_src_nodes)

# --- 2) staggered two-event ESRP ---
r, _ = run_pair([FailureEvent(45, (2,)), FailureEvent(70, (5,))])
assert [e.target_iter for e in r.events] == [41, 61]
assert r.converged_iter == C
print("STAGGERED_OK")

# --- 3) burst: 2nd event before the next storage stage completes ---
r, _ = run_pair([FailureEvent(58, (2,)), FailureEvent(59, (5,))])
assert [e.target_iter for e in r.events] == [41, 41]
assert r.converged_iter == C
print("BURST_OK")

# --- 4) device-resident survival is stricter than the static plan: node 3's
# boundary-tile copy lives only on node 2, which the first event wiped and
# no storage push has refreshed ---
frt = ShardedFailureRuntime(placed, mesh)
raised = False
try:
    with mesh:
        solve_resilient(placed, strategy="esrp", T=20, phi=1, rtol=1e-10,
                        ops=ops, failure_runtime=frt,
                        scenario=[FailureEvent(58, (2,)),
                                  FailureEvent(59, (3,))])
except RuntimeError as e:
    raised = "dead or stale" in str(e)
assert raised
# ... while the host-side simulator (static plan only) survives it
rh = solve_resilient(problem, strategy="esrp", T=20, phi=1, rtol=1e-10,
                     scenario=[FailureEvent(58, (2,)), FailureEvent(59, (3,))])
assert rh.converged_iter == ref.converged_iter
print("STALE_COPY_OK")

# --- 4b) regression: staleness is judged per READ slot, not the newest tag.
# The second event lands exactly on the next stage's FIRST push (iter 60):
# the queue then holds tags [40, 41, 60], recovery needs the consecutive
# (40, 41) pair — whose node-2 rows the first event zeroed — while the tag-60
# entry is fresh. Validating against the newest tag would declare node 2 a
# valid source and silently reconstruct node 1's interior tiles from zeros.
frt = ShardedFailureRuntime(placed, mesh)
raised = False
try:
    with mesh:
        solve_resilient(placed, strategy="esrp", T=20, phi=1, rtol=1e-10,
                        ops=ops, failure_runtime=frt,
                        scenario=[FailureEvent(58, (2,)),
                                  FailureEvent(60, (1,))])
except RuntimeError as e:
    raised = "dead or stale" in str(e)
assert raised
rh = solve_resilient(problem, strategy="esrp", T=20, phi=1, rtol=1e-10,
                     scenario=[FailureEvent(58, (2,)), FailureEvent(60, (1,))])
assert rh.converged_iter == ref.converged_iter
print("STALE_SLOT_TAG_OK")

# --- 5) IMCR staggered multi-node (shard_map injection) ---
r, _ = run_pair([FailureEvent(45, (5, 6)), FailureEvent(70, (1,))],
                strategy="imcr", phi=2)
assert [e.target_iter for e in r.events] == [40, 60]
print("IMCR_OK")

# --- 6) SSOR: twin adoption + slab reload accounting; Chebyshev: replicated
# bounds, zero reload ---
for name, expect_reload in (("ssor", True), ("chebyshev", False)):
    p2 = build_problem("poisson2d", n_nodes=8, nx=40, precond=name)
    plc2 = place_problem(p2, mesh)
    with mesh:
        op2 = sharded_solver_ops(plc2, mesh)
    mir2 = mesh_mirror_ops(plc2, 8)
    ref2 = solve_resilient(plc2, strategy="none", rtol=1e-9, ops=mir2)
    T = 10
    J = (ref2.converged_iter // 2 // T) * T + T - 2
    r, _ = run_pair([FailureEvent(J, (2, 5))], T=T, phi=2, prob=plc2,
                    plc=plc2, op=op2, mir=mir2, rtol=1e-9)
    assert r.converged_iter == ref2.converged_iter
    assert (r.precond_reload_bytes > 0) == expect_reload, name
    print(f"PRECOND_OK {name} reload={r.precond_reload_bytes}")

# --- 7) regression: twin adoption invalidates same-size-mesh ops entries ---
p3 = build_problem("poisson2d", n_nodes=8, nx=40, precond="ssor")
plc3 = place_problem(p3, mesh)
mesh_b = Mesh(np.asarray(jax.devices())[::-1], ("nodes",))  # equal size
sentinel = object()
plc3._sharded_ops_cache = {mesh_b: sentinel}     # entry built pre-adoption
with mesh:
    op3 = sharded_solver_ops(plc3, mesh)         # triggers the adoption
assert "auto twin" in op3.variant
cache = plc3._sharded_ops_cache
assert sentinel not in cache.values()            # stale entry dropped
assert cache[mesh] is op3                        # fresh entry still cached
with mesh:
    assert sharded_solver_ops(plc3, mesh) is op3
print("CACHE_INVALIDATION_OK")

print("SHARDED_SCENARIOS_OK")
"""


@pytest.mark.slow
def test_sharded_scenarios_eight_devices():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    out = subprocess.run([sys.executable, "-c", _SCRIPT], cwd=".",
                         env=env, capture_output=True, text=True,
                         timeout=560)
    assert out.returncode == 0, out.stderr[-4000:]
    for tag in ("QUEUE_OK", "SIMULTANEOUS_OK", "STAGGERED_OK", "BURST_OK",
                "STALE_COPY_OK", "STALE_SLOT_TAG_OK", "IMCR_OK",
                "CACHE_INVALIDATION_OK", "SHARDED_SCENARIOS_OK"):
        assert tag in out.stdout, (tag, out.stdout)
