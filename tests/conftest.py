import jax

# Solver fidelity (the paper runs double precision); explicit dtypes in the
# LM stack are unaffected. Smoke tests must see 1 CPU device — the dry-run
# (and only the dry-run) forces 512 host devices in its own process.
jax.config.update("jax_enable_x64", True)
