"""PCG core: convergence, drift metric, operator plumbing."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.pcg import pcg_init, pcg_step, residual_drift, run_pcg
from repro.sparse.matrices import build_problem


def _dense_spd(n, seed=0):
    rng = np.random.default_rng(seed)
    a = rng.standard_normal((n, n))
    return a @ a.T + n * np.eye(n)


def test_pcg_matches_direct_solve():
    a = _dense_spd(64)
    b = np.random.default_rng(1).standard_normal(64)
    matvec = lambda x: jnp.asarray(a) @ x
    precond = lambda r: r / jnp.asarray(np.diag(a))
    state, rel = run_pcg(matvec, precond, jnp.asarray(b), rtol=1e-12)
    x_direct = np.linalg.solve(a, b)
    assert rel < 1e-12
    np.testing.assert_allclose(np.asarray(state.x), x_direct, rtol=1e-8)


def test_pcg_blockell_poisson():
    p = build_problem("poisson2d", n_nodes=4, nx=24, ny=24)
    state, rel = run_pcg(p.a.matvec, p.apply_precond, p.b, rtol=1e-10)
    assert rel < 1e-10
    true_res = np.linalg.norm(np.asarray(p.b) - p.a.to_dense()
                              @ np.asarray(state.x))
    assert true_res / np.linalg.norm(np.asarray(p.b)) < 1e-9


def test_residual_drift_small_when_converged():
    p = build_problem("poisson2d", n_nodes=4, nx=16, ny=16)
    state, _ = run_pcg(p.a.matvec, p.apply_precond, p.b, rtol=1e-10)
    d = float(residual_drift(p.a.matvec, p.b, state.x, state.r))
    assert abs(d) < 1e-2


def test_pcg_step_iterates_counter():
    p = build_problem("poisson2d", n_nodes=4, nx=16, ny=16)
    st = pcg_init(p.a.matvec, p.apply_precond, p.b)
    st2 = pcg_step(st, p.a.matvec, p.apply_precond)
    assert int(st2.j) == 1
    assert float(jnp.linalg.norm(st2.r)) < float(jnp.linalg.norm(st.r))
