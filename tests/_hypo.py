"""Hypothesis mini-shim: used only when the real package is unavailable
offline. API-compatible subset: @given over strategies with seeded random
sampling (fixed example count), @settings no-op, st.integers/floats/sampled_
from/tuples/composite. Property tests are written against the real API and
run unchanged when hypothesis is installed.
"""
from __future__ import annotations

import functools
import inspect
import random

try:                                      # pragma: no cover
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    class _Strategy:
        def __init__(self, sample):
            self.sample = sample

        def map(self, f):
            return _Strategy(lambda rng: f(self.sample(rng)))

        def filter(self, pred):
            def sample(rng):
                for _ in range(1000):
                    v = self.sample(rng)
                    if pred(v):
                        return v
                raise ValueError("filter failed to find a value")
            return _Strategy(sample)

    class st:  # noqa: N801
        @staticmethod
        def integers(min_value=0, max_value=100):
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def floats(min_value=0.0, max_value=1.0, **_):
            return _Strategy(lambda rng: rng.uniform(min_value, max_value))

        @staticmethod
        def sampled_from(seq):
            seq = list(seq)
            return _Strategy(lambda rng: rng.choice(seq))

        @staticmethod
        def tuples(*strats):
            return _Strategy(lambda rng: tuple(s.sample(rng) for s in strats))

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: rng.random() < 0.5)

        @staticmethod
        def composite(f):
            def builder(*args, **kw):
                def sample(rng):
                    draw = lambda strat: strat.sample(rng)
                    return f(draw, *args, **kw)
                return _Strategy(sample)
            return builder

    def given(*gstrats, **kwstrats):
        def deco(f):
            @functools.wraps(f)
            def wrapper(*args, **kwargs):
                rng = random.Random(0xE5B)
                n = getattr(f, "_max_examples", 25)
                for _ in range(n):
                    vals = [s.sample(rng) for s in gstrats]
                    kvals = {k: s.sample(rng) for k, s in kwstrats.items()}
                    f(*args, *vals, **kwargs, **kvals)
            # pytest resolves fixtures from the *visible* signature. Hide the
            # strategy-drawn parameters (like real hypothesis does) so only
            # genuine fixture params remain; otherwise every @given test
            # errors with "fixture '<param>' not found".
            params = list(inspect.signature(f).parameters.values())
            if gstrats:          # positional strategies consume from the end
                params = params[:-len(gstrats)]
            params = [p for p in params if p.name not in kwstrats]
            del wrapper.__wrapped__
            wrapper.__signature__ = inspect.Signature(params)
            return wrapper
        return deco

    def settings(max_examples=25, **_):
        def deco(f):
            f._max_examples = max_examples
            return f
        return deco
