"""Node-local (additive-Schwarz) preconditioner variants and the sharded
runtime's non-Jacobi acceptance: slab-restriction structure, twin building,
single-device recovery exactness, and (slow, 8 host devices) parity of the
shard_map sweeps against the single-device node-local reference."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.driver import solve_resilient
from repro.precond import local as plocal
from repro.sparse.matrices import build_problem


def test_intra_node_mask_keeps_only_intra_slab_entries():
    from repro.sparse.partition import Partition

    part = Partition(m=100, n_nodes=4, bm=5, bn=5)
    rng = np.random.default_rng(0)
    rows = rng.integers(0, 100, 500)
    cols = rng.integers(0, 100, 500)
    keep = part.intra_node_mask(rows, cols)
    np.testing.assert_array_equal(keep, (rows // 25) == (cols // 25))
    assert 0 < int(keep.sum()) < 500


@pytest.mark.parametrize("name", ("ssor", "ic0"))
def test_node_local_build_is_slab_local(name):
    p_loc = build_problem("poisson2d", n_nodes=4, nx=40, precond=name,
                          precond_opts={"node_local": True})
    p_glob = build_problem("poisson2d", n_nodes=4, nx=40, precond=name)
    assert plocal.precond_is_node_local(p_loc.precond, 4)
    assert not plocal.precond_is_node_local(p_glob.precond, 4)


def test_node_local_rejected_for_chebyshev():
    with pytest.raises(ValueError, match="node_local"):
        build_problem("poisson2d", n_nodes=4, nx=40, precond="chebyshev",
                      precond_opts={"node_local": True})


def test_node_local_twin_matches_node_local_build():
    """The auto-built twin of a global SSOR instance is bit-identical to
    building with precond_opts={"node_local": True} directly."""
    p_glob = build_problem("poisson2d", n_nodes=4, nx=40, precond="ssor",
                          precond_opts={"omega": 1.3})
    p_loc = build_problem("poisson2d", n_nodes=4, nx=40, precond="ssor",
                         precond_opts={"omega": 1.3, "node_local": True})
    twin = plocal.node_local_twin(p_glob)
    assert plocal.precond_is_node_local(twin, 4)
    assert twin.omega == 1.3
    assert plocal.node_local_twin(p_glob) is twin          # cached
    rng = np.random.default_rng(1)
    r = jnp.asarray(rng.standard_normal(p_glob.m))
    np.testing.assert_array_equal(np.asarray(twin.apply(r)),
                                  np.asarray(p_loc.precond.apply(r)))


@pytest.mark.parametrize("name", ("ssor", "ic0"))
def test_node_local_is_weaker_but_converges(name):
    """Additive Schwarz drops coupling, so it needs >= the global variant's
    iterations — but still beats unpreconditioned block-Jacobi-style decay
    and still converges to the same tolerance."""
    kw = dict(nx=40)
    it = {}
    for local in (False, True):
        p = build_problem("poisson2d", n_nodes=4, precond=name,
                          precond_opts={"node_local": local}, **kw)
        rep = solve_resilient(p, strategy="none", rtol=1e-9)
        assert rep.rel_residual < 1e-9
        it[local] = rep.converged_iter
    assert it[True] >= it[False]


def test_node_local_recovery_exact_midstage():
    """Mid-stage failure with the node-local SSOR: Alg. 2 through the
    generic preconditioner-aware path (with the preconditioned P_ff inner
    solve) must rejoin the failure-free trajectory exactly — the failed
    slab decouples, so line 5 is exactly zero and the algebra is the
    clean additive-Schwarz case."""
    p = build_problem("poisson2d", n_nodes=4, nx=40, precond="ssor",
                      precond_opts={"node_local": True})
    ref = solve_resilient(p, strategy="none", rtol=1e-9, chunk=16)
    C = ref.converged_iter
    T = 5
    fail_at = max(2 * T, (C // 2 // T) * T)
    assert fail_at < C
    r = solve_resilient(p, strategy="esrp", T=T, phi=1, rtol=1e-9, chunk=16,
                        fail_at=fail_at, failed_nodes=[2])
    assert r.converged_iter == C
    assert r.rel_residual < 1e-9
    assert r.events[0].pff_iters > 0          # the line-6 inner CG ran


def test_ring_halo_matvec_validates_halo_width():
    """halo_tiles > col_tiles_per_node made xt[-halo_tiles:] silently slice
    the whole slab and fail later with an opaque concat shape error (and
    halo_tiles = 0 the empty one) — both must be rejected at build time,
    before any mesh communication is set up."""
    from repro.comm import shard

    p = build_problem("poisson2d", n_nodes=8, nx=40)
    mesh = shard.nodes_mesh(1)                 # never reached: checks first
    cpt = p.part.col_tiles_per_node
    for bad in (0, cpt + 1, 10 * cpt):
        with pytest.raises(ValueError, match="halo_tiles"):
            shard.ring_halo_matvec(p.a, p.part, mesh, halo_tiles=bad)
    # the boundary value is accepted (the existing 8-device test uses it)
    shard.ring_halo_matvec(p.a, p.part, mesh, halo_tiles=cpt)


def test_ring_halo_matvec_rejects_single_node_ring():
    """A 1-node 'ring' would ppermute both halos to itself (silent zeros)."""
    from repro.comm import shard

    p = build_problem("poisson2d", n_nodes=1, nx=40)
    mesh = shard.nodes_mesh(1)
    with pytest.raises(ValueError, match=">= 2 nodes"):
        shard.ring_halo_matvec(p.a, p.part, mesh, halo_tiles=1)


def test_sharded_sweeps_reject_mesh_partition_mismatch():
    """The shard_map index shift assumes one partition slab per mesh device;
    a mismatched mesh must fail loudly instead of clamping cross-shard loads
    to wrong blocks."""
    from repro.comm import shard

    p = build_problem("poisson2d", n_nodes=4, nx=40, precond="ssor")
    mesh = shard.nodes_mesh(1)
    with pytest.raises(ValueError, match="one partition slab per mesh"):
        shard.sharded_solver_ops(p, mesh)


_SHARD_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
jax.config.update("jax_enable_x64", True)
import numpy as np, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.comm.shard import (attach_local_delta, nodes_mesh, place_problem,
                              sharded_solver_ops)
from repro.core.driver import solve_resilient
from repro.sparse.matrices import build_problem

assert len(jax.devices()) == 8
mesh = nodes_mesh(8)
rng = np.random.default_rng(0)

for name in ("ssor", "ic0", "chebyshev"):
    opts = {"node_local": True} if name != "chebyshev" else None
    p = build_problem("poisson2d", n_nodes=8, nx=40, precond=name,
                      precond_opts=opts)
    p_glob = build_problem("poisson2d", n_nodes=8, nx=40, precond=name)
    ref_glob = solve_resilient(p_glob, strategy="none", rtol=1e-10)
    ref_loc = solve_resilient(p, strategy="none", rtol=1e-10)
    placed = place_problem(p, mesh)
    with mesh:
        ops = sharded_solver_ops(placed, mesh)
        r = solve_resilient(placed, strategy="none", rtol=1e-10, ops=ops)
    # parity vs the single-device node-local reference
    assert r.converged_iter == ref_loc.converged_iter, (
        name, r.converged_iter, ref_loc.converged_iter)
    assert r.rel_residual < 1e-10
    attach_local_delta(r, ref_glob)
    assert r.local_delta_iters == r.converged_iter - ref_glob.converged_iter
    assert r.precond_variant, name
    if name != "chebyshev":
        # the shard_map sweeps are bitwise the single-device apply
        x = jnp.asarray(rng.standard_normal(p.m))
        z_ref = p.precond.apply(x, backend="jnp")
        with mesh:
            z_sh = ops.precond(jax.device_put(x, NamedSharding(mesh, P("nodes"))))
        assert (np.asarray(z_ref) == np.asarray(z_sh)).all(), name
        assert r.local_delta_iters >= 0, (name, r.local_delta_iters)
    print(f"{name}: iters={r.converged_iter} delta={r.local_delta_iters} "
          f"variant={r.precond_variant}")

# ESRP failure + Alg. 2 recovery on the sharded runtime (node-local ssor):
# must rejoin the single-device node-local trajectory exactly
p = build_problem("poisson2d", n_nodes=8, nx=40, precond="ssor",
                  precond_opts={"node_local": True})
ref = solve_resilient(p, strategy="none", rtol=1e-10)
placed = place_problem(p, mesh)
with mesh:
    ops = sharded_solver_ops(placed, mesh)
    r = solve_resilient(placed, strategy="esrp", T=10, phi=1, rtol=1e-10,
                        ops=ops, fail_at=(ref.converged_iter // 2 // 10) * 10,
                        failed_nodes=[3])
assert r.converged_iter == ref.converged_iter, (r.converged_iter,
                                                ref.converged_iter)
assert r.rel_residual < 1e-10

# auto-twin adoption: a *global* ssor problem is accepted; the bundle swaps
# in the node-local twin, records it, and drops closures cached against the
# replaced global operator
p2 = build_problem("poisson2d", n_nodes=8, nx=40, precond="ssor")
placed2 = place_problem(p2, mesh)
placed2.solver_ops("jnp")                 # cache bound to the global apply
assert hasattr(placed2, "_ops_cache")
with mesh:
    ops2 = sharded_solver_ops(placed2, mesh)
    r2 = solve_resilient(placed2, strategy="none", rtol=1e-10, ops=ops2)
assert "auto twin" in ops2.variant, ops2.variant
assert not hasattr(placed2, "_ops_cache")            # stale caches cleared
assert r2.rel_residual < 1e-10
from repro.precond.local import precond_is_node_local
assert precond_is_node_local(placed2.precond, 8)     # adopted problem-wide
assert r2.converged_iter == ref.converged_iter       # == node-local ref

print("SHARD_LOCAL_OK")
"""


@pytest.mark.slow
def test_sharded_non_jacobi_parity_eight_devices():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    out = subprocess.run([sys.executable, "-c", _SHARD_SCRIPT], cwd=".",
                         env=env, capture_output=True, text=True,
                         timeout=560)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "SHARD_LOCAL_OK" in out.stdout
