"""Batched beyond-fail-stop on the 8-device mesh (subprocess suite).

Mirrors tests/test_sdc_mesh.py for the batched stack:

  * a mid-iteration SDCEvent in a B=3 batched mesh solve (device-resident
    ``ShardedFailureRuntime`` with per-member ``rq_sums`` checksums) is
    detected within one check period and repaired — every member rejoins
    the clean batched mesh trajectory;
  * batched queue corruption also corrupts the physical holder's ``rq``
    rows for every member; the per-member checksums flag it and the slot
    invalidation leaves the live trajectory bit-identical;
  * a batched elastic shrink on the 8-node partition re-partitions the
    whole (B, …) state tree onto 7 nodes and every member keeps solving,
    rejoining its own B=1 elastic run norm-wise.
"""
import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
jax.config.update("jax_enable_x64", True)
import numpy as np, jax.numpy as jnp

from repro.comm.shard import (ShardedFailureRuntime, nodes_mesh,
                              place_problem, sharded_solver_ops)
from repro.core.driver import solve_resilient
from repro.core.failures import FailureEvent, SDCEvent
from repro.sparse.matrices import build_problem

B = 3
mesh = nodes_mesh(8)
problem = build_problem("poisson2d", n_nodes=8, nx=40, ny=40)
placed = place_problem(problem, mesh)
with mesh:
    ops_b = sharded_solver_ops(placed, mesh, batch=B)

rng = np.random.default_rng(7)
rhs = rng.standard_normal((B, problem.m))
rhs[1] *= 40.0

frt = ShardedFailureRuntime(placed, mesh, batch=B)
with mesh:
    clean = solve_resilient(placed, strategy="esrp", T=10, phi=2,
                            rtol=1e-10, ops=ops_b, failure_runtime=frt,
                            rhs=jnp.asarray(rhs))
xs = [np.asarray(r.x) for r in clean]
scales = [max(float(np.linalg.norm(x)), 1.0) for x in xs]

# --- 1) batched SDC on the mesh: detect within the cadence, rejoin -------
for tgt in ("r", "queue"):
    frt = ShardedFailureRuntime(placed, mesh, batch=B)
    with mesh:
        reps = solve_resilient(placed, strategy="esrp", T=10, phi=2,
                               rtol=1e-10, ops=ops_b, failure_runtime=frt,
                               rhs=jnp.asarray(rhs),
                               scenario=[SDCEvent(iter=33, nodes=(2,),
                                                  target=tgt)])
    ers = [e for e in reps[0].events if e.kind == "sdc-repair"]
    assert len(ers) == 1, (tgt, [e.kind for e in reps[0].events])
    assert 0 < ers[0].detect_latency <= 16, (tgt, ers[0].detect_latency)
    for k in range(B):
        assert reps[k].converged, (tgt, k)
        assert reps[k].converged_iter == clean[k].converged_iter, (tgt, k)
        err = float(np.linalg.norm(np.asarray(reps[k].x) - xs[k]))
        assert err <= 1e-10 * scales[k], (tgt, k, err)
    if tgt == "queue":
        # per-member rq checksums flagged the physical copies; the live
        # trajectory is untouched (slot invalidation, zero rollback)
        assert ers[0].detector == "queue-checksum", ers[0].detector
        assert ers[0].wasted_iters == 0
        for k in range(B):
            assert (np.asarray(reps[k].x) == xs[k]).all(), k
print("BATCHED_MESH_SDC_OK")

# --- 2) batched elastic shrink on the 8-node partition -------------------
kw = dict(strategy="esrp", T=10, rtol=1e-9, elastic=True,
          scenario=[FailureEvent(iter=30, nodes=(5,))])
reps = solve_resilient(problem, rhs=jnp.asarray(rhs), **kw)
assert all(r.converged and r.final_n_nodes == 7 for r in reps)
for k in range(B):
    solo = solve_resilient(problem, rhs=jnp.asarray(rhs[k]), **kw)
    assert solo.final_n_nodes == 7
    xb, xsolo = np.asarray(reps[k].x), np.asarray(solo.x)
    assert xb.shape == xsolo.shape
    err = np.linalg.norm(xb - xsolo) / max(np.linalg.norm(xsolo), 1.0)
    assert err < 1e-9, (k, err)
print("BATCHED_ELASTIC_SHRINK_OK")
print("BATCHED_BEYOND_FAILSTOP_MESH_OK")
"""


@pytest.mark.slow
def test_batched_beyond_failstop_eight_devices():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    out = subprocess.run([sys.executable, "-c", _SCRIPT], cwd=".",
                         env=env, capture_output=True, text=True,
                         timeout=560)
    assert out.returncode == 0, out.stderr[-4000:]
    for tag in ("BATCHED_MESH_SDC_OK", "BATCHED_ELASTIC_SHRINK_OK",
                "BATCHED_BEYOND_FAILSTOP_MESH_OK"):
        assert tag in out.stdout, (tag, out.stdout)
