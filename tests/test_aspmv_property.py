"""Property tests for the ASpMV redundancy plan (paper §2.2/§2.2.1).

Invariant: after one augmented SpMV every input-vector tile has >= phi + 1
copies on distinct nodes, so any <= phi simultaneous node failures leave a
surviving copy of every tile (last paragraph of §2.2.1). Swept over random
sparsity patterns, node counts and phi — including patterns with empty
columns (m(i) = 0), the case where the paper's printed strict inequality
would fail (erratum note in repro/core/aspmv.py).
"""
import numpy as np
import pytest

from tests._hypo import given, settings, st

from repro.core.aspmv import build_plan
from repro.sparse.blockell import BlockEll
from repro.sparse.partition import Partition, neighbor, neighbors


def _random_problem(seed, n_nodes, rows_per_node, density):
    rng = np.random.default_rng(seed)
    bm = bn = 4
    m = n_nodes * rows_per_node
    nnz = max(int(density * m * m), m)
    rows = rng.integers(0, m, nnz)
    cols = rng.integers(0, m, nnz)
    rows = np.concatenate([rows, np.arange(m)])     # nonzero diagonal
    cols = np.concatenate([cols, np.arange(m)])
    vals = rng.standard_normal(rows.size)
    a = BlockEll.from_coo(rows, cols, vals, m, bm, bn)
    part = Partition(m=m, n_nodes=n_nodes, bm=bm, bn=bn)
    return a, part


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 10_000),
       n_nodes=st.sampled_from([2, 3, 4, 6, 8]),
       phi=st.integers(1, 4),
       density=st.floats(0.0, 0.05))
def test_phi_plus_one_copies(seed, n_nodes, phi, density):
    if phi >= n_nodes:
        phi = n_nodes - 1
    a, part = _random_problem(seed, n_nodes, rows_per_node=8,
                              density=density)
    plan = build_plan(a, part, phi)          # .verify() runs inside
    assert plan.holders.sum(axis=1).min() >= phi + 1


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000), phi=st.integers(1, 3),
       start=st.integers(0, 7))
def test_survives_any_phi_failures(seed, phi, start):
    a, part = _random_problem(seed, 8, rows_per_node=8, density=0.01)
    plan = build_plan(a, part, phi)
    failed = [(start + i) % 8 for i in range(phi)]
    assert plan.survives(np.array(failed)).all()


def test_diagonal_matrix_forces_extra_sends():
    """Pure-diagonal A: ordinary SpMV sends nothing (m(i) = 0 for all i);
    the erratum condition must still create phi copies."""
    m, bm = 32, 4
    rows = cols = np.arange(m)
    a = BlockEll.from_coo(rows, cols, np.ones(m), m, bm, bm)
    part = Partition(m=m, n_nodes=4, bm=bm, bn=bm)
    for phi in (1, 2, 3):
        plan = build_plan(a, part, phi)
        assert plan.natural_tiles_sent == 0
        assert plan.holders.sum(axis=1).min() == phi + 1


def test_neighbor_function_matches_paper_eq1():
    # d_{s,k}: +1, -1, +2, -2, ... around the ring (Eq. 1)
    assert neighbors(5, 4, 16) == [6, 4, 7, 3]
    assert neighbor(0, 2, 16) == 15
    assert neighbor(15, 1, 16) == 0


def test_denser_matrix_needs_fewer_extra_sends():
    """§2.2: denser matrices have lower ASpMV overhead."""
    a1, part = _random_problem(0, 4, 8, density=0.0)
    a2, _ = _random_problem(0, 4, 8, density=0.2)
    p1 = build_plan(a1, part, 1)
    p2 = build_plan(a2, part, 1)
    assert p2.extra_tiles_sent <= p1.extra_tiles_sent
