"""Pluggable preconditioner subsystem: per-preconditioner SPD/symmetry
properties, dense-algebra oracles, cross-backend bit-identity, failure-free
trajectory identity, and Alg. 2 reconstruction exactness through the
non-block-diagonal P_{f,I\\f} path (SSOR/Chebyshev/IC(0)).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import precond as pp
from repro.core import esrp, failures
from repro.core.driver import solve_resilient
from repro.precond.jacobi import invert_blocks
from repro.sparse.matrices import build_problem

ALL_PRECONDS = ("jacobi", "ssor", "chebyshev", "ic0")


@pytest.fixture(scope="module")
def small_problems():
    """m=80 poisson2d per preconditioner (dense checks stay cheap)."""
    return {name: build_problem("poisson2d", n_nodes=2, nx=8, precond=name)
            for name in ALL_PRECONDS}


@pytest.fixture(scope="module")
def p3d_problems():
    """poisson3d (block pattern wider than tridiagonal: IC(0) drops real
    fill, SSOR couples across nodes) per preconditioner."""
    return {name: build_problem("poisson3d", n_nodes=4, nx=8, precond=name)
            for name in ALL_PRECONDS}


def _dense_P(problem):
    # column-by-column (vmap has no batching rule for the optimization
    # barriers that pin the applies' cross-backend bit-identity)
    apply_ = problem.precond.make_apply("jnp")
    eye = np.eye(problem.m)
    return np.stack([np.asarray(apply_(jnp.asarray(eye[:, i])))
                     for i in range(problem.m)], axis=1)


# --------------------------------------------------------------------------- #
# registry
# --------------------------------------------------------------------------- #
def test_registry_lists_all_four():
    assert pp.available() == ["chebyshev", "ic0", "jacobi", "ssor"]


def test_registry_unknown_name_raises():
    with pytest.raises(ValueError, match="unknown preconditioner"):
        pp.build("nope", coo=None, m=0, block=1, dtype=np.float64)


# --------------------------------------------------------------------------- #
# operator properties: symmetry + positive definiteness
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("name", ALL_PRECONDS)
def test_spd_and_symmetric(small_problems, name):
    p = small_problems[name]
    P = _dense_P(p)
    np.testing.assert_allclose(P, P.T, atol=1e-13)
    ev = np.linalg.eigvalsh((P + P.T) / 2)
    assert ev.min() > 0, f"{name}: min eig {ev.min()}"


# --------------------------------------------------------------------------- #
# dense-algebra oracles
# --------------------------------------------------------------------------- #
def test_ssor_matches_dense_formula(small_problems):
    p = small_problems["ssor"]
    A = p.a.to_dense()
    b = p.precond_block
    nb = p.m // b
    D = np.zeros_like(A)
    Lb = np.zeros_like(A)
    for i in range(nb):
        D[i * b:(i + 1) * b, i * b:(i + 1) * b] = \
            A[i * b:(i + 1) * b, i * b:(i + 1) * b]
        for j in range(i):
            Lb[i * b:(i + 1) * b, j * b:(j + 1) * b] = \
                A[i * b:(i + 1) * b, j * b:(j + 1) * b]
    M = (D + Lb) @ np.linalg.inv(D) @ (D + Lb.T)          # omega = 1
    rng = np.random.default_rng(3)
    r = rng.standard_normal(p.m)
    z = np.asarray(p.precond.apply(jnp.asarray(r)))
    np.testing.assert_allclose(z, np.linalg.solve(M, r), rtol=1e-12,
                               atol=1e-13)


def test_ic0_matches_factor_solve(small_problems):
    """On a block-tridiagonal pattern IC(0) has no dropped fill, so
    (L Lᵀ)⁻¹ r from the packed factors must equal the sweeps' output — and
    L Lᵀ must equal A itself (exact factorization)."""
    p = small_problems["ic0"]
    pc = p.precond
    b = p.precond_block
    nb = p.m // b
    L = np.zeros((p.m, p.m))
    lo_idx, lo_n, lo_data, dinv_f = map(
        np.asarray, (pc.lo_idx, pc.lo_n, pc.lo_data, pc.dinv_f))
    for i in range(nb):
        L[i * b:(i + 1) * b, i * b:(i + 1) * b] = np.linalg.inv(dinv_f[i])
        for k in range(lo_n[i]):
            j = lo_idx[i, k]
            L[i * b:(i + 1) * b, j * b:(j + 1) * b] = lo_data[i, k]
    rng = np.random.default_rng(4)
    r = rng.standard_normal(p.m)
    z = np.asarray(pc.apply(jnp.asarray(r)))
    np.testing.assert_allclose(z, np.linalg.solve(L @ L.T, r), rtol=1e-12,
                               atol=1e-13)
    np.testing.assert_allclose(L @ L.T, p.a.to_dense(), atol=1e-10)


def test_chebyshev_matches_dense_recurrence(small_problems):
    p = small_problems["chebyshev"]
    pc = p.precond
    A = p.a.to_dense()
    rng = np.random.default_rng(5)
    r = rng.standard_normal(p.m)
    from repro.kernels.chebyshev.chebyshev import cheb_coefficients
    theta = (pc.hi + pc.lo) / 2.0
    z, dz = r / theta, r / theta
    for a_c, b_c in cheb_coefficients(pc.lo, pc.hi, pc.degree):
        dz = a_c * dz + b_c * (r - A @ z)
        z = z + dz
    np.testing.assert_allclose(np.asarray(pc.apply(jnp.asarray(r))), z,
                               rtol=1e-12, atol=1e-13)


def test_chebyshev_gershgorin_brackets_spectrum(small_problems):
    p = small_problems["chebyshev"]
    ev = np.linalg.eigvalsh(p.a.to_dense())
    assert p.precond.hi >= ev.max() - 1e-12
    assert p.precond.lo > 0


# --------------------------------------------------------------------------- #
# cross-backend bit-identity (pallas/interpret vs jnp)
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("name", ALL_PRECONDS)
def test_apply_bit_identical_across_backends(p3d_problems, name):
    p = p3d_problems[name]
    rng = np.random.default_rng(6)
    for _ in range(3):
        r = jnp.asarray(rng.standard_normal(p.m))
        z_jnp = p.precond.apply(r, backend="jnp")
        z_int = p.precond.apply(r, backend="interpret")
        np.testing.assert_array_equal(np.asarray(z_jnp), np.asarray(z_int))


@pytest.mark.parametrize("name", ("ssor", "chebyshev", "ic0"))
def test_trajectory_bit_identical_across_backends(p3d_problems, name):
    """The full ESRP hot loop (fused matvec_dot + the preconditioner's own
    update path) through the interpret bundle must reproduce the jnp bundle
    bit-for-bit, iteration by iteration, through storage stages."""
    p = p3d_problems[name]
    ops_j = p.solver_ops("jnp")
    ops_i = p.solver_ops("interpret")
    thresh = jnp.asarray(0.0, p.b.dtype)
    s_j = esrp.esrp_init(ops_j.matvec, ops_j.precond, p.b)
    s_i = esrp.esrp_init(ops_i.matvec, ops_i.precond, p.b)
    s_j, norms_j = esrp.run_chunk(s_j, ops_j, 5, 15, thresh)
    s_i, norms_i = esrp.run_chunk(s_i, ops_i, 5, 15, thresh)
    np.testing.assert_array_equal(np.asarray(norms_j), np.asarray(norms_i))
    for a, b in zip(jax.tree.leaves(s_j), jax.tree.leaves(s_i)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# --------------------------------------------------------------------------- #
# convergence on every problem family
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("name", ALL_PRECONDS)
@pytest.mark.parametrize("kind,kw", (
    ("poisson2d", dict(nx=12)),
    ("poisson3d", dict(nx=6)),
    ("banded", dict(n=300, bandwidth=12)),
))
def test_converges_on_all_problem_families(name, kind, kw):
    p = build_problem(kind, n_nodes=2, precond=name, **kw)
    rep = solve_resilient(p, strategy="none", rtol=1e-8)
    assert rep.rel_residual < 1e-8, (name, kind, rep.rel_residual)


def test_ssor_and_ic0_beat_jacobi_on_anisotropic_poisson3d():
    """The paper-proposed experiment in miniature: stronger preconditioners
    cut iterations-to-converge in the anisotropic regime where block-Jacobi
    struggles."""
    iters = {}
    for name in ("jacobi", "ssor", "ic0"):
        p = build_problem("poisson3d", n_nodes=2, nx=8, eps=0.25,
                          precond=name)
        iters[name] = solve_resilient(p, strategy="none",
                                      rtol=1e-8).converged_iter
    assert iters["ssor"] < iters["jacobi"]
    assert iters["ic0"] < iters["jacobi"]


# --------------------------------------------------------------------------- #
# Alg. 2 lines 5-6: the non-block-diagonal P_{f,I\f} path
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("name", ("ssor", "chebyshev", "ic0"))
def test_line56_recovers_r_f_exactly(p3d_problems, name):
    """Given z = P r and the surviving r entries, the local operators must
    recover the failed entries of r: r_f = P_ff⁻¹ (z_f − P_{f,I\\f} r_{I\\f})
    to fp accuracy — the algebra Alg. 2 lines 5-6 rely on."""
    p = p3d_problems[name]
    failed = [1]
    mask = failures.failed_row_mask(p.part, failed)
    f_rows = failures.failed_rows(p.part, failed)
    rng = np.random.default_rng(7)
    r_full = jnp.asarray(rng.standard_normal(p.m))
    z_full = p.precond.apply(r_full)

    offdiag, pff_solve = p.precond.local_ops(mask, f_rows)
    assert offdiag is not None         # genuine off-diagonal coupling
    r_surv = jnp.where(jnp.asarray(mask), 0.0, r_full)   # failed data lost
    v = z_full[jnp.asarray(f_rows)] - offdiag(r_surv)
    r_f = pff_solve(v)
    np.testing.assert_allclose(np.asarray(r_f),
                               np.asarray(r_full)[f_rows],
                               rtol=1e-9, atol=1e-11)


def test_jacobi_local_ops_exact_closed_form(small_problems):
    p = small_problems["jacobi"]
    failed = [0]
    mask = failures.failed_row_mask(p.part, failed)
    f_rows = failures.failed_rows(p.part, failed)
    offdiag, pff_solve = p.precond.local_ops(mask, f_rows)
    assert offdiag is None             # P offdiag is exactly zero
    rng = np.random.default_rng(8)
    r_full = jnp.asarray(rng.standard_normal(p.m))
    z_full = p.precond.apply(r_full)
    r_f = pff_solve(z_full[jnp.asarray(f_rows)])
    np.testing.assert_allclose(np.asarray(r_f), np.asarray(r_full)[f_rows],
                               rtol=1e-12, atol=1e-13)


@pytest.mark.parametrize("name", ("ssor", "chebyshev", "ic0"))
def test_esrp_midstage_failure_exact_reconstruction(p3d_problems, name):
    """Mid-stage node failure + Alg. 2 through the preconditioner-aware
    lines 5-6: the solver must converge in exactly the failure-free
    iteration count (the paper's exact-reconstruction criterion)."""
    p = p3d_problems[name]
    ref = solve_resilient(p, strategy="none", rtol=1e-9, chunk=16)
    C = ref.converged_iter
    assert C > 8, f"{name} converged too fast for a mid-solve failure ({C})"
    T = 3
    # right after a stage's *first* push (the hard mid-stage case), with at
    # least one complete earlier stage to roll back to
    fail_at = max(2 * T, (C // 2 // T) * T)
    assert fail_at < C
    r = solve_resilient(p, strategy="esrp", T=T, phi=1, rtol=1e-9, chunk=16,
                        fail_at=fail_at, failed_nodes=[2])
    assert r.converged_iter == C
    assert r.rel_residual < 1e-9
    assert r.target_iter >= 0 and r.wasted_iters == fail_at - r.target_iter


def test_esrp_failure_recovery_bit_identical_nonlocal(p3d_problems):
    """SSOR (non-local P) failure + recovery must leave the jnp and
    interpret backends on identical reports — recovery routes both through
    the same jnp reconstruction closures."""
    p = p3d_problems["ssor"]
    ref = solve_resilient(p, strategy="none", rtol=1e-9, backend="jnp")
    reports = {}
    for backend in ("jnp", "interpret"):
        reports[backend] = solve_resilient(
            p, strategy="esrp", T=5, phi=1, rtol=1e-9, chunk=16,
            fail_at=10, failed_nodes=[2], backend=backend)
    rj, ri = reports["jnp"], reports["interpret"]
    assert rj.converged_iter == ri.converged_iter == ref.converged_iter
    assert rj.rel_residual == ri.rel_residual
    assert rj.target_iter == ri.target_iter


def test_pff_solve_threads_tolerances(p3d_problems):
    """reconstruct()'s inner_rtol/inner_max_iters must reach the line-6
    P_ff inner CG: a single-iteration budget gives a visibly worse solve
    than the default 1e-14 target."""
    p = p3d_problems["ssor"]
    failed = [1]
    mask = failures.failed_row_mask(p.part, failed)
    f_rows = failures.failed_rows(p.part, failed)
    _, pff_solve = p.precond.local_ops(mask, f_rows)
    rng = np.random.default_rng(11)
    r_full = jnp.asarray(rng.standard_normal(p.m))
    v = p.precond.apply(r_full)[jnp.asarray(f_rows)]  # pretend offdiag = 0
    tight = np.asarray(pff_solve(v))
    loose = np.asarray(pff_solve(v, 1e-1, 1))
    assert not np.allclose(tight, loose, rtol=1e-10, atol=1e-12)


def test_sharded_runtime_accepts_non_jacobi():
    """The non-Jacobi rejection is lifted: the sharded runtime builds a
    bundle for every registered preconditioner. SSOR/IC(0) run node-local
    (adopting the additive-Schwarz twin when the instance has cross-slab
    coupling), Chebyshev distributes through the SpMV; the variant is
    recorded on the bundle and the resulting z = P r matches the
    single-device node-local reference bitwise (1-node mesh ⇒ the twin is
    the instance itself)."""
    from repro.comm import shard

    mesh = shard.nodes_mesh(1)
    for name, expect in (("ssor", "node-local ssor"),
                         ("ic0", "node-local ic0"),
                         ("chebyshev", "spmv-distributed chebyshev")):
        p = build_problem("poisson3d", n_nodes=1, nx=6, precond=name)
        with mesh:
            ops = shard.sharded_solver_ops(p, mesh)
        assert ops.variant.startswith(expect), (name, ops.variant)
        rng = np.random.default_rng(12)
        r = jnp.asarray(rng.standard_normal(p.m))
        with mesh:
            z = ops.precond(r)
        if name != "chebyshev":          # cheb fuses differently under jit
            np.testing.assert_array_equal(
                np.asarray(z), np.asarray(p.precond.apply(r)))
        else:
            np.testing.assert_allclose(
                np.asarray(z), np.asarray(p.precond.apply(r)),
                rtol=1e-13, atol=1e-14)


# --------------------------------------------------------------------------- #
# preconditioned P_ff inner solve (Alg. 2 line 6)
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("name", ("ssor", "chebyshev", "ic0"))
def test_pff_precond_same_answer_fewer_iters(p3d_problems, name):
    """The truncated-operator inner preconditioner must not change what the
    line-6 solve computes (rtol 1e-14 either way), only how fast: strictly
    fewer inner-CG iterations, with stats recorded on the closure."""
    p = p3d_problems[name]
    failed = [1]
    mask = failures.failed_row_mask(p.part, failed)
    f_rows = failures.failed_rows(p.part, failed)
    rng = np.random.default_rng(21)
    r_full = jnp.asarray(rng.standard_normal(p.m))
    z_full = p.precond.apply(r_full)
    results = {}
    for pp in (False, True):
        off, solve = p.precond.local_ops(mask, f_rows, pff_precond=pp)
        assert solve.stats is None
        v = z_full[jnp.asarray(f_rows)] - off(
            jnp.where(jnp.asarray(mask), 0.0, r_full))
        r_f = solve(v)
        assert solve.stats["iters"] > 0 and solve.stats["rel"] < 1e-13
        np.testing.assert_allclose(np.asarray(r_f),
                                   np.asarray(r_full)[f_rows],
                                   rtol=1e-9, atol=1e-11)
        results[pp] = solve.stats["iters"]
    assert results[True] < results[False], results


@pytest.mark.slow
def test_ssor_pff_iteration_drop_3x_on_ci_grid():
    """Acceptance criterion: on the CI grid (poisson2d nx=48, 8 nodes — the
    ~250 ms SSOR recovery of the ROADMAP) the preconditioned P_ff solve
    needs >= 3x fewer inner-CG iterations than the unpreconditioned one."""
    p = build_problem("poisson2d", n_nodes=8, nx=48, precond="ssor")
    failed = [1]
    mask = failures.failed_row_mask(p.part, failed)
    f_rows = failures.failed_rows(p.part, failed)
    rng = np.random.default_rng(22)
    r_full = jnp.asarray(rng.standard_normal(p.m))
    z_full = p.precond.apply(r_full)
    iters = {}
    for pp in (False, True):
        off, solve = p.precond.local_ops(mask, f_rows, pff_precond=pp)
        v = z_full[jnp.asarray(f_rows)] - off(
            jnp.where(jnp.asarray(mask), 0.0, r_full))
        solve(v)
        iters[pp] = solve.stats["iters"]
    assert iters[False] >= 3 * iters[True], iters


def test_event_report_records_pff_iters(p3d_problems):
    """A mid-stage SSOR failure reports the line-6 inner-CG iteration count
    per event; block-Jacobi (closed form, no inner CG) reports -1."""
    for name, expect_cg in (("ssor", True), ("jacobi", False)):
        p = p3d_problems[name]
        ref = solve_resilient(p, strategy="none", rtol=1e-9, chunk=16)
        T = 3
        fail_at = max(2 * T, (ref.converged_iter // 2 // T) * T)
        r = solve_resilient(p, strategy="esrp", T=T, phi=1, rtol=1e-9,
                            chunk=16, fail_at=fail_at, failed_nodes=[2])
        assert r.converged_iter == ref.converged_iter
        if expect_cg:
            assert r.events[0].pff_iters > 0
        else:
            assert r.events[0].pff_iters == -1


def test_midstage_reconstruction_exact_with_and_without_pff_precond(
        p3d_problems):
    """Both line-6 solve variants reconstruct exactly: the solver rejoins
    the failure-free trajectory either way (the inner preconditioner is a
    solver accelerant, not an algebra change)."""
    p = p3d_problems["ssor"]
    ref = solve_resilient(p, strategy="none", rtol=1e-9, chunk=16)
    C = ref.converged_iter
    T = 3
    fail_at = max(2 * T, (C // 2 // T) * T)
    for pp in (False, True):
        r = solve_resilient(p, strategy="esrp", T=T, phi=1, rtol=1e-9,
                            chunk=16, fail_at=fail_at, failed_nodes=[2],
                            pff_precond=pp)
        assert r.converged_iter == C, (pp, r.converged_iter, C)
        assert r.rel_residual < 1e-9


# --------------------------------------------------------------------------- #
# satellite: Lanczos-tightened Chebyshev bounds + auto degree
# --------------------------------------------------------------------------- #
def test_lanczos_ritz_bounds_bracket_spectrum():
    from repro.precond.chebyshev import lanczos_ritz_bounds

    p = build_problem("poisson2d", n_nodes=2, nx=12)
    ev = np.linalg.eigvalsh(p.a.to_dense())
    lo, hi = lanczos_ritz_bounds(p.coo, p.m, iters=12)
    assert ev[0] - 1e-10 <= lo <= ev[-1]
    assert ev[0] <= hi <= ev[-1] + 1e-10
    assert hi - lo > 0.5 * (ev[-1] - ev[0])   # extremes converge fast


def test_lanczos_only_tightens_lo():
    """lo with Lanczos >= lo with the bare hi/eig_ratio clamp on every
    family (the interval only ever shrinks, preserving the SPD argument)."""
    for kind, kw in (("poisson2d", dict(nx=12)),
                     ("banded", dict(n=320, bandwidth=8, shift=5.0))):
        p_old = build_problem(kind, n_nodes=2, precond="chebyshev",
                              precond_opts={"lanczos_iters": 0}, **kw)
        p_new = build_problem(kind, n_nodes=2, precond="chebyshev", **kw)
        assert p_new.precond.lo >= p_old.precond.lo
        assert p_new.precond.hi == p_old.precond.hi   # Gershgorin keeps hi


def test_auto_degree_cut_on_easy_spectrum():
    """On a diagonally-dominant banded matrix (easy spectrum) the tightened
    interval needs no larger polynomial degree, and auto degree responds
    monotonically to the bound quality."""
    from repro.precond.chebyshev import auto_degree

    kw = dict(n=320, bandwidth=8, shift=5.0)
    degs = {}
    for tag, opts in (("old", {"lanczos_iters": 0, "degree": "auto"}),
                      ("lanczos", {"degree": "auto"})):
        p = build_problem("banded", n_nodes=2, precond="chebyshev",
                          precond_opts=opts, **kw)
        degs[tag] = p.precond.degree
        rep = solve_resilient(p, strategy="none", rtol=1e-8)
        assert rep.rel_residual < 1e-8
    assert degs["lanczos"] <= degs["old"]
    assert auto_degree(1.0, 10.0) <= auto_degree(0.1, 10.0)
    assert auto_degree(9.9, 10.0) == 1


# --------------------------------------------------------------------------- #
# serializable static data (safe storage round-trip)
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("name", ALL_PRECONDS)
def test_static_state_roundtrip(small_problems, tmp_path, name):
    p = small_problems[name]
    state = p.precond.static_state()
    path = tmp_path / f"{name}.npz"
    np.savez(path, **state)
    loaded = dict(np.load(path))
    cls = type(p.precond)
    rebuilt = cls.from_static(loaded, m=p.m, dtype=p.b.dtype, a=p.a)
    rng = np.random.default_rng(9)
    r = jnp.asarray(rng.standard_normal(p.m))
    np.testing.assert_array_equal(np.asarray(p.precond.apply(r)),
                                  np.asarray(rebuilt.apply(r)))


# --------------------------------------------------------------------------- #
# satellite: Cholesky-based invert_blocks
# --------------------------------------------------------------------------- #
def test_invert_blocks_matches_inv_and_is_symmetric():
    rng = np.random.default_rng(10)
    g = rng.standard_normal((7, 6, 6))
    spd = g @ np.swapaxes(g, -1, -2) + 6 * np.eye(6)
    out = invert_blocks(spd)
    np.testing.assert_allclose(out, np.linalg.inv(spd), rtol=1e-9,
                               atol=1e-11)
    np.testing.assert_array_equal(out, np.swapaxes(out, -1, -2))


def test_invert_blocks_rejects_non_spd():
    blocks = np.stack([np.eye(4), -np.eye(4)])
    with pytest.raises(np.linalg.LinAlgError, match="not SPD"):
        invert_blocks(blocks)
