"""Per-kernel shape/dtype sweeps vs the pure-jnp oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from tests._hypo import given, settings, st

from repro.kernels.block_jacobi.ops import precond_apply
from repro.kernels.fused_pcg.ops import pcg_update
from repro.kernels.spmv.ops import blockell_matvec
from repro.kernels.spmv.ref import spmv_ref
from repro.sparse.blockell import BlockEll
from repro.sparse.matrices import build_problem


def _tol(dtype):
    return dict(rtol=1e-5, atol=1e-5) if dtype == np.float32 else \
        dict(rtol=1e-11, atol=1e-11)


@pytest.mark.parametrize("dtype", [np.float32, np.float64])
@pytest.mark.parametrize("bm,bn,n_tiles", [(4, 4, 8), (8, 8, 6), (8, 16, 4)])
def test_spmv_kernel_shapes(dtype, bm, bn, n_tiles):
    rng = np.random.default_rng(bm * bn + n_tiles)
    m = bm * n_tiles * 2
    mc = (m // bn) * bn
    m = max(m, mc)
    nnz = 6 * m
    rows = rng.integers(0, m, nnz)
    cols = rng.integers(0, (m // bn) * bn, nnz)
    vals = rng.standard_normal(nnz).astype(dtype)
    a = BlockEll.from_coo(rows, cols, vals, m, bm, bn, dtype=dtype)
    x = jnp.asarray(rng.standard_normal(m).astype(dtype))
    ref = spmv_ref(a.data, a.idx, x)
    ker = blockell_matvec(a, x, backend="interpret")
    np.testing.assert_allclose(np.asarray(ker), np.asarray(ref),
                               **_tol(dtype))


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000), kmax_extra=st.integers(0, 3))
def test_spmv_kernel_random_patterns(seed, kmax_extra):
    rng = np.random.default_rng(seed)
    bm = bn = 8
    m = 128
    nnz = rng.integers(m, 8 * m)
    rows = rng.integers(0, m, nnz)
    cols = rng.integers(0, m, nnz)
    vals = rng.standard_normal(nnz)
    a = BlockEll.from_coo(rows, cols, vals, m, bm, bn)
    if kmax_extra:   # padding slots must contribute exactly zero
        a = BlockEll(
            jnp.pad(a.data, ((0, 0), (0, kmax_extra), (0, 0), (0, 0))),
            jnp.pad(a.idx, ((0, 0), (0, kmax_extra))), a.nblk, a.shape,
            bm, bn)
    x = jnp.asarray(rng.standard_normal(m))
    np.testing.assert_allclose(
        np.asarray(blockell_matvec(a, x, backend="interpret")),
        np.asarray(spmv_ref(a.data, a.idx, x)), rtol=1e-11, atol=1e-11)


@pytest.mark.parametrize("dtype", [np.float32, np.float64])
@pytest.mark.parametrize("m,b,rows", [(512, 8, 64), (640, 10, 160),
                                      (1024, 4, 256)])
def test_fused_pcg_kernel(dtype, m, b, rows):
    rng = np.random.default_rng(m + b)
    pinv = jnp.asarray(rng.standard_normal((m // b, b, b)).astype(dtype))
    x, r, p, q = (jnp.asarray(rng.standard_normal(m).astype(dtype))
                  for _ in range(4))
    alpha = jnp.asarray(dtype(0.37))
    ref = pcg_update(alpha, x, r, p, q, pinv, backend="jnp")
    ker = pcg_update(alpha, x, r, p, q, pinv, backend="interpret", rows=rows)
    for a_, b_ in zip(ref, ker):
        np.testing.assert_allclose(np.asarray(b_), np.asarray(a_),
                                   **_tol(dtype))


@pytest.mark.parametrize("m,b,rows", [(512, 8, 64), (800, 10, 80)])
def test_block_jacobi_kernel(m, b, rows):
    rng = np.random.default_rng(m)
    pinv = jnp.asarray(rng.standard_normal((m // b, b, b)))
    r = jnp.asarray(rng.standard_normal(m))
    np.testing.assert_allclose(
        np.asarray(precond_apply(pinv, r, backend="interpret", rows=rows)),
        np.asarray(precond_apply(pinv, r, backend="jnp")),
        rtol=1e-11, atol=1e-11)


def test_kernel_inside_pcg_solver():
    """The interpret-mode kernel can drive the full resilient solver."""
    from repro.core.driver import solve_resilient
    p = build_problem("poisson2d", n_nodes=4, nx=16, ny=16)
    mv = lambda x: blockell_matvec(p.a, x, backend="interpret")
    r = solve_resilient(p, strategy="esrp", T=5, phi=1, rtol=1e-8,
                        matvec=mv, fail_at=12, failed_nodes=[2], chunk=16)
    assert r.rel_residual < 1e-8


# --------------------------------------------------------------------------- #
# flash attention
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("causal,window", [(True, 0), (False, 0), (True, 24)])
@pytest.mark.parametrize("dtype", [np.float32])
def test_flash_attention_kernel(causal, window, dtype):
    from repro.kernels.attention.flash import flash_attention
    from repro.kernels.attention.ref import attention_ref
    rng = np.random.default_rng(int(causal) + window)
    q, k, v = (jnp.asarray(rng.standard_normal((2, 64, 16)).astype(dtype))
               for _ in range(3))
    ref = attention_ref(q, k, v, causal=causal, window=window)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          bq=16, bk=16, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_gqa_flash_wrapper():
    from repro.kernels.attention.ops import gqa_flash
    rng = np.random.default_rng(7)
    q = jnp.asarray(rng.standard_normal((2, 32, 8, 16)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((2, 32, 2, 16)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((2, 32, 2, 16)), jnp.float32)
    o1 = gqa_flash(q, k, v, backend="interpret", bq=16, bk=16)
    o2 = gqa_flash(q, k, v, backend="jnp")
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=2e-5, atol=2e-5)


def test_chunked_mlstm_matches_recurrent():
    from repro.models.xlstm import mlstm_chunked, mlstm_seq
    rng = np.random.default_rng(3)
    B, S, H, P = 2, 64, 4, 16
    q, k, v = (jnp.asarray(rng.standard_normal((B, S, H, P)), jnp.float32)
               for _ in range(3))
    it = jnp.asarray(rng.standard_normal((B, S, H)) * 2.0, jnp.float32)
    ft = jax.nn.log_sigmoid(
        jnp.asarray(rng.standard_normal((B, S, H)) + 3.0, jnp.float32))
    y_ref, st_ref = mlstm_seq(q, k, v, it, ft)
    for chunk in (8, 32, 64):
        y_c, st_c = mlstm_chunked(q, k, v, it, ft, chunk=chunk)
        np.testing.assert_allclose(np.asarray(y_c), np.asarray(y_ref),
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(st_c["C"]),
                                   np.asarray(st_ref["C"]),
                                   rtol=1e-3, atol=1e-3)
