"""While-aware HLO analyzer: trip counts, dot flops, collective model."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.roofline.hlo_analysis import (COLLECTIVES, HloCosts, analyze,
                                         parse_hlo)


def test_scan_trip_count_multiplies_flops():
    def f(x):
        def body(c, _):
            return c @ c, None
        c, _ = jax.lax.scan(body, x, None, length=8)
        return c

    c = jax.jit(f).lower(
        jax.ShapeDtypeStruct((128, 128), jnp.float32)).compile()
    r = analyze(c.as_text())
    expected = 2 * 128 ** 3 * 8
    assert abs(r.flops - expected) / expected < 0.01
    assert 8 in r.while_trips.values()


def test_nested_scan_trips():
    def f(x):
        def outer(c, _):
            def inner(d, _):
                return d @ d, None
            d, _ = jax.lax.scan(inner, c, None, length=3)
            return d, None
        c, _ = jax.lax.scan(outer, x, None, length=5)
        return c

    c = jax.jit(f).lower(
        jax.ShapeDtypeStruct((64, 64), jnp.float32)).compile()
    r = analyze(c.as_text())
    expected = 2 * 64 ** 3 * 15
    assert abs(r.flops - expected) / expected < 0.01


_FIXTURE = """
HloModule test

%add (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %s = f32[] add(%a, %b)
}

ENTRY %main (p0: f32[64,128], p1: bf16[1024]) -> f32[64,128] {
  %p0 = f32[64,128] parameter(0)
  %p1 = bf16[1024] parameter(1)
  %ag = bf16[2048] all-gather(%p1), replica_groups={}, dimensions={0}
  %ar = f32[64,128] all-reduce(%p0), to_apply=%add
  %rs = bf16[512] reduce-scatter(%p1), to_apply=%add, dimensions={0}
  %cp = bf16[1024] collective-permute(%p1), source_target_pairs={{0,1}}
  ROOT %out = f32[64,128] add(%ar, %ar)
}
"""


def test_collective_ring_model_bytes():
    r = analyze(_FIXTURE)
    # all-gather: |res| = 2048*2 = 4096; all-reduce: 2*|res| = 2*32768 B
    assert r.collectives["all-gather"] == 4096
    assert r.collectives["all-reduce"] == 2 * 64 * 128 * 4
    assert r.collectives["reduce-scatter"] == 1024 * 2   # operand bytes
    assert r.collectives["collective-permute"] == 1024 * 2


def test_parse_hlo_computations():
    comps = parse_hlo(_FIXTURE)
    assert "main" in comps and "add" in comps
    kinds = {op.kind for op in comps["main"].ops}
    assert {"all-gather", "all-reduce", "reduce-scatter",
            "collective-permute"} <= kinds
