"""Solver telemetry subsystem (repro.obs + driver threading — ISSUE 7).

The contract under test:
  * obs=off is FREE: ``run_chunk(..., metrics=False)`` compiles to exactly
    the jaxpr of the pre-telemetry chunk runner (structural alpha-equivalent
    identity via repro.analysis against an inline re-derivation for both
    esrp and imcr), and the driver's default path stays deterministic with
    obs=on rejoining at the same iteration;
  * the on-device metrics ring tells the truth: the per-iteration history
    read back through the chunk record matches a host-side replay (||r||,
    rz bit-tight; push/star flags exactly the Alg. 3 schedule; orth at the
    invariant-noise floor);
  * the span tree is well-formed: every recovery phase nests under its
    fail-stop event span, byte counters are populated from the tier cost
    model, rooflines price the dispatched kernels, and the exported
    Chrome-trace passes the validator + file round-trips;
  * SolveReport/EventReport.to_json is a JSON-safe, schema-versioned dict
    (no device arrays, no NaN) — the BENCH writers' serialization path.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import assert_structurally_equal
from repro.core import esrp, imcr
from repro.core.driver import REPORT_SCHEMA_VERSION, solve_resilient
from repro.core.failures import FailureEvent
from repro.obs import (Tracer, chrome_trace, metrics_snapshot, span_tree,
                       validate_chrome_trace, walk_spans, write_chrome_trace,
                       write_jsonl)
from repro.sparse.matrices import build_problem


@pytest.fixture(scope="module")
def problem():
    return build_problem("poisson2d", n_nodes=4, nx=24, ny=24)


@pytest.fixture(scope="module")
def traced():
    """The acceptance scenario: staggered phi=2 ESRP run with the tracer on
    (a simultaneous two-node event, recovery, then a single-node event)."""
    p = build_problem("poisson2d", n_nodes=8, nx=32, ny=32)
    rep = solve_resilient(
        p, strategy="esrp", T=10, phi=2, rtol=1e-8,
        scenario=[FailureEvent(iter=28, nodes=(1, 3)),
                  FailureEvent(iter=38, nodes=(5,))],
        obs=True)
    assert rep.converged and rep.trace is not None
    return rep


# --------------------------------------------------------------------------- #
# obs=off adds ZERO ops (jaxpr identity vs the pre-telemetry runner)
# --------------------------------------------------------------------------- #
def test_esrp_chunk_metrics_off_jaxpr_identity(problem):
    ops = problem.solver_ops("jnp")
    b = problem.b
    st = esrp.esrp_init(ops.matvec, ops.precond, b, dot=ops.dot)
    thresh = jnp.asarray(1e-8, b.dtype)

    def ref_chunk(s0):
        # the pre-telemetry chunk runner, re-derived inline: plain freeze
        # scan with no aux branch anywhere
        def step(s):
            s2 = esrp.esrp_step(s, ops, 10, b=b, rr_every=0, gated=True,
                                push=None)
            return s2, jnp.linalg.norm(s2.pcg.r)

        def body(carry, _):
            s, rnorm = carry
            s, rnorm = jax.lax.cond(
                rnorm < thresh, lambda s_: (s_, rnorm), step, s)
            return (s, rnorm), rnorm

        (s0, _), norms = jax.lax.scan(body, (s0, jnp.linalg.norm(s0.pcg.r)),
                                      None, length=8)
        return s0, norms

    got = jax.make_jaxpr(lambda s: esrp.run_chunk.__wrapped__(
        s, ops, 10, 8, thresh, 0, True, b, None, False))(st)
    want = jax.make_jaxpr(ref_chunk)(st)
    # structural (alpha-equivalent) identity: same strictness as string
    # equality, but a failure reports the first diverging equation instead
    # of two multi-thousand-line reprs
    assert_structurally_equal(got, want, "esrp obs=off adds zero ops")


def test_imcr_chunk_metrics_off_jaxpr_identity(problem):
    ops = problem.solver_ops("jnp")
    b = problem.b
    st = imcr.imcr_init(ops.matvec, ops.precond, b, dot=ops.dot)
    thresh = jnp.asarray(1e-8, b.dtype)
    rows = problem.part.rows_per_node

    def ref_chunk(s0):
        def step(s):
            s2 = imcr.imcr_step(s, ops, 10, 1, rows, True)
            return s2, jnp.linalg.norm(s2.pcg.r)

        def body(carry, _):
            s, rnorm = carry
            s, rnorm = jax.lax.cond(
                rnorm < thresh, lambda s_: (s_, rnorm), step, s)
            return (s, rnorm), rnorm

        (s0, _), norms = jax.lax.scan(body, (s0, jnp.linalg.norm(s0.pcg.r)),
                                      None, length=8)
        return s0, norms

    got = jax.make_jaxpr(lambda s: imcr.run_chunk.__wrapped__(
        s, ops, 10, 1, rows, 8, thresh, True, False))(st)
    want = jax.make_jaxpr(ref_chunk)(st)
    assert_structurally_equal(got, want, "imcr obs=off adds zero ops")


def test_obs_off_deterministic_and_obs_on_rejoins(problem):
    """obs=None twice is bit-identical (the default path is untouched);
    obs=on converges at the SAME iteration with the solution at the
    fusion-noise floor (arming the ring may legally re-fuse the chunk)."""
    kw = dict(strategy="esrp", T=20, rtol=1e-9,
              scenario=[FailureEvent(iter=41, nodes=(1,))])
    ra = solve_resilient(problem, **kw)
    rb = solve_resilient(problem, **kw)
    np.testing.assert_array_equal(np.asarray(ra.x), np.asarray(rb.x))
    assert ra.converged_iter == rb.converged_iter
    assert ra.trace is None

    ron = solve_resilient(problem, **kw, obs=True)
    assert ron.converged_iter == ra.converged_iter
    err = float(jnp.linalg.norm(ron.x - ra.x))
    assert err <= 1e-9 * max(float(jnp.linalg.norm(ra.x)), 1.0), err


# --------------------------------------------------------------------------- #
# the metrics ring vs a host-side replay
# --------------------------------------------------------------------------- #
def test_iteration_metrics_match_host_replay():
    p = build_problem("poisson2d", n_nodes=4, nx=16, ny=16)
    rep = solve_resilient(p, strategy="esrp", T=10, rtol=1e-9, obs=True)
    C = rep.converged_iter
    h = rep.trace.iter_history()
    assert h["iter"].tolist() == list(range(C))

    ops = p.solver_ops("auto")
    st = esrp.esrp_init(ops.matvec, ops.precond, p.b, dot=ops.dot)
    _, norms = esrp.run_chunk(st, ops, 10, C, None, 0, True, p.b, None,
                              False)
    np.testing.assert_allclose(h["rnorm"], np.asarray(norms), rtol=1e-12)

    # stepwise replay: flags are the Alg. 3 schedule on the pre-step j,
    # rz/orth are the post-step invariants
    for j in range(C):
        push_f, star_f = esrp.storage_flags(st.pcg.j, 10)
        st, _ = esrp.run_chunk(st, ops, 10, 1, None, 0, True, p.b, None,
                               False)
        assert h["push"][j] == float(bool(push_f)), j
        assert h["star"][j] == float(bool(star_f)), j
        np.testing.assert_allclose(h["rz"][j], float(st.pcg.rz), rtol=1e-12)
        # orth = |r^T p - rz| is pure cancellation noise on a clean run:
        # assert the floor, not the exact value (re-fusion moves ulps)
        assert 0 <= h["orth"][j] <= 1e-8 * max(abs(h["rz"][j]), 1e-300), j


def test_history_survives_rollback_dedup(traced):
    """Rolled-back iterations are re-recorded; the history keeps exactly one
    row per iteration with the re-run (later) values winning."""
    h = traced.trace.iter_history()
    assert h["iter"].tolist() == list(range(traced.converged_iter))
    n_push = int(round(float(np.sum(h["push"]))))
    assert n_push > 0
    # the cumulative counter also saw the pushes REDONE on rolled-back
    # stretches (physically repeated traffic), so it bounds the deduped
    # history from above in whole per-push units
    per_push = span_tree(traced.trace.events)[0]["args"]["per_push_bytes"]
    total = traced.trace.counters["tier_push_bytes"]
    assert per_push > 0 and total % per_push == 0
    assert total >= n_push * per_push


# --------------------------------------------------------------------------- #
# span-tree well-formedness + export round-trip (acceptance scenario)
# --------------------------------------------------------------------------- #
def test_trace_validates_and_events_nest(traced):
    tr = traced.trace
    assert validate_chrome_trace(chrome_trace(tr)) == []

    tree = span_tree(tr.events)
    assert tree and tree[0]["name"] == "solve"
    assert tree[0]["args"]["phi"] == 2
    assert tree[0]["dur_us"] is not None

    evs = [n for n in walk_spans(tree) if n["name"] == "event:fail-stop"]
    assert len(evs) == 2
    for ev in evs:
        inner = {d["name"] for d in walk_spans(ev["children"])}
        assert {"inject", "queue_fetch", "alg2_line5_offdiag",
                "alg2_line6_pff_solve", "alg2_line8_aff_solve",
                "scatter"} <= inner, inner
        (qf,) = [d for d in walk_spans(ev["children"])
                 if d["name"] == "queue_fetch"]
        assert qf["args"]["bytes"] > 0
    # recovery phases appear ONLY under their event span
    for n in walk_spans(tree):
        if n["name"].startswith("alg2_"):
            assert n["cat"] == "recovery"
    assert tr.counters["tier_fetch_bytes"] > 0

    its = [e for e in tr.events
           if e["name"] == "iteration" and e["ph"] == "C"]
    assert len(its) >= traced.converged_iter
    assert all("iter" in e["args"] and "rnorm" in e["args"] for e in its)


def test_rooflines_attached(traced):
    rf = traced.trace.meta.get("rooflines", {})
    priced = [k for k, v in rf.items()
              if isinstance(v, dict) and "error" not in v
              and isinstance(v.get("flops"), (int, float)) and v["flops"] > 0
              and v.get("hbm_bytes", 0) > 0]
    assert len(priced) >= 3, rf.keys()
    for k in priced:
        assert rf[k]["flop_per_byte"] == pytest.approx(
            rf[k]["flops"] / rf[k]["hbm_bytes"])


def test_export_round_trip(traced, tmp_path):
    tr = traced.trace
    path = write_chrome_trace(tr, str(tmp_path / "trace.json"))
    with open(path) as f:
        doc = json.load(f)
    assert validate_chrome_trace(doc) == []
    assert len(doc["traceEvents"]) == len(tr.events)
    assert doc["metadata"]["schema_version"] == 1
    assert doc["metadata"]["counters"]["tier_push_bytes"] > 0

    jl = write_jsonl(tr, str(tmp_path / "events.jsonl"))
    lines = [json.loads(ln) for ln in open(jl)]
    assert lines[0]["type"] == "meta"
    assert sum(ln["type"] == "event" for ln in lines) == len(tr.events)
    assert any(ln["type"] == "solve_report" for ln in lines)

    snap = metrics_snapshot(tr)
    assert "obs_span_seconds_total" in snap
    assert 'name="solve"' in snap


def test_tracer_close_unwinds_nested_spans():
    tr = Tracer("t")
    outer = tr.begin("outer")
    tr.begin("inner")
    tr.begin("deeper")
    tr.close(outer, done=True)            # must close deeper+inner first
    assert validate_chrome_trace(chrome_trace(tr)) == []
    (root,) = span_tree(tr.events)
    assert root["args"]["done"] is True
    assert [c["name"] for c in root["children"]] == ["inner"]


# --------------------------------------------------------------------------- #
# report serialization (satellite: to_json powers the BENCH writers)
# --------------------------------------------------------------------------- #
def test_solve_report_to_json(traced):
    d = traced.to_json()
    assert d["schema_version"] == REPORT_SCHEMA_VERSION
    assert "x" not in d and "trace" not in d
    assert d["converged"] is True
    assert d["converged_iter"] == traced.converged_iter
    assert len(d["events"]) == 2
    for e in d["events"]:
        assert e["schema_version"] == REPORT_SCHEMA_VERSION
        assert e["kind"] == "fail-stop"
    # strictly JSON-safe: no device arrays, no NaN/inf anywhere
    json.dumps(d, allow_nan=False)
