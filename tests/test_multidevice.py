"""Multi-device integration: real sharded execution on 8 host devices.

Runs in a subprocess so the forced device count never leaks into the other
tests (the dry-run rule: only dedicated processes override device count).
"""
import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, numpy as np, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import smoke_config
from repro.configs.shapes import Shape, concrete_batch
from repro.launch import mesh as mesh_lib
from repro.models import sharding
from repro.models.lm import LM
from repro.train.optimizer import AdamWConfig, init_opt_state
from repro.train.train_step import make_train_step

assert len(jax.devices()) == 8
mesh = jax.make_mesh((4, 2), ("data", "model"))
sharding.set_context(mesh, mesh_lib.bindings(False))

cfg = smoke_config("internlm2_1_8b")
model = LM(cfg)
params, specs = model.init(jax.random.PRNGKey(0))
param_sh = sharding.physical_shardings(specs, params)
params = jax.device_put(params, param_sh)
opt = init_opt_state(params)
batch = concrete_batch(cfg, Shape("s", 32, 4, "train"))

step = jax.jit(make_train_step(model, AdamWConfig(warmup_steps=2)),
               in_shardings=(param_sh, None, None),
               out_shardings=(param_sh, None, None))
with mesh:
    p2, o2, m = step(params, opt, batch)
loss_sharded = float(m["loss"])

# same step on 1 logical device (no constraints) must agree closely
sharding.set_context(None, {})
p2_ref, o2_ref, m_ref = jax.jit(
    make_train_step(model, AdamWConfig(warmup_steps=2)))(params, opt, batch)
loss_ref = float(m_ref["loss"])
assert abs(loss_sharded - loss_ref) < 1e-3 * max(1.0, abs(loss_ref)), \
    (loss_sharded, loss_ref)
diff = max(float(jnp.abs(a - b).max()) for a, b in
           zip(jax.tree.leaves(p2), jax.tree.leaves(p2_ref)))
assert diff < 2e-2, diff

# buddy roll on a sharded array lowers to a real cross-device permute
x = jax.device_put(jnp.arange(32.0).reshape(8, 4),
                   NamedSharding(mesh, P(("data", "model"), None)))
rolled = jax.jit(lambda v: jnp.roll(v, 4, axis=0))(x)
np.testing.assert_array_equal(np.asarray(rolled),
                              np.roll(np.arange(32.0).reshape(8, 4), 4, 0))
print("MULTIDEVICE_OK", loss_sharded, diff)
"""


@pytest.mark.slow
def test_sharded_train_step_matches_single_device():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    out = subprocess.run([sys.executable, "-c", _SCRIPT], cwd=".",
                         env=env, capture_output=True, text=True,
                         timeout=420)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "MULTIDEVICE_OK" in out.stdout
