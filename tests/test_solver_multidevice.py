"""Distributed solver on 8 host devices (subprocess): the sharded runtime
must reproduce the single-device ESRP solve, and the ring-ppermute banded
SpMV must equal the reference matvec."""
import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
jax.config.update("jax_enable_x64", True)
import numpy as np, jax.numpy as jnp

from repro.comm.shard import (nodes_mesh, place_problem, ring_halo_matvec,
                              sharded_solver_ops)
from repro.core.driver import solve_resilient
from repro.sparse.matrices import build_problem

assert len(jax.devices()) == 8
problem = build_problem("poisson2d", n_nodes=8, nx=40, ny=40)
mesh = nodes_mesh(8)
placed = place_problem(problem, mesh)

with mesh:
    ops = sharded_solver_ops(placed, mesh)
    ref = solve_resilient(problem, strategy="none", rtol=1e-10)
    r = solve_resilient(placed, strategy="esrp", T=20, phi=1, rtol=1e-10,
                        ops=ops, fail_at=ref.converged_iter // 2,
                        failed_nodes=[3])
assert r.rel_residual < 1e-10, r.rel_residual
assert r.converged_iter == ref.converged_iter, (r.converged_iter,
                                                ref.converged_iter)

# ring halo exchange == reference matvec (bandwidth fits in one node slab)
x = jnp.asarray(np.random.default_rng(0).standard_normal(problem.m))
with mesh:
    halo_mv = ring_halo_matvec(placed.a, placed.part, mesh,
                               halo_tiles=placed.part.col_tiles_per_node)
    y_ring = halo_mv(jax.device_put(
        x, jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec("nodes"))))
y_ref = problem.a.matvec(x)
err = float(jnp.abs(y_ring - y_ref).max())
assert err < 1e-11, err
print("SOLVER_MULTIDEVICE_OK", r.converged_iter, err)
"""


@pytest.mark.slow
def test_distributed_solver_matches_single_device():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    out = subprocess.run([sys.executable, "-c", _SCRIPT], cwd=".",
                         env=env, capture_output=True, text=True,
                         timeout=500)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "SOLVER_MULTIDEVICE_OK" in out.stdout


_ASPMV_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
jax.config.update("jax_enable_x64", True)
import numpy as np, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.comm.shard import aspmv_push, nodes_mesh, place_problem
from repro.core.aspmv import build_plan
from repro.sparse.matrices import build_problem
from repro.sparse.partition import neighbor

problem = build_problem("poisson2d", n_nodes=8, nx=32, ny=32)
plan = build_plan(problem.a, problem.part, phi=2)
mesh = nodes_mesh(8)
placed = place_problem(problem, mesh)
x = jnp.asarray(np.random.default_rng(0).standard_normal(problem.m))
xs = jax.device_put(x, NamedSharding(mesh, P("nodes")))
with mesh:
    received = aspmv_push(plan, problem.part, mesh)(xs)

bn = problem.part.bn
xt = np.asarray(x).reshape(-1, bn)
checked = 0
for k, (vals, idx) in enumerate(received, start=1):
    vals, idx = np.asarray(vals), np.asarray(idx)
    for d in range(8):                       # receiving node
        for slot, t in enumerate(idx[d]):
            if t < 0:
                continue
            # node d received tile t from its k-th reverse neighbour
            np.testing.assert_allclose(vals[d, slot], xt[t], rtol=1e-14)
            assert plan.holders[t, d], (t, d)
            checked += 1
assert checked > 50, checked
print("ASPMV_PUSH_OK", checked)
"""


@pytest.mark.slow
def test_aspmv_physical_push_delivers_redundant_tiles():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    out = subprocess.run([sys.executable, "-c", _ASPMV_SCRIPT], cwd=".",
                         env=env, capture_output=True, text=True,
                         timeout=420)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "ASPMV_PUSH_OK" in out.stdout
