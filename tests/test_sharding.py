"""Sharding-context resolution: divisibility and conflict fallbacks."""
import pytest
from jax.sharding import PartitionSpec as P

from repro.models import sharding


class _FakeMesh:
    def __init__(self, shape):
        self.shape = shape


@pytest.fixture
def ctx():
    old_mesh, old_bind = sharding._CTX.mesh, sharding._CTX.bindings
    sharding._CTX.mesh = _FakeMesh({"pod": 2, "data": 16, "model": 16})
    sharding._CTX.bindings = {
        "dp": ("pod", "data"), "fsdp": ("pod", "data"),
        "tp": ("model",), "atp": ("model",), "sp": ("data",), "seqtp": ("model",)}
    yield sharding._CTX
    sharding._CTX.mesh, sharding._CTX.bindings = old_mesh, old_bind


def test_divisible_dims_fully_sharded(ctx):
    spec = sharding._resolve(("dp", None, "tp"), (256, 7, 4096))
    assert spec == P(("pod", "data"), None, "model")


def test_indivisible_dim_falls_back_to_prefix_or_replicated(ctx):
    # batch=1 cannot shard 32 ways -> prefix "pod"? 1 % 2 != 0 -> replicated
    spec = sharding._resolve(("dp", "sp"), (1, 524288))
    assert spec[0] is None
    assert spec[1] == "data"
    # batch=16 shards over pod*data? 16 % 32 != 0 -> prefix ("pod",)=2 works
    spec = sharding._resolve(("dp",), (16,))
    assert spec[0] == "pod"


def test_conflicting_axes_dropped(ctx):
    # dp consumes "data"; sp would reuse it -> dropped
    spec = sharding._resolve(("dp", "sp", "tp", None), (128, 32768, 16, 128))
    assert spec == P(("pod", "data"), None, "model", None)


def test_kv_head_deficit_replicates(ctx):
    # kv heads = 8 on a 16-way model axis -> replicated
    spec = sharding._resolve(("dp", None, "tp", None), (128, 1, 8, 128))
    assert spec[2] is None


def test_axis_size(ctx):
    assert sharding.axis_size("tp") == 16
    assert sharding.axis_size("dp") == 32
    assert sharding.axis_size("unbound") == 1


def test_no_mesh_is_noop():
    import jax.numpy as jnp
    x = jnp.zeros((4, 4))
    assert sharding.constrain(x, "dp", "tp") is x
