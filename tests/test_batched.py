"""Batched-axis solve stack: per-member convergence freeze + trajectory
identity.

The batched contract (ISSUE 8):

  * a (B, M) ``rhs`` arms the batched solve — every op carries a leading B
    axis and one dispatch advances all B members;
  * B=1 batched is bit-identical in f64 to the unbatched path;
  * a member converging mid-chunk FREEZES: later iterations must not touch
    its rows, while stragglers continue unaffected (continuous batching);
  * batched-vs-B×(B=1-loop) trajectories are bit-identical in f64 for
    esrp/imcr on the jnp + interpret backends, including through a
    mid-solve FailureEvent + Alg. 2 recovery (the default exact bundle);
  * the opt-in fused throughput mode (``batch_fused=True``) matches the
    exact trajectory to ~ulp, not bitwise;
  * per-member ``SolveReport``s carry schema v2 ``batch_index`` /
    ``batch_size`` placement.

Beyond-fail-stop on the batch axis (ISSUE 9): SDC detect → repair, elastic
shrunk-mesh recovery, and periodic residual replacement all run on (B, M)
state, and the exact bundle keeps every member bit-identical in f64 to its
own B=1 run through each of them.
"""
import numpy as np
import pytest

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp

from repro.core import sdc
from repro.core.driver import REPORT_SCHEMA_VERSION, solve_resilient
from repro.core.failures import FailureEvent, SDCEvent
from repro.sparse.matrices import build_problem


@pytest.fixture(scope="module")
def problem():
    return build_problem("poisson2d", n_nodes=4, nx=20)


@pytest.fixture(scope="module")
def small_problem():
    return build_problem("poisson2d", n_nodes=4, nx=12)


def _rhs_pair(problem):
    """Member 0 smooth (fast CG convergence), member 1 rough (straggler)."""
    rng = np.random.default_rng(3)
    return np.stack([np.ones(problem.part.m),
                     rng.standard_normal(problem.part.m)])


# --------------------------------------------------------------------------- #
# B=1 equivalence (acceptance criterion)
# --------------------------------------------------------------------------- #
def test_b1_batched_bit_identical_to_unbatched(problem):
    kw = dict(strategy="esrp", T=10, phi=1, rtol=1e-9)
    ref = solve_resilient(problem, **kw)
    reps = solve_resilient(problem, rhs=jnp.asarray(problem.b)[None, :], **kw)
    assert isinstance(reps, list) and len(reps) == 1
    assert reps[0].converged_iter == ref.converged_iter
    assert (np.asarray(reps[0].x) == np.asarray(ref.x)).all(), \
        "B=1 batched diverged from the unbatched path"
    assert reps[0].batch_index == 0 and reps[0].batch_size == 1


def test_b1_batched_with_failure_bit_identical(problem):
    kw = dict(strategy="esrp", T=10, phi=1, rtol=1e-9,
              scenario=[FailureEvent(25, (1,))])
    ref = solve_resilient(problem, **kw)
    reps = solve_resilient(problem, rhs=jnp.asarray(problem.b)[None, :], **kw)
    assert reps[0].converged_iter == ref.converged_iter
    assert (np.asarray(reps[0].x) == np.asarray(ref.x)).all()
    assert [e.target_iter for e in reps[0].events] == \
        [e.target_iter for e in ref.events]


# --------------------------------------------------------------------------- #
# per-member convergence freeze (continuous batching)
# --------------------------------------------------------------------------- #
def test_member_converging_mid_chunk_freezes(problem):
    """Member 0 (smooth rhs) converges mid-chunk well before member 1; its
    rows must stop updating at its own convergence: a run capped between
    the two convergence points carries bit-identical member-0 rows to the
    full run, while the straggler is still mid-flight."""
    rhs = jnp.asarray(_rhs_pair(problem))
    kw = dict(strategy="esrp", T=10, rtol=1e-8, chunk=8)
    full = solve_resilient(problem, rhs=rhs, **kw)
    k0, k1 = full[0].converged_iter, full[1].converged_iter
    assert k0 < k1, "fixture rhs must separate the convergence points"
    cap = ((k0 + k1) // 2 // 8) * 8          # chunk-aligned, between k0, k1
    assert k0 < cap < k1
    capped = solve_resilient(problem, rhs=rhs, max_iters=cap, **kw)
    # frozen rows asserted: iterations (k0, cap] did not touch member 0
    assert capped[0].converged and capped[0].converged_iter == k0
    assert (np.asarray(capped[0].x) == np.asarray(full[0].x)).all(), \
        "converged member kept updating after its freeze point"
    # the straggler really was mid-flight at the cap
    assert not capped[1].converged
    assert not (np.asarray(capped[1].x) == np.asarray(full[1].x)).all()


def test_straggler_unaffected_by_frozen_member(problem):
    """The straggler's trajectory is bit-identical to its own B=1 run —
    the frozen member contributes nothing after its convergence."""
    rhs = _rhs_pair(problem)
    kw = dict(strategy="esrp", T=10, rtol=1e-8, chunk=8)
    full = solve_resilient(problem, rhs=jnp.asarray(rhs), **kw)
    solo = solve_resilient(problem, rhs=jnp.asarray(rhs[1]), **kw)
    assert full[1].converged_iter == solo.converged_iter
    assert (np.asarray(full[1].x) == np.asarray(solo.x)).all()


def test_zero_rhs_member_freezes_at_zero(problem):
    """A zero-RHS member (the micro-batch padding case) freezes at
    iteration 0: x stays exactly 0, rel = 0, reported converged."""
    rhs = np.stack([np.zeros(problem.part.m),
                    np.asarray(problem.b)])
    reps = solve_resilient(problem, rhs=jnp.asarray(rhs), strategy="esrp",
                           T=10, rtol=1e-9)
    assert reps[0].converged and reps[0].rel_residual == 0.0
    assert (np.asarray(reps[0].x) == 0.0).all()
    # the real member is untouched by the padding row
    ref = solve_resilient(problem, strategy="esrp", T=10, rtol=1e-9)
    assert (np.asarray(reps[1].x) == np.asarray(ref.x)).all()


# --------------------------------------------------------------------------- #
# batched-vs-B×(B=1) trajectory identity, esrp/imcr × jnp/interpret
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("strategy", ["esrp", "imcr"])
@pytest.mark.parametrize("backend", ["jnp", "interpret"])
def test_batched_matches_sequential_with_failure(small_problem, strategy,
                                                 backend):
    rng = np.random.default_rng(5)
    rhs = rng.standard_normal((3, small_problem.part.m))
    kw = dict(strategy=strategy, T=5, phi=1, rtol=1e-9, backend=backend,
              scenario=[FailureEvent(12, (1,))], chunk=16)
    reps = solve_resilient(small_problem, rhs=jnp.asarray(rhs), **kw)
    assert len(reps) == 3
    for k in range(3):
        solo = solve_resilient(small_problem, rhs=jnp.asarray(rhs[k]), **kw)
        assert reps[k].converged_iter == solo.converged_iter, k
        assert (np.asarray(reps[k].x) == np.asarray(solo.x)).all(), \
            f"member {k} diverged from its B=1 run ({strategy}/{backend})"
        assert reps[k].batch_index == k and reps[k].batch_size == 3


# --------------------------------------------------------------------------- #
# fused throughput mode
# --------------------------------------------------------------------------- #
def test_fused_mode_converges_and_tracks_exact(problem):
    rng = np.random.default_rng(11)
    rhs = rng.standard_normal((4, problem.part.m))
    kw = dict(strategy="esrp", T=10, phi=1, rtol=1e-8)
    exact = solve_resilient(problem, rhs=jnp.asarray(rhs), **kw)
    fused = solve_resilient(problem, rhs=jnp.asarray(rhs),
                            batch_fused=True, **kw)
    for k in range(4):
        assert fused[k].converged
        xe, xf = np.asarray(exact[k].x), np.asarray(fused[k].x)
        rel = np.linalg.norm(xf - xe) / np.linalg.norm(xe)
        assert rel < 1e-12, (k, rel)


# --------------------------------------------------------------------------- #
# report schema + batched restrictions
# --------------------------------------------------------------------------- #
def test_report_schema_v2_batch_placement(problem):
    rng = np.random.default_rng(2)
    rhs = rng.standard_normal((2, problem.part.m))
    reps = solve_resilient(problem, rhs=jnp.asarray(rhs), strategy="esrp",
                           T=10, rtol=1e-9)
    assert REPORT_SCHEMA_VERSION >= 2
    for k, r in enumerate(reps):
        doc = r.to_json()
        assert doc["schema_version"] == REPORT_SCHEMA_VERSION
        assert doc["batch_index"] == k and doc["batch_size"] == 2
    # the unbatched report places itself as member 0 of a size-1 batch
    doc = solve_resilient(problem, strategy="esrp", T=10,
                          rtol=1e-9).to_json()
    assert doc["batch_index"] == 0 and doc["batch_size"] == 1


def test_batched_rejects_bad_inputs(problem):
    rhs = jnp.asarray(np.ones((2, problem.part.m)))
    with pytest.raises(ValueError, match="rhs row length"):
        solve_resilient(problem, rhs=rhs[:, :-1])
    # a failure runtime built for the wrong batch width: the message names
    # the constructor call that would match this solve
    rt = type("FakeRuntime", (), {"batch": 0})()
    with pytest.raises(ValueError,
                       match=r"ShardedFailureRuntime\(problem, mesh, "
                             r"batch=2\)"):
        solve_resilient(problem, rhs=rhs, failure_runtime=rt)
    rt = type("FakeRuntime", (), {"batch": 3})()
    with pytest.raises(ValueError,
                       match=r"this solve is unbatched.*default 0"):
        solve_resilient(problem, failure_runtime=rt)


# --------------------------------------------------------------------------- #
# beyond-fail-stop on the batch axis (ISSUE 9 tentpole)
# --------------------------------------------------------------------------- #
def _repairs(rep):
    return [e for e in rep.events if e.kind == "sdc-repair"]


@pytest.mark.parametrize("target", ["p", "r", "queue"])
@pytest.mark.parametrize("backend", ["jnp", "interpret"])
def test_batched_sdc_repair_matches_sequential(small_problem, backend,
                                               target):
    """A mid-iteration SDCEvent in a B=4 batched solve is detected within
    check_every, repaired through the per-member Alg. 2 path, and every
    member rejoins its own B=1 run bit-identically in f64."""
    rng = np.random.default_rng(7)
    rhs = rng.standard_normal((4, small_problem.part.m))
    kw = dict(strategy="esrp", T=5, rtol=1e-9, backend=backend, chunk=16,
              scenario=[SDCEvent(iter=12, nodes=(1,), target=target)])
    reps = solve_resilient(small_problem, rhs=jnp.asarray(rhs), **kw)
    assert len(reps) == 4
    (er,) = _repairs(reps[0])
    assert 0 < er.detect_latency <= sdc.SDCPolicy().check_every
    assert er.detect_iter == 12 + er.detect_latency
    for k in range(4):
        solo = solve_resilient(small_problem, rhs=jnp.asarray(rhs[k]), **kw)
        assert reps[k].converged_iter == solo.converged_iter, (k, target)
        assert (np.asarray(reps[k].x) == np.asarray(solo.x)).all(), \
            f"member {k} diverged from its B=1 run after SDC repair " \
            f"({target}/{backend})"


def test_batched_detect_latency_lands_in_the_trace(small_problem):
    """obs=on, batched: the ``sdc_detect`` instant carries the attributed
    latency, bounded by the check cadence — detection latency stays a
    first-class trace signal on the batch axis (ISSUE 9 satellite)."""
    rng = np.random.default_rng(7)
    rhs = rng.standard_normal((4, small_problem.part.m))
    reps = solve_resilient(
        small_problem, rhs=jnp.asarray(rhs), strategy="esrp", T=5,
        rtol=1e-9, chunk=16, obs=True,
        scenario=[SDCEvent(iter=12, nodes=(1,), target="r")])
    (er,) = _repairs(reps[0])
    instants = [e for e in reps[0].trace.events
                if e["name"] == "sdc_detect" and e["ph"] == "i"]
    assert len(instants) == 1
    a = instants[0]["args"]
    assert a["latency"] == er.detect_latency
    assert 0 < a["latency"] <= sdc.SDCPolicy().check_every
    assert a["iter"] == er.detect_iter
    from repro.obs import span_tree, walk_spans
    spans = [n for n in walk_spans(span_tree(reps[0].trace.events))
             if n["name"] == "event:sdc-repair"]
    assert len(spans) == 1


def test_sdc_event_after_member_converged_shields_it(problem):
    """An SDC strike AFTER member 0's convergence must not disturb its
    frozen rows: injection and repair are both member-selected. The live
    straggler still detects, repairs, and matches its solo run bitwise."""
    rhs = jnp.asarray(_rhs_pair(problem))
    kw = dict(strategy="esrp", T=10, rtol=1e-8, chunk=8)
    clean = solve_resilient(problem, rhs=rhs, **kw)
    k0, k1 = clean[0].converged_iter, clean[1].converged_iter
    assert k0 + 2 < k1, "fixture rhs must separate the convergence points"
    ev = [SDCEvent(iter=k0 + 2, nodes=(1,), target="r")]
    reps = solve_resilient(problem, rhs=rhs, scenario=ev, **kw)
    assert len(_repairs(reps[0])) == 1
    assert reps[0].converged_iter == k0
    assert (np.asarray(reps[0].x) == np.asarray(clean[0].x)).all(), \
        "SDC repair touched a converged member's frozen rows"
    solo = solve_resilient(problem, rhs=rhs[1], scenario=ev, **kw)
    assert reps[1].converged_iter == solo.converged_iter
    assert (np.asarray(reps[1].x) == np.asarray(solo.x)).all()


def test_padded_zero_rhs_member_never_flags(problem):
    """Satellite regression: with the invariant checks armed, a padded
    zero-RHS member (‖b‖ = 0) is excluded from every relative detector —
    the run must finish with no repairs and the padding rows exactly 0."""
    rhs = np.stack([np.asarray(problem.b), np.zeros(problem.part.m)])
    reps = solve_resilient(problem, rhs=jnp.asarray(rhs), strategy="esrp",
                           T=10, rtol=1e-9, sdc_policy=sdc.SDCPolicy())
    assert _repairs(reps[0]) == [], \
        "a zero-RHS padding member tripped an SDC detector"
    assert reps[0].sdc_checks > 0
    assert reps[1].converged and reps[1].rel_residual == 0.0
    assert (np.asarray(reps[1].x) == 0.0).all()
    ref = solve_resilient(problem, strategy="esrp", T=10, rtol=1e-9,
                          sdc_policy=sdc.SDCPolicy())
    assert (np.asarray(reps[0].x) == np.asarray(ref.x)).all()


def test_batched_elastic_shrink_matches_sequential(small_problem):
    """Unsurvivable failure + elastic=True on a B=3 batch: the whole (B, …)
    state tree re-partitions onto the shrunk mesh and every member keeps
    solving. Rejoin is norm-wise vs the member's own B=1 elastic run (the
    re-padded length may re-associate reductions)."""
    rng = np.random.default_rng(9)
    rhs = rng.standard_normal((3, small_problem.part.m))
    kw = dict(strategy="esrp", T=5, rtol=1e-9, chunk=16, elastic=True,
              scenario=[FailureEvent(12, (2,))])
    reps = solve_resilient(small_problem, rhs=jnp.asarray(rhs), **kw)
    assert len(reps) == 3
    for k in range(3):
        solo = solve_resilient(small_problem, rhs=jnp.asarray(rhs[k]), **kw)
        assert solo.final_n_nodes < small_problem.part.n_nodes
        assert reps[k].final_n_nodes == solo.final_n_nodes
        assert reps[k].converged and solo.converged
        xs, xb = np.asarray(solo.x), np.asarray(reps[k].x)
        assert xs.shape == xb.shape
        err = np.linalg.norm(xb - xs) / max(np.linalg.norm(xs), 1.0)
        assert err < 1e-9, (k, err)


def test_batched_rr_every_matches_sequential(small_problem):
    """Periodic residual replacement on the batch axis: the batch-aware
    ops.dot keeps the replaced r/z bit-identical per member."""
    rng = np.random.default_rng(13)
    rhs = rng.standard_normal((2, small_problem.part.m))
    kw = dict(strategy="esrp", T=5, rtol=1e-9, rr_every=7, chunk=16)
    reps = solve_resilient(small_problem, rhs=jnp.asarray(rhs), **kw)
    for k in range(2):
        solo = solve_resilient(small_problem, rhs=jnp.asarray(rhs[k]), **kw)
        assert reps[k].converged_iter == solo.converged_iter, k
        assert (np.asarray(reps[k].x) == np.asarray(solo.x)).all(), \
            f"member {k} diverged from its B=1 run under rr_every"


# --------------------------------------------------------------------------- #
# sdc_policy=None / obs=off adds ZERO ops on the batched path (structural
# jaxpr identity vs the pre-telemetry freeze scan — see repro.analysis)
# --------------------------------------------------------------------------- #
def test_batched_chunk_metrics_off_jaxpr_identity(small_problem):
    from repro.analysis import assert_structurally_equal
    from repro.core import esrp

    B = 3
    ops = small_problem.solver_ops("jnp", batch=B)
    rhs = jnp.stack([jnp.asarray(small_problem.b) * (i + 1.0)
                     for i in range(B)])
    st = esrp.esrp_init(ops.matvec, ops.precond, rhs, dot=ops.dot)
    thresh = jnp.full((B,), 1e-8, rhs.dtype)

    def step(s):
        s2 = esrp.esrp_step(s, ops, 10, b=rhs, rr_every=0, gated=True,
                            push=None)
        return s2, jnp.linalg.norm(s2.pcg.r, axis=-1)

    def ref_chunk(s0):
        # the batched freeze scan with no aux branch anywhere: converged
        # members hold their rows, the chunk halts when all are done
        def advance(carry):
            s, rnorm = carry
            s2, rn2 = step(s)
            done = rnorm < thresh
            return (esrp.member_select(s, s2, done),
                    jnp.where(done, rnorm, rn2))

        def body(carry, _):
            carry = jax.lax.cond(jnp.all(carry[1] < thresh),
                                 lambda c: c, advance, carry)
            return carry, carry[1]

        (s0, _), norms = jax.lax.scan(
            body, (s0, jnp.linalg.norm(s0.pcg.r, axis=-1)), None, length=8)
        return s0, norms

    got = jax.make_jaxpr(lambda s: esrp.run_chunk.__wrapped__(
        s, ops, 10, 8, thresh, 0, True, rhs, None, False))(st)
    want = jax.make_jaxpr(ref_chunk)(st)
    assert_structurally_equal(got, want, "batched obs=off adds zero ops")
