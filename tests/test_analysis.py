"""Static-invariant checker (repro.analysis — ISSUE 10).

The contract under test:
  * the structural differ is exactly as strict as string equality modulo
    alpha-renaming (variable names never matter, one extra op always does)
    and reports the first diverging equation with its path;
  * every pass fires on its deliberately-broken fixture (``broken.*``) and
    stays silent on every clean registered entry point this box can build;
  * the shared walker reproduces the old hand-rolled ``_dots`` contract
    (recurse through pjit bodies, skip cond branches);
  * findings documents round-trip through the JSON schema validator and
    baseline waivers absorb exactly ``max`` occurrences of their key;
  * the CLI gates: exit 1 on findings, 0 when the baseline absorbs them,
    and the committed baseline keeps ``--entry all`` green (subprocess,
    8 forced host devices — the sharded entries analyze too).
"""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import pytest

from repro.analysis import (Finding, apply_baseline, assert_structurally_equal,
                            check_findings_doc, findings_doc,
                            first_divergence, walker)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# --------------------------------------------------------------------------- #
# structural differ
# --------------------------------------------------------------------------- #
def test_differ_alpha_equivalence():
    def f(a, b):
        return jnp.sum(a * b) + 1.0

    def g(x, y):                     # same graph, different binder names
        return jnp.sum(x * y) + 1.0

    x = jnp.ones(8)
    assert first_divergence(jax.make_jaxpr(f)(x, x),
                            jax.make_jaxpr(g)(x, x)) is None
    assert_structurally_equal(jax.make_jaxpr(f)(x, x),
                              jax.make_jaxpr(g)(x, x))


def test_differ_catches_one_extra_op_with_path():
    def f(a):
        return jnp.sum(a * 2.0)

    def g(a):
        return jnp.sum(a * 2.0 + 0.0)    # one smuggled add

    x = jnp.ones(8)
    div = first_divergence(jax.make_jaxpr(f)(x), jax.make_jaxpr(g)(x))
    assert div is not None
    assert "eqn" in div["path"]
    with pytest.raises(AssertionError, match="diverge"):
        assert_structurally_equal(jax.make_jaxpr(f)(x), jax.make_jaxpr(g)(x))


def test_differ_catches_literal_and_dtype_changes():
    x = jnp.ones(8)
    a = jax.make_jaxpr(lambda v: v * 2.0)(x)
    b = jax.make_jaxpr(lambda v: v * 3.0)(x)
    assert first_divergence(a, b) is not None
    c = jax.make_jaxpr(lambda v: v * 2.0)(jnp.ones(8, jnp.float32))
    assert first_divergence(a, c) is not None


def test_differ_descends_into_cond_branches():
    def mk(off_branch):
        def f(v, flag):
            return jax.lax.cond(flag, lambda u: u * 2.0, off_branch, v)
        return jax.make_jaxpr(f)(jnp.ones(8), True)

    same = first_divergence(mk(lambda u: u + 1.0), mk(lambda u: u + 1.0))
    assert same is None
    div = first_divergence(mk(lambda u: u + 1.0), mk(lambda u: u + 2.0))
    assert div is not None and "branches" in div["path"]


# --------------------------------------------------------------------------- #
# walker (the shared traversal the gating tests migrated onto)
# --------------------------------------------------------------------------- #
def _gated_graph():
    def f(v, flag):
        w = jnp.dot(v, v) * v                      # unconditional dot
        w = jax.jit(lambda u: u * jnp.dot(u, u))(w)   # dot inside pjit body
        return jax.lax.cond(flag,
                            lambda u: jnp.dot(u, u),  # dot under cond
                            lambda u: jnp.asarray(0.0), w)
    return jax.make_jaxpr(f)(jnp.ones(8), True)


def test_walker_counts_match_dots_contract():
    j = _gated_graph()
    assert walker.count_primitives(j, "dot_general", into_conds=False) == 2
    assert walker.count_primitives(j, "dot_general", into_conds=True) == 3


def test_walker_sites_carry_paths_and_cond_flag():
    sites = walker.sites_of(_gated_graph(), "dot_general")
    assert len(sites) == 3
    in_cond = [s for s in sites if s.in_cond]
    assert len(in_cond) == 1 and "branches" in in_cond[0].path


# --------------------------------------------------------------------------- #
# findings schema + baseline waivers
# --------------------------------------------------------------------------- #
def _finding(**kw):
    base = dict(pass_id="gating", entry="e", eqn_path="eqn0",
                severity="error", code="c", explanation="why")
    base.update(kw)
    return Finding(**base)


def test_findings_doc_schema_roundtrip():
    doc = findings_doc([_finding()], entries=["e"], passes=["gating"])
    assert check_findings_doc(json.loads(json.dumps(doc))) == []


def test_findings_doc_schema_rejects_bad_docs():
    good = findings_doc([_finding()], entries=["e"], passes=["gating"])
    bad = json.loads(json.dumps(good))
    bad["findings"][0]["severity"] = "catastrophic"
    assert check_findings_doc(bad)
    bad2 = json.loads(json.dumps(good))
    bad2["findings"][0]["entry"] = "unregistered"
    assert check_findings_doc(bad2)
    assert check_findings_doc({"schema_version": 99})


def test_baseline_waiver_budget():
    waivers = [dict(pass_id="gating", entry="e", code="c", max=2,
                    justification="known")]
    fs = [_finding(), _finding(), _finding(),
          _finding(code="other")]
    new, waived = apply_baseline(fs, waivers)
    assert len(waived) == 2             # budget caps at max
    assert len(new) == 2                # overflow + unmatched code stay new


# --------------------------------------------------------------------------- #
# every pass fires on its broken fixture; clean entries stay silent
# --------------------------------------------------------------------------- #
_EXPECT = {
    "broken.identity": ("identity", "jaxpr-divergence"),
    "broken.gating": ("gating", "gated-branch-not-free"),
    "broken.host_sync": ("host_sync", "host-sync"),
    "broken.determinism": ("determinism", "unpinned-dot"),
    "broken.batch": ("determinism", "batch-axis-reduction"),
    "broken.sharding": ("sharding", "member-axis-sharded"),
}


@pytest.mark.parametrize("entry", sorted(_EXPECT))
def test_broken_fixture_trips_its_pass(entry):
    from repro.analysis import registry
    from repro.analysis.passes import run_passes
    pass_id, code = _EXPECT[entry]
    found = run_passes(registry.build(entry))
    assert any(f.pass_id == pass_id and f.code == code for f in found), \
        [(f.pass_id, f.code) for f in found]


def test_clean_entries_only_baselined_findings():
    """Every entry this box can build yields no finding outside the
    committed baseline (the in-process version of the CI gate; the
    8-device entries run in the subprocess test below)."""
    from repro.analysis import registry
    from repro.analysis.findings import load_baseline
    from repro.analysis.passes import run_passes
    waivers = load_baseline(
        os.path.join(REPO, "artifacts", "analysis", "baseline.json"))
    n_dev = jax.device_count()
    analyzed, findings = [], []
    for name in registry.names():
        if registry.get(name).requires_devices > n_dev:
            continue
        findings += run_passes(registry.build(name))
        analyzed.append(name)
    assert len(analyzed) >= 10, analyzed
    new, _ = apply_baseline(findings, waivers)
    assert not new, [(f.entry, f.pass_id, f.code, f.eqn_path) for f in new]


# --------------------------------------------------------------------------- #
# CLI gate (subprocess: fresh interpreter, 8 forced host devices)
# --------------------------------------------------------------------------- #
def _cli(*args, timeout=600):
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    env.pop("XLA_FLAGS", None)       # __main__ must set the device count
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis", *args],
        capture_output=True, text=True, timeout=timeout, cwd=REPO, env=env)


def test_cli_exit_codes_and_json(tmp_path):
    r = _cli("--entry", "broken.determinism", "--format", "json",
             "--out", str(tmp_path / "f.json"))
    assert r.returncode == 1, r.stderr
    doc = json.loads(r.stdout)
    assert doc["new_findings"] and doc["tool"] == "repro.analysis"
    with open(tmp_path / "f.json") as f:
        assert check_findings_doc(json.load(f)) == []

    ok = _cli("--entry", "kernels.spmv_dot.jnp")
    assert ok.returncode == 0, ok.stdout + ok.stderr


@pytest.mark.slow
def test_cli_all_entries_green_with_baseline():
    """The committed gate itself: all registered entries — including the
    8-device sharded ones (the subprocess forces 8 host devices) — with
    the committed baseline."""
    r = _cli("--entry", "all", "--baseline",
             "artifacts/analysis/baseline.json")
    assert r.returncode == 0, r.stdout + r.stderr
    assert "skipped" not in r.stdout, r.stdout
