"""Failure-scenario engine: multi-event / multi-node failures + the three
confirmed crash-path regressions.

Scenario semantics under test (driver.solve_resilient(scenario=...)):
  * simultaneous multi-node events reconstruct exactly — the trajectory
    rejoins the failure-free run (same converged iteration);
  * staggered multi-event runs (failure → recover → fail again) for both
    ESRP and IMCR, with per-event accounting in SolveReport.events;
  * a second event before the next completed storage stage rolls back to
    the SAME reconstruction point again (or restarts when none exists);
  * validation rejects malformed scenarios.

Regression coverage (confirmed crash paths):
  * strategy="none" with an injected failure used to crash with
    AttributeError (plan is None) — must cleanly restart (target_iter=-1);
  * run_pcg with b = 0 used to return rel = NaN (0/0) — must return x = 0,
    rel = 0.0 (protects the Alg. 2 line-6/8 inner solves);
  * the post-recovery resume used to run a bare pcg_iterate_ops, skipping a
    residual replacement landing on the resume iteration.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import esrp
from repro.core.driver import solve_resilient
from repro.core.failures import FailureEvent, normalize_scenario
from repro.core.pcg import run_pcg
from repro.sparse.matrices import build_problem


@pytest.fixture(scope="module")
def problem():
    return build_problem("poisson2d", n_nodes=8, nx=40, ny=40)


@pytest.fixture(scope="module")
def reference(problem):
    return solve_resilient(problem, strategy="none", rtol=1e-10)


# --------------------------------------------------------------------------- #
# scenario engine
# --------------------------------------------------------------------------- #
def test_simultaneous_two_node_phi2_exact(problem, reference):
    """φ=2 simultaneous 2-node failure reconstructs exactly: the trajectory
    rejoins the failure-free run (same total iteration count)."""
    J = reference.converged_iter // 2
    r = solve_resilient(problem, strategy="esrp", T=20, phi=2, rtol=1e-10,
                        scenario=[FailureEvent(J, (2, 5))])
    assert r.rel_residual < 1e-10
    assert r.converged_iter == reference.converged_iter
    assert len(r.events) == 1
    assert r.events[0].nodes == (2, 5)
    assert r.inner_rel < 1e-13


def test_staggered_two_event_esrp(problem, reference):
    r = solve_resilient(
        problem, strategy="esrp", T=20, phi=1, rtol=1e-10,
        scenario=[FailureEvent(45, (2,)), FailureEvent(70, (5,))])
    assert r.rel_residual < 1e-10
    assert r.converged_iter == reference.converged_iter
    assert [e.iter for e in r.events] == [45, 70]
    # each event rolled back to its own stage's reconstruction point
    assert r.events[0].target_iter == 41
    assert r.events[1].target_iter == 61
    # aggregate accounting is the per-event sum; scalars mirror the last event
    assert r.wasted_iters == sum(e.wasted_iters for e in r.events)
    assert r.recovery_s == pytest.approx(
        sum(e.recovery_s for e in r.events))
    assert r.target_iter == r.events[-1].target_iter


def test_staggered_two_event_imcr(problem, reference):
    r = solve_resilient(
        problem, strategy="imcr", T=20, phi=2, rtol=1e-10,
        scenario=[FailureEvent(45, (5, 6)), FailureEvent(70, (1,))])
    assert r.rel_residual < 1e-10
    assert r.converged_iter == reference.converged_iter
    assert [e.target_iter for e in r.events] == [40, 60]
    assert r.wasted_iters == sum(e.wasted_iters for e in r.events)


def test_second_failure_before_next_stage_rolls_back_further(problem,
                                                             reference):
    """Event 2 strikes the re-run before the next storage stage (60, 61)
    completes: the queue still holds only the (40, 41) pair, so recovery
    rolls back to 41 AGAIN — the staggered worst case."""
    r = solve_resilient(
        problem, strategy="esrp", T=20, phi=1, rtol=1e-10,
        scenario=[FailureEvent(58, (2,)), FailureEvent(59, (5,))])
    assert r.rel_residual < 1e-10
    assert r.converged_iter == reference.converged_iter
    assert [e.target_iter for e in r.events] == [41, 41]
    assert [e.wasted_iters for e in r.events] == [17, 18]


def test_second_failure_before_first_stage_restarts(problem, reference):
    """Both events land before any storage stage has completed: restart from
    scratch twice and still converge."""
    r = solve_resilient(
        problem, strategy="esrp", T=20, phi=1, rtol=1e-10,
        scenario=[FailureEvent(5, (1,)), FailureEvent(10, (3,))])
    assert r.rel_residual < 1e-10
    assert [e.target_iter for e in r.events] == [-1, -1]
    assert [e.wasted_iters for e in r.events] == [5, 10]
    assert r.converged_iter == reference.converged_iter


def test_imcr_second_event_before_next_checkpoint(problem, reference):
    """IMCR keeps the checkpoint anchor valid through recovery: a second
    event before the next scheduled checkpoint rolls back to the same tag."""
    r = solve_resilient(
        problem, strategy="imcr", T=20, phi=1, rtol=1e-10,
        scenario=[FailureEvent(45, (2,)), FailureEvent(50, (5,))])
    assert r.rel_residual < 1e-10
    assert [e.target_iter for e in r.events] == [40, 40]
    assert r.converged_iter == reference.converged_iter


def test_non_jacobi_multi_node_simultaneous():
    """Multi-node ReconstructionOps over the union of failed rows, with a
    preconditioner that has genuine off-diagonal coupling (real P_{f,I\\f}
    strip + local P_ff solve over a non-contiguous union)."""
    p = build_problem("poisson2d", n_nodes=8, nx=32, precond="ssor")
    ref = solve_resilient(p, strategy="none", rtol=1e-9)
    T = 10        # SSOR converges fast — keep the stage inside the run
    J = (ref.converged_iter // 2 // T) * T + T - 2
    r = solve_resilient(p, strategy="esrp", T=T, phi=2, rtol=1e-9,
                        scenario=[FailureEvent(J, (2, 5))])
    assert r.target_iter > 0          # real rollback, not a restart
    assert r.rel_residual < 1e-9
    assert r.converged_iter == ref.converged_iter
    assert r.inner_rel < 1e-13


def test_unsurvivable_event_raises(problem):
    """phi=1 cannot cover two adjacent failed nodes (all copies lost)."""
    with pytest.raises(RuntimeError):
        solve_resilient(problem, strategy="esrp", T=20, phi=1, rtol=1e-10,
                        scenario=[FailureEvent(45, (0, 1))])


def test_imcr_survival_is_topology_aware(problem, reference):
    """IMCR's per-event check walks the buddy topology: spread-out failures
    beyond the φ count survive (every failed node keeps a live buddy),
    adjacent ones that orphan a node do not."""
    r = solve_resilient(problem, strategy="imcr", T=20, phi=1, rtol=1e-10,
                        scenario=[FailureEvent(45, (2, 6))])
    assert r.rel_residual < 1e-10
    assert r.converged_iter == reference.converged_iter
    with pytest.raises(RuntimeError):
        # node 5's only (phi=1) buddy is node 6 — both failed
        solve_resilient(problem, strategy="imcr", T=20, phi=1, rtol=1e-10,
                        scenario=[FailureEvent(45, (5, 6))])


def test_scenario_validation():
    n = 8
    ok = normalize_scenario([FailureEvent(10, (1,)), (20, (2, 3))], None,
                            None, n)
    assert [e.iter for e in ok] == [10, 20]
    assert ok[1].nodes == (2, 3)
    # legacy shorthand still builds a one-event scenario
    assert normalize_scenario(None, 30, [4], n) == [FailureEvent(30, (4,))]
    # iter=0 is a valid event (fires before any storage push; the driver
    # restarts cleanly) — only negatives are rejected, with a clear message
    assert normalize_scenario([FailureEvent(0, (1,))], None, None,
                              n)[0].iter == 0
    with pytest.raises(ValueError, match="must be >= 0"):
        FailureEvent(-1, (1,))
    assert normalize_scenario(None, None, None, n) == []
    with pytest.raises(ValueError):   # both APIs at once
        normalize_scenario([FailureEvent(10, (1,))], 10, [1], n)
    with pytest.raises(ValueError):   # scenario + stray failed_nodes:
        # silently dropping [3] would run a different experiment
        normalize_scenario([FailureEvent(10, (1,))], None, [3], n)
    with pytest.raises(ValueError):   # non-increasing iterations
        normalize_scenario([FailureEvent(20, (1,)), FailureEvent(10, (2,))],
                           None, None, n)
    with pytest.raises(ValueError):   # duplicate event iteration
        normalize_scenario([FailureEvent(10, (1,)), FailureEvent(10, (2,))],
                           None, None, n)
    with pytest.raises(ValueError):   # node out of range
        normalize_scenario([FailureEvent(10, (8,))], None, None, n)
    with pytest.raises(ValueError):   # repeated node within an event
        normalize_scenario([FailureEvent(10, (1, 1))], None, None, n)
    with pytest.raises(ValueError):   # no survivors
        normalize_scenario([FailureEvent(10, tuple(range(n)))], None, None, n)
    with pytest.raises(ValueError):   # empty event
        normalize_scenario([FailureEvent(10, ())], None, None, n)
    with pytest.raises(ValueError, match="without fail_at"):
        # regression: failed_nodes without fail_at used to silently return []
        # — the requested failure never fired and the run reported a clean
        # solve
        normalize_scenario(None, None, [3], n)


def test_iter_zero_event_restarts_cleanly(problem, reference):
    """An event at iteration 0 fires before any storage push completed:
    the driver restarts from scratch (target_iter = -1) and still
    converges at the reference iteration."""
    r = solve_resilient(problem, strategy="esrp", T=20, rtol=1e-10,
                        scenario=[FailureEvent(0, (2,))])
    assert r.events[0].target_iter == -1
    assert r.events[0].wasted_iters == 0
    assert r.converged
    assert r.converged_iter == reference.converged_iter


def test_failed_nodes_without_fail_at_raises(problem):
    """Driver-level regression for the silent-[] bug: the solve must refuse
    to run a 'failure experiment' whose failure can never fire."""
    with pytest.raises(ValueError, match="without fail_at"):
        solve_resilient(problem, strategy="esrp", T=20, phi=1, rtol=1e-10,
                        failed_nodes=[2])


def test_target_iter_sentinel_normalized(problem, reference):
    """-1 is the single 'no reconstruction point' sentinel: failure-free
    runs report it too (the undocumented -2 is gone); restarts keep it; a
    real rollback reports the reconstruction iteration."""
    assert reference.target_iter == -1 and not reference.events
    assert reference.converged
    r = solve_resilient(problem, strategy="esrp", T=20, phi=1, rtol=1e-10,
                        fail_at=45, failed_nodes=[2])
    assert r.target_iter == 41


def test_attach_local_delta_guarded_at_max_iters(problem, reference):
    """A run stopped at max_iters reports converged=False; the node-local
    iteration delta against it would be meaningless and stays None."""
    from repro.comm.shard import attach_local_delta

    capped = solve_resilient(problem, strategy="none", rtol=1e-10,
                             max_iters=10, chunk=5)
    assert not capped.converged and capped.converged_iter == 10
    attach_local_delta(capped, reference)
    assert capped.local_delta_iters is None
    ok = solve_resilient(problem, strategy="none", rtol=1e-10)
    attach_local_delta(ok, reference)
    assert ok.local_delta_iters == 0


# --------------------------------------------------------------------------- #
# regression: the three confirmed crash paths
# --------------------------------------------------------------------------- #
def test_none_strategy_failure_restarts(problem, reference):
    """strategy="none" + fail_at used to crash (AttributeError on the None
    RedundancyPlan); a failure without redundancy must restart cleanly."""
    r = solve_resilient(problem, strategy="none", rtol=1e-10,
                        fail_at=30, failed_nodes=[1])
    assert r.target_iter == -1
    assert r.wasted_iters == 30
    assert r.rel_residual < 1e-10
    assert r.converged_iter == reference.converged_iter


def test_run_pcg_zero_rhs_returns_zero(problem):
    """b = 0 used to loop to max_iters on NaN and return rel = 0/0 = NaN."""
    mv = problem.a.matvec
    b0 = jnp.zeros_like(problem.b)
    st, rel = run_pcg(mv, problem.apply_precond, b0)
    assert float(rel) == 0.0
    assert not np.isnan(np.asarray(st.x)).any()
    np.testing.assert_array_equal(np.asarray(st.x), 0.0)
    # a nonzero initial guess must not leak through: the solution of b=0 is 0
    st, rel = run_pcg(mv, problem.apply_precond, b0,
                      x0=jnp.ones_like(problem.b))
    assert float(rel) == 0.0
    np.testing.assert_array_equal(np.asarray(st.x), 0.0)


def test_resume_step_applies_residual_replacement(problem):
    """The post-recovery resume runs the same rr gate as the chunk runner:
    when the resume iteration is a replacement iteration, r comes back as
    the TRUE residual b - A x, even from a perturbed recursive residual
    (which the old bare pcg_iterate_ops resume would have propagated)."""
    ops = problem.solver_ops("jnp")
    b = problem.b
    st = esrp.esrp_init(ops.matvec, ops.precond, b)
    st, _ = esrp.run_chunk(st, ops, 20, 41, None)       # land on j = 41
    pert = st.pcg._replace(r=st.pcg.r * (1.0 + 1e-6))   # recursive != true
    out = esrp.numeric_step(pert, ops, b, rr_every=7, gated=True)  # j -> 42
    assert int(out.j) == 42 and 42 % 7 == 0
    true_r = np.asarray(b - ops.matvec(out.x))
    np.testing.assert_allclose(np.asarray(out.r), true_r, rtol=0, atol=1e-12)
    # off-schedule resume keeps the recursive residual (no spurious SpMV)
    out2 = esrp.numeric_step(pert, ops, b, rr_every=9, gated=True)
    assert float(jnp.linalg.norm(out2.r - (b - ops.matvec(out2.x)))) > 0


def test_rr_recovery_rejoins_failure_free_trajectory(problem):
    """Integration: failure at 58 rolls back to 41; the resume re-runs
    iteration 41 whose successor j=42 is a replacement iteration
    (rr_every=7). With the gate routed through the resume, the run rejoins
    the failure-free rr trajectory."""
    ref = solve_resilient(problem, strategy="none", rtol=1e-10, rr_every=7)
    r = solve_resilient(problem, strategy="esrp", T=20, phi=1, rtol=1e-10,
                        rr_every=7, fail_at=58, failed_nodes=[2])
    assert r.target_iter == 41
    assert r.rel_residual < 1e-10
    assert r.converged_iter == ref.converged_iter
