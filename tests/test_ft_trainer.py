"""ESRP-for-training: buddy-plan properties + trainer recovery identity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from tests._hypo import given, settings, st

from repro.configs import smoke_config
from repro.data.pipeline import TokenPipeline
from repro.ft import checkpoint
from repro.ft.buddy import BuddyPlan
from repro.ft.esrp_trainer import ESRPTrainer, FTConfig
from repro.models.lm import LM
from repro.train.optimizer import AdamWConfig, init_opt_state
from repro.train.train_step import make_train_step


# --------------------------------------------------------------------------- #
# buddy plan properties
# --------------------------------------------------------------------------- #
@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 1000), n_ranks=st.sampled_from([4, 8]),
       phi=st.integers(1, 3), start=st.integers(0, 7))
def test_buddy_push_lose_recover_roundtrip(seed, n_ranks, phi, start):
    rng = np.random.default_rng(seed)
    tree = {"a": jnp.asarray(rng.standard_normal((n_ranks * 4, 3))),
            "b": jnp.asarray(rng.standard_normal((2, n_ranks * 2))),
            "scalar": jnp.asarray(1.5)}
    plan = BuddyPlan.build(tree, None, n_ranks, phi)
    buddies = plan.push(tree)
    failed = [(start + i) % n_ranks for i in range(min(phi, n_ranks - 1))]
    # failure loses live shards AND the buffer slices hosted on failed ranks
    lost = plan.lose(tree, failed)
    buddies_lost = [plan.lose(b, failed) for b in buddies]
    rec = plan.recover(lost, buddies_lost, failed)
    for k in tree:
        np.testing.assert_array_equal(np.asarray(rec[k]),
                                      np.asarray(tree[k]))


def test_buddy_too_many_failures_raise():
    tree = {"a": jnp.zeros((8, 2))}
    plan = BuddyPlan.build(tree, None, 8, 1)
    with pytest.raises(RuntimeError):
        plan.recover(tree, plan.push(tree), [0, 1])


# --------------------------------------------------------------------------- #
# trainer end-to-end
# --------------------------------------------------------------------------- #
@pytest.fixture(scope="module")
def setup():
    cfg = smoke_config("internlm2_1_8b")
    model = LM(cfg)
    params, specs = model.init(jax.random.PRNGKey(0))
    opt = init_opt_state(params)
    ts = make_train_step(model, AdamWConfig(warmup_steps=4))
    pipe = TokenPipeline(cfg, global_batch=4, seq_len=32, seed=7)
    ref = ESRPTrainer(model, ts, pipe, FTConfig(mode="none"), specs)
    p_ref, o_ref, _ = ref.run(params, opt, n_steps=22)
    return model, ts, pipe, specs, params, opt, p_ref


def _max_diff(a, b):
    return max(float(jnp.abs(x - y).max())
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


@pytest.mark.parametrize("mode,fail_at,failed", [
    ("esrp", 13, [2]),
    ("esrp", 17, [5, 6]),
    ("imcr", 13, [0]),
])
def test_recovery_reproduces_trajectory(setup, mode, fail_at, failed):
    model, ts, pipe, specs, params, opt, p_ref = setup
    tr = ESRPTrainer(model, ts, pipe,
                     FTConfig(mode=mode, T=5, phi=len(failed), n_ranks=8),
                     specs)
    p_ft, _, _ = tr.run(params, opt, n_steps=22, fail_at=fail_at,
                        failed_ranks=failed)
    assert _max_diff(p_ref, p_ft) == 0.0     # bitwise trajectory identity


def test_fit_staggered_two_event_scenario(setup):
    """fit(scenario=[...]): two staggered events — the second striking after
    the first's rollback+replay — reproduce the undisturbed trajectory
    bit-for-bit, and a simultaneous multi-rank event rides the same path."""
    from repro.core.failures import FailureEvent

    model, ts, pipe, specs, params, opt, p_ref = setup
    tr = ESRPTrainer(model, ts, pipe,
                     FTConfig(mode="esrp", T=5, phi=2, n_ranks=8), specs)
    p_ft, _, losses = tr.fit(params, opt, n_steps=22,
                             scenario=[FailureEvent(13, (2,)),
                                       FailureEvent(17, (5, 6))])
    assert _max_diff(p_ref, p_ft) == 0.0     # bitwise trajectory identity
    assert set(losses) == set(range(22))


def test_fit_legacy_run_equivalence(setup):
    """run(fail_at=...) is the one-event shorthand of fit(scenario=...)."""
    from repro.core.failures import FailureEvent

    model, ts, pipe, specs, params, opt, p_ref = setup
    mk = lambda: ESRPTrainer(model, ts, pipe,
                             FTConfig(mode="esrp", T=5, phi=1, n_ranks=8),
                             specs)
    p_a, _, _ = mk().run(params, opt, n_steps=22, fail_at=13,
                         failed_ranks=[2])
    p_b, _, _ = mk().fit(params, opt, n_steps=22,
                         scenario=[FailureEvent(13, (2,))])
    assert _max_diff(p_a, p_b) == 0.0


def test_fit_failed_ranks_without_fail_at_raises(setup):
    """Regression (normalize_scenario): failed_ranks without fail_at used to
    silently train failure-free."""
    model, ts, pipe, specs, params, opt, _ = setup
    tr = ESRPTrainer(model, ts, pipe,
                     FTConfig(mode="esrp", T=5, phi=1, n_ranks=8), specs)
    with pytest.raises(ValueError, match="without fail_at"):
        tr.fit(params, opt, n_steps=22, failed_ranks=[1])


def test_esrp_pushes_less_than_imcr(setup):
    model, ts, pipe, specs, params, opt, _ = setup
    a = ESRPTrainer(model, ts, pipe,
                    FTConfig(mode="esrp", T=5, phi=1, n_ranks=8), specs)
    b = ESRPTrainer(model, ts, pipe,
                    FTConfig(mode="imcr", T=5, phi=1, n_ranks=8), specs)
    a.run(params, opt, n_steps=12)
    b.run(params, opt, n_steps=12)
    assert a.push_count == b.push_count > 0
    assert a.push_bytes < b.push_bytes        # params ride the FSDP gather


def test_compressed_redundancy_bounded_error(setup):
    model, ts, pipe, specs, params, opt, p_ref = setup
    tr = ESRPTrainer(model, ts, pipe,
                     FTConfig(mode="esrp", T=5, phi=1, n_ranks=8,
                              compress=True), specs)
    p_ft, _, _ = tr.run(params, opt, n_steps=22, fail_at=13,
                        failed_ranks=[3])
    d = _max_diff(p_ref, p_ft)
    assert 0 < d < 1e-2                       # bf16 moments: small, bounded


def test_failure_before_first_stage_raises(setup):
    model, ts, pipe, specs, params, opt, _ = setup
    tr = ESRPTrainer(model, ts, pipe,
                     FTConfig(mode="esrp", T=50, phi=1, n_ranks=8), specs)
    with pytest.raises(RuntimeError):
        tr.run(params, opt, n_steps=22, fail_at=10, failed_ranks=[1])


# --------------------------------------------------------------------------- #
# disk checkpointing
# --------------------------------------------------------------------------- #
def test_checkpoint_roundtrip(tmp_path, setup):
    model, ts, pipe, specs, params, opt, _ = setup
    checkpoint.save(str(tmp_path), 7, params=params, opt=opt)
    assert checkpoint.latest_step(str(tmp_path)) == 7
    out = checkpoint.restore(str(tmp_path), 7,
                             {"params": params, "opt": opt})
    assert _max_diff(out["params"], params) == 0.0
    assert int(out["opt"].step) == int(opt.step)


def test_checkpoint_detects_corrupted_payload(tmp_path, setup):
    """A checkpoint whose bytes changed after save must raise a descriptive
    CorruptCheckpointError on restore, not silently unflatten garbage."""
    model, ts, pipe, specs, params, opt, _ = setup
    checkpoint.save(str(tmp_path), 3, params=params, opt=opt)
    payload = tmp_path / "step_00000003" / "params.npz"
    blob = bytearray(payload.read_bytes())
    blob[len(blob) // 2] ^= 0xFF                    # one flipped byte
    payload.write_bytes(bytes(blob))
    with pytest.raises(checkpoint.CorruptCheckpointError,
                       match=r"params.*integrity"):
        checkpoint.restore(str(tmp_path), 3, {"params": params, "opt": opt})
    # the untouched tree still restores fine on its own
    out = checkpoint.restore(str(tmp_path), 3, {"opt": opt})
    assert int(out["opt"].step) == int(opt.step)


def test_checkpoint_legacy_manifest_without_checksums(tmp_path, setup):
    """Manifests written before the checksum field restore unverified
    (backward compatibility) instead of failing."""
    import json
    model, ts, pipe, specs, params, opt, _ = setup
    checkpoint.save(str(tmp_path), 5, params=params)
    man = tmp_path / "step_00000005" / "manifest.json"
    doc = json.loads(man.read_text())
    for entry in doc["trees"].values():
        entry.pop("sha256")
    man.write_text(json.dumps(doc))
    out = checkpoint.restore(str(tmp_path), 5, {"params": params})
    assert _max_diff(out["params"], params) == 0.0


def test_data_pipeline_deterministic_skippable():
    cfg = smoke_config("internlm2_1_8b")
    pipe = TokenPipeline(cfg, global_batch=2, seq_len=16, seed=3)
    b1 = pipe.batch_at(41)
    b2 = pipe.batch_at(41)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    b3 = pipe.batch_at(42)
    assert not np.array_equal(np.asarray(b1["tokens"]),
                              np.asarray(b3["tokens"]))


def test_elastic_restart_different_rank_count(tmp_path, setup):
    """Elastic scaling: checkpoint under 8 FSDP ranks, resume under 4 —
    the state is logically global, so resharding is free and the trajectory
    continues exactly (losses match a straight run)."""
    model, ts, pipe, specs, params, opt, p_ref = setup
    tr8 = ESRPTrainer(model, ts, pipe,
                      FTConfig(mode="esrp", T=5, phi=1, n_ranks=8), specs)
    p_mid, o_mid, _ = tr8.run(params, opt, n_steps=10)
    checkpoint.save(str(tmp_path), 10, params=p_mid, opt=o_mid)

    out = checkpoint.restore(str(tmp_path), 10,
                             {"params": p_mid, "opt": o_mid})
    tr4 = ESRPTrainer(model, ts, pipe,
                      FTConfig(mode="esrp", T=5, phi=1, n_ranks=4), specs)
    p_end, _, _ = tr4.run(out["params"], out["opt"], n_steps=22,
                          start_step=10, fail_at=17, failed_ranks=[1])
    assert _max_diff(p_ref, p_end) == 0.0
