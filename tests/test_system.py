"""End-to-end behaviour of the paper's system: ESR / ESRP / IMCR recovery.

The central claims under test:
  * trajectory identity — failure-free ESRP follows exactly the plain-PCG
    trajectory (same iteration count, same residuals);
  * exact state reconstruction — after <= phi simultaneous node failures the
    solver converges to the same solution in the same total iteration count
    (up to fp noise), for failures at every phase of the storage cycle;
  * queue-of-3 semantics — a failure right after the FIRST push of a storage
    stage rolls back to the PREVIOUS stage (Fig. 1);
  * IMCR rollback correctness.
"""
import numpy as np
import pytest

from repro.core.driver import solve_resilient
from repro.sparse.matrices import build_problem


@pytest.fixture(scope="module")
def problem():
    return build_problem("poisson2d", n_nodes=8, nx=40, ny=40)


@pytest.fixture(scope="module")
def reference(problem):
    return solve_resilient(problem, strategy="none", rtol=1e-10)


def test_reference_converges(reference):
    assert reference.rel_residual < 1e-10
    assert reference.converged_iter > 60      # enough room for T=20 stages


def test_esrp_failure_free_trajectory_identity(problem, reference):
    r = solve_resilient(problem, strategy="esrp", T=20, phi=1, rtol=1e-10)
    assert r.converged_iter == reference.converged_iter
    assert np.isclose(r.rel_residual, reference.rel_residual, rtol=1e-6)


@pytest.mark.parametrize("T,phi,failed", [
    (1, 1, [3]),             # ESR
    (20, 1, [0]),            # ESRP single failure (start)
    (20, 3, [4, 5, 6]),      # multiple-node failure (center)
    (20, 7, [0, 1, 2, 3, 4, 5, 6]),          # phi = N - 1 extreme
    (50, 2, [6, 7]),
])
def test_recovery_converges_same_iterations(problem, reference, T, phi,
                                            failed):
    J = reference.converged_iter // 2
    r = solve_resilient(problem, strategy="esrp", T=T, phi=phi, rtol=1e-10,
                        fail_at=J, failed_nodes=failed)
    assert r.rel_residual < 1e-10
    # same trajectory after rollback => total converged iteration unchanged
    assert r.converged_iter == reference.converged_iter
    if T == 1:
        assert r.wasted_iters == 0            # ESR: no rollback
    else:
        assert 0 <= r.wasted_iters <= T + 1
    assert r.inner_rel < 1e-13                # Alg.2 line-8 inner solve


def test_queue_of_three_mid_stage_failure(problem):
    """Failure right after the first push of stage (60, 61): the newest copy
    has no consecutive partner yet -> roll back to the previous stage's
    reconstruction point, iteration 41 (paper Fig. 1)."""
    r = solve_resilient(problem, strategy="esrp", T=20, phi=1, rtol=1e-10,
                        fail_at=60, failed_nodes=[2])
    assert r.target_iter == 41
    assert r.wasted_iters == 19
    assert r.rel_residual < 1e-10


def test_worst_case_two_before_stage(problem):
    r = solve_resilient(problem, strategy="esrp", T=20, phi=1, rtol=1e-10,
                        fail_at=59, failed_nodes=[7])
    assert r.target_iter == 41 and r.wasted_iters == 18


def test_early_failure_restarts(problem):
    r = solve_resilient(problem, strategy="esrp", T=20, phi=1, rtol=1e-10,
                        fail_at=5, failed_nodes=[1])
    assert r.target_iter == -1                # before first storage stage
    assert r.rel_residual < 1e-10


def test_imcr_recovery(problem, reference):
    J = reference.converged_iter // 2
    r = solve_resilient(problem, strategy="imcr", T=20, phi=2, rtol=1e-10,
                        fail_at=J, failed_nodes=[5, 6])
    assert r.rel_residual < 1e-10
    assert r.converged_iter == reference.converged_iter
    assert 0 <= r.wasted_iters < 40


def test_failures_beyond_phi_raise(problem):
    with pytest.raises(RuntimeError):
        solve_resilient(problem, strategy="esrp", T=20, phi=1, rtol=1e-10,
                        fail_at=45, failed_nodes=[0, 1])


def test_drift_comparable_to_reference(problem, reference):
    J = reference.converged_iter // 2
    r = solve_resilient(problem, strategy="esrp", T=20, phi=1, rtol=1e-10,
                        fail_at=J, failed_nodes=[3])
    # Eq. 2 drift should not blow up vs the reference run
    assert abs(r.drift) < 100 * max(abs(reference.drift), 1e-12) + 1e-6


def test_residual_replacement_reduces_drift(problem, reference):
    """Beyond-paper extension: periodic r := b - Ax replacement (the paper's
    §Accuracy cites [27] but does not implement it) tightens Eq. 2 drift and
    keeps ESRP recovery exact."""
    rr = solve_resilient(problem, strategy="none", rtol=1e-10, rr_every=25)
    assert rr.converged_iter == reference.converged_iter
    assert abs(rr.drift) <= abs(reference.drift)
    r = solve_resilient(problem, strategy="esrp", T=20, phi=1, rtol=1e-10,
                        rr_every=25, fail_at=reference.converged_iter // 2,
                        failed_nodes=[2])
    assert r.rel_residual < 1e-10
