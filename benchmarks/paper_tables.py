"""Paper-table benchmarks (Tables 2-4, Figs 2-3 of the paper).

Protocol = the paper's §5: reference runs, failure-free resilient runs, and
runs with one multi-node failure event injected 2 iterations before the end
of the checkpoint interval containing iteration C/2 (worst case), at
locations start (rank 0) / center (rank N/2); medians over repetitions;
relative overhead vs the reference median. SuiteSparse is not available
offline, so seeded surrogates of the same regime stand in (DESIGN.md §3):
  table2 -> poisson2d 192x192   (Emilia_923 regime: elliptic, moderate band)
  table3 -> poisson3d 32^3      (audikw_1 regime: 3-D, denser band)
"""
from __future__ import annotations

import dataclasses
import json
import time

import numpy as np

import jax

from repro.core.aspmv import build_plan
from repro.core.driver import SolveReport, solve_resilient
from repro.sparse.matrices import build_problem

N_NODES = 16
RTOL = 1e-8


@dataclasses.dataclass
class Row:
    strategy: str
    T: int
    phi: int
    scenario: str          # "ff" | "start" | "center"
    overhead: float        # (t - t0) / t0
    recon_overhead: float  # recovery_s / t0
    wasted: int
    drift: float
    runtime_s: float


def _fail_iter(C: int, T: int) -> int:
    if T <= 1:
        return C // 2
    k = (C // 2) // T
    return max(k * T + T - 2, 3)


def _median_run(problem, reps, **kw) -> SolveReport:
    solve_resilient(problem, **kw)          # warmup: jit compiles excluded
    reports = [solve_resilient(problem, **kw) for _ in range(reps)]
    reports.sort(key=lambda r: r.runtime_s)
    return reports[len(reports) // 2]


def run_table(kind: str, gen_kw: dict, *, Ts=(1, 20, 50, 100),
              phis=(1, 3, 8), reps=5, chunk=128):
    """Returns (reference median time, C, rows)."""
    problem = build_problem(kind, n_nodes=N_NODES, **gen_kw)

    # reference (non-resilient) runs
    solve_resilient(problem, strategy="none", rtol=RTOL, chunk=chunk)  # warm
    refs = [solve_resilient(problem, strategy="none", rtol=RTOL, chunk=chunk)
            for _ in range(reps)]
    t0 = float(np.median([r.runtime_s for r in refs]))
    C = refs[0].converged_iter
    ref_drift = refs[0].drift

    rows = [Row("reference", 0, 0, "ff", 0.0, 0.0, 0, ref_drift, t0)]
    for strategy in ("esrp", "imcr"):
        t_list = Ts if strategy == "esrp" else tuple(t for t in Ts if t > 1)
        for T in t_list:
            for phi in phis:
                # failure-free overhead
                r = _median_run(problem, reps, strategy=strategy, T=T,
                                phi=phi, rtol=RTOL, chunk=chunk)
                rows.append(Row(strategy, T, phi, "ff",
                                (r.runtime_s - t0) / t0,
                                0.0, 0, r.drift, r.runtime_s))
                # with failures: psi = phi simultaneous node failures
                J = _fail_iter(C, T)
                for scenario, first in (("start", 0), ("center", N_NODES // 2)):
                    failed = [(first + i) % N_NODES for i in range(phi)]
                    r = _median_run(problem, reps, strategy=strategy, T=T,
                                    phi=phi, rtol=RTOL, chunk=chunk,
                                    fail_at=J, failed_nodes=failed)
                    rows.append(Row(strategy, T, phi, scenario,
                                    (r.runtime_s - t0) / t0,
                                    r.recovery_s / t0, r.wasted_iters,
                                    r.drift, r.runtime_s))
    return t0, C, rows


def comm_volume_table(kind: str, gen_kw: dict, phis=(1, 3, 8)):
    """Analytic per-event communication volumes (paper §2.2.1/§3.1): ASpMV
    natural vs augmented bytes, and IMCR checkpoint bytes (4 vectors x phi
    buddies) — exact, size-independent of the CPU host."""
    problem = build_problem(kind, n_nodes=N_NODES, **gen_kw)
    itemsize = np.dtype(problem.b.dtype).itemsize
    out = []
    for phi in phis:
        plan = build_plan(problem.a, problem.part, phi)
        nat, aug = plan.bytes_per_aspmv(itemsize)
        imcr = 4 * problem.m * itemsize * phi        # x,r,z,p to phi buddies
        out.append({"phi": phi, "spmv_bytes": nat, "aspmv_bytes": aug,
                    "aspmv_extra": aug - nat, "imcr_ckpt_bytes": imcr,
                    "esrp_stage_bytes": 2 * (aug - nat)})
    return out


def format_rows(name: str, t0: float, C: int, rows: list[Row]) -> str:
    lines = [f"# {name}: t0={t0:.3f}s C={C} (medians, overhead vs t0)",
             "strategy,T,phi,scenario,overhead_pct,recon_overhead_pct,"
             "wasted_iters,drift,runtime_s"]
    for r in rows:
        lines.append(
            f"{r.strategy},{r.T},{r.phi},{r.scenario},"
            f"{100 * r.overhead:.2f},{100 * r.recon_overhead:.2f},"
            f"{r.wasted},{r.drift:.3e},{r.runtime_s:.3f}")
    return "\n".join(lines)
