"""Benchmark entry point — one function per paper table/figure.

``python -m benchmarks.run``            reduced grid (CI-sized, ~10 min)
``python -m benchmarks.run --full``     the paper's full T x phi x location
                                        grid, 5 repetitions (~1 h on 1 core)
``python -m benchmarks.run --only X``   X in {only_choices}
                                        (derived from ``ALL`` below — add a
                                        benchmark there and this list follows)

Output: CSV blocks ``name,us_per_call,derived`` per the harness convention,
plus the full tables to artifacts/bench/.
"""
from __future__ import annotations

import argparse
import os
import time

import numpy as np


def _ensure_dir():
    os.makedirs("artifacts/bench", exist_ok=True)


def bench_paper_table(table: str, full: bool):
    """Tables 2/3 + Figs 2/3: ESRP vs ESR(T=1) vs IMCR overheads."""
    import jax
    jax.config.update("jax_enable_x64", True)
    from benchmarks.paper_tables import format_rows, run_table

    if table == "table2":
        kind, kw = "poisson2d", dict(nx=192)
    else:
        kind, kw = "poisson3d", dict(nx=32)
    Ts = (1, 20, 50, 100) if full else (1, 20, 50)
    phis = (1, 3, 8) if full else (1, 3)
    reps = 5 if full else 3
    t_start = time.time()
    t0, C, rows = run_table(kind, kw, Ts=Ts, phis=phis, reps=reps)
    text = format_rows(f"{table} ({kind} surrogate)", t0, C, rows)
    _ensure_dir()
    with open(f"artifacts/bench/{table}.csv", "w") as f:
        f.write(text + "\n")
    print(text)
    # harness CSV: the paper's headline setting (T=20, phi=1)
    sel = [r for r in rows if r.T == 20 and r.phi == 1]
    for r in sel:
        print(f"{table}_{r.strategy}_T{r.T}_phi{r.phi}_{r.scenario},"
              f"{1e6 * r.runtime_s:.0f},overhead_pct={100 * r.overhead:.2f}")
    print(f"# {table} wall {time.time() - t_start:.0f}s")


def bench_table4(full: bool):
    """Residual drift (paper Eq. 2 / Table 4)."""
    import jax
    jax.config.update("jax_enable_x64", True)
    from repro.core.driver import solve_resilient
    from repro.sparse.matrices import build_problem

    out = []
    for name, kind, kw in (("poisson2d_192", "poisson2d", dict(nx=192)),
                           ("poisson3d_32", "poisson3d", dict(nx=32))):
        p = build_problem(kind, n_nodes=16, **kw)
        ref = solve_resilient(p, strategy="none", rtol=1e-8, chunk=128)
        drifts = []
        C = ref.converged_iter
        for loc in (0, 8):
            for phi in (1, 3):
                failed = [(loc + i) % 16 for i in range(phi)]
                r = solve_resilient(p, strategy="esrp", T=20, phi=phi,
                                    rtol=1e-8, chunk=128,
                                    fail_at=(C // 2 // 20) * 20 + 18,
                                    failed_nodes=failed)
                drifts.append(r.drift)
        row = (f"table4_{name},0,reference={ref.drift:.3e};"
               f"median={np.median(drifts):.3e};min={np.min(drifts):.3e}")
        out.append(row)
        print(row)
    _ensure_dir()
    with open("artifacts/bench/table4.csv", "w") as f:
        f.write("\n".join(out) + "\n")


def bench_volume():
    """Communication-volume model (paper §2.2.1/§3.1, exact)."""
    import jax
    jax.config.update("jax_enable_x64", True)
    from benchmarks.paper_tables import comm_volume_table

    for name, kind, kw in (("poisson2d_192", "poisson2d", dict(nx=192)),
                           ("poisson3d_32", "poisson3d", dict(nx=32))):
        for row in comm_volume_table(kind, kw):
            print(f"volume_{name}_phi{row['phi']},0,"
                  f"spmv={row['spmv_bytes']};aspmv={row['aspmv_bytes']};"
                  f"esrp_stage={row['esrp_stage_bytes']};"
                  f"imcr_ckpt={row['imcr_ckpt_bytes']}")


def bench_kernels():
    """Kernel validation sweeps + jnp-path timing (us/call)."""
    import jax
    jax.config.update("jax_enable_x64", True)
    import jax.numpy as jnp
    from repro.kernels.spmv.ops import blockell_matvec
    from repro.kernels.spmv.ref import spmv_ref
    from repro.kernels.fused_pcg.ops import pcg_update
    from repro.sparse.matrices import build_problem

    p = build_problem("poisson3d", n_nodes=16, nx=32)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal(p.m))
    y_ref = spmv_ref(p.a.data, p.a.idx, x)
    y_ker = blockell_matvec(p.a, x, backend="interpret")
    err = float(jnp.abs(y_ref - y_ker).max())
    assert err < 1e-10, err
    f = jax.jit(lambda v: spmv_ref(p.a.data, p.a.idx, v))
    f(x).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(50):
        y = f(x)
    y.block_until_ready()
    us = (time.perf_counter() - t0) / 50 * 1e6
    nnz = float(np.sum(np.asarray(p.a.nblk)) * p.a.bm * p.a.bn)
    print(f"kernel_spmv,{us:.0f},interpret_err={err:.1e};gflops="
          f"{2 * nnz / us / 1e3:.2f}")

    alpha = jnp.asarray(0.3)
    r, q, pv = x, x * 0.5, x * 0.25
    ref = pcg_update(alpha, x, r, pv, q, p.pinv_blocks, backend="jnp")
    ker = pcg_update(alpha, x, r, pv, q, p.pinv_blocks, backend="interpret",
                     rows=160)
    err = max(float(jnp.abs(a - b).max()) for a, b in zip(ref, ker))
    g = jax.jit(lambda a, x_, r_, p_, q_: pcg_update(
        a, x_, r_, p_, q_, p.pinv_blocks, backend="jnp"))
    g(alpha, x, r, pv, q)[0].block_until_ready()
    t0 = time.perf_counter()
    for _ in range(50):
        o = g(alpha, x, r, pv, q)
    o[0].block_until_ready()
    us = (time.perf_counter() - t0) / 50 * 1e6
    print(f"kernel_fused_pcg,{us:.0f},interpret_err={err:.1e}")


def bench_ft(trace=False):
    """ESRP-for-training overheads (us/step, push volume per stage).
    Timing routes through a span tracer (one ``measure:ft_*`` span per
    config); ``trace=True`` also threads it into the trainer (storage/
    recovery spans, per-step loss counter) and exports artifacts/obs/
    ft_trace.json."""
    import jax
    from repro.configs import smoke_config
    from repro.models.lm import LM
    from repro.obs import Tracer, write_chrome_trace
    from repro.train.optimizer import AdamWConfig, init_opt_state
    from repro.train.train_step import make_train_step
    from repro.data.pipeline import TokenPipeline
    from repro.ft.esrp_trainer import ESRPTrainer, FTConfig

    tracer = Tracer("bench_ft")
    cfg = smoke_config("internlm2_1_8b")
    model = LM(cfg)
    params, specs = model.init(jax.random.PRNGKey(0))
    opt = init_opt_state(params)
    ts = make_train_step(model, AdamWConfig(warmup_steps=4))
    pipe = TokenPipeline(cfg, global_batch=4, seq_len=64, seed=7)
    for mode, compress in (("none", False), ("esrp", False),
                           ("esrp", True), ("imcr", False)):
        label = mode + ("_bf16" if compress else "")
        tr = ESRPTrainer(model, ts, pipe,
                         FTConfig(mode=mode, T=10, phi=1, n_ranks=8,
                                  compress=compress), specs,
                         obs=tracer if trace else None)
        with tracer.span(f"warmup:ft_{label}", cat="warmup"):
            tr.run(params, opt, n_steps=3)    # warmup: amortize jit compile
        tr.push_bytes = tr.push_count = 0
        with tracer.span(f"measure:ft_{label}", cat="measure") as m_sp:
            tr.run(params, opt, n_steps=40)
        dt = m_sp.dur_s
        print(f"ft_{label},{1e6 * dt / 40:.0f},"
              f"push_MB_per_stage="
              f"{tr.push_bytes / max(tr.push_count, 1) / 1e6:.2f}")
    if trace:
        os.makedirs("artifacts/obs", exist_ok=True)
        path = write_chrome_trace(tracer, "artifacts/obs/ft_trace.json")
        print(f"# wrote {path} ({len(tracer.events)} events)")


def bench_iteration(full: bool):
    """Per-iteration hot-loop microbenchmark (us/iteration) for the three
    execution configurations this repo's perf trajectory tracks:

      jnp         seed path: unfused closure ops (einsum SpMV, separate pᵀq
                  and rᵀz dots) + jnp.where storage bookkeeping
      fused       SolverOps bundle (fused SpMV+dot, fused x/r/z/rz update),
                  still where-gated
      fused_cond  the full PR: fused bundle + lax.cond-gated queue push /
                  star capture / residual replacement

    Rows ``iteration_<config>`` use rr_every=0 (the paper's setting);
    ``iteration_<config>_rr10`` adds residual replacement every 10 iterations
    — the case where cond-gating removes a whole SpMV+precond from 9 of
    every 10 iterations.
    """
    import jax
    jax.config.update("jax_enable_x64", True)
    import jax.numpy as jnp
    from repro.core import esrp
    from repro.core.ops import make_closure_ops
    from repro.sparse.matrices import build_problem

    kind, kw = ("poisson3d", dict(nx=32)) if full else \
        ("poisson2d", dict(nx=96))
    p = build_problem(kind, n_nodes=16, **kw)
    T, n_iters, reps = 20, 100, 5
    thresh = jnp.asarray(-1.0, p.b.dtype)      # never freezes: pure hot loop

    configs = (
        ("jnp", make_closure_ops(p.a.matvec, p.apply_precond), False),
        ("fused", p.solver_ops("jnp"), False),
        ("fused_cond", p.solver_ops("jnp"), True),
    )
    out = []
    for rr in (0, 10):
        for name, ops, gated in configs:
            run = lambda s: esrp.run_chunk(s, ops, T, n_iters, thresh,
                                           rr, gated, p.b)
            st = esrp.esrp_init(ops.matvec, ops.precond, p.b)
            run(st)[1].block_until_ready()     # compile
            best = float("inf")
            for _ in range(reps):
                st_r = esrp.esrp_init(ops.matvec, ops.precond, p.b)
                t0 = time.perf_counter()
                _, norms = run(st_r)
                norms.block_until_ready()
                best = min(best, time.perf_counter() - t0)
            us = best / n_iters * 1e6
            label = f"iteration_{name}" + (f"_rr{rr}" if rr else "")
            out.append((label, us))
            print(f"{label},{us:.1f},m={p.m};T={T};gated={int(gated)}")
    base = dict(out)[f"iteration_jnp"]
    winner = dict(out)[f"iteration_fused_cond"]
    print(f"iteration_speedup,0,fused_cond_vs_jnp={base / winner:.3f}x")
    _ensure_dir()
    with open("artifacts/bench/iteration.csv", "w") as f:
        f.writelines(f"{k},{v:.1f}\n" for k, v in out)


def bench_roofline():
    """Roofline terms per dry-run cell (from artifacts/dryrun)."""
    from repro.roofline.report import summarize
    for line in summarize("artifacts/dryrun"):
        print(line)


def bench_precond(full):
    """Preconditioner x T x failure-location sweep — the experiment the
    paper's conclusion proposes ("more appropriate preconditioners") but
    never runs: iterations-to-converge, per-iteration cost, wall-clock, and
    recovery overhead for block-Jacobi vs SSOR vs Chebyshev vs IC(0),
    including the anisotropic poisson3d regime where block-Jacobi struggles
    and the denser banded family (audikw_1 regime). Emits a wall-clock
    winner per problem (meaningful now that the sweeps route through the
    wavefront kernels when the elimination DAG allows) and a
    machine-readable BENCH_precond.json next to the CSV."""
    import json

    import jax
    jax.config.update("jax_enable_x64", True)
    from repro.core.driver import solve_resilient
    from repro.sparse.matrices import build_problem

    problems = [("poisson2d", "poisson2d", dict(nx=64 if full else 48)),
                ("poisson3d_aniso", "poisson3d",
                 dict(nx=16 if full else 12, eps=0.25)),
                ("banded", "banded",
                 dict(n=2400 if full else 1600, bandwidth=16, density=0.4))]
    preconds = ("jacobi", "ssor", "chebyshev", "ic0")
    Ts = (10, 20, 50) if full else (10, 20)
    lines = ["problem,precond,T,scenario,iters,us_per_iter,runtime_ms,sweep,"
             "recovery_ms,wasted,rel_residual"]
    iters_aniso = {}
    wall: dict[str, dict[str, float]] = {}
    rows_json = []
    for pname, kind, kw in problems:
        wall[pname] = {}
        for name in preconds:
            p = build_problem(kind, n_nodes=8, precond=name, **kw)
            # the timed run resolves backend "auto" to jnp on this CPU host,
            # which executes the sequential sweep; "(wavefront-ready)" marks
            # structures whose kernel backends would take the level grid
            sweep_kind = "-"
            if name in ("ssor", "ic0"):
                sweep_kind = ("sequential(wavefront-ready)"
                              if p.precond.lo_wf is not None
                              else "sequential")
            solve_resilient(p, strategy="none", rtol=1e-8, chunk=32)  # warmup
            ref = solve_resilient(p, strategy="none", rtol=1e-8, chunk=32)
            C = ref.converged_iter
            us = 1e6 * ref.runtime_s / max(C, 1)
            wall[pname][name] = ref.runtime_s
            if pname == "poisson3d_aniso":
                iters_aniso[name] = C
            lines.append(f"{pname},{name},-,failure-free,{C},{us:.1f},"
                         f"{1e3 * ref.runtime_s:.1f},{sweep_kind},-,-,"
                         f"{ref.rel_residual:.2e}")
            rows_json.append(dict(problem=pname, precond=name, iters=C,
                                  us_per_iter=us,
                                  runtime_ms=1e3 * ref.runtime_s,
                                  sweep=sweep_kind,
                                  rel_residual=ref.rel_residual))
            print(f"precond_{pname}_{name},{us:.1f},iters={C};"
                  f"sweep={sweep_kind}")
            # warm the recovery path once (jitted reconstruction closures,
            # scatter kernels) so recovery_ms rows measure reconstruction,
            # not one-off compiles
            if 2 * Ts[0] < C:
                solve_resilient(p, strategy="esrp", T=Ts[0], phi=1,
                                rtol=1e-8, chunk=32, fail_at=2 * Ts[0],
                                failed_nodes=[1])
            for T in Ts:
                scens = {"early": 2 * T, "mid": (C // 2 // T) * T}
                if scens["mid"] <= scens["early"]:
                    del scens["mid"]       # would duplicate the early config
                for scen, fail_at in scens.items():
                    if fail_at >= C:
                        continue
                    r = solve_resilient(p, strategy="esrp", T=T, phi=1,
                                        rtol=1e-8, chunk=32,
                                        fail_at=fail_at, failed_nodes=[1])
                    # us_per_iter only for failure-free rows: failed runs
                    # pay one-off jit compiles for the post-failure chunk
                    # tails, which would misread as per-iteration cost
                    lines.append(
                        f"{pname},{name},{T},{scen}@{fail_at},"
                        f"{r.converged_iter},-,-,-,"
                        f"{1e3 * r.recovery_s:.2f},{r.wasted_iters},"
                        f"{r.rel_residual:.2e}")
    best = min((n for n in preconds if n != "jacobi"),
               key=lambda n: iters_aniso[n])
    print(f"precond_best_aniso,0,winner={best};iters={iters_aniso[best]};"
          f"jacobi_iters={iters_aniso['jacobi']}")
    winners = {}
    for pname in wall:
        w = min(wall[pname], key=wall[pname].get)
        winners[pname] = dict(winner=w, runtime_ms=1e3 * wall[pname][w])
        print(f"precond_wallclock_{pname},{1e6 * wall[pname][w]:.0f},"
              f"winner={w}")
    _ensure_dir()
    with open("artifacts/bench/precond.csv", "w") as f:
        f.write("\n".join(lines) + "\n")
    with open("artifacts/bench/BENCH_precond.json", "w") as f:
        json.dump(dict(problems={n: kw for n, _, kw in problems},
                       rows=rows_json, wallclock_winners=winners,
                       aniso_iter_winner=dict(
                           winner=best, iters=iters_aniso[best],
                           jacobi_iters=iters_aniso["jacobi"])),
                  f, indent=1, default=float)
    print("# wrote artifacts/bench/precond.csv + BENCH_precond.json")


def bench_recovery(full):
    """Alg. 2 reconstruction microbench per preconditioner: recovery
    wall-clock and line-6 inner-CG iteration count with the unpreconditioned
    (historical) vs preconditioned P_ff solve — the recovery cost Pachajoa
    et al. (arXiv:1907.13077) find dominated by the preconditioner-shaped
    inner solves. Warm runs (reconstruction closures jitted by a throwaway
    first run, same policy as the precond sweep); every row must rejoin the
    failure-free trajectory exactly. Writes artifacts/bench/recovery.csv +
    BENCH_recovery.json.
    """
    import json

    import jax
    jax.config.update("jax_enable_x64", True)
    from repro.core.driver import solve_resilient
    from repro.sparse.matrices import build_problem

    # ic0 runs on the anisotropic poisson3d grid: on poisson2d its block
    # pattern is tridiagonal, the factorization is exact (P = A⁻¹ to fp),
    # and the whole convergence tail is rounding-driven — no stable rejoin
    # point exists for a recovery experiment there
    configs = [("poisson2d", dict(nx=64 if full else 48),
                ("jacobi", "ssor", "chebyshev")),
               ("poisson3d", dict(nx=16 if full else 12, eps=0.25),
                ("ic0",))]
    lines = ["problem,precond,pff_precond,T,fail_at,iters,recovery_ms,"
             "pff_iters,inner_rel,exact_rejoin"]
    rows = []
    runs = [(kind, kw, name) for kind, kw, preconds in configs
            for name in preconds]
    for kind, kw, name in runs:
        p = build_problem(kind, n_nodes=8, precond=name, **kw)
        ref = solve_resilient(p, strategy="none", rtol=1e-8, chunk=32)
        C = ref.converged_iter
        # one completed storage stage before the failure, failure well
        # before convergence — adapt T to each preconditioner's C
        T = max(2, min(10, C // 3))
        fail_at = 2 * T
        for pp in (False, True):
            common = dict(strategy="esrp", T=T, phi=1, rtol=1e-8, chunk=32,
                          fail_at=fail_at, failed_nodes=[1], pff_precond=pp)
            solve_resilient(p, **common)             # warm the jit caches
            r = solve_resilient(p, **common)
            ev = r.events[0]
            row = dict(problem=kind, precond=name, pff_precond=pp, T=T,
                       fail_at=fail_at, iters=r.converged_iter,
                       recovery_ms=1e3 * r.recovery_s,
                       pff_iters=ev.pff_iters, inner_rel=r.inner_rel,
                       exact_rejoin=r.converged_iter == C)
            rows.append(row)
            lines.append(f"{kind},{name},{int(pp)},{T},{fail_at},"
                         f"{r.converged_iter},{row['recovery_ms']:.2f},"
                         f"{ev.pff_iters},{r.inner_rel:.2e},"
                         f"{int(row['exact_rejoin'])}")
            tag = "pff" if pp else "nopff"
            print(f"recovery_{name}_{tag},{1e3 * row['recovery_ms']:.0f},"
                  f"pff_iters={ev.pff_iters};"
                  f"exact={int(row['exact_rejoin'])}")
    for _, _, name in runs:
        sel = {r_["pff_precond"]: r_ for r_ in rows if r_["precond"] == name}
        if sel[False]["pff_iters"] > 0:
            speed = sel[False]["recovery_ms"] / max(sel[True]["recovery_ms"],
                                                    1e-9)
            it_cut = sel[False]["pff_iters"] / max(sel[True]["pff_iters"], 1)
            print(f"recovery_speedup_{name},0,"
                  f"wallclock={speed:.2f}x;pff_iter_cut={it_cut:.2f}x")
    assert all(r_["exact_rejoin"] for r_ in rows), "recovery lost exactness"
    _ensure_dir()
    with open("artifacts/bench/recovery.csv", "w") as f:
        f.write("\n".join(lines) + "\n")
    with open("artifacts/bench/BENCH_recovery.json", "w") as f:
        json.dump(dict(configs=[dict(kind=k, preconds=list(ps), **kw_)
                                for k, kw_, ps in configs],
                       n_nodes=8, rows=rows), f, indent=1, default=float)
    print(f"# wrote artifacts/bench/recovery.csv + BENCH_recovery.json "
          f"({len(rows)} rows)")


def bench_failures(full, sharded=False, tiers=False, trace=False):
    """Failure-scenario sweep: simultaneous vs staggered vs burst × φ × T
    for ESRP and IMCR — the multi-failure experiment of Pachajoa et al.
    (arXiv:1907.13077) on top of the paper's protocol.

      simultaneous  one event, φ nodes at once (worst case two iterations
                    before a storage stage completes)
      staggered     φ events of one node each, spaced a full period apart
                    (failure → recover → fail again)
      burst         two events one iteration apart: the second strikes the
                    re-run before the next storage stage completes, forcing
                    a rollback to the SAME reconstruction point again

    With ``--sharded`` (requires the 8-virtual-device XLA flag set by
    ``main``) the T=20 ESRP rows additionally run on the 8-device mesh with
    the device-resident failure runtime (redundancy copies physically on the
    neighbour devices, shard_map injection, recovery from surviving shards)
    and the ``sharded_iter``/``sharded_exact`` columns record the mesh run's
    convergence and whether it rejoined the single-device mesh-mirror
    trajectory bit-identically.

    Every row also carries ``tier_recovery_ms`` — the measured recovery
    time re-priced under each storage tier's read cost model (the fetch of
    the redundant p pair is the only tier-dependent step of a recovery).
    With ``tiers=True`` (``--tiers``) an additional tier × φ × T sweep runs
    REAL solves with ``storage_tier=...`` threaded through the driver, so
    the push/fetch accounting columns (push_count, push_bytes, model
    seconds) come from the solver itself, not a host-side re-pricing.

    Writes artifacts/bench/failures.csv (per-row sweep) and a
    machine-readable BENCH_failures.json next to it so the recovery-cost
    trajectory is trackable across PRs.

    All wall-clock rows are read back out of a span tracer (one
    ``measure:*`` span per timed solve), so the CSV columns and the
    exported trace can never disagree. With ``trace=True`` (``--trace``)
    the tracer is also threaded through every solve (per-iteration metrics
    ring, recovery spans) and exported to artifacts/obs/ as
    failures_trace.json (Chrome/Perfetto) + failures_events.jsonl +
    failures_metrics.txt. BENCH_failures.json always embeds the roofline
    FLOP/byte attribution of the dispatched kernels (CI fails without it).
    """
    import json

    import jax
    jax.config.update("jax_enable_x64", True)
    from repro.core.driver import solve_resilient
    from repro.core.failures import FailureEvent
    from repro.core.tiers import TIERS, resolve_tier
    from repro.obs import (Tracer, metrics_snapshot, solver_rooflines,
                           write_chrome_trace, write_jsonl)
    from repro.sparse.matrices import build_problem

    tracer = Tracer("bench_failures")
    obs = tracer if trace else None
    n_nodes = 8
    kind, kw = "poisson2d", dict(nx=96 if full else 48)
    p = build_problem(kind, n_nodes=n_nodes, **kw)
    mesh = placed = sh_ops = mirror = frt = None
    if sharded:
        from repro.comm.shard import (ShardedFailureRuntime, mesh_mirror_ops,
                                      nodes_mesh, place_problem,
                                      sharded_solver_ops)
        if len(jax.devices()) < n_nodes:
            raise RuntimeError(
                f"--sharded needs {n_nodes} devices; run via main() so the "
                f"xla_force_host_platform_device_count flag is set before "
                f"jax imports")
        mesh = nodes_mesh(n_nodes)
        placed = place_problem(p, mesh)
        with mesh:
            sh_ops = sharded_solver_ops(placed, mesh)
        mirror = mesh_mirror_ops(p, n_nodes)
        # ONE runtime for the whole sweep: the jitted chunk runners key
        # their compile cache on its (per-phi cached) push closure, so a
        # fresh runtime per row would recompile every row; bind_plan resets
        # the per-solve wiped-copy tracking anyway
        frt = ShardedFailureRuntime(placed, mesh)

    def run_sharded(T, phi, events):
        """One mesh run + its mesh-mirror reference; returns the sharded
        column trio (iter, bit-exact rejoin, recovery ms)."""
        with mesh:
            r = solve_resilient(placed, strategy="esrp", T=T, phi=phi,
                                rtol=1e-8, chunk=32, scenario=list(events),
                                ops=sh_ops, failure_runtime=frt)
        rm = solve_resilient(p, strategy="esrp", T=T, phi=phi, rtol=1e-8,
                             chunk=32, scenario=list(events), ops=mirror)
        exact = bool((np.asarray(r.x) == np.asarray(rm.x)).all()
                     and r.converged_iter == rm.converged_iter)
        return r.converged_iter, exact, 1e3 * r.recovery_s
    with tracer.span("warmup:reference", cat="warmup"):
        solve_resilient(p, strategy="none", rtol=1e-8, chunk=32, obs=obs)
    with tracer.span("measure:reference", cat="measure") as ref_sp:
        ref = solve_resilient(p, strategy="none", rtol=1e-8, chunk=32,
                              obs=obs)
    C, t0 = ref.converged_iter, ref_sp.dur_s
    Ts = (10, 20, 50) if full else (10, 20)
    phis = (1, 2, 4) if full else (1, 2)

    def scenarios(T, phi):
        J1 = (C // 2 // T) * T + T - 2          # two before a stage completes
        spread = [(1 + 2 * i) % n_nodes for i in range(phi)]  # buddy-safe
        out = {"simultaneous": [FailureEvent(J1, tuple(spread))],
               "burst": [FailureEvent(J1, (1,)), FailureEvent(J1 + 1, (3,))]}
        if phi > 1:
            out["staggered"] = [FailureEvent(J1 + k * T, (spread[k],))
                                for k in range(phi)]
        return {name: evs for name, evs in out.items()
                if all(ev.iter < C for ev in evs)}

    header = ("strategy,T,phi,scenario,n_events,converged_iter,wasted_iters,"
              "recovery_ms,runtime_s,overhead_pct,rel_residual,drift,targets,"
              "sharded_iter,sharded_exact")
    lines = [header]
    rows = []
    for strategy in ("esrp", "imcr"):
        for T in Ts:
            for phi in phis:
                for scen, events in scenarios(T, phi).items():
                    # first run pays the one-off jit compiles of the
                    # post-failure chunk tails + reconstruction closures;
                    # report the warm second run (same policy as precond's
                    # us_per_iter note — compile time is not recovery cost)
                    label = f"{strategy}:{scen}:T{T}:phi{phi}"
                    with tracer.span(f"warmup:{label}", cat="warmup"):
                        solve_resilient(p, strategy=strategy, T=T, phi=phi,
                                        rtol=1e-8, chunk=32, scenario=events,
                                        obs=obs)
                    with tracer.span(f"measure:{label}",
                                     cat="measure") as m_sp:
                        r = solve_resilient(p, strategy=strategy, T=T,
                                            phi=phi, rtol=1e-8, chunk=32,
                                            scenario=events, obs=obs)
                    runtime_s = m_sp.dur_s
                    row = dict(
                        strategy=strategy, T=T, phi=phi, scenario=scen,
                        n_events=len(events),
                        event_iters=[e.iter for e in events],
                        converged_iter=r.converged_iter,
                        wasted_iters=r.wasted_iters,
                        recovery_ms=1e3 * r.recovery_s,
                        runtime_s=runtime_s,
                        overhead_pct=100 * (runtime_s - t0) / t0,
                        rel_residual=r.rel_residual, drift=r.drift,
                        targets=[e.target_iter for e in r.events],
                        per_event_wasted=[e.wasted_iters for e in r.events],
                        # the full schema-versioned report (NaN-free JSON;
                        # per-event recovery breakdown included)
                        report=r.to_json(),
                        # measured recovery re-priced per storage tier: the
                        # redundant-pair fetch is the tier-dependent step
                        tier_recovery_ms={
                            name: 1e3 * (r.recovery_s + sum(
                                t.read_s(e.fetch_bytes) for e in r.events
                                if e.fetch_bytes))
                            for name, t in TIERS.items()},
                        sharded_iter=None, sharded_exact=None,
                        sharded_recovery_ms=None)
                    if sharded and strategy == "esrp" and T == 20:
                        (row["sharded_iter"], row["sharded_exact"],
                         row["sharded_recovery_ms"]) = run_sharded(
                            T, phi, events)
                    rows.append(row)
                    si, se = row["sharded_iter"], row["sharded_exact"]
                    sh_cols = (f",{'' if si is None else si}"
                               f",{'' if se is None else int(se)}")
                    lines.append(
                        f"{strategy},{T},{phi},{scen},{len(events)},"
                        f"{r.converged_iter},{r.wasted_iters},"
                        f"{1e3 * r.recovery_s:.2f},{runtime_s:.3f},"
                        f"{row['overhead_pct']:.1f},{r.rel_residual:.2e},"
                        f"{r.drift:.2e},"
                        f"{'|'.join(str(t) for t in row['targets'])}"
                        + sh_cols)
    # --tiers: tier × φ × T with REAL per-tier solves (storage_tier threaded
    # through the driver) on the representative simultaneous ESRP scenario;
    # the data path is tier-independent, so converged_iter must match the
    # tier-less row and only the accounting columns move
    tier_rows = []
    if tiers:
        for T in Ts:
            for phi in phis:
                events = scenarios(T, phi)["simultaneous"]
                for name in TIERS:
                    with tracer.span(f"measure:tier:{name}:T{T}:phi{phi}",
                                     cat="measure"):
                        r = solve_resilient(p, strategy="esrp", T=T, phi=phi,
                                            rtol=1e-8, chunk=32,
                                            scenario=events,
                                            storage_tier=name, obs=obs)
                    t = resolve_tier(name)
                    tier_rows.append(dict(
                        tier=name, T=T, phi=phi, scenario="simultaneous",
                        converged_iter=r.converged_iter,
                        wasted_iters=r.wasted_iters,
                        recovery_ms=1e3 * r.recovery_s,
                        recovery_ms_model=1e3 * (r.recovery_s
                                                 + r.fetch_s_model),
                        push_count=r.push_count, push_bytes=r.push_bytes,
                        push_s_model=r.push_s_model,
                        fetch_bytes=sum(e.fetch_bytes for e in r.events),
                        fetch_s_model=r.fetch_s_model,
                        write_s_per_mb=t.write_s(1 << 20)))
        base = {(r_["T"], r_["phi"]): r_["converged_iter"] for r_ in rows
                if r_["strategy"] == "esrp"
                and r_["scenario"] == "simultaneous"}
        assert all(tr["converged_iter"] == base[(tr["T"], tr["phi"])]
                   for tr in tier_rows), "tier changed the data path"
        tier_header = ("tier,T,phi,converged_iter,recovery_ms,"
                       "recovery_ms_model,push_count,push_bytes,"
                       "push_s_model,fetch_bytes,fetch_s_model")
        tier_lines = [tier_header] + [
            f"{tr['tier']},{tr['T']},{tr['phi']},{tr['converged_iter']},"
            f"{tr['recovery_ms']:.2f},{tr['recovery_ms_model']:.2f},"
            f"{tr['push_count']},{tr['push_bytes']},"
            f"{tr['push_s_model']:.3e},{tr['fetch_bytes']},"
            f"{tr['fetch_s_model']:.3e}" for tr in tier_rows]
        _ensure_dir()
        with open("artifacts/bench/failures_tiers.csv", "w") as f:
            f.write("\n".join(tier_lines) + "\n")
        for tr in tier_rows:
            if tr["T"] == max(Ts) and tr["phi"] == max(phis):
                print(f"failures_tier_{tr['tier']}_T{tr['T']}"
                      f"_phi{tr['phi']},"
                      f"{1e3 * tr['recovery_ms_model']:.0f},"
                      f"push_bytes={tr['push_bytes']};"
                      f"push_s_model={tr['push_s_model']:.3e};"
                      f"fetch_s_model={tr['fetch_s_model']:.3e}")
    # harness CSV: the headline multi-failure settings at T=20
    for row in rows:
        if row["T"] == 20 and (row["phi"] == max(phis) or
                               row["scenario"] == "burst"):
            print(f"failures_{row['strategy']}_{row['scenario']}"
                  f"_T{row['T']}_phi{row['phi']},"
                  f"{1e6 * row['runtime_s']:.0f},"
                  f"wasted={row['wasted_iters']};"
                  f"recovery_ms={row['recovery_ms']:.2f};"
                  f"overhead_pct={row['overhead_pct']:.1f}")
    exact = sum(r_["converged_iter"] == C for r_ in rows)
    print(f"failures_exact_rejoin,0,rejoined={exact}/{len(rows)};ref_C={C}")
    sh_rows = [r_ for r_ in rows if r_["sharded_iter"] is not None]
    if sh_rows:
        ok = sum(bool(r_["sharded_exact"]) for r_ in sh_rows)
        worst = max(r_["sharded_recovery_ms"] for r_ in sh_rows)
        print(f"failures_sharded_rejoin,0,bit_exact={ok}/{len(sh_rows)};"
              f"max_recovery_ms={worst:.2f}")
    _ensure_dir()
    with open("artifacts/bench/failures.csv", "w") as f:
        f.write("\n".join(lines) + "\n")
    summary = dict(
        problem=dict(kind=kind, n_nodes=n_nodes, m=p.m, **kw),
        reference=dict(converged_iter=C, runtime_s=t0,
                       rel_residual=ref.rel_residual, drift=ref.drift,
                       report=ref.to_json()),
        # FLOP/byte attribution of the dispatched kernels from their lowered
        # HLO (repro.obs.rooflines) — the CI validator requires >= 3 priced
        # kernels here
        rooflines=solver_rooflines(p.solver_ops("auto"), p.b),
        sweep=dict(Ts=list(Ts), phis=list(phis),
                   strategies=["esrp", "imcr"]),
        rows=rows,
        tiers=dict(names=list(TIERS),
                   # constants provenance: "placeholder" class numbers or a
                   # scripts/calibrate_tiers.py measurement record (loaded
                   # via REPRO_TIER_CALIBRATION)
                   provenance={t.name: t.provenance
                               for t in TIERS.values()},
                   swept=bool(tier_rows), rows=tier_rows),
        aggregate=dict(
            n_rows=len(rows),
            exact_rejoin=exact,
            max_wasted_iters=max(r_["wasted_iters"] for r_ in rows),
            max_recovery_ms=max(r_["recovery_ms"] for r_ in rows),
            median_overhead_pct=float(np.median(
                [r_["overhead_pct"] for r_ in rows])),
            sharded_rows=len(sh_rows),
            sharded_bit_exact=sum(bool(r_["sharded_exact"])
                                  for r_ in sh_rows)))
    with open("artifacts/bench/BENCH_failures.json", "w") as f:
        json.dump(summary, f, indent=1, default=float)
    print(f"# wrote artifacts/bench/failures.csv + BENCH_failures.json "
          f"({len(rows)} rows)")
    if trace:
        os.makedirs("artifacts/obs", exist_ok=True)
        trace_path = write_chrome_trace(tracer,
                                        "artifacts/obs/failures_trace.json")
        jsonl_path = "artifacts/obs/failures_events.jsonl"
        if os.path.exists(jsonl_path):    # write_jsonl appends by design
            os.remove(jsonl_path)
        write_jsonl(tracer, jsonl_path)
        with open("artifacts/obs/failures_metrics.txt", "w") as f:
            f.write(metrics_snapshot(tracer))
        print(f"# wrote {trace_path} ({len(tracer.events)} events) "
              f"+ failures_events.jsonl + failures_metrics.txt")


def bench_serve(full, trace=False):
    """Streaming solver service: aggregate throughput + p50/p99 request
    latency vs micro-batch width B, with failures AND silent corruption
    injected under load, plus the deadline-aware front-end columns.

    The request stream is identical for every width (same seed, same RHS
    set) and ``fail_every=2`` lands the scenario — a FailureEvent *and* an
    SDCEvent — in every second micro-batch, so exactly half the requests
    ride through a failure + Alg. 2 recovery and an SDC detect→repair at
    *every* B (the per-request exposure is width-invariant and the
    comparison is fair). Each width gets one warmup pass covering both the
    failing and clean micro-batch compiles before the timed drain.

    A final pass at the widest B drives the deadline-aware policy
    (``max_queue_wait_s=0`` partial dispatches, per-request deadlines with
    a controlled set of pre-expired requests) — its row carries the
    queue-wait p99, deadline-miss rate, and partial-dispatch count, and the
    miss accounting must show ZERO requests mischaracterized as failures.

    Writes artifacts/bench/serve.csv + BENCH_serve.json; the JSON embeds
    the B>=8-vs-B=1 aggregate-throughput speedup (acceptance: > 2x) and the
    solver-kernel rooflines (the CI ``--min-kernels`` gate). With
    ``trace``, the widest sweep runs under an obs.Tracer and exports
    artifacts/obs/serve_trace.json + serve_metrics.txt."""
    import json

    import jax
    jax.config.update("jax_enable_x64", True)
    from repro.core.failures import FailureEvent, SDCEvent
    from repro.serve.solver_service import SolverService
    from repro.sparse.matrices import build_problem

    _ensure_dir()
    nx = 40 if full else 28
    n_req = 32 if full else 16
    widths = [1, 2, 4, 8, 16] if full else [1, 2, 4, 8]
    problem = build_problem("poisson2d", n_nodes=8, nx=nx)
    scenario = [FailureEvent(25, (1,)),
                SDCEvent(iter=38, nodes=(2,), target="r")]
    rng = np.random.default_rng(11)
    reqs = rng.standard_normal((n_req, problem.part.m))
    kw = dict(strategy="esrp", T=20, phi=1, rtol=1e-8)

    tracer = None
    if trace:
        from repro.obs import Tracer
        tracer = Tracer("bench_serve")
        tracer.meta.update(bench="serve", nx=nx, requests=n_req,
                           widths=widths)

    rows = []
    for B in widths:
        traced = trace and B == widths[-1]
        # warmup must compile the SAME chunk-runner variant the timed pass
        # dispatches: a tracer arms the metrics ring (a static argument of
        # the jitted runners), so the traced width warms under a throwaway
        # tracer or the timed drain would pay the recompile
        # B=1 runs the exact per-member bundle (the honest sequential
        # baseline — fused einsums only pay off once they amortize over
        # members); B>1 runs the fused throughput mode the service defaults
        # to. Warmup must compile the same variants.
        fused = B > 1
        warm = SolverService(problem, batch=B, scenario=scenario,
                             fail_every=1, obs=traced, fused=fused, **kw)
        for k in range(2 * B):            # one failing + one clean compile
            warm.submit(reqs[k % n_req])
        warm.run()
        svc = SolverService(problem, batch=B, scenario=scenario,
                            fail_every=2, obs=tracer if traced else None,
                            fused=fused, **kw)
        for k in range(n_req):
            svc.submit(reqs[k])
        svc.run()
        st = svc.stats()
        st["batch"] = B
        st["mode"] = "greedy"
        rows.append(st)
        us_per_req = st["solve_wall_s"] / st["requests"] * 1e6
        print(f"serve_B{B},{us_per_req:.0f},"
              f"rps={st['throughput_rps']:.2f};"
              f"p50_ms={st['latency_p50_ms']:.0f};"
              f"p99_ms={st['latency_p99_ms']:.0f};"
              f"converged={st['all_converged']}")

    # deadline-aware pass at the widest B: partial dispatches on queue-wait
    # timeout, generous live deadlines, and a controlled pair of pre-expired
    # requests — the miss accounting must never read as failures
    B = widths[-1]
    n_expired = 2
    svc = SolverService(problem, batch=B, scenario=scenario, fail_every=2,
                        fused=B > 1, max_queue_wait_s=0.0, **kw)
    for k in range(n_req):
        svc.submit(reqs[k], deadline_s=-1.0 if k < n_expired else 600.0)
        if (k + 1) % max(1, B // 2) == 0:   # below-width arrival bursts
            while svc.ready():
                svc.step()
    svc.run()
    st = svc.stats()
    st["batch"] = B
    st["mode"] = "deadline"
    rows.append(st)
    assert st["failed"] == 0, \
        f"deadline misses mischaracterized as failures: {st['failed']}"
    assert st["deadline_missed"] == n_expired, st["deadline_missed"]
    print(f"serve_deadline_B{B},partials={st['partial_dispatches']};"
          f"miss_rate={st['deadline_miss_rate']:.3f};"
          f"wait_p99_ms={st['queue_wait_p99_ms']:.1f};"
          f"failed={st['failed']}")

    thr = {r["batch"]: r["throughput_rps"] for r in rows
           if r["mode"] == "greedy"}
    wide = [b for b in thr if b >= 8]
    speedup = max(thr[b] for b in wide) / thr[1] if wide else float("nan")
    cols = ["mode", "batch", "requests", "microbatches", "mean_fill",
            "solve_wall_s", "throughput_rps", "latency_p50_ms",
            "latency_p99_ms", "latency_mean_ms", "queue_wait_p50_ms",
            "queue_wait_p99_ms", "deadline_miss_rate", "partial_dispatches",
            "retries_total", "failed", "iters_total", "all_converged"]
    with open("artifacts/bench/serve.csv", "w") as f:
        f.write(",".join(cols) + "\n")
        for r in rows:
            f.write(",".join(str(r[c]) for c in cols) + "\n")
    from repro.obs import solver_rooflines
    with open("artifacts/bench/BENCH_serve.json", "w") as f:
        json.dump(dict(
            bench="serve", problem="poisson2d", nx=nx, n_nodes=8,
            requests=n_req, fail_every=2, scenario_iter=25, sdc_iter=38,
            rows=rows,
            deadline=dict(batch=B, expired_submitted=n_expired,
                          deadline_missed=st["deadline_missed"],
                          deadline_miss_rate=st["deadline_miss_rate"],
                          partial_dispatches=st["partial_dispatches"],
                          queue_wait_p99_ms=st["queue_wait_p99_ms"],
                          failed=st["failed"]),
            # solver-kernel FLOP/byte attribution (repro.obs.rooflines) —
            # the CI validator prices these with --min-kernels
            rooflines=solver_rooflines(problem.solver_ops("auto"),
                                       problem.b),
            speedup_b8_vs_b1=speedup,
            criteria=dict(metric="aggregate throughput at B>=8 vs B=1 "
                                 "sequential", threshold=2.0,
                          value=speedup, passed=bool(speedup > 2.0)),
        ), f, indent=1)
    print(f"# wrote artifacts/bench/serve.csv + BENCH_serve.json "
          f"(B>=8 vs B=1 speedup {speedup:.2f}x)")
    if tracer is not None:
        from repro.obs import metrics_snapshot, write_chrome_trace, \
            write_jsonl
        os.makedirs("artifacts/obs", exist_ok=True)
        path = write_chrome_trace(tracer, "artifacts/obs/serve_trace.json")
        jsonl_path = "artifacts/obs/serve_events.jsonl"
        if os.path.exists(jsonl_path):    # write_jsonl appends by design
            os.remove(jsonl_path)
        write_jsonl(tracer, jsonl_path)
        with open("artifacts/obs/serve_metrics.txt", "w") as f:
            f.write(metrics_snapshot(tracer))
        print(f"# wrote {path} + serve_events.jsonl + serve_metrics.txt")


ALL = {
    "table2": lambda full: bench_paper_table("table2", full),
    "table3": lambda full: bench_paper_table("table3", full),
    "table4": lambda full: bench_table4(full),
    "volume": lambda full: bench_volume(),
    "kernels": lambda full: bench_kernels(),
    "iteration": bench_iteration,
    "precond": bench_precond,
    "recovery": bench_recovery,
    "failures": bench_failures,
    "ft": lambda full: bench_ft(),          # --trace routed in main()
    "roofline": lambda full: bench_roofline(),
    "serve": bench_serve,                   # --trace routed in main()
}

# the --only list in the module docstring is derived from ALL so it cannot
# drift when benchmarks are added (it omitted "iteration" once already)
__doc__ = __doc__.replace("{only_choices}", "|".join(ALL))


def main() -> None:
    ap = argparse.ArgumentParser(
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None, choices=list(ALL))
    ap.add_argument("--sharded", action="store_true",
                    help="failures sweep only: also run the T=20 ESRP rows "
                         "on an 8-device mesh with the device-resident "
                         "failure runtime (adds the sharded_iter/"
                         "sharded_exact columns)")
    ap.add_argument("--tiers", action="store_true",
                    help="failures sweep only: also run the storage-tier × "
                         "φ × T sweep with real per-tier solves "
                         "(storage_tier threaded through the driver); "
                         "writes failures_tiers.csv and the tiers section "
                         "of BENCH_failures.json")
    ap.add_argument("--trace", action="store_true",
                    help="failures/ft/serve sweeps: thread an obs.Tracer "
                         "through the solves and export Chrome-trace + "
                         "JSONL + metrics snapshot under artifacts/obs/")
    args = ap.parse_args()
    if args.sharded:
        # must precede the first jax import (bench functions import lazily)
        flags = os.environ.get("XLA_FLAGS", "")
        if "host_platform_device_count" not in flags:
            os.environ["XLA_FLAGS"] = (
                flags + " --xla_force_host_platform_device_count=8").strip()
    names = [args.only] if args.only else list(ALL)
    for name in names:
        print(f"\n== {name} ==")
        if name == "failures":
            ALL[name](args.full, sharded=args.sharded, tiers=args.tiers,
                      trace=args.trace)
        elif name == "ft":
            bench_ft(trace=args.trace)
        elif name == "serve":
            bench_serve(args.full, trace=args.trace)
        else:
            ALL[name](args.full)


if __name__ == "__main__":
    main()
