"""Deterministic, O(1)-skippable synthetic token pipeline.

``batch_at(step)`` derives the batch purely from (seed, step) via
``jax.random.fold_in`` — no iterator state. This is what makes ESRP-style
rollback work for training: after a failure the trainer rolls back <= T
steps and *replays* the same batches, reproducing the undisturbed trajectory
exactly (the paper's trajectory-identity property, §1.1). A real deployment
substitutes any deterministic-seek data loader (e.g. an index-shuffled token
store); the contract is just ``step -> batch``.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class TokenPipeline:
    cfg: ModelConfig
    global_batch: int
    seq_len: int
    seed: int = 0

    def batch_at(self, step: int) -> dict:
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), step)
        b, s = self.global_batch, self.seq_len
        cfg = self.cfg
        # Zipfian unigrams: a learnable marginal, so demo losses actually
        # descend from ln(V) toward the Zipf entropy (still fully
        # deterministic in (seed, step) — the ESRP replay contract)
        logits = -jnp.log1p(jnp.arange(cfg.vocab, dtype=jnp.float32))
        toks = jax.random.categorical(
            key, logits[None, None, :], shape=(b, s + 1)).astype(jnp.int32)
        batch = {}
        if cfg.frontend == "vlm":
            nf = cfg.n_frontend_tokens
            kf = jax.random.fold_in(key, 1)
            batch["patch_embeds"] = jax.random.normal(
                kf, (b, nf, cfg.d_model), jnp.float32)
            batch["tokens"] = toks[:, :s - nf]
            batch["labels"] = toks[:, 1:s - nf + 1]
        elif cfg.frontend == "audio":
            kf = jax.random.fold_in(key, 1)
            batch["frame_embeds"] = jax.random.normal(
                kf, (b, s, cfg.d_model), jnp.float32)
            batch["labels"] = toks[:, 1:]
        else:
            batch["tokens"] = toks[:, :s]
            batch["labels"] = toks[:, 1:]
        return batch
