"""Roofline report: three terms per dry-run cell + bottleneck + notes.

Hardware model (TPU v5e, per chip): 197 TFLOP/s bf16, 819 GB/s HBM,
50 GB/s/link ICI. Terms are seconds-per-step lower bounds:
    compute    = HLO_FLOPs_per_chip / peak
    memory     = HBM_bytes_per_chip / bw          (perfect-fusion floor)
    collective = link_bytes_per_chip / link_bw    (ring model, 1 link)
The bottleneck is the max term; roofline fraction = compute / max term
(how close the cell is to being compute-limited — 1.0 means the arithmetic
is the wall). MODEL_FLOPS/HLO_FLOPs flags remat/attention/dispatch overhead.
"""
from __future__ import annotations

import glob
import json
import os

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9


def terms(rec: dict) -> dict:
    pd = rec["per_device"]
    c = pd["hlo_flops"] / PEAK_FLOPS
    m = pd["hbm_bytes"] / HBM_BW
    n = pd["collective_bytes"] / LINK_BW
    dom = max((("compute", c), ("memory", m), ("collective", n)),
              key=lambda t: t[1])
    return {
        "compute_s": c, "memory_s": m, "collective_s": n,
        "dominant": dom[0],
        "roofline_fraction": c / max(c, m, n) if max(c, m, n) > 0 else 0.0,
        "useful_flops_ratio": (rec["model_flops_per_device"]
                               / max(pd["hlo_flops"], 1.0)),
    }


def load(dirpath: str) -> list[dict]:
    out = []
    for f in sorted(glob.glob(os.path.join(dirpath, "*.json"))):
        rec = json.load(open(f))
        if rec.get("status") == "ok":
            rec["terms"] = terms(rec)
        out.append(rec)
    return out


def summarize(dirpath: str) -> list[str]:
    lines = ["cell,us_per_call,derived"]
    for rec in load(dirpath):
        tag = f"{rec['arch']}__{rec['shape']}__{rec['mesh']}"
        if rec.get("status") != "ok":
            lines.append(f"roofline_{tag},0,status=FAILED")
            continue
        t = rec["terms"]
        bound = max(t["compute_s"], t["memory_s"], t["collective_s"])
        lines.append(
            f"roofline_{tag},{1e6 * bound:.0f},"
            f"compute={t['compute_s']:.4f};memory={t['memory_s']:.4f};"
            f"collective={t['collective_s']:.4f};dom={t['dominant']};"
            f"frac={t['roofline_fraction']:.3f};"
            f"useful={t['useful_flops_ratio']:.3f}")
    return lines


def markdown_table(dirpath: str, mesh: str = "16x16") -> str:
    """EXPERIMENTS.md §Roofline table (single-pod cells)."""
    rows = ["| arch | shape | compute s | memory s | collective s | "
            "bottleneck | roofline frac | useful FLOPs |",
            "|---|---|---|---|---|---|---|---|"]
    for rec in load(dirpath):
        if rec.get("status") != "ok" or rec.get("mesh") != mesh:
            continue
        t = rec["terms"]
        rows.append(
            f"| {rec['arch']} | {rec['shape']} | {t['compute_s']:.3f} | "
            f"{t['memory_s']:.3f} | {t['collective_s']:.3f} | "
            f"{t['dominant']} | {t['roofline_fraction']:.3f} | "
            f"{t['useful_flops_ratio']:.2f} |")
    return "\n".join(rows)
