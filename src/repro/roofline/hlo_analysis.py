"""While-aware HLO cost analyzer over the post-SPMD-partitioning HLO dump.

Why this source: (a) ``compiled.cost_analysis()`` visits while bodies ONCE, so
scanned-layer models are undercounted ~n_layers x (measured); (b) the CPU
backend legalizes bf16 compute to f32 during optimization, which would
inflate every byte count 2x vs the TPU target. The
``after_spmd-partitioning`` dump is per-device, still bf16, still while-
structured, and pre-fusion — exactly the program a TPU backend would start
from.

Cost model ("perfect fusion"):
  flops            — dot: 2 x |result| x contraction size; convolution approx.
  hbm_bytes        — ops that must touch HBM in a well-fused TPU program:
                     dot/conv (operands + result), collectives (result),
                     gather/dynamic-slice (2x slice), scatter/dynamic-update-
                     slice (2x update), reduce (operands + result). Pure
                     elementwise/layout ops are assumed fused (skipped), so
                     this is an HBM-traffic floor; §Roofline notes say so.
  collective_bytes — per-chip ring-model link traffic: all-reduce 2x|res|,
                     all-gather |res|, reduce-scatter |operand|,
                     collective-permute / all-to-all max(|res|, |operand|).
  while bodies are multiplied by trip counts parsed from the loop-condition
  compare constants.

All numbers are per chip (the module is the partitioned per-device program).
"""
from __future__ import annotations

import dataclasses
import re

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "token": 0, "opaque": 0, "s4": 1, "u4": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e5m2fnuz": 1, "f8e4m3fnuz": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\((.*)$")
_CALL_ATTR_RE = re.compile(
    r"(?:calls|to_apply|condition|body|branch_computations)="
    r"(?:{([^}]*)}|%?([\w.\-]+))")

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")
_CALLER_KINDS = ("call", "conditional", "while", "fusion", "map", "sort",
                 "reduce", "scatter", "reduce-window", "select-and-scatter",
                 "all-reduce", "reduce-scatter")


def _shapes_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(type_str: str):
    m = _SHAPE_RE.search(type_str)
    if not m:
        return None, []
    dims = [int(d) for d in m.group(2).split(",")] if m.group(2) else []
    return m.group(1), dims


@dataclasses.dataclass
class Op:
    name: str
    kind: str
    result_type: str
    rest: str
    operand_types: list


@dataclasses.dataclass
class Computation:
    name: str
    ops: list


def parse_hlo(text: str) -> dict:
    comps: dict[str, Computation] = {}
    current = None
    for line in text.splitlines():
        stripped = line.strip()
        header = re.match(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*{",
                          stripped)
        if header:
            current = Computation(header.group(1), [])
            comps[current.name] = current
            continue
        if stripped.startswith("}"):
            continue
        m = _OP_RE.match(line)
        if m and current is not None:
            name, rtype, kind, rest = m.groups()
            current.ops.append(Op(name, kind, rtype, rest, []))
    for comp in comps.values():
        types = {op.name: op.result_type for op in comp.ops}
        for op in comp.ops:
            arg_sec = op.rest.split("),")[0]
            for t in re.finditer(r"%([\w.\-]+)", arg_sec):
                if t.group(1) in types:
                    op.operand_types.append(types[t.group(1)])
    return comps


def _dot_flops(op: Op) -> float:
    _, rdims = _shape_dims(op.result_type)
    out = 1.0
    for d in rdims:
        out *= d
    cm = re.search(r"lhs_contracting_dims={([\d,]*)}", op.rest)
    contraction = 1.0
    if cm and op.operand_types:
        _, ldims = _shape_dims(op.operand_types[0])
        for idx in cm.group(1).split(","):
            if idx and int(idx) < len(ldims):
                contraction *= ldims[int(idx)]
    return 2.0 * out * contraction


def _collective_bytes(op: Op) -> float:
    res = _shapes_bytes(op.result_type)
    opnd = sum(_shapes_bytes(t) for t in op.operand_types)
    if op.kind.startswith("all-reduce"):
        return 2.0 * res
    if op.kind.startswith("all-gather"):
        return res
    if op.kind.startswith("reduce-scatter"):
        return opnd if opnd else res
    return max(res, opnd)


def _trip_count(comps: dict, cond_name: str) -> int:
    best = 1
    seen = set()
    stack = [cond_name]
    while stack:
        cname = stack.pop()
        if cname in seen or cname not in comps:
            continue
        seen.add(cname)
        for op in comps[cname].ops:
            if op.kind == "constant":
                cm = re.match(r"(\d+)\)?", op.rest)
                if cm:
                    best = max(best, int(cm.group(1)))
            cm2 = re.search(r"constant\((\d+)\)", op.rest)
            if cm2:
                best = max(best, int(cm2.group(1)))
            for g in _CALL_ATTR_RE.finditer(op.rest):
                names = g.group(1) or g.group(2)
                for n in re.findall(r"%?([\w.\-]+)", names):
                    stack.append(n)
    return best


@dataclasses.dataclass
class HloCosts:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_bytes: float = 0.0
    collectives: dict = dataclasses.field(default_factory=dict)
    while_trips: dict = dataclasses.field(default_factory=dict)

    def add(self, other: "HloCosts", mult: float = 1.0):
        self.flops += other.flops * mult
        self.hbm_bytes += other.hbm_bytes * mult
        self.collective_bytes += other.collective_bytes * mult
        for k, v in other.collectives.items():
            self.collectives[k] = self.collectives.get(k, 0.0) + v * mult


def analyze(text: str) -> HloCosts:
    comps = parse_hlo(text)
    memo: dict[str, HloCosts] = {}
    trips_seen: dict[str, int] = {}

    called = set()
    for comp in comps.values():
        for op in comp.ops:
            for g in _CALL_ATTR_RE.finditer(op.rest):
                names = g.group(1) or g.group(2)
                for n in re.findall(r"%?([\w.\-]+)", names):
                    called.add(n)
    entry_m = re.search(r"ENTRY\s+%?([\w.\-]+)", text)
    entry = entry_m.group(1) if entry_m else None
    if entry not in comps:
        candidates = [c for c in comps if c not in called]
        entry = candidates[-1] if candidates else next(iter(comps))

    def cost_of(cname: str) -> HloCosts:
        if cname in memo:
            return memo[cname]
        total = HloCosts()
        memo[cname] = total
        comp = comps.get(cname)
        if comp is None:
            return total
        for op in comp.ops:
            kind = op.kind
            if kind == "dot":
                total.flops += _dot_flops(op)
                total.hbm_bytes += (_shapes_bytes(op.result_type)
                                    + sum(_shapes_bytes(t)
                                          for t in op.operand_types))
            elif kind == "convolution":
                total.flops += 2.0 * _shapes_bytes(op.result_type)
                total.hbm_bytes += (_shapes_bytes(op.result_type)
                                    + sum(_shapes_bytes(t)
                                          for t in op.operand_types))
            elif any(kind.startswith(c) for c in COLLECTIVES):
                base = kind.split("-start")[0].split("-done")[0]
                if kind.endswith("-done"):
                    continue                       # counted at -start
                cb = _collective_bytes(op)
                total.collective_bytes += cb
                total.collectives[base] = total.collectives.get(base, 0.) + cb
                total.hbm_bytes += _shapes_bytes(op.result_type)
            elif kind == "while":
                bm = re.search(r"body=%?([\w.\-]+)", op.rest)
                cm = re.search(r"condition=%?([\w.\-]+)", op.rest)
                body = bm.group(1) if bm else None
                cond = cm.group(1) if cm else None
                trips = _trip_count(comps, cond) if cond else 1
                if body:
                    trips_seen[body] = trips
                    total.add(cost_of(body), trips)
            elif kind in ("gather", "dynamic-slice"):
                total.hbm_bytes += 2.0 * _shapes_bytes(op.result_type)
            elif kind in ("scatter", "dynamic-update-slice"):
                upd = (op.operand_types[1] if len(op.operand_types) > 1
                       else op.result_type)
                total.hbm_bytes += 2.0 * _shapes_bytes(upd)
                if kind == "scatter":
                    for g in _CALL_ATTR_RE.finditer(op.rest):
                        names = g.group(1) or g.group(2)
                        for n in re.findall(r"%?([\w.\-]+)", names):
                            if n in comps:
                                total.add(cost_of(n))
            elif kind == "reduce" or kind == "reduce-window":
                total.hbm_bytes += (_shapes_bytes(op.result_type)
                                    + sum(_shapes_bytes(t)
                                          for t in op.operand_types))
            elif kind in ("call", "conditional", "fusion", "map", "sort",
                          "select-and-scatter", "custom-call"):
                for g in _CALL_ATTR_RE.finditer(op.rest):
                    names = g.group(1) or g.group(2)
                    for n in re.findall(r"%?([\w.\-]+)", names):
                        if n in comps:
                            total.add(cost_of(n))
        return total

    out = HloCosts()
    out.add(cost_of(entry))
    out.while_trips = dict(trips_seen)
    return out
