"""MODEL_FLOPS: the useful-work baseline for the roofline ratio.

train:   6 * N * D  (fwd 2ND + bwd 4ND), N = active params, D = tokens
prefill: 2 * N * D
decode:  2 * N * B  (one token per sequence)

For MoE archs N counts only *active* parameters: non-expert params plus
(top_k + n_shared)/n_experts of the routed expert params. Attention
score/value FLOPs (O(S^2)) are excluded per the standard 6ND convention —
the HLO/model ratio therefore runs above 1 for long sequences, which the
§Roofline notes call out per cell.
"""
from __future__ import annotations

from repro.models.config import ModelConfig
from repro.models.lm import LM, PAD_MULTIPLE


def active_params(cfg: ModelConfig) -> float:
    """Active-parameter count from config arithmetic (not materialized)."""
    d, f = cfg.d_model, cfg.d_ff
    dh, h, kh = cfg.head_dim, cfg.n_heads, cfg.n_kv_heads
    attn = d * (h * dh) * 2 + d * (kh * dh) * 2
    emb = cfg.vocab * d * (1 if cfg.tie_embeddings else 2)

    per_layer_dense = attn + 3 * d * f
    if cfg.block_pattern == "moe":
        fe = cfg.d_ff_expert or f
        active_experts = cfg.top_k + cfg.n_shared_experts
        per_layer = attn + d * cfg.n_experts + 3 * d * fe * active_experts
        return cfg.n_layers * per_layer + emb
    if cfg.block_pattern.startswith("mamba_hybrid"):
        di = cfg.ssm_expand * d
        hh = di // cfg.ssm_head_dim
        n = cfg.ssm_state
        mamba = d * (2 * di + 2 * n + hh) + di * d + cfg.conv_width * (
            di + 2 * n)
        k = cfg.pattern_arg(6)
        n_shared_invocations = cfg.n_layers // k
        shared = attn + 3 * d * f
        return (cfg.n_layers * mamba + n_shared_invocations * shared + emb)
    if cfg.block_pattern.startswith("xlstm"):
        di = 2 * d
        mlstm = d * 2 * di + di * 3 * di + di * 2 * cfg.n_heads + di * d
        slstm = d * 4 * d + cfg.n_heads * (d // cfg.n_heads) ** 2 * 4 + \
            d * 2 * (4 * d // 3) + (4 * d // 3) * d
        k = cfg.pattern_arg(4)
        n_groups = cfg.n_layers // k
        return n_groups * ((k - 1) * mlstm + slstm) + emb
    return cfg.n_layers * per_layer_dense + emb


def model_flops(cfg: ModelConfig, shape) -> float:
    n = active_params(cfg)
    tokens = shape.global_batch * shape.seq_len
    if shape.kind == "train":
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        return 2.0 * n * tokens
    return 2.0 * n * shape.global_batch          # decode: 1 token/sequence
