"""Disk checkpointing (the outer layer of defense, below ESRP in frequency).

In the paper's framing: ESRP handles node failures within a job (in-memory,
cheap, every T steps); disk checkpoints handle full-job loss (rare, slow,
every T_disk >> T steps). Plain npz + a json manifest per save — no external
checkpoint library in this environment. Arrays are saved device-host via
numpy; restore returns numpy arrays that jax consumes directly (sharding is
re-applied by the caller's jit in_shardings).

Every payload is checksummed (sha256 over the raw npz bytes) at save time
and verified at load time: under a silent-data-corruption threat model a
checkpoint that restores corrupted bytes is *worse* than no checkpoint —
the run resumes from poisoned state with no detector left to notice (the
in-memory invariant checks only guard live solver state). A mismatch raises
``CorruptCheckpointError`` instead of silently unflattening garbage.
"""
from __future__ import annotations

import hashlib
import json
import os

import jax
import numpy as np


class CorruptCheckpointError(RuntimeError):
    """A checkpoint payload failed its integrity check on load."""


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def _digest(path: str) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for block in iter(lambda: f.read(1 << 20), b""):
            h.update(block)
    return h.hexdigest()


def save(path: str, step: int, **trees) -> None:
    """save(dir, step, params=..., opt=...). Atomic via rename."""
    os.makedirs(path, exist_ok=True)
    tmp = os.path.join(path, f".tmp_step_{step}")
    os.makedirs(tmp, exist_ok=True)
    manifest = {"step": step, "trees": {}}
    for name, tree in trees.items():
        leaves, treedef = _flatten(tree)
        payload = os.path.join(tmp, f"{name}.npz")
        np.savez(payload,
                 **{f"leaf_{i}": np.asarray(a) for i, a in enumerate(leaves)})
        manifest["trees"][name] = {
            "n_leaves": len(leaves),
            "treedef": str(treedef),
            "sha256": _digest(payload),
        }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    final = os.path.join(path, f"step_{step:08d}")
    if os.path.exists(final):
        import shutil
        shutil.rmtree(final)
    os.rename(tmp, final)


def latest_step(path: str):
    if not os.path.isdir(path):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(path)
             if d.startswith("step_")]
    return max(steps) if steps else None


def restore(path: str, step: int, templates: dict) -> dict:
    """templates: {name: pytree with the target structure}. Returns
    {name: restored pytree} (+ "step"). Verifies each payload's stored
    checksum before unflattening; raises CorruptCheckpointError on
    mismatch."""
    d = os.path.join(path, f"step_{step:08d}")
    manifest_path = os.path.join(d, "manifest.json")
    manifest = None
    if os.path.exists(manifest_path):
        with open(manifest_path) as f:
            manifest = json.load(f)
    out = {"step": step}
    for name, template in templates.items():
        payload = os.path.join(d, f"{name}.npz")
        entry = (manifest or {}).get("trees", {}).get(name, {})
        expected = entry.get("sha256")
        if expected is not None:
            actual = _digest(payload)
            if actual != expected:
                raise CorruptCheckpointError(
                    f"checkpoint payload {payload!r} (step {step}, tree "
                    f"{name!r}) failed its integrity check: stored sha256 "
                    f"{expected[:16]}…, got {actual[:16]}… — the bytes "
                    f"changed after save; refusing to restore corrupted "
                    f"state")
        data = np.load(payload)
        leaves, treedef = _flatten(template)
        restored = [data[f"leaf_{i}"] for i in range(len(leaves))]
        out[name] = jax.tree_util.tree_unflatten(treedef, restored)
    return out
