"""Disk checkpointing (the outer layer of defense, below ESRP in frequency).

In the paper's framing: ESRP handles node failures within a job (in-memory,
cheap, every T steps); disk checkpoints handle full-job loss (rare, slow,
every T_disk >> T steps). Plain npz + a json manifest per save — no external
checkpoint library in this environment. Arrays are saved device-host via
numpy; restore returns numpy arrays that jax consumes directly (sharding is
re-applied by the caller's jit in_shardings).
"""
from __future__ import annotations

import json
import os

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save(path: str, step: int, **trees) -> None:
    """save(dir, step, params=..., opt=...). Atomic via rename."""
    os.makedirs(path, exist_ok=True)
    tmp = os.path.join(path, f".tmp_step_{step}")
    os.makedirs(tmp, exist_ok=True)
    manifest = {"step": step, "trees": {}}
    for name, tree in trees.items():
        leaves, treedef = _flatten(tree)
        np.savez(os.path.join(tmp, f"{name}.npz"),
                 **{f"leaf_{i}": np.asarray(a) for i, a in enumerate(leaves)})
        manifest["trees"][name] = {
            "n_leaves": len(leaves),
            "treedef": str(treedef),
        }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    final = os.path.join(path, f"step_{step:08d}")
    if os.path.exists(final):
        import shutil
        shutil.rmtree(final)
    os.rename(tmp, final)


def latest_step(path: str):
    if not os.path.isdir(path):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(path)
             if d.startswith("step_")]
    return max(steps) if steps else None


def restore(path: str, step: int, templates: dict) -> dict:
    """templates: {name: pytree with the target structure}. Returns
    {name: restored pytree} (+ "step")."""
    d = os.path.join(path, f"step_{step:08d}")
    out = {"step": step}
    for name, template in templates.items():
        data = np.load(os.path.join(d, f"{name}.npz"))
        leaves, treedef = _flatten(template)
        restored = [data[f"leaf_{i}"] for i in range(len(leaves))]
        out[name] = jax.tree_util.tree_unflatten(treedef, restored)
    return out
