"""Buddy-redundancy primitives over the FSDP axis (paper Eq. 1 on a mesh).

A "node" here is a position along the FSDP sharding axis. ``buddy_push``
produces, for each k in 1..phi, a pytree whose shard at rank ``d_{s,k}``
holds rank s's data — realized as ``jnp.roll`` along each leaf's sharded
dimension, which GSPMD lowers to a ``collective-permute`` ring hop: the
physical buddy send of the paper's IMCR / the moment push of our ESRP
trainer. Recovery reads failed rank f's content from buf k at slice
``d_{f,k}`` of the *rolled* tree, i.e. the surviving buddy's memory.

Shard-dim selection: the first dimension whose logical spec names "fsdp"
(falling back to the first dim divisible by the axis size). Scalars and
replicated leaves need no redundancy (they survive on any rank, like the
paper's replicated beta).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.sparse.partition import neighbor


def _fsdp_dim(spec: Optional[P], arr, n_ranks: int) -> Optional[int]:
    if spec is not None:
        for i, ax in enumerate(spec):
            names = (ax,) if isinstance(ax, str) else tuple(ax or ())
            if "fsdp" in names:
                return i if arr.shape[i] % n_ranks == 0 else None
    for i, d in enumerate(arr.shape):
        if d % n_ranks == 0 and d >= n_ranks:
            return i
    return None


def shard_slice(arr, dim: int, rank: int, n_ranks: int):
    size = arr.shape[dim] // n_ranks
    idx = [slice(None)] * arr.ndim
    idx[dim] = slice(rank * size, (rank + 1) * size)
    return tuple(idx)


@dataclasses.dataclass(frozen=True)
class BuddyPlan:
    """Static layout: per-leaf shard dims + ring neighbours."""
    n_ranks: int
    phi: int
    dims: tuple          # flat tuple of per-leaf shard dim (or None)
    treedef: object

    @staticmethod
    def build(tree, specs, n_ranks: int, phi: int) -> "BuddyPlan":
        leaves, treedef = jax.tree.flatten(tree)
        if specs is None:
            spec_leaves = [None] * len(leaves)
        else:
            spec_leaves = jax.tree.leaves(
                specs, is_leaf=lambda x: isinstance(x, P))
        dims = tuple(_fsdp_dim(s, a, n_ranks)
                     for s, a in zip(spec_leaves, leaves))
        return BuddyPlan(n_ranks, phi, dims, treedef)

    # ------------------------------------------------------------------ #
    def push(self, tree, dtype=None):
        """phi rolled copies: copy k's shard at rank d_{s,k} = rank s's data.
        Optionally down-cast (compressed redundancy, beyond-paper)."""
        leaves = jax.tree.flatten(tree)[0]
        out = []
        for k in range(1, self.phi + 1):
            # receiving rank d = neighbor(s, k): shift = d - s
            shift = ((k + 1) // 2) if k % 2 == 1 else -(k // 2)
            rolled = []
            for leaf, dim in zip(leaves, self.dims):
                if dim is None:
                    rolled.append(leaf)         # replicated: survives anyway
                    continue
                size = leaf.shape[dim] // self.n_ranks
                r = jnp.roll(leaf, shift * size, axis=dim)
                rolled.append(r.astype(dtype) if dtype else r)
            out.append(jax.tree.unflatten(self.treedef, rolled))
        return out

    def lose(self, tree, failed: list[int]):
        """Failure simulation: zero the failed ranks' shards (paper §4)."""
        leaves = jax.tree.flatten(tree)[0]
        out = []
        for leaf, dim in zip(leaves, self.dims):
            if dim is None:
                out.append(leaf)
                continue
            for f in failed:
                sl = shard_slice(leaf, dim, f, self.n_ranks)
                leaf = leaf.at[sl].set(0)
            out.append(leaf)
        return jax.tree.unflatten(self.treedef, out)

    def recover(self, lost_tree, buddies: list, failed: list[int],
                dtype_tree=None):
        """Rebuild failed shards from surviving buddies' buffers."""
        failed_set = set(failed)
        if len(failed) > self.phi:
            raise RuntimeError(
                f"{len(failed)} simultaneous failures exceed phi={self.phi}")
        lost_leaves = jax.tree.flatten(lost_tree)[0]
        buddy_leaves = [jax.tree.flatten(b)[0] for b in buddies]
        out = list(lost_leaves)
        for f in failed:
            k_ok = next(k for k in range(1, self.phi + 1)
                        if neighbor(f, k, self.n_ranks) not in failed_set)
            d = neighbor(f, k_ok, self.n_ranks)
            for i, dim in enumerate(self.dims):
                if dim is None:
                    continue
                src = buddy_leaves[k_ok - 1][i]
                sl_d = shard_slice(src, dim, d, self.n_ranks)
                sl_f = shard_slice(out[i], dim, f, self.n_ranks)
                out[i] = out[i].at[sl_f].set(
                    src[sl_d].astype(out[i].dtype))
        return jax.tree.unflatten(self.treedef, out)

    def bytes_per_push(self, tree) -> int:
        """Per-push communication volume (for the overhead model)."""
        total = 0
        for leaf, dim in zip(jax.tree.flatten(tree)[0], self.dims):
            if dim is not None:
                total += leaf.size * leaf.dtype.itemsize
        return total * self.phi
