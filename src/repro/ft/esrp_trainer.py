"""ESRP fault tolerance for LM training — the paper's technique as a
first-class framework feature (DESIGN.md §4).

Mapping of the paper's concepts onto the (params, Adam moments) train state
sharded FSDP-style along the ``data`` mesh axis:

  ASpMV piggyback        -> params are all-gathered along the FSDP axis every
                            step anyway; at a storage stage each rank simply
                            *retains* its phi ring-neighbours' param shards
                            from the gather it already performed. Zero extra
                            communication — redundancy inherent to the
                            algorithm, exactly the ESR insight.
  explicit moment push   -> Adam m/v are never communicated by training, so
                            they get a real buddy push (collective-permute
                            ring hops) every T steps — the analogue of the
                            paper's queue/starred storage. Optionally pushed
                            in bf16 ("compressed redundancy", beyond-paper).
  queue-of-2 stages      -> pushes alternate between two buffer slots so a
                            failure *during* a push still finds a complete,
                            consistent (step, params, m, v) set — the
                            training analogue of the paper's queue-of-3
                            rationale (one in-flight + one committed).
  rollback + replay      -> the data pipeline is (seed, step)-deterministic,
                            so recovery rolls everyone to the last storage
                            stage and replays <= T steps, reproducing the
                            undisturbed trajectory bit-for-bit (tested).
  IMCR baseline          -> mode="imcr": params are *pushed* too (no
                            piggyback) — the paper's comparison carried over.

A "node" is a position along the FSDP axis; a node failure loses every shard
slice owned by that position (params, moments — and, like the paper's
replicated scalars, the step counter survives on any rank).
"""
from __future__ import annotations

import contextlib
import dataclasses
import time
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.ft.buddy import BuddyPlan
from repro.train.optimizer import OptState


@dataclasses.dataclass(frozen=True)
class FTConfig:
    mode: str = "esrp"            # "esrp" | "imcr" | "none"
    T: int = 20                   # storage interval (steps)
    phi: int = 1                  # tolerated simultaneous node failures
    n_ranks: int = 8              # FSDP-axis length ("nodes")
    compress: bool = False        # bf16 moment redundancy (beyond-paper)


class FTBuffers(NamedTuple):
    """Redundant storage. Two slots alternate (in-flight safety).

    Per slot: ``local`` is each rank's own snapshot (the paper's starred
    duplicates — zero communication; survivors roll back from it) and the
    ``*_buddies`` lists hold the phi ring-rolled copies (what buddies
    received — replacements rebuild failed shards from them). Both live in
    node memory, so a failure loses their failed-rank slices as well."""
    slot_local: list             # per slot: (params, mu, nu) snapshot trees
    slot_params: list            # per slot: list over k of rolled param trees
    slot_mu: list
    slot_nu: list
    slot_step: list              # step each slot snapshots (-1 = empty)
    active: int                  # slot last written


class ESRPTrainer:
    """Wraps a pjit-able train_step with ESRP storage/recovery."""

    def __init__(self, model, train_step: Callable, pipeline, ft: FTConfig,
                 param_specs=None, obs=None):
        self.model = model
        self.train_step = jax.jit(train_step)
        self.pipeline = pipeline
        self.ft = ft
        self.param_specs = param_specs
        self._plan: Optional[BuddyPlan] = None
        self.push_bytes = 0
        self.push_count = 0
        self.obs = obs                # obs.Tracer: storage/failure/recovery
        #                               spans + per-step loss counter

    def _span(self, name: str, cat: str, **args):
        if self.obs is None:
            return contextlib.nullcontext()
        return self.obs.span(name, cat=cat, **args)

    # ------------------------------------------------------------------ #
    def init_buffers(self, params, opt: OptState) -> FTBuffers:
        self._plan = BuddyPlan.build(params, self.param_specs,
                                     self.ft.n_ranks, self.ft.phi)
        self._mplan = BuddyPlan.build(opt.mu, None, self.ft.n_ranks,
                                      self.ft.phi)
        empty = [None, None]
        return FTBuffers(slot_local=list(empty), slot_params=list(empty),
                         slot_mu=list(empty), slot_nu=list(empty),
                         slot_step=[-1, -1], active=0)

    def storage_stage(self, params, opt: OptState, bufs: FTBuffers,
                      step: int) -> FTBuffers:
        """Every T steps: retain params (esrp: free at gather time; imcr:
        explicit push) + push moments to phi buddies + local snapshots (the
        paper's starred duplicates, no communication)."""
        if self.ft.mode == "none":
            return bufs
        with self._span("ft_storage_push", cat="storage", step=step,
                        mode=self.ft.mode) as push_sp:
            dtype = jnp.bfloat16 if self.ft.compress else None
            p_copies = self._plan.push(params)     # esrp: retained, not sent
            mu_copies = self._mplan.push(opt.mu, dtype)
            nu_copies = self._mplan.push(opt.nu, dtype)
            local = (jax.tree.map(jnp.copy, params),
                     jax.tree.map(jnp.copy, opt.mu),
                     jax.tree.map(jnp.copy, opt.nu))
            slot = 1 - bufs.active                 # write the non-active slot
            sl = list(bufs.slot_local)
            sp = list(bufs.slot_params)
            sm = list(bufs.slot_mu)
            sn = list(bufs.slot_nu)
            ss = list(bufs.slot_step)
            sl[slot], sp[slot], sm[slot], sn[slot], ss[slot] = (
                local, p_copies, mu_copies, nu_copies, step)
            # communication accounting: moments always travel; params only
            # under imcr (esrp retains them from the existing FSDP all-gather)
            scale = 0.5 if self.ft.compress else 1.0   # bf16 moment push
            pushed = int(self._mplan.bytes_per_push(opt.mu) * 2 * scale)
            if self.ft.mode == "imcr":
                pushed += self._plan.bytes_per_push(params)
            self.push_bytes += pushed
            self.push_count += 1
            if push_sp is not None:
                push_sp.args["bytes"] = pushed
                self.obs.add_counter("ft_push_bytes", pushed, step=step)
        return FTBuffers(sl, sp, sm, sn, ss, active=slot)

    # ------------------------------------------------------------------ #
    def inject_failure(self, params, opt: OptState, bufs: FTBuffers,
                       failed: list[int]):
        """Zero the failed ranks' shards of ALL node-resident state — live
        params/moments AND the redundancy buffers they host (paper §4: a
        failed node loses everything, including copies it held for others)."""
        lose_p = lambda t: self._plan.lose(t, failed)
        lose_m = lambda t: self._mplan.lose(t, failed)
        params = lose_p(params)
        opt = OptState(mu=lose_m(opt.mu), nu=lose_m(opt.nu), step=opt.step)
        sl, sp, sm, sn = (list(bufs.slot_local), list(bufs.slot_params),
                          list(bufs.slot_mu), list(bufs.slot_nu))
        for i in range(2):
            if bufs.slot_step[i] < 0:
                continue
            sl[i] = (lose_p(sl[i][0]), lose_m(sl[i][1]), lose_m(sl[i][2]))
            sp[i] = [lose_p(t) for t in sp[i]]
            sm[i] = [lose_m(t) for t in sm[i]]
            sn[i] = [lose_m(t) for t in sn[i]]
        bufs = FTBuffers(sl, sp, sm, sn, list(bufs.slot_step), bufs.active)
        return params, opt, bufs

    def recover(self, bufs: FTBuffers, failed: list[int]):
        """Roll everyone back to the last storage stage: survivors restore
        from their local snapshots, failed shards are rebuilt from the
        surviving buddies' rolled copies. Returns (params, opt, step)."""
        slot = bufs.active
        if bufs.slot_step[slot] < 0:
            slot = 1 - slot
        if bufs.slot_step[slot] < 0:
            raise RuntimeError("failure before the first storage stage")
        base_p, base_mu, base_nu = bufs.slot_local[slot]
        params = self._plan.recover(base_p, bufs.slot_params[slot], failed)
        mu = self._mplan.recover(base_mu, bufs.slot_mu[slot], failed)
        nu = self._mplan.recover(base_nu, bufs.slot_nu[slot], failed)
        restart = bufs.slot_step[slot]
        opt = OptState(mu=mu, nu=nu, step=jnp.asarray(restart, jnp.int32))
        return params, opt, restart

    # ------------------------------------------------------------------ #
    def fit(self, params, opt: OptState, n_steps: int,
            scenario: Optional[list] = None,
            fail_at: Optional[int] = None,
            failed_ranks: Optional[list[int]] = None, start_step: int = 0):
        """Training loop with storage stages + an optional *failure
        scenario*: a list of ``FailureEvent(step, ranks)`` entries with the
        solver driver's semantics (``core.failures.normalize_scenario`` —
        simultaneous multi-rank events, staggered multi-event runs, strictly
        increasing step numbers, each event firing exactly once). Recovery
        rolls everyone back to the last storage stage and replays, so a
        later event's step is reached again on the replay *after* its
        predecessor was consumed — rollback never re-arms an event. The
        legacy ``fail_at``/``failed_ranks`` shorthand maps to one event.
        Returns (params, opt, losses: dict step -> loss)."""
        from repro.core.failures import normalize_scenario

        pending = normalize_scenario(scenario, fail_at, failed_ranks,
                                     self.ft.n_ranks)
        bufs = self.init_buffers(params, opt)
        losses = {}
        step = start_step
        while step < n_steps:
            if self.ft.mode != "none" and step % self.ft.T == 0 and step > 0:
                bufs = self.storage_stage(params, opt, bufs, step)
            if pending and step == pending[0].iter:
                ev = pending.pop(0)
                failed = list(ev.nodes)
                with self._span("ft_inject", cat="event", step=step,
                                ranks=failed):
                    params, opt, bufs = self.inject_failure(params, opt,
                                                            bufs, failed)
                with self._span("ft_recover", cat="recovery",
                                ranks=failed) as rec_sp:
                    params, opt, step = self.recover(bufs, failed)
                    if rec_sp is not None:
                        rec_sp.args["restart_step"] = step
                continue
            batch = self.pipeline.batch_at(step)
            params, opt, metrics = self.train_step(params, opt, batch)
            losses[step] = float(metrics["loss"])
            if self.obs is not None:
                self.obs.counter("ft_step", step=step, loss=losses[step])
            step += 1
        return params, opt, losses

    def run(self, params, opt: OptState, n_steps: int,
            fail_at: Optional[int] = None,
            failed_ranks: Optional[list[int]] = None, start_step: int = 0):
        """Legacy single-event entry point; ``fit`` is the scenario form."""
        return self.fit(params, opt, n_steps, fail_at=fail_at,
                        failed_ranks=failed_ranks, start_step=start_step)
