"""Serving steps: the solver micro-batch step, plus the LM prefill/decode
pair.

``make_solve_step`` is the solver service's functional core — one dispatch
of a padded (B, M) right-hand-side micro-batch through the batched resilient
solver, returning the B per-member ``SolveReport``s. The LM builders remain
for the language-model serving path (``--arch`` on the launcher):
``decode_*`` shapes in the assignment lower ``serve_step`` — one new token
against a KV cache of seq_len — NOT ``train_step``; these builders are what
the dry-run lowers for the inference cells.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp


def make_solve_step(problem, **solve_kwargs) -> Callable:
    """The solver service's functional core: ``rhs (B, M) ->
    list[SolveReport]``.

    Thin partial application of ``solve_resilient`` — exists so the
    micro-batcher, the benchmarks, and the tests all dispatch through one
    entry point (and so the LM serving steps and the solver step live side
    by side in ``repro.serve``)."""
    from repro.core.driver import solve_resilient

    def solve_step(rhs, scenario=None, obs=None):
        return solve_resilient(problem, rhs=rhs, scenario=scenario, obs=obs,
                               **solve_kwargs)

    return solve_step


def make_prefill_step(model):
    def prefill_step(params, batch, caches):
        logits, caches = model.prefill(params, batch, caches)
        # next-token from the last prompt position (greedy)
        next_tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        return next_tok, caches

    return prefill_step


def make_decode_step(model):
    def decode_step(params, tokens, caches, pos):
        logits, caches = model.decode_step(params, tokens, caches, pos)
        next_tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        return next_tok, caches

    return decode_step
