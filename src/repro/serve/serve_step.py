"""Serving steps: prefill (prompt -> populated cache) and decode (one token).

``decode_*`` shapes in the assignment lower ``serve_step`` — one new token
against a KV cache of seq_len — NOT ``train_step``; these builders are what
the dry-run lowers for the inference cells.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def make_prefill_step(model):
    def prefill_step(params, batch, caches):
        logits, caches = model.prefill(params, batch, caches)
        # next-token from the last prompt position (greedy)
        next_tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        return next_tok, caches

    return prefill_step


def make_decode_step(model):
    def decode_step(params, tokens, caches, pos):
        logits, caches = model.decode_step(params, tokens, caches, pos)
        next_tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        return next_tok, caches

    return decode_step
