"""Streaming resilient solve service: request queue -> micro-batcher ->
batched ``solve_resilient``.

The service owns one ``Problem`` (one operator + preconditioner) and serves a
stream of right-hand sides against it — the production shape of the paper's
setting, where a PDE operator is factored/partitioned once and many load
vectors arrive over time (time steps, optimization iterates, parameter
sweeps). Requests are drained in fixed-size micro-batches of ``B`` members:

  * every micro-batch is padded to exactly ``B`` rows with zero RHS members
    (a zero row freezes at iteration 0 under the per-member convergence
    freeze and reports rel = 0), so the jitted batched chunk runners compile
    once and are reused for every dispatch — including the final partial
    batch;
  * the whole micro-batch advances in lockstep through the batched
    ``SolverOps`` bundle; members that converge early freeze in place
    (continuous batching) while stragglers keep iterating;
  * a ``FailureEvent`` striking mid-batch hits all ``B`` members at once and
    one Alg. 2 reconstruction pass recovers every member together — the
    service keeps serving through injected failures;
  * per-request latency (queue wait + solve) lands as nested spans and
    records on a ``repro.obs.Tracer``, and each member's ``SolveReport``
    carries its ``batch_index``/``batch_size`` placement.

The service is synchronous by design: ``submit`` enqueues, ``run`` drains.
That keeps it deterministic (testable bit-for-bit against B=1 references
with ``fused=False``; the default fused throughput mode matches to ~ulp)
while exercising the same micro-batching control flow an async front-end
would drive.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Optional

import numpy as np

from repro.core.driver import SolveReport
from repro.serve.serve_step import make_solve_step


@dataclasses.dataclass
class SolveRequest:
    req_id: int
    rhs: np.ndarray
    t_submit: float


@dataclasses.dataclass
class RequestResult:
    req_id: int
    report: SolveReport
    latency_s: float        # submit -> result available
    queue_wait_s: float     # submit -> micro-batch dispatch
    solve_s: float          # the micro-batch solve wall time
    batch_seq: int          # which micro-batch served it
    batch_fill: int         # real members in that micro-batch (<= B)


class SolverService:
    """Request queue + micro-batcher over the batched resilient solver.

    ``scenario`` (a list of ``core.failures.FailureEvent``) is injected into
    micro-batches where ``batch_seq % fail_every == 0`` — failures under
    sustained load, not a one-off. ``obs`` accepts a ``repro.obs.Tracer``
    (or ``True`` for a fresh one, exposed as ``self.tracer``).

    ``fused=True`` (default) runs the micro-batch in the fused-batched
    throughput mode — one einsum per iteration serves all B members, which
    is where the aggregate-throughput win comes from on op-overhead-bound
    backends. Members then match their B=1 references to ~ulp rather than
    bit-exactly; pass ``fused=False`` for the exact per-member-unrolled
    bundle (what the bit-identity tests drive)."""

    def __init__(self, problem, batch: int = 8, *, strategy: str = "esrp",
                 T: int = 20, phi: int = 1, rtol: float = 1e-8,
                 backend: str = "auto", ops=None, failure_runtime=None,
                 scenario=None, fail_every: int = 1, obs=None,
                 fused: bool = True,
                 solve_kwargs: Optional[dict] = None):
        if batch < 1:
            raise ValueError(f"batch must be >= 1, got {batch}")
        self.problem = problem
        self.batch = int(batch)
        self.m = int(problem.part.m)
        self.dtype = problem.b.dtype
        self.scenario = list(scenario) if scenario else None
        self.fail_every = max(1, int(fail_every))
        self.fused = bool(fused)
        kw = dict(strategy=strategy, T=T, phi=phi, rtol=rtol,
                  backend=backend, batch_fused=self.fused)
        if ops is not None:
            kw["ops"] = ops
        if failure_runtime is not None:
            kw["failure_runtime"] = failure_runtime
        kw.update(solve_kwargs or {})
        self._step = make_solve_step(problem, **kw)
        from repro.obs import Tracer
        self.tracer = obs if isinstance(obs, Tracer) else (
            Tracer("solver_service") if obs else None)
        self._queue: deque[SolveRequest] = deque()
        self.results: dict[int, RequestResult] = {}
        self._next_id = 0
        self._batch_seq = 0
        self._run_wall_s = 0.0        # cumulative time inside step()

    # ------------------------------------------------------------------ #
    def submit(self, rhs) -> int:
        """Enqueue one system (rhs of length M); returns the request id."""
        rhs = np.asarray(rhs, self.dtype)
        if rhs.shape != (self.m,):
            raise ValueError(
                f"rhs shape {rhs.shape} != ({self.m},): the service solves "
                f"one system per request against the shared operator")
        rid = self._next_id
        self._next_id += 1
        self._queue.append(SolveRequest(rid, rhs, time.perf_counter()))
        if self.tracer is not None:
            self.tracer.instant("request_submit", cat="serve", req_id=rid,
                                queued=len(self._queue))
        return rid

    def pending(self) -> int:
        return len(self._queue)

    # ------------------------------------------------------------------ #
    def step(self) -> list[RequestResult]:
        """Dispatch ONE micro-batch: drain up to B queued requests, pad to
        exactly B with zero-RHS members, solve, and file per-request
        results. Returns the new results (empty if the queue was empty)."""
        if not self._queue:
            return []
        reqs = [self._queue.popleft()
                for _ in range(min(self.batch, len(self._queue)))]
        fill = len(reqs)
        seq = self._batch_seq
        self._batch_seq += 1
        rhs = np.zeros((self.batch, self.m), self.dtype)
        for k, rq in enumerate(reqs):
            rhs[k] = rq.rhs
        scen = (list(self.scenario) if self.scenario is not None
                and seq % self.fail_every == 0 else None)

        tr = self.tracer
        mb_sp = None
        req_spans = []
        if tr is not None:
            mb_sp = tr.begin("microbatch", cat="serve", seq=seq, fill=fill,
                             batch=self.batch, padded=self.batch - fill,
                             failures=bool(scen))
            # per-request spans nest (LIFO) inside the micro-batch span:
            # each covers its request's residence in this dispatch, with the
            # queue wait and end-to-end latency attached on close
            req_spans = [tr.begin("request", cat="serve", req_id=rq.req_id,
                                  batch_index=k, seq=seq)
                         for k, rq in enumerate(reqs)]

        t0 = time.perf_counter()
        reports = self._step(rhs, scenario=scen, obs=tr)
        solve_s = time.perf_counter() - t0
        self._run_wall_s += solve_s
        t_done = time.perf_counter()

        out = []
        for k, rq in enumerate(reqs):
            rep = reports[k]
            res = RequestResult(
                req_id=rq.req_id, report=rep,
                latency_s=t_done - rq.t_submit,
                queue_wait_s=t0 - rq.t_submit,
                solve_s=solve_s, batch_seq=seq, batch_fill=fill)
            self.results[rq.req_id] = res
            out.append(res)
        if tr is not None:
            for sp, res in zip(reversed(req_spans), reversed(out)):
                tr.close(sp, latency_ms=res.latency_s * 1e3,
                         queue_wait_ms=res.queue_wait_s * 1e3,
                         converged=res.report.converged,
                         iters=res.report.converged_iter)
            tr.close(mb_sp, solve_s=solve_s)
            tr.add_counter("requests_served", fill, seq=seq)
            tr.record("microbatch", dict(
                seq=seq, fill=fill, batch=self.batch, solve_s=solve_s,
                failures=bool(scen),
                iters=[r.report.converged_iter for r in out]))
        return out

    def run(self) -> list[RequestResult]:
        """Drain the whole queue; returns results in completion order."""
        out = []
        while self._queue:
            out.extend(self.step())
        return out

    # ------------------------------------------------------------------ #
    def stats(self) -> dict:
        """Aggregate serving statistics over every completed request."""
        res = sorted(self.results.values(), key=lambda r: r.req_id)
        if not res:
            return dict(requests=0, batch=self.batch)
        lat = np.asarray([r.latency_s for r in res])
        wait = np.asarray([r.queue_wait_s for r in res])
        solve_wall = self._run_wall_s
        return dict(
            requests=len(res),
            batch=self.batch,
            microbatches=self._batch_seq,
            mean_fill=float(np.mean([r.batch_fill for r in res])),
            solve_wall_s=solve_wall,
            throughput_rps=(len(res) / solve_wall if solve_wall > 0
                            else float("inf")),
            latency_p50_ms=float(np.percentile(lat, 50) * 1e3),
            latency_p99_ms=float(np.percentile(lat, 99) * 1e3),
            latency_mean_ms=float(lat.mean() * 1e3),
            queue_wait_p50_ms=float(np.percentile(wait, 50) * 1e3),
            iters_total=int(sum(max(0, r.report.converged_iter)
                                for r in res)),
            all_converged=bool(all(r.report.converged for r in res)),
        )
