"""Streaming resilient solve service: request queue -> micro-batcher ->
batched ``solve_resilient``.

The service owns one ``Problem`` (one operator + preconditioner) and serves a
stream of right-hand sides against it — the production shape of the paper's
setting, where a PDE operator is factored/partitioned once and many load
vectors arrive over time (time steps, optimization iterates, parameter
sweeps). Requests are drained in fixed-size micro-batches of ``B`` members:

  * every micro-batch is padded to exactly ``B`` rows with zero RHS members
    (a zero row freezes at iteration 0 under the per-member convergence
    freeze and reports rel = 0), so the jitted batched chunk runners compile
    once and are reused for every dispatch — including the final partial
    batch;
  * the whole micro-batch advances in lockstep through the batched
    ``SolverOps`` bundle; members that converge early freeze in place
    (continuous batching) while stragglers keep iterating;
  * a ``FailureEvent`` striking mid-batch hits all ``B`` members at once and
    one Alg. 2 reconstruction pass recovers every member together — the
    service keeps serving through injected failures;
  * per-request latency (queue wait + solve) lands as nested spans and
    records on a ``repro.obs.Tracer``, and each member's ``SolveReport``
    carries its ``batch_index``/``batch_size`` placement.

Deadline-aware front-end (the async-shaped policy layer, still driven
synchronously so every behavior stays deterministic under test):

  * ``max_queue_wait_s`` bounds head-of-line blocking: ``step()`` dispatches
    only when a full micro-batch is queued OR the oldest request has waited
    that long — then it ships a *partial* batch (padded as usual) instead of
    holding the request hostage to fill;
  * per-request deadlines (``submit(rhs, deadline_s=...)``): a request whose
    deadline expires while still queued is dropped before dispatch
    (``status="deadline_missed"``, no report); one that completes late keeps
    its numerically-valid report but is marked ``deadline_missed`` — in both
    cases a missed deadline is a distinct terminal state, never counted as a
    failure;
  * bounded retry: a micro-batch whose solve dies on an unsurvivable event
    (``RuntimeError`` from the redundancy plan) is retried up to
    ``max_retries`` times with exponential backoff, with the scenario
    cleared on the retry (the failure already struck; the re-solve runs on
    the restored cluster). Exhausted retries file ``status="failed"``;
  * graceful degradation (``degrade=True``): solves run with elastic
    shrunk-mesh recovery, and once a micro-batch reports a shrink the
    service *adopts* the shrunk problem — subsequent micro-batches dispatch
    directly on the surviving nodes (scenario events aimed at amputated
    nodes are dropped) and every result records ``final_n_nodes``.

The service is synchronous by design: ``submit`` enqueues, ``run`` drains.
That keeps it deterministic (testable bit-for-bit against B=1 references
with ``fused=False``; the default fused throughput mode matches to ~ulp)
while exercising the same micro-batching control flow an async front-end
would drive.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Optional

import numpy as np

from repro.core.driver import SolveReport
from repro.serve.serve_step import make_solve_step


@dataclasses.dataclass
class SolveRequest:
    req_id: int
    rhs: np.ndarray
    t_submit: float
    t_deadline: Optional[float] = None   # absolute perf_counter time


@dataclasses.dataclass
class RequestResult:
    req_id: int
    report: Optional[SolveReport]   # None when dropped/failed before a solve
    latency_s: float        # submit -> result available
    queue_wait_s: float     # submit -> micro-batch dispatch (or drop)
    solve_s: float          # the micro-batch solve wall time
    batch_seq: int          # which micro-batch served it (-1 = queue drop)
    batch_fill: int         # real members in that micro-batch (<= B)
    status: str = "ok"      # "ok" | "deadline_missed" | "failed"
    retries: int = 0        # solve re-dispatches this result rode through
    final_n_nodes: int = 0  # node count that produced it (shrinks under
    #                         elastic degradation; 0 = no solve ran)


class SolverService:
    """Request queue + micro-batcher over the batched resilient solver.

    ``scenario`` (a list of ``core.failures.FailureEvent``) is injected into
    micro-batches where ``batch_seq % fail_every == 0`` — failures under
    sustained load, not a one-off. ``obs`` accepts a ``repro.obs.Tracer``
    (or ``True`` for a fresh one, exposed as ``self.tracer``).

    ``fused=True`` (default) runs the micro-batch in the fused-batched
    throughput mode — one einsum per iteration serves all B members, which
    is where the aggregate-throughput win comes from on op-overhead-bound
    backends. Members then match their B=1 references to ~ulp rather than
    bit-exactly; pass ``fused=False`` for the exact per-member-unrolled
    bundle (what the bit-identity tests drive).

    Deadline/retry/degradation knobs: ``max_queue_wait_s`` (None = legacy
    greedy dispatch), per-request ``deadline_s`` on ``submit``,
    ``max_retries`` + ``retry_backoff_s``, ``degrade`` (see module
    docstring)."""

    def __init__(self, problem, batch: int = 8, *, strategy: str = "esrp",
                 T: int = 20, phi: int = 1, rtol: float = 1e-8,
                 backend: str = "auto", ops=None, failure_runtime=None,
                 scenario=None, fail_every: int = 1, obs=None,
                 fused: bool = True,
                 max_queue_wait_s: Optional[float] = None,
                 max_retries: int = 0, retry_backoff_s: float = 0.05,
                 degrade: bool = False,
                 solve_kwargs: Optional[dict] = None):
        if batch < 1:
            raise ValueError(f"batch must be >= 1, got {batch}")
        if max_queue_wait_s is not None and max_queue_wait_s < 0:
            raise ValueError(
                f"max_queue_wait_s must be >= 0, got {max_queue_wait_s}")
        if max_retries < 0:
            raise ValueError(f"max_retries must be >= 0, got {max_retries}")
        self.problem = problem
        self.batch = int(batch)
        self.m = int(problem.part.m)       # request length: the ORIGINAL
        #                                    system size, even after a shrink
        self.dtype = problem.b.dtype
        self.scenario = list(scenario) if scenario else None
        self.fail_every = max(1, int(fail_every))
        self.fused = bool(fused)
        self.max_queue_wait_s = max_queue_wait_s
        self.max_retries = int(max_retries)
        self.retry_backoff_s = float(retry_backoff_s)
        self.degrade = bool(degrade)
        self.n_nodes = int(problem.part.n_nodes)
        kw = dict(strategy=strategy, T=T, phi=phi, rtol=rtol,
                  backend=backend, batch_fused=self.fused)
        if ops is not None:
            kw["ops"] = ops
        if failure_runtime is not None:
            kw["failure_runtime"] = failure_runtime
        kw.update(solve_kwargs or {})
        if self.degrade:
            # degradation rides the elastic path: an unreplaced loss shrinks
            # the mesh instead of killing the batch
            kw.setdefault("elastic", True)
        self._solve_kw = kw
        self._step = make_solve_step(problem, **kw)
        from repro.obs import Tracer
        self.tracer = obs if isinstance(obs, Tracer) else (
            Tracer("solver_service") if obs else None)
        self._queue: deque[SolveRequest] = deque()
        self.results: dict[int, RequestResult] = {}
        self._next_id = 0
        self._batch_seq = 0
        self._run_wall_s = 0.0        # cumulative time inside step()
        self.partial_dispatches = 0   # queue-wait-timeout partial batches

    # ------------------------------------------------------------------ #
    def submit(self, rhs, deadline_s: Optional[float] = None) -> int:
        """Enqueue one system (rhs of length M); returns the request id.
        ``deadline_s`` (seconds from now) marks the request
        ``deadline_missed`` instead of serving it past its usefulness."""
        rhs = np.asarray(rhs, self.dtype)
        if rhs.shape != (self.m,):
            raise ValueError(
                f"rhs shape {rhs.shape} != ({self.m},): the service solves "
                f"one system per request against the shared operator")
        rid = self._next_id
        self._next_id += 1
        now = time.perf_counter()
        t_deadline = None if deadline_s is None else now + float(deadline_s)
        self._queue.append(SolveRequest(rid, rhs, now, t_deadline))
        if self.tracer is not None:
            self.tracer.instant("request_submit", cat="serve", req_id=rid,
                                queued=len(self._queue))
        return rid

    def pending(self) -> int:
        return len(self._queue)

    def ready(self) -> bool:
        """Would ``step()`` dispatch right now? Always true with work queued
        under the legacy greedy policy; with ``max_queue_wait_s`` set, true
        once a full micro-batch is queued or the oldest request has waited
        out the bound."""
        if not self._queue:
            return False
        if self.max_queue_wait_s is None:
            return True
        if len(self._queue) >= self.batch:
            return True
        age = time.perf_counter() - self._queue[0].t_submit
        return age >= self.max_queue_wait_s

    # ------------------------------------------------------------------ #
    def _drop_expired(self, rq: SolveRequest, now: float) -> RequestResult:
        res = RequestResult(
            req_id=rq.req_id, report=None, latency_s=now - rq.t_submit,
            queue_wait_s=now - rq.t_submit, solve_s=0.0, batch_seq=-1,
            batch_fill=0, status="deadline_missed")
        self.results[rq.req_id] = res
        if self.tracer is not None:
            self.tracer.instant("deadline_missed", cat="serve",
                                req_id=rq.req_id, where="queue",
                                waited_ms=res.queue_wait_s * 1e3)
        return res

    def _active_scenario(self, seq: int):
        if self.scenario is None or seq % self.fail_every != 0:
            return None
        # under degradation the mesh may have shrunk: an event aimed at an
        # amputated node can no longer strike
        scen = [e for e in self.scenario
                if max(e.nodes, default=0) < self.n_nodes]
        return scen or None

    def _solve_with_retry(self, rhs, scen, tr, seq):
        """Dispatch the micro-batch; on an unsurvivable event (RuntimeError
        out of the redundancy plan) retry with backoff, scenario cleared.
        Returns (reports|None, retries, solve_s)."""
        attempt = 0
        t_begin = time.perf_counter()
        while True:
            try:
                reports = self._step(rhs, scenario=scen, obs=tr)
                return reports, attempt, time.perf_counter() - t_begin
            except RuntimeError as exc:
                if tr is not None:
                    tr.instant("solve_retry", cat="serve", seq=seq,
                               attempt=attempt, error=str(exc)[:200])
                if attempt >= self.max_retries:
                    return None, attempt, time.perf_counter() - t_begin
                time.sleep(self.retry_backoff_s * (2 ** attempt))
                attempt += 1
                scen = None   # the event already struck; re-solve clean

    def _maybe_degrade(self, reports) -> None:
        """Adopt the shrunk problem once a dispatch reports an elastic
        shrink, so later micro-batches serve directly on the survivors."""
        n_new = min(r.final_n_nodes for r in reports)
        if not self.degrade or n_new >= self.n_nodes:
            return
        from repro.core import elastic
        self.problem = elastic.shrink_problem(self.problem, n_new)
        self.n_nodes = n_new
        self._step = make_solve_step(self.problem, **self._solve_kw)
        if self.tracer is not None:
            self.tracer.instant("service_degraded", cat="serve",
                                n_nodes=n_new)

    # ------------------------------------------------------------------ #
    def step(self, force: bool = False) -> list[RequestResult]:
        """Dispatch ONE micro-batch: drain up to B queued requests (dropping
        ones whose deadline already expired), pad to exactly B with zero-RHS
        members, solve (with bounded retry), and file per-request results.
        Returns the new results — empty if the queue was empty or (under
        ``max_queue_wait_s``) not yet worth dispatching; ``force=True``
        dispatches whatever is queued regardless (what ``run`` uses to
        drain)."""
        if not self._queue or (not force and not self.ready()):
            return []
        now = time.perf_counter()
        out: list[RequestResult] = []
        reqs: list[SolveRequest] = []
        while self._queue and len(reqs) < self.batch:
            rq = self._queue.popleft()
            if rq.t_deadline is not None and now > rq.t_deadline:
                out.append(self._drop_expired(rq, now))
                continue
            reqs.append(rq)
        if not reqs:
            return out
        fill = len(reqs)
        seq = self._batch_seq
        self._batch_seq += 1
        waited = (self.max_queue_wait_s is not None and fill < self.batch
                  and not self._queue and not force)
        if waited:
            self.partial_dispatches += 1
        m_cur = int(self.problem.part.m)   # >= self.m after a shrink re-pad
        rhs = np.zeros((self.batch, m_cur), self.dtype)
        for k, rq in enumerate(reqs):
            rhs[k, :self.m] = rq.rhs
        scen = self._active_scenario(seq)

        tr = self.tracer
        mb_sp = None
        req_spans = []
        if tr is not None:
            mb_sp = tr.begin("microbatch", cat="serve", seq=seq, fill=fill,
                             batch=self.batch, padded=self.batch - fill,
                             failures=bool(scen), partial_on_wait=waited,
                             n_nodes=self.n_nodes)
            # per-request spans nest (LIFO) inside the micro-batch span:
            # each covers its request's residence in this dispatch, with the
            # queue wait and end-to-end latency attached on close
            req_spans = [tr.begin("request", cat="serve", req_id=rq.req_id,
                                  batch_index=k, seq=seq)
                         for k, rq in enumerate(reqs)]

        t0 = time.perf_counter()
        reports, retries, solve_s = self._solve_with_retry(rhs, scen, tr,
                                                           seq)
        self._run_wall_s += solve_s
        t_done = time.perf_counter()

        for k, rq in enumerate(reqs):
            rep = reports[k] if reports is not None else None
            status = "ok" if rep is not None else "failed"
            if rep is not None:
                rep.retries = retries
                if rq.t_deadline is not None and t_done > rq.t_deadline:
                    # late completion: the report stays (numerically valid),
                    # the terminal state is the miss — never a failure
                    rep.deadline_missed = True
                    status = "deadline_missed"
                    if tr is not None:
                        tr.instant("deadline_missed", cat="serve",
                                   req_id=rq.req_id, where="solve")
            res = RequestResult(
                req_id=rq.req_id, report=rep,
                latency_s=t_done - rq.t_submit,
                queue_wait_s=t0 - rq.t_submit,
                solve_s=solve_s, batch_seq=seq, batch_fill=fill,
                status=status, retries=retries,
                final_n_nodes=(rep.final_n_nodes if rep is not None else 0))
            self.results[rq.req_id] = res
            out.append(res)
        served = out[-fill:]
        if tr is not None:
            for sp, res in zip(reversed(req_spans), reversed(served)):
                tr.close(sp, latency_ms=res.latency_s * 1e3,
                         queue_wait_ms=res.queue_wait_s * 1e3,
                         status=res.status,
                         converged=bool(res.report is not None
                                        and res.report.converged),
                         iters=(res.report.converged_iter
                                if res.report is not None else -1))
            tr.close(mb_sp, solve_s=solve_s, retries=retries)
            tr.add_counter("requests_served", fill, seq=seq)
            tr.record("microbatch", dict(
                seq=seq, fill=fill, batch=self.batch, solve_s=solve_s,
                failures=bool(scen), retries=retries,
                partial_on_wait=waited, n_nodes=self.n_nodes,
                iters=[(r.report.converged_iter if r.report is not None
                        else -1) for r in served]))
        if reports is not None:
            self._maybe_degrade(reports)
        return out

    def run(self) -> list[RequestResult]:
        """Drain the whole queue; returns results in completion order."""
        out = []
        while self._queue:
            out.extend(self.step(force=True))
        return out

    # ------------------------------------------------------------------ #
    def stats(self) -> dict:
        """Aggregate serving statistics over every completed request."""
        res = sorted(self.results.values(), key=lambda r: r.req_id)
        if not res:
            return dict(requests=0, batch=self.batch)
        solved = [r for r in res if r.report is not None]
        lat = np.asarray([r.latency_s for r in solved] or [0.0])
        wait = np.asarray([r.queue_wait_s for r in solved] or [0.0])
        solve_wall = self._run_wall_s
        misses = sum(r.status == "deadline_missed" for r in res)
        return dict(
            requests=len(res),
            batch=self.batch,
            microbatches=self._batch_seq,
            mean_fill=float(np.mean([r.batch_fill for r in solved]))
            if solved else 0.0,
            solve_wall_s=solve_wall,
            throughput_rps=(len(solved) / solve_wall if solve_wall > 0
                            else float("inf")),
            latency_p50_ms=float(np.percentile(lat, 50) * 1e3),
            latency_p99_ms=float(np.percentile(lat, 99) * 1e3),
            latency_mean_ms=float(lat.mean() * 1e3),
            queue_wait_p50_ms=float(np.percentile(wait, 50) * 1e3),
            queue_wait_p99_ms=float(np.percentile(wait, 99) * 1e3),
            deadline_missed=misses,
            deadline_miss_rate=misses / len(res),
            failed=sum(r.status == "failed" for r in res),
            retries_total=sum(r.retries for r in res),
            partial_dispatches=self.partial_dispatches,
            final_n_nodes=self.n_nodes,
            iters_total=int(sum(max(0, r.report.converged_iter)
                                for r in solved)),
            all_converged=bool(solved and all(r.report.converged
                                              for r in solved)),
        )
