"""Preconditioner protocol + registry — the pluggable subsystem the paper's
conclusion calls for ("these differences can be alleviated by the
implementation of more appropriate preconditioners").

A ``Preconditioner`` owns three things:

  * the hot-loop apply z = P r, per SolverOps backend ("jnp" | "pallas" |
    "interpret") with the repo's bit-identity contract between them;
  * the *recovery-aware* local operators for exact state reconstruction
    (paper Alg. 2 lines 5-6): ``local_ops(mask, f_rows)`` returns
    (offdiag_apply, pff_solve) where

        offdiag_apply(r_surv) = P_{f, I\\f} r_{I\\f}        (line 5)
        pff_solve(v)  solves  P_ff r_f = v                  (line 6)

    For preconditioners with genuine off-diagonal coupling (SSOR, IC(0),
    Chebyshev) the generic path realizes both matrix-free: linearity gives
    P_{f,I\\f} r_{I\\f} = (P r̃)_f with r̃ zeroed on I_f, and P_ff — an SPD
    principal submatrix of P — is solved by inner CG on u ↦ (P ũ)_f, each
    operator application running the preconditioner's real kernels
    (triangular sweeps for SSOR/IC(0), the polynomial recurrence for
    Chebyshev). Block-Jacobi overrides both with its exact closed forms
    (offdiag ≡ 0, P_ff⁻¹ = the raw diagonal blocks) — the seed's Alg. 2
    shortcut, bit-preserved.
  * ``static_state()`` — the serializable static data (host numpy) that a
    replacement node retrieves from safe storage to rebuild the operator
    after a failure (Alg. 2 line 1).

Implementations self-register via ``@register(name)``; ``build(name, ...)``
is the single constructor entry point used by ``sparse.matrices
.build_problem(..., precond=name)``.
"""
from __future__ import annotations

import abc
from typing import Callable, Optional

import jax.numpy as jnp
import numpy as np

_REGISTRY: dict[str, type] = {}


def tripart_matvec(idx, data, v, b: int):
    """y = T v for a zero-padded ELL triangular strip (jnp, vectorized).

    Invalid slots carry zero blocks (``blocktri._ell_pack``), so no mask is
    needed — this is a *matvec* through the strip, the cheap building block
    of the truncated-operator inner preconditioners (no substitution)."""
    import jax.numpy as jnp

    vb = v.reshape(-1, b)[idx]                       # (nbr, kmax, b)
    return jnp.einsum("nkij,nkj->ni", data, vb).reshape(-1)


def register(name: str):
    """Class decorator: register a Preconditioner under ``name``."""

    def deco(cls):
        cls.name = name
        _REGISTRY[name] = cls
        return cls

    return deco


def available() -> list[str]:
    return sorted(_REGISTRY)


def build(name: str, **ctx) -> "Preconditioner":
    """Build a registered preconditioner from problem context (COO, Block-ELL
    matrix, block size, dtype, precomputed diagonal blocks, plus
    per-preconditioner options)."""
    if name not in _REGISTRY:
        raise ValueError(
            f"unknown preconditioner {name!r}; available: {available()}")
    return _REGISTRY[name].build(**ctx)


class Preconditioner(abc.ABC):
    """Base class: backend-cached applies + generic recovery operators."""

    name: str = "?"
    m: int
    block: int

    # ------------------------------------------------------------------ #
    # hot-loop apply
    # ------------------------------------------------------------------ #
    @abc.abstractmethod
    def _make_apply(self, backend: str) -> Callable:
        """Backend-specific closure r -> z = P r."""

    def make_apply(self, backend: str = "jnp") -> Callable:
        """Cached per backend: the jitted chunk runners treat the SolverOps
        bundle (which holds this closure) as a static argument, so the same
        object must come back on every call. "auto" resolves here, before
        the cache and the subclasses' routing decisions, so "auto" and its
        resolution share one cache entry and the per-backend gates (e.g.
        the wavefront-vs-sequential sweep routing) see a concrete name."""
        if backend == "auto":
            import jax
            backend = "pallas" if jax.default_backend() == "tpu" else "jnp"
        cache = getattr(self, "_apply_cache", None)
        if cache is None:
            cache = {}
            self._apply_cache = cache
        if backend not in cache:
            cache[backend] = self._make_apply(backend)
        return cache[backend]

    def apply(self, r, backend: str = "jnp"):
        return self.make_apply(backend)(r)

    # ------------------------------------------------------------------ #
    # serializable static data (safe storage)
    # ------------------------------------------------------------------ #
    @abc.abstractmethod
    def static_state(self) -> dict:
        """Host-side dict of numpy arrays / plain scalars — everything a
        replacement node needs (beyond the problem's COO) to rebuild the
        operator. Round-trips through ``np.savez``."""

    # ------------------------------------------------------------------ #
    # recovery: Alg. 2 lines 5-6
    # ------------------------------------------------------------------ #
    def local_ops(self, mask: np.ndarray, f_rows: np.ndarray,
                  pff_precond: bool = True
                  ) -> tuple[Optional[Callable], Callable]:
        """(offdiag_apply, pff_solve) for a failed row set.

        Generic matrix-free path (any linear SPD preconditioner):
        ``offdiag_apply(r_surv)`` masks the failed entries and applies the
        full operator; ``pff_solve(v[, rtol, max_iters])`` runs CG on the
        restricted operator u ↦ (P ũ)_f — callers (``esr.reconstruct``)
        thread their ``inner_rtol``/``inner_max_iters`` through, defaulting
        to the paper's line-8 inner-solve tolerance. ``offdiag_apply`` may
        be None, meaning P_{f,I\\f} ≡ 0 exactly (block-Jacobi) so line 5
        degenerates to v = z_f.

        ``pff_precond=True`` (default) preconditions that inner CG with the
        SPD approximation of P_ff⁻¹ the subclass supplies via
        ``_pff_inner_precond`` — for SSOR/IC(0) the failed-slab-truncated
        operator M_ff (cheap triangular *matvecs*, no solves), which makes
        the P_ff solve the dominant recovery cost only by a small constant
        instead of by its condition number (the cost Pachajoa et al.,
        arXiv:1907.13077, identify as dominating reconstruction). The
        closure records ``pff_solve.stats = {"iters", "rel"}`` after each
        run so the recovery report can account for the inner solve.
        """
        from repro.core.pcg import run_pcg

        mask_d = jnp.asarray(mask)
        fr = jnp.asarray(np.asarray(f_rows))
        apply_full = self.make_apply("jnp")
        zeros = jnp.zeros((self.m,), self.dtype)

        def offdiag_apply(r_surv):
            return apply_full(jnp.where(mask_d, 0.0, r_surv))[fr]

        def pff_op(u):
            return apply_full(zeros.at[fr].set(u))[fr]

        inner = self._pff_inner_precond(mask, f_rows) if pff_precond \
            else None
        if inner is None:
            inner = lambda v: v

        def pff_solve(v, rtol: float = 1e-14, max_iters: int = 20_000):
            state, rel = run_pcg(pff_op, inner, v, rtol=rtol,
                                 max_iters=max_iters)
            pff_solve.stats = {"iters": int(state.j), "rel": float(rel)}
            return state.x

        pff_solve.stats = None
        return offdiag_apply, pff_solve

    def _pff_inner_precond(self, mask: np.ndarray, f_rows: np.ndarray
                           ) -> Optional[Callable]:
        """SPD approximation of P_ff⁻¹ preconditioning the line-6 inner CG
        (None = identity). Subclasses with genuine off-diagonal coupling
        override with their failed-slab-truncated operator."""
        return None

    @property
    def dtype(self):
        return self._dtype
