"""Block-Jacobi preconditioner — P = blockdiag(A_bb)⁻¹ (paper §5: uniform
blocks, max size 10, never straddling node boundaries).

Migrated here from ``sparse/matrices.py``; the block extraction and the
Cholesky-based batched inverse are host-side static data. The recovery
operators are the exact closed forms the seed hard-wired into Alg. 2:
P has zero off-diagonal (line 5: v = z_f), and P_ff⁻¹ is the raw diagonal
blocks (line 6: a block matvec) — overriding the generic matrix-free path so
the default configuration stays bit-identical to the pre-subsystem code.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.precond.base import Preconditioner, register


def block_jacobi_blocks(rows, cols, vals, m: int, b: int,
                        dtype=np.float64) -> np.ndarray:
    """Extract the (m/b, b, b) diagonal blocks of A (host-side, static)."""
    if m % b:
        raise ValueError(f"M={m} not divisible by precond block {b}")
    blk_r, blk_c = rows // b, cols // b
    on = blk_r == blk_c
    out = np.zeros((m // b, b, b), dtype)
    np.add.at(out, (blk_r[on], rows[on] % b, cols[on] % b), vals[on])
    return out


def invert_blocks(blocks: np.ndarray) -> np.ndarray:
    """P = blockdiag(A_bb)^{-1}; batched Cholesky-based inverse.

    A_bb⁻¹ = L⁻ᵀ L⁻¹ from A_bb = L Lᵀ: better conditioned than the general
    LU inverse, exactly symmetric by construction, and ``np.linalg.cholesky``
    raising on a non-positive-definite block doubles as an SPD validation of
    the problem setup."""
    try:
        chol = np.linalg.cholesky(blocks)
    except np.linalg.LinAlgError as e:
        raise np.linalg.LinAlgError(
            "block-Jacobi blocks are not SPD — the problem matrix is not "
            f"symmetric positive definite ({e})") from e
    eye = np.broadcast_to(np.eye(blocks.shape[-1], dtype=blocks.dtype),
                          blocks.shape)
    linv = np.linalg.solve(chol, eye)            # L⁻¹, batched
    return np.swapaxes(linv, -1, -2) @ linv


@register("jacobi")
class BlockJacobi(Preconditioner):
    def __init__(self, diag_blocks, pinv_blocks, block: int, m: int, dtype):
        self.diag_blocks = jnp.asarray(diag_blocks)
        self.pinv_blocks = jnp.asarray(pinv_blocks)
        self.block = block
        self.m = m
        self._dtype = dtype

    @classmethod
    def build(cls, *, coo, m, block, dtype, diag_blocks=None,
              pinv_blocks=None, **_):
        if diag_blocks is None:
            rows, cols, vals = coo
            diag_blocks = block_jacobi_blocks(rows, cols, vals, m, block,
                                              dtype)
        if pinv_blocks is None:
            pinv_blocks = invert_blocks(np.asarray(diag_blocks))
        return cls(diag_blocks, pinv_blocks, block, m, dtype)

    def _make_apply(self, backend: str):
        from repro.core.ops import pick_rows
        from repro.kernels.block_jacobi.block_jacobi import block_jacobi_apply
        from repro.kernels.block_jacobi.ref import block_jacobi_apply_ref

        pinv = self.pinv_blocks
        if backend == "jnp":
            return lambda r: block_jacobi_apply_ref(pinv, r)
        interp = backend == "interpret"
        rows = pick_rows(self.m, self.block)
        return lambda r: block_jacobi_apply(pinv, r, rows=rows,
                                            interpret=interp)

    def static_state(self) -> dict:
        return {"diag_blocks": np.asarray(self.diag_blocks),
                "pinv_blocks": np.asarray(self.pinv_blocks),
                "block": self.block}

    @classmethod
    def from_static(cls, state, *, m: int, dtype, **_):
        return cls(state["diag_blocks"], state["pinv_blocks"],
                   int(state["block"]), m, dtype)

    def local_ops(self, mask, f_rows, **_):
        """Exact closed forms: P offdiag ≡ 0 (None), P_ff⁻¹ = A_bb blocks."""
        b = self.block
        blk_ids = np.unique(np.asarray(f_rows) // b)
        diag_f = self.diag_blocks[jnp.asarray(blk_ids)]

        def pff_solve(v, rtol=None, max_iters=None):
            # exact direct solve — the tolerance knobs don't apply
            return jnp.einsum("nij,nj->ni", diag_f,
                              v.reshape(-1, b)).reshape(-1)

        return None, pff_solve
