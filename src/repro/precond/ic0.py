"""IC(0) blocked incomplete Cholesky preconditioner.

Level-0 fill at the preconditioner block granularity: the factor L keeps
exactly the block sparsity pattern of lower(A). Host-side factorization
(static data, rebuildable from the COO after a failure):

  for each block row i (ascending), for each pattern block j < i:
      L_ij = (A_ij − Σ_{k ∈ pat(i) ∩ pat(j), k < j} L_ik L_jkᵀ) L_jj⁻ᵀ
  D_i  = A_ii − Σ_{k ∈ pat(i)} L_ik L_ikᵀ ;   L_ii = chol(D_i)

Existence is guaranteed for M-/H-matrices (the Poisson and diagonally-
dominant banded regimes here); on breakdown a Manteuffel diagonal shift
A + α diag(A) is retried with increasing α. The apply is two blocked
triangular sweeps (``kernels/ic0``) with the L_ii⁻¹ diagonal solves
precomputed as dense blocks. P = (L Lᵀ)⁻¹ is SPD with dense off-diagonal
coupling, so Alg. 2 recovery uses the generic matrix-free path.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.precond.base import Preconditioner, register
from repro.precond.blocktri import TriPart, _ell_pack, block_split, \
    transpose_tripart, wavefront_pair


def _ic0_factor(diag: np.ndarray, lower: TriPart, shift: float):
    """One factorization attempt; returns (L_ii (nbr,b,b), {(i,j): L_ij},
    pattern lists) or raises LinAlgError on breakdown."""
    nbr, b, _ = diag.shape
    pat = [list(map(int, lower.idx[i, :int(lower.n[i])]))
           for i in range(nbr)]
    a_lo = {(i, j): lower.data[i, k]
            for i in range(nbr) for k, j in enumerate(pat[i])}
    l_lo: dict[tuple[int, int], np.ndarray] = {}
    l_ii = np.zeros_like(diag)
    for i in range(nbr):
        pat_i = pat[i]
        for j in pat_i:                              # ascending
            s = a_lo[(i, j)].copy()
            for k in pat_i:
                if k >= j:
                    break
                if (j, k) in l_lo:
                    s -= l_lo[(i, k)] @ l_lo[(j, k)].T
            # L_ij L_jjᵀ = S  ⟹  L_ij = (L_jj⁻¹ Sᵀ)ᵀ
            l_lo[(i, j)] = np.linalg.solve(l_ii[j], s.T).T
        # Manteuffel shift: boost the diagonal entries of the diagonal block
        d = diag[i] + shift * np.diag(np.diag(diag[i])) if shift \
            else diag[i].copy()
        for k in pat_i:
            d = d - l_lo[(i, k)] @ l_lo[(i, k)].T
        l_ii[i] = np.linalg.cholesky(d)              # raises on breakdown
    return l_ii, l_lo, pat


@register("ic0")
class IC0(Preconditioner):
    def __init__(self, lo_idx, lo_n, lo_data, up_idx, up_n, up_data, dinv_f,
                 dinv_b, block: int, m: int, dtype, shift: float = 0.0,
                 sweep_mode: str = "auto"):
        self.sweep_mode = sweep_mode
        self.lo_wf, self.up_wf = wavefront_pair(
            TriPart(np.asarray(lo_idx), np.asarray(lo_n),
                    np.asarray(lo_data)),
            TriPart(np.asarray(up_idx), np.asarray(up_n),
                    np.asarray(up_data)),
            np.asarray(dinv_f), np.asarray(dinv_b), m // block, sweep_mode)
        self.lo_idx = jnp.asarray(lo_idx)
        self.lo_n = jnp.asarray(lo_n)
        self.lo_data = jnp.asarray(lo_data)
        self.up_idx = jnp.asarray(up_idx)
        self.up_n = jnp.asarray(up_n)
        self.up_data = jnp.asarray(up_data)
        self.dinv_f = jnp.asarray(dinv_f)
        self.dinv_b = jnp.asarray(dinv_b)
        self.block = block
        self.m = m
        self._dtype = dtype
        self.shift = shift

    @classmethod
    def build(cls, *, coo, m, block, dtype,
              shifts=(0.0, 0.01, 0.1, 0.5, 1.0), sweep_mode: str = "auto",
              **_):
        rows, cols, vals = coo
        diag, lower, _upper = block_split(rows, cols, vals, m, block, dtype)
        nbr = m // block
        err = None
        for shift in shifts:
            try:
                l_ii, l_lo, pat = _ic0_factor(diag, lower, shift)
                break
            except np.linalg.LinAlgError as e:
                err = e
        else:
            raise np.linalg.LinAlgError(
                f"IC(0) breakdown even with shifts {shifts}: {err}")

        # pack L's strictly-lower blocks (pattern order is already sorted)
        br = np.asarray([i for i in range(nbr) for _ in pat[i]], np.int64)
        bc = np.asarray([j for i in range(nbr) for j in pat[i]], np.int64)
        blk = (np.stack([l_lo[(i, j)] for i in range(nbr) for j in pat[i]])
               if br.size else np.empty((0, block, block), dtype))
        l_lower = _ell_pack(br, bc, blk, nbr, block, dtype)
        l_upper = transpose_tripart(l_lower, nbr)    # Lᵀ strict upper = L_jiᵀ

        eye = np.broadcast_to(np.eye(block, dtype=dtype), l_ii.shape)
        dinv_f = np.linalg.solve(l_ii, eye)          # L_ii⁻¹
        dinv_b = np.swapaxes(dinv_f, -1, -2)         # L_ii⁻ᵀ
        return cls(l_lower.idx, l_lower.n, l_lower.data,
                   l_upper.idx, l_upper.n, l_upper.data,
                   dinv_f, dinv_b, block, m, dtype, shift, sweep_mode)

    def _make_apply(self, backend: str):
        from repro.kernels.ic0.ops import ic0_precond_apply

        args = (self.lo_idx, self.lo_n, self.lo_data, self.up_idx, self.up_n,
                self.up_data, self.dinv_f, self.dinv_b)
        # kernel backends take the level-scheduled grid; the jnp reference
        # keeps the unpadded sequential sweep unless forced (the two routes
        # are bit-identical, so this cannot fork backend trajectories)
        wf = backend != "jnp" or self.sweep_mode == "wavefront"
        lo_wf = self.lo_wf if wf else None
        up_wf = self.up_wf if wf else None
        return lambda r: ic0_precond_apply(*args, r, backend=backend,
                                           lo_wf=lo_wf, up_wf=up_wf)

    def _pff_inner_precond(self, mask, f_rows):
        """Failed-slab-truncated factor product: B = (L Lᵀ)_ff.

        P = (L Lᵀ)⁻¹, so P_ff⁻¹ ≈ (L Lᵀ)_ff — an SPD principal submatrix
        of the factor product, applied with two triangular *matvecs* (the
        diagonal factor blocks L_ii are rebuilt host-side from their stored
        inverses once per failed set)."""
        from repro.precond.base import tripart_matvec

        fr = jnp.asarray(np.asarray(f_rows))
        zeros = jnp.zeros((self.m,), self.dtype)
        b = self.block
        l_ii = jnp.asarray(np.linalg.inv(np.asarray(self.dinv_f)))
        l_iit = jnp.swapaxes(l_ii, -1, -2)
        lo_idx, lo_data = self.lo_idx, self.lo_data
        up_idx, up_data = self.up_idx, self.up_data

        def inner(u):
            v = zeros.at[fr].set(u)
            t = jnp.einsum("nij,nj->ni", l_iit,
                           v.reshape(-1, b)).reshape(-1) \
                + tripart_matvec(up_idx, up_data, v, b)      # Lᵀ v
            mv = jnp.einsum("nij,nj->ni", l_ii,
                            t.reshape(-1, b)).reshape(-1) \
                + tripart_matvec(lo_idx, lo_data, t, b)      # L (Lᵀ v)
            return mv[fr]

        return inner

    def static_state(self) -> dict:
        return {"lo_idx": np.asarray(self.lo_idx),
                "lo_n": np.asarray(self.lo_n),
                "lo_data": np.asarray(self.lo_data),
                "up_idx": np.asarray(self.up_idx),
                "up_n": np.asarray(self.up_n),
                "up_data": np.asarray(self.up_data),
                "dinv_f": np.asarray(self.dinv_f),
                "dinv_b": np.asarray(self.dinv_b),
                "block": self.block, "shift": self.shift,
                "sweep_mode": self.sweep_mode}

    @classmethod
    def from_static(cls, state, *, m: int, dtype, **_):
        return cls(state["lo_idx"], state["lo_n"], state["lo_data"],
                   state["up_idx"], state["up_n"], state["up_data"],
                   state["dinv_f"], state["dinv_b"], int(state["block"]),
                   m, dtype, float(state["shift"]),
                   str(state.get("sweep_mode", "auto")))
