"""Node-local (additive-Schwarz) preconditioner variants.

The sequential SSOR/IC(0) sweeps do not partition over the "nodes" mesh
axis: every block row of the substitution may depend on rows owned by other
nodes, which would serialize the whole distributed iteration (the scaling
obstruction Levonyak et al., arXiv:1912.09230, identify for resilient PCG).
The standard fix is the *block-Jacobi / additive-Schwarz* variant: drop every
coupling between different nodes' row slabs, so the preconditioner becomes
block-diagonal over nodes and each node sweeps its own diagonal slab
independently — embarrassingly parallel over the mesh axis, at the price of
a (usually small) iteration-count increase that ``SolveReport
.local_delta_iters`` tracks.

Algebraically this is the same preconditioner *class* applied to
blockdiag(A_s) (each A_s an SPD principal submatrix of A), so everything
else — SPD-ness, the recovery-aware Alg. 2 local operators, static-state
round-trips — is inherited unchanged from the registered implementation. In
fact recovery gets *simpler*: when the failed set is a union of whole node
slabs, P_{f, I\\f} is exactly zero.
"""
from __future__ import annotations

import numpy as np


def is_slab_local(idx: np.ndarray, n: np.ndarray, nbr_per_node: int) -> bool:
    """True iff every valid ELL slot of every block row references a column
    block in the same node slab as the row (host-side static check)."""
    idx = np.asarray(idx)
    n = np.asarray(n)
    nbr, kmax = idx.shape
    valid = np.arange(kmax)[None, :] < n[:, None]
    row_slab = np.arange(nbr)[:, None] // nbr_per_node
    return bool(np.all(~valid | (idx // nbr_per_node == row_slab)))


def precond_is_node_local(pc, n_nodes: int) -> bool:
    """Whether a triangular-sweep preconditioner's structure already is
    node-local (so the sharded runtime can sweep each slab independently)."""
    nbr = pc.m // pc.block
    if nbr % n_nodes:
        return False
    per = nbr // n_nodes
    return (is_slab_local(pc.lo_idx, pc.lo_n, per)
            and is_slab_local(pc.up_idx, pc.up_n, per))


def static_reload_bytes(problem, failed) -> tuple[str, int]:
    """Per-preconditioner-state survival check + safe-storage reload
    accounting for a failure of ``failed`` nodes on the sharded runtime.

    The preconditioner's *static* state carries no redundant copies of its
    own — survivability rests on it being rebuildable from the COO in safe
    storage, per class:

      * block-Jacobi — the inverted diagonal blocks of the failed rows are
        re-inverted from the reloaded A rows; accounted as the failed-slab
        block bytes.
      * SSOR / IC(0) — the node-local sweep strips are static *and*
        slab-local (the adopted twin), so the replacement rebuilds exactly
        its own slab's lo/up factors + diagonal terms; a global-sweep
        instance is rejected (its triangular strips span surviving slabs —
        the sharded runtime must adopt the twin first).
      * Chebyshev — the [lo, hi] bounds are replicated scalars; every
        survivor still holds them, nothing reloads beyond the A rows.

    Returns (description, bytes) — the reload volume charged to the event
    (``EventReport.precond_reload_bytes``); the A-row/b reload common to
    every strategy is already covered by the paper's protocol and excluded.
    """
    part = problem.part
    pc = problem.precond
    itemsize = np.dtype(problem.b.dtype).itemsize
    n_failed = len(set(failed))
    if pc is None or pc.name == "jacobi":
        blocks = (n_failed * part.rows_per_node) // problem.precond_block
        nbytes = blocks * problem.precond_block ** 2 * itemsize
        return "jacobi: reinvert failed-slab diagonal blocks", int(nbytes)
    if pc.name == "chebyshev":
        return "chebyshev: replicated [lo, hi] bounds survive", 0
    if pc.name not in ("ssor", "ic0"):
        raise NotImplementedError(pc.name)
    n_nodes = part.n_nodes
    if not precond_is_node_local(pc, n_nodes):
        raise RuntimeError(
            f"{pc.name}: global-sweep strips span failed and surviving "
            f"slabs — the sharded runtime must adopt the node-local twin "
            f"before its state can be rebuilt per-slab from safe storage")
    nbr = pc.m // pc.block
    per = nbr // n_nodes
    mask = np.zeros(nbr, bool)
    for s in set(failed):
        mask[s * per:(s + 1) * per] = True
    b2 = pc.block ** 2
    tri = int(np.asarray(pc.lo_n)[mask].sum()
              + np.asarray(pc.up_n)[mask].sum()) * b2
    diag = 2 * int(mask.sum()) * b2       # ssor: dinv+mid; ic0: dinv_f+dinv_b
    return (f"{pc.name}: rebuild failed-slab sweep strips from COO",
            int((tri + diag) * itemsize))


def node_local_twin(problem):
    """Build the node-local (additive-Schwarz) twin of ``problem``'s SSOR /
    IC(0) preconditioner from the COO in safe storage, preserving the
    builder options the instance carries. Cached per problem."""
    pc = problem.precond
    cache = getattr(problem, "_node_local_twin", None)
    if cache is not None:
        return cache
    rows, cols, vals = problem.coo
    # the partition's ownership map is the single source of the slab
    # definition — the same mask build_problem's node_local option applies
    keep = problem.part.intra_node_mask(rows, cols)
    coo = (rows[keep], cols[keep], vals[keep])
    opts = {"sweep_mode": getattr(pc, "sweep_mode", "auto")}
    if pc.name == "ssor":
        opts["omega"] = pc.omega
    twin = type(pc).build(coo=coo, m=problem.m, block=pc.block,
                          dtype=problem.b.dtype, **opts)
    problem._node_local_twin = twin
    return twin
