"""Node-local (additive-Schwarz) preconditioner variants.

The sequential SSOR/IC(0) sweeps do not partition over the "nodes" mesh
axis: every block row of the substitution may depend on rows owned by other
nodes, which would serialize the whole distributed iteration (the scaling
obstruction Levonyak et al., arXiv:1912.09230, identify for resilient PCG).
The standard fix is the *block-Jacobi / additive-Schwarz* variant: drop every
coupling between different nodes' row slabs, so the preconditioner becomes
block-diagonal over nodes and each node sweeps its own diagonal slab
independently — embarrassingly parallel over the mesh axis, at the price of
a (usually small) iteration-count increase that ``SolveReport
.local_delta_iters`` tracks.

Algebraically this is the same preconditioner *class* applied to
blockdiag(A_s) (each A_s an SPD principal submatrix of A), so everything
else — SPD-ness, the recovery-aware Alg. 2 local operators, static-state
round-trips — is inherited unchanged from the registered implementation. In
fact recovery gets *simpler*: when the failed set is a union of whole node
slabs, P_{f, I\\f} is exactly zero.
"""
from __future__ import annotations

import numpy as np


def is_slab_local(idx: np.ndarray, n: np.ndarray, nbr_per_node: int) -> bool:
    """True iff every valid ELL slot of every block row references a column
    block in the same node slab as the row (host-side static check)."""
    idx = np.asarray(idx)
    n = np.asarray(n)
    nbr, kmax = idx.shape
    valid = np.arange(kmax)[None, :] < n[:, None]
    row_slab = np.arange(nbr)[:, None] // nbr_per_node
    return bool(np.all(~valid | (idx // nbr_per_node == row_slab)))


def precond_is_node_local(pc, n_nodes: int) -> bool:
    """Whether a triangular-sweep preconditioner's structure already is
    node-local (so the sharded runtime can sweep each slab independently)."""
    nbr = pc.m // pc.block
    if nbr % n_nodes:
        return False
    per = nbr // n_nodes
    return (is_slab_local(pc.lo_idx, pc.lo_n, per)
            and is_slab_local(pc.up_idx, pc.up_n, per))


def node_local_twin(problem):
    """Build the node-local (additive-Schwarz) twin of ``problem``'s SSOR /
    IC(0) preconditioner from the COO in safe storage, preserving the
    builder options the instance carries. Cached per problem."""
    pc = problem.precond
    cache = getattr(problem, "_node_local_twin", None)
    if cache is not None:
        return cache
    rows, cols, vals = problem.coo
    # the partition's ownership map is the single source of the slab
    # definition — the same mask build_problem's node_local option applies
    keep = problem.part.intra_node_mask(rows, cols)
    coo = (rows[keep], cols[keep], vals[keep])
    opts = {"sweep_mode": getattr(pc, "sweep_mode", "auto")}
    if pc.name == "ssor":
        opts["omega"] = pc.omega
    twin = type(pc).build(coo=coo, m=problem.m, block=pc.block,
                          dtype=problem.b.dtype, **opts)
    problem._node_local_twin = twin
    return twin
