"""Host-side block-triangular structure extraction (static data).

Splits a COO matrix into its block-diagonal / strictly-block-lower /
strictly-block-upper parts at the preconditioner block granularity b, stored
ELL-style (padded per-row slot arrays) so the triangular-sweep kernels
(``repro.kernels.trisweep``) can substitute through them with static shapes.
Like the Block-ELL matrix itself, everything here is "static data in safe
storage" in the paper's sense: replacement nodes can rebuild it from the COO
after a failure.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class TriPart:
    """One strictly-triangular part in padded ELL form.

    idx:  (nbr, kmax) int32 — column-block ids, 0-padded
    n:    (nbr,) int32      — valid slots per block row
    data: (nbr, kmax, b, b) — dense block values (zero-padded)
    """

    idx: np.ndarray
    n: np.ndarray
    data: np.ndarray


def _ell_pack(br: np.ndarray, bc: np.ndarray, blocks: np.ndarray,
              nbr: int, b: int, dtype) -> TriPart:
    """Pack (block-row, block-col, value-block) triples into padded ELL.

    ``br``/``bc`` must already be unique pairs sorted by (br, bc) — the
    substitution order the sweeps assume (ascending column within a row)."""
    counts = np.bincount(br, minlength=nbr)
    kmax = max(int(counts.max()) if counts.size else 0, 1)
    idx = np.zeros((nbr, kmax), np.int32)
    data = np.zeros((nbr, kmax, b, b), dtype)
    starts = np.zeros(nbr + 1, np.int64)
    np.cumsum(counts, out=starts[1:])
    slot = np.arange(br.size) - starts[br]
    idx[br, slot] = bc.astype(np.int32)
    data[br, slot] = blocks
    return TriPart(idx=idx, n=counts.astype(np.int32), data=data)


def block_split(rows: np.ndarray, cols: np.ndarray, vals: np.ndarray,
                m: int, b: int, dtype=np.float64):
    """Split COO into (diag, lower, upper) at block granularity b.

    Returns (diag_blocks (nbr, b, b), lower: TriPart, upper: TriPart)."""
    if m % b:
        raise ValueError(f"M={m} not divisible by block {b}")
    nbr = m // b
    rows = np.asarray(rows, np.int64)
    cols = np.asarray(cols, np.int64)
    vals = np.asarray(vals, dtype)
    br, bc = rows // b, cols // b
    key = br * nbr + bc
    uniq, inv = np.unique(key, return_inverse=True)
    ubr, ubc = uniq // nbr, uniq % nbr
    blocks = np.zeros((uniq.size, b, b), dtype)
    np.add.at(blocks, (inv, rows % b, cols % b), vals)

    diag = np.zeros((nbr, b, b), dtype)
    on = ubr == ubc
    diag[ubr[on]] = blocks[on]
    lo = ubc < ubr
    up = ubc > ubr
    lower = _ell_pack(ubr[lo], ubc[lo], blocks[lo], nbr, b, dtype)
    upper = _ell_pack(ubr[up], ubc[up], blocks[up], nbr, b, dtype)
    return diag, lower, upper


def transpose_tripart(part: TriPart, nbr: int) -> TriPart:
    """ELL of Tᵀ from the ELL of T (block (i,j) -> blockᵀ at (j,i))."""
    b = part.data.shape[-1]
    br_l, bc_l, blk_l = [], [], []
    for i in range(nbr):
        for k in range(int(part.n[i])):
            br_l.append(int(part.idx[i, k]))
            bc_l.append(i)
            blk_l.append(part.data[i, k].T)
    if not br_l:
        return _ell_pack(np.empty(0, np.int64), np.empty(0, np.int64),
                         np.empty((0, b, b), part.data.dtype), nbr, b,
                         part.data.dtype)
    br = np.asarray(br_l, np.int64)
    bc = np.asarray(bc_l, np.int64)
    blk = np.stack(blk_l)
    order = np.lexsort((bc, br))
    return _ell_pack(br[order], bc[order], blk[order], nbr, b,
                     part.data.dtype)
