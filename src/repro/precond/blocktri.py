"""Host-side block-triangular structure extraction (static data).

Splits a COO matrix into its block-diagonal / strictly-block-lower /
strictly-block-upper parts at the preconditioner block granularity b, stored
ELL-style (padded per-row slot arrays) so the triangular-sweep kernels
(``repro.kernels.trisweep``) can substitute through them with static shapes.
Like the Block-ELL matrix itself, everything here is "static data in safe
storage" in the paper's sense: replacement nodes can rebuild it from the COO
after a failure.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class TriPart:
    """One strictly-triangular part in padded ELL form.

    idx:  (nbr, kmax) int32 — column-block ids, 0-padded
    n:    (nbr,) int32      — valid slots per block row
    data: (nbr, kmax, b, b) — dense block values (zero-padded)
    """

    idx: np.ndarray
    n: np.ndarray
    data: np.ndarray


def _ell_pack(br: np.ndarray, bc: np.ndarray, blocks: np.ndarray,
              nbr: int, b: int, dtype) -> TriPart:
    """Pack (block-row, block-col, value-block) triples into padded ELL.

    ``br``/``bc`` must already be unique pairs sorted by (br, bc) — the
    substitution order the sweeps assume (ascending column within a row)."""
    counts = np.bincount(br, minlength=nbr)
    kmax = max(int(counts.max()) if counts.size else 0, 1)
    idx = np.zeros((nbr, kmax), np.int32)
    data = np.zeros((nbr, kmax, b, b), dtype)
    starts = np.zeros(nbr + 1, np.int64)
    np.cumsum(counts, out=starts[1:])
    slot = np.arange(br.size) - starts[br]
    idx[br, slot] = bc.astype(np.int32)
    data[br, slot] = blocks
    return TriPart(idx=idx, n=counts.astype(np.int32), data=data)


def block_split(rows: np.ndarray, cols: np.ndarray, vals: np.ndarray,
                m: int, b: int, dtype=np.float64):
    """Split COO into (diag, lower, upper) at block granularity b.

    Returns (diag_blocks (nbr, b, b), lower: TriPart, upper: TriPart)."""
    if m % b:
        raise ValueError(f"M={m} not divisible by block {b}")
    nbr = m // b
    rows = np.asarray(rows, np.int64)
    cols = np.asarray(cols, np.int64)
    vals = np.asarray(vals, dtype)
    br, bc = rows // b, cols // b
    key = br * nbr + bc
    uniq, inv = np.unique(key, return_inverse=True)
    ubr, ubc = uniq // nbr, uniq % nbr
    blocks = np.zeros((uniq.size, b, b), dtype)
    np.add.at(blocks, (inv, rows % b, cols % b), vals)

    diag = np.zeros((nbr, b, b), dtype)
    on = ubr == ubc
    diag[ubr[on]] = blocks[on]
    lo = ubc < ubr
    up = ubc > ubr
    lower = _ell_pack(ubr[lo], ubc[lo], blocks[lo], nbr, b, dtype)
    upper = _ell_pack(ubr[up], ubc[up], blocks[up], nbr, b, dtype)
    return diag, lower, upper


@dataclasses.dataclass
class LevelSchedule:
    """Level-major (wavefront) repacking of a TriPart + its diagonal solves.

    The elimination DAG of a block-triangular sweep has block row ``i``
    depending on the rows its off-diagonal slots reference; rows on the same
    *level* (longest dependency path length) are mutually independent and can
    be processed together — one wavefront kernel grid step per level instead
    of one per row. Rows inside a level are padded to the widest level
    ``width``; padded slots point at a scratch block (row id ``nbr``) with
    zeroed ``dinv`` so they write zeros into the scratch slot of the
    (m + b)-length work vector instead of branching.

    rows:  (n_levels, width) int32 — global block-row ids (padding = nbr)
    nrows: (n_levels,) int32       — valid rows per level
    idx:   (n_levels, width, kmax) int32 — column-block ids (0-padded)
    n:     (n_levels, width) int32 — valid slots per row (0 on padding)
    data:  (n_levels, width, kmax, b, b)
    dinv:  (n_levels, width, b, b) — per-row diagonal inverse blocks
    """

    rows: np.ndarray
    nrows: np.ndarray
    idx: np.ndarray
    n: np.ndarray
    data: np.ndarray
    dinv: np.ndarray

    @property
    def n_levels(self) -> int:
        return self.rows.shape[0]

    @property
    def width(self) -> int:
        return self.rows.shape[1]


def dag_levels(idx: np.ndarray, n: np.ndarray, *, reverse: bool) -> np.ndarray:
    """Longest-path level of each block row in the elimination DAG.

    Forward sweeps depend on smaller row ids (process rows ascending),
    backward sweeps on larger ones (descending); either way
    ``level[i] = 1 + max(level[deps])`` with no-dependency rows at level 0.
    """
    nbr = idx.shape[0]
    level = np.zeros(nbr, np.int32)
    order = range(nbr - 1, -1, -1) if reverse else range(nbr)
    for i in order:
        k = int(n[i])
        if k:
            level[i] = int(level[idx[i, :k]].max()) + 1
    return level


def level_schedule(part: TriPart, dinv: np.ndarray, *,
                   reverse: bool) -> LevelSchedule:
    """Pack a TriPart + diagonal inverses into level-major wavefront form.

    Rows within a level keep the sequential sweep's processing order
    (ascending for forward, descending for backward) — irrelevant for the
    values (rows in a level are independent) but it makes the layout
    deterministic and diffable against the sequential kernel's row order.
    """
    idx = np.asarray(part.idx)
    n = np.asarray(part.n)
    data = np.asarray(part.data)
    dinv = np.asarray(dinv)
    nbr, kmax = idx.shape
    b = dinv.shape[-1]
    level = dag_levels(idx, n, reverse=reverse)
    n_levels = int(level.max()) + 1 if nbr else 1
    order = np.argsort(-level if reverse else level, kind="stable")
    if reverse:
        order = order[::-1]            # descending row id within each level
    width = max(int(np.bincount(level, minlength=1).max()), 1) if nbr else 1
    rows = np.full((n_levels, width), nbr, np.int32)      # scratch padding
    nrows = np.zeros(n_levels, np.int32)
    widx = np.zeros((n_levels, width, kmax), np.int32)
    wn = np.zeros((n_levels, width), np.int32)
    wdata = np.zeros((n_levels, width, kmax, b, b), data.dtype)
    wdinv = np.zeros((n_levels, width, b, b), dinv.dtype)
    for i in order:
        lv = level[i]
        s = nrows[lv]
        rows[lv, s] = i
        widx[lv, s] = idx[i]
        wn[lv, s] = n[i]
        wdata[lv, s] = data[i]
        wdinv[lv, s] = dinv[i]
        nrows[lv] += 1
    return LevelSchedule(rows=rows, nrows=nrows, idx=widx, n=wn, data=wdata,
                         dinv=wdinv)


def _favorable_shape(n_levels: int, width: int, nbr: int,
                     max_level_frac: float = 0.5,
                     max_pad_factor: float = 4.0) -> bool:
    if nbr == 0:
        return False
    return (n_levels <= max_level_frac * nbr
            and n_levels * width <= max_pad_factor * nbr)


def wavefront_favorable(sched: LevelSchedule, nbr: int,
                        *, max_level_frac: float = 0.5,
                        max_pad_factor: float = 4.0) -> bool:
    """Whether the wavefront layout beats the sequential sweep: the level
    count must actually shorten the grid (``n_levels <= max_level_frac·nbr``)
    and the rectangular padding must not blow the work/VMEM footprint up
    (``n_levels·width <= max_pad_factor·nbr``). Chain-structured DAGs (e.g.
    Poisson slabs at block granularity, where every block row touches its
    predecessor) fail the first test and keep the sequential kernel."""
    return _favorable_shape(sched.n_levels, sched.width, nbr,
                            max_level_frac, max_pad_factor)


def _level_shape(part: TriPart, *, reverse: bool) -> tuple[int, int]:
    """(n_levels, width) of a TriPart's elimination DAG — the favorability
    inputs, computed from the level histogram alone so rejection costs no
    padded packing (worst-case pad is O(nbr²) memory)."""
    nbr = np.asarray(part.idx).shape[0]
    if nbr == 0:
        return 1, 1
    level = dag_levels(np.asarray(part.idx), np.asarray(part.n),
                       reverse=reverse)
    counts = np.bincount(level)
    return counts.size, max(int(counts.max()), 1)


def wavefront_pair(lo: TriPart, up: TriPart, dinv_lo: np.ndarray,
                   dinv_up: np.ndarray, nbr: int, mode: str = "auto"):
    """Build the (forward, backward) device wavefront bundles for a
    symmetric-sweep preconditioner, or (None, None) when the elimination
    DAGs don't warrant the level-scheduled kernels.

    mode: "auto" (use wavefront iff both DAGs pass ``wavefront_favorable``)
    | "wavefront" (force) | "sequential" (never)."""
    if mode == "sequential":
        return None, None
    if mode not in ("auto", "wavefront"):
        raise ValueError(f"sweep_mode must be auto|wavefront|sequential, "
                         f"got {mode!r}")
    if mode != "wavefront":
        # gate on the level histogram alone — packing an unfavorable DAG
        # would transiently allocate up to O(nbr²) padded blocks
        if not all(_favorable_shape(*_level_shape(part, reverse=rev), nbr)
                   for part, rev in ((lo, False), (up, True))):
            return None, None
    lo_s = level_schedule(lo, dinv_lo, reverse=False)
    up_s = level_schedule(up, dinv_up, reverse=True)
    from repro.kernels.trisweep.ops import wavefront_from_schedule
    return wavefront_from_schedule(lo_s), wavefront_from_schedule(up_s)


def transpose_tripart(part: TriPart, nbr: int) -> TriPart:
    """ELL of Tᵀ from the ELL of T (block (i,j) -> blockᵀ at (j,i))."""
    b = part.data.shape[-1]
    br_l, bc_l, blk_l = [], [], []
    for i in range(nbr):
        for k in range(int(part.n[i])):
            br_l.append(int(part.idx[i, k]))
            bc_l.append(i)
            blk_l.append(part.data[i, k].T)
    if not br_l:
        return _ell_pack(np.empty(0, np.int64), np.empty(0, np.int64),
                         np.empty((0, b, b), part.data.dtype), nbr, b,
                         part.data.dtype)
    br = np.asarray(br_l, np.int64)
    bc = np.asarray(bc_l, np.int64)
    blk = np.stack(blk_l)
    order = np.lexsort((bc, br))
    return _ell_pack(br[order], bc[order], blk[order], nbr, b,
                     part.data.dtype)
