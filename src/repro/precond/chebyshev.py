"""Chebyshev polynomial preconditioner — matrix-free z = p_d(A) r.

The degree-d Chebyshev semi-iteration polynomial approximating A⁻¹ on
[lo, hi], with the eigenvalue bounds estimated host-side from Gershgorin
discs: hi = max_i Σ_j |a_ij| (always a true upper bound for symmetric A);
lo = max(disc lower bound, hi / eig_ratio) — the floor caps the targeted
condition span at eig_ratio like the standard smoothed-aggregation practice
(a tiny-but-positive disc bound would waste the whole polynomial on the
spectrum's bottom edge). Clamping only *shrinks* the target interval, and
λ p_d(λ) > 0 for every λ ∈ (0, hi] regardless, so the operator stays SPD.

No triangular structure, no setup beyond two scalars: each apply is d
Block-ELL SpMVs (the paper's hot-spot kernel), which makes it the natural
choice when SpMV throughput dwarfs everything else. P = p_d(A) has dense
off-diagonal coupling, so recovery uses the generic matrix-free Alg. 2 path
(each inner-CG operator application runs the polynomial recurrence).
"""
from __future__ import annotations

import numpy as np

from repro.precond.base import Preconditioner, register


def gershgorin_bounds(rows, cols, vals, m: int) -> tuple[float, float]:
    """(lo, hi) eigenvalue bounds from Gershgorin discs (host-side).

    lo may be ≤ 0 for non-diagonally-dominant SPD matrices — callers clamp."""
    rows = np.asarray(rows)
    vals = np.asarray(vals)
    absrow = np.zeros(m)
    np.add.at(absrow, rows, np.abs(vals))
    diag = np.zeros(m)
    on = rows == np.asarray(cols)
    np.add.at(diag, rows[on], vals[on])
    # disc centre a_ii, radius Σ_{j≠i}|a_ij| = absrow − |a_ii| = absrow − a_ii
    return float((2.0 * diag - absrow).min()), float(absrow.max())


def lanczos_ritz_bounds(coo, m: int, iters: int = 8,
                        seed: int = 0) -> tuple[float, float]:
    """(θ_min, θ_max) Ritz values from a few host-side Lanczos iterations
    with full reorthogonalization (cheap at ``iters`` ≤ ~16).

    For symmetric A the Ritz values always lie inside [λ_min, λ_max], with
    the extremes converging outward fastest — so θ_min is a principled
    *inner* estimate of λ_min that tightens the Chebyshev target interval
    on easy spectra where the Gershgorin disc bound degenerates to ≤ 0
    (the lo = hi/eig_ratio clamp wasted polynomial degree there)."""
    rows, cols, vals = (np.asarray(a) for a in coo)

    def mv(x):
        y = np.zeros(m)
        np.add.at(y, rows, vals * x[cols])
        return y

    rng = np.random.default_rng(seed)
    q = rng.standard_normal(m)
    q /= np.linalg.norm(q)
    basis = [q]
    alphas: list[float] = []
    betas: list[float] = []
    q_prev = np.zeros(m)
    beta = 0.0
    for _ in range(max(iters, 1)):
        w = mv(q) - beta * q_prev
        alpha = float(q @ w)
        alphas.append(alpha)
        w -= alpha * q
        for qq in basis:                 # full reorthogonalization
            w -= (qq @ w) * qq
        beta = float(np.linalg.norm(w))
        if beta < 1e-12 * max(abs(alpha), 1.0):
            break                        # invariant subspace: T is exact
        betas.append(beta)
        q_prev, q = q, w / beta
        basis.append(q)
    k = len(alphas)
    t = np.diag(alphas)
    if k > 1:
        off = np.asarray(betas[:k - 1])
        t += np.diag(off, 1) + np.diag(off, -1)
    ev = np.linalg.eigvalsh(t)
    return float(ev[0]), float(ev[-1])


def auto_degree(lo: float, hi: float, target: float = 0.05,
                max_degree: int = 16) -> int:
    """Smallest degree whose Chebyshev damping 2/T_d(σ) on [lo, hi] drops
    below ``target`` (σ = (hi+lo)/(hi−lo)); tight bounds ⇒ large σ ⇒ small
    degree — the "cut the polynomial degree on easy spectra" payoff."""
    sigma = (hi + lo) / (hi - lo) if hi > lo else float("inf")
    if not np.isfinite(sigma):
        return 1
    d = np.arccosh(2.0 / target) / np.arccosh(sigma)
    return int(min(max(np.ceil(d), 1), max_degree))


@register("chebyshev")
class Chebyshev(Preconditioner):
    def __init__(self, a, lo: float, hi: float, degree: int, block: int,
                 m: int, dtype):
        self.a = a                      # BlockEll (the problem matrix)
        self.lo = lo
        self.hi = hi
        self.degree = degree
        self.block = block
        self.m = m
        self._dtype = dtype

    @classmethod
    def build(cls, *, coo, m, block, dtype, a=None, degree: int | str = 4,
              eig_ratio: float = 30.0, lanczos_iters: int = 8,
              auto_target: float = 0.05, **_):
        """``lanczos_iters`` > 0 (default 8) tightens ``lo`` with the
        Lanczos Ritz estimate θ_min (relaxed by 0.9); the Gershgorin disc
        bound and the ``hi/eig_ratio`` floor remain as fallbacks, so the
        interval only ever *shrinks* relative to the old clamp (the SPD
        argument is unchanged: λ p_d(λ) > 0 on (0, hi] regardless).
        Gershgorin keeps supplying ``hi`` — a guaranteed upper bound,
        which a Ritz estimate is not. ``degree="auto"`` picks the smallest
        degree reaching ``auto_target`` damping on [lo, hi]."""
        if a is None:
            raise ValueError("Chebyshev needs the Block-ELL matrix (a=...)")
        if degree != "auto" and (isinstance(degree, str) or degree < 1):
            raise ValueError(
                f"degree must be a positive int or 'auto', got {degree!r}")
        rows, cols, vals = coo
        lo_g, hi = gershgorin_bounds(rows, cols, vals, m)
        lo = max(lo_g, hi / eig_ratio)
        if lanczos_iters:
            ritz_lo, _ = lanczos_ritz_bounds(coo, m, lanczos_iters)
            lo = max(lo, 0.9 * ritz_lo)
        if degree == "auto":
            degree = auto_degree(lo, hi, auto_target)
        return cls(a, lo, hi, degree, block, m, dtype)

    def _make_apply(self, backend: str):
        from repro.kernels.chebyshev.ops import chebyshev_precond_apply

        data, idx = self.a.data, self.a.idx
        lo, hi, deg = self.lo, self.hi, self.degree
        return lambda r: chebyshev_precond_apply(data, idx, r, lo=lo, hi=hi,
                                                 degree=deg, backend=backend)

    def _pff_inner_precond(self, mask, f_rows):
        """B = A_ff (one Block-ELL SpMV restricted to the failed rows):
        p_d(A) ≈ A⁻¹ on [lo, hi], so A_ff is the natural SPD approximation
        of P_ff⁻¹ — the Chebyshev analogue of the truncated-operator inner
        preconditioners."""
        import jax.numpy as jnp

        fr = jnp.asarray(np.asarray(f_rows))
        zeros = jnp.zeros((self.m,), self._dtype)
        a = self.a

        def inner(u):
            return a.matvec(zeros.at[fr].set(u))[fr]

        return inner

    def static_state(self) -> dict:
        # A itself is the problem's static data (safe storage); only the
        # spectral bounds and the degree are preconditioner state.
        return {"lo": self.lo, "hi": self.hi, "degree": self.degree,
                "block": self.block}

    @classmethod
    def from_static(cls, state, *, m: int, dtype, a=None, **_):
        if a is None:
            raise ValueError("Chebyshev.from_static needs the matrix (a=...)")
        return cls(a, float(state["lo"]), float(state["hi"]),
                   int(state["degree"]), int(state["block"]), m, dtype)
