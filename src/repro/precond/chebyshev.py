"""Chebyshev polynomial preconditioner — matrix-free z = p_d(A) r.

The degree-d Chebyshev semi-iteration polynomial approximating A⁻¹ on
[lo, hi], with the eigenvalue bounds estimated host-side from Gershgorin
discs: hi = max_i Σ_j |a_ij| (always a true upper bound for symmetric A);
lo = max(disc lower bound, hi / eig_ratio) — the floor caps the targeted
condition span at eig_ratio like the standard smoothed-aggregation practice
(a tiny-but-positive disc bound would waste the whole polynomial on the
spectrum's bottom edge). Clamping only *shrinks* the target interval, and
λ p_d(λ) > 0 for every λ ∈ (0, hi] regardless, so the operator stays SPD.

No triangular structure, no setup beyond two scalars: each apply is d
Block-ELL SpMVs (the paper's hot-spot kernel), which makes it the natural
choice when SpMV throughput dwarfs everything else. P = p_d(A) has dense
off-diagonal coupling, so recovery uses the generic matrix-free Alg. 2 path
(each inner-CG operator application runs the polynomial recurrence).
"""
from __future__ import annotations

import numpy as np

from repro.precond.base import Preconditioner, register


def gershgorin_bounds(rows, cols, vals, m: int) -> tuple[float, float]:
    """(lo, hi) eigenvalue bounds from Gershgorin discs (host-side).

    lo may be ≤ 0 for non-diagonally-dominant SPD matrices — callers clamp."""
    rows = np.asarray(rows)
    vals = np.asarray(vals)
    absrow = np.zeros(m)
    np.add.at(absrow, rows, np.abs(vals))
    diag = np.zeros(m)
    on = rows == np.asarray(cols)
    np.add.at(diag, rows[on], vals[on])
    # disc centre a_ii, radius Σ_{j≠i}|a_ij| = absrow − |a_ii| = absrow − a_ii
    return float((2.0 * diag - absrow).min()), float(absrow.max())


@register("chebyshev")
class Chebyshev(Preconditioner):
    def __init__(self, a, lo: float, hi: float, degree: int, block: int,
                 m: int, dtype):
        self.a = a                      # BlockEll (the problem matrix)
        self.lo = lo
        self.hi = hi
        self.degree = degree
        self.block = block
        self.m = m
        self._dtype = dtype

    @classmethod
    def build(cls, *, coo, m, block, dtype, a=None, degree: int = 4,
              eig_ratio: float = 30.0, **_):
        if a is None:
            raise ValueError("Chebyshev needs the Block-ELL matrix (a=...)")
        if degree < 1:
            raise ValueError(f"degree must be >= 1, got {degree}")
        rows, cols, vals = coo
        lo_g, hi = gershgorin_bounds(rows, cols, vals, m)
        lo = max(lo_g, hi / eig_ratio)
        return cls(a, lo, hi, degree, block, m, dtype)

    def _make_apply(self, backend: str):
        from repro.kernels.chebyshev.ops import chebyshev_precond_apply

        data, idx = self.a.data, self.a.idx
        lo, hi, deg = self.lo, self.hi, self.degree
        return lambda r: chebyshev_precond_apply(data, idx, r, lo=lo, hi=hi,
                                                 degree=deg, backend=backend)

    def static_state(self) -> dict:
        # A itself is the problem's static data (safe storage); only the
        # spectral bounds and the degree are preconditioner state.
        return {"lo": self.lo, "hi": self.hi, "degree": self.degree,
                "block": self.block}

    @classmethod
    def from_static(cls, state, *, m: int, dtype, a=None, **_):
        if a is None:
            raise ValueError("Chebyshev.from_static needs the matrix (a=...)")
        return cls(a, float(state["lo"]), float(state["hi"]),
                   int(state["degree"]), int(state["block"]), m, dtype)
