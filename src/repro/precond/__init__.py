"""Pluggable preconditioner subsystem (registry + four implementations).

Importing this package registers: jacobi, ssor, chebyshev, ic0. See
``repro.precond.base`` for the protocol (hot-loop apply per SolverOps
backend, recovery-aware Alg. 2 local operators, serializable static data).
"""
from repro.precond.base import Preconditioner, available, build, register
from repro.precond import chebyshev, ic0, jacobi, ssor  # noqa: F401 (register)
from repro.precond.chebyshev import Chebyshev
from repro.precond.ic0 import IC0
from repro.precond.jacobi import BlockJacobi
from repro.precond.ssor import SSOR

__all__ = ["Preconditioner", "available", "build", "register",
           "BlockJacobi", "SSOR", "Chebyshev", "IC0"]
