"""SSOR / symmetric block Gauss-Seidel preconditioner.

  M = (1/(ω(2−ω))) (D + ωL) D⁻¹ (D + ωU),   z = M⁻¹ r

with D = blockdiag(A_bb), L/U = strictly-block-lower/-upper parts of A at
the preconditioner block granularity, ω ∈ (0, 2) (ω = 1 → symmetric block
Gauss-Seidel). SPD for SPD A. Unlike block-Jacobi this couples across node
boundaries — P = M⁻¹ has genuine off-diagonal structure, so Alg. 2
reconstruction runs the generic recovery-aware path (masked full apply for
line 5, inner CG over the sweeps for line 6) inherited from the base class.

Static data: the ω-scaled triangular block strips, D blocks and their
Cholesky inverses — all rebuildable from the COO in safe storage.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.precond.base import Preconditioner, register
from repro.precond.blocktri import TriPart, block_split, wavefront_pair
from repro.precond.jacobi import invert_blocks


@register("ssor")
class SSOR(Preconditioner):
    def __init__(self, lo_idx, lo_n, lo_data, up_idx, up_n, up_data, dinv,
                 mid_blocks, block: int, m: int, dtype, omega: float,
                 sweep_mode: str = "auto"):
        # level schedules are derived host-side from the triangular
        # structure before the device upload; "auto" keeps the sequential
        # kernels on chain-structured DAGs (see blocktri.wavefront_favorable)
        self.sweep_mode = sweep_mode
        self.lo_wf, self.up_wf = wavefront_pair(
            TriPart(np.asarray(lo_idx), np.asarray(lo_n),
                    np.asarray(lo_data)),
            TriPart(np.asarray(up_idx), np.asarray(up_n),
                    np.asarray(up_data)),
            np.asarray(dinv), np.asarray(dinv), m // block, sweep_mode)
        self.lo_idx = jnp.asarray(lo_idx)
        self.lo_n = jnp.asarray(lo_n)
        self.lo_data = jnp.asarray(lo_data)
        self.up_idx = jnp.asarray(up_idx)
        self.up_n = jnp.asarray(up_n)
        self.up_data = jnp.asarray(up_data)
        self.dinv = jnp.asarray(dinv)
        self.mid_blocks = jnp.asarray(mid_blocks)
        self.block = block
        self.m = m
        self._dtype = dtype
        self.omega = omega

    @classmethod
    def build(cls, *, coo, m, block, dtype, omega: float = 1.0,
              pinv_blocks=None, sweep_mode: str = "auto", **_):
        if not 0.0 < omega < 2.0:
            raise ValueError(f"SSOR needs omega in (0, 2), got {omega}")
        rows, cols, vals = coo
        diag, lower, upper = block_split(rows, cols, vals, m, block, dtype)
        dinv = (np.asarray(pinv_blocks) if pinv_blocks is not None
                else invert_blocks(diag))
        return cls(lower.idx, lower.n, omega * lower.data,
                   upper.idx, upper.n, omega * upper.data,
                   dinv, (omega * (2.0 - omega)) * diag,
                   block, m, dtype, omega, sweep_mode)

    def _make_apply(self, backend: str):
        from repro.core.ops import pick_rows
        from repro.kernels.ssor.ops import ssor_precond_apply

        rows = pick_rows(self.m, self.block)
        args = (self.lo_idx, self.lo_n, self.lo_data, self.up_idx, self.up_n,
                self.up_data, self.dinv, self.mid_blocks)
        # the wavefront shortens the sequential *kernel grid* (one step per
        # DAG level); the jnp reference runs its rows serially either way,
        # so it keeps the unpadded sequential sweep unless explicitly forced
        # — bit-identity between the routes is a tested invariant, so mixed
        # routing cannot fork the backends' trajectories
        wf = backend != "jnp" or self.sweep_mode == "wavefront"
        lo_wf = self.lo_wf if wf else None
        up_wf = self.up_wf if wf else None
        return lambda r: ssor_precond_apply(*args, r, backend=backend,
                                            rows=rows, lo_wf=lo_wf,
                                            up_wf=up_wf)

    def _pff_inner_precond(self, mask, f_rows):
        """Failed-slab-truncated SSOR matrix: B = M_ff with
        M = (1/(ω(2−ω))) (D + ωL) D⁻¹ (D + ωU).

        P_ff = (M⁻¹)_ff, whose inverse M_ff approximates up to the slab's
        off-diagonal coupling, and M_ff is an SPD principal submatrix of M
        — so CG on P_ff preconditioned with B converges in a handful of
        iterations instead of O(√cond(P_ff)). Each B apply is two
        triangular *matvecs* plus three block-diagonal einsums (no
        substitution sweeps)."""
        from repro.precond.base import tripart_matvec

        fr = jnp.asarray(np.asarray(f_rows))
        zeros = jnp.zeros((self.m,), self.dtype)
        b = self.block
        inv_s = 1.0 / (self.omega * (2.0 - self.omega))
        lo_idx, lo_data = self.lo_idx, self.lo_data
        up_idx, up_data = self.up_idx, self.up_data
        mid, dinv = self.mid_blocks, self.dinv

        def dmat(v):                                  # D v (mid = ω(2−ω) D)
            return inv_s * jnp.einsum("nij,nj->ni", mid,
                                      v.reshape(-1, b)).reshape(-1)

        def inner(u):
            v = zeros.at[fr].set(u)
            t = dmat(v) + tripart_matvec(up_idx, up_data, v, b)
            s = jnp.einsum("nij,nj->ni", dinv,
                           t.reshape(-1, b)).reshape(-1)
            mv = inv_s * (dmat(s) + tripart_matvec(lo_idx, lo_data, s, b))
            return mv[fr]

        return inner

    def static_state(self) -> dict:
        return {"lo_idx": np.asarray(self.lo_idx),
                "lo_n": np.asarray(self.lo_n),
                "lo_data": np.asarray(self.lo_data),
                "up_idx": np.asarray(self.up_idx),
                "up_n": np.asarray(self.up_n),
                "up_data": np.asarray(self.up_data),
                "dinv": np.asarray(self.dinv),
                "mid_blocks": np.asarray(self.mid_blocks),
                "block": self.block, "omega": self.omega,
                "sweep_mode": self.sweep_mode}

    @classmethod
    def from_static(cls, state, *, m: int, dtype, **_):
        return cls(state["lo_idx"], state["lo_n"], state["lo_data"],
                   state["up_idx"], state["up_n"], state["up_data"],
                   state["dinv"], state["mid_blocks"], int(state["block"]),
                   m, dtype, float(state["omega"]),
                   str(state.get("sweep_mode", "auto")))
