"""SSOR / symmetric block Gauss-Seidel preconditioner.

  M = (1/(ω(2−ω))) (D + ωL) D⁻¹ (D + ωU),   z = M⁻¹ r

with D = blockdiag(A_bb), L/U = strictly-block-lower/-upper parts of A at
the preconditioner block granularity, ω ∈ (0, 2) (ω = 1 → symmetric block
Gauss-Seidel). SPD for SPD A. Unlike block-Jacobi this couples across node
boundaries — P = M⁻¹ has genuine off-diagonal structure, so Alg. 2
reconstruction runs the generic recovery-aware path (masked full apply for
line 5, inner CG over the sweeps for line 6) inherited from the base class.

Static data: the ω-scaled triangular block strips, D blocks and their
Cholesky inverses — all rebuildable from the COO in safe storage.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.precond.base import Preconditioner, register
from repro.precond.blocktri import block_split
from repro.precond.jacobi import invert_blocks


@register("ssor")
class SSOR(Preconditioner):
    def __init__(self, lo_idx, lo_n, lo_data, up_idx, up_n, up_data, dinv,
                 mid_blocks, block: int, m: int, dtype, omega: float):
        self.lo_idx = jnp.asarray(lo_idx)
        self.lo_n = jnp.asarray(lo_n)
        self.lo_data = jnp.asarray(lo_data)
        self.up_idx = jnp.asarray(up_idx)
        self.up_n = jnp.asarray(up_n)
        self.up_data = jnp.asarray(up_data)
        self.dinv = jnp.asarray(dinv)
        self.mid_blocks = jnp.asarray(mid_blocks)
        self.block = block
        self.m = m
        self._dtype = dtype
        self.omega = omega

    @classmethod
    def build(cls, *, coo, m, block, dtype, omega: float = 1.0,
              pinv_blocks=None, **_):
        if not 0.0 < omega < 2.0:
            raise ValueError(f"SSOR needs omega in (0, 2), got {omega}")
        rows, cols, vals = coo
        diag, lower, upper = block_split(rows, cols, vals, m, block, dtype)
        dinv = (np.asarray(pinv_blocks) if pinv_blocks is not None
                else invert_blocks(diag))
        return cls(lower.idx, lower.n, omega * lower.data,
                   upper.idx, upper.n, omega * upper.data,
                   dinv, (omega * (2.0 - omega)) * diag,
                   block, m, dtype, omega)

    def _make_apply(self, backend: str):
        from repro.core.ops import pick_rows
        from repro.kernels.ssor.ops import ssor_precond_apply

        rows = pick_rows(self.m, self.block)
        args = (self.lo_idx, self.lo_n, self.lo_data, self.up_idx, self.up_n,
                self.up_data, self.dinv, self.mid_blocks)
        return lambda r: ssor_precond_apply(*args, r, backend=backend,
                                            rows=rows)

    def static_state(self) -> dict:
        return {"lo_idx": np.asarray(self.lo_idx),
                "lo_n": np.asarray(self.lo_n),
                "lo_data": np.asarray(self.lo_data),
                "up_idx": np.asarray(self.up_idx),
                "up_n": np.asarray(self.up_n),
                "up_data": np.asarray(self.up_data),
                "dinv": np.asarray(self.dinv),
                "mid_blocks": np.asarray(self.mid_blocks),
                "block": self.block, "omega": self.omega}

    @classmethod
    def from_static(cls, state, *, m: int, dtype, **_):
        return cls(state["lo_idx"], state["lo_n"], state["lo_data"],
                   state["up_idx"], state["up_n"], state["up_data"],
                   state["dinv"], state["mid_blocks"], int(state["block"]),
                   m, dtype, float(state["omega"]))
