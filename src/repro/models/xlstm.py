"""xLSTM blocks: mLSTM (matrix memory, parallelizable) and sLSTM (scalar
memory with recurrent mixing) — [arXiv:2405.04517].

The mLSTM recurrence is run as a ``lax.scan`` over time with exponential-gate
stabilization in log space (states C (B,H,P,P), n (B,H,P), m (B,H)). sLSTM is
inherently sequential (recurrent R h_{t-1} term — that is its point) and also
scans. Decode is the same cell applied once — O(1) state, which is why
xlstm-125m runs the long_500k cell. A chunked-parallel mLSTM formulation is a
§Perf hillclimb candidate (see EXPERIMENTS.md).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.models.layers import dense_init, norm_init, apply_norm
from repro.models.sharding import constrain
from repro.models.ssm import _causal_conv


def xlstm_dims(cfg):
    di = 2 * cfg.d_model                 # mLSTM expansion factor 2
    h = cfg.n_heads
    return di, h, di // h


# --------------------------------------------------------------------------- #
# mLSTM
# --------------------------------------------------------------------------- #
def mlstm_init(cfg, key, dtype):
    d = cfg.d_model
    di, h, p_ = xlstm_dims(cfg)
    ks = jax.random.split(key, 7)
    params = {
        "w_up": dense_init(ks[0], (d, 2 * di), dtype),       # x, z-gate
        "conv_w": (jax.random.normal(ks[1], (cfg.conv_width, di)) * 0.1
                   ).astype(dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "w_qkv": dense_init(ks[2], (di, 3 * di), dtype),
        "w_if": dense_init(ks[3], (di, 2 * h), dtype, scale=0.02),
        "b_if": jnp.concatenate([jnp.zeros((h,)), 3.0 * jnp.ones((h,))]
                                ).astype(jnp.float32),
        "gn_scale": jnp.ones((di,), dtype),
        "w_down": dense_init(ks[4], (di, d), dtype, scale=1.0 / np.sqrt(di)),
    }
    specs = {"w_up": P("fsdp", "tp"), "conv_w": P(None, "tp"),
             "conv_b": P("tp"), "w_qkv": P("fsdp", "tp"),
             "w_if": P("fsdp", None), "b_if": P(None),
             "gn_scale": P("tp"), "w_down": P("tp", "fsdp")}
    return params, specs


def _mlstm_cell(carry, inp):
    """One stabilized mLSTM step. carry: (C,n,m); inp: (q,k,v,it,ft)."""
    C, n, m = carry
    q, k, v, it, ft = inp                       # (B,H,P),(B,H,P),(B,H,P),(B,H)
    m_new = jnp.maximum(ft + m, it)
    i_p = jnp.exp(it - m_new)[..., None]
    f_p = jnp.exp(ft + m - m_new)[..., None]
    C = f_p[..., None] * C + i_p[..., None] * (v[..., :, None] * k[..., None, :])
    n = f_p * n + i_p * k
    num = jnp.einsum("bhpq,bhq->bhp", C, q)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhp,bhp->bh", n, q)), 1.0)
    return (C, n, m_new), num / den[..., None]


def mlstm_seq(q, k, v, it, ft, state=None):
    """q,k,v: (B,S,H,P); it,ft: (B,S,H) fp32. Returns (y, final_state)."""
    b, s, h, p_ = q.shape
    if state is None:
        C = jnp.zeros((b, h, p_, p_), jnp.float32)
        n = jnp.zeros((b, h, p_), jnp.float32)
        m = jnp.full((b, h), -1e30, jnp.float32)
    else:
        C, n, m = state["C"], state["n"], state["m"]
    cst = lambda t: constrain(t, *((None, "dp") + (None,) * (t.ndim - 2)))
    xs = (cst(jnp.moveaxis(q, 1, 0).astype(jnp.float32)),
          cst(jnp.moveaxis(k, 1, 0).astype(jnp.float32)),
          cst(jnp.moveaxis(v, 1, 0).astype(jnp.float32)),
          cst(jnp.moveaxis(it, 1, 0)), cst(jnp.moveaxis(ft, 1, 0)))
    (C, n, m), ys = jax.lax.scan(_mlstm_cell, (C, n, m), xs)
    return jnp.moveaxis(ys, 0, 1), {"C": C, "n": n, "m": m}


def mlstm_chunked(q, k, v, it, ft, state=None, chunk: int = 64):
    """Exact stabilized chunkwise mLSTM (beyond-paper optimization; §Perf
    iteration xlstm-1).

    Identical numerics to ``mlstm_seq`` (tested allclose): with per-chunk
    in-chunk log-decay b_t = cumsum(ft) and a_j = i_j - b_j, the recurrent
    stabilizer unrolls to m_t = b_t + M_t, M_t = max(m_prev, cummax_{j<=t}
    a_j), so every intra-chunk weight exp(a_j - M_t) and carry-in weight
    exp(m_prev - M_t) is <= 1 — the sequential max recurrence becomes a
    cummax and the time scan collapses from S steps of (P x P) outer products
    to S/Q steps of (Q x Q)/(Q x P) MXU matmuls. This removes the per-step
    collectives that made xlstm train/prefill cells ~1000x collective-bound
    in the baseline dry-run.
    """
    bsz, s, h, p_ = q.shape
    nc = s // chunk
    f32 = jnp.float32
    # scan inputs must NOT be sharded on the chunk (time) dim: a dynamic
    # slice over a sharded loop dim makes GSPMD re-gather the whole array
    # every iteration (measured: the baseline's per-step all-gathers).
    # Batch shards over dp; the model axis stays out of the recurrence.
    cst = lambda t: constrain(t, *(("dp",) + (None,) * (t.ndim - 1)))
    qc = cst(q.astype(f32).reshape(bsz, nc, chunk, h, p_))
    kc = cst(k.astype(f32).reshape(bsz, nc, chunk, h, p_))
    vc = cst(v.astype(f32).reshape(bsz, nc, chunk, h, p_))
    bcum = cst(jnp.cumsum(ft.reshape(bsz, nc, chunk, h), axis=2))
    a = cst(it.reshape(bsz, nc, chunk, h) - bcum)              # (B,nc,Q,H)

    if state is None:
        C0 = jnp.zeros((bsz, h, p_, p_), f32)
        n0 = jnp.zeros((bsz, h, p_), f32)
        m0 = jnp.full((bsz, h), -1e30, f32)
    else:
        C0, n0, m0 = state["C"], state["n"], state["m"]

    tri = jnp.tril(jnp.ones((chunk, chunk), bool))

    def chunk_step(carry, inp):
        C, n, m = carry
        q_c, k_c, v_c, a_c, b_c = inp          # (B,Q,H,P)/(B,Q,H)
        M = jnp.maximum(jax.lax.cummax(a_c, axis=1), m[:, None, :])  # (B,Q,H)
        w_intra = jnp.exp(a_c[:, None, :, :] - M[:, :, None, :])     # (B,t,j,H)
        w_intra = jnp.where(tri[None, :, :, None], w_intra, 0.0)
        qk = jnp.einsum("bqhp,bjhp->bqjh", q_c, k_c)
        scores = qk * w_intra
        num = jnp.einsum("bqjh,bjhp->bqhp", scores, v_c)
        w_in = jnp.exp(m[:, None, :] - M)                            # (B,Q,H)
        num = num + w_in[..., None] * jnp.einsum("bhpr,bqhr->bqhp", C, q_c)
        nvec = (jnp.einsum("bqjh,bjhp->bqhp", w_intra, k_c)
                + w_in[..., None] * n[:, None])
        den = jnp.maximum(
            jnp.abs(jnp.einsum("bqhp,bqhp->bqh", nvec, q_c)), 1.0)
        h_out = num / den[..., None]
        # carry to chunk end (exact recurrent state at t = Q-1)
        m_last = M[:, -1]                                            # (B,H)
        w_k = jnp.exp(a_c - m_last[:, None, :])                      # (B,Q,H)
        decay = jnp.exp(m - m_last)
        C_new = (decay[..., None, None] * C
                 + jnp.einsum("bjh,bjhp,bjhq->bhpq", w_k, v_c, k_c))
        n_new = decay[..., None] * n + jnp.einsum("bjh,bjhp->bhp", w_k, k_c)
        m_new = b_c[:, -1] + m_last
        return (C_new, n_new, m_new), h_out

    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (qc, kc, vc, a, bcum))
    (C, n, m), ys = jax.lax.scan(chunk_step, (C0, n0, m0), xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(bsz, s, h, p_)
    return y, {"C": C, "n": n, "m": m}


def mlstm_block(p, x, cfg, *, cache=None):
    b, s, d = x.shape
    di, h, pd = xlstm_dims(cfg)
    cdt = x.dtype
    up = x @ p["w_up"].astype(cdt)
    xr, z = jnp.split(up, 2, axis=-1)
    conv_state = None if cache is None else cache["conv"]
    xc, new_conv = _causal_conv(xr, p["conv_w"].astype(cdt),
                                p["conv_b"].astype(cdt), conv_state)
    xc = jax.nn.silu(xc)
    qkv = xc @ p["w_qkv"].astype(cdt)
    q, k, v = [t.reshape(b, s, h, pd) for t in jnp.split(qkv, 3, -1)]
    k = k / np.sqrt(pd)
    gates = (xc @ p["w_if"].astype(cdt)).astype(jnp.float32) + p["b_if"]
    it, ft = jnp.split(gates, 2, -1)            # (B,S,H) pre-activations
    ft = jax.nn.log_sigmoid(ft)                 # log f-gate (≤0, stable)
    state = None if cache is None else cache
    if s > 1:
        chunk = s
        for cand in (64, 32, 16, 8, 4, 2, 1):
            if s % cand == 0:
                chunk = cand
                break
        y, new_state = mlstm_chunked(q, k, v, it, ft, state, chunk=chunk)
    else:
        y, new_state = mlstm_seq(q, k, v, it, ft, state)
    y = y.reshape(b, s, di).astype(cdt)
    yf = y.astype(jnp.float32)
    y = (yf * jax.lax.rsqrt(jnp.mean(yf ** 2, -1, keepdims=True) + 1e-6)
         * p["gn_scale"].astype(jnp.float32)).astype(cdt)
    y = y * jax.nn.silu(z)
    out = y @ p["w_down"].astype(cdt)
    new_cache = dict(new_state, conv=new_conv)
    return constrain(out, "dp", None, None), new_cache


# --------------------------------------------------------------------------- #
# sLSTM
# --------------------------------------------------------------------------- #
def slstm_init(cfg, key, dtype):
    d = cfg.d_model
    h = cfg.n_heads
    pd = d // h
    ks = jax.random.split(key, 5)
    params = {
        "conv_w": (jax.random.normal(ks[0], (cfg.conv_width, d)) * 0.1
                   ).astype(dtype),
        "conv_b": jnp.zeros((d,), dtype),
        "w_gates": dense_init(ks[1], (d, 4 * d), dtype),      # z,i,f,o
        "r_gates": (jax.random.normal(ks[2], (h, pd, 4 * pd)) /
                    np.sqrt(pd)).astype(dtype),               # block-diag R
                    # (replicated: it lives inside the per-step recurrence)
        "b_gates": jnp.zeros((4 * d,), jnp.float32),
        "gn_scale": jnp.ones((d,), dtype),
        "w_ff_up": dense_init(ks[3], (d, 2 * (4 * d // 3)), dtype),
        "w_ff_dn": dense_init(ks[4], (4 * d // 3, d), dtype,
                              scale=1.0 / np.sqrt(4 * d // 3)),
    }
    specs = {"conv_w": P(None, "tp"), "conv_b": P("tp"),
             "w_gates": P("fsdp", "tp"), "r_gates": P(None, None, None),
             "b_gates": P(None), "gn_scale": P("tp"),
             "w_ff_up": P("fsdp", "tp"), "w_ff_dn": P("tp", "fsdp")}
    return params, specs


def _slstm_cell(p_r, carry, wx):
    """carry: (c,n,m,hprev) each (B,H,P)[m,n scalar-per-unit]; wx: (B,4*d)."""
    c, n, m, hp = carry
    b = hp.shape[0]
    h_, pd = p_r.shape[0], p_r.shape[1]
    rec = jnp.einsum("bhp,hpq->bhq", hp, p_r)        # (B,H,4P)
    gates = wx.reshape(b, h_, 4 * pd) + rec
    zt, it, ft, ot = jnp.split(gates, 4, -1)          # (B,H,P)
    m_new = jnp.maximum(jax.nn.log_sigmoid(ft) + m, it)
    i_p = jnp.exp(it - m_new)
    f_p = jnp.exp(jax.nn.log_sigmoid(ft) + m - m_new)
    c = f_p * c + i_p * jnp.tanh(zt)
    n = f_p * n + i_p
    hnew = jax.nn.sigmoid(ot) * c / jnp.maximum(n, 1.0)
    return (c, n, m_new, hnew), hnew


def slstm_block(p, x, cfg, *, cache=None):
    b, s, d = x.shape
    h = cfg.n_heads
    pd = d // h
    cdt = x.dtype
    conv_state = None if cache is None else cache["conv"]
    xc, new_conv = _causal_conv(x, p["conv_w"].astype(cdt),
                                p["conv_b"].astype(cdt), conv_state)
    xc = jax.nn.silu(xc)
    wx = (xc @ p["w_gates"].astype(cdt)).astype(jnp.float32) + p["b_gates"]
    # replicate over the model axis / shard batch over dp before the time
    # scan — a time-dim-sharded xs forces a full re-gather per step
    wx = constrain(wx, "dp", None, None)
    if cache is None:
        z = jnp.zeros((b, h, pd), jnp.float32)
        carry = (z, z, jnp.full((b, h, pd), -1e30, jnp.float32), z)
    else:
        carry = (cache["c"], cache["n"], cache["m"], cache["h"])
    r = p["r_gates"].astype(jnp.float32)
    (c, n, m, hl), ys = jax.lax.scan(
        lambda cr, inp: _slstm_cell(r, cr, inp), carry,
        jnp.moveaxis(wx, 1, 0))
    y = jnp.moveaxis(ys, 0, 1).reshape(b, s, d).astype(cdt)
    yf = y.astype(jnp.float32)
    y = (yf * jax.lax.rsqrt(jnp.mean(yf ** 2, -1, keepdims=True) + 1e-6)
         * p["gn_scale"].astype(jnp.float32)).astype(cdt)
    # small gated FFN (the sLSTM block's 4/3 projection)
    g, u = jnp.split(y @ p["w_ff_up"].astype(cdt), 2, -1)
    out = (jax.nn.silu(g) * u) @ p["w_ff_dn"].astype(cdt)
    new_cache = {"c": c, "n": n, "m": m, "h": hl, "conv": new_conv}
    return constrain(out, "dp", None, None), new_cache


# --------------------------------------------------------------------------- #
# residual wrappers
# --------------------------------------------------------------------------- #
def xlstm_block_init(cfg, key, dtype, kind: str):
    kb, kn = jax.random.split(key)
    if kind == "mlstm":
        bp, bs = mlstm_init(cfg, kb, dtype)
    else:
        bp, bs = slstm_init(cfg, kb, dtype)
    np_, ns = norm_init(cfg, dtype)
    return {"blk": bp, "ln": np_}, {"blk": bs, "ln": ns}


def xlstm_block(p, x, cfg, kind: str, *, cache=None):
    fn = mlstm_block if kind == "mlstm" else slstm_block
    hid, new_cache = fn(p["blk"], apply_norm(p["ln"], x, cfg.norm), cfg,
                        cache=cache)
    return x + hid, new_cache
