"""Model configuration for the assigned architecture pool.

One dataclass covers all 10 families; the ``block_pattern`` field selects the
stack layout:
  "dense"          — homogeneous decoder blocks (attention + FFN)
  "local_global:K" — K-1 sliding-window layers per 1 global layer (gemma3)
  "moe"            — dense attention + MoE FFN
  "mamba_hybrid:K" — Mamba2 blocks with one *shared* attention block applied
                     after every K Mamba blocks (zamba2)
  "xlstm:K"        — mLSTM blocks with one sLSTM block every K (xlstm)
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    # stack layout
    block_pattern: str = "dense"
    parallel_block: bool = False          # PaLM/command-r style attn ∥ ffn
    norm: str = "rmsnorm"                 # "rmsnorm" | "layernorm"
    tie_embeddings: bool = False
    rope_theta: float = 10_000.0
    window: int = 0                       # sliding window (local layers)
    # MoE
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    d_ff_expert: int = 0
    capacity_factor: float = 1.25
    # SSM (mamba2 / zamba2)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 128
    conv_width: int = 4
    # xLSTM
    mlstm_head_dim: int = 0
    # modality frontend stub: "none" | "vlm" | "audio"
    frontend: str = "none"
    n_frontend_tokens: int = 0            # patch/frame embeds prepended
    # numerics
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    remat: str = "full"                   # "full" | "none"
    # parallelism profile: "2d" = FSDP x TP (Megatron-style),
    # "fsdp" = pure ZeRO-3 data parallelism over every mesh axis — for archs
    # where TP activation all-reduces exceed FSDP param gathers (§Perf cr-1)
    parallelism: str = "2d"
    # architecture notes recorded in DESIGN.md
    source: str = ""

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads

    @property
    def attention_free(self) -> bool:
        return self.block_pattern.startswith("xlstm")

    @property
    def subquadratic(self) -> bool:
        return (self.block_pattern.startswith(("mamba_hybrid", "xlstm"))
                or self.block_pattern.startswith("local_global"))

    def pattern_arg(self, default: int = 0) -> int:
        if ":" in self.block_pattern:
            return int(self.block_pattern.split(":")[1])
        return default

    def padded_vocab(self, multiple: int) -> int:
        return ((self.vocab + multiple - 1) // multiple) * multiple

    def padded_experts(self, multiple: int) -> int:
        if self.n_experts == 0:
            return 0
        return ((self.n_experts + multiple - 1) // multiple) * multiple
