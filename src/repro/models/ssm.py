"""Mamba2 (SSD — state-space duality) blocks, TPU-adapted.

Training/prefill uses the *chunked* SSD formulation: within a chunk the
recurrence is expanded into a masked (Q×Q) attention-like matmul (MXU work),
across chunks a short ``lax.scan`` carries the (H, N, P) state — this is the
natural TPU mapping of Mamba2 (matmul-heavy, no per-step scan over the full
sequence). Decode carries the recurrent state explicitly: O(1) per token,
which is what makes the long_500k cells tractable for the hybrid/SSM archs.

Simplifications vs. the reference CUDA implementation (recorded in DESIGN.md):
single B/C group (G=1), no learned init states, RMSNorm gate before out-proj.
Since A < 0 and dt > 0, every exponential in the chunked form is ≤ 1 — the
decay matrices are built in fp32 without extra stabilization.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.models.layers import dense_init, norm_init, apply_norm
from repro.models.sharding import constrain


def mamba_dims(cfg):
    d_inner = cfg.ssm_expand * cfg.d_model
    n_heads = d_inner // cfg.ssm_head_dim
    return d_inner, n_heads, cfg.ssm_head_dim, cfg.ssm_state


def mamba_init(cfg, key, dtype):
    d = cfg.d_model
    di, h, p_, n = mamba_dims(cfg)
    conv_ch = di + 2 * n                      # x, B, C get the causal conv
    ks = jax.random.split(key, 6)
    params = {
        # in_proj -> [z (di), x (di), B (n), C (n), dt (h)]
        "w_in": dense_init(ks[0], (d, 2 * di + 2 * n + h), dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.conv_width, conv_ch)) *
                   0.1).astype(dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "a_log": jnp.zeros((h,), jnp.float32),
        "dt_bias": jnp.full((h,), -2.0, jnp.float32),
        "d_skip": jnp.ones((h,), jnp.float32),
        "w_out": dense_init(ks[2], (di, d), dtype, scale=1.0 / np.sqrt(di)),
        "gn_scale": jnp.ones((di,), dtype),
    }
    specs = {
        "w_in": P("fsdp", "tp"),
        "conv_w": P(None, "tp"),
        "conv_b": P("tp"),
        "a_log": P("tp"),
        "dt_bias": P("tp"),
        "d_skip": P("tp"),
        "w_out": P("tp", "fsdp"),
        "gn_scale": P("tp"),
    }
    return params, specs


def _split_proj(cfg, proj):
    di, h, p_, n = mamba_dims(cfg)
    z, x, bm, cm, dt = jnp.split(
        proj, [di, 2 * di, 2 * di + n, 2 * di + 2 * n], axis=-1)
    return z, x, bm, cm, dt


def _causal_conv(x, w, b, state=None):
    """Depthwise causal conv. x: (B,S,C); w: (W,C). state: (B,W-1,C) carries
    the last inputs for decode. Returns (y, new_state)."""
    width = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], width - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(width))
    return y + b, xp[:, -(width - 1):]


def ssd_chunked(xh, dt, a, bm, cm, chunk: int, init_state=None):
    """Chunked SSD scan.

    xh: (B,S,H,P) inputs; dt: (B,S,H) >=0; a: (H,) < 0;
    bm, cm: (B,S,N). Returns (y: (B,S,H,P), final_state: (B,H,N,P)).
    """
    b, s, h, p_ = xh.shape
    n = bm.shape[-1]
    q = chunk
    nc = s // q
    f32 = jnp.float32

    la = (dt.astype(f32) * a).reshape(b, nc, q, h)            # log-decay ≤ 0
    xb = (xh.astype(f32) * dt.astype(f32)[..., None]).reshape(b, nc, q, h, p_)
    bmc = bm.astype(f32).reshape(b, nc, q, n)
    cmc = cm.astype(f32).reshape(b, nc, q, n)

    cum = jnp.cumsum(la, axis=2)                              # (B,nc,Q,H)
    total = cum[:, :, -1]                                     # (B,nc,H)

    # intra-chunk: scores[i,j] = exp(cum_i - cum_j) * (C_i . B_j), i >= j
    decay = jnp.exp(cum[:, :, :, None] - cum[:, :, None, :, :])  # (B,nc,Q,Q,H)
    tri = jnp.tril(jnp.ones((q, q), bool))
    cb = jnp.einsum("bcin,bcjn->bcij", cmc, bmc)              # (B,nc,Q,Q)
    scores = jnp.where(tri[None, None, ..., None], cb[..., None] * decay, 0.0)
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", scores, xb)

    # chunk summary states: S_c = sum_j exp(total - cum_j) B_j ⊗ Xb_j
    w_end = jnp.exp(total[:, :, None] - cum)                  # (B,nc,Q,H)
    s_chunk = jnp.einsum("bcjn,bcjh,bcjhp->bchnp", bmc, w_end, xb)

    # inter-chunk recurrence (short scan over nc)
    g = jnp.exp(total)                                        # (B,nc,H)
    s0 = (jnp.zeros((b, h, n, p_), f32) if init_state is None
          else init_state.astype(f32))

    def step(carry, inp):
        g_c, s_c = inp                                        # (B,H), (B,H,N,P)
        new = carry * g_c[..., None, None] + s_c
        return new, carry                                     # emit state BEFORE chunk

    gT = jnp.moveaxis(g, 1, 0)                                # (nc,B,H)
    sT = jnp.moveaxis(s_chunk, 1, 0)                          # (nc,B,H,N,P)
    final, prev_states = jax.lax.scan(step, s0, (gT, sT))
    prev = jnp.moveaxis(prev_states, 0, 1)                    # (B,nc,H,N,P)

    y_inter = jnp.einsum("bcin,bcih,bchnp->bcihp", cmc, jnp.exp(cum), prev)
    y = (y_intra + y_inter).reshape(b, s, h, p_)
    return y, final


def mamba_block(p, x, cfg, *, ssm_cache=None):
    """x: (B,S,d). ssm_cache: {"state": (B,H,N,P), "conv": (B,W-1,C)} for
    decode (S=1) / carried prefill. Returns (out, new_cache)."""
    b, s, d = x.shape
    di, h, pd, n = mamba_dims(cfg)
    cdt = x.dtype

    proj = x @ p["w_in"].astype(cdt)
    z, xr, bm, cm, dtr = _split_proj(cfg, proj)
    conv_in = jnp.concatenate([xr, bm, cm], axis=-1)
    conv_state = None if ssm_cache is None else ssm_cache["conv"]
    conv_out, new_conv = _causal_conv(conv_in, p["conv_w"].astype(cdt),
                                      p["conv_b"].astype(cdt), conv_state)
    conv_out = jax.nn.silu(conv_out)
    xr, bm, cm = jnp.split(conv_out, [di, di + n], axis=-1)
    xh = constrain(xr.reshape(b, s, h, pd), "dp", None, "tp", None)

    dt = jax.nn.softplus(dtr.astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["a_log"])                                  # (H,) < 0

    if ssm_cache is None or s > 1:
        init = None if ssm_cache is None else ssm_cache["state"]
        y, final = ssd_chunked(xh, dt, a, bm, cm,
                               min(cfg.ssm_chunk, s), init_state=init)
    else:                                                     # decode: 1 step
        st = ssm_cache["state"].astype(jnp.float32)           # (B,H,N,P)
        dt1 = dt[:, 0]                                        # (B,H)
        g = jnp.exp(dt1 * a[None])                            # (B,H)
        upd = jnp.einsum("bn,bh,bhp->bhnp", bm[:, 0].astype(jnp.float32),
                         dt1, xh[:, 0].astype(jnp.float32))
        st = st * g[..., None, None] + upd
        y = jnp.einsum("bn,bhnp->bhp", cm[:, 0].astype(jnp.float32), st)
        y = y[:, None]                                        # (B,1,H,P)
        final = st

    y = y + xh.astype(jnp.float32) * p["d_skip"][None, None, :, None]
    y = y.reshape(b, s, di).astype(cdt)
    # gated RMSNorm (mamba2 style), then out-projection
    y = y * jax.nn.silu(z)
    yf = y.astype(jnp.float32)
    y = (yf * jax.lax.rsqrt(jnp.mean(yf ** 2, -1, keepdims=True) + 1e-6)
         * p["gn_scale"].astype(jnp.float32)).astype(cdt)
    out = y @ p["w_out"].astype(cdt)
    new_cache = {"state": final.astype(jnp.float32), "conv": new_conv}
    return constrain(out, "dp", None, None), new_cache


def mamba_residual_init(cfg, key, dtype):
    km, kn = jax.random.split(key)
    mp, ms = mamba_init(cfg, km, dtype)
    np_, ns = norm_init(cfg, dtype)
    return {"mamba": mp, "ln": np_}, {"mamba": ms, "ln": ns}


def mamba_residual(p, x, cfg, *, ssm_cache=None):
    h, cache = mamba_block(p["mamba"], apply_norm(p["ln"], x, cfg.norm), cfg,
                           ssm_cache=ssm_cache)
    return x + h, cache
