from repro.models.config import ModelConfig
from repro.models.lm import LM

__all__ = ["ModelConfig", "LM"]
