"""Shared transformer layers: norms, RoPE, GQA attention, gated MLP.

Pure functions over explicit param pytrees (dicts of arrays) plus a parallel
tree of *logical* ``PartitionSpec``s produced at init time. Logical axes:
  "fsdp" — parameter/optimizer sharding axis   (bound to ("pod","data"))
  "tp"   — tensor parallel axis                (bound to ("model",))
  "dp"   — activation batch axis               (bound to ("pod","data"))
  "sp"   — sequence sharding for long KV       (bound to ("data",))
``sharding.constrain`` applies them with divisibility/conflict fallbacks.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.models.sharding import axis_size, constrain


# --------------------------------------------------------------------------- #
# init helpers
# --------------------------------------------------------------------------- #
def dense_init(key, shape, dtype, scale: Optional[float] = None):
    scale = scale if scale is not None else 1.0 / np.sqrt(shape[0])
    return (jax.random.normal(key, shape) * scale).astype(dtype)


# --------------------------------------------------------------------------- #
# norms
# --------------------------------------------------------------------------- #
def norm_init(cfg, dtype):
    p = {"scale": jnp.ones((cfg.d_model,), dtype)}
    s = {"scale": P(None)}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros((cfg.d_model,), dtype)
        s["bias"] = P(None)
    return p, s


def apply_norm(p, x, kind: str, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    if kind == "layernorm":
        xf = xf - xf.mean(-1, keepdims=True)
    rms = jax.lax.rsqrt(jnp.mean(jnp.square(xf), -1, keepdims=True) + eps)
    out = xf * rms * p["scale"].astype(jnp.float32)
    if kind == "layernorm" and "bias" in p:
        out = out + p["bias"].astype(jnp.float32)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------- #
# rotary embeddings
# --------------------------------------------------------------------------- #
def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, H, Dh); positions: (S,) or (B, S)."""
    dh = x.shape[-1]
    freqs = 1.0 / (theta ** (jnp.arange(0, dh, 2, dtype=jnp.float32) / dh))
    ang = positions[..., None].astype(jnp.float32) * freqs      # (..., S, Dh/2)
    sin = jnp.sin(ang)[..., None, :]
    cos = jnp.cos(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------- #
# GQA attention (grouped einsum — KV heads are never materialized H-wide)
# --------------------------------------------------------------------------- #
def attention_init(cfg, key, dtype):
    d, dh = cfg.d_model, cfg.head_dim
    h, k = cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(key, 4)
    params = {
        "wq": dense_init(ks[0], (d, h * dh), dtype),
        "wk": dense_init(ks[1], (d, k * dh), dtype),
        "wv": dense_init(ks[2], (d, k * dh), dtype),
        "wo": dense_init(ks[3], (h * dh, d), dtype, scale=1.0 / np.sqrt(h * dh)),
    }
    specs = {"wq": P("fsdp", "atp"), "wk": P("fsdp", "atp"),
             "wv": P("fsdp", "atp"), "wo": P("atp", "fsdp")}
    return params, specs


def attention(p, x, cfg, *, positions, window: int = 0,
              kv_cache=None, cache_pos=None):
    """GQA attention.

    Train/prefill: x (B,S,d), causal (+ optional sliding ``window``) mask.
    Decode: x (B,1,d); kv_cache {"k","v"}: (B,S_max,K,Dh), updated in place at
    cache_pos. Returns (out, new_cache).
    """
    b, s, d = x.shape
    h, kh, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    g = h // kh
    cdt = x.dtype

    # adaptive sharding:
    #  * flattened attention heads h = kh*g shard over the model axis when
    #    divisible (covers GQA configs where neither kh nor g alone divides);
    #  * otherwise the q-sequence dim shards over the model axis;
    #  * KV caches prefer kv-head sharding, else sequence-over-model
    #    (flash-decoding split-K: softmax then reduces across the model axis)
    tp = axis_size("atp")
    heads_sharded = tp > 1 and h % tp == 0
    kvh_sharded = tp > 1 and kh % tp == 0

    q = (x @ p["wq"].astype(cdt)).reshape(b, s, kh, g, dh)
    kx = (x @ p["wk"].astype(cdt)).reshape(b, s, kh, dh)
    vx = (x @ p["wv"].astype(cdt)).reshape(b, s, kh, dh)
    q = apply_rope(q.reshape(b, s, h, dh), positions,
                   cfg.rope_theta).reshape(b, s, kh, g, dh)
    kx = apply_rope(kx, positions, cfg.rope_theta)
    q = constrain(q, "dp", None, "atp" if kvh_sharded else None,
                  "atp" if (heads_sharded and not kvh_sharded) else None, None)

    if kv_cache is not None:
        kv_axes = ("dp", "sp", "atp", None) if kvh_sharded else \
                  ("dp", "seqtp", None, None)
        kv_seq_ax = "sp" if kvh_sharded else "seqtp"
        zero = jnp.zeros((), jnp.int32)
        start = (zero, jnp.asarray(cache_pos, jnp.int32), zero, zero)
        ck = jax.lax.dynamic_update_slice(
            kv_cache["k"], kx.astype(kv_cache["k"].dtype), start)
        cv = jax.lax.dynamic_update_slice(
            kv_cache["v"], vx.astype(kv_cache["v"].dtype), start)
        ck = constrain(ck, *kv_axes)
        cv = constrain(cv, *kv_axes)
        new_cache = {"k": ck, "v": cv}
        keys, values = ck.astype(cdt), cv.astype(cdt)
        kv_positions = jnp.arange(ck.shape[1])
    else:
        new_cache = None
        keys, values = kx, vx
        kv_positions = positions
        keys = constrain(keys, "dp", "sp",
                         "atp" if kvh_sharded else None, None)
        values = constrain(values, "dp", "sp",
                           "atp" if kvh_sharded else None, None)
        kv_seq_ax = "sp"

    logits = jnp.einsum("bqkgd,bskd->bkgqs", q, keys) / np.sqrt(dh)
    # flatten (kh, g) -> h for the softmax block so the full head count can
    # shard over the model axis (adjacent-dim merge keeps GSPMD propagation)
    logits = logits.reshape(b, h, s, keys.shape[1])
    if heads_sharded:
        log_axes = ("dp", "atp", None, kv_seq_ax)
    else:
        log_axes = ("dp", None, "seqtp", kv_seq_ax)
    logits = constrain(logits, *log_axes)

    qpos = positions if positions.ndim == 1 else positions.reshape(-1)
    mask = kv_positions[None, :] <= qpos[:, None]               # causal; also
    # masks the not-yet-written tail of a decode cache (those slots have
    # kv_position > current position by construction)
    if window > 0:
        mask &= kv_positions[None, :] > qpos[:, None] - window
    logits = jnp.where(mask[None, None], logits.astype(jnp.float32),
                       jnp.float32(-1e30))
    probs = jax.nn.softmax(logits, axis=-1).astype(cdt)
    probs = constrain(probs, *log_axes)
    probs = probs.reshape(b, kh, g, s, keys.shape[1])
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, values)
    out = out.reshape(b, s, h * dh) @ p["wo"].astype(cdt)
    return constrain(out, "dp", None, None), new_cache


# --------------------------------------------------------------------------- #
# gated MLP (SwiGLU)
# --------------------------------------------------------------------------- #
def mlp_init(cfg, key, dtype, d_ff=None):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    params = {
        "wi": dense_init(ks[0], (d, f), dtype),
        "wg": dense_init(ks[1], (d, f), dtype),
        "wo": dense_init(ks[2], (f, d), dtype, scale=1.0 / np.sqrt(f)),
    }
    specs = {"wi": P("fsdp", "atp"), "wg": P("fsdp", "atp"),
             "wo": P("atp", "fsdp")}
    return params, specs


def mlp(p, x):
    cdt = x.dtype
    h = jax.nn.silu(x @ p["wg"].astype(cdt)) * (x @ p["wi"].astype(cdt))
    h = constrain(h, "dp", None, "atp")
    return h @ p["wo"].astype(cdt)


# --------------------------------------------------------------------------- #
# dense decoder block
# --------------------------------------------------------------------------- #
def dense_block_init(cfg, key, dtype):
    ka, km = jax.random.split(key, 2)
    attn_p, attn_s = attention_init(cfg, ka, dtype)
    mlp_p, mlp_s = mlp_init(cfg, km, dtype)
    n1, n1s = norm_init(cfg, dtype)
    n2, n2s = norm_init(cfg, dtype)
    return ({"attn": attn_p, "mlp": mlp_p, "ln1": n1, "ln2": n2},
            {"attn": attn_s, "mlp": mlp_s, "ln1": n1s, "ln2": n2s})


def dense_block(p, x, cfg, *, positions, window=0, kv_cache=None,
                cache_pos=None):
    if cfg.parallel_block:              # command-r style: attn ∥ ffn, one norm
        hN = apply_norm(p["ln1"], x, cfg.norm)
        a, cache = attention(p["attn"], hN, cfg, positions=positions,
                             window=window, kv_cache=kv_cache,
                             cache_pos=cache_pos)
        return x + a + mlp(p["mlp"], hN), cache
    a, cache = attention(p["attn"], apply_norm(p["ln1"], x, cfg.norm), cfg,
                         positions=positions, window=window,
                         kv_cache=kv_cache, cache_pos=cache_pos)
    x = x + a
    x = x + mlp(p["mlp"], apply_norm(p["ln2"], x, cfg.norm))
    return x, cache
