"""LM assembly: stacks, scan-over-layers, train/prefill/decode entry points.

Every architecture is a list of *segments*; each segment is a homogeneous
stack scanned with ``lax.scan`` over stacked params (keeps HLO size and
compile time bounded at 512 devices). Heterogeneous patterns become grouped
segments:

  dense            [("dense", L)]
  local_global:K   [("lg_group", L//K)] + [("local", L mod K)]   (gemma3)
  moe              [("moe", L)]
  mamba_hybrid:K   [("zamba_group", L//K)] + [("mamba", L mod K)] (zamba2;
                   one *shared* attention block applied per group — single
                   param set closed over by every group iteration)
  xlstm:K          [("xlstm_group", L//K)] + mLSTM remainder      (xlstm)

Modality frontends (vlm/audio) are stubs per the assignment: ``input_specs``
provides precomputed patch/frame embeddings; here they are consumed as-is.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.models import layers, moe as moe_mod, ssm, xlstm as xlstm_mod
from repro.models.config import ModelConfig
from repro.models.sharding import constrain

PAD_MULTIPLE = 16          # vocab / expert padding multiple (max model-axis)


@dataclasses.dataclass(frozen=True)
class Segment:
    kind: str          # dense | local | moe | mamba | lg_group | zamba_group
    #                  | xlstm_group
    n: int             # scan length
    group: int = 0     # inner group size (lg/zamba/xlstm groups)


def build_segments(cfg: ModelConfig) -> list[Segment]:
    pat = cfg.block_pattern
    L = cfg.n_layers
    if pat == "dense":
        return [Segment("dense", L)]
    if pat == "moe":
        return [Segment("moe", L)]
    if pat.startswith("local_global"):
        k = cfg.pattern_arg(6)
        segs = [Segment("lg_group", L // k, group=k)]
        if L % k:
            segs.append(Segment("local", L % k))
        return segs
    if pat.startswith("mamba_hybrid"):
        k = cfg.pattern_arg(6)
        segs = [Segment("zamba_group", L // k, group=k)]
        if L % k:
            segs.append(Segment("mamba", L % k))
        return segs
    if pat.startswith("xlstm"):
        k = cfg.pattern_arg(4)
        segs = [Segment("xlstm_group", L // k, group=k)]
        if L % k:
            segs.append(Segment("mamba_rem_invalid", L % k))  # should not happen
        return segs
    raise ValueError(pat)


class LM:
    """Functional model: ``init`` -> (params, logical specs); apply fns are
    pure and jit/pjit-friendly."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.segments = build_segments(cfg)
        self.v_pad = cfg.padded_vocab(PAD_MULTIPLE)
        self.pdt = jnp.dtype(cfg.param_dtype)
        self.cdt = jnp.dtype(cfg.compute_dtype)

    # ------------------------------------------------------------------ #
    # init
    # ------------------------------------------------------------------ #
    def _block_init(self, kind: str, key):
        cfg = self.cfg
        if kind in ("dense", "local"):
            return layers.dense_block_init(cfg, key, self.pdt)
        if kind == "moe":
            return moe_mod.moe_block_init(cfg, key, self.pdt, PAD_MULTIPLE)
        if kind == "mamba":
            return ssm.mamba_residual_init(cfg, key, self.pdt)
        raise ValueError(kind)

    def _stack(self, init_fn, key, dims: tuple[int, ...]):
        """vmap ``init_fn`` over a grid of keys. Spec trees (static Python
        objects) are captured via a trace-time side channel so no concrete
        init ever runs — ``abstract_init`` works for 100B-scale configs."""
        cap = {}

        def only_params(k):
            p, s = init_fn(k)
            cap["specs"] = s
            return p

        keys = jax.random.split(key, int(np.prod(dims)))
        keys = keys.reshape(tuple(dims) + keys.shape[1:])
        fn = only_params
        for _ in dims:
            fn = jax.vmap(fn)
        return fn(keys), cap["specs"]

    def _segment_init(self, seg: Segment, key):
        if seg.kind in ("dense", "local", "moe", "mamba"):
            return self._stack(lambda k: self._block_init(seg.kind, k),
                               key, (seg.n,))
        if seg.kind == "lg_group":
            kl, kg = jax.random.split(key)
            lp, ls = self._stack(lambda k: self._block_init("local", k),
                                 kl, (seg.n, seg.group - 1))
            gp, gs = self._stack(lambda k: self._block_init("dense", k),
                                 kg, (seg.n,))
            return {"local": lp, "global": gp}, {"local": ls, "global": gs}
        if seg.kind == "zamba_group":
            mp, ms = self._stack(lambda k: self._block_init("mamba", k),
                                 key, (seg.n, seg.group))
            return {"mamba": mp}, {"mamba": ms}
        if seg.kind == "xlstm_group":
            km, ks_ = jax.random.split(key)
            mp, ms = self._stack(
                lambda k: xlstm_mod.xlstm_block_init(self.cfg, k, self.pdt,
                                                     "mlstm"),
                km, (seg.n, seg.group - 1))
            sp, ss = self._stack(
                lambda k: xlstm_mod.xlstm_block_init(self.cfg, k, self.pdt,
                                                     "slstm"), ks_, (seg.n,))
            return {"mlstm": mp, "slstm": sp}, {"mlstm": ms, "slstm": ss}
        raise ValueError(seg.kind)

    def abstract_init(self, key):
        """(param ShapeDtypeStructs, logical specs) without any allocation."""
        cap = {}

        def f(k):
            p, s = self.init(k)
            cap["specs"] = s
            return p

        shapes = jax.eval_shape(f, key)
        return shapes, cap["specs"]

    def init(self, key):
        cfg = self.cfg
        n_seg = len(self.segments)
        keys = jax.random.split(key, n_seg + 4)
        params: dict[str, Any] = {}
        specs: dict[str, Any] = {}

        params["emb"] = (jax.random.normal(keys[0], (self.v_pad, cfg.d_model))
                         * 0.02).astype(self.pdt)
        specs["emb"] = P("tp", "fsdp")
        if not cfg.tie_embeddings:
            params["head"] = layers.dense_init(
                keys[1], (cfg.d_model, self.v_pad), self.pdt)
            specs["head"] = P("fsdp", "tp")
        np_, ns = layers.norm_init(cfg, self.pdt)
        params["out_norm"], specs["out_norm"] = np_, ns

        if cfg.block_pattern.startswith("mamba_hybrid"):
            sp, ss = layers.dense_block_init(cfg, keys[2], self.pdt)
            params["shared_attn"], specs["shared_attn"] = sp, ss

        seg_p, seg_s = [], []
        for seg, k in zip(self.segments, keys[4:]):
            p_, s_ = self._segment_init(seg, k)
            # stacked params carry 1 (segment scan) or 2 (+ inner group)
            # leading dims; pad each logical spec with Nones to match rank
            s_ = jax.tree.map(
                lambda sp_, arr: P(*((None,) * (arr.ndim - len(sp_))
                                     + tuple(sp_))),
                s_, p_, is_leaf=lambda x: isinstance(x, P))
            seg_p.append(p_)
            seg_s.append(s_)
        params["segments"] = seg_p
        specs["segments"] = seg_s
        return params, specs

    # ------------------------------------------------------------------ #
    # forward
    # ------------------------------------------------------------------ #
    def _block_apply(self, kind: str, p, x, *, positions, cache=None,
                     cache_pos=None, theta=None, window=0):
        cfg = self.cfg
        if kind in ("dense", "local"):
            w = cfg.window if kind == "local" else window
            c = cfg if theta is None else dataclasses.replace(
                cfg, rope_theta=theta)
            return layers.dense_block(p, x, c, positions=positions, window=w,
                                      kv_cache=cache, cache_pos=cache_pos)
        if kind == "moe":
            return moe_mod.moe_block(p, x, cfg, positions=positions,
                                     pad_experts_to=PAD_MULTIPLE,
                                     kv_cache=cache, cache_pos=cache_pos)
        if kind == "mamba":
            return ssm.mamba_residual(p, x, cfg, ssm_cache=cache)
        raise ValueError(kind)

    def _segment_apply(self, seg: Segment, p, x, *, positions, caches=None,
                       cache_pos=None, shared_attn=None):
        cfg = self.cfg
        use_cache = caches is not None
        remat = cfg.remat == "full" and not use_cache

        def wrap(f):
            return jax.checkpoint(f) if remat else f

        if seg.kind in ("dense", "local", "moe", "mamba"):
            theta = 10_000.0 if seg.kind == "local" else None
            @wrap
            def body(x, inp):
                lp, lc = inp
                out, nc = self._block_apply(seg.kind, lp, x,
                                            positions=positions, cache=lc,
                                            cache_pos=cache_pos, theta=theta)
                return constrain(out, "dp", "seqtp", None), nc
            xs = (p, caches)
            x, new_caches = jax.lax.scan(body, x, xs)
            return x, new_caches

        if seg.kind == "lg_group":
            local_theta = 10_000.0
            @wrap
            def body(x, inp):
                gp, gc = inp
                def inner(x, li):
                    lp, lc = li
                    out, nc = self._block_apply("local", lp, x,
                                                positions=positions, cache=lc,
                                                cache_pos=cache_pos,
                                                theta=local_theta)
                    return out, nc
                x, lc_new = jax.lax.scan(
                    inner, x, (gp["local"],
                               None if gc is None else gc["local"]))
                x, gc_new = self._block_apply(
                    "dense", gp["global"], x, positions=positions,
                    cache=None if gc is None else gc["global"],
                    cache_pos=cache_pos, theta=self.cfg.rope_theta)
                return constrain(x, "dp", "seqtp", None), \
                    {"local": lc_new, "global": gc_new}
            x, new_caches = jax.lax.scan(body, x, (p, caches))
            return x, new_caches

        if seg.kind == "zamba_group":
            @wrap
            def body(x, inp):
                gp, gc = inp
                def inner(x, li):
                    lp, lc = li
                    out, nc = ssm.mamba_residual(lp, x, cfg, ssm_cache=lc)
                    return out, nc
                x, mc_new = jax.lax.scan(
                    inner, x, (gp["mamba"],
                               None if gc is None else gc["mamba"]))
                x, ac_new = layers.dense_block(
                    shared_attn, x, cfg, positions=positions,
                    kv_cache=None if gc is None else gc["attn"],
                    cache_pos=cache_pos)
                return constrain(x, "dp", "seqtp", None), \
                    {"mamba": mc_new, "attn": ac_new}
            x, new_caches = jax.lax.scan(body, x, (p, caches))
            return x, new_caches

        if seg.kind == "xlstm_group":
            @wrap
            def body(x, inp):
                gp, gc = inp
                def inner(x, li):
                    lp, lc = li
                    return xlstm_mod.xlstm_block(lp, x, cfg, "mlstm",
                                                 cache=lc)
                x, mc_new = jax.lax.scan(
                    inner, x, (gp["mlstm"],
                               None if gc is None else gc["mlstm"]))
                x, sc_new = xlstm_mod.xlstm_block(
                    gp["slstm"], x, cfg, "slstm",
                    cache=None if gc is None else gc["slstm"])
                return constrain(x, "dp", "seqtp", None), \
                    {"mlstm": mc_new, "slstm": sc_new}
            x, new_caches = jax.lax.scan(body, x, (p, caches))
            return x, new_caches
        raise ValueError(seg.kind)

    def embed(self, params, batch):
        """Token + frontend embedding. Returns (x, positions)."""
        cfg = self.cfg
        x = None
        if "tokens" in batch:
            x = params["emb"].astype(self.cdt)[batch["tokens"]]
        if cfg.frontend == "vlm" and "patch_embeds" in batch:
            # prefill/train: stub frontend embeddings prepended; decode steps
            # see text tokens only (the patches are already in the caches)
            x = jnp.concatenate(
                [batch["patch_embeds"].astype(self.cdt), x], axis=1)
        elif cfg.frontend == "audio" and "frame_embeds" in batch:
            x = batch["frame_embeds"].astype(self.cdt)
        positions = jnp.arange(x.shape[1])
        return constrain(x, "dp", None, None), positions

    def forward(self, params, batch, *, caches=None, cache_pos=None,
                positions=None):
        """Full forward. Returns (logits, new_caches)."""
        cfg = self.cfg
        x, pos = self.embed(params, batch)
        if positions is not None:
            pos = positions
        # sequence-parallel residual stream (Megatron-SP): the scan carry —
        # which remat saves per layer — is sharded over the model axis too,
        # bounding saved activations to B*S*d/(dp*tp) per layer
        x = constrain(x, "dp", "seqtp", None)
        shared = params.get("shared_attn")
        new_caches = []
        for i, seg in enumerate(self.segments):
            x, nc = self._segment_apply(
                seg, params["segments"][i], x, positions=pos,
                caches=None if caches is None else caches[i],
                cache_pos=cache_pos, shared_attn=shared)
            x = constrain(x, "dp", "seqtp", None)
            new_caches.append(nc)
        x = layers.apply_norm(params["out_norm"], x, cfg.norm)
        head = (params["emb"].T if cfg.tie_embeddings
                else params["head"]).astype(self.cdt)
        logits = x @ head
        return constrain(logits, "dp", None, "tp"), new_caches

    # ------------------------------------------------------------------ #
    # loss
    # ------------------------------------------------------------------ #
    def loss(self, params, batch):
        """Mean CE over positions with labels >= 0 (frontend/pad = -1)."""
        logits, _ = self.forward(params, batch)
        labels = batch["labels"]
        if logits.shape[1] != labels.shape[1]:       # vlm: frontend prepended
            pad = logits.shape[1] - labels.shape[1]
            labels = jnp.concatenate(
                [jnp.full((labels.shape[0], pad), -1, labels.dtype), labels],
                axis=1)
        lf = logits.astype(jnp.float32)
        lse = jax.nn.logsumexp(lf, axis=-1)
        gold = jnp.take_along_axis(
            lf, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
        mask = (labels >= 0).astype(jnp.float32)
        ce = (lse - gold) * mask
        return ce.sum() / jnp.maximum(mask.sum(), 1.0)

    # ------------------------------------------------------------------ #
    # serve: cache init / prefill / decode
    # ------------------------------------------------------------------ #
    def init_cache(self, batch_size: int, max_len: int):
        """Abstract cache pytree (zeros) for decode; mirrors segments."""
        cfg = self.cfg
        kh, dh = cfg.n_kv_heads, cfg.head_dim
        kv = lambda s_len: {
            "k": jnp.zeros((batch_size, s_len, kh, dh), self.cdt),
            "v": jnp.zeros((batch_size, s_len, kh, dh), self.cdt)}

        def mamba_cache():
            di, h, p_, n = ssm.mamba_dims(cfg)
            conv_ch = di + 2 * n
            return {"state": jnp.zeros((batch_size, h, n, p_), jnp.float32),
                    "conv": jnp.zeros((batch_size, cfg.conv_width - 1,
                                       conv_ch), self.cdt)}

        def xlstm_cache(kind):
            if kind == "mlstm":
                di, h, p_ = xlstm_mod.xlstm_dims(cfg)
                return {"C": jnp.zeros((batch_size, h, p_, p_), jnp.float32),
                        "n": jnp.zeros((batch_size, h, p_), jnp.float32),
                        "m": jnp.full((batch_size, h), -1e30, jnp.float32),
                        "conv": jnp.zeros((batch_size, cfg.conv_width - 1, di),
                                          self.cdt)}
            h, pd = cfg.n_heads, cfg.d_model // cfg.n_heads
            z = jnp.zeros((batch_size, h, pd), jnp.float32)
            return {"c": z, "n": z, "m": jnp.full((batch_size, h, pd), -1e30,
                                                  jnp.float32), "h": z,
                    "conv": jnp.zeros((batch_size, cfg.conv_width - 1,
                                       cfg.d_model), self.cdt)}

        def stack(tree, n):
            return jax.tree.map(
                lambda a: jnp.broadcast_to(a, (n,) + a.shape), tree)

        caches = []
        for seg in self.segments:
            if seg.kind in ("dense", "moe"):
                caches.append(stack(kv(max_len), seg.n))
            elif seg.kind == "local":
                caches.append(stack(kv(max_len), seg.n))
            elif seg.kind == "mamba":
                caches.append(stack(mamba_cache(), seg.n))
            elif seg.kind == "lg_group":
                caches.append({
                    "local": stack(stack(kv(max_len), seg.group - 1), seg.n),
                    "global": stack(kv(max_len), seg.n)})
            elif seg.kind == "zamba_group":
                caches.append({
                    "mamba": stack(stack(mamba_cache(), seg.group), seg.n),
                    "attn": stack(kv(max_len), seg.n)})
            elif seg.kind == "xlstm_group":
                caches.append({
                    "mlstm": stack(stack(xlstm_cache("mlstm"), seg.group - 1),
                                   seg.n),
                    "slstm": stack(xlstm_cache("slstm"), seg.n)})
        return caches

    def prefill(self, params, batch, caches):
        """Populate caches from a full prompt; returns (logits, caches)."""
        return self.forward(params, batch, caches=caches, cache_pos=0)

    def decode_step(self, params, tokens, caches, pos):
        """One token: tokens (B,1) int32; pos scalar int32."""
        positions = pos[None] if pos.ndim == 0 else pos
        batch = {"tokens": tokens}
        if self.cfg.frontend == "audio":
            batch = {"frame_embeds":
                     params["emb"].astype(self.cdt)[tokens]}
        logits, caches = self.forward(params, batch, caches=caches,
                                      cache_pos=pos, positions=positions)
        return logits, caches

    # ------------------------------------------------------------------ #
    def count_params(self, params) -> int:
        return sum(int(np.prod(a.shape)) for a in jax.tree.leaves(params))
