"""Mixture-of-experts FFN with sort-based capacity dispatch.

TPU adaptation notes: instead of GShard's one-hot dispatch einsum (whose
dispatch FLOPs exceed the expert GEMMs for large E·C) we sort token-slots by
expert id and scatter into a dense (E, C, d) buffer — gathers/scatters are
memory ops, the MXU only sees the real batched expert GEMMs, so compiled
FLOPs ≈ active-parameter FLOPs (what the 6·N_active·D roofline expects).
Experts are sharded over the "tp" axis (expert parallelism); counts are
padded to a multiple of the axis size with router masking (DESIGN.md §6).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.models.layers import dense_init, mlp, mlp_init
from repro.models.sharding import constrain


def moe_init(cfg, key, dtype, pad_experts_to: int = 1):
    d, fe = cfg.d_model, cfg.d_ff_expert or cfg.d_ff
    e = cfg.padded_experts(pad_experts_to)
    ks = jax.random.split(key, 5)
    params = {
        "router": dense_init(ks[0], (d, e), dtype, scale=0.02),
        "wi": dense_init(ks[1], (e, d, fe), dtype),
        "wg": dense_init(ks[2], (e, d, fe), dtype),
        "wo": dense_init(ks[3], (e, fe, d), dtype, scale=1.0 / np.sqrt(fe)),
    }
    specs = {
        "router": P("fsdp", None),
        "wi": P("tp", "fsdp", None),
        "wg": P("tp", "fsdp", None),
        "wo": P("tp", "fsdp", None),
    }
    if cfg.n_shared_experts:
        shared_p, shared_s = mlp_init(cfg, ks[4], dtype,
                                      d_ff=cfg.n_shared_experts * fe)
        params["shared"] = shared_p
        specs["shared"] = shared_s
    return params, specs


def moe_ffn(p, x, cfg, pad_experts_to: int = 1, n_groups: int = 0):
    """x: (B, S, d) -> (B, S, d). Top-k routing with capacity drop.

    Dispatch is *grouped by data-parallel shard* (GShard-style groups bound
    to the physical dp axis): the sort/scatter indices stay local to each
    group, so GSPMD shards the dispatch over dp instead of replicating a
    global (E*C, d) scatter buffer — the baseline's dominant all-reduce
    (measured 6.7e12 B/device/step for qwen2-moe train_4k; see EXPERIMENTS.md
    §Perf iteration moe-1). Expert GEMMs run on a (G, E, C_g, d) batch with
    G sharded over dp and E over tp; token->expert traffic becomes the
    expected all-to-all. With 1 device (tests) G=1 reproduces the exact
    ungrouped semantics.
    """
    from repro.models.sharding import axis_size

    b, s, d = x.shape
    cdt = x.dtype
    e = cfg.padded_experts(pad_experts_to)
    k = cfg.top_k
    n = b * s
    g = n_groups or axis_size("dp")
    while n % g:                                          # batch not divisible
        g //= 2
    g = max(g, 1)
    ng = n // g                                           # tokens per group
    cap = int(np.ceil(ng * k / e * cfg.capacity_factor))
    cap = max(8, ((cap + 7) // 8) * 8)                    # align

    xf = x.reshape(n, d)
    logits = (xf @ p["router"].astype(cdt)).astype(jnp.float32)
    if e != cfg.n_experts:                                # mask pad experts
        pad_mask = jnp.arange(e) >= cfg.n_experts
        logits = jnp.where(pad_mask[None, :], -1e30, logits)
    weights, experts = jax.lax.top_k(logits, k)           # (n, k)
    weights = jax.nn.softmax(weights, axis=-1).astype(cdt)

    # ---- group-local sort-based dispatch -------------------------------- #
    xg = constrain(xf.reshape(g, ng, d), "dp", None, None)
    exp_g = experts.reshape(g, ng * k)
    w_g = weights.reshape(g, ng * k)

    order = jnp.argsort(exp_g, axis=1)                    # (g, ng*k) local
    sorted_exp = jnp.take_along_axis(exp_g, order, axis=1)
    pos = jnp.arange(ng * k)[None, :]
    seg_start = jax.vmap(
        lambda se: jnp.searchsorted(se, jnp.arange(e), side="left"))(
        sorted_exp)                                       # (g, e)
    rank = pos - jnp.take_along_axis(seg_start, sorted_exp, axis=1)
    keep = rank < cap
    token_of_slot = order // k                            # (g, ng*k) local ids

    dest = jnp.where(keep, sorted_exp * cap + rank, e * cap)
    # integer gather (vmapped) — take_along_axis would broadcast the u32
    # index tensor to (g, ng*k, d), which GSPMD then all-reduces (measured
    # 51 GB/step for qwen2-moe; §Perf iteration moe-2)
    gathered = jax.vmap(lambda xv, t: xv[t])(xg, token_of_slot)
    buf = jnp.zeros((g, e * cap + 1, d), cdt)
    buf = jax.vmap(lambda bu, de, ga: bu.at[de].set(ga))(buf, dest, gathered)
    expert_in = buf[:, :-1].reshape(g, e, cap, d)
    expert_in = constrain(expert_in, "dp", "tp", None, None)

    # ---- batched expert SwiGLU (G x E grid; E sharded over tp) ---------- #
    h = (jax.nn.silu(jnp.einsum("gecd,edf->gecf", expert_in,
                                p["wg"].astype(cdt)))
         * jnp.einsum("gecd,edf->gecf", expert_in, p["wi"].astype(cdt)))
    h = constrain(h, "dp", "tp", None, None)
    expert_out = jnp.einsum("gecf,efd->gecd", h, p["wo"].astype(cdt))
    expert_out = constrain(expert_out, "dp", "tp", None, None)

    # ---- combine back (group-local gather + weighted segment sum) ------- #
    flat_out = expert_out.reshape(g, e * cap, d)
    slot_src = jnp.minimum(dest, e * cap - 1)
    slot_out = jax.vmap(lambda fo, s_: fo[s_])(flat_out, slot_src)
    # NOTE (§Perf moe-4, refuted): slot-sharding this combine over the model
    # axis ("seqtp") made GSPMD all-gather the expert buffer instead of
    # forming an all-to-all (N 8.54 -> 12.70 s) — the true fix is a
    # shard_map-level manual a2a; left as the documented next lever.
    slot_out = jnp.where(keep[..., None], slot_out, jnp.zeros((1, d), cdt))
    w_sorted = jnp.take_along_axis(w_g, order, axis=1)
    contrib = slot_out * w_sorted[..., None]
    out = jnp.zeros((g, ng, d), cdt)
    out = jax.vmap(lambda o, t, c: o.at[t].add(c))(out, token_of_slot,
                                                   contrib)
    out = out.reshape(b, s, d)

    if cfg.n_shared_experts:
        # shared experts run on the natural (B, S, d) layout — a (1, n, d)
        # pseudo-batch cannot shard over dp and was measured replicating
        # 1M-token activations (103 GB/step of all-gather; §Perf moe-2)
        out = out + mlp(p["shared"], x)
    return constrain(out, "dp", None, None)


def moe_block_init(cfg, key, dtype, pad_experts_to: int = 1):
    from repro.models.layers import attention_init, norm_init
    ka, km = jax.random.split(key, 2)
    attn_p, attn_s = attention_init(cfg, ka, dtype)
    moe_p, moe_s = moe_init(cfg, km, dtype, pad_experts_to)
    n1, n1s = norm_init(cfg, dtype)
    n2, n2s = norm_init(cfg, dtype)
    return ({"attn": attn_p, "moe": moe_p, "ln1": n1, "ln2": n2},
            {"attn": attn_s, "moe": moe_s, "ln1": n1s, "ln2": n2s})


def moe_block(p, x, cfg, *, positions, pad_experts_to: int = 1,
              kv_cache=None, cache_pos=None):
    from repro.models.layers import apply_norm, attention
    a, cache = attention(p["attn"], apply_norm(p["ln1"], x, cfg.norm), cfg,
                         positions=positions, kv_cache=kv_cache,
                         cache_pos=cache_pos)
    x = x + a
    x = x + moe_ffn(p["moe"], apply_norm(p["ln2"], x, cfg.norm), cfg,
                    pad_experts_to)
    return x, cache
