"""Logical-axis sharding context.

Model code annotates tensors with *logical* axes ("dp", "tp", "fsdp", "sp");
the launcher binds them to physical mesh axes. ``constrain`` applies a
``with_sharding_constraint`` with two safety fallbacks that keep every
(arch × shape × mesh) cell compiling:

  * divisibility — a dim that does not divide by the bound mesh-axis size is
    replicated instead (e.g. kv_heads=8 on a 16-way "model" axis, batch=1 on
    the dp axis for long-context decode);
  * conflict     — a mesh axis may appear only once per spec; later logical
    axes that would reuse it are dropped (e.g. "sp" sequence sharding skipped
    when "dp" already consumed the data axis for a shardable batch).

The same resolution logic converts logical param-spec trees into physical
``NamedSharding``s (``physical_param_specs``).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass
class ShardCtx:
    mesh: Optional[Mesh] = None
    # logical -> tuple of physical mesh axis names
    bindings: dict = dataclasses.field(default_factory=dict)

    def axis_size(self, names: tuple[str, ...]) -> int:
        return math.prod(self.mesh.shape[n] for n in names)


_CTX = ShardCtx()


def set_context(mesh: Optional[Mesh], bindings: dict) -> None:
    """bindings: e.g. {"dp": ("pod","data"), "fsdp": ("pod","data"),
    "tp": ("model",), "sp": ("data",)}. None mesh disables constraints
    (single-device tests)."""
    _CTX.mesh = mesh
    _CTX.bindings = {k: tuple(v) if v else () for k, v in bindings.items()}


def get_context() -> ShardCtx:
    return _CTX


def axis_size(logical: str) -> int:
    """Total mesh size bound to a logical axis (1 if unbound / no mesh)."""
    if _CTX.mesh is None:
        return 1
    names = _CTX.bindings.get(logical, ())
    return _CTX.axis_size(names) if names else 1


def _resolve(logical_axes, shape) -> P:
    """Logical spec -> physical PartitionSpec with fallbacks."""
    used: set[str] = set()
    phys = []
    for dim, logical in enumerate(logical_axes):
        if logical is None:
            phys.append(None)
            continue
        names = _CTX.bindings.get(logical, ())
        names = tuple(n for n in names if n not in used)
        if not names:
            phys.append(None)
            continue
        # largest prefix of the binding that divides the dim
        while names and shape[dim] % _CTX.axis_size(names) != 0:
            names = names[:-1]
        if names:
            used.update(names)
            phys.append(names if len(names) > 1 else names[0])
        else:
            phys.append(None)
    return P(*phys)


def constrain(x: jax.Array, *logical_axes):
    """Annotate array x with logical axes (None = replicated dim)."""
    if _CTX.mesh is None:
        return x
    if len(logical_axes) != x.ndim:
        raise ValueError(f"{len(logical_axes)} axes for rank-{x.ndim} array")
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(_CTX.mesh, _resolve(logical_axes, x.shape)))


def physical_spec(logical: P, shape) -> P:
    return _resolve(tuple(logical) + (None,) * (len(shape) - len(logical)),
                    shape)


def physical_shardings(logical_specs, shapes):
    """Map a pytree of logical P specs + matching ShapeDtypeStructs/arrays to
    NamedShardings (for jit in_shardings/out_shardings)."""
    mesh = _CTX.mesh

    def one(spec, arr):
        return NamedSharding(mesh, physical_spec(spec, arr.shape))

    return jax.tree.map(one, logical_specs, shapes,
                        is_leaf=lambda x: isinstance(x, P))
