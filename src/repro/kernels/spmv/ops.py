"""Jit'd dispatch wrapper: Pallas kernel on TPU, interpret-mode kernel for
validation, jnp oracle as the default CPU path."""
from __future__ import annotations

import jax

from repro.kernels.spmv.ref import spmv_ref
from repro.kernels.spmv.spmv import spmv as spmv_pallas
from repro.sparse.blockell import BlockEll


def blockell_matvec(a: BlockEll, x: jax.Array, *, backend: str = "auto"):
    """backend: "auto" (pallas on TPU else jnp), "pallas", "interpret",
    "jnp"."""
    if backend == "auto":
        backend = ("pallas" if jax.default_backend() == "tpu" else "jnp")
    if backend == "jnp":
        return spmv_ref(a.data, a.idx, x)
    return spmv_pallas(a.data, a.idx, x,
                       interpret=(backend == "interpret"))
