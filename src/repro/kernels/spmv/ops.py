"""Jit'd dispatch wrappers: Pallas kernels on TPU, interpret-mode kernels for
validation, jnp oracles as the default CPU path."""
from __future__ import annotations

import jax

from repro.kernels.spmv.ref import spmv_ref
from repro.kernels.spmv.spmv import spmv as spmv_pallas
from repro.sparse.blockell import BlockEll


def blockell_matvec(a: BlockEll, x: jax.Array, *, backend: str = "auto"):
    """backend: "auto" (pallas on TPU else jnp), "pallas", "interpret",
    "jnp". (The fused SpMV+dot variant is routed by repro.core.ops, which
    owns the solver-side backend dispatch.)"""
    if backend == "auto":
        backend = ("pallas" if jax.default_backend() == "tpu" else "jnp")
    if backend == "jnp":
        return spmv_ref(a.data, a.idx, x)
    return spmv_pallas(a.data, a.idx, x,
                       interpret=(backend == "interpret"))
