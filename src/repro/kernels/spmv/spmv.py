"""Block-ELL SpMV Pallas TPU kernel — the paper's per-iteration hot spot.

TPU adaptation of the paper's CSR SpMV (GSL on CPU): the matrix is stored as
dense (bm x bn) tiles with per-slot column-tile indices. The key TPU
mechanism is ``PrefetchScalarGridSpec``: the int32 column-index array is
prefetched to SMEM *before* the kernel runs, so the x-tile gather is a
BlockSpec ``index_map`` lookup — the DMA engine streams exactly the needed
x tiles HBM->VMEM while the MXU does the (bm x bn) @ (bn,) products. Padding
slots point at column-tile 0 with zero data, so no in-kernel branching.

Grid: (row_tiles, kmax). The accumulator lives in a VMEM scratch; slot k==0
zeroes it, slot k==kmax-1 writes out — one HBM write per row tile.

VMEM working set per step: one (bm, bn) data tile + one (bn,) x tile +
(bm,) accumulator. For TPU-efficient shapes pick bn = 128 (lane width) and
bm a multiple of 8; tests sweep small shapes in interpret mode.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _spmv_kernel(idx_ref, data_ref, x_ref, o_ref, acc_ref):
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(data_ref[0, 0], x_ref[0],
                            preferred_element_type=acc_ref.dtype)

    @pl.when(k == pl.num_programs(1) - 1)
    def _flush():
        o_ref[0] = acc_ref[...]


def _spmv_dot_kernel(idx_ref, data_ref, x_ref, xrow_ref, o_ref, dot_ref,
                     acc_ref):
    """SpMV plus the partial dot xᵀ(Ax): at the flush slot the freshly
    accumulated y row tile is still in VMEM, so the per-row-tile dot costs
    one extra (bm,) read of x instead of a full second pass over y and x."""
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(data_ref[0, 0], x_ref[0],
                            preferred_element_type=acc_ref.dtype)

    @pl.when(k == pl.num_programs(1) - 1)
    def _flush():
        o_ref[0] = acc_ref[...]
        dot_ref[0] = jnp.sum(acc_ref[...] * xrow_ref[0])


@functools.partial(jax.jit, static_argnames=("interpret",))
def spmv(data: jax.Array, idx: jax.Array, x: jax.Array,
         *, interpret: bool = False) -> jax.Array:
    """data: (rt, kmax, bm, bn); idx: (rt, kmax) int32; x: (ct*bn,).
    Returns y = A @ x with y: (rt*bm,)."""
    rt, kmax, bm, bn = data.shape
    xb = x.reshape(-1, bn)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(rt, kmax),
        in_specs=[
            pl.BlockSpec((1, 1, bm, bn), lambda r, k, idx: (r, k, 0, 0)),
            pl.BlockSpec((1, bn), lambda r, k, idx: (idx[r, k], 0)),
        ],
        out_specs=pl.BlockSpec((1, bm), lambda r, k, idx: (r, 0)),
        scratch_shapes=[pltpu.VMEM((bm,), data.dtype)],
    )
    out = pl.pallas_call(
        _spmv_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((rt, bm), data.dtype),
        interpret=interpret,
    )(idx, data, xb)
    return out.reshape(rt * bm)


@functools.partial(jax.jit, static_argnames=("interpret",))
def spmv_dot(data: jax.Array, idx: jax.Array, x: jax.Array,
             *, interpret: bool = False) -> tuple[jax.Array, jax.Array]:
    """Fused y = A @ x and xᵀy in one kernel pass.

    The PCG step needs q = A·p and then α = rz / pᵀq; unfused that is a full
    second read of p and q from HBM. Here the pᵀq partial for each row tile
    is formed while the y tile is still in VMEM (the x row tile rides along
    as one extra (bm,) input), and only a (rt,) partial vector goes back to
    HBM — the caller reduces it in deterministic row-tile order.

    data: (rt, kmax, bm, bn); idx: (rt, kmax) int32; x: (ct*bn,) with
    rt*bm == ct*bn (square A). Returns (y, xᵀy)."""
    rt, kmax, bm, bn = data.shape
    xb = x.reshape(-1, bn)
    xr = x.reshape(rt, bm)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(rt, kmax),
        in_specs=[
            pl.BlockSpec((1, 1, bm, bn), lambda r, k, idx: (r, k, 0, 0)),
            pl.BlockSpec((1, bn), lambda r, k, idx: (idx[r, k], 0)),
            pl.BlockSpec((1, bm), lambda r, k, idx: (r, 0)),
        ],
        out_specs=(pl.BlockSpec((1, bm), lambda r, k, idx: (r, 0)),
                   pl.BlockSpec((1,), lambda r, k, idx: (r,))),
        scratch_shapes=[pltpu.VMEM((bm,), data.dtype)],
    )
    out, partial = pl.pallas_call(
        _spmv_dot_kernel,
        grid_spec=grid_spec,
        out_shape=(jax.ShapeDtypeStruct((rt, bm), data.dtype),
                   jax.ShapeDtypeStruct((rt,), data.dtype)),
        interpret=interpret,
    )(idx, data, xb, xr)
    # no optimization_barrier here (unlike ref.py): the pallas_call output
    # is already opaque to XLA, so the (rt,) partials' association cannot
    # be re-fused (the repro.analysis determinism pass relies on this)
    return out.reshape(rt * bm), jnp.sum(partial)


# --------------------------------------------------------------------------- #
# batched kernels: explicit leading B grid dimension. The grid becomes
# (B, rt, kmax) with k still the innermost (sequential) axis, so each (b, r)
# cell accumulates through the identical VMEM-scratch slot sequence as the
# unbatched kernel — per-member results are bit-identical to B separate
# unbatched calls, while the whole batch is one pallas_call (one dispatch).
# The matrix tiles and the prefetched index array are shared across members.
# --------------------------------------------------------------------------- #
def _spmv_kernel_b(idx_ref, data_ref, x_ref, o_ref, acc_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(data_ref[0, 0], x_ref[0, 0],
                            preferred_element_type=acc_ref.dtype)

    @pl.when(k == pl.num_programs(2) - 1)
    def _flush():
        o_ref[0, 0] = acc_ref[...]


def _spmv_dot_kernel_b(idx_ref, data_ref, x_ref, xrow_ref, o_ref, dot_ref,
                       acc_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(data_ref[0, 0], x_ref[0, 0],
                            preferred_element_type=acc_ref.dtype)

    @pl.when(k == pl.num_programs(2) - 1)
    def _flush():
        o_ref[0, 0] = acc_ref[...]
        dot_ref[0, 0] = jnp.sum(acc_ref[...] * xrow_ref[0, 0])


@functools.partial(jax.jit, static_argnames=("interpret",))
def spmv_batched(data: jax.Array, idx: jax.Array, x: jax.Array,
                 *, interpret: bool = False) -> jax.Array:
    """data: (rt, kmax, bm, bn); idx: (rt, kmax) int32; x: (B, ct*bn).
    Returns y with y[i] = A @ x[i], shape (B, rt*bm)."""
    rt, kmax, bm, bn = data.shape
    nb = x.shape[0]
    xb = x.reshape(nb, -1, bn)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nb, rt, kmax),
        in_specs=[
            pl.BlockSpec((1, 1, bm, bn), lambda b, r, k, idx: (r, k, 0, 0)),
            pl.BlockSpec((1, 1, bn), lambda b, r, k, idx: (b, idx[r, k], 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bm), lambda b, r, k, idx: (b, r, 0)),
        scratch_shapes=[pltpu.VMEM((bm,), data.dtype)],
    )
    out = pl.pallas_call(
        _spmv_kernel_b,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((nb, rt, bm), data.dtype),
        interpret=interpret,
    )(idx, data, xb)
    return out.reshape(nb, rt * bm)


@functools.partial(jax.jit, static_argnames=("interpret",))
def spmv_dot_batched(data: jax.Array, idx: jax.Array, x: jax.Array,
                     *, interpret: bool = False
                     ) -> tuple[jax.Array, jax.Array]:
    """Batched fused y = A @ x and xᵀy: one kernel pass advances all B
    members. Returns (y: (B, rt*bm), xᵀy: (B,)); the (B, rt) partials are
    reduced per member in the same row-tile order as the unbatched caller."""
    rt, kmax, bm, bn = data.shape
    nb = x.shape[0]
    xb = x.reshape(nb, -1, bn)
    xr = x.reshape(nb, rt, bm)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nb, rt, kmax),
        in_specs=[
            pl.BlockSpec((1, 1, bm, bn), lambda b, r, k, idx: (r, k, 0, 0)),
            pl.BlockSpec((1, 1, bn), lambda b, r, k, idx: (b, idx[r, k], 0)),
            pl.BlockSpec((1, 1, bm), lambda b, r, k, idx: (b, r, 0)),
        ],
        out_specs=(pl.BlockSpec((1, 1, bm), lambda b, r, k, idx: (b, r, 0)),
                   pl.BlockSpec((1, 1), lambda b, r, k, idx: (b, r))),
        scratch_shapes=[pltpu.VMEM((bm,), data.dtype)],
    )
    out, partial = pl.pallas_call(
        _spmv_dot_kernel_b,
        grid_spec=grid_spec,
        out_shape=(jax.ShapeDtypeStruct((nb, rt, bm), data.dtype),
                   jax.ShapeDtypeStruct((nb, rt), data.dtype)),
        interpret=interpret,
    )(idx, data, xb, xr)
    return out.reshape(nb, rt * bm), jnp.sum(partial, axis=1)
