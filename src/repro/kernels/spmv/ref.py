"""Pure-jnp oracles for the Block-ELL SpMV kernels.

``spmv_ref`` is the free-form einsum oracle used by the kernel validation
sweeps. ``spmv_seq_ref`` / ``spmv_dot_ref`` mirror the Pallas kernels'
*reduction structure* (sequential accumulation over the k slots, per-row-tile
dot partials): on the same inputs they produce bit-identical f64 results to
the kernels, which is what lets the trajectory-identity property be asserted
exactly across the jnp and Pallas ``SolverOps`` backends.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def spmv_ref(data: jax.Array, idx: jax.Array, x: jax.Array) -> jax.Array:
    """data: (rt, kmax, bm, bn); idx: (rt, kmax); x: (ct*bn,) -> (rt*bm,)."""
    rt, kmax, bm, bn = data.shape
    xb = x.reshape(-1, bn)
    gathered = xb[idx]                                    # (rt, kmax, bn)
    out = jnp.einsum("rkij,rkj->ri", data, gathered)
    return out.reshape(rt * bm)


def spmv_seq_ref(data: jax.Array, idx: jax.Array, x: jax.Array) -> jax.Array:
    """SpMV with the kernel's accumulation order: acc += data[:, k] @ x_k,
    k ascending — one (bm, bn) @ (bn,) product per slot, summed sequentially
    exactly like the Pallas grid's inner dimension."""
    rt, kmax, bm, bn = data.shape
    xb = x.reshape(-1, bn)
    acc = jnp.zeros((rt, bm), data.dtype)
    for k in range(kmax):
        acc = acc + jnp.einsum("rij,rj->ri", data[:, k], xb[idx[:, k]])
    return acc.reshape(rt * bm)


def spmv_dot_ref(data: jax.Array, idx: jax.Array,
                 x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Fused y = A @ x and xᵀy, mirroring ``spmv_dot``'s per-row-tile
    partial-sum order. Returns (y, xᵀy)."""
    rt, kmax, bm, bn = data.shape
    xb = x.reshape(-1, bn)
    acc = jnp.zeros((rt, bm), data.dtype)
    for k in range(kmax):
        acc = acc + jnp.einsum("rij,rj->ri", data[:, k], xb[idx[:, k]])
    partial = jnp.sum(acc * x.reshape(rt, bm), axis=1)    # (rt,)
    # keep the (per-row-tile partials -> final sum) association: without the
    # barrier XLA collapses the two reduces into one flat sum, breaking the
    # bit-identity with the kernel's (rt,) partial output.
    partial = jax.lax.optimization_barrier(partial)
    return acc.reshape(rt * bm), jnp.sum(partial)


# --------------------------------------------------------------------------- #
# batched (leading B axis): per-member unrolled loops over the scalar refs.
# A fused batched einsum ("rij,brj->bri") is NOT bit-identical per member to
# the scalar einsum in f64 — XLA picks a different contraction order — so the
# batched refs apply the exact scalar subgraph to each member row and stack.
# That makes batched-vs-B×(B=1) trajectory identity hold by construction.
# --------------------------------------------------------------------------- #
def spmv_seq_ref_batched(data: jax.Array, idx: jax.Array,
                         x: jax.Array) -> jax.Array:
    """x: (B, ct*bn) -> (B, rt*bm); member i identical to spmv_seq_ref(x[i])."""
    return jnp.stack([spmv_seq_ref(data, idx, x[i])
                      for i in range(x.shape[0])])


def spmv_dot_ref_batched(data: jax.Array, idx: jax.Array,
                         x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Batched fused y = A @ x and xᵀy. Returns ((B, rt*bm), (B,))."""
    pairs = [spmv_dot_ref(data, idx, x[i]) for i in range(x.shape[0])]
    return (jnp.stack([y for y, _ in pairs]),
            jnp.stack([d for _, d in pairs]))


# --------------------------------------------------------------------------- #
# fused-batched variants: ONE batched einsum per k slot serves all B members.
# On an op-overhead-bound host backend this is what actually amortizes the
# batch (the unrolled refs above emit B subgraphs per iteration — B x the op
# count); the price is that member i's rounding is no longer bit-identical
# to its B=1 run (XLA contracts "rij,brj->bri" in a different order). The
# k-slot accumulation order and the per-row-tile partial association are
# kept, so the deviation is einsum-internal only (~ulp level). Opt-in via
# SolverOps fused batching (solve_resilient(batch_fused=True)).
# --------------------------------------------------------------------------- #
def spmv_seq_ref_fused(data: jax.Array, idx: jax.Array,
                       x: jax.Array) -> jax.Array:
    """x: (B, ct*bn) -> (B, rt*bm); one einsum per k slot for all members."""
    rt, kmax, bm, bn = data.shape
    nb = x.shape[0]
    xb = x.reshape(nb, -1, bn)
    acc = jnp.zeros((nb, rt, bm), data.dtype)
    for k in range(kmax):
        acc = acc + jnp.einsum("rij,brj->bri", data[:, k], xb[:, idx[:, k]])
    return acc.reshape(nb, rt * bm)


def spmv_dot_ref_fused(data: jax.Array, idx: jax.Array,
                       x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Batched fused y = A @ x and xᵀy, one einsum per slot for the whole
    batch. Returns ((B, rt*bm), (B,))."""
    rt, kmax, bm, bn = data.shape
    nb = x.shape[0]
    xb = x.reshape(nb, -1, bn)
    acc = jnp.zeros((nb, rt, bm), data.dtype)
    for k in range(kmax):
        acc = acc + jnp.einsum("rij,brj->bri", data[:, k], xb[:, idx[:, k]])
    partial = jnp.sum(acc * x.reshape(nb, rt, bm), axis=2)       # (B, rt)
    partial = jax.lax.optimization_barrier(partial)
    return acc.reshape(nb, rt * bm), jnp.sum(partial, axis=1)
