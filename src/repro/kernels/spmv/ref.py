"""Pure-jnp oracle for the Block-ELL SpMV kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def spmv_ref(data: jax.Array, idx: jax.Array, x: jax.Array) -> jax.Array:
    """data: (rt, kmax, bm, bn); idx: (rt, kmax); x: (ct*bn,) -> (rt*bm,)."""
    rt, kmax, bm, bn = data.shape
    xb = x.reshape(-1, bn)
    gathered = xb[idx]                                    # (rt, kmax, bn)
    out = jnp.einsum("rkij,rkj->ri", data, gathered)
    return out.reshape(rt * bm)
