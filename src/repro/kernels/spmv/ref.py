"""Pure-jnp oracles for the Block-ELL SpMV kernels.

``spmv_ref`` is the free-form einsum oracle used by the kernel validation
sweeps. ``spmv_seq_ref`` / ``spmv_dot_ref`` mirror the Pallas kernels'
*reduction structure* (sequential accumulation over the k slots, per-row-tile
dot partials): on the same inputs they produce bit-identical f64 results to
the kernels, which is what lets the trajectory-identity property be asserted
exactly across the jnp and Pallas ``SolverOps`` backends.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def spmv_ref(data: jax.Array, idx: jax.Array, x: jax.Array) -> jax.Array:
    """data: (rt, kmax, bm, bn); idx: (rt, kmax); x: (ct*bn,) -> (rt*bm,)."""
    rt, kmax, bm, bn = data.shape
    xb = x.reshape(-1, bn)
    gathered = xb[idx]                                    # (rt, kmax, bn)
    out = jnp.einsum("rkij,rkj->ri", data, gathered)
    return out.reshape(rt * bm)


def spmv_seq_ref(data: jax.Array, idx: jax.Array, x: jax.Array) -> jax.Array:
    """SpMV with the kernel's accumulation order: acc += data[:, k] @ x_k,
    k ascending — one (bm, bn) @ (bn,) product per slot, summed sequentially
    exactly like the Pallas grid's inner dimension."""
    rt, kmax, bm, bn = data.shape
    xb = x.reshape(-1, bn)
    acc = jnp.zeros((rt, bm), data.dtype)
    for k in range(kmax):
        acc = acc + jnp.einsum("rij,rj->ri", data[:, k], xb[idx[:, k]])
    return acc.reshape(rt * bm)


def spmv_dot_ref(data: jax.Array, idx: jax.Array,
                 x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Fused y = A @ x and xᵀy, mirroring ``spmv_dot``'s per-row-tile
    partial-sum order. Returns (y, xᵀy)."""
    rt, kmax, bm, bn = data.shape
    xb = x.reshape(-1, bn)
    acc = jnp.zeros((rt, bm), data.dtype)
    for k in range(kmax):
        acc = acc + jnp.einsum("rij,rj->ri", data[:, k], xb[idx[:, k]])
    partial = jnp.sum(acc * x.reshape(rt, bm), axis=1)    # (rt,)
    # keep the (per-row-tile partials -> final sum) association: without the
    # barrier XLA collapses the two reduces into one flat sum, breaking the
    # bit-identity with the kernel's (rt,) partial output.
    partial = jax.lax.optimization_barrier(partial)
    return acc.reshape(rt * bm), jnp.sum(partial)
