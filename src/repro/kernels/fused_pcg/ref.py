"""Pure-jnp oracle for the fused PCG update.

With ``rows`` set, the r'.z' reduction is computed as per-``rows``-block
partial sums followed by a (grid,) reduction — the exact association order of
the Pallas kernel's (grid,) partial output — so the jnp SolverOps backend is
bit-comparable (f64) to the kernel-backed one. ``rows=None`` keeps the plain
full-vector dot (the seed behaviour, used by the kernel validation sweeps,
which compare with tolerances anyway).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def fused_pcg_update_ref(alpha, x, r, p, q, pinv_blocks, rows: int | None = None):
    x_new = x + alpha * p
    r_new = r - alpha * q
    nb, b, _ = pinv_blocks.shape
    z_new = jnp.einsum("nij,nj->ni", pinv_blocks,
                       r_new.reshape(nb, b)).reshape(-1)
    if rows is None:
        rz = r_new @ z_new
    else:
        partial = jnp.sum((r_new * z_new).reshape(-1, rows), axis=1)
        # pin the partial -> final association (XLA would otherwise collapse
        # the two reduces into one flat sum and break kernel bit-identity)
        partial = jax.lax.optimization_barrier(partial)
        rz = jnp.sum(partial)
    return x_new, r_new, z_new, rz
