"""Pure-jnp oracle for the fused PCG update.

With ``rows`` set, the r'.z' reduction is computed as per-``rows``-block
partial sums followed by a (grid,) reduction — the exact association order of
the Pallas kernel's (grid,) partial output — so the jnp SolverOps backend is
bit-comparable (f64) to the kernel-backed one. ``rows=None`` keeps the plain
full-vector dot (the seed behaviour, used by the kernel validation sweeps,
which compare with tolerances anyway).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def fused_pcg_update_ref(alpha, x, r, p, q, pinv_blocks, rows: int | None = None):
    x_new = x + alpha * p
    r_new = r - alpha * q
    nb, b, _ = pinv_blocks.shape
    z_new = jnp.einsum("nij,nj->ni", pinv_blocks,
                       r_new.reshape(nb, b)).reshape(-1)
    if rows is None:
        rz = r_new @ z_new
    else:
        partial = jnp.sum((r_new * z_new).reshape(-1, rows), axis=1)
        # pin the partial -> final association (XLA would otherwise collapse
        # the two reduces into one flat sum and break kernel bit-identity)
        partial = jax.lax.optimization_barrier(partial)
        rz = jnp.sum(partial)
    return x_new, r_new, z_new, rz


def fused_pcg_update_ref_batched(alpha, x, r, p, q, pinv_blocks,
                                 rows: int | None = None):
    """Batched oracle: per-member unrolled loop over the scalar ref.

    alpha: (B,); x, r, p, q: (B, M). Applying the exact scalar subgraph to
    each member row (rather than a fused batched einsum) is what keeps each
    member bit-identical in f64 to its own B=1 run. Returns per-member
    (x', r', z') stacked (B, M) and rz' (B,)."""
    outs = [fused_pcg_update_ref(alpha[i], x[i], r[i], p[i], q[i],
                                 pinv_blocks, rows=rows)
            for i in range(x.shape[0])]
    return (jnp.stack([o[0] for o in outs]), jnp.stack([o[1] for o in outs]),
            jnp.stack([o[2] for o in outs]), jnp.stack([o[3] for o in outs]))


def fused_pcg_update_ref_fused(alpha, x, r, p, q, pinv_blocks,
                               rows: int | None = None):
    """Fused-batched update: one einsum/axpy serves all B members.

    The throughput-mode counterpart of the unrolled batched oracle above
    (see the fused-batched note in kernels/spmv/ref.py): per-member
    results match the B=1 run to ~ulp, not bit-exactly. alpha: (B,);
    x, r, p, q: (B, M); returns (B, M) triples and rz' (B,)."""
    a = alpha[:, None]
    x_new = x + a * p
    r_new = r - a * q
    nbatch = x.shape[0]
    nb, b, _ = pinv_blocks.shape
    z_new = jnp.einsum("nij,bnj->bni", pinv_blocks,
                       r_new.reshape(nbatch, nb, b)).reshape(nbatch, -1)
    if rows is None:
        rz = jnp.einsum("bi,bi->b", r_new, z_new)
    else:
        partial = jnp.sum((r_new * z_new).reshape(nbatch, -1, rows), axis=2)
        partial = jax.lax.optimization_barrier(partial)
        rz = jnp.sum(partial, axis=1)
    return x_new, r_new, z_new, rz
