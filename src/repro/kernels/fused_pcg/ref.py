"""Pure-jnp oracle for the fused PCG update."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def fused_pcg_update_ref(alpha, x, r, p, q, pinv_blocks):
    x_new = x + alpha * p
    r_new = r - alpha * q
    nb, b, _ = pinv_blocks.shape
    z_new = jnp.einsum("nij,nj->ni", pinv_blocks,
                       r_new.reshape(nb, b)).reshape(-1)
    return x_new, r_new, z_new, r_new @ z_new
