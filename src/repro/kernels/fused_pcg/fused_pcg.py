"""Fused PCG vector-update Pallas kernel (beyond-paper optimization).

Lines 4-7 of the paper's Alg. 1 are four memory-bound vector passes:
  x' = x + a p;  r' = r - a q;  z' = P r' (block-Jacobi);  rz' = r'.z'
Unfused that is ~10 vector reads + 4 writes of HBM traffic per iteration;
fused it is 5 reads (x, r, p, q, P-blocks) + 3 writes (x', r', z') + one
(grid,) partial-dot write. On a memory-bound PCG iteration this cuts the
non-SpMV traffic by ~2x (see EXPERIMENTS.md §Perf for the measured terms).

Grid: 1-D over row blocks of ``rows`` rows (a multiple of the preconditioner
block b). The block-Jacobi apply is a batched (rows/b, b, b) @ (rows/b, b)
matvec on the freshly computed r' while it is still in VMEM. The rz partial
sums land in a (grid,) output and are reduced by the caller (deterministic
order — matches the distributed psum layout).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fused_kernel(alpha_ref, x_ref, r_ref, p_ref, q_ref, pb_ref,
                  xo_ref, ro_ref, zo_ref, rz_ref):
    a = alpha_ref[0]
    x_new = x_ref[...] + a * p_ref[...]
    r_new = r_ref[...] - a * q_ref[...]
    nb, b, _ = pb_ref.shape
    z_new = jnp.einsum("nij,nj->ni", pb_ref[...], r_new.reshape(nb, b),
                       preferred_element_type=r_new.dtype).reshape(-1)
    xo_ref[...] = x_new
    ro_ref[...] = r_new
    zo_ref[...] = z_new
    rz_ref[0] = jnp.sum(r_new * z_new)


@functools.partial(jax.jit, static_argnames=("rows", "interpret"))
def fused_pcg_update(alpha: jax.Array, x: jax.Array, r: jax.Array,
                     p: jax.Array, q: jax.Array, pinv_blocks: jax.Array,
                     *, rows: int = 256, interpret: bool = False):
    """Returns (x', r', z', rz') with rz' = r'.z' fully reduced.

    x, r, p, q: (M,); pinv_blocks: (M/b, b, b); alpha: scalar.
    ``rows`` is the per-grid-step block length (multiple of b; for TPU pick
    a multiple of 1024 so the VPU sees full lanes)."""
    m = x.shape[0]
    nb, b, _ = pinv_blocks.shape
    if m % rows or rows % b:
        raise ValueError(f"rows={rows} must divide M={m} and be a multiple "
                         f"of the precond block {b}")
    grid = m // rows
    bpg = rows // b                      # precond blocks per grid step

    vec = pl.BlockSpec((rows,), lambda i: (i,))
    out_shapes = (
        jax.ShapeDtypeStruct((m,), x.dtype),
        jax.ShapeDtypeStruct((m,), x.dtype),
        jax.ShapeDtypeStruct((m,), x.dtype),
        jax.ShapeDtypeStruct((grid,), x.dtype),
    )
    xo, ro, zo, partial = pl.pallas_call(
        _fused_kernel,
        grid=(grid,),
        in_specs=[pl.BlockSpec((1,), lambda i: (0,)),
                  vec, vec, vec, vec,
                  pl.BlockSpec((bpg, b, b), lambda i: (i, 0, 0))],
        out_specs=(vec, vec, vec, pl.BlockSpec((1,), lambda i: (i,))),
        out_shape=out_shapes,
        interpret=interpret,
    )(alpha.reshape(1), x, r, p, q, pinv_blocks)
    return xo, ro, zo, jnp.sum(partial)


def _fused_kernel_b(alpha_ref, x_ref, r_ref, p_ref, q_ref, pb_ref,
                    xo_ref, ro_ref, zo_ref, rz_ref):
    a = alpha_ref[0]
    x_new = x_ref[0] + a * p_ref[0]
    r_new = r_ref[0] - a * q_ref[0]
    nb, b, _ = pb_ref.shape
    z_new = jnp.einsum("nij,nj->ni", pb_ref[...], r_new.reshape(nb, b),
                       preferred_element_type=r_new.dtype).reshape(-1)
    xo_ref[0] = x_new
    ro_ref[0] = r_new
    zo_ref[0] = z_new
    rz_ref[0, 0] = jnp.sum(r_new * z_new)


@functools.partial(jax.jit, static_argnames=("rows", "interpret"))
def fused_pcg_update_batched(alpha: jax.Array, x: jax.Array, r: jax.Array,
                             p: jax.Array, q: jax.Array,
                             pinv_blocks: jax.Array,
                             *, rows: int = 256, interpret: bool = False):
    """Batched fused update: one kernel pass advances all B members.

    alpha: (B,); x, r, p, q: (B, M); pinv_blocks: (M/b, b, b) shared across
    the batch. Grid (B, M/rows) — each (b, i) cell runs the identical
    program as the unbatched kernel's cell i on member b's rows, so member
    results are bit-identical to B separate unbatched calls. Returns
    (x', r', z') as (B, M) and rz' as (B,)."""
    nb_batch, m = x.shape
    nb, b, _ = pinv_blocks.shape
    if m % rows or rows % b:
        raise ValueError(f"rows={rows} must divide M={m} and be a multiple "
                         f"of the precond block {b}")
    grid = m // rows
    bpg = rows // b

    vec = pl.BlockSpec((1, rows), lambda bi, i: (bi, i))
    out_shapes = (
        jax.ShapeDtypeStruct((nb_batch, m), x.dtype),
        jax.ShapeDtypeStruct((nb_batch, m), x.dtype),
        jax.ShapeDtypeStruct((nb_batch, m), x.dtype),
        jax.ShapeDtypeStruct((nb_batch, grid), x.dtype),
    )
    xo, ro, zo, partial = pl.pallas_call(
        _fused_kernel_b,
        grid=(nb_batch, grid),
        in_specs=[pl.BlockSpec((1,), lambda bi, i: (bi,)),
                  vec, vec, vec, vec,
                  pl.BlockSpec((bpg, b, b), lambda bi, i: (i, 0, 0))],
        out_specs=(vec, vec, vec, pl.BlockSpec((1, 1), lambda bi, i: (bi, i))),
        out_shape=out_shapes,
        interpret=interpret,
    )(alpha, x, r, p, q, pinv_blocks)
    return xo, ro, zo, jnp.sum(partial, axis=1)
