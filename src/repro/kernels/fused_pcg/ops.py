"""Dispatch wrapper for the fused PCG update."""
from __future__ import annotations

import jax

from repro.kernels.fused_pcg.fused_pcg import fused_pcg_update
from repro.kernels.fused_pcg.ref import fused_pcg_update_ref


def pcg_update(alpha, x, r, p, q, pinv_blocks, *, backend: str = "auto",
               rows: int = 256):
    if backend == "auto":
        backend = "pallas" if jax.default_backend() == "tpu" else "jnp"
    if backend == "jnp":
        return fused_pcg_update_ref(alpha, x, r, p, q, pinv_blocks)
    return fused_pcg_update(alpha, x, r, p, q, pinv_blocks, rows=rows,
                            interpret=(backend == "interpret"))
