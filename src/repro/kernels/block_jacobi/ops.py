"""Dispatch wrapper for block-Jacobi apply."""
from __future__ import annotations

import jax

from repro.kernels.block_jacobi.block_jacobi import block_jacobi_apply
from repro.kernels.block_jacobi.ref import block_jacobi_apply_ref


def precond_apply(pinv_blocks, r, *, backend: str = "auto", rows: int = 256):
    if backend == "auto":
        backend = "pallas" if jax.default_backend() == "tpu" else "jnp"
    if backend == "jnp":
        return block_jacobi_apply_ref(pinv_blocks, r)
    return block_jacobi_apply(pinv_blocks, r, rows=rows,
                              interpret=(backend == "interpret"))
