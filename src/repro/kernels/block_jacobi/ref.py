"""Pure-jnp oracle for block-Jacobi apply."""
from __future__ import annotations

import jax.numpy as jnp


def block_jacobi_apply_ref(pinv_blocks, r):
    nb, b, _ = pinv_blocks.shape
    return jnp.einsum("nij,nj->ni", pinv_blocks,
                      r.reshape(nb, b)).reshape(-1)
