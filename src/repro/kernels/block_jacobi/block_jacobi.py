"""Block-Jacobi preconditioner apply: z = blockdiag(P_1..P_nb) r.

Batched small (b x b) @ (b,) matvecs, gridded so each step streams a
contiguous strip of blocks through VMEM. Used standalone by the
reconstruction inner solves (Alg. 2 lines 6/8); the main loop fuses the same
computation into ``kernels.fused_pcg``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _bj_kernel(pb_ref, r_ref, o_ref):
    nb, b, _ = pb_ref.shape
    o_ref[...] = jnp.einsum(
        "nij,nj->ni", pb_ref[...], r_ref[...].reshape(nb, b),
        preferred_element_type=o_ref.dtype).reshape(-1)


@functools.partial(jax.jit, static_argnames=("rows", "interpret"))
def block_jacobi_apply(pinv_blocks: jax.Array, r: jax.Array,
                       *, rows: int = 256, interpret: bool = False):
    """pinv_blocks: (M/b, b, b); r: (M,) -> z: (M,)."""
    m = r.shape[0]
    nb, b, _ = pinv_blocks.shape
    if m % rows or rows % b:
        raise ValueError(f"rows={rows} incompatible with M={m}, b={b}")
    grid = m // rows
    bpg = rows // b
    return pl.pallas_call(
        _bj_kernel,
        grid=(grid,),
        in_specs=[pl.BlockSpec((bpg, b, b), lambda i: (i, 0, 0)),
                  pl.BlockSpec((rows,), lambda i: (i,))],
        out_specs=pl.BlockSpec((rows,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((m,), r.dtype),
        interpret=interpret,
    )(pinv_blocks, r)
