"""Pure-jnp oracle for the blocked triangular sweep.

Mirrors the kernel's evaluation order exactly — row-sequential substitution,
sequential k-slot accumulation, the same masked gather and ``jnp.dot`` calls
— so in f64 it is bit-identical to the Pallas kernel (the cross-backend
trajectory-identity property the SolverOps layer relies on).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=("reverse",))
def block_sweep_ref(idx: jax.Array, n: jax.Array, data: jax.Array,
                    dinv: jax.Array, r: jax.Array,
                    *, reverse: bool = False) -> jax.Array:
    nbr, kmax, b, _ = data.shape

    def row(t, y):
        i = (nbr - 1 - t) if reverse else t
        acc = jax.lax.dynamic_slice(r, (i * b,), (b,))

        def slot(k, acc):
            j = idx[i, k]
            yj = jax.lax.dynamic_slice(y, (j * b,), (b,))
            yj = jnp.where(k < n[i], yj, jnp.zeros_like(yj))
            return acc - jnp.dot(data[i, k], yj,
                                 preferred_element_type=acc.dtype)

        acc = jax.lax.fori_loop(0, kmax, slot, acc)
        yi = jnp.dot(dinv[i], acc, preferred_element_type=acc.dtype)
        return jax.lax.dynamic_update_slice(y, yi, (i * b,))

    return jax.lax.fori_loop(0, nbr, row, jnp.zeros_like(r))
