"""Pure-jnp oracle for the blocked triangular sweep.

Mirrors the kernel's evaluation order exactly — row-sequential substitution,
sequential k-slot accumulation, the same masked gather and ``jnp.dot`` calls
— so in f64 it is bit-identical to the Pallas kernel (the cross-backend
trajectory-identity property the SolverOps layer relies on).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=("reverse",))
def block_sweep_ref(idx: jax.Array, n: jax.Array, data: jax.Array,
                    dinv: jax.Array, r: jax.Array,
                    *, reverse: bool = False) -> jax.Array:
    nbr, kmax, b, _ = data.shape

    def row(t, y):
        i = (nbr - 1 - t) if reverse else t
        acc = jax.lax.dynamic_slice(r, (i * b,), (b,))

        def slot(k, acc):
            j = idx[i, k]
            yj = jax.lax.dynamic_slice(y, (j * b,), (b,))
            yj = jnp.where(k < n[i], yj, jnp.zeros_like(yj))
            return acc - jnp.dot(data[i, k], yj,
                                 preferred_element_type=acc.dtype)

        acc = jax.lax.fori_loop(0, kmax, slot, acc)
        yi = jnp.dot(dinv[i], acc, preferred_element_type=acc.dtype)
        return jax.lax.dynamic_update_slice(y, yi, (i * b,))

    return jax.lax.fori_loop(0, nbr, row, jnp.zeros_like(r))


@jax.jit
def wavefront_sweep_ref(rows: jax.Array, n: jax.Array, idx: jax.Array,
                        data: jax.Array, dinv: jax.Array,
                        r: jax.Array) -> jax.Array:
    """Mirror of the wavefront kernel: outer fori over levels, inner fori
    over the level's (padded) row slots, the same (m + b) scratch-padded work
    vector, masked slot loads and ``jnp.dot`` calls — bit-identical to both
    the Pallas wavefront kernel and (by row-independence within levels) the
    sequential ``block_sweep_ref`` in f64."""
    n_levels, width, kmax, b, _ = data.shape
    m = r.shape[0]
    r_pad = jnp.concatenate([r, jnp.zeros((b,), r.dtype)])

    def level(t, y):
        def row(w, y):
            i = rows[t, w]
            acc = jax.lax.dynamic_slice(r_pad, (i * b,), (b,))

            def slot(k, acc):
                j = idx[t, w, k]
                yj = jax.lax.dynamic_slice(y, (j * b,), (b,))
                yj = jnp.where(k < n[t, w], yj, jnp.zeros_like(yj))
                return acc - jnp.dot(data[t, w, k], yj,
                                     preferred_element_type=acc.dtype)

            acc = jax.lax.fori_loop(0, kmax, slot, acc)
            yi = jnp.dot(dinv[t, w], acc, preferred_element_type=acc.dtype)
            return jax.lax.dynamic_update_slice(y, yi, (i * b,))

        return jax.lax.fori_loop(0, width, row, y)

    y = jax.lax.fori_loop(0, n_levels, level, jnp.zeros((m + b,), r.dtype))
    return y[:m]
