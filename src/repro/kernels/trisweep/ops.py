"""Dispatch wrapper for the blocked triangular sweep (sequential and
level-scheduled/wavefront forms)."""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.kernels.trisweep.ref import block_sweep_ref, wavefront_sweep_ref
from repro.kernels.trisweep.trisweep import block_sweep, wavefront_sweep


class Wavefront(NamedTuple):
    """Device-side level-major sweep arrays (see blocktri.LevelSchedule).
    A pytree, so it threads through jit; hashable layout comes from the
    caller keeping one instance per preconditioner."""
    rows: jax.Array      # (n_levels, width) int32, padding = nbr
    n: jax.Array         # (n_levels, width) int32
    idx: jax.Array       # (n_levels, width, kmax) int32
    data: jax.Array      # (n_levels, width, kmax, b, b)
    dinv: jax.Array      # (n_levels, width, b, b)


def wavefront_from_schedule(sched) -> Wavefront:
    """Upload a host-side ``blocktri.LevelSchedule`` to device arrays."""
    return Wavefront(rows=jnp.asarray(sched.rows), n=jnp.asarray(sched.n),
                     idx=jnp.asarray(sched.idx),
                     data=jnp.asarray(sched.data),
                     dinv=jnp.asarray(sched.dinv))


def sweep(idx, n, data, dinv, r, *, reverse: bool = False,
          backend: str = "auto", schedule: Wavefront | None = None):
    """Solve (D̂ + T) y = r. With ``schedule`` set, the level-scheduled
    wavefront kernels run one grid step per elimination-DAG level (all
    independent block rows of a level together) instead of one per row —
    bit-identical results either way (same per-row arithmetic)."""
    if backend == "auto":
        backend = "pallas" if jax.default_backend() == "tpu" else "jnp"
    if schedule is not None:
        if backend == "jnp":
            return wavefront_sweep_ref(schedule.rows, schedule.n,
                                       schedule.idx, schedule.data,
                                       schedule.dinv, r)
        return wavefront_sweep(schedule.rows, schedule.n, schedule.idx,
                               schedule.data, schedule.dinv, r,
                               interpret=(backend == "interpret"))
    if backend == "jnp":
        return block_sweep_ref(idx, n, data, dinv, r, reverse=reverse)
    return block_sweep(idx, n, data, dinv, r, reverse=reverse,
                       interpret=(backend == "interpret"))
