"""Dispatch wrapper for the blocked triangular sweep."""
from __future__ import annotations

import jax

from repro.kernels.trisweep.ref import block_sweep_ref
from repro.kernels.trisweep.trisweep import block_sweep


def sweep(idx, n, data, dinv, r, *, reverse: bool = False,
          backend: str = "auto"):
    if backend == "auto":
        backend = "pallas" if jax.default_backend() == "tpu" else "jnp"
    if backend == "jnp":
        return block_sweep_ref(idx, n, data, dinv, r, reverse=reverse)
    return block_sweep(idx, n, data, dinv, r, reverse=reverse,
                       interpret=(backend == "interpret"))
