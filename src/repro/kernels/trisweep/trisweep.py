"""Blocked triangular-sweep Pallas kernel — the substrate of the SSOR and
IC(0) preconditioner applies.

Solves (D̂ + T) y = r by block substitution, where T is a strictly
block-triangular matrix stored ELL-style at the preconditioner block
granularity b and D̂ is block-diagonal with *precomputed inverse* blocks
(``dinv``): every diagonal solve is a dense (b x b) @ (b,) matvec.

  forward  (reverse=False):  y_i = dinv_i (r_i - sum_{k} T[i,k] y_{idx[i,k]})
                             rows processed 0 .. nbr-1 (all idx[i,k] < i)
  backward (reverse=True):   same recurrence, rows nbr-1 .. 0 (idx[i,k] > i)

Grid: (nbr,), one block row per step — TPU grids execute *sequentially*, so
step t may read the y blocks written by earlier steps: the output BlockSpec
is the full (M,) vector with a constant index map, which pins y in VMEM for
the whole sweep (no HBM round-trip between rows). The per-row index/count
arrays ride in as scalar prefetch (SMEM), exactly like the Block-ELL SpMV's
column indices. Padding slots point at block 0 with zero data; loads of
not-yet-written y regions are masked before the multiply (the output buffer
is uninitialized, and NaN * 0 = NaN would otherwise leak in).

The whole input vector plus the T strip must fit VMEM (M up to ~200k f64 on
a 16 MB core) — the regime of the paper's per-node subdomains. The k-slot
accumulation is sequential (fori_loop), so the jnp reference
(``ref.block_sweep_ref``) reproduces the reduction order bit-for-bit in f64.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _sweep_kernel(idx_ref, n_ref, data_ref, dinv_ref, r_ref, y_ref,
                  *, reverse: bool, nbr: int):
    t = pl.program_id(0)
    i = (nbr - 1 - t) if reverse else t          # row this step owns
    b = r_ref.shape[0]
    kmax = data_ref.shape[1]
    acc = r_ref[...]

    def slot(k, acc):
        j = idx_ref[i, k]
        yj = y_ref[pl.ds(j * b, b)]
        yj = jnp.where(k < n_ref[i], yj, jnp.zeros_like(yj))
        return acc - jnp.dot(data_ref[0, k], yj,
                             preferred_element_type=acc.dtype)

    acc = jax.lax.fori_loop(0, kmax, slot, acc)
    y_ref[pl.ds(i * b, b)] = jnp.dot(dinv_ref[0], acc,
                                     preferred_element_type=acc.dtype)


def _wavefront_kernel(rows_ref, n_ref, idx_ref, data_ref, dinv_ref, r_ref,
                      y_ref):
    t = pl.program_id(0)
    width = rows_ref.shape[1]
    kmax = idx_ref.shape[2]
    b = dinv_ref.shape[-1]

    def row(w, _):
        i = rows_ref[t, w]                       # padding rows point at the
        acc = r_ref[pl.ds(i * b, b)]             # scratch block i = nbr
        def slot(k, acc):
            j = idx_ref[t, w, k]
            yj = y_ref[pl.ds(j * b, b)]
            yj = jnp.where(k < n_ref[t, w], yj, jnp.zeros_like(yj))
            return acc - jnp.dot(data_ref[0, w, k], yj,
                                 preferred_element_type=acc.dtype)
        acc = jax.lax.fori_loop(0, kmax, slot, acc)
        y_ref[pl.ds(i * b, b)] = jnp.dot(dinv_ref[0, w], acc,
                                         preferred_element_type=acc.dtype)
        return 0

    jax.lax.fori_loop(0, width, row, 0)


@functools.partial(jax.jit, static_argnames=("interpret",))
def wavefront_sweep(rows: jax.Array, n: jax.Array, idx: jax.Array,
                    data: jax.Array, dinv: jax.Array, r: jax.Array,
                    *, interpret: bool = False) -> jax.Array:
    """Level-scheduled (wavefront) blocked triangular sweep.

    Inputs are the level-major arrays of a ``precond.blocktri.LevelSchedule``
    (one grid step per elimination-DAG level, all of the level's independent
    block rows processed in that step): rows (n_levels, width) int32 row ids
    with padding = nbr; n/idx/data/dinv per (level, slot). The work vector is
    (m + b): the trailing scratch block absorbs padding-row writes (their
    ``dinv`` is zero), so the kernel has no per-row branch. Per-row
    arithmetic — masked slot loads, sequential k accumulation, one dense
    diagonal matvec — is exactly the sequential kernel's, so the result is
    bit-identical to ``block_sweep`` in f64 (rows within a level are
    mutually independent by construction).
    """
    n_levels, width, kmax, b, _ = data.shape
    m = r.shape[0]
    mp = m + b
    r_pad = jnp.concatenate([r, jnp.zeros((b,), r.dtype)])

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(n_levels,),
        in_specs=[
            pl.BlockSpec((1, width, kmax, b, b),
                         lambda t, *_: (t, 0, 0, 0, 0)),
            pl.BlockSpec((1, width, b, b), lambda t, *_: (t, 0, 0, 0)),
            pl.BlockSpec((mp,), lambda t, *_: (0,)),
        ],
        out_specs=pl.BlockSpec((mp,), lambda t, *_: (0,)),
    )
    y = pl.pallas_call(
        _wavefront_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((mp,), r.dtype),
        interpret=interpret,
    )(rows, n, idx, data, dinv, r_pad)
    return y[:m]


@functools.partial(jax.jit, static_argnames=("reverse", "interpret"))
def block_sweep(idx: jax.Array, n: jax.Array, data: jax.Array,
                dinv: jax.Array, r: jax.Array, *, reverse: bool = False,
                interpret: bool = False) -> jax.Array:
    """idx: (nbr, kmax) int32 column-block ids (0-padded); n: (nbr,) int32
    valid slots; data: (nbr, kmax, b, b); dinv: (nbr, b, b); r: (m,).
    Returns y with (D̂ + T) y = r."""
    nbr, kmax, b, _ = data.shape
    m = r.shape[0]
    if m != nbr * b:
        raise ValueError(f"M={m} != nbr*b = {nbr}*{b}")
    row = (lambda t, idx, n: (nbr - 1 - t,)) if reverse else \
        (lambda t, idx, n: (t,))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(nbr,),
        in_specs=[
            pl.BlockSpec((1, kmax, b, b), lambda t, idx, n: row(t, idx, n) + (0, 0, 0)),
            pl.BlockSpec((1, b, b), lambda t, idx, n: row(t, idx, n) + (0, 0)),
            pl.BlockSpec((b,), row),
        ],
        out_specs=pl.BlockSpec((m,), lambda t, idx, n: (0,)),
    )
    return pl.pallas_call(
        functools.partial(_sweep_kernel, reverse=reverse, nbr=nbr),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((m,), r.dtype),
        interpret=interpret,
    )(idx, n, data, dinv, r)
