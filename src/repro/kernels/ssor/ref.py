"""Pure-jnp oracle for the SSOR apply — composes the bit-identical sweep and
block-Jacobi references in the same order as the kernel path."""
from __future__ import annotations

import functools

import jax

from repro.kernels.block_jacobi.ref import block_jacobi_apply_ref
from repro.kernels.trisweep.ref import block_sweep_ref


@functools.partial(jax.jit)
def ssor_apply_ref(lo_idx, lo_n, lo_data, up_idx, up_n, up_data, dinv,
                   mid_blocks, r):
    y = block_sweep_ref(lo_idx, lo_n, lo_data, dinv, r, reverse=False)
    w = block_jacobi_apply_ref(mid_blocks, y)
    return block_sweep_ref(up_idx, up_n, up_data, dinv, w, reverse=True)
