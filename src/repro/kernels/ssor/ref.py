"""Pure-jnp oracle for the SSOR apply — composes the bit-identical sweep and
block-Jacobi references in the same order as the kernel path."""
from __future__ import annotations

import functools

import jax

from repro.kernels.block_jacobi.ref import block_jacobi_apply_ref
from repro.kernels.trisweep.ref import block_sweep_ref, wavefront_sweep_ref


@functools.partial(jax.jit)
def ssor_apply_ref(lo_idx, lo_n, lo_data, up_idx, up_n, up_data, dinv,
                   mid_blocks, r, lo_wf=None, up_wf=None):
    if lo_wf is not None:
        y = wavefront_sweep_ref(lo_wf.rows, lo_wf.n, lo_wf.idx, lo_wf.data,
                                lo_wf.dinv, r)
    else:
        y = block_sweep_ref(lo_idx, lo_n, lo_data, dinv, r, reverse=False)
    w = block_jacobi_apply_ref(mid_blocks, y)
    if up_wf is not None:
        return wavefront_sweep_ref(up_wf.rows, up_wf.n, up_wf.idx,
                                   up_wf.data, up_wf.dinv, w)
    return block_sweep_ref(up_idx, up_n, up_data, dinv, w, reverse=True)
