"""Dispatch wrapper for the SSOR apply."""
from __future__ import annotations

import jax

from repro.kernels.ssor.ref import ssor_apply_ref
from repro.kernels.ssor.ssor import ssor_apply


def ssor_precond_apply(lo_idx, lo_n, lo_data, up_idx, up_n, up_data, dinv,
                       mid_blocks, r, *, backend: str = "auto",
                       rows: int = 256, lo_wf=None, up_wf=None):
    if backend == "auto":
        backend = "pallas" if jax.default_backend() == "tpu" else "jnp"
    if backend == "jnp":
        return ssor_apply_ref(lo_idx, lo_n, lo_data, up_idx, up_n, up_data,
                              dinv, mid_blocks, r, lo_wf=lo_wf, up_wf=up_wf)
    return ssor_apply(lo_idx, lo_n, lo_data, up_idx, up_n, up_data, dinv,
                      mid_blocks, r, rows=rows,
                      interpret=(backend == "interpret"),
                      lo_wf=lo_wf, up_wf=up_wf)
