"""SSOR (symmetric block Gauss-Seidel) preconditioner apply — Pallas path.

  z = ω(2−ω) (D + ωU)^{-1} D (D + ωL)^{-1} r
    = M⁻¹ r  with  M = (1/(ω(2−ω))) (D + ωL) D⁻¹ (D + ωU),

the standard SSOR preconditioner (SPD for SPD A and ω ∈ (0, 2); ω = 1 is
symmetric block Gauss-Seidel). Three passes, all kernelized:

  1. forward blocked substitution   (D + ωL) y = r     (kernels/trisweep)
  2. block-diagonal matvec          w = ω(2−ω) D y     (kernels/block_jacobi)
  3. backward blocked substitution  (D + ωU) z = w     (kernels/trisweep)

The caller pre-scales: ``lo_data``/``up_data`` hold ωL / ωU blocks, ``dinv``
holds D⁻¹ blocks, ``mid_blocks`` holds ω(2−ω) D blocks — all static data
(rebuilt from the COO in safe storage after a failure).
"""
from __future__ import annotations

import functools

import jax

from repro.kernels.block_jacobi.block_jacobi import block_jacobi_apply
from repro.kernels.trisweep.trisweep import block_sweep, wavefront_sweep


@functools.partial(jax.jit, static_argnames=("rows", "interpret"))
def ssor_apply(lo_idx, lo_n, lo_data, up_idx, up_n, up_data, dinv,
               mid_blocks, r, *, rows: int = 256, interpret: bool = False,
               lo_wf=None, up_wf=None):
    """``lo_wf``/``up_wf``: optional level-major ``trisweep.ops.Wavefront``
    bundles — when present the substitutions run one grid step per
    elimination-DAG level instead of per block row (bit-identical values)."""
    if lo_wf is not None:
        y = wavefront_sweep(lo_wf.rows, lo_wf.n, lo_wf.idx, lo_wf.data,
                            lo_wf.dinv, r, interpret=interpret)
    else:
        y = block_sweep(lo_idx, lo_n, lo_data, dinv, r, reverse=False,
                        interpret=interpret)
    w = block_jacobi_apply(mid_blocks, y, rows=rows, interpret=interpret)
    if up_wf is not None:
        return wavefront_sweep(up_wf.rows, up_wf.n, up_wf.idx, up_wf.data,
                               up_wf.dinv, w, interpret=interpret)
    return block_sweep(up_idx, up_n, up_data, dinv, w, reverse=True,
                       interpret=interpret)
