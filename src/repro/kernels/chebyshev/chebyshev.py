"""Chebyshev polynomial preconditioner apply — Pallas path.

  z = p_d(A) r,  p_d ≈ A⁻¹ on [lo, hi]  (matrix-free: d Block-ELL SpMVs)

The classic Chebyshev semi-iteration for A z = r from z₀ = 0 run a *fixed*
number of steps: the result is a fixed polynomial in A applied to r, hence a
linear, symmetric operator, and SPD because λ p_d(λ) = 1 − T_d((θ−λ)/δ) /
T_d(θ/δ) > 0 for all λ ∈ (0, hi]. The eigenvalue bounds come from Gershgorin
discs (host-side, see ``repro.precond.chebyshev``).

All vector algebra is plain jnp, shared verbatim with the reference backend;
only the SpMV differs (Pallas kernel vs ``spmv_seq_ref``), and those two are
bit-identical in f64 — so the whole apply is.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.spmv.spmv import spmv


def cheb_coefficients(lo: float, hi: float, degree: int):
    """Host-side (a_k, b_k) pairs of the semi-iteration: dz ← a dz + b (r−Az).

    ρ-recurrence: ρ₁ = δ/θ, ρ_{k} = 1/(2θ/δ − ρ_{k−1}); a_k = ρ_k ρ_{k−1},
    b_k = 2ρ_k/δ."""
    theta = (hi + lo) / 2.0
    delta = (hi - lo) / 2.0
    sigma = theta / delta
    rho = 1.0 / sigma
    out = []
    for _ in range(degree - 1):
        rho_new = 1.0 / (2.0 * sigma - rho)
        out.append((rho_new * rho, 2.0 * rho_new / delta))
        rho = rho_new
    return out


def cheb_recurrence(matvec, r, *, lo: float, hi: float, degree: int):
    """z = p_d(A) r via the Chebyshev semi-iteration (d = degree ≥ 1).

    The correction steps run under ``lax.scan`` with the SpMV result behind
    an ``optimization_barrier``: the scan materializes the carried (z, dz)
    pair at every step and the barrier pins the SpMV output, so XLA cannot
    fuse the jnp reference's einsum chain into the surrounding axpys (FMA
    formations the opaque Pallas call never gets) — which is what makes the
    two backends bit-identical in f64."""
    theta = (hi + lo) / 2.0
    z = r / theta
    if degree == 1:
        return z
    coefs = jnp.asarray(cheb_coefficients(lo, hi, degree), r.dtype)

    def body(carry, c):
        z, dz = carry
        q = jax.lax.optimization_barrier(matvec(z))
        dz = c[0] * dz + c[1] * (r - q)
        return (z + dz, dz), ()

    (z, _), _ = jax.lax.scan(body, (z, z), coefs)
    return z


@functools.partial(jax.jit,
                   static_argnames=("lo", "hi", "degree", "interpret"))
def chebyshev_apply(data: jax.Array, idx: jax.Array, r: jax.Array,
                    *, lo: float, hi: float, degree: int,
                    interpret: bool = False) -> jax.Array:
    """data/idx: the Block-ELL matrix; r: (M,). Returns z = p_d(A) r."""
    mv = lambda v: spmv(data, idx, v, interpret=interpret)
    return cheb_recurrence(mv, r, lo=lo, hi=hi, degree=degree)
