"""Pure-jnp oracle for the Chebyshev apply — the identical recurrence over
the kernel-mirrored sequential SpMV reference (bit-identical in f64)."""
from __future__ import annotations

import functools

import jax

from repro.kernels.chebyshev.chebyshev import cheb_recurrence
from repro.kernels.spmv.ref import spmv_seq_ref


@functools.partial(jax.jit, static_argnames=("lo", "hi", "degree"))
def chebyshev_apply_ref(data: jax.Array, idx: jax.Array, r: jax.Array,
                        *, lo: float, hi: float, degree: int) -> jax.Array:
    mv = lambda v: spmv_seq_ref(data, idx, v)
    return cheb_recurrence(mv, r, lo=lo, hi=hi, degree=degree)
