"""Dispatch wrapper for the Chebyshev apply."""
from __future__ import annotations

import jax

from repro.kernels.chebyshev.chebyshev import chebyshev_apply
from repro.kernels.chebyshev.ref import chebyshev_apply_ref


def chebyshev_precond_apply(data, idx, r, *, lo: float, hi: float,
                            degree: int, backend: str = "auto"):
    if backend == "auto":
        backend = "pallas" if jax.default_backend() == "tpu" else "jnp"
    if backend == "jnp":
        return chebyshev_apply_ref(data, idx, r, lo=lo, hi=hi, degree=degree)
    return chebyshev_apply(data, idx, r, lo=lo, hi=hi, degree=degree,
                           interpret=(backend == "interpret"))
