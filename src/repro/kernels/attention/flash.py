"""Flash attention (forward) Pallas TPU kernel — streaming-softmax tiling.

The §Perf residual for the attention-heavy cells (command-r, gemma3) is the
XLA path's materialized (S x S) fp32 logits plus full causal-masked matmuls.
This kernel streams KV blocks through VMEM with the online-softmax
recurrence and *skips* fully-masked blocks via ``pl.when`` — causal work is
a true S^2/2 and sliding-window work O(S·W) on TPU (grid points with no
live entries never touch the MXU).

Grid: (B*H, q_blocks, kv_blocks), kv innermost. Scratch: fp32 accumulator
(Bq, dh) + running max/sum (Bq,). Block sizes default to MXU/VPU-aligned
(128, 128); tests sweep small shapes in interpret mode against the jnp
oracle, including GQA head fan-out at the ops.py level.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
                  *, sm_scale, causal, window, bq, bk, kv_len):
    qi = pl.program_id(1)
    kj = pl.program_id(2)
    q_pos = qi * bq + jax.lax.iota(jnp.int32, bq)

    @pl.when(kj == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, -1e30)
        l_ref[...] = jnp.zeros_like(l_ref)

    # block-level liveness: skip kv blocks entirely above the causal
    # diagonal / outside the window
    first_q, last_q = qi * bq, qi * bq + bq - 1
    first_k, last_k = kj * bk, kj * bk + bk - 1
    live = jnp.asarray(True)
    if causal:
        live &= first_k <= last_q
    if window > 0:
        live &= last_k > first_q - window

    @pl.when(live)
    def _block():
        k_pos = kj * bk + jax.lax.iota(jnp.int32, bk)
        s = jnp.dot(q_ref[0], k_ref[0].T,
                    preferred_element_type=jnp.float32) * jnp.float32(sm_scale)
        mask = jnp.ones((bq, bk), bool)
        if causal:
            mask &= k_pos[None, :] <= q_pos[:, None]
        if window > 0:
            mask &= k_pos[None, :] > q_pos[:, None] - window
        mask &= (k_pos[None, :] < kv_len)
        s = jnp.where(mask, s, jnp.float32(-1e30))

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        corr = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_ref[...] = corr * l_ref[...] + p.sum(axis=1)
        acc_ref[...] = (corr[:, None] * acc_ref[...]
                        + jnp.dot(p.astype(v_ref.dtype), v_ref[0],
                                  preferred_element_type=jnp.float32))
        m_ref[...] = m_new

    @pl.when(kj == pl.num_programs(2) - 1)
    def _flush():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...],
                                jnp.float32(1e-30))[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "bq", "bk",
                                             "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    bq: int = 128, bk: int = 128,
                    interpret: bool = False) -> jax.Array:
    """q: (BH, Sq, dh); k, v: (BH, Skv, dh). Returns (BH, Sq, dh)."""
    bh, sq, dh = q.shape
    skv = k.shape[1]
    bq = min(bq, sq)
    bk = min(bk, skv)
    sq_pad = ((sq + bq - 1) // bq) * bq
    skv_pad = ((skv + bk - 1) // bk) * bk
    if sq_pad != sq:
        q = jnp.pad(q, ((0, 0), (0, sq_pad - sq), (0, 0)))
    if skv_pad != skv:
        k = jnp.pad(k, ((0, 0), (0, skv_pad - skv), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, skv_pad - skv), (0, 0)))

    grid = (bh, sq_pad // bq, skv_pad // bk)
    kernel = functools.partial(
        _flash_kernel, sm_scale=1.0 / np.sqrt(dh), causal=causal,
        window=window, bq=bq, bk=bk, kv_len=skv)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, dh), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, dh), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, dh), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, dh), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq_pad, dh), q.dtype),
        scratch_shapes=[pltpu.VMEM((bq, dh), jnp.float32),
                        pltpu.VMEM((bq,), jnp.float32),
                        pltpu.VMEM((bq,), jnp.float32)],
        interpret=interpret,
    )(q, k, v)
    return out[:, :sq]
