"""Pure-jnp oracle for flash attention."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def attention_ref(q, k, v, *, causal=True, window=0):
    """q: (BH, Sq, dh); k, v: (BH, Skv, dh)."""
    dh = q.shape[-1]
    s = jnp.einsum("bqd,bkd->bqk", q, k).astype(jnp.float32) / np.sqrt(dh)
    sq, skv = q.shape[1], k.shape[1]
    q_pos = jnp.arange(sq)[:, None] + (skv - sq if causal else 0)
    k_pos = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window > 0:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    return jnp.einsum("bqk,bkd->bqd", p, v)
