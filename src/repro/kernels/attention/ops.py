"""Dispatch wrapper: GQA-aware flash attention entry point.

Maps (B, S, KH, G, dh) grouped-query layouts onto the (B*H, S, dh) kernel
by expanding KV heads at the wrapper level (the kernel itself streams KV
blocks, so the expansion is an indexing view, not extra HBM traffic on TPU).
Self-attention only (sq == skv) for the causal path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.attention.flash import flash_attention
from repro.kernels.attention.ref import attention_ref


def gqa_flash(q, k, v, *, causal=True, window=0, backend="auto",
              bq=128, bk=128):
    """q: (B, S, H, dh); k, v: (B, S, KH, dh) with H = KH * G."""
    b, s, h, dh = q.shape
    kh = k.shape[2]
    g = h // kh
    if backend == "auto":
        backend = "pallas" if jax.default_backend() == "tpu" else "jnp"
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, s, dh)
    kf = jnp.repeat(k.transpose(0, 2, 1, 3), g, axis=1).reshape(b * h, s, dh)
    vf = jnp.repeat(v.transpose(0, 2, 1, 3), g, axis=1).reshape(b * h, s, dh)
    if backend == "jnp":
        of = attention_ref(qf, kf, vf, causal=causal, window=window)
    else:
        of = flash_attention(qf, kf, vf, causal=causal, window=window,
                             bq=bq, bk=bk,
                             interpret=(backend == "interpret"))
    return of.reshape(b, h, s, dh).transpose(0, 2, 1, 3)
