"""Pure-jnp oracle for the IC(0) apply — the bit-identical sweep reference
composed in the same order as the kernel path."""
from __future__ import annotations

import functools

import jax

from repro.kernels.trisweep.ref import block_sweep_ref, wavefront_sweep_ref


@functools.partial(jax.jit)
def ic0_apply_ref(lo_idx, lo_n, lo_data, up_idx, up_n, up_data, dinv_f,
                  dinv_b, r, lo_wf=None, up_wf=None):
    if lo_wf is not None:
        y = wavefront_sweep_ref(lo_wf.rows, lo_wf.n, lo_wf.idx, lo_wf.data,
                                lo_wf.dinv, r)
    else:
        y = block_sweep_ref(lo_idx, lo_n, lo_data, dinv_f, r, reverse=False)
    if up_wf is not None:
        return wavefront_sweep_ref(up_wf.rows, up_wf.n, up_wf.idx,
                                   up_wf.data, up_wf.dinv, y)
    return block_sweep_ref(up_idx, up_n, up_data, dinv_b, y, reverse=True)
