"""Pure-jnp oracle for the IC(0) apply — the bit-identical sweep reference
composed in the same order as the kernel path."""
from __future__ import annotations

import functools

import jax

from repro.kernels.trisweep.ref import block_sweep_ref


@functools.partial(jax.jit)
def ic0_apply_ref(lo_idx, lo_n, lo_data, up_idx, up_n, up_data, dinv_f,
                  dinv_b, r):
    y = block_sweep_ref(lo_idx, lo_n, lo_data, dinv_f, r, reverse=False)
    return block_sweep_ref(up_idx, up_n, up_data, dinv_b, y, reverse=True)
