"""IC(0) incomplete-Cholesky preconditioner apply — Pallas path.

  z = (L Lᵀ)⁻¹ r   via   L y = r  (forward sweep),  Lᵀ z = y  (backward),

where L is the level-0-fill blocked incomplete Cholesky factor of A
(computed host-side in ``repro.precond.ic0`` — static data). Both solves are
blocked substitutions through ``kernels/trisweep``:

  * forward:  ``lo_*`` holds the strictly-lower L blocks, ``dinv_f`` the
    precomputed L_ii⁻¹ blocks (each diagonal solve is a dense matvec);
  * backward: ``up_*`` holds Lᵀ's strictly-upper blocks (= L_jiᵀ),
    ``dinv_b`` the L_ii⁻ᵀ blocks.
"""
from __future__ import annotations

import functools

import jax

from repro.kernels.trisweep.trisweep import block_sweep


@functools.partial(jax.jit, static_argnames=("interpret",))
def ic0_apply(lo_idx, lo_n, lo_data, up_idx, up_n, up_data, dinv_f, dinv_b,
              r, *, interpret: bool = False):
    y = block_sweep(lo_idx, lo_n, lo_data, dinv_f, r, reverse=False,
                    interpret=interpret)
    return block_sweep(up_idx, up_n, up_data, dinv_b, y, reverse=True,
                       interpret=interpret)
