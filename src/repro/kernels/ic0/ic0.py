"""IC(0) incomplete-Cholesky preconditioner apply — Pallas path.

  z = (L Lᵀ)⁻¹ r   via   L y = r  (forward sweep),  Lᵀ z = y  (backward),

where L is the level-0-fill blocked incomplete Cholesky factor of A
(computed host-side in ``repro.precond.ic0`` — static data). Both solves are
blocked substitutions through ``kernels/trisweep``:

  * forward:  ``lo_*`` holds the strictly-lower L blocks, ``dinv_f`` the
    precomputed L_ii⁻¹ blocks (each diagonal solve is a dense matvec);
  * backward: ``up_*`` holds Lᵀ's strictly-upper blocks (= L_jiᵀ),
    ``dinv_b`` the L_ii⁻ᵀ blocks.
"""
from __future__ import annotations

import functools

import jax

from repro.kernels.trisweep.trisweep import block_sweep, wavefront_sweep


@functools.partial(jax.jit, static_argnames=("interpret",))
def ic0_apply(lo_idx, lo_n, lo_data, up_idx, up_n, up_data, dinv_f, dinv_b,
              r, *, interpret: bool = False, lo_wf=None, up_wf=None):
    """``lo_wf``/``up_wf``: optional level-major ``trisweep.ops.Wavefront``
    bundles — one grid step per elimination-DAG level (bit-identical)."""
    if lo_wf is not None:
        y = wavefront_sweep(lo_wf.rows, lo_wf.n, lo_wf.idx, lo_wf.data,
                            lo_wf.dinv, r, interpret=interpret)
    else:
        y = block_sweep(lo_idx, lo_n, lo_data, dinv_f, r, reverse=False,
                        interpret=interpret)
    if up_wf is not None:
        return wavefront_sweep(up_wf.rows, up_wf.n, up_wf.idx, up_wf.data,
                               up_wf.dinv, y, interpret=interpret)
    return block_sweep(up_idx, up_n, up_data, dinv_b, y, reverse=True,
                       interpret=interpret)
