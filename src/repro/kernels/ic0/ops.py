"""Dispatch wrapper for the IC(0) apply."""
from __future__ import annotations

import jax

from repro.kernels.ic0.ic0 import ic0_apply
from repro.kernels.ic0.ref import ic0_apply_ref


def ic0_precond_apply(lo_idx, lo_n, lo_data, up_idx, up_n, up_data, dinv_f,
                      dinv_b, r, *, backend: str = "auto", lo_wf=None,
                      up_wf=None):
    if backend == "auto":
        backend = "pallas" if jax.default_backend() == "tpu" else "jnp"
    if backend == "jnp":
        return ic0_apply_ref(lo_idx, lo_n, lo_data, up_idx, up_n, up_data,
                             dinv_f, dinv_b, r, lo_wf=lo_wf, up_wf=up_wf)
    return ic0_apply(lo_idx, lo_n, lo_data, up_idx, up_n, up_data, dinv_f,
                     dinv_b, r, interpret=(backend == "interpret"),
                     lo_wf=lo_wf, up_wf=up_wf)
