"""Block-row distribution of a sparse matrix over N nodes (paper §1.2).

Node ``s`` owns the contiguous index range ``I_s = [s*R, (s+1)*R)`` of rows of
the system matrix and the matching entries of every distributed vector — the
PETSc-style *block row distribution* the paper assumes. On TPU the "node" axis
is a mesh axis; here we keep the mapping static and explicit so that both the
single-device simulator (``comm.sim``) and the ``shard_map`` runtime
(``comm.shard``) agree on ownership.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class Partition:
    """Static description of the block-row distribution.

    Attributes:
      m:        global problem size M (rows).
      n_nodes:  number of nodes N.
      bm:       tile height used by the Block-ELL storage (rows per tile).
      bn:       tile width (columns per tile). Redundancy bookkeeping runs at
                ``bn``-column-tile granularity (TPU adaptation of the paper's
                per-entry sets; see DESIGN.md §3).
    """

    m: int
    n_nodes: int
    bm: int
    bn: int

    def __post_init__(self):
        if self.m % self.n_nodes != 0:
            raise ValueError(f"M={self.m} not divisible by N={self.n_nodes}")
        if self.rows_per_node % self.bm != 0:
            raise ValueError(
                f"rows/node={self.rows_per_node} not divisible by bm={self.bm}")
        if self.rows_per_node % self.bn != 0:
            raise ValueError(
                f"rows/node={self.rows_per_node} not divisible by bn={self.bn}")

    # -- sizes ------------------------------------------------------------
    @property
    def rows_per_node(self) -> int:
        return self.m // self.n_nodes

    @property
    def row_tiles(self) -> int:           # global number of row tiles
        return self.m // self.bm

    @property
    def col_tiles(self) -> int:           # global number of column tiles
        return self.m // self.bn

    @property
    def row_tiles_per_node(self) -> int:
        return self.rows_per_node // self.bm

    @property
    def col_tiles_per_node(self) -> int:
        return self.rows_per_node // self.bn

    # -- ownership ---------------------------------------------------------
    def owner_of_row(self, i) -> np.ndarray:
        return np.asarray(i) // self.rows_per_node

    def owner_of_col_tile(self, t) -> np.ndarray:
        return np.asarray(t) // self.col_tiles_per_node

    def node_rows(self, s: int) -> tuple[int, int]:
        r = self.rows_per_node
        return s * r, (s + 1) * r

    def node_col_tiles(self, s: int) -> tuple[int, int]:
        c = self.col_tiles_per_node
        return s * c, (s + 1) * c

    def intra_node_mask(self, rows, cols) -> np.ndarray:
        """Entrywise mask of COO coordinates whose row and column are owned
        by the same node — the entries an additive-Schwarz (node-local)
        preconditioner keeps."""
        return self.owner_of_row(rows) == self.owner_of_row(cols)


def shrunk_partition(part: Partition, n_new: int,
                     precond_block: int = 1) -> Partition:
    """The elastic re-partition: the same rows (re-padded up to the new
    divisibility unit) spread over ``n_new`` < N nodes.

    The new global size is the smallest multiple of
    ``n_new · lcm(bm, bn, precond_block)`` that holds the current M — the
    same padding rule ``build_problem`` applies at construction, so the
    appended rows are decoupled identity rows that never perturb the
    solution (see core.elastic).
    """
    if not 1 <= n_new < part.n_nodes:
        raise ValueError(
            f"shrunk partition needs 1 <= n_new < {part.n_nodes}, "
            f"got {n_new}")
    unit = n_new * int(np.lcm.reduce([part.bm, part.bn, precond_block]))
    m_new = ((part.m + unit - 1) // unit) * unit
    return Partition(m=m_new, n_nodes=n_new, bm=part.bm, bn=part.bn)


def neighbor(s: int, k: int, n_nodes: int) -> int:
    """Designated redundancy destination ``d_{s,k}`` — Eq. (1) of the paper.

    The φ nearest ring neighbours of node ``s``: +1, -1, +2, -2, ... for
    k = 1, 2, 3, 4, ...  (k odd → s + ceil(k/2), k even → s - k/2, mod N).
    """
    if k < 1:
        raise ValueError("k is 1-based")
    if k % 2 == 1:
        return (s + (k + 1) // 2) % n_nodes
    return (s - k // 2) % n_nodes


def neighbors(s: int, phi: int, n_nodes: int) -> list[int]:
    """``[d_{s,1}, ..., d_{s,phi}]``."""
    return [neighbor(s, k, n_nodes) for k in range(1, phi + 1)]
