"""Block-ELL sparse storage: the TPU-native adaptation of the paper's CSR SpMV.

The matrix is tiled into dense (bm x bn) blocks. Every *row tile* stores a
fixed number ``kmax`` of column tiles (dense data + int32 column-tile index),
padded with explicit zero tiles pointing at column-tile 0. This trades a bit
of padding for:

  * MXU-aligned dense (bm x bn) @ (bn,) products instead of scalar CSR
    traversal (the GSL path the paper uses on CPUs),
  * a static shape that `jax.jit`/Pallas can tile over, and
  * a per-row-tile gather of x blocks that maps 1:1 onto a Pallas
    scalar-prefetch ``BlockSpec`` index_map (see ``repro.kernels.spmv``).

Construction happens host-side in numpy (static data in the paper's sense —
it can be "retrieved from safe storage" after a failure).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.sparse.partition import Partition


@dataclasses.dataclass
class BlockEll:
    """Block-ELL matrix.

    data: (row_tiles, kmax, bm, bn)  dense tile values (zero tiles pad).
    idx:  (row_tiles, kmax) int32    column-tile index per slot (0 pads).
    nblk: (row_tiles,) int32         number of valid slots per row tile.
    shape: (M, M)
    """

    data: jax.Array
    idx: jax.Array
    nblk: jax.Array
    shape: tuple[int, int]
    bm: int
    bn: int

    @property
    def row_tiles(self) -> int:
        return self.data.shape[0]

    @property
    def kmax(self) -> int:
        return self.data.shape[1]

    @property
    def dtype(self):
        return self.data.dtype

    # ------------------------------------------------------------------ #
    @staticmethod
    def from_coo(rows: np.ndarray, cols: np.ndarray, vals: np.ndarray,
                 m: int, bm: int, bn: int, kmax: Optional[int] = None,
                 dtype=np.float64) -> "BlockEll":
        if m % bm or m % bn:
            raise ValueError(f"M={m} must be divisible by bm={bm} and bn={bn}")
        rows = np.asarray(rows, np.int64)
        cols = np.asarray(cols, np.int64)
        vals = np.asarray(vals, dtype)
        rt, ct = rows // bm, cols // bn
        n_row_tiles = m // bm
        # unique (row_tile, col_tile) pairs, slot numbering per row tile
        key = rt * (m // bn) + ct
        uniq, inv = np.unique(key, return_inverse=True)
        urt, uct = uniq // (m // bn), uniq % (m // bn)
        counts = np.bincount(urt, minlength=n_row_tiles)
        needed = int(counts.max()) if counts.size else 1
        if kmax is None:
            kmax = max(needed, 1)
        elif needed > kmax:
            raise ValueError(f"kmax={kmax} < max tiles/row-tile {needed}")
        # slot index of each unique tile within its row tile (uniq sorted => ct ascending)
        starts = np.zeros(n_row_tiles + 1, np.int64)
        np.cumsum(counts, out=starts[1:])
        slot_of_uniq = np.arange(uniq.size) - starts[urt]
        data = np.zeros((n_row_tiles, kmax, bm, bn), dtype)
        idx = np.zeros((n_row_tiles, kmax), np.int32)
        idx[urt, slot_of_uniq] = uct.astype(np.int32)
        # scatter values into dense tiles
        u = inv                      # which unique tile each nnz belongs to
        np.add.at(data, (rt, slot_of_uniq[u], rows % bm, cols % bn), vals)
        nblk = counts.astype(np.int32)
        return BlockEll(jnp.asarray(data), jnp.asarray(idx), jnp.asarray(nblk),
                        (m, m), bm, bn)

    @staticmethod
    def from_dense(a: np.ndarray, bm: int, bn: int,
                   kmax: Optional[int] = None) -> "BlockEll":
        a = np.asarray(a)
        rows, cols = np.nonzero(a)
        return BlockEll.from_coo(rows, cols, a[rows, cols], a.shape[0], bm, bn,
                                 kmax=kmax, dtype=a.dtype)

    # ------------------------------------------------------------------ #
    def to_dense(self) -> np.ndarray:
        m = self.shape[0]
        out = np.zeros((m, m), self.data.dtype)
        data = np.asarray(self.data)
        idx = np.asarray(self.idx)
        nblk = np.asarray(self.nblk)
        for r in range(self.row_tiles):
            for k in range(int(nblk[r])):
                c = int(idx[r, k])
                out[r * self.bm:(r + 1) * self.bm,
                    c * self.bn:(c + 1) * self.bn] += data[r, k]
        return out

    def matvec(self, x: jax.Array) -> jax.Array:
        """Reference jnp SpMV (the oracle; kernels/spmv accelerates this)."""
        xb = x.reshape(-1, self.bn)                       # (col_tiles, bn)
        gathered = xb[self.idx]                           # (rt, kmax, bn)
        out = jnp.einsum("rkij,rkj->ri", self.data, gathered)
        return out.reshape(-1)

    # -- partition-aware views ---------------------------------------- #
    def node_slice(self, part: Partition, s: int) -> "BlockEll":
        """Row tiles owned by node s (a (R x M) strip, still Block-ELL)."""
        rpt = part.row_tiles_per_node
        sl = slice(s * rpt, (s + 1) * rpt)
        return BlockEll(self.data[sl], self.idx[sl], self.nblk[sl],
                        (part.rows_per_node, self.shape[1]), self.bm, self.bn)

    def needed_col_tiles(self, part: Partition) -> list[np.ndarray]:
        """For each node l: sorted unique global column tiles its rows touch.

        This is the tile-granular analogue of the paper's sets ``I_{s,l}``
        (restricted to what l *receives*): the owner of tile t must send t to
        every node whose rows reference it.
        """
        idx = np.asarray(self.idx)
        nblk = np.asarray(self.nblk)
        valid = np.arange(self.kmax)[None, :] < nblk[:, None]
        out = []
        rpt = part.row_tiles_per_node
        for l in range(part.n_nodes):
            sl = slice(l * rpt, (l + 1) * rpt)
            t = idx[sl][valid[sl]]
            out.append(np.unique(t))
        return out
