"""SPD test-problem generators (paper §5 uses SuiteSparse; offline we generate
problems of the same regime — elliptic-PDE discretizations and banded SPD).

All generators return COO triples (host numpy). ``build_problem`` packages a
generator output into the distributed ``Problem`` used by the solvers: the
Block-ELL matrix, the partition, the right-hand side, a registered
preconditioner from ``repro.precond`` (block-Jacobi by default; SSOR /
Chebyshev / IC(0) via ``precond=...``), and the raw COO (the "static data in
safe storage" that the paper assumes replacement nodes can reload after a
failure — Alg. 2 line 1).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.sparse.blockell import BlockEll
from repro.sparse.partition import Partition


# --------------------------------------------------------------------------- #
# generators: COO triples for SPD matrices
# --------------------------------------------------------------------------- #
def poisson2d(nx: int, ny: Optional[int] = None):
    """5-point Laplacian on an nx x ny grid (Dirichlet). SPD, bandwidth nx."""
    ny = ny or nx
    n = nx * ny
    i = np.arange(n)
    x, y = i % nx, i // nx
    rows, cols, vals = [i], [i], [np.full(n, 4.0)]
    for (dx, dy) in ((1, 0), (-1, 0), (0, 1), (0, -1)):
        ok = (0 <= x + dx) & (x + dx < nx) & (0 <= y + dy) & (y + dy < ny)
        rows.append(i[ok]); cols.append(i[ok] + dx + dy * nx)
        vals.append(np.full(ok.sum(), -1.0))
    return np.concatenate(rows), np.concatenate(cols), np.concatenate(vals), n


def poisson3d(nx: int, ny: Optional[int] = None, nz: Optional[int] = None,
              eps: float = 1.0):
    """7-point Laplacian on an nx x ny x nz grid. SPD, bandwidth nx*ny.
    ``eps`` < 1 makes the y/z couplings anisotropic (harder for block-Jacobi
    — more PCG iterations, the regime of the paper's structural matrices)."""
    ny = ny or nx
    nz = nz or nx
    n = nx * ny * nz
    i = np.arange(n)
    x = i % nx
    y = (i // nx) % ny
    z = i // (nx * ny)
    rows, cols, vals = [i], [i], [np.full(n, 2.0 + 4.0 * eps)]
    for (dx, dy, dz) in ((1, 0, 0), (-1, 0, 0), (0, 1, 0), (0, -1, 0),
                         (0, 0, 1), (0, 0, -1)):
        ok = ((0 <= x + dx) & (x + dx < nx) & (0 <= y + dy) & (y + dy < ny)
              & (0 <= z + dz) & (z + dz < nz))
        w = -1.0 if dx else -eps
        rows.append(i[ok]); cols.append(i[ok] + dx + dy * nx + dz * nx * ny)
        vals.append(np.full(ok.sum(), w))
    return np.concatenate(rows), np.concatenate(cols), np.concatenate(vals), n


def banded_spd(n: int, bandwidth: int, density: float = 0.5, seed: int = 0,
               shift: float = 0.1):
    """Random symmetric banded matrix made SPD by diagonal dominance.

    Mimics the denser-band structural matrices (audikw_1 regime): entries
    within ``bandwidth`` of the diagonal with probability ``density``.
    """
    rng = np.random.default_rng(seed)
    rows_l, cols_l, vals_l = [], [], []
    for off in range(1, bandwidth + 1):
        m = n - off
        mask = rng.random(m) < density
        i = np.arange(m)[mask]
        v = rng.standard_normal(i.size)
        rows_l += [i, i + off]
        cols_l += [i + off, i]
        vals_l += [v, v]
    rows = np.concatenate(rows_l) if rows_l else np.empty(0, np.int64)
    cols = np.concatenate(cols_l) if cols_l else np.empty(0, np.int64)
    vals = np.concatenate(vals_l) if vals_l else np.empty(0)
    # diagonal dominance => SPD
    abssum = np.zeros(n)
    np.add.at(abssum, rows, np.abs(vals))
    rows = np.concatenate([rows, np.arange(n)])
    cols = np.concatenate([cols, np.arange(n)])
    vals = np.concatenate([vals, abssum + shift])
    return rows, cols, vals, n


# --------------------------------------------------------------------------- #
# preconditioners live in repro.precond (registry + jacobi/ssor/chebyshev/
# ic0); the block-Jacobi block extraction and Cholesky-based inverse are
# re-exported here for backward compatibility with the seed API.
# --------------------------------------------------------------------------- #
from repro.precond.jacobi import (block_jacobi_blocks,   # noqa: F401, E402
                                  invert_blocks)


# --------------------------------------------------------------------------- #
# problem container
# --------------------------------------------------------------------------- #
@dataclasses.dataclass
class Problem:
    """A distributed SPD system Ax = b plus its preconditioner.

    ``coo`` is retained host-side: it is the paper's "static data in safe
    storage" from which replacement nodes rebuild ``A_{I_f,I}``, ``P_{I_f,*}``
    and ``b_{I_f}`` during reconstruction (Alg. 2 line 1).
    """

    a: BlockEll
    part: Partition
    b: jax.Array
    pinv_blocks: jax.Array        # (M/b, b, b) inverted block-Jacobi blocks
    diag_blocks: jax.Array        # (M/b, b, b) raw A diagonal blocks (= P^-1)
    precond_block: int
    coo: tuple[np.ndarray, np.ndarray, np.ndarray]
    precond: object = None        # repro.precond.Preconditioner (None/"jacobi"
    #                               keeps the seed block-Jacobi fast paths)

    @property
    def m(self) -> int:
        return self.part.m

    @property
    def precond_name(self) -> str:
        return "jacobi" if self.precond is None else self.precond.name

    def apply_precond(self, r: jax.Array) -> jax.Array:
        """z = P r (jnp reference backend).

        Block-Jacobi keeps the seed's einsum over ``self.pinv_blocks`` —
        bit-identical to the pre-subsystem path and sharding-aware (the
        arrays are re-placed by ``comm.shard.place_problem``); other
        preconditioners delegate to their registered apply."""
        if self.precond is None or self.precond.name == "jacobi":
            rb = r.reshape(-1, self.precond_block)
            return jnp.einsum("nij,nj->ni", self.pinv_blocks, rb).reshape(-1)
        return self.precond.apply(r, backend="jnp")

    def solver_ops(self, backend: str = "auto", batch: int = 0,
                   fused: bool = False):
        """The SolverOps execution bundle for this problem (see
        repro.core.ops). Cached per (backend, batch): the jitted chunk
        runners treat the bundle as a static argument, so reusing the same
        object across solves reuses their compiled code instead of
        re-tracing.

        backend: "auto" (pallas on TPU, jnp elsewhere) | "jnp" | "pallas" |
        "interpret". ``batch`` > 0 returns the batched bundle whose ops
        carry a leading B axis (one dispatch advances B members);
        ``fused=True`` picks its throughput mode (fused-batched einsums,
        per-member ~ulp instead of bit-identical — see core.ops)."""
        from repro.core.ops import make_problem_ops
        if backend == "auto":
            backend = "pallas" if jax.default_backend() == "tpu" else "jnp"
        cache = getattr(self, "_ops_cache", None)
        if cache is None:
            cache = {}
            self._ops_cache = cache
        key = (backend, batch, fused)
        if key not in cache:
            cache[key] = make_problem_ops(self, backend, batch=batch,
                                          fused=fused)
        return cache[key]

    def submatrix_coo(self, row_lo: int, row_hi: int, col_lo: int, col_hi: int):
        """COO of A[row_lo:row_hi, col_lo:col_hi] (for A_ff / inner solves)."""
        rows, cols, vals = self.coo
        ok = (rows >= row_lo) & (rows < row_hi) & (cols >= col_lo) & (cols < col_hi)
        return rows[ok] - row_lo, cols[ok] - col_lo, vals[ok]


def build_problem(kind: str, n_nodes: int, *, bm: int = 8, bn: int = 8,
                  precond_block: int = 10, dtype=np.float64, seed: int = 0,
                  precond: str = "jacobi", precond_opts: dict | None = None,
                  **kw) -> Problem:
    """Build a distributed SPD problem.

    kind: "poisson2d" (nx[, ny]) | "poisson3d" (nx[, ny, nz]) |
          "banded" (n, bandwidth[, density]).

    ``precond`` selects a registered preconditioner ("jacobi" | "ssor" |
    "chebyshev" | "ic0"); ``precond_opts`` passes options through to its
    builder (e.g. omega=1.2 for SSOR, degree=6 for Chebyshev). The
    block-Jacobi diagonal/inverse blocks are always built — they also serve
    as the Alg. 2 line-8 inner-solve preconditioner.

    ``precond_opts={"node_local": True}`` builds the additive-Schwarz
    variant of SSOR/IC(0): the preconditioner sees only the COO entries
    whose row and column are owned by the same node, so its sweeps restrict
    to each node's diagonal slab and partition over the "nodes" mesh axis
    (``comm.shard`` runs them embarrassingly parallel). A no-op for
    block-Jacobi (its blocks never straddle node boundaries); rejected for
    Chebyshev, whose sharded apply distributes through the SpMV instead.

    The problem size is padded (with identity rows) up to
    lcm(n_nodes*bm, n_nodes*bn, n_nodes*precond_block) multiples so that the
    partition constraints hold; padding rows are decoupled (A_ii=1, b_i=0) and
    do not perturb the solution of the original system.
    """
    if kind == "poisson2d":
        rows, cols, vals, m = poisson2d(**kw)
    elif kind == "poisson3d":
        rows, cols, vals, m = poisson3d(**kw)
    elif kind == "banded":
        rows, cols, vals, m = banded_spd(seed=seed, **kw)
    else:
        raise ValueError(f"unknown problem kind {kind!r}")

    unit = n_nodes * int(np.lcm.reduce([bm, bn, precond_block]))
    m_pad = ((m + unit - 1) // unit) * unit
    if m_pad != m:
        pad = np.arange(m, m_pad)
        rows = np.concatenate([rows, pad])
        cols = np.concatenate([cols, pad])
        vals = np.concatenate([vals, np.ones(pad.size)])
    vals = vals.astype(dtype)

    part = Partition(m=m_pad, n_nodes=n_nodes, bm=bm, bn=bn)
    a = BlockEll.from_coo(rows, cols, vals, m_pad, bm, bn, dtype=dtype)
    diag = block_jacobi_blocks(rows, cols, vals, m_pad, precond_block, dtype)
    pinv = invert_blocks(diag)
    from repro import precond as precond_pkg
    opts = dict(precond_opts or {})
    node_local = bool(opts.pop("node_local", False))
    pc_coo = (rows, cols, vals)
    if node_local and precond not in ("jacobi",):
        if precond == "chebyshev":
            raise ValueError(
                "node_local does not apply to chebyshev — its sharded apply "
                "distributes through the SpMV (comm.shard)")
        keep = part.intra_node_mask(rows, cols)
        pc_coo = (rows[keep], cols[keep], vals[keep])
    pc = precond_pkg.build(precond, coo=pc_coo, m=m_pad,
                           block=precond_block, dtype=dtype, a=a,
                           diag_blocks=diag, pinv_blocks=pinv,
                           **opts)
    rng = np.random.default_rng(seed + 1)
    b = rng.standard_normal(m_pad).astype(dtype)
    if m_pad != m:
        b[m:] = 0.0
    return Problem(a=a, part=part, b=jnp.asarray(b),
                   pinv_blocks=jnp.asarray(pinv), diag_blocks=jnp.asarray(diag),
                   precond_block=precond_block, coo=(rows, cols, vals),
                   precond=pc)
