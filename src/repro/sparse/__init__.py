from repro.sparse.partition import Partition
from repro.sparse.blockell import BlockEll
from repro.sparse import matrices

__all__ = ["Partition", "BlockEll", "matrices"]
