"""Solver telemetry: sync-free iteration metrics, nested lifecycle spans,
Chrome-trace/Perfetto + JSONL exporters, and roofline-attributed kernel costs.

The subsystem is strictly opt-in: the solver's hot loop compiles with zero
added ops unless a ``Tracer`` is threaded through ``solve_resilient(obs=...)``
(asserted at jaxpr level in tests/test_obs.py). With a tracer attached:

  * every chunk's norm readback also carries a small on-device metrics ring
    (per-iteration ||r||, rz, storage-push/star flags, the orthogonality
    invariant residual) — a full convergence/event history at zero extra
    dispatches;
  * solver lifecycle phases (chunk dispatch/settle, storage pushes, failure
    injection, the Alg. 2 recovery broken into its line-5/6/8 inner phases
    plus the queue fetch, SDC detect -> repair, elastic re-partition) land as
    nested wall-time spans with byte counters from ``aspmv.RedundancyPlan``
    and ``core.tiers``;
  * the lowered HLO of each dispatched kernel is priced once at build time by
    the seed roofline analyzer (``roofline/hlo_analysis``) and attached as
    FLOP/byte metadata to the trace and to BENCH_*.json.
"""
from repro.obs.export import (chrome_trace, metrics_snapshot, span_tree,
                              validate_chrome_trace, walk_spans,
                              write_chrome_trace, write_jsonl)
from repro.obs.rooflines import kernel_roofline, solver_rooflines
from repro.obs.trace import SCHEMA_VERSION, Span, Tracer, jsonable

__all__ = [
    "SCHEMA_VERSION", "Span", "Tracer", "jsonable",
    "chrome_trace", "write_chrome_trace", "write_jsonl",
    "validate_chrome_trace", "span_tree", "walk_spans", "metrics_snapshot",
    "kernel_roofline", "solver_rooflines",
]
