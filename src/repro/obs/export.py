"""Trace exporters + validators: Chrome-trace/Perfetto JSON, append-only
JSONL event log, Prometheus-style text metrics snapshot, span-tree assembly.

The Chrome JSON object format (https://docs.google.com/document/d/1CvAClvFfyA5R-PhYUmn5OOQtYMH4h6I0nSsKchNAySU)
is what Perfetto's legacy importer and chrome://tracing both read: a
``traceEvents`` list of {name, cat, ph, ts, pid, tid, args} with B/E duration
pairs, "C" counters, and "i" instants. ``validate_chrome_trace`` enforces the
subset this repo emits — sorted timestamps and stack-disciplined B/E pairs
per (pid, tid) — and is what the CI bench-smoke job runs over the emitted
artifact (``python -m repro.obs.validate``).
"""
from __future__ import annotations

import json
import os

from repro.obs.trace import SCHEMA_VERSION, Tracer, jsonable

_PHASES = {"B", "E", "C", "i", "X", "M"}


def chrome_trace(tracer: Tracer) -> dict:
    """The exportable Chrome-trace JSON object for ``tracer``."""
    meta = dict(tracer.meta)
    meta["counters"] = jsonable(tracer.counters)
    return {"traceEvents": list(tracer.events),
            "displayTimeUnit": "ms",
            "metadata": jsonable(meta)}


def write_chrome_trace(tracer: Tracer, path: str) -> str:
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
    with open(path, "w") as f:
        json.dump(chrome_trace(tracer), f)
    return path


def write_jsonl(tracer: Tracer, path: str) -> str:
    """Append-only JSONL event log: one meta line, then one line per trace
    event, then the non-trace records (solve reports). Appending (not
    truncating) lets a sweep accumulate runs into one log."""
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
    with open(path, "a") as f:
        f.write(json.dumps({"type": "meta",
                            **jsonable(dict(tracer.meta))}) + "\n")
        for ev in tracer.events:
            f.write(json.dumps({"type": "event", **ev}) + "\n")
        for rec in tracer.records:
            f.write(json.dumps(rec) + "\n")
    return path


# --------------------------------------------------------------------------- #
def validate_chrome_trace(doc) -> list[str]:
    """Schema/sortedness/B-E-matching errors in a Chrome-trace object (the
    parsed JSON dict). Empty list = valid."""
    errors: list[str] = []
    if not isinstance(doc, dict) or "traceEvents" not in doc:
        return ["missing traceEvents list"]
    events = doc["traceEvents"]
    if not isinstance(events, list):
        return ["traceEvents is not a list"]
    last_ts: dict[tuple, float] = {}
    stacks: dict[tuple, list[str]] = {}
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            errors.append(f"event {i}: not an object")
            continue
        ph = ev.get("ph")
        name = ev.get("name")
        if ph not in _PHASES:
            errors.append(f"event {i} ({name!r}): unknown ph {ph!r}")
            continue
        ts = ev.get("ts")
        if not isinstance(ts, (int, float)):
            errors.append(f"event {i} ({name!r}): non-numeric ts {ts!r}")
            continue
        key = (ev.get("pid", 0), ev.get("tid", 0))
        if key in last_ts and ts < last_ts[key]:
            errors.append(f"event {i} ({name!r}): ts {ts} < previous "
                          f"{last_ts[key]} on track {key}")
        last_ts[key] = ts
        stack = stacks.setdefault(key, [])
        if ph == "B":
            stack.append(name)
        elif ph == "E":
            if not stack:
                errors.append(f"event {i} ({name!r}): E without open B")
            elif stack[-1] != name:
                errors.append(f"event {i}: E {name!r} closes open B "
                              f"{stack[-1]!r}")
                stack.pop()
            else:
                stack.pop()
    for key, stack in stacks.items():
        if stack:
            errors.append(f"track {key}: unclosed span(s) {stack}")
    return errors


def span_tree(events: list[dict]) -> list[dict]:
    """Reconstruct the nested span forest from B/E events. Each node is
    {name, cat, ts, dur_us, args, children}; instants/counters are skipped.
    Used by the well-formedness tests (every recovery span must sit under
    its event span) and by the ``--trace`` per-phase breakdown printers."""
    roots: list[dict] = []
    stacks: dict[tuple, list[dict]] = {}
    for ev in events:
        ph = ev.get("ph")
        if ph not in ("B", "E"):
            continue
        key = (ev.get("pid", 0), ev.get("tid", 0))
        stack = stacks.setdefault(key, [])
        if ph == "B":
            node = dict(name=ev.get("name"), cat=ev.get("cat", ""),
                        ts=ev.get("ts"), dur_us=None, args=ev.get("args", {}),
                        children=[])
            (stack[-1]["children"] if stack else roots).append(node)
            stack.append(node)
        elif stack:
            node = stack.pop()
            node["dur_us"] = ev["ts"] - node["ts"]
            node["args"] = ev.get("args", node["args"])
    return roots


def walk_spans(nodes: list[dict]):
    """Depth-first iterator over a ``span_tree`` forest."""
    for node in nodes:
        yield node
        yield from walk_spans(node["children"])


# --------------------------------------------------------------------------- #
def metrics_snapshot(tracer: Tracer) -> str:
    """Prometheus-style text snapshot: aggregate span wall time + call counts
    by (name, cat), plus the cumulative counters — the serving stack's
    metrics hook (``launch/serve.py --trace``)."""
    agg: dict[tuple[str, str], list[float]] = {}
    for node in walk_spans(span_tree(tracer.events)):
        if node["dur_us"] is None:
            continue
        key = (node["name"], node["cat"])
        tot = agg.setdefault(key, [0, 0.0])
        tot[0] += 1
        tot[1] += node["dur_us"] / 1e6
    lines = [f"# obs metrics snapshot: tracer={tracer.name} "
             f"schema_version={SCHEMA_VERSION}",
             "# TYPE obs_span_seconds_total counter",
             "# TYPE obs_span_calls_total counter",
             "# TYPE obs_counter gauge"]
    for (name, cat), (calls, secs) in sorted(agg.items()):
        labels = f'{{name="{name}",cat="{cat}"}}'
        lines.append(f"obs_span_seconds_total{labels} {secs:.9f}")
        lines.append(f"obs_span_calls_total{labels} {calls}")
    for name, value in sorted(tracer.counters.items()):
        lines.append(f'obs_counter{{name="{name}"}} {value}')
    return "\n".join(lines) + "\n"
