"""CLI trace/bench validator (the CI bench-smoke gate).

    PYTHONPATH=src python -m repro.obs.validate artifacts/obs/failures_trace.json \
        --bench artifacts/bench/BENCH_failures.json \
        --reports artifacts/obs/serve_events.jsonl
    PYTHONPATH=src python -m repro.obs.validate --analysis artifacts/analysis/findings.json

Exit 0 iff: the trace parses, passes the Chrome-trace schema checks (sorted
timestamps, stack-matched B/E pairs); with ``--bench``, the BENCH json
carries roofline FLOP/byte metadata for at least ``--min-kernels`` kernels
(default 3, the PR acceptance bar); and with ``--reports``, every
``solve_report`` record in the JSONL event log satisfies its schema —
report schema_version >= 2 requires consistent ``batch_index`` /
``batch_size`` placement fields (the batched-serving report contract).

``--analysis`` validates a ``repro.analysis`` findings document (the
static-invariant CI artifact) against its schema: version/tool stamp,
entry/pass inventories, and well-formed Finding records whose pass_id and
entry cross-reference the inventories. The trace positional is optional in
this mode.
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.obs.export import validate_chrome_trace

_ROOFLINE_FIELDS = ("flops", "hbm_bytes", "flop_per_byte")


def check_bench_rooflines(doc: dict, min_kernels: int = 3) -> list[str]:
    roofs = doc.get("rooflines")
    if not isinstance(roofs, dict) or not roofs:
        return ["BENCH json lacks a 'rooflines' section"]
    errors = []
    priced = 0
    for name, rec in roofs.items():
        if not isinstance(rec, dict):
            errors.append(f"roofline {name!r}: not an object")
            continue
        if "error" in rec:
            continue                     # a kernel may not lower off-mesh
        missing = [f for f in _ROOFLINE_FIELDS
                   if not isinstance(rec.get(f), (int, float))]
        if missing:
            errors.append(f"roofline {name!r}: missing/non-numeric {missing}")
        else:
            priced += 1
    if priced < min_kernels:
        errors.append(f"only {priced} kernels carry roofline fields "
                      f"(need >= {min_kernels})")
    return errors


def check_report_batch_fields(lines) -> list[str]:
    """Validate the ``solve_report`` records of a JSONL event log.

    Every record must parse and carry a ``schema_version``; version >= 2
    reports (the batched-axis refactor) must place themselves in their
    micro-batch: integer ``batch_index`` / ``batch_size`` with
    0 <= batch_index < max(1, batch_size) (an unbatched solve reports
    index 0 of size 1). Version >= 3 reports (the deadline-aware serving
    front-end) must additionally carry a boolean ``deadline_missed``, an
    integer ``retries`` >= 0, and an integer ``final_n_nodes`` >= 0.
    Returns error strings; also errors when the log holds no solve_report
    at all (an empty gate gates nothing)."""
    errors = []
    n_reports = 0
    for i, line in enumerate(lines):
        line = line.strip()
        if not line:
            continue
        try:
            rec = json.loads(line)
        except json.JSONDecodeError as e:
            errors.append(f"line {i + 1}: unparseable ({e})")
            continue
        if rec.get("type") != "solve_report":
            continue
        n_reports += 1
        data = rec.get("data")
        if not isinstance(data, dict):
            errors.append(f"line {i + 1}: solve_report without data")
            continue
        ver = data.get("schema_version")
        if not isinstance(ver, int):
            errors.append(f"line {i + 1}: missing schema_version")
            continue
        if ver < 2:
            continue                 # pre-batching reports carry no placement
        bi, bs = data.get("batch_index"), data.get("batch_size")
        if not isinstance(bi, int) or not isinstance(bs, int):
            errors.append(f"line {i + 1}: schema_version {ver} report "
                          f"lacks integer batch_index/batch_size "
                          f"(got {bi!r}/{bs!r})")
        elif not 0 <= bi < max(1, bs):
            errors.append(f"line {i + 1}: batch_index {bi} out of range "
                          f"for batch_size {bs}")
        if ver < 3:
            continue                 # pre-serving reports carry no deadline
        dm = data.get("deadline_missed")
        if not isinstance(dm, bool):
            errors.append(f"line {i + 1}: schema_version {ver} report "
                          f"lacks boolean deadline_missed (got {dm!r})")
        for field in ("retries", "final_n_nodes"):
            val = data.get(field)
            if not isinstance(val, int) or isinstance(val, bool) \
                    or val < 0:
                errors.append(f"line {i + 1}: schema_version {ver} report "
                              f"lacks integer {field} >= 0 (got {val!r})")
    if not n_reports:
        errors.append("no solve_report records found")
    return errors


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("trace", nargs="?", default=None,
                    help="Chrome-trace JSON to validate (optional with "
                         "--analysis)")
    ap.add_argument("--bench", default=None,
                    help="BENCH_*.json that must carry roofline fields")
    ap.add_argument("--min-kernels", type=int, default=3)
    ap.add_argument("--reports", default=None,
                    help="JSONL event log whose solve_report records must "
                         "satisfy the report schema (v2+: batch placement)")
    ap.add_argument("--analysis", default=None,
                    help="repro.analysis findings JSON to schema-check")
    args = ap.parse_args(argv)
    if args.trace is None and args.analysis is None:
        ap.error("nothing to validate: give a trace and/or --analysis")

    errors = []
    n_events = 0
    if args.trace:
        with open(args.trace) as f:
            doc = json.load(f)
        errors += [f"{args.trace}: {e}" for e in validate_chrome_trace(doc)]
        n_events = len(doc.get("traceEvents", []))
        if not n_events:
            errors.append(f"{args.trace}: empty traceEvents")
    n_findings = 0
    if args.analysis:
        # jax-free import: the findings schema lives outside the tracer
        from repro.analysis.findings import check_findings_doc
        with open(args.analysis) as f:
            adoc = json.load(f)
        errors += [f"{args.analysis}: {e}" for e in check_findings_doc(adoc)]
        n_findings = len(adoc.get("findings") or [])
    if args.bench:
        with open(args.bench) as f:
            bench = json.load(f)
        errors += [f"{args.bench}: {e}"
                   for e in check_bench_rooflines(bench, args.min_kernels)]
    if args.reports:
        with open(args.reports) as f:
            errors += [f"{args.reports}: {e}"
                       for e in check_report_batch_fields(f)]
    for e in errors:
        print(f"FAIL {e}", file=sys.stderr)
    if not errors:
        parts = []
        if args.trace:
            parts.append(f"{args.trace}: {n_events} events")
        if args.bench:
            parts.append(f"{args.bench}: rooflines present")
        if args.reports:
            parts.append(f"{args.reports}: report schema ok")
        if args.analysis:
            parts.append(f"{args.analysis}: findings schema ok "
                         f"({n_findings} findings)")
        print("OK " + "; ".join(parts))
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
