"""CLI trace/bench validator (the CI bench-smoke gate).

    PYTHONPATH=src python -m repro.obs.validate artifacts/obs/failures_trace.json \
        --bench artifacts/bench/BENCH_failures.json

Exit 0 iff: the trace parses, passes the Chrome-trace schema checks (sorted
timestamps, stack-matched B/E pairs), and — with ``--bench`` — the BENCH
json carries roofline FLOP/byte metadata for at least ``--min-kernels``
kernels (default 3, the PR acceptance bar).
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.obs.export import validate_chrome_trace

_ROOFLINE_FIELDS = ("flops", "hbm_bytes", "flop_per_byte")


def check_bench_rooflines(doc: dict, min_kernels: int = 3) -> list[str]:
    roofs = doc.get("rooflines")
    if not isinstance(roofs, dict) or not roofs:
        return ["BENCH json lacks a 'rooflines' section"]
    errors = []
    priced = 0
    for name, rec in roofs.items():
        if not isinstance(rec, dict):
            errors.append(f"roofline {name!r}: not an object")
            continue
        if "error" in rec:
            continue                     # a kernel may not lower off-mesh
        missing = [f for f in _ROOFLINE_FIELDS
                   if not isinstance(rec.get(f), (int, float))]
        if missing:
            errors.append(f"roofline {name!r}: missing/non-numeric {missing}")
        else:
            priced += 1
    if priced < min_kernels:
        errors.append(f"only {priced} kernels carry roofline fields "
                      f"(need >= {min_kernels})")
    return errors


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("trace", help="Chrome-trace JSON to validate")
    ap.add_argument("--bench", default=None,
                    help="BENCH_*.json that must carry roofline fields")
    ap.add_argument("--min-kernels", type=int, default=3)
    args = ap.parse_args(argv)

    errors = []
    with open(args.trace) as f:
        doc = json.load(f)
    errors += [f"{args.trace}: {e}" for e in validate_chrome_trace(doc)]
    n_events = len(doc.get("traceEvents", []))
    if not n_events:
        errors.append(f"{args.trace}: empty traceEvents")
    if args.bench:
        with open(args.bench) as f:
            bench = json.load(f)
        errors += [f"{args.bench}: {e}"
                   for e in check_bench_rooflines(bench, args.min_kernels)]
    for e in errors:
        print(f"FAIL {e}", file=sys.stderr)
    if not errors:
        print(f"OK {args.trace}: {n_events} events"
              + (f"; {args.bench}: rooflines present" if args.bench else ""))
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
