"""Roofline attribution: price each dispatched solver kernel's lowered HLO
once at build time with the seed analyzer (``roofline/hlo_analysis``).

ROADMAP's "real-accelerator perf campaign" item wants every kernel gated
against a roofline target computed from the HLO, reported in BENCH_*.json.
This module is the bridge: ``solver_rooflines`` lowers the SolverOps bundle's
kernels (SpMV, fused SpMV+dot, preconditioner apply, fused update, and the
whole PCG iteration) against shape-only abstract inputs, runs the while-aware
cost analyzer over the compiled text, and returns FLOP / HBM-byte /
collective-byte counts plus the FLOP/byte arithmetic intensity per kernel.
The driver attaches the result to the trace metadata (``Tracer.meta``) and
``benchmarks/run.py`` embeds it in BENCH_failures.json (CI fails if absent).

Costs are analyzer-model numbers over the *post-optimization* HLO of the
current backend — a per-program traffic floor for relative comparison, not a
measured hardware counter (same caveat as ``roofline/report.py``).
"""
from __future__ import annotations

import jax
import numpy as np

from repro.roofline.hlo_analysis import analyze


def kernel_roofline(fn, *args, label: str = "kernel") -> dict:
    """Lower+compile ``fn`` on the given abstract args and price the HLO.
    Returns a JSON-safe dict; a kernel that cannot lower in this context
    (e.g. a mesh-bound shard_map closure outside its mesh) degrades to an
    ``error`` entry instead of failing the solve."""
    try:
        text = jax.jit(fn).lower(*args).compile().as_text()
        costs = analyze(text)
        out = dict(kernel=label, flops=float(costs.flops),
                   hbm_bytes=float(costs.hbm_bytes),
                   collective_bytes=float(costs.collective_bytes),
                   flop_per_byte=float(costs.flops
                                       / max(costs.hbm_bytes, 1.0)))
        if costs.while_trips:
            out["while_trips"] = {k: int(v)
                                  for k, v in costs.while_trips.items()}
        return out
    except Exception as e:                  # noqa: BLE001 - observability
        return dict(kernel=label, error=f"{type(e).__name__}: {e}")


def solver_rooflines(ops, b) -> dict[str, dict]:
    """FLOP/byte attribution for the kernels a resilient solve dispatches
    through the SolverOps bundle, keyed by kernel name. ``b`` supplies the
    vector shape/dtype (no data is read — lowering is shape-only)."""
    from repro.core.pcg import PCGState, pcg_iterate_ops

    vec = jax.ShapeDtypeStruct(np.shape(b), b.dtype)
    scalar = jax.ShapeDtypeStruct((), b.dtype)
    state = PCGState(x=vec, r=vec, z=vec, p=vec, rz=scalar, beta=scalar,
                     j=jax.ShapeDtypeStruct((), np.int32))
    kernels = {
        "spmv": (ops.matvec, (vec,)),
        "spmv_dot": (ops.matvec_dot, (vec,)),
        "precond": (ops.precond, (vec,)),
        "update": (lambda a, x, r, p, q: ops.update(a, x, r, p, q),
                   (scalar, vec, vec, vec, vec)),
        "iteration": (lambda s: pcg_iterate_ops(s, ops), (state,)),
    }
    return {name: kernel_roofline(fn, *args, label=name)
            for name, (fn, args) in kernels.items()}
