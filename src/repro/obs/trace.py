"""Span tracer + per-iteration metrics accumulator (Chrome-trace event model).

One ``Tracer`` instance accumulates, in memory, an ordered list of Chrome
trace events (``ph`` in B/E/C/i — the subset Perfetto renders), a metadata
dict (roofline attributions, redundancy-plan volumes), cumulative byte/count
counters, and the per-iteration metric history assembled from the chunked
driver's readbacks. Exporters live in ``repro.obs.export``.

Conventions:
  * timestamps are microseconds since tracer creation, strictly increasing
    (two events within the clock's resolution are nudged apart by 1 ns so
    the exported trace is always sorted — a validator requirement);
  * span ``args`` may be mutated while the span is open (``sp.args[...] =``);
    the final values land on the closing "E" event — how the driver attaches
    results (converged?, fetch bytes, inner residuals) to a phase it opened
    before knowing them;
  * per-iteration metrics are stamped at *readback* time, not at iteration
    time: the sync-free protocol reads a whole chunk's ring in one host
    sync, so rows share the settle timestamp and carry the true iteration
    index in their args.

``jsonable`` is the single serialization path shared by the trace exporters,
the JSONL event log, and the report ``to_json`` methods (driver satellite):
device/numpy scalars coerce to Python, arrays to lists, NaN/inf to None.
"""
from __future__ import annotations

import contextlib
import math
import time

import numpy as np

SCHEMA_VERSION = 1


def jsonable(obj):
    """Coerce ``obj`` to JSON-safe types (NaN/inf -> None, numpy/device
    scalars -> Python, arrays/tuples/sets -> lists, dict keys -> str)."""
    if obj is None or isinstance(obj, (bool, int, str)):
        return obj
    if isinstance(obj, float):
        return obj if math.isfinite(obj) else None
    if isinstance(obj, dict):
        return {str(k): jsonable(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple, set)):
        return [jsonable(v) for v in obj]
    if isinstance(obj, np.integer):
        return int(obj)
    if isinstance(obj, (np.floating, np.bool_)):
        return jsonable(obj.item())
    if isinstance(obj, np.ndarray):
        return [jsonable(v) for v in obj.tolist()]
    if hasattr(obj, "__array__"):           # jax.Array (incl. 0-d scalars)
        return jsonable(np.asarray(obj))
    return str(obj)


class Span:
    """Handle for one (possibly still open) span. ``args`` is mutable while
    open; ``dur_s`` is None until the span closes."""

    __slots__ = ("name", "cat", "args", "t0_us", "t1_us")

    def __init__(self, name: str, cat: str, args: dict, t0_us: float):
        self.name = name
        self.cat = cat
        self.args = args
        self.t0_us = t0_us
        self.t1_us: float | None = None

    @property
    def dur_s(self) -> float | None:
        return None if self.t1_us is None else (self.t1_us - self.t0_us) / 1e6

    def __repr__(self):  # pragma: no cover - debugging aid
        state = "open" if self.t1_us is None else f"{self.dur_s:.6f}s"
        return f"Span({self.name!r}, {self.cat!r}, {state})"


class Tracer:
    """Accumulates spans, counters, instants, and iteration metrics."""

    def __init__(self, name: str = "solve"):
        self.name = name
        self._clock0 = time.perf_counter()
        self._last_us = 0.0
        self.events: list[dict] = []      # Chrome trace events, ts-ordered
        self.records: list[dict] = []     # non-trace JSONL records (reports)
        self.meta: dict = {"schema_version": SCHEMA_VERSION, "tracer": name}
        self.counters: dict[str, float] = {}
        self._stack: list[Span] = []
        self._hist_iter: list[int] = []
        self._hist: dict[str, list] = {}

    # ------------------------------------------------------------------ #
    def _ts(self) -> float:
        us = (time.perf_counter() - self._clock0) * 1e6
        if us <= self._last_us:            # clock resolution tie: nudge 1 ns
            us = self._last_us + 1e-3
        self._last_us = us
        return us

    # -- spans --------------------------------------------------------- #
    def begin(self, name: str, cat: str = "solver", **args) -> Span:
        """Open a span (explicit form — pair with ``end``/``close``)."""
        sp = Span(name, cat, dict(args), self._ts())
        self.events.append(dict(name=name, cat=cat, ph="B", ts=sp.t0_us,
                                pid=0, tid=0, args=jsonable(sp.args)))
        self._stack.append(sp)
        return sp

    def end(self, **args) -> Span:
        """Close the innermost open span; ``args`` merge into its ``args``."""
        sp = self._stack.pop()
        sp.args.update(args)
        sp.t1_us = self._ts()
        self.events.append(dict(name=sp.name, cat=sp.cat, ph="E", ts=sp.t1_us,
                                pid=0, tid=0, args=jsonable(sp.args)))
        return sp

    def close(self, sp: Span, **args) -> Span:
        """Close ``sp``, first closing anything still nested inside it (an
        exception may have unwound past inner ``begin``s)."""
        while self._stack and self._stack[-1] is not sp:
            self.end()
        return self.end(**args) if self._stack else sp

    @contextlib.contextmanager
    def span(self, name: str, cat: str = "solver", **args):
        sp = self.begin(name, cat, **args)
        try:
            yield sp
        finally:
            self.close(sp)

    # -- points -------------------------------------------------------- #
    def instant(self, name: str, cat: str = "solver", **args) -> None:
        self.events.append(dict(name=name, cat=cat, ph="i", s="t",
                                ts=self._ts(), pid=0, tid=0,
                                args=jsonable(args)))

    def counter(self, name: str, **values) -> None:
        """Sampled counter event (one Chrome counter track per args key)."""
        self.events.append(dict(name=name, cat="counter", ph="C",
                                ts=self._ts(), pid=0, tid=0,
                                args=jsonable(values)))

    def add_counter(self, name: str, delta, **args) -> float:
        """Cumulative counter: bump the running total and emit it."""
        cur = self.counters.get(name, 0) + delta
        self.counters[name] = cur
        payload = dict(value=cur, **args)
        self.events.append(dict(name=name, cat="counter", ph="C",
                                ts=self._ts(), pid=0, tid=0,
                                args=jsonable(payload)))
        return cur

    def record(self, kind: str, payload) -> None:
        """Append a non-trace record (e.g. a SolveReport) for the JSONL log."""
        self.records.append(dict(type=kind, ts=self._ts(),
                                 data=jsonable(payload)))

    # -- iteration metrics --------------------------------------------- #
    def record_iters(self, iters, **columns) -> None:
        """Append one chunk's per-iteration metric rows (already trimmed to
        the executed count by the caller). ``iters`` are the executed
        iteration indices; each column is a same-length array. Also emits
        one counter event per iteration so the history renders as Perfetto
        counter tracks."""
        idx = np.asarray(iters, np.int64)
        self._hist_iter.extend(int(j) for j in idx)
        cols = {k: np.asarray(v) for k, v in columns.items()}
        for k, v in cols.items():
            if v.shape[0] != idx.shape[0]:
                raise ValueError(f"column {k!r}: {v.shape[0]} rows for "
                                 f"{idx.shape[0]} iterations")
            self._hist.setdefault(k, []).extend(v.tolist())
        for row in range(idx.shape[0]):
            self.events.append(dict(
                name="iteration", cat="metrics", ph="C", ts=self._ts(),
                pid=0, tid=0,
                args=jsonable({"iter": int(idx[row]),
                               **{k: v[row] for k, v in cols.items()}})))

    def iter_history(self) -> dict:
        """The accumulated per-iteration history as numpy columns, sorted by
        iteration with later duplicates winning (a rollback re-executes a
        stretch; the re-run's values are the ones the solve continued from).
        """
        it = np.asarray(self._hist_iter, np.int64)
        last_pos: dict[int, int] = {}
        for pos, j in enumerate(it.tolist()):
            last_pos[j] = pos
        keep = np.asarray([last_pos[j] for j in sorted(last_pos)], np.int64)
        out = {"iter": it[keep] if it.size else it}
        for k, v in self._hist.items():
            arr = np.asarray(v)
            out[k] = arr[keep] if it.size else arr
        return out
