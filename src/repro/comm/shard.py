"""Distributed solver runtime: the paper's MPI cluster on a JAX mesh.

Three layers:

* ``place_problem`` + ``sharded_matvec`` — the production path: block-rows of
  the Block-ELL matrix and all vectors are sharded over a 1-D "nodes" mesh
  axis; the SpMV's halo exchange is an ``all_gather`` of the input vector
  (general sparsity) under ``shard_map``, each device running the
  sequential-k Block-ELL product over its own row slab, and dot products
  reduce as per-node partials + ``psum`` — so the *same* ESRP/IMCR code from
  ``repro.core`` runs distributed unchanged, and ``mesh_mirror_ops`` builds
  the single-device reference bundle with the identical reduction structure
  (the sharded trajectory is bit-identical to it in f64, tested on 8 host
  devices).

* the **device-resident failure story**: ``redundancy_queue`` materializes
  the paper §2.2.1 ASpMV redundancy on the mesh — at every storage push the
  current search direction's column tiles are physically placed on their
  designated holder devices (ring ``ppermute`` sends to the d_{s,k}
  neighbours + retention of the naturally-travelling tiles), rotating
  through the queue-of-3 in ``ESRPState.rq``. ``ShardedFailureRuntime``
  plugs into ``core.driver.solve_resilient``: failure injection is a
  ``shard_map`` operation zeroing only the failed devices' shards (live
  vectors, starred locals, own-queue rows AND the copies the failed device
  held for others), and reconstruction reads p^(j-1), p^(j) for the failed
  rows out of the *surviving devices'* queue shards — never from a
  replicated array — with a device-resident survival check that is stricter
  than the static plan (a copy wiped by an earlier event only revives at
  the next storage push).

* ``ring_halo_matvec`` — the banded-matrix specialization matching the
  paper's point-to-point neighbour sends: each node exchanges only its
  boundary column-tiles with its ±1 ring neighbours via
  ``jax.lax.ppermute`` inside ``shard_map`` (the TPU ICI analogue of the
  paper's MPI sends; ASpMV's designated destinations d_{s,k} are the same
  ring hops). Valid when the sparsity bandwidth fits within one node's
  column range (Poisson-type problems partitioned in slabs).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.analysis.marks import sync_free
from repro.sparse.blockell import BlockEll
from repro.sparse.matrices import Problem


def nodes_mesh(n_nodes: int) -> Mesh:
    return jax.make_mesh((n_nodes,), ("nodes",))


def place_problem(problem: Problem, mesh: Mesh) -> Problem:
    """Shard the static data block-row-wise over the "nodes" axis."""
    a = problem.a
    row_sh = NamedSharding(mesh, P("nodes"))
    vec_sh = NamedSharding(mesh, P("nodes"))
    a2 = BlockEll(jax.device_put(a.data, row_sh),
                  jax.device_put(a.idx, row_sh),
                  jax.device_put(a.nblk, row_sh), a.shape, a.bm, a.bn)
    import dataclasses
    return dataclasses.replace(
        problem, a=a2, b=jax.device_put(problem.b, vec_sh),
        pinv_blocks=jax.device_put(problem.pinv_blocks, row_sh),
        diag_blocks=jax.device_put(problem.diag_blocks, row_sh))


def sharded_matvec(a: BlockEll, mesh: Mesh, batch: int = 0):
    """General-sparsity distributed SpMV under ``shard_map``: all-gather x
    (the halo exchange), then each device runs the *sequential-k* Block-ELL
    product over its own row slab.

    The per-row accumulation order is exactly ``spmv_seq_ref``'s (the jnp
    SolverOps backend), and rows are independent — so the distributed
    product is bit-identical in f64 to the single-device one regardless of
    how XLA partitions the surrounding graph (the free-form einsum the
    previous implementation used re-associated the k×bn reduction
    differently under SPMD partitioning). ``mesh_mirror_ops`` relies on
    this for the single-device reference trajectory.

    ``batch`` > 0: the input is (B, M) with the row axis sharded
    (P(None, "nodes")); ONE all-gather moves every member's halo and each
    device runs the per-member-unrolled sequential product over its slab —
    per member bit-identical to the unbatched sharded product.
    """
    from repro.kernels.spmv.ref import spmv_seq_ref

    if batch:
        @functools.partial(
            shard_map, mesh=mesh,
            in_specs=(P("nodes"), P("nodes"), P(None, "nodes")),
            out_specs=P(None, "nodes"), check_rep=False)
        def mv_b(data, idx, x_local):
            xg = jax.lax.all_gather(x_local, "nodes", axis=1, tiled=True)
            return jnp.stack([spmv_seq_ref(data, idx, xg[i])
                              for i in range(batch)])

        return lambda x: mv_b(a.data, a.idx, x)

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(P("nodes"), P("nodes"), P("nodes")),
        out_specs=P("nodes"), check_rep=False)
    def mv(data, idx, x_local):
        xg = jax.lax.all_gather(x_local, "nodes", tiled=True)
        return spmv_seq_ref(data, idx, xg)

    return lambda x: mv(a.data, a.idx, x)


def _dot_lane(m: int, n_nodes: int, lane: int = 8) -> int:
    """Lane width for the pinned slab dot (the f64 SIMD register width; the
    Block-ELL bn in practice). Falls back to 1 when the slab doesn't tile."""
    slab = m // n_nodes
    return lane if slab % lane == 0 else 1


def _slab_dot(u, v, lane: int):
    """One node's share of a distributed dot, with a *pinned* reduction
    structure: per-``lane``-wide row partials (a fixed-size SIMD reduce XLA
    cannot re-associate) barriered against collapsing, then one flat sum of
    the row partials. A plain local ``u @ v`` compiles to a different
    re-association depending on the surrounding fusion context, which breaks
    the sharded-vs-mirror bit-identity (measured: ~half of random inputs)."""
    p = jnp.einsum("rj,rj->r", u.reshape(-1, lane), v.reshape(-1, lane))
    return jnp.sum(jax.lax.optimization_barrier(p))


def sharded_dot(mesh: Mesh, m: int, lane: int = 8, batch: int = 0):
    """uᵀv for node-sharded vectors: each device reduces its own slab with
    the pinned structure of ``_slab_dot``, then ``psum`` accumulates the
    per-node partials around the ring (sequential order — ``mesh_dot`` is
    the bit-identical single-device form).

    ``batch`` > 0 takes (B, M) inputs and returns the (B,) replicated dot
    vector: per-member pinned slab reductions (the exact unbatched
    subgraph, unrolled) stacked into one psum."""
    n = mesh.shape["nodes"]
    lane = _dot_lane(m, n, lane)

    if batch:
        @functools.partial(shard_map, mesh=mesh,
                           in_specs=(P(None, "nodes"), P(None, "nodes")),
                           out_specs=P(), check_rep=False)
        def dot_b(u, v):
            part = jnp.stack([_slab_dot(u[i], v[i], lane)
                              for i in range(batch)])
            return jax.lax.psum(part, "nodes")

        return dot_b

    @functools.partial(shard_map, mesh=mesh,
                       in_specs=(P("nodes"), P("nodes")), out_specs=P(),
                       check_rep=False)
    def dot(u, v):
        return jax.lax.psum(_slab_dot(u, v, lane), "nodes")

    return dot


def mesh_dot(n_nodes: int, m: int, lane: int = 8):
    """Single-device uᵀv with the mesh's exact reduction structure: the same
    pinned per-slab dots as ``sharded_dot``'s shard_map body, accumulated
    sequentially over the node axis like ``psum`` does around the ring —
    bit-identical in f64 to the distributed dot (``mesh_mirror_ops``)."""
    lane = _dot_lane(m, n_nodes, lane)

    def dot(u, v):
        u8 = u.reshape(n_nodes, -1)
        v8 = v.reshape(n_nodes, -1)
        acc = jnp.zeros((), u.dtype)
        for s in range(n_nodes):
            acc = acc + _slab_dot(u8[s], v8[s], lane)
        return acc

    return dot


def _ensure_node_local(problem: Problem, n: int):
    """Adopt the node-local (additive-Schwarz) twin problem-wide when the
    registered SSOR/IC(0) instance still carries cross-slab coupling, so
    that Alg. 2 recovery reconstructs against the same operator the
    distributed hot loop applies. Clears every closure cache bound to the
    replaced global-sweep operator — including ``_sharded_ops_cache``: a
    same-shape mesh entry built pre-adoption would otherwise keep applying
    the old operator (``jax.make_mesh`` interns equal-shape meshes, so the
    stale entry is reachable from a *fresh* mesh object)."""
    from repro.precond import local as plocal

    pc = problem.precond
    if plocal.precond_is_node_local(pc, n):
        return pc, False
    pc = plocal.node_local_twin(problem)
    problem.precond = pc
    for attr in ("_recon_cache", "_ops_cache", "_closure_ops_cache",
                 "_sharded_ops_cache", "_mesh_mirror_cache"):
        if hasattr(problem, attr):
            delattr(problem, attr)
    assert plocal.precond_is_node_local(pc, n)
    return pc, True


def _sharded_sweep_precond(problem: Problem, mesh: Mesh):
    """Node-local SSOR/IC(0) apply for the sharded runtime.

    The sweeps run under ``shard_map`` with every static strip placed
    block-row-wise: each device substitutes through *its own* diagonal slab
    only — the additive-Schwarz variant, embarrassingly parallel over the
    "nodes" axis (a global sequential sweep would serialize the whole
    distributed iteration). If the problem's preconditioner still carries
    cross-slab coupling, its node-local twin is built from the COO in safe
    storage and **adopted as ``problem.precond``** so that Alg. 2 recovery
    reconstructs against the same operator the hot loop applies.
    Per-row arithmetic matches the single-device node-local reference
    (``build_problem(..., precond_opts={"node_local": True})``) exactly.
    """
    from functools import partial

    from repro.kernels.block_jacobi.ref import block_jacobi_apply_ref
    from repro.kernels.trisweep.ref import block_sweep_ref

    n = mesh.shape["nodes"]
    if n != problem.part.n_nodes:
        # the slab restriction, the twin, and the shard_map index shift all
        # assume one partition slab per mesh device; a mismatched mesh would
        # silently clamp cross-shard loads to wrong blocks
        raise ValueError(
            f"node-local sweeps need one partition slab per mesh device: "
            f"mesh has {n} nodes, partition has {problem.part.n_nodes}")
    pc, adopted = _ensure_node_local(problem, n)
    variant = (f"node-local {pc.name} (auto twin)" if adopted
               else f"node-local {pc.name}")
    per = (pc.m // pc.block) // n
    put = lambda a: jax.device_put(a, NamedSharding(mesh, P("nodes")))

    if pc.name == "ssor":
        statics = tuple(map(put, (pc.lo_idx, pc.lo_n, pc.lo_data, pc.up_idx,
                                  pc.up_n, pc.up_data, pc.dinv,
                                  pc.mid_blocks)))

        @partial(shard_map, mesh=mesh, in_specs=(P("nodes"),) * 9,
                 out_specs=P("nodes"), check_rep=False)
        def apply_local(lo_idx, lo_n, lo_data, up_idx, up_n, up_data, dinv,
                        mid, r):
            base = jax.lax.axis_index("nodes") * per     # global -> slab ids
            y = block_sweep_ref(lo_idx - base, lo_n, lo_data, dinv, r,
                                reverse=False)
            w = block_jacobi_apply_ref(mid, y)
            return block_sweep_ref(up_idx - base, up_n, up_data, dinv, w,
                                   reverse=True)
    else:                                                # ic0
        statics = tuple(map(put, (pc.lo_idx, pc.lo_n, pc.lo_data, pc.up_idx,
                                  pc.up_n, pc.up_data, pc.dinv_f,
                                  pc.dinv_b)))

        @partial(shard_map, mesh=mesh, in_specs=(P("nodes"),) * 9,
                 out_specs=P("nodes"), check_rep=False)
        def apply_local(lo_idx, lo_n, lo_data, up_idx, up_n, up_data,
                        dinv_f, dinv_b, r):
            base = jax.lax.axis_index("nodes") * per
            y = block_sweep_ref(lo_idx - base, lo_n, lo_data, dinv_f, r,
                                reverse=False)
            return block_sweep_ref(up_idx - base, up_n, up_data, dinv_b, y,
                                   reverse=True)

    return (lambda r: apply_local(*statics, r)), variant


def _sharded_chebyshev_precond(problem: Problem, mesh: Mesh):
    """Chebyshev apply for the sharded runtime: the polynomial recurrence
    over the all-gather sharded SpMV — no node-local approximation needed
    (the operator is d distributed matvecs, identical algebra to the
    single-device apply)."""
    from repro.kernels.chebyshev.chebyshev import cheb_recurrence

    pc = problem.precond
    mv = sharded_matvec(problem.a, mesh)
    vec = NamedSharding(mesh, P("nodes"))

    def apply_(r):
        z = cheb_recurrence(mv, r, lo=pc.lo, hi=pc.hi, degree=pc.degree)
        return jax.lax.with_sharding_constraint(z, vec)

    return apply_, "spmv-distributed chebyshev"


def _ops_from_parts(backend, mv, precond, dot, variant, constrain):
    """Assemble the (sharded | mesh-mirror) SolverOps bundle from its parts —
    one definition of the update/dot structure, so the two runtimes cannot
    drift apart numerically. Batch-polymorphic: with (B, M) vectors the
    scalars arrive as (B,) and broadcast over the trailing row axis
    (``_expand`` is the identity on the unbatched path)."""
    from repro.core.ops import SolverOps
    from repro.core.pcg import _expand

    def matvec_dot(p):
        q = mv(p)
        return q, dot(p, q)

    def update(alpha, x, r, p, q):
        a = _expand(alpha, x)
        x_new = constrain(x + a * p)
        r_new = constrain(r - a * q)
        z_new = constrain(precond(r_new))
        return x_new, r_new, z_new, dot(r_new, z_new)

    return SolverOps(backend, mv, matvec_dot, precond, update, variant, dot)


def sharded_solver_ops(problem: Problem, mesh: Mesh, batch: int = 0):
    """SolverOps bundle for the distributed runtime.

    The same ESRP/IMCR core from ``repro.core`` runs through this bundle
    unchanged: the SpMV is the all-gather sharded matvec, every vector
    produced by the update is constrained back to the block-row placement
    (so XLA keeps the whole iteration SPMD-partitioned instead of
    replicating intermediates), and the pᵀq / rᵀz dots lower to per-node
    partials + the natural psum across the "nodes" axis. Cached per
    (problem, mesh): the jitted chunk runners treat the bundle as a static
    argument.

    Every registered preconditioner is accepted: block-Jacobi keeps the
    seed's einsum over re-placed blocks, SSOR/IC(0) run their node-local
    (additive-Schwarz) sweeps under ``shard_map`` (building and adopting
    the twin when the instance still has cross-slab coupling — see
    ``_sharded_sweep_precond``), and Chebyshev distributes through the
    sharded SpMV. ``SolveReport.precond_variant`` records which variant ran;
    compare iteration counts against the global-sweep reference with
    ``attach_local_delta``. ``mesh_mirror_ops`` builds the single-device
    bundle this one is bit-identical to in f64.

    ``batch`` > 0 builds the batched-axis bundle: all vectors are (B, M)
    with the row axis sharded (P(None, "nodes")), the SpMV gathers every
    member's halo in one collective, the dots reduce to a replicated (B,)
    vector, and the preconditioner applies per member (block-Jacobi only —
    the node-local sweeps and Chebyshev recurrence pend).
    """
    cache = getattr(problem, "_sharded_ops_cache", None)
    # unbatched entries keep the bare-mesh key (pre-batch callers index
    # the cache by mesh); batched bundles get their own keys beside them
    key = mesh if not batch else (mesh, batch)
    if cache is not None and key in cache:
        return cache[key]
    n = mesh.shape["nodes"]
    variant = ""
    name = problem.precond_name
    if batch:
        if name != "jacobi":
            raise NotImplementedError(
                f"batched sharded runtime supports the block-Jacobi "
                f"preconditioner only (got {name!r})")
        vec = NamedSharding(mesh, P(None, "nodes"))
        mv = sharded_matvec(problem.a, mesh, batch=batch)
        precond = lambda r: jnp.stack([problem.apply_precond(r[i])
                                       for i in range(batch)])
        dot = sharded_dot(mesh, problem.m, problem.part.bn, batch=batch)
    else:
        vec = NamedSharding(mesh, P("nodes"))
        mv = sharded_matvec(problem.a, mesh)
        if name == "jacobi":
            precond = problem.apply_precond
        elif name == "chebyshev":
            precond, variant = _sharded_chebyshev_precond(problem, mesh)
        elif name in ("ssor", "ic0"):
            precond, variant = _sharded_sweep_precond(problem, mesh)
        else:
            raise NotImplementedError(
                f"sharded runtime has no distributed apply for "
                f"preconditioner {name!r}")
        dot = sharded_dot(mesh, problem.m, problem.part.bn)
    constrain = lambda v: jax.lax.with_sharding_constraint(v, vec)
    ops = _ops_from_parts("sharded", mv, precond, dot, variant, constrain)
    # re-fetch: building the bundle may have *cleared* the cache attribute
    # (twin adoption drops every closure cache, this one included)
    cache = getattr(problem, "_sharded_ops_cache", None)
    if cache is None:
        cache = {}
        problem._sharded_ops_cache = cache
    cache[key] = ops
    return ops


def mesh_mirror_ops(problem: Problem, n_nodes: int, batch: int = 0):
    """Single-device SolverOps with the *mesh's* reduction structure: the
    sequential-k SpMV, per-node partial dots summed over the node axis, and
    the same preconditioner variant the sharded runtime applies (adopting
    the node-local twin exactly like ``_sharded_sweep_precond`` would).

    This is the single-device reference trajectory the sharded runtime
    rejoins **bit-identically in f64** — the distributed analogue of the
    jnp-backend's kernel-mirrored reduction order. Use it as the reference
    for sharded parity/scenario tests; against the plain jnp backend only
    iteration-count equality holds (flat vs per-node dot association).

    ``batch`` > 0 mirrors the batched sharded bundle: every op unrolls the
    unbatched mesh-structured subgraph per member, so the batched sharded
    trajectory rejoins this reference bit-identically per member.
    """
    cache = getattr(problem, "_mesh_mirror_cache", None)
    if cache is None:
        cache = {}
        problem._mesh_mirror_cache = cache
    key = (n_nodes, batch)
    if key not in cache:
        from repro.kernels.spmv.ref import spmv_seq_ref

        if n_nodes != problem.part.n_nodes:
            raise ValueError(
                f"mesh mirror needs one partition slab per simulated node: "
                f"asked n={n_nodes}, partition has {problem.part.n_nodes}")
        a = problem.a
        matvec = lambda x: spmv_seq_ref(a.data, a.idx, x)
        variant = ""
        name = problem.precond_name
        if batch:
            if name != "jacobi":
                raise NotImplementedError(
                    f"batched mesh mirror supports the block-Jacobi "
                    f"preconditioner only (got {name!r})")
            mv1, dot1 = matvec, mesh_dot(n_nodes, problem.m, problem.part.bn)
            cache[key] = _ops_from_parts(
                "mesh-mirror",
                lambda x: jnp.stack([mv1(x[i]) for i in range(batch)]),
                lambda r: jnp.stack([problem.apply_precond(r[i])
                                     for i in range(batch)]),
                lambda u, v: jnp.stack([dot1(u[i], v[i])
                                        for i in range(batch)]),
                "mesh-mirror", lambda v: v)
            return cache[key]
        if name == "jacobi":
            precond = problem.apply_precond
        elif name == "chebyshev":
            from repro.kernels.chebyshev.chebyshev import cheb_recurrence

            pc = problem.precond
            precond = lambda r: cheb_recurrence(matvec, r, lo=pc.lo,
                                                hi=pc.hi, degree=pc.degree)
            variant = "spmv-distributed chebyshev"
        elif name in ("ssor", "ic0"):
            pc, adopted = _ensure_node_local(problem, n_nodes)
            precond = lambda r: pc.apply(r, backend="jnp")
            variant = (f"node-local {pc.name} (auto twin)" if adopted
                       else f"node-local {pc.name}")
            cache = {}
            problem._mesh_mirror_cache = cache    # adoption dropped the attr
        else:
            raise NotImplementedError(name)
        cache[key] = _ops_from_parts(
            "mesh-mirror", matvec, precond,
            mesh_dot(n_nodes, problem.m, problem.part.bn),
            f"mesh-mirror {variant}".strip(), lambda v: v)
    return cache[key]


def attach_local_delta(report, reference) -> None:
    """Record on ``report`` the iteration-count delta of the node-local
    (additive-Schwarz) run vs the global-sweep reference solve — the price
    of making the sweeps partition over the mesh axis. If either run
    stopped at max_iters without converging, ``converged_iter`` is just
    where the budget ran out and the delta would be meaningless — left
    ``None``."""
    if not (report.converged and reference.converged):
        report.local_delta_iters = None
        return
    report.local_delta_iters = report.converged_iter - reference.converged_iter


# --------------------------------------------------------------------------- #
# banded specialization: ppermute halo exchange (the paper's neighbour sends)
# --------------------------------------------------------------------------- #
def ring_halo_matvec(a: BlockEll, part, mesh: Mesh, halo_tiles: int):
    """Banded SpMV with explicit ±1 ring halo exchange.

    Requires every referenced column tile of node s to lie within
    [s's first tile - halo_tiles, s's last tile + halo_tiles] — checked at
    build time against the sparsity structure. ``halo_tiles`` column tiles
    are sent to each ring neighbour per product (the paper's I_{s,s±1});
    communication volume = 2 * halo_tiles * bn * itemsize per node.
    """
    n = part.n_nodes
    cpt = part.col_tiles_per_node
    if n < 2:
        # a 1-node "ring" sends both halos to itself; ppermute with self
        # edges silently yields zeros — reject at build time
        raise ValueError(
            f"ring halo exchange needs >= 2 nodes, got n_nodes={n}")
    if not 1 <= halo_tiles <= cpt:
        # halo_tiles > cpt would make xt[-halo_tiles:] silently slice the
        # whole slab (and halo_tiles = 0 the empty one), failing later with
        # an opaque concatenate shape error instead of here
        raise ValueError(
            f"halo_tiles={halo_tiles} must be within [1, col_tiles_per_node"
            f"={cpt}]: each node can only send tiles it owns")
    # static check: band fits the halo
    idx = np.asarray(a.idx)
    nblk = np.asarray(a.nblk)
    rpt = part.row_tiles_per_node
    for s in range(n):
        rows = slice(s * rpt, (s + 1) * rpt)
        valid = idx[rows][np.arange(a.kmax)[None, :] < nblk[rows][:, None]]
        if valid.size and (valid.min() < s * cpt - halo_tiles
                           or valid.max() >= (s + 1) * cpt + halo_tiles):
            raise ValueError(f"node {s}: sparsity exceeds halo_tiles="
                             f"{halo_tiles}")

    bn = a.bn

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(P("nodes"), P("nodes"), P("nodes")),
        out_specs=P("nodes"), check_rep=False)
    def mv(data, idx_l, x):
        # x: local slab (rows_per_node,) -> tiles (cpt, bn)
        xt = x.reshape(cpt, bn)
        lo = jax.lax.ppermute(xt[-halo_tiles:], "nodes",
                              [(i, (i + 1) % n) for i in range(n)])
        hi = jax.lax.ppermute(xt[:halo_tiles], "nodes",
                              [(i, (i - 1) % n) for i in range(n)])
        ext = jnp.concatenate([lo, xt, hi], axis=0)   # (cpt + 2*halo, bn)
        me = jax.lax.axis_index("nodes")
        base = me * cpt - halo_tiles
        local_idx = jnp.clip(idx_l - base, 0, ext.shape[0] - 1)
        gathered = ext[local_idx]                     # (rpt, kmax, bn)
        y = jnp.einsum("rkij,rkj->ri", data, gathered)
        return y.reshape(-1)

    return lambda x: mv(a.data, a.idx, x)


# --------------------------------------------------------------------------- #
# physical ASpMV redundancy (paper §2.2.1 on the ICI ring)
# --------------------------------------------------------------------------- #
def _designated_sends(plan, part):
    """Host-side static send lists for the §2.2.1 redundancy pushes: for
    each k in 1..phi, an (n_nodes, width_k) int32 array of the column tiles
    node s ships to its designated destination d_{s,k} (-1 = padding) —
    every tile of s the destination holds after one ASpMV (natural + extra,
    i.e. the queue entry the buddy can serve after a failure) — plus the
    matching ppermute edge list."""
    from repro.sparse.partition import neighbor

    n = part.n_nodes
    send_idx_k, perms = [], []
    for k in range(1, plan.phi + 1):
        rows = []
        for s in range(n):
            d = neighbor(s, k, n)
            lo, hi = part.node_col_tiles(s)
            rows.append([t for t in range(lo, hi) if plan.holders[t, d]
                         and part.owner_of_col_tile(t) == s])
        width = max(len(r) for r in rows)
        idx = np.full((n, width), -1, np.int32)
        for s, r in enumerate(rows):
            idx[s, :len(r)] = r
        send_idx_k.append(idx)
        perms.append([(s, neighbor(s, k, n)) for s in range(n)])
    return send_idx_k, perms


def aspmv_push(plan, part, mesh: Mesh):
    """Materialize the augmented-SpMV redundancy sends as ring ppermutes.

    For each k in 1..phi, every node sends the column tiles of the input
    vector listed in I_{s,d_{s,k}} ∪ R^c_{s,k} to its designated neighbour
    d_{s,k} (Eq. 1) — one ``collective-permute`` per k, payload padded to the
    largest per-node send count (static shape). Returns a function
    ``push(x) -> list over k of (recv_tiles, recv_idx)`` where node d's row
    of ``recv_tiles`` holds the tile values it received (its share of the
    paper's redundancy queue entry) and ``recv_idx`` the *global* column-tile
    ids (-1 = padding). ``redundancy_queue`` is the hot-loop form: the same
    sends scattered straight into the device-resident queue entry.
    """
    from functools import partial

    n = part.n_nodes
    cpt = part.col_tiles_per_node
    bn = part.bn
    send_idx_k, perms = _designated_sends(plan, part)

    def make_one(k):
        idx = jax.device_put(jnp.asarray(send_idx_k[k]),
                             NamedSharding(mesh, P("nodes")))
        perm = perms[k]

        @partial(shard_map, mesh=mesh, in_specs=(P("nodes"), P("nodes")),
                 out_specs=(P("nodes"), P("nodes")), check_rep=False)
        def push(x_local, idx_local):
            xt = x_local.reshape(cpt, bn)
            me = jax.lax.axis_index("nodes")
            local = jnp.clip(idx_local[0] - me * cpt, 0, cpt - 1)
            payload = jnp.where((idx_local[0] >= 0)[:, None], xt[local], 0.0)
            recv = jax.lax.ppermute(payload, "nodes", perm)
            recv_idx = jax.lax.ppermute(idx_local[0], "nodes", perm)
            return recv[None], recv_idx[None]

        return lambda x: push(x, idx)

    fns = [make_one(k) for k in range(plan.phi)]
    return lambda x: [f(x) for f in fns]


def redundancy_queue(plan, part, mesh: Mesh, batch: int = 0):
    """Device-resident ASpMV redundancy queue entry (paper §2.2.1).

    One push physically places, on every node d, a copy of each column tile
    the plan says d holds for another owner: the designated sends travel as
    the same ring ``ppermute``s as ``aspmv_push`` (one hop per k — the
    paper's explicit redundancy traffic), and tiles that already travel
    *naturally* to a non-designated receiver are retained out of the
    all-gather the SpMV performs anyway (the ESR zero-extra-communication
    insight). Returns ``(hold_idx, push)``:

      hold_idx  (n_nodes, width) int32, static: hold_idx[d, j] is the global
                column tile whose copy lives in slot j of node d's queue
                entry (-1 = padding).
      push      x -> (n_nodes, width, bn): node d's row holds the tile
                values it received/retained this push — its physical share
                of the redundancy queue, sharded over the "nodes" axis.

    ``batch`` > 0 pushes all B members' directions in the same collectives:
    x is (B, M) (row axis sharded), the payload of each ppermute is
    (B, width_k, bn), and the entry comes back (B, n_nodes, width, bn) with
    the node axis sharded — per member identical to the unbatched entry
    (every op is data movement; nothing reduces across members).
    """
    from functools import partial

    from repro.sparse.partition import neighbor

    n = part.n_nodes
    cpt = part.col_tiles_per_node
    bn = part.bn
    ct = part.col_tiles
    owner = part.owner_of_col_tile(np.arange(ct))

    hold_rows = [np.nonzero(plan.holders[:, d] & (owner != d))[0]
                 for d in range(n)]
    width = max((r.size for r in hold_rows), default=0)
    if width == 0:
        raise ValueError("redundancy plan holds no off-owner copies — "
                         "nothing to queue (n_nodes < 2?)")
    hold_idx = np.full((n, width), -1, np.int32)
    slot_of = [dict() for _ in range(n)]
    for d, r in enumerate(hold_rows):
        hold_idx[d, :r.size] = r
        slot_of[d].update({int(t): j for j, t in enumerate(r)})

    send_idx_k, perms = _designated_sends(plan, part)
    # per k: the receiving slot of each ppermute lane (node d receives the
    # tiles its k-th *reverse* neighbour sent; the lane order is the
    # sender's, so map sender-lane tile -> receiver hold slot)
    recv_slot_k = []
    for k in range(plan.phi):
        wk = send_idx_k[k].shape[1]
        rs = np.full((n, wk), -1, np.int32)
        for s in range(n):
            d = neighbor(s, k + 1, n)
            for j, t in enumerate(send_idx_k[k][s]):
                if t >= 0:
                    rs[d, j] = slot_of[d][int(t)]
        recv_slot_k.append(rs)
    # natural retention: hold tiles not covered by any designated send
    covered = [set() for _ in range(n)]
    for k in range(plan.phi):
        for s in range(n):
            d = neighbor(s, k + 1, n)
            covered[d].update(int(t) for t in send_idx_k[k][s] if t >= 0)
    nat_rows = [[t for t in hold_rows[d] if int(t) not in covered[d]]
                for d in range(n)]
    wn = max(len(r) for r in nat_rows)
    nat_idx = np.full((n, max(wn, 1)), -1, np.int32)
    nat_slot = np.full((n, max(wn, 1)), -1, np.int32)
    for d, r in enumerate(nat_rows):
        for j, t in enumerate(r):
            nat_idx[d, j] = t
            nat_slot[d, j] = slot_of[d][int(t)]

    put = lambda a: jax.device_put(jnp.asarray(a),
                                   NamedSharding(mesh, P("nodes")))
    statics = ([put(i) for i in send_idx_k] + [put(r) for r in recv_slot_k]
               + [put(nat_idx), put(nat_slot)])
    phi = plan.phi

    if batch:
        out_sh = NamedSharding(mesh, P(None, "nodes"))

        @partial(shard_map, mesh=mesh,
                 in_specs=(P(None, "nodes"),) + (P("nodes"),) * len(statics),
                 out_specs=P(None, "nodes"), check_rep=False)
        def push_b(x_local, *stat):
            send = stat[:phi]
            rslot = stat[phi:2 * phi]
            nidx, nslot = stat[2 * phi], stat[2 * phi + 1]
            xt = x_local.reshape(batch, cpt, bn)
            me = jax.lax.axis_index("nodes")
            buf = jnp.zeros((batch, width + 1, bn), x_local.dtype)
            for k in range(phi):
                sidx = send[k][0]
                local = jnp.clip(sidx - me * cpt, 0, cpt - 1)
                payload = jnp.where((sidx >= 0)[None, :, None],
                                    xt[:, local], 0.0)
                recv = jax.lax.ppermute(payload, "nodes", perms[k])
                slot = rslot[k][0]
                buf = buf.at[:, jnp.where(slot >= 0, slot, width)].set(recv)
            if wn:
                xg = jax.lax.all_gather(xt, "nodes", axis=1, tiled=True)
                ni, ns = nidx[0], nslot[0]
                vals = xg[:, jnp.clip(ni, 0, ct - 1)]
                buf = buf.at[:, jnp.where(ns >= 0, ns, width)].set(vals)
            return buf[:, None, :width]

        fn_b = sync_free(lambda x: jax.lax.with_sharding_constraint(
            push_b(x, *statics), out_sh))
        return hold_idx, fn_b

    out_sh = NamedSharding(mesh, P("nodes"))

    @partial(shard_map, mesh=mesh,
             in_specs=(P("nodes"),) * (1 + len(statics)),
             out_specs=P("nodes"), check_rep=False)
    def push(x_local, *stat):
        send = stat[:phi]
        rslot = stat[phi:2 * phi]
        nidx, nslot = stat[2 * phi], stat[2 * phi + 1]
        xt = x_local.reshape(cpt, bn)
        me = jax.lax.axis_index("nodes")
        # scratch row `width` swallows the padding-lane writes, so a pad can
        # never overwrite (or 0.0-perturb) a real slot
        buf = jnp.zeros((width + 1, bn), x_local.dtype)
        for k in range(phi):
            sidx = send[k][0]
            local = jnp.clip(sidx - me * cpt, 0, cpt - 1)
            payload = jnp.where((sidx >= 0)[:, None], xt[local], 0.0)
            recv = jax.lax.ppermute(payload, "nodes", perms[k])
            slot = rslot[k][0]
            buf = buf.at[jnp.where(slot >= 0, slot, width)].set(recv)
        if wn:
            xg = jax.lax.all_gather(xt, "nodes", tiled=True)   # (ct, bn)
            ni, ns = nidx[0], nslot[0]
            vals = xg[jnp.clip(ni, 0, ct - 1)]
            buf = buf.at[jnp.where(ns >= 0, ns, width)].set(vals)
        return buf[None, :width]

    # the push runs inside sync-free chunk bodies: collectives only, no
    # host round-trip (registered with the repro.analysis host-sync pass)
    fn = sync_free(lambda x: jax.lax.with_sharding_constraint(
        push(x, *statics), out_sh))
    return hold_idx, fn


def _node_axis_zeroer(mesh: Mesh, axis: int):
    """shard_map op zeroing entire shards of the devices flagged in ``dead``
    — the physical failure injection (no gather/replicate round-trip; each
    device tests only its own axis index). ``axis`` is the array axis the
    "nodes" mesh axis shards."""
    spec = P(*([None] * axis + ["nodes"]))

    @functools.partial(shard_map, mesh=mesh, in_specs=(spec, P()),
                       out_specs=spec, check_rep=False)
    def zero(v, dead):
        me = jax.lax.axis_index("nodes")
        return jnp.where(dead[me], jnp.zeros_like(v), v)

    return zero


class ShardedFailureRuntime:
    """Device-resident failure semantics for ``solve_resilient`` on the mesh.

    Plugs the three physical pieces into the driver:

      * ``init_queue`` / ``queue_push`` — the ``ESRPState.rq`` redundancy
        queue: per-device copies physically placed on the designated
        neighbours at every storage push (``redundancy_queue``).
      * ``lose_esrp`` / ``lose_pcg`` — failure injection as a ``shard_map``
        zeroing of the failed devices' shards only: live vectors, starred
        locals, the device's own queue rows AND the copies it held for
        others (a failed node loses everything node-resident, paper §4).
      * ``assemble_pair`` — reconstruction inputs: p^(j-1), p^(j) restricted
        to the failed rows are read from *surviving devices'* ``rq`` shards
        (host-side static source choice via ``RedundancyPlan.copy_sources``,
        stricter than the static plan: copies wiped by an earlier event are
        stale until the next storage push refreshes them).

    Also accounts the per-preconditioner static-state reload a replacement
    node performs (``precond.local.static_reload_bytes``) —
    ``EventReport.precond_reload_bytes``.
    """

    def __init__(self, problem: Problem, mesh: Mesh, batch: int = 0):
        n = mesh.shape["nodes"]
        if n != problem.part.n_nodes:
            raise ValueError(
                f"failure runtime needs one partition slab per mesh device: "
                f"mesh has {n} nodes, partition has {problem.part.n_nodes}")
        self.problem = problem
        self.mesh = mesh
        self.n = n
        self.part = problem.part
        self.batch = batch  # > 0: the runtime serves the batched (B, M)
        #                     solve — queue entries and injections carry the
        #                     member axis; one event strikes all B members
        self.plan = None
        self.queue_push = None
        self._hold_idx = None
        self._slot_of = None
        self._queues = {}   # phi -> (hold_idx, push, slot_of): the push
        #                     closure must keep a stable identity across
        #                     solves (the jitted chunk runners key their
        #                     compile cache on it)
        self._zeroers = {}  # sharded-node-axis index -> shard_map zeroer
        #                     (vectors/queues of both the unbatched and
        #                     batched layouts resolve their axis by ndim)
        self._wiped: dict[int, int] = {}   # device -> newest q tag when its
        #                                    held copies were zeroed
        self.last_sources: tuple[int, ...] = ()

    def _zero(self, v, dead, axis: int):
        z = self._zeroers.get(axis)
        if z is None:
            z = self._zeroers[axis] = _node_axis_zeroer(self.mesh, axis)
        return z(v, dead)

    # -- driver hooks ------------------------------------------------------ #
    def bind_plan(self, plan) -> None:
        """Called by the driver once the RedundancyPlan exists: build (or
        reuse — the driver builds a fresh plan object per solve, but the
        layout only depends on φ) the physical queue layout + push closure,
        and reset the wiped-copy tracking for the new run."""
        self.plan = plan
        self._wiped.clear()
        entry = self._queues.get(plan.phi)
        if entry is None:
            hold_idx, push = redundancy_queue(plan, self.part, self.mesh,
                                              batch=self.batch)
            slot_of = [{int(t): j for j, t in enumerate(row) if t >= 0}
                       for row in hold_idx]
            entry = self._queues[plan.phi] = (hold_idx, push, slot_of)
        self._hold_idx, self.queue_push, self._slot_of = entry

    def init_queue(self, st, reset: bool = False):
        """Attach the empty (3, n, width, bn) device-resident queue to a
        fresh ESRPState (placed on the node axis; (3, B, n, width, bn) on
        the batched runtime). reset=True also forgets wiped-copy tracking
        (a restart rebuilds everything from scratch)."""
        if reset:
            self._wiped.clear()
        w = self._hold_idx.shape[1]
        if self.batch:
            rq = jax.device_put(
                jnp.zeros((3, self.batch, self.n, w, self.part.bn),
                          self.problem.b.dtype),
                NamedSharding(self.mesh, P(None, None, "nodes")))
        else:
            rq = jax.device_put(
                jnp.zeros((3, self.n, w, self.part.bn),
                          self.problem.b.dtype),
                NamedSharding(self.mesh, P(None, "nodes")))
        st = st._replace(rq=rq)
        if not isinstance(st.q_sums, tuple):
            # per-holder checksums of the physical copies ride along with the
            # host-visible q checksums (same push-time write protocol);
            # batched entries checksum per member: (3, B, n)
            if self.batch:
                st = st._replace(rq_sums=jax.device_put(
                    jnp.zeros((3, self.batch, self.n),
                              self.problem.b.dtype),
                    NamedSharding(self.mesh, P(None, None, "nodes"))))
            else:
                st = st._replace(rq_sums=jax.device_put(
                    jnp.zeros((3, self.n), self.problem.b.dtype),
                    NamedSharding(self.mesh, P(None, "nodes"))))
        return st

    def _dead(self, failed) -> jnp.ndarray:
        dead = np.zeros(self.n, bool)
        dead[list(failed)] = True
        return jnp.asarray(dead)

    def lose_pcg(self, pcg, failed):
        """Zero the failed devices' shards of the live vectors (x, r, z, p).
        The sharded node axis is resolved by rank — (M,) and batched (B, M)
        vectors both shard their last axis — so one injection covers both
        layouts (a fail-stop event wipes a device's rows for all B members
        at once)."""
        dead = self._dead(failed)
        l = lambda v: self._zero(v, dead, v.ndim - 1)
        return pcg._replace(x=l(pcg.x), r=l(pcg.r), z=l(pcg.z), p=l(pcg.p))

    def lose_esrp(self, st, failed):
        """Full §4 injection for an ESRPState: live vectors, starred locals,
        the failed devices' own queue rows, and the redundancy copies they
        held for others (their ``rq`` rows)."""
        dead = self._dead(failed)
        l = lambda v: self._zero(v, dead, v.ndim - 1)
        st = st._replace(
            pcg=self.lose_pcg(st.pcg, failed),
            x_s=l(st.x_s), r_s=l(st.r_s), z_s=l(st.z_s), p_s=l(st.p_s),
            q=self._zero(st.q, dead, st.q.ndim - 1))
        if not isinstance(st.rq, tuple):
            # (3, n, w, bn) or batched (3, B, n, w, bn): holder axis is
            # always three from the end
            st = st._replace(rq=self._zero(st.rq, dead, st.rq.ndim - 3))
        # keep checksums consistent with the zeroed copies (sum of zeros = 0)
        # so the wipe itself never reads as queue corruption; the dead-holder
        # column broadcasts over every leading axis ((3, n), (3, B, n), and
        # per-slab (3, ..., n_slabs) layouts alike — the latter only when the
        # slab count equals the node count, hence the shape guard)
        def _wipe_col(sums):
            col = jnp.asarray(self._dead(failed)).reshape(
                (1,) * (sums.ndim - 1) + (-1,))
            return jnp.where(col, 0, sums)
        if not isinstance(st.q_sums, tuple) \
                and st.q_sums.shape[-1] == self.n:
            st = st._replace(q_sums=_wipe_col(st.q_sums))
        if not isinstance(st.rq_sums, tuple):
            st = st._replace(rq_sums=_wipe_col(st.rq_sums))
        return st

    def lose_live(self, st, failed):
        """SDC-repair injection: discard the flagged devices' live vectors
        and starred locals but keep their queue rows and held copies —
        nothing was physically lost, the stored redundancy is still intact
        (and checksum-verified at read time)."""
        dead = self._dead(failed)
        l = lambda v: self._zero(v, dead, v.ndim - 1)
        return st._replace(pcg=self.lose_pcg(st.pcg, failed),
                           x_s=l(st.x_s), r_s=l(st.r_s), z_s=l(st.z_s),
                           p_s=l(st.p_s))

    def mark_wiped(self, failed, newest_tag: int) -> None:
        """Record that the failed devices' held copies are gone: every queue
        entry tagged <= ``newest_tag`` has their rows zeroed. Only entries
        pushed *later* (a strictly newer tag) carry fresh copies again."""
        for d in failed:
            self._wiped[int(d)] = int(newest_tag)

    def _checksum_valid(self, st, slots) -> np.ndarray:
        """Read-time verification of the device-resident copies: recompute
        each holder's checksum for the slots about to be read and exclude
        holders whose stored copy no longer matches its push-time checksum
        (a corrupted copy must never enter Alg. 2 — ``copy_sources`` falls
        back to an alternate holder, or raises when none is left). The
        comparison is tolerance-based (differing jit contexts may reduce in
        a different order) and NaN-unsafe values compare as corrupt."""
        if isinstance(getattr(st, "rq_sums", ()), tuple):
            return np.ones(self.n, bool)
        ok = np.ones(self.n, bool)
        for slot in sorted({int(s) for s in slots}):
            # (n, w, bn) or batched (B, n, w, bn): reduce the tile axes,
            # leaving per-holder (or per-member-per-holder) sums
            actual = np.asarray(
                jax.device_get(st.rq[slot]).sum(axis=(-2, -1)))
            ref = np.asarray(jax.device_get(st.rq_sums[slot]))
            good = np.abs(actual - ref) <= 1e-9 * (np.abs(ref) + 1.0)
            if good.ndim == 2:
                # a holder is usable only if EVERY member's copy verifies —
                # Alg. 2 assembles the whole batch from one source choice
                good = good.all(axis=0)
            ok &= good
        return ok

    def _valid_sources(self, read_tag: int) -> np.ndarray:
        """Which devices hold fresh copies in a queue entry tagged
        ``read_tag``. Must be the tag of the *oldest slot actually read* —
        validating against the newest tag would declare a device fresh as
        soon as any later push landed, even though recovery falls back to a
        pre-refresh slot pair whose rows are still zero (e.g. a second
        failure striking exactly on a stage's first push)."""
        return np.array([d not in self._wiped
                         or read_tag > self._wiped[d]
                         for d in range(self.n)])

    def assemble_pair(self, st, prev_slot: int, curr_slot: int, failed):
        """Rebuild full-length p^(j-1), p^(j): surviving rows from each
        node's own queue history (``st.q`` — failed rows were zeroed by the
        injection), failed rows gathered from the surviving devices'
        device-resident ``rq`` shards. Returns (p_prev, p_curr, sources)."""
        from repro.core import failures

        oldest_read = int(st.q_tags[prev_slot])
        valid = self._valid_sources(oldest_read)
        valid &= self._checksum_valid(st, (prev_slot, curr_slot))
        tiles, src = self.plan.copy_sources(failed, valid)
        slots = np.array([self._slot_of[int(d)][int(t)]
                          for t, d in zip(tiles, src)], np.int32)
        f_rows = jnp.asarray(failures.failed_rows(self.part, list(failed)))
        src_j = jnp.asarray(src.astype(np.int32))
        slots_j = jnp.asarray(slots)

        def fill(slot):
            if self.batch:
                # (3, B, n, w, bn): gather the same (holder, slot) pairs for
                # every member — one Alg. 2 assembly serves the whole batch
                vals = st.rq[slot][:, src_j, slots_j]    # (B, n_tiles, bn)
                return st.q[slot].at[:, f_rows].set(
                    vals.reshape(self.batch, -1))
            vals = st.rq[slot][src_j, slots_j]           # (n_tiles, bn)
            return st.q[slot].at[f_rows].set(vals.reshape(-1))

        self.last_sources = tuple(sorted({int(d) for d in src}))
        return fill(prev_slot), fill(curr_slot), self.last_sources

    def precond_reload(self, failed):
        """Per-preconditioner-state survival check + safe-storage reload
        accounting for the replacement nodes (SSOR/IC(0) slab strips rebuild
        from the COO; Chebyshev bounds are replicated scalars; block-Jacobi
        reloads its inverted diagonal blocks)."""
        from repro.precond.local import static_reload_bytes

        return static_reload_bytes(self.problem, failed)
