"""Distributed solver runtime: the paper's MPI cluster on a JAX mesh.

Two layers:

* ``place_problem`` + ``sharded_matvec`` — the production path: block-rows of
  the Block-ELL matrix and all vectors are sharded over a 1-D "nodes" mesh
  axis; the SpMV's halo exchange is an ``all_gather`` of the input vector
  (general sparsity), and dot products reduce across nodes — plain jit +
  NamedSharding, so the *same* ESRP/IMCR code from ``repro.core`` runs
  distributed unchanged (tested on 8 host devices in
  tests/test_solver_multidevice.py).

* ``ring_halo_matvec`` — the banded-matrix specialization matching the
  paper's point-to-point neighbour sends: each node exchanges only its
  boundary column-tiles with its ±1 ring neighbours via
  ``jax.lax.ppermute`` inside ``shard_map`` (the TPU ICI analogue of the
  paper's MPI sends; ASpMV's designated destinations d_{s,k} are the same
  ring hops). Valid when the sparsity bandwidth fits within one node's
  column range (Poisson-type problems partitioned in slabs).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.sparse.blockell import BlockEll
from repro.sparse.matrices import Problem


def nodes_mesh(n_nodes: int) -> Mesh:
    return jax.make_mesh((n_nodes,), ("nodes",))


def place_problem(problem: Problem, mesh: Mesh) -> Problem:
    """Shard the static data block-row-wise over the "nodes" axis."""
    a = problem.a
    row_sh = NamedSharding(mesh, P("nodes"))
    vec_sh = NamedSharding(mesh, P("nodes"))
    a2 = BlockEll(jax.device_put(a.data, row_sh),
                  jax.device_put(a.idx, row_sh),
                  jax.device_put(a.nblk, row_sh), a.shape, a.bm, a.bn)
    import dataclasses
    return dataclasses.replace(
        problem, a=a2, b=jax.device_put(problem.b, vec_sh),
        pinv_blocks=jax.device_put(problem.pinv_blocks, row_sh),
        diag_blocks=jax.device_put(problem.diag_blocks, row_sh))


def sharded_matvec(a: BlockEll, mesh: Mesh):
    """General-sparsity distributed SpMV: gather x, local block-ELL product.
    Output stays node-sharded (the natural block-row result placement)."""

    def mv(x):
        y = a.matvec(x)
        return jax.lax.with_sharding_constraint(
            y, NamedSharding(mesh, P("nodes")))

    return mv


def _sharded_sweep_precond(problem: Problem, mesh: Mesh):
    """Node-local SSOR/IC(0) apply for the sharded runtime.

    The sweeps run under ``shard_map`` with every static strip placed
    block-row-wise: each device substitutes through *its own* diagonal slab
    only — the additive-Schwarz variant, embarrassingly parallel over the
    "nodes" axis (a global sequential sweep would serialize the whole
    distributed iteration). If the problem's preconditioner still carries
    cross-slab coupling, its node-local twin is built from the COO in safe
    storage and **adopted as ``problem.precond``** so that Alg. 2 recovery
    reconstructs against the same operator the hot loop applies.
    Per-row arithmetic matches the single-device node-local reference
    (``build_problem(..., precond_opts={"node_local": True})``) exactly.
    """
    from functools import partial

    from jax.experimental.shard_map import shard_map

    from repro.kernels.block_jacobi.ref import block_jacobi_apply_ref
    from repro.kernels.trisweep.ref import block_sweep_ref
    from repro.precond import local as plocal

    n = mesh.shape["nodes"]
    if n != problem.part.n_nodes:
        # the slab restriction, the twin, and the shard_map index shift all
        # assume one partition slab per mesh device; a mismatched mesh would
        # silently clamp cross-shard loads to wrong blocks
        raise ValueError(
            f"node-local sweeps need one partition slab per mesh device: "
            f"mesh has {n} nodes, partition has {problem.part.n_nodes}")
    pc = problem.precond
    if plocal.precond_is_node_local(pc, n):
        variant = f"node-local {pc.name}"
    else:
        pc = plocal.node_local_twin(problem)
        problem.precond = pc
        # closures cached against the replaced global-sweep operator must
        # not survive the adoption (reconstruction would otherwise rebuild
        # against a different P than the hot loop applies)
        for attr in ("_recon_cache", "_ops_cache", "_closure_ops_cache"):
            if hasattr(problem, attr):
                delattr(problem, attr)
        variant = f"node-local {pc.name} (auto twin)"
        assert plocal.precond_is_node_local(pc, n)
    per = (pc.m // pc.block) // n
    put = lambda a: jax.device_put(a, NamedSharding(mesh, P("nodes")))

    if pc.name == "ssor":
        statics = tuple(map(put, (pc.lo_idx, pc.lo_n, pc.lo_data, pc.up_idx,
                                  pc.up_n, pc.up_data, pc.dinv,
                                  pc.mid_blocks)))

        @partial(shard_map, mesh=mesh, in_specs=(P("nodes"),) * 9,
                 out_specs=P("nodes"), check_rep=False)
        def apply_local(lo_idx, lo_n, lo_data, up_idx, up_n, up_data, dinv,
                        mid, r):
            base = jax.lax.axis_index("nodes") * per     # global -> slab ids
            y = block_sweep_ref(lo_idx - base, lo_n, lo_data, dinv, r,
                                reverse=False)
            w = block_jacobi_apply_ref(mid, y)
            return block_sweep_ref(up_idx - base, up_n, up_data, dinv, w,
                                   reverse=True)
    else:                                                # ic0
        statics = tuple(map(put, (pc.lo_idx, pc.lo_n, pc.lo_data, pc.up_idx,
                                  pc.up_n, pc.up_data, pc.dinv_f,
                                  pc.dinv_b)))

        @partial(shard_map, mesh=mesh, in_specs=(P("nodes"),) * 9,
                 out_specs=P("nodes"), check_rep=False)
        def apply_local(lo_idx, lo_n, lo_data, up_idx, up_n, up_data,
                        dinv_f, dinv_b, r):
            base = jax.lax.axis_index("nodes") * per
            y = block_sweep_ref(lo_idx - base, lo_n, lo_data, dinv_f, r,
                                reverse=False)
            return block_sweep_ref(up_idx - base, up_n, up_data, dinv_b, y,
                                   reverse=True)

    return (lambda r: apply_local(*statics, r)), variant


def _sharded_chebyshev_precond(problem: Problem, mesh: Mesh):
    """Chebyshev apply for the sharded runtime: the polynomial recurrence
    over the all-gather sharded SpMV — no node-local approximation needed
    (the operator is d distributed matvecs, identical algebra to the
    single-device apply)."""
    from repro.kernels.chebyshev.chebyshev import cheb_recurrence

    pc = problem.precond
    mv = sharded_matvec(problem.a, mesh)
    vec = NamedSharding(mesh, P("nodes"))

    def apply_(r):
        z = cheb_recurrence(mv, r, lo=pc.lo, hi=pc.hi, degree=pc.degree)
        return jax.lax.with_sharding_constraint(z, vec)

    return apply_, "spmv-distributed chebyshev"


def sharded_solver_ops(problem: Problem, mesh: Mesh):
    """SolverOps bundle for the distributed runtime.

    The same ESRP/IMCR core from ``repro.core`` runs through this bundle
    unchanged: the SpMV is the all-gather sharded matvec, every vector
    produced by the fused update is constrained back to the block-row
    placement (so XLA keeps the whole iteration SPMD-partitioned instead of
    replicating intermediates), and the pᵀq / rᵀz dots lower to the natural
    psum across the "nodes" axis. Cached per (problem, mesh): the jitted
    chunk runners treat the bundle as a static argument.

    Every registered preconditioner is accepted: block-Jacobi keeps the
    seed's einsum over re-placed blocks, SSOR/IC(0) run their node-local
    (additive-Schwarz) sweeps under ``shard_map`` (building and adopting
    the twin when the instance still has cross-slab coupling — see
    ``_sharded_sweep_precond``), and Chebyshev distributes through the
    sharded SpMV. ``SolveReport.precond_variant`` records which variant ran;
    compare iteration counts against the global-sweep reference with
    ``attach_local_delta``.
    """
    from repro.core.ops import SolverOps

    cache = getattr(problem, "_sharded_ops_cache", None)
    if cache is None:
        cache = {}
        problem._sharded_ops_cache = cache
    if mesh not in cache:
        vec = NamedSharding(mesh, P("nodes"))
        mv = sharded_matvec(problem.a, mesh)
        variant = ""
        name = problem.precond_name
        if name == "jacobi":
            precond = problem.apply_precond
        elif name == "chebyshev":
            precond, variant = _sharded_chebyshev_precond(problem, mesh)
        elif name in ("ssor", "ic0"):
            precond, variant = _sharded_sweep_precond(problem, mesh)
        else:
            raise NotImplementedError(
                f"sharded runtime has no distributed apply for "
                f"preconditioner {name!r}")
        constrain = lambda v: jax.lax.with_sharding_constraint(v, vec)

        def matvec_dot(p):
            q = mv(p)
            return q, p @ q

        def update(alpha, x, r, p, q):
            x_new = constrain(x + alpha * p)
            r_new = constrain(r - alpha * q)
            z_new = constrain(precond(r_new))
            return x_new, r_new, z_new, r_new @ z_new

        cache[mesh] = SolverOps("sharded", mv, matvec_dot, precond, update,
                                variant)
    return cache[mesh]


def attach_local_delta(report, reference) -> None:
    """Record on ``report`` the iteration-count delta of the node-local
    (additive-Schwarz) run vs the global-sweep reference solve — the price
    of making the sweeps partition over the mesh axis."""
    report.local_delta_iters = report.converged_iter - reference.converged_iter


# --------------------------------------------------------------------------- #
# banded specialization: ppermute halo exchange (the paper's neighbour sends)
# --------------------------------------------------------------------------- #
def ring_halo_matvec(a: BlockEll, part, mesh: Mesh, halo_tiles: int):
    """Banded SpMV with explicit ±1 ring halo exchange.

    Requires every referenced column tile of node s to lie within
    [s's first tile - halo_tiles, s's last tile + halo_tiles] — checked at
    build time against the sparsity structure. ``halo_tiles`` column tiles
    are sent to each ring neighbour per product (the paper's I_{s,s±1});
    communication volume = 2 * halo_tiles * bn * itemsize per node.
    """
    from jax.experimental.shard_map import shard_map

    n = part.n_nodes
    cpt = part.col_tiles_per_node
    # static check: band fits the halo
    idx = np.asarray(a.idx)
    nblk = np.asarray(a.nblk)
    rpt = part.row_tiles_per_node
    for s in range(n):
        rows = slice(s * rpt, (s + 1) * rpt)
        valid = idx[rows][np.arange(a.kmax)[None, :] < nblk[rows][:, None]]
        if valid.size and (valid.min() < s * cpt - halo_tiles
                           or valid.max() >= (s + 1) * cpt + halo_tiles):
            raise ValueError(f"node {s}: sparsity exceeds halo_tiles="
                             f"{halo_tiles}")

    bn = a.bn

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(P("nodes"), P("nodes"), P("nodes")),
        out_specs=P("nodes"), check_rep=False)
    def mv(data, idx_l, x):
        # x: local slab (rows_per_node,) -> tiles (cpt, bn)
        xt = x.reshape(cpt, bn)
        lo = jax.lax.ppermute(xt[-halo_tiles:], "nodes",
                              [(i, (i + 1) % n) for i in range(n)])
        hi = jax.lax.ppermute(xt[:halo_tiles], "nodes",
                              [(i, (i - 1) % n) for i in range(n)])
        ext = jnp.concatenate([lo, xt, hi], axis=0)   # (cpt + 2*halo, bn)
        me = jax.lax.axis_index("nodes")
        base = me * cpt - halo_tiles
        local_idx = jnp.clip(idx_l - base, 0, ext.shape[0] - 1)
        gathered = ext[local_idx]                     # (rpt, kmax, bn)
        y = jnp.einsum("rkij,rkj->ri", data, gathered)
        return y.reshape(-1)

    return lambda x: mv(a.data, a.idx, x)


# --------------------------------------------------------------------------- #
# physical ASpMV redundancy pushes (paper §2.2.1 on the ICI ring)
# --------------------------------------------------------------------------- #
def aspmv_push(plan, part, mesh: Mesh):
    """Materialize the augmented-SpMV redundancy sends as ring ppermutes.

    For each k in 1..phi, every node sends the column tiles of the input
    vector listed in I_{s,d_{s,k}} ∪ R^c_{s,k} to its designated neighbour
    d_{s,k} (Eq. 1) — one ``collective-permute`` per k, payload padded to the
    largest per-node send count (static shape). Returns a function
    ``push(x) -> list over k of (recv_tiles, recv_idx)`` where node d's row
    of ``recv_tiles`` holds the tile values it received (its share of the
    paper's redundancy queue entry) and ``recv_idx`` the *global* column-tile
    ids (-1 = padding).
    """
    from functools import partial

    from jax.experimental.shard_map import shard_map

    from repro.sparse.partition import neighbor

    n = part.n_nodes
    cpt = part.col_tiles_per_node
    bn = part.bn

    # host-side static send lists per k: natural I_{s,d} tiles are already in
    # flight during SpMV; the queue holds natural + extra = everything the
    # buddy can serve after a failure
    send_idx_k = []
    perms = []
    for k in range(1, plan.phi + 1):
        rows = []
        for s in range(n):
            d = neighbor(s, k, n)
            lo, hi = part.node_col_tiles(s)
            natural = [t for t in range(lo, hi) if plan.holders[t, d]
                       and part.owner_of_col_tile(t) == s]
            rows.append(natural)
        width = max(len(r) for r in rows)
        idx = np.full((n, width), -1, np.int32)
        for s, r in enumerate(rows):
            idx[s, :len(r)] = r
        send_idx_k.append(idx)
        perms.append([(s, neighbor(s, k, n)) for s in range(n)])

    def make_one(k):
        idx = jax.device_put(jnp.asarray(send_idx_k[k]),
                             NamedSharding(mesh, P("nodes")))
        perm = perms[k]

        @partial(shard_map, mesh=mesh, in_specs=(P("nodes"), P("nodes")),
                 out_specs=(P("nodes"), P("nodes")), check_rep=False)
        def push(x_local, idx_local):
            xt = x_local.reshape(cpt, bn)
            me = jax.lax.axis_index("nodes")
            local = jnp.clip(idx_local[0] - me * cpt, 0, cpt - 1)
            payload = jnp.where((idx_local[0] >= 0)[:, None], xt[local], 0.0)
            recv = jax.lax.ppermute(payload, "nodes", perm)
            recv_idx = jax.lax.ppermute(idx_local[0], "nodes", perm)
            return recv[None], recv_idx[None]

        return lambda x: push(x, idx)

    fns = [make_one(k) for k in range(plan.phi)]
    return lambda x: [f(x) for f in fns]
