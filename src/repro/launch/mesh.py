"""Production meshes and logical-axis bindings.

Single pod: (16, 16) = 256 chips, axes ("data", "model").
Multi-pod:  (2, 16, 16) = 512 chips, axes ("pod", "data", "model") — the
"pod" axis extends data parallelism across pods (gradient all-reduce over the
inter-pod links) while "model" tensor-parallelism stays inside a pod, the
standard placement for ICI-connected pods with slower inter-pod links.

Functions, not module constants: importing this module never touches jax
device state (smoke tests must keep seeing 1 CPU device).
"""
from __future__ import annotations

import jax

from repro.models import sharding


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def bindings(multi_pod: bool = False, profile: str = "2d") -> dict:
    """Logical-axis -> mesh-axes map for repro.models.sharding.

    profile "2d":   FSDP over (pod, data) x TP over model (Megatron-style).
    profile "fsdp": pure ZeRO-3 — params/optimizer shard over EVERY axis,
                    batch over every axis, no tensor parallelism. Chosen per
                    arch (ModelConfig.parallelism) when TP activation
                    all-reduces exceed FSDP param gathers (§Perf cr-1).
    """
    dp = ("pod", "data") if multi_pod else ("data",)
    if profile == "fsdp":
        every = dp + ("model",)
        return {
            "dp": every,
            "fsdp": every,
            "tp": (),            # unbound: tensor dims stay replicated
            "atp": (),
            "sp": ("data",),
            "seqtp": ("model",),
        }
    if profile == "ep":
        # expert parallelism only: the model axis is reserved for the MoE
        # expert dim; attention/dense-MLP run data-parallel (their weights
        # are small — replicating them removes the Megatron activation
        # all-reduces; §Perf iteration moe-3)
        return {
            "dp": dp,
            "fsdp": dp,
            "tp": ("model",),    # experts + vocab
            "atp": (),           # attention/MLP: replicated weights
            "sp": ("data",),
            "seqtp": ("model",),
        }
    return {
        "dp": dp,            # batch
        "fsdp": dp,          # parameter/optimizer sharding (ZeRO/FSDP)
        "tp": ("model",),    # tensor parallel (experts, vocab)
        "atp": ("model",),   # attention/dense-MLP tensor parallel
        "sp": ("data",),     # sequence sharding (long-context decode)
        "seqtp": ("model",), # Megatron-style sequence parallelism: residual
                             # carries + KV-cache fallback over the model axis
    }


def activate(mesh, multi_pod: bool = False, profile: str = "2d"):
    sharding.set_context(mesh, bindings(multi_pod, profile))
    return mesh


def make_test_mesh(n_data: int = 2, n_model: int = 2):
    """Small mesh for multi-device subprocess tests."""
    mesh = jax.make_mesh((n_data, n_model), ("data", "model"))
    sharding.set_context(mesh, bindings(False))
    return mesh
