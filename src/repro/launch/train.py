"""Production training launcher.

On a real TPU pod this process runs per host under ``jax.distributed``; the
mesh comes from ``mesh.make_production_mesh`` and the ESRP fault-tolerance
layer runs with the same code exercised by the CPU tests. On CPU it runs the
reduced configs end-to-end (the dry-run proves the full configs lower and
compile on the production meshes).

    PYTHONPATH=src python -m repro.launch.train --arch internlm2-1.8b \
        --smoke --steps 50 --ft esrp --T 20 --phi 1
"""
from __future__ import annotations

import argparse

import jax

from repro.configs import get_config, smoke_config
from repro.data.pipeline import TokenPipeline
from repro.ft import checkpoint
from repro.ft.esrp_trainer import ESRPTrainer, FTConfig
from repro.launch import mesh as mesh_lib
from repro.models import sharding
from repro.models.lm import LM
from repro.train.optimizer import AdamWConfig, init_opt_state
from repro.train.train_step import make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ft", default="esrp", choices=["esrp", "imcr", "none"])
    ap.add_argument("--T", type=int, default=20)
    ap.add_argument("--phi", type=int, default=1)
    ap.add_argument("--compress", action="store_true",
                    help="bf16 moment redundancy")
    ap.add_argument("--mesh", default="none",
                    choices=["none", "single", "multi"],
                    help="production mesh (requires enough devices)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0)
    args = ap.parse_args()

    if args.mesh != "none":
        m = mesh_lib.make_production_mesh(multi_pod=args.mesh == "multi")
        mesh_lib.activate(m, args.mesh == "multi")

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = LM(cfg)
    params, specs = model.init(jax.random.PRNGKey(0))
    print(f"[train] {cfg.name}: {model.count_params(params) / 1e6:.1f}M "
          f"params, ft={args.ft} T={args.T} phi={args.phi}")
    opt = init_opt_state(params)
    step_fn = make_train_step(model, AdamWConfig())
    pipe = TokenPipeline(cfg, global_batch=args.batch, seq_len=args.seq)
    n_ranks = (sharding.axis_size("fsdp")
               if sharding.get_context().mesh is not None else 8)
    trainer = ESRPTrainer(
        model, step_fn, pipe,
        FTConfig(mode=args.ft, T=args.T, phi=args.phi, n_ranks=n_ranks,
                 compress=args.compress), specs)

    done = 0
    while done < args.steps:
        n = min(args.ckpt_every or args.steps, args.steps - done)
        params, opt, losses = trainer.run(params, opt, n_steps=done + n,
                                          start_step=done)
        done += n
        last = losses[max(losses)]
        print(f"[train] step {done}: loss {last:.4f}")
        if args.ckpt_dir:
            checkpoint.save(args.ckpt_dir, done, params=params, opt=opt)
    print(f"[train] done: {trainer.push_count} storage stages, "
          f"{trainer.push_bytes / 1e6:.2f} MB redundancy traffic")


if __name__ == "__main__":
    main()
