"""Production serving launcher: batched prefill + decode loop.

On a TPU pod the mesh comes from ``make_production_mesh`` and the KV caches
shard per the adaptive policy in ``repro.models.layers`` (kv-heads over the
model axis when divisible, else sequence split-K). On CPU it serves the
reduced configs end-to-end; the serve cells of the dry-run prove the full
configs lower/compile on the production meshes.

    PYTHONPATH=src python -m repro.launch.serve --arch internlm2-1.8b \
        --smoke --batch 4 --prompt-len 32 --new-tokens 64
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, smoke_config
from repro.launch import mesh as mesh_lib
from repro.models.lm import LM
from repro.serve.serve_step import make_decode_step, make_prefill_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=64)
    ap.add_argument("--mesh", default="none",
                    choices=["none", "single", "multi"])
    args = ap.parse_args()

    if args.mesh != "none":
        m = mesh_lib.make_production_mesh(multi_pod=args.mesh == "multi")
        mesh_lib.activate(m, args.mesh == "multi")   # serve keeps 2d profile

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = LM(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    print(f"[serve] {cfg.name}: {model.count_params(params) / 1e6:.1f}M "
          f"params, batch {args.batch}")

    max_len = args.prompt_len + args.new_tokens
    caches = model.init_cache(args.batch, max_len)
    prompts = jax.random.randint(
        jax.random.PRNGKey(1), (args.batch, args.prompt_len), 0, cfg.vocab,
        dtype=jnp.int32)
    prefill = jax.jit(make_prefill_step(model))
    decode = jax.jit(make_decode_step(model))

    t0 = time.time()
    tok, caches = prefill(params, {"tokens": prompts}, caches)
    jax.block_until_ready(tok)
    t_prefill = time.time() - t0
    t1 = time.time()
    for i in range(args.new_tokens - 1):
        pos = jnp.asarray(args.prompt_len + i, jnp.int32)
        tok, caches = decode(params, tok, caches, pos)
    jax.block_until_ready(tok)
    t_decode = time.time() - t1
    n_new = args.batch * (args.new_tokens - 1)
    print(f"[serve] prefill {args.batch}x{args.prompt_len} in "
          f"{t_prefill * 1000:.0f} ms; decode {n_new} tokens in "
          f"{t_decode:.2f}s ({n_new / t_decode:.1f} tok/s)")


if __name__ == "__main__":
    main()
