"""Serving launcher. Default mode: the streaming resilient SOLVER service —
a request queue of right-hand sides micro-batched through the batched
``solve_resilient`` (per-member convergence freeze, failures injected under
load, per-request latency spans):

    PYTHONPATH=src python -m repro.launch.serve --requests 32 --batch 8 \
        --fail-at 30 --fail-nodes 1 --trace

``--arch`` switches to the legacy language-model path (batched prefill +
decode loop). On a TPU pod the mesh comes from ``make_production_mesh`` and
the KV caches shard per the adaptive policy in ``repro.models.layers``; on
CPU it serves the reduced configs end-to-end:

    PYTHONPATH=src python -m repro.launch.serve --arch internlm2-1.8b \
        --smoke --batch 4 --prompt-len 32 --new-tokens 64 --trace
"""
from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np


def _write_trace(tracer, metrics_out=None):
    from repro.obs import metrics_snapshot, write_chrome_trace
    os.makedirs("artifacts/obs", exist_ok=True)
    path = write_chrome_trace(tracer, "artifacts/obs/serve_trace.json")
    snap = metrics_snapshot(tracer)
    metrics_path = metrics_out or "artifacts/obs/serve_metrics.txt"
    with open(metrics_path, "w") as fh:
        fh.write(snap)
    print(f"[serve] wrote {path} + {metrics_path}")
    print(snap, end="")


def run_solver(args):
    from repro.core.failures import FailureEvent
    from repro.serve.solver_service import SolverService
    from repro.sparse.matrices import build_problem

    jax.config.update("jax_enable_x64", True)
    problem = build_problem(args.problem, n_nodes=args.n_nodes, nx=args.nx)
    scenario = None
    if args.fail_at is not None:
        nodes = tuple(int(s) for s in args.fail_nodes.split(","))
        scenario = [FailureEvent(args.fail_at, nodes)]

    tracer = None
    if args.trace:
        from repro.obs import Tracer
        tracer = Tracer("serve")
        tracer.meta.update(mode="solver", problem=args.problem,
                           n_nodes=args.n_nodes, nx=args.nx,
                           batch=args.batch, requests=args.requests,
                           strategy=args.strategy, T=args.T, phi=args.phi)

    svc = SolverService(problem, batch=args.batch, strategy=args.strategy,
                        T=args.T, phi=args.phi, rtol=args.rtol,
                        backend=args.backend, scenario=scenario,
                        fail_every=args.fail_every, obs=tracer,
                        max_queue_wait_s=args.max_queue_wait,
                        max_retries=args.max_retries,
                        degrade=args.degrade)
    rng = np.random.default_rng(args.seed)
    print(f"[serve] solver service: {args.requests} requests over "
          f"{args.problem} n={problem.part.m} (B={args.batch}, "
          f"strategy={args.strategy}"
          + (f", failures@{args.fail_at} every {args.fail_every} "
             f"micro-batches" if scenario else "") + ")")
    t0 = time.time()
    for _ in range(args.requests):
        svc.submit(rng.standard_normal(problem.part.m),
                   deadline_s=args.deadline)
        if args.arrival_every:
            # staggered arrivals: the queue-wait bound decides when a
            # partial micro-batch beats waiting for fill
            time.sleep(args.arrival_every)
        while svc.ready():
            svc.step()
    svc.run()                                  # drain the tail
    wall = time.time() - t0
    st = svc.stats()
    print(f"[serve] {st['requests']} served in {wall:.2f}s "
          f"({st['throughput_rps']:.2f} req/s solve-side) | latency p50 "
          f"{st['latency_p50_ms']:.0f} ms p99 {st['latency_p99_ms']:.0f} ms "
          f"| {st['microbatches']} micro-batches, mean fill "
          f"{st['mean_fill']:.1f}, all_converged={st['all_converged']}")
    if args.max_queue_wait is not None or args.deadline is not None \
            or args.max_retries or args.degrade:
        print(f"[serve] deadline policy: queue-wait p99 "
              f"{st['queue_wait_p99_ms']:.0f} ms | deadline-miss rate "
              f"{st['deadline_miss_rate']:.3f} ({st['deadline_missed']} "
              f"missed) | {st['partial_dispatches']} partial dispatches | "
              f"{st['retries_total']} retries, {st['failed']} failed | "
              f"serving on {st['final_n_nodes']} nodes")
    if tracer is not None:
        _write_trace(tracer, args.metrics_out)
    return st


def run_lm(args):
    from repro.configs import get_config, smoke_config
    from repro.launch import mesh as mesh_lib
    from repro.models.lm import LM
    from repro.serve.serve_step import make_decode_step, make_prefill_step

    if args.mesh != "none":
        m = mesh_lib.make_production_mesh(multi_pod=args.mesh == "multi")
        mesh_lib.activate(m, args.mesh == "multi")   # serve keeps 2d profile

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = LM(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    print(f"[serve] {cfg.name}: {model.count_params(params) / 1e6:.1f}M "
          f"params, batch {args.batch}")

    max_len = args.prompt_len + args.new_tokens
    caches = model.init_cache(args.batch, max_len)
    prompts = jax.random.randint(
        jax.random.PRNGKey(1), (args.batch, args.prompt_len), 0, cfg.vocab,
        dtype=jnp.int32)
    prefill = jax.jit(make_prefill_step(model))
    decode = jax.jit(make_decode_step(model))

    tracer = None
    if args.trace:
        from repro.obs import Tracer
        tracer = Tracer("serve")
        tracer.meta.update(arch=cfg.name, batch=args.batch,
                           prompt_len=args.prompt_len,
                           new_tokens=args.new_tokens, mesh=args.mesh)

    def span(name, **sargs):
        if tracer is None:
            import contextlib
            return contextlib.nullcontext()
        return tracer.span(name, cat="serve", **sargs)

    t0 = time.time()
    with span("prefill", batch=args.batch, prompt_len=args.prompt_len):
        tok, caches = prefill(params, {"tokens": prompts}, caches)
        jax.block_until_ready(tok)
    t_prefill = time.time() - t0
    t1 = time.time()
    with span("decode", new_tokens=args.new_tokens - 1):
        for i in range(args.new_tokens - 1):
            pos = jnp.asarray(args.prompt_len + i, jnp.int32)
            tok, caches = decode(params, tok, caches, pos)
            if tracer is not None:
                tracer.counter("tokens_decoded",
                               tokens=args.batch * (i + 1))
        jax.block_until_ready(tok)
    t_decode = time.time() - t1
    n_new = args.batch * (args.new_tokens - 1)
    print(f"[serve] prefill {args.batch}x{args.prompt_len} in "
          f"{t_prefill * 1000:.0f} ms; decode {n_new} tokens in "
          f"{t_decode:.2f}s ({n_new / t_decode:.1f} tok/s)")

    if tracer is not None:
        tracer.add_counter("tokens_total", n_new)
        _write_trace(tracer, args.metrics_out)


def main():
    ap = argparse.ArgumentParser()
    # shared
    ap.add_argument("--batch", type=int, default=8,
                    help="solver micro-batch width B / LM serving batch")
    ap.add_argument("--trace", action="store_true",
                    help="span-trace the run; writes "
                         "artifacts/obs/serve_trace.json + serve_metrics.txt")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="with --trace: write the text metrics snapshot "
                         "here instead of artifacts/obs/serve_metrics.txt")
    # solver service (default mode)
    ap.add_argument("--problem", default="poisson2d")
    ap.add_argument("--nx", type=int, default=32)
    ap.add_argument("--n-nodes", type=int, default=8)
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--strategy", default="esrp",
                    choices=["esrp", "imcr", "none"])
    ap.add_argument("--T", type=int, default=20)
    ap.add_argument("--phi", type=int, default=1)
    ap.add_argument("--rtol", type=float, default=1e-8)
    ap.add_argument("--backend", default="auto")
    ap.add_argument("--fail-at", type=int, default=None,
                    help="inject a FailureEvent at this iteration of every "
                         "fail-every'th micro-batch")
    ap.add_argument("--fail-nodes", default="1",
                    help="comma-separated node ids for --fail-at")
    ap.add_argument("--fail-every", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    # deadline-aware front-end
    ap.add_argument("--max-queue-wait", type=float, default=None,
                    metavar="S",
                    help="dispatch a partial micro-batch once the oldest "
                         "queued request has waited this long (None = "
                         "greedy dispatch)")
    ap.add_argument("--deadline", type=float, default=None, metavar="S",
                    help="per-request deadline in seconds; expired requests "
                         "end deadline_missed instead of blocking")
    ap.add_argument("--max-retries", type=int, default=0,
                    help="retries (with backoff) for a micro-batch whose "
                         "solve dies on an unsurvivable event")
    ap.add_argument("--degrade", action="store_true",
                    help="keep serving on the elastically shrunk mesh "
                         "after an unreplaced node loss")
    ap.add_argument("--arrival-every", type=float, default=0.0, metavar="S",
                    help="stagger request arrivals by this many seconds "
                         "(exercises the queue-wait dispatch policy)")
    # LM path
    ap.add_argument("--arch", default=None,
                    help="serve a language model instead of the solver")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=64)
    ap.add_argument("--mesh", default="none",
                    choices=["none", "single", "multi"])
    args = ap.parse_args()

    if args.arch:
        run_lm(args)
    else:
        run_solver(args)


if __name__ == "__main__":
    main()
