"""Production serving launcher: batched prefill + decode loop.

On a TPU pod the mesh comes from ``make_production_mesh`` and the KV caches
shard per the adaptive policy in ``repro.models.layers`` (kv-heads over the
model axis when divisible, else sequence split-K). On CPU it serves the
reduced configs end-to-end; the serve cells of the dry-run prove the full
configs lower/compile on the production meshes.

    PYTHONPATH=src python -m repro.launch.serve --arch internlm2-1.8b \
        --smoke --batch 4 --prompt-len 32 --new-tokens 64 --trace
"""
from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, smoke_config
from repro.launch import mesh as mesh_lib
from repro.models.lm import LM
from repro.serve.serve_step import make_decode_step, make_prefill_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=64)
    ap.add_argument("--mesh", default="none",
                    choices=["none", "single", "multi"])
    ap.add_argument("--trace", action="store_true",
                    help="span-trace prefill/decode; writes "
                         "artifacts/obs/serve_trace.json + serve_metrics.txt")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="with --trace: write the text metrics snapshot "
                         "here instead of artifacts/obs/serve_metrics.txt")
    args = ap.parse_args()

    if args.mesh != "none":
        m = mesh_lib.make_production_mesh(multi_pod=args.mesh == "multi")
        mesh_lib.activate(m, args.mesh == "multi")   # serve keeps 2d profile

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = LM(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    print(f"[serve] {cfg.name}: {model.count_params(params) / 1e6:.1f}M "
          f"params, batch {args.batch}")

    max_len = args.prompt_len + args.new_tokens
    caches = model.init_cache(args.batch, max_len)
    prompts = jax.random.randint(
        jax.random.PRNGKey(1), (args.batch, args.prompt_len), 0, cfg.vocab,
        dtype=jnp.int32)
    prefill = jax.jit(make_prefill_step(model))
    decode = jax.jit(make_decode_step(model))

    tracer = None
    if args.trace:
        from repro.obs import Tracer
        tracer = Tracer("serve")
        tracer.meta.update(arch=cfg.name, batch=args.batch,
                           prompt_len=args.prompt_len,
                           new_tokens=args.new_tokens, mesh=args.mesh)

    def span(name, **sargs):
        if tracer is None:
            import contextlib
            return contextlib.nullcontext()
        return tracer.span(name, cat="serve", **sargs)

    t0 = time.time()
    with span("prefill", batch=args.batch, prompt_len=args.prompt_len):
        tok, caches = prefill(params, {"tokens": prompts}, caches)
        jax.block_until_ready(tok)
    t_prefill = time.time() - t0
    t1 = time.time()
    with span("decode", new_tokens=args.new_tokens - 1):
        for i in range(args.new_tokens - 1):
            pos = jnp.asarray(args.prompt_len + i, jnp.int32)
            tok, caches = decode(params, tok, caches, pos)
            if tracer is not None:
                tracer.counter("tokens_decoded",
                               tokens=args.batch * (i + 1))
        jax.block_until_ready(tok)
    t_decode = time.time() - t1
    n_new = args.batch * (args.new_tokens - 1)
    print(f"[serve] prefill {args.batch}x{args.prompt_len} in "
          f"{t_prefill * 1000:.0f} ms; decode {n_new} tokens in "
          f"{t_decode:.2f}s ({n_new / t_decode:.1f} tok/s)")

    if tracer is not None:
        from repro.obs import metrics_snapshot, write_chrome_trace
        tracer.add_counter("tokens_total", n_new)
        os.makedirs("artifacts/obs", exist_ok=True)
        path = write_chrome_trace(tracer, "artifacts/obs/serve_trace.json")
        snap = metrics_snapshot(tracer)
        metrics_path = args.metrics_out or "artifacts/obs/serve_metrics.txt"
        with open(metrics_path, "w") as fh:
            fh.write(snap)
        print(f"[serve] wrote {path} + {metrics_path}")
        print(snap, end="")


if __name__ == "__main__":
    main()
