import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

DOC = """Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

MUST be run as its own process (``python -m repro.launch.dryrun ...``): the
first two lines force 512 host platform devices BEFORE any other import so
``jax.make_mesh`` can build the production meshes; smoke tests and benchmarks
must never import this module.

Per cell it lowers the right step function (train_step / prefill_step /
decode_step) against ShapeDtypeStruct inputs (no allocation), compiles it,
and dumps to ``artifacts/dryrun/<arch>__<shape>__<mesh>.json``:
  - memory_analysis (bytes per device: args/outputs/temps/peak)
  - cost_analysis (XLA's own numbers, while-bodies counted once)
  - while-aware per-device costs (repro.roofline.hlo_analysis): HLO_FLOPs,
    HBM bytes, per-kind collective bytes — the §Roofline inputs
  - MODEL_FLOPS (6·N·D dense / 6·N_active·D MoE; 2·N·tokens for serve)
  - lower/compile wall times and status.
"""

import argparse
import dataclasses
import glob
import json
import shutil
import tempfile
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, get_config
from repro.configs.shapes import SHAPES, applicable, input_specs
from repro.launch import mesh as mesh_lib
from repro.models import sharding
from repro.models.lm import LM
from repro.roofline import hlo_analysis
from repro.roofline.model_flops import model_flops
from repro.train.optimizer import AdamWConfig, init_opt_state
from repro.train.train_step import make_train_step
from repro.serve.serve_step import make_decode_step, make_prefill_step


def _sds(tree):
    return jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), tree)


def lower_cell(arch: str, shape_name: str, multi_pod: bool):
    """Lower + compile one cell; returns (lowered, compiled, meta)."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = mesh_lib.make_production_mesh(multi_pod=multi_pod)
    # serve cells keep the 2d profile (decode/prefill have no optimizer state
    # and benefit from TP); train cells honor the arch's parallelism profile.
    # ZeRO-3 ("fsdp") additionally requires batch >= device count — on the
    # 2-pod mesh train_4k's 256 batch < 512 chips, so it falls back to 2d
    # (measured regression otherwise; EXPERIMENTS.md §Perf profile note).
    profile = cfg.parallelism if shape.kind == "train" else "2d"
    if profile == "fsdp" and shape.global_batch % mesh.devices.size != 0:
        profile = "2d"
    mesh_lib.activate(mesh, multi_pod, profile)
    model = LM(cfg)

    params_sds, specs = model.abstract_init(jax.random.PRNGKey(0))
    param_sh = sharding.physical_shardings(specs, params_sds)
    batch_sds = input_specs(cfg, shape)
    meta = {"arch": arch, "shape": shape_name,
            "mesh": "2x16x16" if multi_pod else "16x16",
            "n_devices": mesh.devices.size,
            "params": float(sum(np.prod(a.shape)
                                for a in jax.tree.leaves(params_sds)))}

    with mesh:
        if shape.kind == "train":
            opt_sds = jax.eval_shape(init_opt_state, params_sds)
            opt_sh = type(opt_sds)(
                mu=param_sh, nu=param_sh,
                step=jax.sharding.NamedSharding(
                    mesh, jax.sharding.PartitionSpec()))
            step_fn = make_train_step(model, AdamWConfig())
            lowered = jax.jit(
                step_fn,
                in_shardings=(param_sh, opt_sh, None),
                out_shardings=(param_sh, opt_sh, None),
            ).lower(params_sds, opt_sds, batch_sds)
        elif shape.kind == "prefill":
            cache_sds = jax.eval_shape(
                lambda: model.init_cache(shape.global_batch, shape.seq_len))
            step_fn = make_prefill_step(model)
            lowered = jax.jit(step_fn, in_shardings=(param_sh, None, None)
                              ).lower(params_sds, batch_sds, cache_sds)
        else:  # decode
            cache_sds = jax.eval_shape(
                lambda: model.init_cache(shape.global_batch, shape.seq_len))
            tokens = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
            pos = jax.ShapeDtypeStruct((), jnp.int32)
            step_fn = make_decode_step(model)
            lowered = jax.jit(step_fn, in_shardings=(param_sh, None, None, None)
                              ).lower(params_sds, tokens, cache_sds, pos)
    return lowered, meta, cfg, shape


def run_cell(arch: str, shape_name: str, multi_pod: bool, outdir: str):
    t0 = time.time()
    record = {"arch": arch, "shape": shape_name,
              "mesh": "2x16x16" if multi_pod else "16x16", "status": "error"}
    tag = f"{arch}__{shape_name}__{record['mesh']}"
    try:
        lowered, meta, cfg, shape = lower_cell(arch, shape_name, multi_pod)
        t_lower = time.time() - t0
        t1 = time.time()
        # dump the post-SPMD-partitioning HLO: per-device, still bf16 (the
        # CPU backend legalizes bf16->f32 later, which would inflate byte
        # counts 2x vs the TPU target), still while-structured
        dump_dir = tempfile.mkdtemp(prefix="dryrun_hlo_")
        compiled = lowered.compile(compiler_options={
            "xla_dump_to": dump_dir,
            "xla_dump_hlo_pass_re": "spmd-partitioning",
        })
        t_compile = time.time() - t1
        record.update(meta)

        mem = compiled.memory_analysis()
        mem_d = {}
        for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                     "temp_size_in_bytes", "generated_code_size_in_bytes",
                     "alias_size_in_bytes", "peak_memory_in_bytes"):
            if mem is not None and hasattr(mem, attr):
                mem_d[attr] = int(getattr(mem, attr))
        ca = compiled.cost_analysis() or {}
        spmd_files = sorted(glob.glob(
            os.path.join(dump_dir, "*after_spmd-partitioning*.txt")))
        if not spmd_files:
            raise RuntimeError("no spmd-partitioning dump found")
        with open(spmd_files[-1]) as f:
            costs = hlo_analysis.analyze(f.read())
        shutil.rmtree(dump_dir, ignore_errors=True)

        n_dev = meta["n_devices"]
        mf = model_flops(cfg, shape)
        record.update({
            "status": "ok",
            "t_lower_s": t_lower, "t_compile_s": t_compile,
            "memory_analysis": mem_d,
            "xla_cost_analysis": {k: float(v) for k, v in ca.items()
                                  if isinstance(v, (int, float))},
            "per_device": {
                "hlo_flops": costs.flops,
                "hbm_bytes": costs.hbm_bytes,
                "collective_bytes": costs.collective_bytes,
                "collectives": costs.collectives,
            },
            "while_trips": costs.while_trips,
            "model_flops_global": mf,
            "model_flops_per_device": mf / n_dev,
        })
        print(f"[dryrun] OK  {tag}: lower {t_lower:.1f}s compile "
              f"{t_compile:.1f}s flops/dev {costs.flops:.3e} "
              f"coll/dev {costs.collective_bytes:.3e}B")
    except Exception as e:  # noqa: BLE001 — record and continue the campaign
        record["error"] = f"{type(e).__name__}: {e}"
        record["traceback"] = traceback.format_exc()[-4000:]
        print(f"[dryrun] FAIL {tag}: {record['error']}")
    os.makedirs(outdir, exist_ok=True)
    with open(os.path.join(outdir, f"{tag}.json"), "w") as f:
        json.dump(record, f, indent=1)
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="arch id (default: all)")
    ap.add_argument("--shape", default=None, help="shape name (default: all)")
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else ARCHS
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    n_ok = n_fail = n_skip = 0
    for arch in archs:
        cfg = get_config(arch)
        for shape_name in shapes:
            if not applicable(cfg, shape_name):
                print(f"[dryrun] SKIP {arch}__{shape_name} "
                      f"(long-context requires sub-quadratic arch)")
                n_skip += 1
                continue
            for mp in meshes:
                tag = (f"{arch}__{shape_name}__"
                       f"{'2x16x16' if mp else '16x16'}")
                path = os.path.join(args.out, tag + ".json")
                if args.skip_existing and os.path.exists(path):
                    with open(path) as f:
                        if json.load(f).get("status") == "ok":
                            n_skip += 1
                            continue
                rec = run_cell(arch, shape_name, mp, args.out)
                n_ok += rec["status"] == "ok"
                n_fail += rec["status"] != "ok"
    print(f"[dryrun] done: {n_ok} ok, {n_fail} failed, {n_skip} skipped")
    return 0 if n_fail == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
