"""Train step: loss -> grad -> AdamW. Pure function factory for pjit."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.train.optimizer import AdamWConfig, OptState, adamw_update


def make_train_step(model, opt_cfg: AdamWConfig):
    def train_step(params, opt_state: OptState, batch):
        loss, grads = jax.value_and_grad(model.loss)(params, batch)
        new_params, new_opt, gnorm = adamw_update(opt_cfg, params, grads,
                                                  opt_state)
        metrics = {"loss": loss.astype(jnp.float32), "grad_norm": gnorm,
                   "step": new_opt.step}
        return new_params, new_opt, metrics

    return train_step
