"""AdamW, hand-rolled (no optax in this environment).

Moments are fp32 pytrees mirroring the params, so they inherit the params'
sharding (FSDP over ("pod","data") × TP over "model") — the ZeRO-style layout
that the ESRP fault-tolerance layer (repro.ft) protects with periodic buddy
storage.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100


class OptState(NamedTuple):
    mu: Any            # fp32, like params
    nu: Any            # fp32, like params
    step: jax.Array    # int32 scalar


def init_opt_state(params) -> OptState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return OptState(mu=zeros,
                    nu=jax.tree.map(jnp.copy, zeros),
                    step=jnp.zeros((), jnp.int32))


def _schedule(cfg: AdamWConfig, step):
    warm = jnp.minimum(step.astype(jnp.float32) / max(cfg.warmup_steps, 1),
                       1.0)
    return cfg.lr * warm


def adamw_update(cfg: AdamWConfig, params, grads, opt: OptState):
    """Returns (new_params, new_opt_state, grad_norm)."""
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    step = opt.step + 1
    lr = _schedule(cfg, step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu = cfg.b1 * mu + (1 - cfg.b1) * g
        nu = cfg.b2 * nu + (1 - cfg.b2) * jnp.square(g)
        mhat = mu / b1c
        nhat = nu / b2c
        pf = p.astype(jnp.float32)
        pf = pf - lr * (mhat / (jnp.sqrt(nhat) + cfg.eps)
                        + cfg.weight_decay * pf)
        return pf.astype(p.dtype), mu, nu

    out = jax.tree.map(upd, params, grads, opt.mu, opt.nu)
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_mu = jax.tree.map(lambda t: t[1], out,
                          is_leaf=lambda x: isinstance(x, tuple))
    new_nu = jax.tree.map(lambda t: t[2], out,
                          is_leaf=lambda x: isinstance(x, tuple))
    return new_params, OptState(new_mu, new_nu, step), gnorm
