"""repro.analysis — static-invariant checker for the solver stack.

Walks the jaxprs of registered solver entry points and machine-checks the
invariants the paper's recovery math rests on: bit-identical obs=off /
sdc_policy=None compilation (structural differ), zero-cost ``lax.cond``
gating, sync-free chunk bodies, optimization_barrier-pinned reductions,
and shard_map PartitionSpec discipline. See ``python -m repro.analysis
--list`` and EXPERIMENTS.md "Static invariants".

This package root stays jax-free: the CLI must set XLA_FLAGS (8 forced
host devices for the sharded entries) before jax is imported, and tests
import the walker/differ without paying registry-tracing costs. The
jax-importing pieces (``registry``, ``fixtures``, ``cli``) load lazily.
"""
from repro.analysis import marks, structural, walker
from repro.analysis.findings import (FINDINGS_SCHEMA_VERSION, Finding,
                                     apply_baseline, check_findings_doc,
                                     findings_doc, load_baseline)
from repro.analysis.structural import (assert_structurally_equal,
                                       canonical_lines, first_divergence)

__all__ = [
    "FINDINGS_SCHEMA_VERSION", "Finding", "apply_baseline",
    "assert_structurally_equal", "canonical_lines", "check_findings_doc",
    "findings_doc", "first_divergence", "load_baseline", "marks",
    "structural", "walker",
]
