"""Entry-point registry: every solver surface the static passes gate.

Each entry builds an ``EntrySpec`` — the traced jaxpr of one registered
solver entry point plus its invariant contract (tags, identity reference,
gate count, sharding-spec tables). Building only *traces* (plus a cheap
``*_init`` evaluation); nothing is compiled.

The registry spans the esrp/imcr/pcg chunk runners (plain, residual-
replacement, SDC-guarded, obs=on, batched), the preconditioner applies,
the fused SpMV+dot kernel oracle, and the 8-device sharded variants
(chunk with physical queue pushes, matvec, mirror-pinned dot, redundancy
queue). Entries whose mesh needs more host devices than available declare
``requires_devices`` and are skipped (and reported) rather than crashing —
``python -m repro.analysis`` forces ``--xla_force_host_platform_device_count=8``
so the CLI always covers them on CPU.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable

from repro.analysis.passes import EntrySpec

_REGISTRY: dict[str, "EntryPoint"] = {}

# chunk length / storage period used for all traced chunk entries: small
# enough to trace fast, large enough that every gate appears
_T, _N = 10, 8


@dataclasses.dataclass(frozen=True)
class EntryPoint:
    name: str
    build: Callable[[], EntrySpec]
    requires_devices: int = 1
    broken: bool = False      # deliberately-violating fixture (tests only)
    summary: str = ""


def register(name: str, *, requires_devices: int = 1, broken: bool = False,
             summary: str = ""):
    def deco(fn):
        _REGISTRY[name] = EntryPoint(name, fn, requires_devices, broken,
                                     summary)
        return fn
    return deco


def names(include_broken: bool = False) -> list[str]:
    _ensure_fixtures()
    return sorted(n for n, e in _REGISTRY.items()
                  if include_broken or not e.broken)


def get(name: str) -> EntryPoint:
    _ensure_fixtures()
    return _REGISTRY[name]


def build(name: str) -> EntrySpec:
    return get(name).build()


def _ensure_fixtures():
    from repro.analysis import fixtures  # noqa: F401  (registers broken.*)


# --------------------------------------------------------------------------- #
# shared problem / trace helpers
# --------------------------------------------------------------------------- #
@functools.lru_cache
def _problem(n_nodes: int = 4, nx: int = 16, precond: str = "jacobi"):
    from repro.sparse.matrices import build_problem
    return build_problem("poisson2d", n_nodes=n_nodes, nx=nx, ny=nx,
                         precond=precond)


def _rhs(problem, batch: int):
    import jax.numpy as jnp
    b = jnp.asarray(problem.b)
    if not batch:
        return b
    # distinct members so nothing constant-folds uniformly
    return jnp.stack([b * (i + 1.0) for i in range(batch)])


def _thresh(rhs, batch: int):
    import jax.numpy as jnp
    return (jnp.full((batch,), 1e-8, rhs.dtype) if batch
            else jnp.asarray(1e-8, rhs.dtype))


def _esrp_chunk_jaxpr(ops, rhs, thresh, *, rr_every=0, metrics=False,
                      sdc_check=None, push=None, st=None, T=_T, n=_N):
    import jax
    from repro.core import esrp
    if st is None:
        st = esrp.esrp_init(ops.matvec, ops.precond, rhs, dot=ops.dot)
    return st, jax.make_jaxpr(lambda s: esrp.run_chunk.__wrapped__(
        s, ops, T, n, thresh, rr_every, True, rhs, push, metrics,
        sdc_check))(st)


def _esrp_ref_chunk_jaxpr(ops, rhs, thresh, st, *, T=_T, n=_N):
    """The pre-telemetry, guard-free chunk runner re-derived inline (the
    identity reference for obs=off / sdc_policy=None): a plain freeze scan
    over ``esrp_step`` — per-member freeze on batched state."""
    import jax
    import jax.numpy as jnp
    from repro.core import esrp
    batched = rhs.ndim == 2

    def norm(r):
        return jnp.linalg.norm(r) if not batched \
            else jnp.linalg.norm(r, axis=-1)

    def step(s):
        s2 = esrp.esrp_step(s, ops, T, b=rhs, rr_every=0, gated=True,
                            push=None)
        return s2, norm(s2.pcg.r)

    def ref_chunk(s0):
        if batched:
            def advance(carry):
                s, rnorm = carry
                s2, rn2 = step(s)
                done = rnorm < thresh
                return (esrp.member_select(s, s2, done),
                        jnp.where(done, rnorm, rn2))

            def body(carry, _):
                carry = jax.lax.cond(jnp.all(carry[1] < thresh),
                                     lambda c: c, advance, carry)
                return carry, carry[1]
        else:
            def body(carry, _):
                s, rnorm = carry
                s, rnorm = jax.lax.cond(
                    rnorm < thresh, lambda s_: (s_, rnorm), step, s)
                return (s, rnorm), rnorm

        (s0, _), norms = jax.lax.scan(body, (s0, norm(s0.pcg.r)), None,
                                      length=n)
        return s0, norms

    return jax.make_jaxpr(ref_chunk)(st)


# --------------------------------------------------------------------------- #
# esrp / imcr / pcg chunk runners (single device)
# --------------------------------------------------------------------------- #
def _esrp_entry(name, backend, *, rr_every=0, batch=0, metrics=False,
                sdc=False, with_ref=False, T=_T):
    ops = (_problem().solver_ops(backend, batch=batch) if batch
           else _problem().solver_ops(backend))
    rhs = _rhs(_problem(), batch)
    thresh = _thresh(rhs, batch)
    sdc_check = None
    if sdc:
        from repro.core.sdc import SDCPolicy
        sdc_check = SDCPolicy(check_every=4)
    st, jaxpr = _esrp_chunk_jaxpr(ops, rhs, thresh, rr_every=rr_every,
                                  metrics=metrics, sdc_check=sdc_check, T=T)
    ref = (_esrp_ref_chunk_jaxpr(ops, rhs, thresh, st, T=T)
           if with_ref else None)
    tags = {"sync_free", "gated"}
    if not metrics:
        tags.add("bit_identical")
    if batch:
        tags.add("batched")
    # freeze cond + per-iteration push/star gates (+ replacement, + guard)
    min_gates = 3 + (1 if rr_every else 0) + (1 if sdc else 0)
    return EntrySpec(
        name=name, jaxpr=jaxpr, tags=frozenset(tags), identity_ref=ref,
        identity_label="pre-telemetry guard-free chunk scan",
        batch=batch, min_gates=min_gates)


register("esrp.chunk.jnp", summary="ESRP chunk runner, jnp reference ops; "
         "identity vs the pre-telemetry scan")(
    lambda: _esrp_entry("esrp.chunk.jnp", "jnp", with_ref=True))

register("esrp.chunk.interpret", summary="ESRP chunk runner, Pallas kernels "
         "in interpret mode")(
    lambda: _esrp_entry("esrp.chunk.interpret", "interpret"))

register("esrp.chunk.rr.jnp", summary="ESRP chunk with the residual-"
         "replacement gate armed (rr_every=4)")(
    lambda: _esrp_entry("esrp.chunk.rr.jnp", "jnp", rr_every=4))

register("esrp.chunk.sdc.jnp", summary="ESRP chunk with the on-device SDC "
         "halt guard armed")(
    lambda: _esrp_entry("esrp.chunk.sdc.jnp", "jnp", sdc=True))

register("esrp.chunk.obs.jnp", summary="ESRP chunk with the metrics ring "
         "armed (obs=on)")(
    lambda: _esrp_entry("esrp.chunk.obs.jnp", "jnp", metrics=True))

register("esrp.chunk.batched.jnp", summary="batched (B=3) ESRP chunk, "
         "per-member convergence freeze; identity vs the batched scan")(
    lambda: _esrp_entry("esrp.chunk.batched.jnp", "jnp", batch=3,
                        with_ref=True))

register("pcg.chunk.jnp", summary="plain-PCG chunk (strategy='none' "
         "T-sentinel); sdc_policy=None must equal the guard-free scan")(
    lambda: _esrp_entry("pcg.chunk.jnp", "jnp", with_ref=True, T=1 << 30))


def _imcr_entry(name, *, batch=0, with_ref=False):
    import jax
    import jax.numpy as jnp
    from repro.core import imcr
    p = _problem()
    ops = p.solver_ops("jnp", batch=batch) if batch else p.solver_ops("jnp")
    rhs = _rhs(p, batch)
    thresh = _thresh(rhs, batch)
    rows = p.part.rows_per_node
    st = imcr.imcr_init(ops.matvec, ops.precond, rhs, dot=ops.dot)
    jaxpr = jax.make_jaxpr(lambda s: imcr.run_chunk.__wrapped__(
        s, ops, _T, 1, rows, _N, thresh, True, False))(st)
    ref = None
    if with_ref:
        def step(s):
            s2 = imcr.imcr_step(s, ops, _T, 1, rows, True)
            return s2, jnp.linalg.norm(s2.pcg.r)

        def ref_chunk(s0):
            def body(carry, _):
                s, rnorm = carry
                s, rnorm = jax.lax.cond(
                    rnorm < thresh, lambda s_: (s_, rnorm), step, s)
                return (s, rnorm), rnorm

            (s0, _), norms = jax.lax.scan(
                body, (s0, jnp.linalg.norm(s0.pcg.r)), None, length=_N)
            return s0, norms

        ref = jax.make_jaxpr(ref_chunk)(st)
    tags = {"sync_free", "gated", "bit_identical"}
    if batch:
        tags.add("batched")
    return EntrySpec(name=name, jaxpr=jaxpr, tags=frozenset(tags),
                     identity_ref=ref,
                     identity_label="pre-telemetry guard-free chunk scan",
                     batch=batch, min_gates=2)   # freeze + checkpoint gate


register("imcr.chunk.jnp", summary="IMCR chunk runner; identity vs the "
         "pre-telemetry scan")(
    lambda: _imcr_entry("imcr.chunk.jnp", with_ref=True))

register("imcr.chunk.batched.jnp", summary="batched (B=2) IMCR chunk")(
    lambda: _imcr_entry("imcr.chunk.batched.jnp", batch=2))


# --------------------------------------------------------------------------- #
# preconditioner applies + the fused SpMV/dot kernel oracle
# --------------------------------------------------------------------------- #
def _precond_entry(name, precond, extra_tags=()):
    import jax
    p = _problem(precond=precond)
    ops = p.solver_ops("jnp")
    rhs = _rhs(p, 0)
    jaxpr = jax.make_jaxpr(ops.precond)(rhs)
    return EntrySpec(name=name, jaxpr=jaxpr,
                     tags=frozenset({"sync_free", *extra_tags}))


for _pname, _ptags in (("jacobi", ("bit_identical",)), ("ssor", ()),
                       ("chebyshev", ()), ("ic0", ())):
    register(f"precond.{_pname}.jnp",
             summary=f"{_pname} preconditioner apply (jnp route)")(
        functools.partial(_precond_entry, f"precond.{_pname}.jnp", _pname,
                          _ptags))


def _spmv_dot_entry():
    import jax
    p = _problem()
    ops = p.solver_ops("jnp")
    jaxpr = jax.make_jaxpr(ops.matvec_dot)(_rhs(p, 0))
    return EntrySpec(name="kernels.spmv_dot.jnp", jaxpr=jaxpr,
                     tags=frozenset({"sync_free", "bit_identical"}))


register("kernels.spmv_dot.jnp", summary="fused y=Ax + x'y oracle — the "
         "optimization_barrier pinning idiom itself")(_spmv_dot_entry)


# --------------------------------------------------------------------------- #
# 8-device sharded variants
# --------------------------------------------------------------------------- #
_NODES = 8
# which array axis the "nodes" mesh axis may shard, by operand rank (see
# EXPERIMENTS.md "Static invariants"): vectors on axis 0, Block-ELL
# data/idx on axis 0, queue-push entries (n, w, bn) on axis 0
_SHARD_AXES = {1: (0,), 2: (0,), 3: (0,), 4: (0,)}
# batched: (B, M) vectors on axis 1, statics keep axis 0, the batched
# queue entry (B, n, w, bn) on axis 1; rank-4 also admits axis 0 for the
# Block-ELL data (row_tiles, ell, bn, bn), which is batch-independent
_SHARD_AXES_B = {1: (0,), 2: (0, 1), 3: (0, 1), 4: (0, 1)}


@functools.lru_cache
def _sharded_setup(batch: int = 0):
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.comm.shard import (ShardedFailureRuntime, nodes_mesh,
                                  place_problem, sharded_solver_ops)
    from repro.core import esrp
    from repro.core.aspmv import build_plan
    p = _problem(n_nodes=_NODES, nx=32)
    mesh = nodes_mesh(_NODES)
    placed = place_problem(p, mesh)
    with mesh:
        ops = sharded_solver_ops(placed, mesh, batch=batch)
    frt = ShardedFailureRuntime(placed, mesh, batch=batch)
    frt.bind_plan(build_plan(p.a, p.part, phi=2))
    rhs = _rhs(placed, batch)
    spec = P(None, "nodes") if batch else P("nodes")
    rhs = jax.device_put(rhs, NamedSharding(mesh, spec))
    with mesh:
        st = esrp.esrp_init(ops.matvec, ops.precond, rhs, dot=ops.dot)
        st = frt.init_queue(st)
    return placed, mesh, ops, frt, rhs, st


def _sharded_chunk_entry(name, batch=0, gathers=None):
    placed, mesh, ops, frt, rhs, st = _sharded_setup(batch)
    thresh = _thresh(rhs, batch)
    with mesh:
        _, jaxpr = _esrp_chunk_jaxpr(ops, rhs, thresh, push=frt.queue_push,
                                     st=st)
    tags = {"sync_free", "gated", "bit_identical", "sharded"}
    if batch:
        tags.add("batched")
    return EntrySpec(
        name=name, jaxpr=jaxpr, tags=frozenset(tags), batch=batch,
        min_gates=3, mesh_axes=("nodes",), allowed_gathers=gathers,
        nodes_axis_by_rank=dict(_SHARD_AXES_B if batch else _SHARD_AXES))


# gather budget: the SpMV halo all_gather + the queue push's natural-
# retention gather, each traced once inside the scan body
register("sharded.esrp.chunk.8dev", requires_devices=_NODES,
         summary="ESRP chunk on the 8-device mesh with physical queue "
         "pushes")(
    lambda: _sharded_chunk_entry("sharded.esrp.chunk.8dev", gathers=2))

register("sharded.esrp.chunk.batched.8dev", requires_devices=_NODES,
         summary="batched (B=2) ESRP chunk on the 8-device mesh")(
    lambda: _sharded_chunk_entry("sharded.esrp.chunk.batched.8dev",
                                 batch=2, gathers=2))


def _sharded_matvec_entry():
    import jax
    placed, mesh, ops, frt, rhs, st = _sharded_setup(0)
    with mesh:
        jaxpr = jax.make_jaxpr(ops.matvec)(rhs)
    return EntrySpec(name="sharded.matvec.8dev", jaxpr=jaxpr,
                     tags=frozenset({"sync_free", "sharded"}),
                     mesh_axes=("nodes",), allowed_gathers=1,
                     nodes_axis_by_rank=dict(_SHARD_AXES))


register("sharded.matvec.8dev", requires_devices=_NODES,
         summary="sharded Block-ELL SpMV (one halo all_gather)")(
    _sharded_matvec_entry)


def _sharded_dot_entry():
    import jax
    placed, mesh, ops, frt, rhs, st = _sharded_setup(0)
    with mesh:
        jaxpr = jax.make_jaxpr(ops.dot)(rhs, rhs)
    return EntrySpec(name="sharded.dot.8dev", jaxpr=jaxpr,
                     tags=frozenset({"sync_free", "bit_identical",
                                     "sharded"}),
                     mesh_axes=("nodes",), allowed_gathers=0,
                     nodes_axis_by_rank=dict(_SHARD_AXES))


register("sharded.dot.8dev", requires_devices=_NODES,
         summary="mirror-pinned slab dot (psum of barrier-pinned partials)")(
    _sharded_dot_entry)


def _sharded_queue_push_entry():
    import jax
    placed, mesh, ops, frt, rhs, st = _sharded_setup(0)
    with mesh:
        jaxpr = jax.make_jaxpr(frt.queue_push)(rhs)
    return EntrySpec(name="sharded.queue_push.8dev", jaxpr=jaxpr,
                     tags=frozenset({"sync_free", "sharded"}),
                     mesh_axes=("nodes",), allowed_gathers=1,
                     nodes_axis_by_rank=dict(_SHARD_AXES))


register("sharded.queue_push.8dev", requires_devices=_NODES,
         summary="redundancy-queue push (ring ppermutes + retention "
         "gather)")(_sharded_queue_push_entry)
