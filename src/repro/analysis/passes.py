"""The five static-invariant passes.

Each pass takes an ``EntrySpec`` (a traced entry-point jaxpr plus the
invariants the entry registered for) and returns ``Finding`` records.
Passes are pure jaxpr walks — no jax import, no execution — so a full
``--entry all`` run costs tracing time only.

  identity     structural differ vs the entry's registered reference jaxpr
               (obs=off / sdc_policy=None must compile to the pre-telemetry,
               guard-free chunk runner — exact rejoin rests on it)
  gating       every ``cond`` gate must own a work-free branch: the disabled
               side of the storage/SDC/residual-replacement gates adds zero
               SpMV/dot/queue-copy ops on non-storage iterations
  host_sync    no device->host forcing op (callbacks, infeed/outfeed) inside
               chunk bodies registered sync-free
  determinism  full-contraction reductions on bit-identical paths must be
               pinned by the optimization_barrier partial-accumulation idiom;
               batched entries must never reduce across the member axis
  sharding     shard_map in/out names stay on the declared mesh axes with no
               unintended replication, member-axis sharding, or explicit
               all-gathers beyond the entry's known SpMV-gather budget
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

from repro.analysis import structural, walker
from repro.analysis.findings import Finding

PASS_IDS = ("identity", "gating", "host_sync", "determinism", "sharding")

# ops that do real work when they appear inside a gate's "disabled" branch:
# SpMV/dot arithmetic, nested loops, and the queue-copy data movement the
# storage prelude performs on push iterations
WORK_PRIMS = frozenset({
    "dot_general", "conv_general_dilated", "scan", "while", "pallas_call",
    "concatenate", "gather", "scatter", "scatter-add", "dynamic_slice",
    "dynamic_update_slice", "all_gather", "ppermute", "psum", "all_reduce",
})

# primitives that force a device->host transfer (or round-trip) when they
# appear inside a chunk body: the sync-free driver protocol forbids them
SYNC_PRIM_NAMES = frozenset({
    "infeed", "outfeed", "host_local_array_to_global_array",
    "global_array_to_host_local_array",
})

# producers whose scalar reduce_sum is a *norm* (abs/square chains): these
# are shared jnp subgraphs across backends — deterministic by construction,
# no partial-accumulation pinning required (see EXPERIMENTS.md)
_NORM_PRODUCERS = frozenset({"abs", "integer_pow", "square", "real"})
# shape-preserving hops the pin detector looks through between a barrier
# and the reduction it pins
_TRANSPARENT = frozenset({"reshape", "convert_element_type", "squeeze",
                          "transpose", "copy"})


@dataclasses.dataclass
class EntrySpec:
    """One registered entry point: its traced jaxpr plus the invariant
    contract the passes check it against."""
    name: str
    jaxpr: Any                          # ClosedJaxpr of the entry
    tags: frozenset = frozenset()       # {"sync_free","gated","bit_identical",
    #                                      "batched","sharded"}
    identity_ref: Any = None            # ClosedJaxpr the entry must match
    identity_label: str = ""            # what the ref re-derives
    batch: int = 0                      # leading member-axis extent (0 = unbatched)
    min_gates: int = 0                  # cond gates the entry must hoist
    mesh_axes: tuple = ()               # declared mesh axis names ("nodes",)
    allowed_gathers: int | None = None  # explicit all_gather budget
    nodes_axis_by_rank: dict = dataclasses.field(default_factory=dict)
    #                                   # rank -> allowed sharded-axis indices
    repl_limit: int = 256               # max elements a replicated operand may hold


def _f(spec, pass_id, path, code, explanation, severity="error") -> Finding:
    return Finding(pass_id=pass_id, entry=spec.name, eqn_path=path,
                   severity=severity, code=code, explanation=explanation)


# --------------------------------------------------------------------------- #
# pass 1: structural identity
# --------------------------------------------------------------------------- #
def run_identity(spec: EntrySpec) -> list[Finding]:
    if spec.identity_ref is None:
        return []
    div = structural.first_divergence(spec.jaxpr, spec.identity_ref)
    if div is None:
        return []
    return [_f(spec, "identity", div["path"], "jaxpr-divergence",
               structural.divergence_message(div, spec.identity_label))]


# --------------------------------------------------------------------------- #
# pass 2: gating audit
# --------------------------------------------------------------------------- #
def _work_count(jaxpr) -> int:
    return sum(1 for s in walker.walk(jaxpr)
               if s.eqn.primitive.name in WORK_PRIMS)


def run_gating(spec: EntrySpec) -> list[Finding]:
    findings = []
    conds = walker.sites_of(spec.jaxpr, "cond")
    for site in conds:
        branches = walker.cond_branches(site.eqn)
        if len(branches) != 2:
            continue                    # N-way switch, not a gate
        costs = [_work_count(b) for b in branches]
        if min(costs) > 0:
            findings.append(_f(
                spec, "gating", site.path, "gated-branch-not-free",
                f"cond gate has no work-free branch: per-branch work-op "
                f"counts {costs} (WORK_PRIMS) — the disabled side of a "
                f"storage/SDC/replacement gate must contribute zero "
                f"SpMV/dot/queue-copy ops"))
    if spec.min_gates and len(conds) < spec.min_gates:
        findings.append(_f(
            spec, "gating", "", "missing-gates",
            f"entry registered {spec.min_gates} cond gates (storage push, "
            f"star capture, replacement, ...) but only {len(conds)} cond "
            f"eqns found — bookkeeping has been un-hoisted into the "
            f"unconditional trace"))
    return findings


# --------------------------------------------------------------------------- #
# pass 3: host-sync detection
# --------------------------------------------------------------------------- #
def run_host_sync(spec: EntrySpec) -> list[Finding]:
    if "sync_free" not in spec.tags:
        return []
    findings = []
    for site in walker.walk(spec.jaxpr):
        name = site.eqn.primitive.name
        if name in SYNC_PRIM_NAMES or "callback" in name:
            findings.append(_f(
                spec, "host_sync", site.path, "host-sync",
                f"'{name}' forces a device->host transfer inside a chunk "
                f"body registered sync-free — it would stall the driver's "
                f"overlapped dispatch/readback protocol"))
    return findings


# --------------------------------------------------------------------------- #
# pass 4: determinism / re-association lint
# --------------------------------------------------------------------------- #
def _producer_index(jaxpr):
    """var id -> producing eqn, for one (sub-)jaxpr level."""
    idx = {}
    for eqn in walker.unwrap(jaxpr).eqns:
        for v in eqn.outvars:
            idx[id(v)] = eqn
    return idx


def _pinned_or_norm(eqn, producers, hops: int = 4) -> bool:
    """Is this reduction's operand chain pinned by an optimization_barrier,
    or a norm-shaped (abs/square) monitoring reduction?"""
    var = eqn.invars[0]
    for _ in range(hops):
        prod = producers.get(id(var))
        if prod is None:
            return False
        name = prod.primitive.name
        if name in ("optimization_barrier", "pallas_call"):
            # a kernel output is as opaque to XLA as a barrier: the
            # partials' association is fixed at the kernel boundary
            return True
        if name in _NORM_PRODUCERS:
            return True
        if name == "mul" and len(prod.invars) == 2 \
                and prod.invars[0] is prod.invars[1]:
            return True                 # x*x square
        if name not in _TRANSPARENT:
            return False
        var = prod.invars[0]
    return False


def _scalar_contraction(eqn, batch: int) -> bool:
    """A dot_general / reduce_sum collapsing a whole vector: output rank 0,
    or rank 1 of extent ``batch`` (a per-member full contraction)."""
    out = eqn.outvars[0].aval
    shape = getattr(out, "shape", None)
    if shape is None:
        return False
    if len(shape) == 0:
        return True
    return batch > 0 and len(shape) == 1 and shape[0] == batch


def _each_jaxpr(jaxpr, prefix=""):
    """(prefix, jaxpr) for the entry and every nested sub-jaxpr — except
    pallas_call kernel bodies, whose reduction association is fixed by the
    kernel's own grid/block program (the pinning idiom lives *around* the
    kernel, not inside it)."""
    j = walker.unwrap(jaxpr)
    yield prefix, j
    for i, eqn in enumerate(j.eqns):
        if eqn.primitive.name == "pallas_call":
            continue
        for key, sub in walker.sub_jaxprs(eqn):
            yield from _each_jaxpr(sub, f"{prefix}eqn{i}/{key}/")


def run_determinism(spec: EntrySpec) -> list[Finding]:
    findings = []
    if "bit_identical" in spec.tags:
        for prefix, j in _each_jaxpr(spec.jaxpr):
            producers = _producer_index(j)
            for i, eqn in enumerate(j.eqns):
                name = eqn.primitive.name
                if name not in ("dot_general", "reduce_sum"):
                    continue
                if not _scalar_contraction(eqn, spec.batch):
                    continue
                op_shape = getattr(eqn.invars[0].aval, "shape", ())
                if name == "reduce_sum" \
                        and int(_size(op_shape)) <= 16:
                    continue            # tiny bookkeeping reduce
                if name == "dot_general":
                    # the pinned idiom never emits a full-contraction
                    # dot_general — it splits into per-block partials +
                    # barrier + reduce_sum — so any scalar dot here is
                    # an unpinned reduction
                    findings.append(_f(
                        spec, "determinism", f"{prefix}eqn{i}",
                        "unpinned-dot",
                        f"full-contraction dot_general (operand shape "
                        f"{tuple(op_shape)}) on a bit-identical-registered "
                        f"path: XLA may re-associate it per "
                        f"backend/topology — use the per-block partials + "
                        f"optimization_barrier + reduce_sum idiom "
                        f"(kernels/spmv/ref.py)"))
                elif not _pinned_or_norm(eqn, producers):
                    findings.append(_f(
                        spec, "determinism", f"{prefix}eqn{i}",
                        "unpinned-reduce",
                        f"scalar reduce_sum (operand shape "
                        f"{tuple(op_shape)}) not fed by an "
                        f"optimization_barrier (and not a norm-shaped "
                        f"abs/square reduction): the partial-sum "
                        f"association is at XLA's mercy"))
    if spec.batch > 0:
        for site in walker.walk(spec.jaxpr):
            eqn = site.eqn
            name = eqn.primitive.name
            if name not in ("reduce_sum", "reduce_prod",
                            "reduce_max", "reduce_min"):
                continue
            aval = eqn.invars[0].aval
            shape = getattr(aval, "shape", ())
            dtype = str(getattr(aval, "dtype", ""))
            axes = eqn.params.get("axes", ())
            if (len(shape) >= 2 and shape[0] == spec.batch
                    and 0 in tuple(axes) and dtype.startswith("float")):
                findings.append(_f(
                    spec, "determinism", site.path, "batch-axis-reduction",
                    f"{name} over axis 0 of a ({spec.batch}, ...) operand "
                    f"mixes members across the batch axis — batched ops "
                    f"must be rank-polymorphic in the leading axis "
                    f"(reduce per member, axis=-1)"))
    return findings


def _size(shape) -> int:
    n = 1
    for d in shape:
        n *= int(d)
    return n


# --------------------------------------------------------------------------- #
# pass 5: sharding-spec check
# --------------------------------------------------------------------------- #
def _names_to_axis(names: dict, rank: int):
    """shard_map in/out names dict {dim: (axis,...)} -> index of the dim
    sharded on the mesh (None = fully replicated)."""
    sharded = [d for d, ax in names.items() if ax]
    return sharded[0] if sharded else None


def run_sharding(spec: EntrySpec) -> list[Finding]:
    if "sharded" not in spec.tags:
        return []
    findings = []
    gathers = 0
    for site in walker.sites_of(spec.jaxpr, "shard_map"):
        eqn = site.eqn
        mesh = eqn.params.get("mesh")
        axis_names = tuple(getattr(mesh, "axis_names", ()))
        if spec.mesh_axes and axis_names != tuple(spec.mesh_axes):
            findings.append(_f(
                spec, "sharding", site.path, "foreign-mesh",
                f"shard_map over mesh axes {axis_names} — entry declared "
                f"{tuple(spec.mesh_axes)}"))
        in_names = eqn.params.get("in_names", ())
        out_names = eqn.params.get("out_names", ())
        roles = [("in", n, v.aval) for n, v in zip(in_names, eqn.invars)] + \
                [("out", n, v.aval) for n, v in zip(out_names, eqn.outvars)]
        for role, names, aval in roles:
            shape = tuple(getattr(aval, "shape", ()))
            rank = len(shape)
            axis = _names_to_axis(names, rank)
            if axis is None:
                if _size(shape) > spec.repl_limit:
                    findings.append(_f(
                        spec, "sharding", site.path, "unintended-replication",
                        f"shard_map {role} of shape {shape} is fully "
                        f"replicated ({_size(shape)} elems > repl_limit "
                        f"{spec.repl_limit}): every device pays the whole "
                        f"array — shard it on 'nodes' or whitelist it"))
                continue
            if spec.batch and axis == 0 and rank >= 2 \
                    and shape[0] == spec.batch:
                findings.append(_f(
                    spec, "sharding", site.path, "member-axis-sharded",
                    f"shard_map {role} of shape {shape} shards the leading "
                    f"member axis (B={spec.batch}) across 'nodes' — members "
                    f"are independent solves and must stay device-local "
                    f"(expected P(None, ..., 'nodes'))"))
                continue
            allowed = spec.nodes_axis_by_rank.get(rank)
            if allowed is not None and axis not in allowed:
                findings.append(_f(
                    spec, "sharding", site.path, "wrong-partition-axis",
                    f"shard_map {role} of shape {shape} sharded on axis "
                    f"{axis}; entry declares rank-{rank} operands sharded "
                    f"on axis {tuple(allowed)} (e.g. rq (3,B,n,w,bn) under "
                    f"P(None,None,'nodes'))"))
        body = eqn.params.get("jaxpr")
        if body is not None:
            gathers += sum(1 for s in walker.walk(body)
                           if s.eqn.primitive.name == "all_gather")
    if spec.allowed_gathers is not None and gathers > spec.allowed_gathers:
        findings.append(_f(
            spec, "sharding", "", "extra-all-gather",
            f"{gathers} explicit all_gather eqns inside shard_map bodies; "
            f"entry budgets {spec.allowed_gathers} (the known SpMV halo "
            f"gather + queue retention) — an extra gather replicates a "
            f"whole vector per call"))
    # sharding_constraint specs must stay on the declared mesh axes
    for site in walker.sites_of(spec.jaxpr, "sharding_constraint"):
        sharding = site.eqn.params.get("sharding")
        sp = getattr(sharding, "spec", None)
        if sp is None:
            continue
        used = {a for part in sp if part
                for a in ((part,) if isinstance(part, str) else tuple(part))}
        if spec.mesh_axes and not used <= set(spec.mesh_axes):
            findings.append(_f(
                spec, "sharding", site.path, "foreign-mesh",
                f"with_sharding_constraint uses axes {sorted(used)} outside "
                f"the declared mesh {tuple(spec.mesh_axes)}"))
    return findings


PASSES: dict[str, Callable[[EntrySpec], list[Finding]]] = {
    "identity": run_identity,
    "gating": run_gating,
    "host_sync": run_host_sync,
    "determinism": run_determinism,
    "sharding": run_sharding,
}


def run_passes(spec: EntrySpec, pass_ids=PASS_IDS) -> list[Finding]:
    findings = []
    for pid in pass_ids:
        findings += PASSES[pid](spec)
    return findings
