"""Entry point for ``python -m repro.analysis``.

Forces 8 host-platform devices (matching the subprocess convention of the
8-device mesh test suites) so the ``sharded.*.8dev`` entries are analyzable
on any CPU box — but only if jax has not been imported yet and the caller
did not pin the flag themselves.
"""
import os
import sys

if "jax" not in sys.modules:
    _flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in _flags:
        os.environ["XLA_FLAGS"] = (
            _flags + " --xla_force_host_platform_device_count=8").strip()

from repro.analysis.cli import main

if __name__ == "__main__":
    sys.exit(main())
