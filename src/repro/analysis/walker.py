"""Shared jaxpr traversal for the static-analysis passes (and tests).

Everything here duck-types jax's ``Jaxpr`` / ``ClosedJaxpr`` objects
(``.eqns`` / ``.jaxpr`` attributes) so the module imports without jax —
the CLI needs that to configure ``XLA_FLAGS`` before jax loads.

The walk replaces the one-off traversals that used to live in
``tests/test_solver_ops.py`` (``_dots`` / ``_sub``): every equation is
yielded with a stable path (``eqn3/branches[1]/eqn0``) usable as a finding
anchor, and cond descent is a switch, so "count work executed
unconditionally" and "audit what hides inside gates" are the same walk.
"""
from __future__ import annotations

from typing import Any, Iterable, Iterator, NamedTuple


def unwrap(obj):
    """The raw ``Jaxpr`` behind a ``ClosedJaxpr`` (or the object itself)."""
    return obj.jaxpr if hasattr(obj, "jaxpr") else obj


def sub_jaxprs(eqn) -> list[tuple[str, Any]]:
    """``(param_path, Jaxpr)`` for every sub-jaxpr in ``eqn.params``
    (cond branches, scan/pjit/shard_map bodies, ...), in sorted-key order
    so paths are deterministic."""
    out = []
    for key in sorted(eqn.params):
        val = eqn.params[key]
        if isinstance(val, (list, tuple)):
            for i, u in enumerate(val):
                if hasattr(u, "jaxpr") or hasattr(u, "eqns"):
                    out.append((f"{key}[{i}]", unwrap(u)))
        elif hasattr(val, "jaxpr") or hasattr(val, "eqns"):
            out.append((key, unwrap(val)))
    return out


class EqnSite(NamedTuple):
    """One equation plus where it sits: ``path`` is the / -joined chain of
    eqn indices and sub-jaxpr param keys from the entry jaxpr down."""
    path: str
    eqn: Any
    in_cond: bool      # True iff the site is inside any cond branch

    @property
    def depth(self) -> int:
        return self.path.count("/") // 2


def walk(jaxpr, *, into_conds: bool = True, _prefix: str = "",
         _in_cond: bool = False) -> Iterator[EqnSite]:
    """Yield every equation reachable from ``jaxpr`` depth-first.

    ``into_conds=False`` skips cond branches — the remaining sites are
    exactly the ops executed unconditionally (the old ``_dots`` contract).
    """
    j = unwrap(jaxpr)
    for i, eqn in enumerate(j.eqns):
        path = f"{_prefix}eqn{i}"
        yield EqnSite(path, eqn, _in_cond)
        is_cond = eqn.primitive.name == "cond"
        if is_cond and not into_conds:
            continue
        for key, sub in sub_jaxprs(eqn):
            yield from walk(sub, into_conds=into_conds,
                            _prefix=f"{path}/{key}/",
                            _in_cond=_in_cond or is_cond)


def count_primitives(jaxpr, names: str | Iterable[str], *,
                     into_conds: bool = False) -> int:
    """How many equations with these primitive names execute — by default
    unconditionally (cond branches excluded), the gating-audit convention."""
    wanted = {names} if isinstance(names, str) else set(names)
    return sum(1 for s in walk(jaxpr, into_conds=into_conds)
               if s.eqn.primitive.name in wanted)


def sites_of(jaxpr, names: str | Iterable[str], *,
             into_conds: bool = True) -> list[EqnSite]:
    """All sites whose primitive name is in ``names``."""
    wanted = {names} if isinstance(names, str) else set(names)
    return [s for s in walk(jaxpr, into_conds=into_conds)
            if s.eqn.primitive.name in wanted]


def cond_branches(eqn) -> list[Any]:
    """The branch jaxprs of a cond equation (index 0 = predicate False)."""
    return [unwrap(b) for b in eqn.params["branches"]]
