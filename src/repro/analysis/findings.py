"""Finding records, the findings-JSON schema, and baseline waivers.

A ``Finding`` is one violated invariant at one equation site. The JSON
document written by ``python -m repro.analysis --out`` (and validated by
``repro.obs.validate --analysis``) is::

    {"schema_version": 1, "tool": "repro.analysis",
     "entries": [...], "passes": [...], "skipped": [...],
     "findings": [{"pass_id", "entry", "eqn_path", "severity",
                   "code", "explanation"}, ...]}

The committed baseline (``artifacts/analysis/baseline.json``) waives
known findings by ``(pass_id, entry, code)`` — deliberately NOT by eqn
path, which shifts between jax versions — each with a required
justification and a ``max`` occurrence count, so a waived class cannot
silently grow.
"""
from __future__ import annotations

import dataclasses
import json

FINDINGS_SCHEMA_VERSION = 1
SEVERITIES = ("error", "warning", "info")


@dataclasses.dataclass(frozen=True)
class Finding:
    pass_id: str          # which pass fired (repro.analysis.passes.PASS_IDS)
    entry: str            # registered entry-point name
    eqn_path: str         # walker path of the offending equation ("" = whole entry)
    severity: str         # error | warning | info
    code: str             # stable short code, the baseline-waiver unit
    explanation: str

    def waiver_key(self) -> tuple[str, str, str]:
        return (self.pass_id, self.entry, self.code)

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


def findings_doc(findings, entries, passes, skipped=()) -> dict:
    return {
        "schema_version": FINDINGS_SCHEMA_VERSION,
        "tool": "repro.analysis",
        "entries": sorted(entries),
        "passes": sorted(passes),
        "skipped": sorted(skipped),
        "findings": [f.to_json() for f in findings],
    }


def check_findings_doc(doc) -> list[str]:
    """Schema errors of an analyzer findings JSON (the
    ``repro.obs.validate --analysis`` gate). Empty list = valid."""
    errors = []
    if not isinstance(doc, dict):
        return ["findings doc is not a JSON object"]
    ver = doc.get("schema_version")
    if not isinstance(ver, int) or ver < 1:
        errors.append(f"missing/invalid schema_version (got {ver!r})")
    if doc.get("tool") != "repro.analysis":
        errors.append(f"tool is not 'repro.analysis' (got {doc.get('tool')!r})")
    entries = doc.get("entries")
    if not isinstance(entries, list) or not entries \
            or not all(isinstance(e, str) for e in entries):
        errors.append("entries must be a non-empty list of entry names")
        entries = []
    passes = doc.get("passes")
    if not isinstance(passes, list) or not passes \
            or not all(isinstance(p, str) for p in passes):
        errors.append("passes must be a non-empty list of pass ids")
        passes = []
    if not isinstance(doc.get("findings"), list):
        errors.append("findings must be a list")
        return errors
    for i, f in enumerate(doc["findings"]):
        if not isinstance(f, dict):
            errors.append(f"finding {i}: not an object")
            continue
        for field in ("pass_id", "entry", "eqn_path", "code", "explanation"):
            if not isinstance(f.get(field), str):
                errors.append(f"finding {i}: missing string {field}")
        if f.get("severity") not in SEVERITIES:
            errors.append(f"finding {i}: severity {f.get('severity')!r} "
                          f"not in {SEVERITIES}")
        if passes and isinstance(f.get("pass_id"), str) \
                and f["pass_id"] not in passes:
            errors.append(f"finding {i}: pass_id {f['pass_id']!r} "
                          f"not in the doc's passes list")
        if entries and isinstance(f.get("entry"), str) \
                and f["entry"] not in entries:
            errors.append(f"finding {i}: entry {f['entry']!r} "
                          f"not in the doc's entries list")
        if isinstance(f.get("explanation"), str) and not f["explanation"]:
            errors.append(f"finding {i}: empty explanation")
    return errors


# --------------------------------------------------------------------------- #
# baseline waivers
# --------------------------------------------------------------------------- #
def load_baseline(path: str) -> list[dict]:
    """Waiver records from a committed baseline file; each must carry
    pass_id/entry/code, a justification, and an occurrence cap ``max``."""
    with open(path) as f:
        doc = json.load(f)
    waivers = doc.get("waivers", [])
    for i, w in enumerate(waivers):
        for field in ("pass_id", "entry", "code", "justification"):
            if not isinstance(w.get(field), str) or not w[field]:
                raise ValueError(f"baseline waiver {i}: missing {field}")
        if not isinstance(w.get("max"), int) or w["max"] < 1:
            raise ValueError(f"baseline waiver {i}: 'max' must be an int >= 1")
    return waivers


def apply_baseline(findings, waivers):
    """Split findings into (new, waived). A waiver absorbs up to ``max``
    findings with its (pass_id, entry, code); overflow stays new."""
    budget = {(w["pass_id"], w["entry"], w["code"]): w["max"] for w in waivers}
    new, waived = [], []
    for f in findings:
        key = f.waiver_key()
        if budget.get(key, 0) > 0:
            budget[key] -= 1
            waived.append(f)
        else:
            new.append(f)
    return new, waived
