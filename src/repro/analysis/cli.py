"""``python -m repro.analysis`` — run the static-invariant passes.

    python -m repro.analysis --entry all --format text
    python -m repro.analysis --entry all --baseline artifacts/analysis/baseline.json \
        --out artifacts/analysis/findings.json        # the CI gate
    python -m repro.analysis --list

Exit status 0 iff no finding survives the baseline waivers. ``--out``
writes the findings JSON (validated by ``repro.obs.validate --analysis``).

Keep this module import-light: ``__main__`` configures XLA_FLAGS for the
8-device host platform *before* anything imports jax, so the registry and
jax itself are imported lazily inside ``main``.
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.analysis.findings import (apply_baseline, findings_doc,
                                     load_baseline)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="static-invariant checker: jaxpr identity, zero-cost "
                    "gating, sync-freedom, reduction pinning, sharding "
                    "discipline")
    ap.add_argument("--entry", default="all",
                    help="comma-separated entry names, or 'all' (every "
                         "registered non-broken entry)")
    ap.add_argument("--passes", default="all",
                    help="comma-separated pass ids, or 'all'")
    ap.add_argument("--format", choices=("text", "json"), default="text")
    ap.add_argument("--baseline", default=None,
                    help="baseline JSON whose waivers suppress known "
                         "findings (artifacts/analysis/baseline.json)")
    ap.add_argument("--out", default=None,
                    help="write the findings JSON document here")
    ap.add_argument("--list", action="store_true",
                    help="list registered entries and exit")
    args = ap.parse_args(argv)

    from repro.analysis import registry
    from repro.analysis.passes import PASS_IDS, run_passes

    if args.list:
        for name in registry.names(include_broken=True):
            ep = registry.get(name)
            extra = " [broken fixture]" if ep.broken else ""
            if ep.requires_devices > 1:
                extra += f" [needs {ep.requires_devices} devices]"
            print(f"{name:36s} {ep.summary}{extra}")
        return 0

    pass_ids = (PASS_IDS if args.passes == "all"
                else tuple(p for p in args.passes.split(",") if p))
    unknown = set(pass_ids) - set(PASS_IDS)
    if unknown:
        print(f"unknown passes: {sorted(unknown)} "
              f"(have {list(PASS_IDS)})", file=sys.stderr)
        return 2

    if args.entry == "all":
        entry_names = registry.names()
    else:
        entry_names = [e for e in args.entry.split(",") if e]
        missing = [e for e in entry_names
                   if e not in registry.names(include_broken=True)]
        if missing:
            print(f"unknown entries: {missing} (see --list)",
                  file=sys.stderr)
            return 2

    import jax
    n_dev = jax.device_count()

    findings, analyzed, skipped = [], [], []
    for name in entry_names:
        ep = registry.get(name)
        if ep.requires_devices > n_dev:
            skipped.append(name)
            print(f"SKIP {name}: needs {ep.requires_devices} devices, "
                  f"have {n_dev}", file=sys.stderr)
            continue
        spec = registry.build(name)
        findings += run_passes(spec, pass_ids)
        analyzed.append(name)

    doc = findings_doc(findings, analyzed, pass_ids, skipped)
    if args.out:
        import os
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(doc, f, indent=1)

    waived = []
    if args.baseline:
        findings, waived = apply_baseline(findings,
                                          load_baseline(args.baseline))

    if args.format == "json":
        doc["new_findings"] = [f.to_json() for f in findings]
        doc["waived"] = len(waived)
        print(json.dumps(doc, indent=1))
    else:
        for f in findings:
            where = f.eqn_path or "<entry>"
            print(f"{f.severity.upper()} [{f.pass_id}] {f.entry} @ {where} "
                  f"({f.code})\n    {f.explanation}")
        print(f"analyzed {len(analyzed)} entries x {len(pass_ids)} passes: "
              f"{len(findings)} new finding(s), {len(waived)} waived"
              + (f", {len(skipped)} skipped ({', '.join(skipped)})"
                 if skipped else ""))
    return 1 if findings else 0
