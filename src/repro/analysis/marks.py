"""Invariant registration marks (import-light; no jax dependency).

Solver code registers the invariants it promises at the definition site:
``@sync_free`` on a chunk runner means "no op in my jaxpr may force a
device->host transfer" — the analyzer's host-sync pass keys off this
registry rather than a hard-coded list, so adding a runner automatically
puts it under the gate. The decorators only record the qualified name and
tag the function; they never wrap or slow the decorated callable.
"""
from __future__ import annotations

# qualified names of chunk runners registered sync-free (driver protocol:
# the convergence/halt flags ride the scan carry, readback overlaps the
# next dispatch — nothing inside the body may sync with the host)
SYNC_FREE: set[str] = set()


def _qualname(fn) -> str:
    return f"{getattr(fn, '__module__', '?')}." \
           f"{getattr(fn, '__qualname__', getattr(fn, '__name__', repr(fn)))}"


def sync_free(fn):
    """Register ``fn`` as a sync-free chunk body (analyzed by the
    ``host_sync`` pass; see repro.analysis.passes)."""
    SYNC_FREE.add(_qualname(fn))
    fn.__analysis_sync_free__ = True
    return fn
