"""Structural (alpha-equivalent) jaxpr comparison.

``canonical_lines`` renders a jaxpr as a deterministic list of
``(path, line)`` pairs: variables are renamed ``v0, v1, ...`` in order of
first appearance per (sub-)jaxpr, params are sorted by key, sub-jaxprs
(cond branches, scan bodies, pjit calls) are recursed with path-labelled
placeholders, and memory addresses in param reprs are masked. Two jaxprs
canonicalize identically iff they are the same program modulo variable
naming — the same strictness as ``str(a) == str(b)`` (which the identity
tests used to assert) minus the accidental dependence on trace-order var
names.

``first_divergence`` / ``assert_structurally_equal`` report the first
equation where two canonicalizations part ways, with context — replacing
an opaque string-inequality failure with "eqn N in branch B differs: got X,
want Y".
"""
from __future__ import annotations

import re

from repro.analysis.walker import unwrap

_ADDR = re.compile(r"0x[0-9a-fA-F]+")


def _aval_str(aval) -> str:
    short = getattr(aval, "str_short", None)
    return short() if callable(short) else repr(aval)


def _param_repr(val, path: str, key: str, subs: list) -> str:
    """Repr of one param value; jaxpr-valued params become path-labelled
    placeholders and are queued on ``subs`` for recursion."""
    if hasattr(val, "jaxpr") or hasattr(val, "eqns"):
        sub_path = f"{path}/{key}/"
        subs.append((sub_path, unwrap(val)))
        return f"<jaxpr:{key}>"
    if isinstance(val, (list, tuple)):
        inner = [_param_repr(v, path, f"{key}[{i}]", subs)
                 for i, v in enumerate(val)]
        return "(" + ",".join(inner) + ")"
    if isinstance(val, dict):
        inner = [f"{k!r}:{_param_repr(v, path, f'{key}.{k}', subs)}"
                 for k, v in sorted(val.items(), key=lambda kv: repr(kv[0]))]
        return "{" + ",".join(inner) + "}"
    return _ADDR.sub("0x..", repr(val))


def _canon(jaxpr, path: str, out: list) -> None:
    j = unwrap(jaxpr)
    names: dict[int, str] = {}

    def atom(v) -> str:
        if hasattr(v, "val"):                       # Literal
            return f"lit({_ADDR.sub('0x..', repr(v.val))}:{_aval_str(v.aval)})"
        if id(v) not in names:
            names[id(v)] = f"v{len(names)}"
        return f"{names[id(v)]}:{_aval_str(v.aval)}"

    out.append((path, "in(" + " ".join(
        atom(v) for v in (*j.constvars, *j.invars)) + ")"))
    for i, eqn in enumerate(j.eqns):
        p = f"{path}eqn{i}" if path.endswith("/") or not path \
            else f"{path}/eqn{i}"
        subs: list = []
        params = " ".join(
            f"{k}={_param_repr(eqn.params[k], p, k, subs)}"
            for k in sorted(eqn.params))
        effects = ""
        if getattr(eqn, "effects", None):
            effects = " effects=" + ",".join(
                sorted(_ADDR.sub("0x..", str(e)) for e in eqn.effects))
        out.append((p, " ".join(atom(v) for v in eqn.outvars)
                    + " = " + eqn.primitive.name
                    + "[" + params + "]" + effects + " "
                    + " ".join(atom(v) for v in eqn.invars)))
        for sub_path, sub in subs:
            _canon(sub, sub_path, out)
    out.append((path, "out(" + " ".join(atom(v) for v in j.outvars) + ")"))


def canonical_lines(jaxpr) -> list[tuple[str, str]]:
    """Deterministic (path, line) rendering of a jaxpr; two jaxprs are
    alpha-equivalent iff their canonical lines are equal."""
    out: list[tuple[str, str]] = []
    _canon(jaxpr, "", out)
    return out


def first_divergence(got, want) -> dict | None:
    """None if structurally equal, else the first diverging canonical line:
    ``{"index", "path", "got", "want", "context"}``."""
    a, b = canonical_lines(got), canonical_lines(want)
    for i, (la, lb) in enumerate(zip(a, b)):
        if la != lb:
            ctx = [f"  {pa} | {ta}" for pa, ta in a[max(0, i - 2):i]]
            return {"index": i, "path": la[0] or lb[0],
                    "got": f"{la[0]}: {la[1]}", "want": f"{lb[0]}: {lb[1]}",
                    "context": ctx}
    if len(a) != len(b):
        longer, tag = (a, "got") if len(a) > len(b) else (b, "want")
        i = min(len(a), len(b))
        return {"index": i, "path": longer[i][0],
                "got": f"[{len(a)} lines]", "want": f"[{len(b)} lines]",
                "context": [f"  extra {tag} line: "
                            f"{longer[i][0]}: {longer[i][1]}"]}
    return None


def divergence_message(div: dict, label: str = "") -> str:
    head = f"jaxprs structurally diverge{f' ({label})' if label else ''} " \
           f"at canonical line {div['index']} (path {div['path'] or '<top>'})"
    return "\n".join([head, *div["context"],
                      f"  got:  {div['got']}", f"  want: {div['want']}"])


def assert_structurally_equal(got, want, label: str = "") -> None:
    """Raise AssertionError naming the first diverging equation (the
    structural-differ replacement for ``assert str(got) == str(want)``)."""
    div = first_divergence(got, want)
    if div is not None:
        raise AssertionError(divergence_message(div, label))
