"""Deliberately-broken entry points (``broken.*``) — the analyzer's own
test fixtures. Each violates exactly the invariant its pass checks, so the
test suite can prove every pass actually fires:

  broken.identity     obs=off path that silently gained an extra op
  broken.gating       a "gate" whose disabled branch still runs a dot
  broken.host_sync    a debug callback inside a sync-free chunk body
  broken.determinism  a bare full-vector dot on a bit-identical path
  broken.batch        a reduction across the member axis of a batched op
  broken.sharding     replicated output + member-axis sharding (1-dev mesh)

Excluded from ``--entry all`` (and the CI gate); reachable by explicit
name for tests and demos.
"""
from __future__ import annotations

from repro.analysis.passes import EntrySpec
from repro.analysis.registry import register

_B = 3          # member count of the broken batched entry


@register("broken.identity", broken=True,
          summary="obs=off path with a smuggled extra op")
def _broken_identity():
    import jax
    import jax.numpy as jnp

    def runner(x):
        return jnp.cumsum(x * 2.0 + 1.0)

    def reference(x):
        return jnp.cumsum(x * 2.0)       # the op the runner smuggled in

    x = jnp.ones(32)
    return EntrySpec(
        name="broken.identity", jaxpr=jax.make_jaxpr(runner)(x),
        identity_ref=jax.make_jaxpr(reference)(x),
        identity_label="runner must add zero ops over the reference",
        tags=frozenset())


@register("broken.gating", broken=True,
          summary="cond gate whose disabled branch still pays a dot")
def _broken_gating():
    import jax
    import jax.numpy as jnp

    def runner(x, flag):
        # the "disabled" branch was supposed to be a passthrough but
        # recomputes a (cheaper) dot anyway — the gate saves nothing
        return jax.lax.cond(flag,
                            lambda v: v * (v @ v),
                            lambda v: v * (v[:8] @ v[:8]), x)

    x = jnp.ones(64)
    return EntrySpec(name="broken.gating",
                     jaxpr=jax.make_jaxpr(runner)(x, True),
                     tags=frozenset({"gated"}), min_gates=1)


@register("broken.host_sync", broken=True,
          summary="debug callback inside a sync-free chunk body")
def _broken_host_sync():
    import jax
    import jax.numpy as jnp

    def runner(x):
        def body(c, _):
            jax.debug.print("rnorm={r}", r=jnp.linalg.norm(c))
            return c * 0.5, jnp.linalg.norm(c)

        return jax.lax.scan(body, x, None, length=4)

    return EntrySpec(name="broken.host_sync",
                     jaxpr=jax.make_jaxpr(runner)(jnp.ones(32)),
                     tags=frozenset({"sync_free"}))


@register("broken.determinism", broken=True,
          summary="bare full-vector dot on a bit-identical path")
def _broken_determinism():
    import jax
    import jax.numpy as jnp

    def runner(u, v):
        # no per-block partials, no optimization_barrier: XLA picks the
        # association — a different backend/topology forks the trajectory
        return u @ v + jnp.sum(u * v * 2.0)

    x = jnp.ones(128)
    return EntrySpec(name="broken.determinism",
                     jaxpr=jax.make_jaxpr(runner)(x, x),
                     tags=frozenset({"bit_identical"}))


@register("broken.batch", broken=True,
          summary="reduction across the member axis of a batched op")
def _broken_batch():
    import jax
    import jax.numpy as jnp

    def runner(x):                      # x: (B, M)
        return jnp.sum(x, axis=0) / x.shape[0]   # mixes members!

    return EntrySpec(name="broken.batch",
                     jaxpr=jax.make_jaxpr(runner)(jnp.ones((_B, 64))),
                     tags=frozenset({"batched"}), batch=_B)


@register("broken.sharding", broken=True,
          summary="replicated big output + member-axis sharding")
def _broken_sharding():
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    mesh = jax.make_mesh((1,), ("nodes",))

    def runner(x, xb):                  # x: (M,), xb: (B, M)
        # out_specs P() replicates the whole vector on every device;
        # the batched operand shards the *member* axis across nodes
        rep = shard_map(lambda v: jax.lax.all_gather(v, "nodes",
                                                     tiled=True),
                        mesh=mesh, in_specs=(P("nodes"),), out_specs=P(),
                        check_rep=False)(x)
        mixed = shard_map(lambda v: v * 2.0, mesh=mesh,
                          in_specs=(P("nodes"),), out_specs=P("nodes"),
                          check_rep=False)(xb)
        return rep, mixed

    jaxpr = jax.make_jaxpr(runner)(jnp.ones(512), jnp.ones((_B, 64)))
    return EntrySpec(name="broken.sharding", jaxpr=jaxpr,
                     tags=frozenset({"sharded", "batched"}), batch=_B,
                     mesh_axes=("nodes",), allowed_gathers=0,
                     nodes_axis_by_rank={1: (0,), 2: (1,)})
