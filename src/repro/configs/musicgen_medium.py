"""musicgen-medium [audio] — 48L d_model=1536 24H (GQA kv=24) d_ff=6144
vocab=2048 — decoder-only over EnCodec tokens. Frontend STUB: input_specs
provides precomputed frame embeddings; single-stream (the 4-codebook delay
pattern is a frontend concern). [arXiv:2306.05284]"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="musicgen-medium",
        n_layers=48, d_model=1536, n_heads=24, n_kv_heads=24,
        d_ff=6144, vocab=2048,
        block_pattern="dense", norm="layernorm",
        rope_theta=10_000.0,
        frontend="audio",
        parallelism="fsdp",   # §Perf: ZeRO-3 beats 2D for train (cr-1 generalized)
        source="arXiv:2306.05284")


def smoke() -> ModelConfig:
    return ModelConfig(
        name="musicgen-medium-smoke",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab=128, block_pattern="dense", norm="layernorm",
        frontend="audio", remat="none")
