"""xlstm-125m [ssm] — 12L d_model=768 4H (GQA kv=4) d_ff=0 vocab=50304 —
sLSTM + mLSTM blocks (3 mLSTM : 1 sLSTM per group; no separate FFN —
the blocks carry their own projections). [arXiv:2405.04517; unverified]"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="xlstm-125m",
        n_layers=12, d_model=768, n_heads=4, n_kv_heads=4,
        d_ff=0, vocab=50304,
        block_pattern="xlstm:4",
        norm="rmsnorm", tie_embeddings=True,
        parallelism="fsdp",   # §Perf: ZeRO-3 beats 2D for train (cr-1 generalized)
        source="arXiv:2405.04517")


def smoke() -> ModelConfig:
    return ModelConfig(
        name="xlstm-125m-smoke",
        n_layers=4, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=0, vocab=256, block_pattern="xlstm:4",
        tie_embeddings=True, remat="none")
