"""internvl2-1b [vlm] — 24L d_model=896 14H (GQA kv=2) d_ff=4864
vocab=151655 — InternViT frontend (STUB: precomputed patch embeddings) +
Qwen2-0.5B-style backbone. [arXiv:2404.16821]"""
from repro.models.config import ModelConfig

N_PATCHES = 256        # precomputed patch embeds prepended to the text tokens


def config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-1b",
        n_layers=24, d_model=896, n_heads=14, n_kv_heads=2,
        d_ff=4864, vocab=151655,
        block_pattern="dense", norm="rmsnorm",
        rope_theta=1_000_000.0,
        frontend="vlm", n_frontend_tokens=N_PATCHES,
        parallelism="fsdp",   # §Perf: ZeRO-3 beats 2D for train (cr-1 generalized)
        source="arXiv:2404.16821")


def smoke() -> ModelConfig:
    return ModelConfig(
        name="internvl2-1b-smoke",
        n_layers=2, d_model=56, n_heads=4, n_kv_heads=2,
        d_ff=96, vocab=256, block_pattern="dense",
        frontend="vlm", n_frontend_tokens=8, remat="none")
