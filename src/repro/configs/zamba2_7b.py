"""zamba2-7b [hybrid] — 81L d_model=3584 32H (GQA kv=32) d_ff=14336
vocab=32000, ssm_state=64 — Mamba2 backbone + one shared attention block
applied every 6 Mamba blocks (weights shared across invocations).
[arXiv:2411.15242; unverified]"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-7b",
        n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32,
        d_ff=14336, vocab=32000,
        block_pattern="mamba_hybrid:6",
        ssm_state=64, ssm_head_dim=64, ssm_expand=2, ssm_chunk=128,
        norm="rmsnorm", rope_theta=10_000.0,
        parallelism="fsdp",   # §Perf: ZeRO-3 beats 2D for train (cr-1 generalized)
        source="arXiv:2411.15242")


def smoke() -> ModelConfig:
    return ModelConfig(
        name="zamba2-7b-smoke",
        n_layers=7, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab=256,
        block_pattern="mamba_hybrid:3",
        ssm_state=16, ssm_head_dim=16, ssm_expand=2, ssm_chunk=16,
        remat="none")
