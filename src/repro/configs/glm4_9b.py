"""glm4-9b [dense] — 40L d_model=4096 32H (GQA kv=2) d_ff=13696
vocab=151552 — RoPE, GQA. [hf:THUDM/glm-4-9b]"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="glm4-9b",
        n_layers=40, d_model=4096, n_heads=32, n_kv_heads=2,
        d_ff=13696, vocab=151552,
        block_pattern="dense", norm="rmsnorm",
        rope_theta=10_000.0,
        parallelism="fsdp",   # §Perf: ZeRO-3 beats 2D for train (cr-1 generalized)
        source="hf:THUDM/glm-4-9b")


def smoke() -> ModelConfig:
    return ModelConfig(
        name="glm4-9b-smoke",
        n_layers=2, d_model=64, n_heads=8, n_kv_heads=2,
        d_ff=96, vocab=256, block_pattern="dense", remat="none")
