"""Assigned architecture registry: ``get_config(name)`` / ``ARCHS``."""
from __future__ import annotations

import importlib

ARCHS = [
    "command_r_plus_104b",
    "internlm2_1_8b",
    "glm4_9b",
    "gemma3_27b",
    "qwen2_moe_a2_7b",
    "granite_moe_1b_a400m",
    "internvl2_1b",
    "zamba2_7b",
    "xlstm_125m",
    "musicgen_medium",
]

ALIASES = {a.replace("_", "-"): a for a in ARCHS}


def get_config(name: str, **overrides):
    key = ALIASES.get(name, name).replace("-", "_").replace(".", "_")
    mod = importlib.import_module(f"repro.configs.{key}")
    cfg = mod.config()
    if overrides:
        import dataclasses
        cfg = dataclasses.replace(cfg, **overrides)
    return cfg


def smoke_config(name: str):
    """Reduced same-family config for CPU smoke tests."""
    key = ALIASES.get(name, name).replace("-", "_").replace(".", "_")
    mod = importlib.import_module(f"repro.configs.{key}")
    return mod.smoke()
