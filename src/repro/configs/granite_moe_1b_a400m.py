"""granite-moe-1b-a400m [moe] — 24L d_model=1024 16H (GQA kv=8) d_ff=512
vocab=49155, MoE 32 experts top-8.
[hf:ibm-granite/granite-3.0-1b-a400m-base]"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-1b-a400m",
        n_layers=24, d_model=1024, n_heads=16, n_kv_heads=8,
        d_ff=512, vocab=49155,
        block_pattern="moe",
        n_experts=32, top_k=8, n_shared_experts=0, d_ff_expert=512,
        norm="rmsnorm", rope_theta=10_000.0,
        source="hf:ibm-granite/granite-3.0-1b-a400m-base")


def smoke() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-1b-a400m-smoke",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=64, vocab=259,          # deliberately not a multiple of 16
        block_pattern="moe",
        n_experts=5, top_k=2, n_shared_experts=0, d_ff_expert=64,
        remat="none")
