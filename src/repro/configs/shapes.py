"""Assigned input shapes and ``input_specs`` — ShapeDtypeStruct stand-ins for
every model input (no device allocation; the dry-run lowers against these).

  train_4k     seq_len=4096    global_batch=256   -> train_step
  prefill_32k  seq_len=32768   global_batch=32    -> prefill_step
  decode_32k   seq_len=32768   global_batch=128   -> decode_step (1 new token,
                                                    KV cache of seq_len)
  long_500k    seq_len=524288  global_batch=1     -> decode_step; requires a
               sub-quadratic arch — run for zamba2-7b / xlstm-125m / gemma3-27b
               (sliding-window), skipped for pure full-attention archs
               (DESIGN.md §Arch-applicability).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig

LONG_OK = {"gemma3-27b", "zamba2-7b", "xlstm-125m"}


@dataclasses.dataclass(frozen=True)
class Shape:
    name: str
    seq_len: int
    global_batch: int
    kind: str              # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": Shape("train_4k", 4096, 256, "train"),
    "prefill_32k": Shape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": Shape("decode_32k", 32768, 128, "decode"),
    "long_500k": Shape("long_500k", 524288, 1, "decode"),
}


def applicable(cfg: ModelConfig, shape_name: str) -> bool:
    if shape_name == "long_500k":
        return cfg.name in LONG_OK
    return True


def cells(cfgs):
    """All applicable (cfg, shape) dry-run cells."""
    out = []
    for cfg in cfgs:
        for sname, shape in SHAPES.items():
            if applicable(cfg, sname):
                out.append((cfg, shape))
    return out


def input_specs(cfg: ModelConfig, shape: Shape):
    """ShapeDtypeStructs for the model inputs of one cell.

    train/prefill: token batch (+ stub frontend embeddings + labels);
    decode: one new token per sequence (the KV cache specs come from
    ``LM.init_cache`` via ``jax.eval_shape``).
    """
    b, s = shape.global_batch, shape.seq_len
    f32 = jnp.float32
    i32 = jnp.int32
    sds = jax.ShapeDtypeStruct

    if shape.kind == "decode":
        return {"tokens": sds((b, 1), i32)}

    batch = {}
    if cfg.frontend == "vlm":
        nf = cfg.n_frontend_tokens
        batch["patch_embeds"] = sds((b, nf, cfg.d_model), f32)
        batch["tokens"] = sds((b, s - nf), i32)
        batch["labels"] = sds((b, s - nf), i32)
    elif cfg.frontend == "audio":
        batch["frame_embeds"] = sds((b, s, cfg.d_model), f32)
        batch["labels"] = sds((b, s), i32)
    else:
        batch["tokens"] = sds((b, s), i32)
        batch["labels"] = sds((b, s), i32)
    if shape.kind == "prefill":
        batch.pop("labels", None)
    return batch


def concrete_batch(cfg: ModelConfig, shape: Shape, seed: int = 0):
    """Small-scale concrete batch matching input_specs (for smoke tests)."""
    specs = input_specs(cfg, shape)
    key = jax.random.PRNGKey(seed)
    out = {}
    for name, sd in specs.items():
        key, k = jax.random.split(key)
        if sd.dtype == jnp.int32:
            out[name] = jax.random.randint(k, sd.shape, 0, cfg.vocab,
                                           dtype=jnp.int32)
        else:
            out[name] = jax.random.normal(k, sd.shape, sd.dtype)
    return out
