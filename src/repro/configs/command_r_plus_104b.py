"""command-r-plus-104b [dense] — 64L d_model=12288 96H (GQA kv=8)
d_ff=33792 vocab=256000 — GQA, no-bias, parallel blocks, tied embeddings.
[hf:CohereForAI/c4ai-command-r-v01; unverified]"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="command-r-plus-104b",
        n_layers=64, d_model=12288, n_heads=96, n_kv_heads=8,
        d_ff=33792, vocab=256000,
        block_pattern="dense", parallel_block=True,
        norm="layernorm", tie_embeddings=True,
        rope_theta=75_000_000.0,
        parallelism="fsdp",   # §Perf cr-1: ZeRO-3 beats 2D for this cell
        source="hf:CohereForAI/c4ai-command-r-plus")


def smoke() -> ModelConfig:
    return ModelConfig(
        name="command-r-plus-104b-smoke",
        n_layers=2, d_model=64, n_heads=8, n_kv_heads=2,
        d_ff=128, vocab=256,
        block_pattern="dense", parallel_block=True,
        norm="layernorm", tie_embeddings=True, remat="none")
