"""gemma3-27b [dense] — 62L d_model=5376 32H (GQA kv=16) d_ff=21504
vocab=262144 — 5:1 local:global sliding-window, 128k context.
[hf:google/gemma-3-27b-pt; unverified]"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma3-27b",
        n_layers=62, d_model=5376, n_heads=32, n_kv_heads=16,
        d_ff=21504, vocab=262144,
        block_pattern="local_global:6", window=1024,
        norm="rmsnorm", tie_embeddings=True,
        rope_theta=1_000_000.0,                  # global layers; locals 10k
        parallelism="fsdp",   # §Perf: ZeRO-3 beats 2D for train (cr-1 generalized)
        source="hf:google/gemma-3-27b-pt")


def smoke() -> ModelConfig:
    return ModelConfig(
        name="gemma3-27b-smoke",
        n_layers=8, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab=256,
        block_pattern="local_global:6", window=16,
        tie_embeddings=True, remat="none")
