"""internlm2-1.8b [dense] — 24L d_model=2048 16H (GQA kv=8) d_ff=8192
vocab=92544 — GQA. [arXiv:2403.17297; hf]"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="internlm2-1.8b",
        n_layers=24, d_model=2048, n_heads=16, n_kv_heads=8,
        d_ff=8192, vocab=92544,
        block_pattern="dense", norm="rmsnorm",
        rope_theta=1_000_000.0,
        parallelism="fsdp",   # §Perf: ZeRO-3 beats 2D for train (cr-1 generalized)
        source="arXiv:2403.17297")


def smoke() -> ModelConfig:
    return ModelConfig(
        name="internlm2-1.8b-smoke",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab=256, block_pattern="dense", remat="none")
