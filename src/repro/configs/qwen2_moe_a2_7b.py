"""qwen2-moe-a2.7b [moe] — 24L d_model=2048 16H (GQA kv=16) d_ff=1408
vocab=151936, MoE 60 routed top-4 + 4 shared experts.
[hf:Qwen/Qwen1.5-MoE-A2.7B]"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-moe-a2.7b",
        n_layers=24, d_model=2048, n_heads=16, n_kv_heads=16,
        d_ff=1408, vocab=151936,
        block_pattern="moe",
        n_experts=60, top_k=4, n_shared_experts=4, d_ff_expert=1408,
        norm="rmsnorm", rope_theta=1_000_000.0,
        source="hf:Qwen/Qwen1.5-MoE-A2.7B")


def smoke() -> ModelConfig:
    return ModelConfig(
        name="qwen2-moe-a2.7b-smoke",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=64, vocab=256,
        block_pattern="moe",
        n_experts=6, top_k=2, n_shared_experts=2, d_ff_expert=64,
        remat="none")
