"""Resilient-solve orchestration: run → (inject failures) → recover → converge.

Generalizes the paper's experimental protocol (§4-§5) from one node-failure
event per run to a *failure scenario*: a list of ``FailureEvent(iter, nodes)``
entries, each injected at a marked iteration (the driver lands exactly on
it). An event may strike several nodes simultaneously (the multi-node case of
Pachajoa et al., arXiv:1907.13077), and events may be staggered — failure →
recover → fail again, including a second event landing before the next
completed storage stage, which rolls back to the *same* reconstruction point
again (or restarts when none exists). Rollback rewinds the iteration counter
below already-consumed events without re-arming them; validation (strictly
increasing event iterations) keeps every pending event ahead of the rewound
counter, so each fires exactly once. Failed nodes zero out all their dynamic
data and then act as their own replacements. Reported quantities match the
paper's tables — total runtime, reconstruction overhead, wasted iterations,
converged iteration count, residual drift (Eq. 2) — plus a per-event
breakdown (``SolveReport.events``).

The hot loop runs through a ``SolverOps`` bundle (repro.core.ops): Block-ELL
SpMV fused with the pᵀq dot, fused vector update, cond-gated storage
bookkeeping. Convergence uses a sync-free chunked protocol: each chunk
carries ||r|| as a done flag and freezes the state at first convergence, so
the driver never re-runs a chunk to land on the convergence iteration, and
the norm-record readback of chunk i overlaps with the dispatch of chunk i+1
instead of blocking between chunks.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import esr, esrp, imcr
from repro.core.aspmv import RedundancyPlan, build_plan
from repro.core.failures import (FailureEvent, failed_row_mask,
                                 normalize_scenario, zero_failed)
from repro.core.ops import SolverOps, make_closure_ops
from repro.core.pcg import PCGState, residual_drift
from repro.sparse.matrices import Problem


@dataclasses.dataclass
class EventReport:
    """Per-event recovery accounting (one entry per fired FailureEvent)."""
    iter: int                    # iteration J the event struck
    nodes: tuple[int, ...]
    target_iter: int             # reconstruction point (-1 = restart)
    wasted_iters: int            # rollback distance of this event
    recovery_s: float            # reconstruction ops only
    inner_rel: float             # Alg.2 line-8 inner solve (nan: imcr/none)
    pff_iters: int = -1          # Alg.2 line-6 inner-CG iterations (-1 when
    #                              the preconditioner has a closed form)
    precond_reload_bytes: int = 0   # static preconditioner state the
    #                              replacement reloads from safe storage
    #                              (sharded runtime; see
    #                              precond.local.static_reload_bytes)
    queue_src_nodes: tuple[int, ...] = ()   # devices whose *physical* queue
    #                              shards supplied the failed rows'
    #                              p-copies (sharded runtime; empty on the
    #                              host-side simulator)


@dataclasses.dataclass
class SolveReport:
    strategy: str
    T: int
    phi: int
    converged_iter: int
    rel_residual: float
    runtime_s: float
    recovery_s: float            # reconstruction ops only, summed over events
    wasted_iters: int            # rollback distance, summed over events
    target_iter: int             # last event's reconstruction point; -1 when
    #                              no reconstruction happened (restart, or no
    #                              failure event at all — see ``events``)
    inner_rel: float             # last event's Alg.2 line-8 inner-solve residual
    drift: float                 # paper Eq. (2)
    aspmv_natural_bytes: int = 0
    aspmv_total_bytes: int = 0
    run_calls: int = 0           # chunk dispatches (no final-chunk re-run)
    events: list[EventReport] = dataclasses.field(default_factory=list)
    precond_variant: str = ""    # e.g. "node-local ssor" on the sharded
    #                              runtime (SolverOps.variant)
    local_delta_iters: Optional[int] = None   # iteration-count delta of a
    #                              node-local run vs the global-sweep
    #                              reference (shard.attach_local_delta)
    converged: bool = True       # False: the run stopped at max_iters with
    #                              ||r|| still above threshold
    precond_reload_bytes: int = 0   # summed over events (sharded runtime)
    x: Optional[object] = dataclasses.field(default=None, repr=False)
    #                              final iterate (device array) — lets parity
    #                              tests assert bit-identical rejoin; rel/
    #                              drift above are host-side norms whose flat
    #                              reduction may differ from the mesh's by
    #                              1 ulp even on identical vectors


def _find_convergence(norms: np.ndarray, thresh: float) -> int:
    """Index of first iteration with ||r|| < thresh, or -1."""
    below = np.nonzero(norms < thresh)[0]
    return int(below[0]) if below.size else -1


# module-level so the trace cache survives across solves (a fresh jit wrapper
# per resume would recompile the same iteration every failure run).
# esrp.numeric_step (not bare pcg_iterate_ops): the resume iteration must run
# the same rr_every residual-replacement gate as the chunk runner, or a
# replacement landing on the reconstruction point would be silently skipped
# and the post-recovery trajectory would fork off the failure-free one.
_resume_step = jax.jit(esrp.numeric_step, static_argnums=(1, 3, 4))


def solve_resilient(
    problem: Problem,
    strategy: str = "esrp",            # "esrp" | "imcr" | "none"
    T: int = 20,
    phi: int = 1,
    rtol: float = 1e-8,
    max_iters: int = 100_000,
    fail_at: Optional[int] = None,     # legacy one-event shorthand
    failed_nodes: Optional[list[int]] = None,
    scenario: Optional[list[FailureEvent]] = None,   # multi-event scenario
    matvec: Optional[Callable] = None,
    chunk: int = 64,
    rr_every: int = 0,                 # residual replacement period (0 = off)
    backend: str = "auto",             # SolverOps backend for the hot loop
    ops: Optional[SolverOps] = None,   # explicit bundle (overrides backend)
    gated: bool = True,                # cond-gated storage/rr bookkeeping
    pff_precond: bool = True,          # precondition the Alg.2 line-6 inner
    #                                    CG (False = historical plain CG)
    failure_runtime=None,              # comm.shard.ShardedFailureRuntime:
    #                                    device-resident redundancy queue,
    #                                    shard_map injection, and recovery
    #                                    reads from surviving devices' shards
) -> SolveReport:
    if ops is None:
        if matvec is not None:
            # cache the closure bundle on the problem so repeated solves with
            # the same matvec reuse the jitted chunk runners (the bundle is
            # their static argument), without pinning the problem in a
            # module-global cache
            cache = getattr(problem, "_closure_ops_cache", None)
            if cache is None:
                cache = {}
                problem._closure_ops_cache = cache
            key = (matvec, problem.apply_precond)
            if key not in cache:
                cache[key] = make_closure_ops(*key)
            ops = cache[key]
        else:
            ops = problem.solver_ops(backend)
    matvec = ops.matvec
    precond = ops.precond
    b = problem.b
    thresh_dev = jnp.asarray(rtol * float(jnp.linalg.norm(b)), b.dtype)
    # host-side scans must compare against the *same* value the chunk
    # runner's freeze uses, or (in f32) a norm between the two would freeze
    # the device state without the host ever declaring convergence
    thresh = float(thresh_dev)
    part = problem.part

    plan: Optional[RedundancyPlan] = None
    push = None
    if strategy == "esrp":
        plan = build_plan(problem.a, part, phi)   # static, verified φ+1 copies
        if failure_runtime is not None:
            # device-resident redundancy: the storage pushes physically
            # place each node's p-tiles on the designated holder devices
            failure_runtime.bind_plan(plan)
            push = failure_runtime.queue_push
    dot = getattr(ops, "dot", None)

    if strategy == "imcr":
        st = imcr.imcr_init(matvec, precond, b, dot=dot)
        run = lambda s, n: imcr.run_chunk(s, ops, T, phi,
                                          part.rows_per_node, n,
                                          thresh_dev, gated)
    elif strategy == "esrp":
        st = esrp.esrp_init(matvec, precond, b, dot=dot)
        if failure_runtime is not None:
            st = failure_runtime.init_queue(st)
        run = lambda s, n: esrp.run_chunk(s, ops, T, n, thresh_dev,
                                          rr_every, gated, b, push)
    elif strategy == "none":
        st = esrp.esrp_init(matvec, precond, b, dot=dot)  # T=max: no stores
        run = lambda s, n: esrp.run_chunk(s, ops, 1 << 30, n, thresh_dev,
                                          rr_every, gated, b)
    else:
        raise ValueError(strategy)

    pending = normalize_scenario(scenario, fail_at, failed_nodes,
                                 part.n_nodes)
    event_reports: list[EventReport] = []
    recovery_s = 0.0
    wasted = 0
    target = -1       # "no reconstruction point": restart or no event at all
    inner_rel = float("nan")
    # rr gating applies to the esrp/none runners only; imcr's chunk runner
    # has no replacement gate, so its resume must not add one either
    resume_rr = rr_every if strategy != "imcr" else 0

    t0 = time.perf_counter()
    total_iters = 0
    run_calls = 0
    resume_numeric_only = False
    converged = False
    # one chunk's norm record kept in flight: (device norms, start iteration).
    # Readback (the host sync) happens only after the *next* chunk has been
    # dispatched, so device compute and host bookkeeping overlap.
    inflight: Optional[tuple[jax.Array, int]] = None

    def settle(entry) -> bool:
        """Block on one chunk's norm record; True iff it converged. The
        chunk runner froze the state at first convergence, so on a hit the
        live ``st`` already is the state at iteration base + hit + 1 — no
        re-run needed, only the count is fixed up."""
        nonlocal total_iters, converged
        norms, base = entry
        hit = _find_convergence(np.asarray(norms), thresh)
        if hit >= 0:
            total_iters = base + hit + 1
            converged = True
        return converged

    while not converged:
        if resume_numeric_only:
            # post-recovery: re-run the reconstruction-point iteration without
            # its storage prelude (its push already happened pre-failure) but
            # WITH the rr_every replacement gate (see _resume_step). Jitted so
            # the jnp backend fuses exactly like inside run_chunk — keeps the
            # cross-backend trajectory bit-identity through recovery.
            pcg = _resume_step(st.pcg, ops, b, resume_rr, gated)
            st = st._replace(pcg=pcg)
            total_iters = int(pcg.j)
            resume_numeric_only = False
            if float(jnp.linalg.norm(pcg.r)) < thresh:
                converged = True
                break
            continue

        n = chunk
        if pending:
            n = min(n, pending[0].iter - total_iters)
        entry = None
        if n > 0:
            st, norms = run(st, n)               # async dispatch
            run_calls += 1
            entry = (norms, total_iters)
            total_iters += n

        if inflight is not None:
            prev, inflight = inflight, None
            if settle(prev):
                break                            # entry (if any) discarded:
                #                                  the state is frozen past
                #                                  convergence by construction
        at_fail = bool(pending) and total_iters == pending[0].iter
        if entry is not None:
            if at_fail or total_iters >= max_iters:
                if settle(entry):
                    break
            else:
                inflight = entry                 # overlap with next dispatch
        if total_iters >= max_iters:
            break

        if at_fail:
            ev = pending.pop(0)
            failed = list(ev.nodes)
            ev_inner = float("nan")
            ev_pff = -1
            ev_reload = 0
            ev_src: tuple[int, ...] = ()
            if strategy == "imcr":
                st, ev_wasted, target, rec_t = _imcr_failure(
                    st, part, failed, phi, matvec, precond, b,
                    dot=dot, fruntime=failure_runtime)
            elif strategy == "none":
                # no redundancy of any kind: nothing can rebuild the lost
                # entries — cleanly restart from scratch, counting the work
                st, ev_wasted, target, rec_t = _none_failure(
                    st, matvec, precond, b, dot=dot)
            else:
                (st, ev_wasted, target, ev_inner, rec_t, ev_pff, ev_reload,
                 ev_src) = _esrp_failure(
                    problem, plan, st, failed, T, ops, pff_precond,
                    fruntime=failure_runtime, push=push)
                inner_rel = ev_inner
            recovery_s += rec_t
            wasted += ev_wasted
            event_reports.append(EventReport(
                iter=ev.iter, nodes=ev.nodes, target_iter=target,
                wasted_iters=ev_wasted, recovery_s=rec_t,
                inner_rel=ev_inner, pff_iters=ev_pff,
                precond_reload_bytes=ev_reload, queue_src_nodes=ev_src))
            total_iters = int(st.pcg.j)
            resume_numeric_only = target >= 0
    runtime = time.perf_counter() - t0

    pcg = st.pcg
    jax.block_until_ready(pcg.x)
    drift = float(residual_drift(matvec, b, pcg.x, pcg.r))
    rel = float(jnp.linalg.norm(pcg.r)) / float(jnp.linalg.norm(b))
    nat_bytes = tot_bytes = 0
    if plan is not None:
        nat_bytes, tot_bytes = plan.bytes_per_aspmv(np.dtype(problem.b.dtype).itemsize)
    return SolveReport(
        strategy=strategy, T=T, phi=phi, converged_iter=total_iters,
        rel_residual=rel, runtime_s=runtime, recovery_s=recovery_s,
        wasted_iters=wasted, target_iter=target, inner_rel=inner_rel,
        drift=drift, aspmv_natural_bytes=nat_bytes,
        aspmv_total_bytes=tot_bytes, run_calls=run_calls,
        events=event_reports,
        precond_variant=getattr(ops, "variant", ""),
        converged=converged,
        precond_reload_bytes=sum(e.precond_reload_bytes
                                 for e in event_reports),
        x=pcg.x)


# --------------------------------------------------------------------------- #
def _none_failure(st: esrp.ESRPState, matvec, precond, b, dot=None):
    """strategy="none": no redundant copies, no checkpoints — every failure
    is a full restart with target_iter = -1 and J wasted iterations."""
    J = int(st.pcg.j)
    return esrp.esrp_init(matvec, precond, b, dot=dot), J, -1, 0.0


# --------------------------------------------------------------------------- #
def _esrp_failure(problem: Problem, plan: RedundancyPlan, st: esrp.ESRPState,
                  failed: list[int], T: int, solver_ops,
                  pff_precond: bool = True, fruntime=None, push=None):
    """Failure strikes during iteration J right after its (A)SpMV: run the
    iteration-J storage prelude (including, on the sharded runtime, the
    physical redundancy sends that were already in flight), lose the failed
    nodes' dynamic data, then reconstruct (Alg. 2) and rebuild a consistent
    post-stage ESRP state.

    With ``fruntime`` (comm.shard.ShardedFailureRuntime) the whole failure
    path is device-resident: injection is a shard_map zeroing of the failed
    devices' shards only, and the p^(j-1)/p^(j) copies feeding Alg. 2 are
    read out of the *surviving devices'* queue shards (``ESRPState.rq``),
    never from a replicated array. Without it (the single-device simulator)
    the queue is the host-visible (3, M) array and injection is the
    replicated ``jnp.where`` of the paper's simulation protocol.
    """
    part = problem.part
    matvec, precond = solver_ops.matvec, solver_ops.precond
    J = int(st.pcg.j)
    st = jax.jit(esrp.esrp_prelude, static_argnums=(1, 2, 3))(st, T, True,
                                                              push)

    # --- the failure: all dynamic data on failed nodes is lost -------------
    if fruntime is not None:
        st = fruntime.lose_esrp(st, failed)
        reload_desc, reload_bytes = fruntime.precond_reload(failed)
        del reload_desc
    else:
        mask = failed_row_mask(part, failed)
        lose = lambda v: zero_failed(v, mask)
        pcg = st.pcg._replace(x=lose(st.pcg.x), r=lose(st.pcg.r),
                              z=lose(st.pcg.z), p=lose(st.pcg.p))
        st = st._replace(pcg=pcg, x_s=lose(st.x_s), r_s=lose(st.r_s),
                         z_s=lose(st.z_s), p_s=lose(st.p_s))
        reload_bytes = 0
    pcg = st.pcg

    # per-event φ-copy survival analysis: a redundant copy of every failed
    # tile must outlive this event's failed set (topology-aware, so a lucky
    # |failed| > φ set can still pass — see RedundancyPlan.check_event)
    plan.check_event(failed)

    target, prev_slot, curr_slot = esrp.recovery_point(st, T)
    if target < 0:
        # before the first completed storage stage: restart from scratch
        st2 = esrp.esrp_init(matvec, precond, problem.b, dot=solver_ops.dot)
        if fruntime is not None:
            st2 = fruntime.init_queue(st2, reset=True)
        return st2, J, -1, float("nan"), 0.0, -1, reload_bytes, ()

    if T == 1:
        # ESR: no rollback — reconstruct the *live* iteration J from the
        # surviving r, x and the replicated scalar β^(J-1) (paper §2.3)
        r_surv, x_surv, z_surv, p_surv = pcg.r, pcg.x, pcg.z, pcg.p
        beta_prev = pcg.beta
        rz = pcg.rz          # replicated scalar — survives the failure
    else:
        r_surv, x_surv, z_surv, p_surv = st.r_s, st.x_s, st.z_s, st.p_s
        beta_prev = st.beta_s
        # r*ᵀz* was captured with the stars precisely so the rollback needs
        # no recompute from the (partly reconstructed) vectors: the stored
        # scalar is the exact value of the uncorrupted trajectory.
        rz = st.rz_s

    # the redundant p-copies Alg. 2 reads: on the sharded runtime the failed
    # rows are assembled from the surviving devices' physical queue shards
    # (the injection zeroed the failed rows of ``q`` itself); the simulator
    # reads the host-side queue directly
    if fruntime is not None:
        p_prev, p_curr, src_nodes = fruntime.assemble_pair(
            st, prev_slot, curr_slot, failed)
    else:
        p_prev, p_curr, src_nodes = st.q[prev_slot], st.q[curr_slot], ()

    # static-data reload (excluded from the recovery timing, paper §4) —
    # cached per (problem, failed-set) so repeated benchmark runs also reuse
    # the jitted inner solve (a C framework has no JIT warmup; timing it
    # would misattribute compilation to the paper's reconstruction cost)
    cache = getattr(problem, "_recon_cache", None)
    if cache is None:
        cache = {}
        problem._recon_cache = cache
    key = (tuple(failed), pff_precond)
    if key not in cache:
        ops = esr.ReconstructionOps.build(problem, failed,
                                          pff_precond=pff_precond)
        # warm the jitted reconstruction (compile excluded from timing)
        esr.reconstruct(ops, p_prev=p_prev, p_curr=p_curr,
                        beta_prev=beta_prev, r_surv=r_surv, x_surv=x_surv
                        )[0].block_until_ready()
        cache[key] = ops
    ops = cache[key]
    t0 = time.perf_counter()
    x_f, r_f, z_f, inner_rel = esr.reconstruct(
        ops, p_prev=p_prev, p_curr=p_curr,
        beta_prev=beta_prev, r_surv=r_surv, x_surv=x_surv)
    f_rows = jnp.asarray(ops.f_rows)
    x = x_surv.at[f_rows].set(x_f)
    r = r_surv.at[f_rows].set(r_f)
    z = z_surv.at[f_rows].set(z_f)
    p = p_surv.at[f_rows].set(p_curr[f_rows])
    jax.block_until_ready(x)
    rec_t = time.perf_counter() - t0

    new_pcg = PCGState(x=x, r=r, z=z, p=p, rz=rz, beta=beta_prev,
                       j=jnp.asarray(target, jnp.int32))
    empty = jnp.zeros_like(p)
    st2 = esrp.ESRPState(
        pcg=new_pcg,
        q=jnp.stack([empty, p_prev, p_curr]),
        q_tags=jnp.asarray([-1, target - 1, target], jnp.int32),
        x_s=x, r_s=r, z_s=z, p_s=p, beta_s=beta_prev, rz_s=rz,
        star_tag=jnp.asarray(target, jnp.int32))
    if fruntime is not None:
        # survivors keep their physical copies; the replacement's shard
        # stays empty (it was wiped) until the next storage push refreshes
        # every device's entry — tracked so a burst event cannot silently
        # read a stale copy
        st2 = st2._replace(rq=jnp.stack(
            [jnp.zeros_like(st.rq[0]), st.rq[prev_slot], st.rq[curr_slot]]))
        fruntime.mark_wiped(failed, target)
    pff_stats = getattr(ops.p_solve, "stats", None) if ops.p_solve else None
    pff_iters = pff_stats["iters"] if pff_stats else -1
    return (st2, J - target, target, float(inner_rel), rec_t, pff_iters,
            reload_bytes, src_nodes)


def _imcr_failure(st: imcr.IMCRState, part, failed: list[int], phi: int,
                  matvec, precond, b, dot=None, fruntime=None):
    """IMCR: zero the failed nodes' live data, then everyone rolls back to the
    last checkpoint (replacements fetch their parts from surviving buddies).

    The checkpoint state (``ck_*``, ``ck_tag``) is left untouched by
    recovery: it still holds the rolled-back-to iteration, so a *second*
    event striking before the next scheduled checkpoint finds a valid
    anchor and rolls back to the same tag again."""
    J = int(st.pcg.j)
    # per-event buddy-survival analysis (|failed| ≤ φ always passes; a
    # spread-out larger set may too — see imcr.check_survivable)
    imcr.check_survivable(failed, phi, part.n_nodes)
    if fruntime is not None:
        # sharded runtime: zero only the failed devices' shards (shard_map)
        st = st._replace(pcg=fruntime.lose_pcg(st.pcg, failed))
    else:
        mask = failed_row_mask(part, failed)
        lose = lambda v: zero_failed(v, mask)
        st = st._replace(pcg=st.pcg._replace(
            x=lose(st.pcg.x), r=lose(st.pcg.r), z=lose(st.pcg.z),
            p=lose(st.pcg.p)))
    tag = int(st.ck_tag)
    if tag < 0:                      # failure before the first checkpoint
        return imcr.imcr_init(matvec, precond, b, dot=dot), J, -1, 0.0
    t0 = time.perf_counter()
    pcg = imcr.recover(st)           # fetch-from-buddy (restore the copies)
    jax.block_until_ready(pcg.x)
    rec_t = time.perf_counter() - t0
    return st._replace(pcg=pcg), J - tag, tag, rec_t
