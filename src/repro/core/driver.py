"""Resilient-solve orchestration: run → (inject failures) → recover → converge.

Generalizes the paper's experimental protocol (§4-§5) from one node-failure
event per run to a *failure scenario*: a list of ``FailureEvent(iter, nodes)``
entries, each injected at a marked iteration (the driver lands exactly on
it). An event may strike several nodes simultaneously (the multi-node case of
Pachajoa et al., arXiv:1907.13077), and events may be staggered — failure →
recover → fail again, including a second event landing before the next
completed storage stage, which rolls back to the *same* reconstruction point
again (or restarts when none exists). Rollback rewinds the iteration counter
below already-consumed events without re-arming them; validation (strictly
increasing event iterations) keeps every pending event ahead of the rewound
counter, so each fires exactly once. Failed nodes zero out all their dynamic
data and then act as their own replacements. Reported quantities match the
paper's tables — total runtime, reconstruction overhead, wasted iterations,
converged iteration count, residual drift (Eq. 2) — plus a per-event
breakdown (``SolveReport.events``).

The hot loop runs through a ``SolverOps`` bundle (repro.core.ops): Block-ELL
SpMV fused with the pᵀq dot, fused vector update, cond-gated storage
bookkeeping. Convergence uses a sync-free chunked protocol: each chunk
carries ||r|| as a done flag and freezes the state at first convergence, so
the driver never re-runs a chunk to land on the convergence iteration, and
the norm-record readback of chunk i overlaps with the dispatch of chunk i+1
instead of blocking between chunks.
"""
from __future__ import annotations

import contextlib
import dataclasses
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import elastic as elastic_mod
from repro.core import esr, esrp, imcr, sdc
from repro.core.aspmv import RedundancyPlan, build_plan, shrink_plan
from repro.core.failures import (FailureEvent, SDCEvent, failed_row_mask,
                                 normalize_scenario, zero_failed)
from repro.core.ops import SolverOps, batch_ops, make_closure_ops
from repro.core.pcg import PCGState, _vec_norm, freeze_pcg, residual_drift
from repro.core.tiers import resolve_tier
from repro.obs.trace import Tracer, jsonable
from repro.sparse.matrices import Problem

# version stamp of the report JSON layout (EventReport/SolveReport.to_json);
# bump on any field rename/removal so downstream BENCH consumers can branch.
# v2: SolveReport gained batch_index/batch_size (batched solves emit one
# report per member).
# v3: SolveReport gained deadline_missed/retries (annotated by the serving
# front-end) and final_n_nodes became required for report validation.
REPORT_SCHEMA_VERSION = 3


def _tspan(tr: Optional[Tracer], name: str, cat: str = "solver", **args):
    """Span on ``tr``, or a no-op context (yielding None) when obs is off."""
    if tr is None:
        return contextlib.nullcontext()
    return tr.span(name, cat=cat, **args)


@dataclasses.dataclass
class EventReport:
    """Per-event recovery accounting (one entry per fired FailureEvent)."""
    iter: int                    # iteration J the event struck
    nodes: tuple[int, ...]
    target_iter: int             # reconstruction point (-1 = restart)
    wasted_iters: int            # rollback distance of this event
    recovery_s: float            # reconstruction ops only
    inner_rel: float             # Alg.2 line-8 inner solve (nan: imcr/none)
    pff_iters: int = -1          # Alg.2 line-6 inner-CG iterations (-1 when
    #                              the preconditioner has a closed form)
    precond_reload_bytes: int = 0   # static preconditioner state the
    #                              replacement reloads from safe storage
    #                              (sharded runtime; see
    #                              precond.local.static_reload_bytes)
    queue_src_nodes: tuple[int, ...] = ()   # devices whose *physical* queue
    #                              shards supplied the failed rows'
    #                              p-copies (sharded runtime; empty on the
    #                              host-side simulator)
    kind: str = "fail-stop"      # "fail-stop" | "sdc-inject" | "sdc-repair"
    detector: str = ""           # sdc-repair: which invariant fired
    detect_iter: int = -1        # sdc-repair: iteration the check fired at
    detect_latency: int = -1     # detect_iter − injection iteration (≤ one
    #                              invariant-check period by construction)
    sdc_target: str = ""         # sdc-inject: corrupted array ("p"/"r"/...)
    sdc_violation: float = float("nan")   # the relative violation measured
    sdc_tol: float = float("nan")         # the tolerance it was compared to
    tier: str = ""               # storage tier the recovery read from
    fetch_bytes: int = 0         # redundancy bytes the recovery fetched
    fetch_s_model: float = 0.0   # tier cost model applied to fetch_bytes
    elastic_n_nodes: int = 0     # >0: node count the run continued on after
    #                              this event (elastic shrunk-mesh recovery)

    def to_json(self) -> dict:
        """JSON-safe dict (NaN/inf -> None, device scalars -> Python) with a
        ``schema_version`` stamp — the serialization the BENCH writers and
        the JSONL event log embed."""
        out = {f.name: jsonable(getattr(self, f.name))
               for f in dataclasses.fields(self)}
        out["schema_version"] = REPORT_SCHEMA_VERSION
        return out


@dataclasses.dataclass
class SolveReport:
    strategy: str
    T: int
    phi: int
    converged_iter: int
    rel_residual: float
    runtime_s: float
    recovery_s: float            # reconstruction ops only, summed over events
    wasted_iters: int            # rollback distance, summed over events
    target_iter: int             # last event's reconstruction point; -1 when
    #                              no reconstruction happened (restart, or no
    #                              failure event at all — see ``events``)
    inner_rel: float             # last event's Alg.2 line-8 inner-solve residual
    drift: float                 # paper Eq. (2)
    aspmv_natural_bytes: int = 0
    aspmv_total_bytes: int = 0
    run_calls: int = 0           # chunk dispatches (no final-chunk re-run)
    events: list[EventReport] = dataclasses.field(default_factory=list)
    precond_variant: str = ""    # e.g. "node-local ssor" on the sharded
    #                              runtime (SolverOps.variant)
    local_delta_iters: Optional[int] = None   # iteration-count delta of a
    #                              node-local run vs the global-sweep
    #                              reference (shard.attach_local_delta)
    converged: bool = True       # False: the run stopped at max_iters with
    #                              ||r|| still above threshold
    precond_reload_bytes: int = 0   # summed over events (sharded runtime)
    tier: str = ""               # redundancy storage tier (core.tiers)
    push_count: int = 0          # storage pushes executed over the run
    #                              (incl. re-pushes on rolled-back stretches)
    push_bytes: int = 0          # total bytes those pushes moved into the
    #                              tier (per-push volume × push_count)
    push_s_model: float = 0.0    # tier cost model over all pushes
    fetch_s_model: float = 0.0   # summed over events' recovery fetches
    sdc_checks: int = 0          # invariant checks evaluated
    sdc_check_every: int = 0     # the cadence they ran at (0 = SDC off)
    final_n_nodes: int = 0       # node count at convergence (shrinks under
    #                              elastic recovery)
    batch_index: int = 0         # this member's row in the batched solve
    batch_size: int = 1          # members the dispatch advanced together
    #                              (1 = plain unbatched solve)
    deadline_missed: bool = False   # serving front-end: the request's
    #                              per-request deadline expired before this
    #                              solve completed (the result may still be
    #                              numerically valid — see solver_service)
    retries: int = 0             # serving front-end: dispatch attempts the
    #                              micro-batch burned on unsurvivable events
    #                              before this solve succeeded
    x: Optional[object] = dataclasses.field(default=None, repr=False)
    #                              final iterate (device array) — lets parity
    #                              tests assert bit-identical rejoin; rel/
    #                              drift above are host-side norms whose flat
    #                              reduction may differ from the mesh's by
    #                              1 ulp even on identical vectors
    trace: Optional[object] = dataclasses.field(default=None, repr=False)
    #                              the obs.Tracer of this solve (obs=on only):
    #                              spans, counters, per-iteration history —
    #                              export via repro.obs.export

    def to_json(self) -> dict:
        """JSON-safe dict with a ``schema_version`` stamp. The device-array
        ``x`` and the live ``trace`` handle are dropped (neither serializes
        usefully; the tracer has its own exporters); NaN/inf coerce to None
        so ``json.dumps(..., allow_nan=False)`` always succeeds."""
        skip = {"x", "trace", "events"}
        out = {f.name: jsonable(getattr(self, f.name))
               for f in dataclasses.fields(self) if f.name not in skip}
        out["events"] = [e.to_json() for e in self.events]
        out["schema_version"] = REPORT_SCHEMA_VERSION
        return out


def _find_convergence(norms: np.ndarray, thresh: float) -> int:
    """Index of first iteration with ||r|| < thresh, or -1."""
    below = np.nonzero(norms < thresh)[0]
    return int(below[0]) if below.size else -1


# module-level so the trace cache survives across solves (a fresh jit wrapper
# per resume would recompile the same iteration every failure run).
# esrp.numeric_step (not bare pcg_iterate_ops): the resume iteration must run
# the same rr_every residual-replacement gate as the chunk runner, or a
# replacement landing on the reconstruction point would be silently skipped
# and the post-recovery trajectory would fork off the failure-free one.
_resume_step = jax.jit(esrp.numeric_step, static_argnums=(1, 3, 4))


def solve_resilient(
    problem: Problem,
    strategy: str = "esrp",            # "esrp" | "imcr" | "none"
    T: int = 20,
    phi: int = 1,
    rtol: float = 1e-8,
    max_iters: int = 100_000,
    fail_at: Optional[int] = None,     # legacy one-event shorthand
    failed_nodes: Optional[list[int]] = None,
    scenario: Optional[list[FailureEvent]] = None,   # multi-event scenario
    matvec: Optional[Callable] = None,
    chunk: int = 64,
    rr_every: int = 0,                 # residual replacement period (0 = off)
    backend: str = "auto",             # SolverOps backend for the hot loop
    ops: Optional[SolverOps] = None,   # explicit bundle (overrides backend)
    gated: bool = True,                # cond-gated storage/rr bookkeeping
    pff_precond: bool = True,          # precondition the Alg.2 line-6 inner
    #                                    CG (False = historical plain CG)
    failure_runtime=None,              # comm.shard.ShardedFailureRuntime:
    #                                    device-resident redundancy queue,
    #                                    shard_map injection, and recovery
    #                                    reads from surviving devices' shards
    sdc_policy: Optional[sdc.SDCPolicy] = None,   # enable the invariant
    #                                    checks (auto-enabled with defaults
    #                                    when the scenario holds an SDCEvent)
    sdc_on_device: bool = True,        # fold the invariant recomputation
    #                                    into the chunk tail on-device
    #                                    (esrp.run_chunk's halt guard):
    #                                    chunks no longer clamp to check
    #                                    boundaries and detection latency
    #                                    stays bounded by check_every even
    #                                    for long chunks. False restores the
    #                                    host-side between-chunk checks
    #                                    (every boundary forces a readback)
    storage_tier="device-neighbour",   # core.tiers name or StorageTier: the
    #                                    redundancy-queue placement cost model
    elastic: bool = False,             # no replacement nodes: after each
    #                                    fail-stop event, re-partition onto
    #                                    the shrunk node count and continue
    batch_fused: bool = False,         # batched throughput mode: fused-
    #                                    batched einsum ops (one op per
    #                                    iteration for all B members) in
    #                                    place of the per-member-unrolled
    #                                    exact bundle. Per-member results
    #                                    deviate from the B=1 run at ~ulp
    #                                    (convergence unaffected); the
    #                                    serving path opts in for >B x
    #                                    dispatch amortization
    rhs=None,                          # right-hand side override. A (B, M)
    #                                    array arms the BATCHED solve: all B
    #                                    systems (same A/P, different b)
    #                                    advance per dispatch with
    #                                    per-member convergence freeze, one
    #                                    FailureEvent strikes every member,
    #                                    one Alg. 2 pass rebuilds them all,
    #                                    and the return is a list of B
    #                                    per-member SolveReports. A (M,)
    #                                    array just replaces problem.b.
    obs=None,                          # observability: an obs.Tracer to
    #                                    record into, or True for a fresh
    #                                    one (returned as report.trace).
    #                                    Default off: the obs=off hot path
    #                                    is bit-identical and compiles to
    #                                    the identical jaxpr (tested)
) -> "SolveReport | list[SolveReport]":
    part = problem.part
    pending = normalize_scenario(scenario, fail_at, failed_nodes,
                                 part.n_nodes)
    rhs_arr = None if rhs is None else jnp.asarray(rhs, problem.b.dtype)
    batched = rhs_arr is not None and rhs_arr.ndim == 2
    nbatch = int(rhs_arr.shape[0]) if batched else 0
    if rhs_arr is not None and rhs_arr.shape[-1] != part.m:
        raise ValueError(
            f"rhs row length {rhs_arr.shape[-1]} != problem size {part.m}")
    if failure_runtime is not None \
            and getattr(failure_runtime, "batch", 0) != nbatch:
        rt_batch = getattr(failure_runtime, "batch", 0)
        if nbatch:
            hint = (f"construct ShardedFailureRuntime(problem, mesh, "
                    f"batch={nbatch}) to match the (B, M) rhs")
        else:
            hint = ("this solve is unbatched — construct "
                    "ShardedFailureRuntime(problem, mesh) and leave the "
                    "batch parameter at its default 0")
        raise ValueError(
            f"failure_runtime was built for batch={rt_batch} but this "
            f"solve has batch={nbatch} — {hint}")
    sdc_events = [e for e in pending if isinstance(e, SDCEvent)]
    if sdc_events or sdc_policy is not None:
        if strategy not in ("esrp", "none"):
            raise ValueError(
                f"SDC detection/repair supports the esrp and none strategies "
                f"(got {strategy!r}): imcr's checkpoint protocol has no "
                f"per-iteration invariants to verify against")
        if strategy == "esrp" and T == 1:
            raise ValueError(
                "SDC with T=1 (ESR) is unsupported: ESR stores every "
                "iteration, so corrupted state would be committed to the "
                "redundancy queue before any check cadence could catch it — "
                "use T >= 2")
        if strategy == "none" and any(e.target == "queue"
                                      for e in sdc_events):
            raise ValueError(
                'strategy="none" keeps no redundancy queue — there is no '
                '"queue" shard to corrupt')
        if sdc_policy is None:
            sdc_policy = sdc.SDCPolicy()
    sdc_on = sdc_policy is not None
    # on-device guard mode: the chunk runner verifies the invariants at
    # every check boundary inside the scan and halts on a violation; the
    # host only confirms + localizes at the halted state (sdc.run_checks)
    sdc_guard = sdc_on and sdc_on_device
    # per-push queue checksums: written at push time, compared at check and
    # read time (only meaningful when something both stores and checks)
    qsum_slabs = part.n_nodes if (sdc_on and strategy == "esrp") else 0
    if elastic:
        if strategy != "esrp":
            raise ValueError(
                f"elastic shrunk-mesh recovery needs the esrp strategy (got "
                f"{strategy!r}): Alg. 2 reconstruction provides the complete "
                f"state the shrunk partition continues from")
        if failure_runtime is not None or ops is not None \
                or matvec is not None:
            raise ValueError(
                "elastic recovery re-partitions the problem and rebuilds its "
                "solver ops — it requires the default problem-built ops (no "
                "custom ops/matvec) and no sharded failure runtime")
    tier = resolve_tier(storage_tier)
    itemsize = np.dtype(problem.b.dtype).itemsize
    if ops is None:
        if matvec is not None:
            # cache the closure bundle on the problem so repeated solves with
            # the same matvec reuse the jitted chunk runners (the bundle is
            # their static argument), without pinning the problem in a
            # module-global cache
            cache = getattr(problem, "_closure_ops_cache", None)
            if cache is None:
                cache = {}
                problem._closure_ops_cache = cache
            key = (matvec, problem.apply_precond, nbatch)
            if key not in cache:
                base = make_closure_ops(matvec, problem.apply_precond)
                cache[key] = batch_ops(base, nbatch) if batched else base
            ops = cache[key]
        else:
            ops = problem.solver_ops(backend, batch=nbatch,
                                     fused=batched and batch_fused)
    matvec = ops.matvec
    precond = ops.precond
    b = rhs_arr if rhs_arr is not None else problem.b
    bnorm = float(jnp.linalg.norm(b))
    if batched:
        # per-member threshold. Zero-RHS members (micro-batch padding) get
        # +inf: their rnorm == 0 row freezes at iteration 0 instead of
        # dividing by zero, and their report carries rel = 0 / converged
        bnorm_v = _vec_norm(b)
        thresh_dev = jnp.where(bnorm_v > 0, rtol * bnorm_v,
                               jnp.inf).astype(b.dtype)
        thresh = np.asarray(thresh_dev)        # (B,) host copy, same values
        conv_iter = np.full(nbatch, -1, np.int64)   # per-member first
        #                                  crossing (set-once, absolute count)
    else:
        thresh_dev = jnp.asarray(rtol * bnorm, b.dtype)
        # host-side scans must compare against the *same* value the chunk
        # runner's freeze uses, or (in f32) a norm between the two would
        # freeze the device state without the host ever declaring convergence
        thresh = float(thresh_dev)

    tr: Optional[Tracer] = obs if isinstance(obs, Tracer) else (
        Tracer("solve_resilient") if obs else None)
    mtr = tr is not None              # static: arms the chunk metrics ring

    plan: Optional[RedundancyPlan] = None
    push = None
    if strategy == "esrp":
        plan = build_plan(problem.a, part, phi)   # static, verified φ+1 copies
        if failure_runtime is not None:
            # device-resident redundancy: the storage pushes physically
            # place each node's p-tiles on the designated holder devices
            failure_runtime.bind_plan(plan)
            push = failure_runtime.queue_push
    dot = getattr(ops, "dot", None)

    if strategy == "imcr":
        st = imcr.imcr_init(matvec, precond, b, dot=dot)
        run = lambda s, n: imcr.run_chunk(s, ops, T, phi,
                                          part.rows_per_node, n,
                                          thresh_dev, gated, mtr)
    elif strategy == "esrp":
        st = esrp.esrp_init(matvec, precond, b, dot=dot, n_slabs=qsum_slabs)
        if failure_runtime is not None:
            st = failure_runtime.init_queue(st)
        run_chk = lambda s, n, chk: esrp.run_chunk(
            s, ops, T, n, thresh_dev, rr_every, gated, b, push, mtr, chk)
        run = lambda s, n: run_chk(s, n, sdc_policy if sdc_guard else None)
    elif strategy == "none":
        st = esrp.esrp_init(matvec, precond, b, dot=dot)  # T=max: no stores
        run_chk = lambda s, n, chk: esrp.run_chunk(
            s, ops, 1 << 30, n, thresh_dev, rr_every, gated, b, None, mtr,
            chk)
        run = lambda s, n: run_chk(s, n, sdc_policy if sdc_guard else None)
    else:
        raise ValueError(strategy)

    event_reports: list[EventReport] = []
    recovery_s = 0.0
    wasted = 0
    target = -1       # "no reconstruction point": restart or no event at all
    inner_rel = float("nan")
    # rr gating applies to the esrp/none runners only; imcr's chunk runner
    # has no replacement gate, so its resume must not add one either
    resume_rr = rr_every if strategy != "imcr" else 0

    # per-push tier volume: needed live (the settle-time byte counters), not
    # just in the end-of-run accounting; rebound on elastic re-partition
    per_push = (tier.push_bytes(plan, part.m, itemsize)
                if strategy == "esrp" and plan is not None else 0)
    solve_sp = None
    if tr is not None:
        # roofline attribution of the dispatched kernels, priced once per
        # (backend, variant, shape) at build time and attached to the trace
        # metadata — the analyzer runs over lowered HLO, no execution
        tr.meta.setdefault("rooflines", {}).update(
            _solver_rooflines_cached(problem, ops, b, backend))
        nat0, tot0 = plan.bytes_per_aspmv(itemsize) if plan is not None \
            else (0, 0)
        solve_sp = tr.begin(
            "solve", cat="solver", strategy=strategy, T=T, phi=phi,
            backend=backend, variant=getattr(ops, "variant", ""),
            tier=tier.name, m=part.m, n_nodes=part.n_nodes, rtol=rtol,
            aspmv_natural_bytes=nat0, aspmv_total_bytes=tot0,
            per_push_bytes=per_push)

    t0 = time.perf_counter()
    total_iters = 0
    run_calls = 0
    resume_numeric_only = False
    converged = False
    sdc_checks = 0
    sdc_repairs = 0
    # injections whose corruption no repair has cleared yet, for the
    # detection-latency attribution: (injection iter, target)
    sdc_wait: list[tuple[int, str]] = []
    # iteration stretches actually executed (rollback re-executes, so pushes
    # re-happen) — the tier push accounting replays the storage schedule
    # over them after the run
    push_ranges: list[tuple[int, int]] = []
    # one chunk's norm record kept in flight: (device record, start
    # iteration, dispatched length, guard armed?). Readback (the host sync)
    # happens only after the *next* chunk has been dispatched, so device
    # compute and host bookkeeping overlap.
    inflight: Optional[tuple] = None
    # iteration count the on-device SDC guard halted at (-1 = no halt
    # pending); set by settle(), consumed by the main loop's check handler
    halt_iter = -1
    # armed when the device guard halted but the host check found nothing
    # (threshold-edge disagreement): the next dispatch steps one iteration
    # guard-free so the run cannot spin on the same boundary
    guard_skip = False

    def settle(entry) -> bool:
        """Block on one chunk's norm record; True iff it converged. The
        chunk runner froze the state at first convergence, so on a hit the
        live ``st`` already is the state at iteration base + hit + 1 — no
        re-run needed, only the count is fixed up.

        With obs on the record also carries the chunk's metrics-ring rows
        (same readback, zero extra dispatches): rows past the executed
        count repeated the frozen carry and are trimmed before they land in
        the tracer's iteration history.

        With the on-device SDC guard armed the record also carries the
        per-iteration halted flags: halted[i] = True means iteration
        base + i did NOT execute — the chunk froze at check boundary
        base + i with a violated invariant, and the live ``st`` is exactly
        the state entering it. The first halt index lands in ``halt_iter``
        (set-once: a chunk dispatched from an already-halted state re-halts
        at its own iteration 0 and must not overwrite the real boundary)."""
        nonlocal total_iters, converged, halt_iter
        record, base, n_disp, guarded = entry
        halt_d = None
        if guarded:
            (norms_d, aux_d, halt_d) = record if mtr else \
                (record[0], None, record[1])
        else:
            norms_d, aux_d = record if mtr else (record, None)
        with _tspan(tr, "chunk_settle", base=base, n=n_disp):
            norms = np.asarray(norms_d)
            h_rel = -1
            if halt_d is not None:
                hidx = np.nonzero(np.asarray(halt_d))[0]
                h_rel = int(hidx[0]) if hidx.size else -1
            if batched:
                # norms is (n_disp, B): the chunk is done when EVERY member
                # is below its own threshold; individual crossings are
                # recorded set-once (the device froze that member, so its
                # later rows just repeat the frozen norm)
                below = norms < thresh[None, :]
                allb = np.nonzero(below.all(axis=1))[0]
                hit = int(allb[0]) if allb.size else -1
                for k in range(nbatch):
                    if conv_iter[k] < 0:
                        idx = np.nonzero(below[:, k])[0]
                        if idx.size:
                            conv_iter[k] = base + int(idx[0]) + 1
            else:
                hit = _find_convergence(norms, thresh)
            if h_rel >= 0:
                # the guard skips once every member converged, so a halt
                # precludes an earlier full-convergence hit; rows from the
                # halt on are passthrough
                hit = -1
                if halt_iter < 0:
                    halt_iter = base + h_rel
            # iterations past a convergence hit ran frozen — no pushes
            executed = (h_rel if h_rel >= 0
                        else hit + 1 if hit >= 0 else n_disp)
            push_ranges.append((base, base + executed))
            if hit >= 0:
                total_iters = base + hit + 1
                converged = True
            if tr is not None and executed > 0:
                aux = np.asarray(aux_d)[:executed]
                if batched:
                    # per-member rows collapse to the tracks the exporters
                    # render: the max-norm (the convergence gate), the
                    # shared storage flags (identical across members), and
                    # the worst-member rz / orthogonality residual
                    tr.record_iters(np.arange(base, base + executed),
                                    rnorm=norms[:executed].max(axis=1),
                                    rz=aux[:, 0].max(axis=1),
                                    push=aux[:, 1, 0], star=aux[:, 2, 0],
                                    orth=aux[:, 3].max(axis=1))
                    n_push = int(round(float(aux[:, 1, 0].sum())))
                else:
                    tr.record_iters(np.arange(base, base + executed),
                                    rnorm=norms[:executed], rz=aux[:, 0],
                                    push=aux[:, 1], star=aux[:, 2],
                                    orth=aux[:, 3])
                    n_push = int(round(float(aux[:, 1].sum())))
                if n_push and per_push:
                    tr.add_counter("tier_push_bytes", n_push * per_push,
                                   pushes=n_push, tier=tier.name)
        return converged

    try:
      while not converged:
        if resume_numeric_only:
            # post-recovery: re-run the reconstruction-point iteration without
            # its storage prelude (its push already happened pre-failure) but
            # WITH the rr_every replacement gate (see _resume_step). Jitted so
            # the jnp backend fuses exactly like inside run_chunk — keeps the
            # cross-backend trajectory bit-identity through recovery.
            with _tspan(tr, "resume_step", iter=total_iters):
                pcg_old = st.pcg
                pcg = _resume_step(pcg_old, ops, b, resume_rr, gated)
                if batched:
                    # members that were already converged (shielded from the
                    # event by the post-recovery member select) must not be
                    # stepped past their frozen state
                    done = _vec_norm(pcg_old.r) < thresh_dev
                    pcg = freeze_pcg(pcg_old, pcg, done)
                st = st._replace(pcg=pcg)
                total_iters = int(pcg.j)
                resume_numeric_only = False
                if batched:
                    rnorm_v = np.asarray(_vec_norm(pcg.r))
                    for k in range(nbatch):
                        if conv_iter[k] < 0 and rnorm_v[k] < thresh[k]:
                            conv_iter[k] = total_iters
                    rnorm = float(rnorm_v.max())
                    if tr is not None:
                        tr.record_iters([total_iters - 1], rnorm=[rnorm])
                    if bool((rnorm_v < thresh).all()):
                        converged = True
                        break
                    continue
                rnorm = float(jnp.linalg.norm(pcg.r))
                if tr is not None:
                    # the re-run iteration's metrics row (the chunk ring
                    # never sees it); its push/star already happened on the
                    # pre-failure pass — dedup keeps this later row
                    tr.record_iters(
                        [total_iters - 1], rnorm=[rnorm],
                        rz=[float(pcg.rz)], push=[0.0], star=[0.0],
                        orth=[float(jnp.abs(pcg.r @ pcg.p - pcg.rz))])
            if rnorm < thresh:
                converged = True
                break
            continue

        n = chunk
        if pending:
            n = min(n, pending[0].iter - total_iters)
        if sdc_on and not sdc_guard:
            # host-side checks: land exactly on every invariant-check
            # boundary — the cadence, plus (ESRP) every storage iteration —
            # state must be verified clean BEFORE it is committed to the
            # queue/stars, or a later rollback would faithfully restore
            # corrupted copies. (The on-device guard verifies the same
            # boundaries inside the scan — before each boundary iteration's
            # prelude — so guard mode dispatches full chunks.)
            n = min(n, _next_sdc_boundary(
                total_iters, sdc_policy.check_every, T,
                strategy == "esrp") - total_iters)
        entry = None
        if guard_skip and n > 0:
            # device/host disagreement escape hatch: the guard halted but
            # the authoritative host check found nothing — step exactly one
            # iteration guard-free to move past the boundary
            with _tspan(tr, "chunk_dispatch", base=total_iters, n=1,
                        guard_skip=True):
                st, record = run_chk(st, 1, None)
            run_calls += 1
            entry = (record, total_iters, 1, False)
            total_iters += 1
            guard_skip = False
        elif n > 0:
            with _tspan(tr, "chunk_dispatch", base=total_iters, n=n):
                st, record = run(st, n)          # async dispatch
            run_calls += 1
            entry = (record, total_iters, n, sdc_guard)
            total_iters += n

        if inflight is not None:
            prev, inflight = inflight, None
            if settle(prev):
                break                            # entry (if any) discarded:
                #                                  the state is frozen past
                #                                  convergence by construction
        if halt_iter >= 0 and entry is not None:
            # the previous chunk halted at a check boundary, so this chunk
            # was dispatched from the frozen halted state: its guard
            # re-fired on entry and zero iterations executed — settle and
            # discard it (set-once halt_iter keeps the real boundary)
            settle(entry)
            entry = None
        at_fail = (halt_iter < 0 and bool(pending)
                   and total_iters == pending[0].iter)
        at_check = (halt_iter < 0 and sdc_on and not sdc_guard and not at_fail
                    and total_iters > 0
                    and _at_sdc_boundary(total_iters, sdc_policy.check_every,
                                         T, strategy == "esrp"))
        if entry is not None:
            if at_fail or at_check or total_iters >= max_iters:
                if settle(entry):
                    break
            else:
                inflight = entry                 # overlap with next dispatch
        from_halt = halt_iter >= 0
        if from_halt:
            # roll the count back to the halted boundary (== st.pcg.j); the
            # authoritative host check below localizes and repairs there.
            # A pending fail event is never at the halt (chunks clamp to
            # event iterations, and the halt lands strictly inside a chunk)
            total_iters = halt_iter
            halt_iter = -1
            at_fail = False
            at_check = True
        if total_iters >= max_iters:
            break

        if at_fail:
            ev = pending.pop(0)
            if any(nd >= part.n_nodes for nd in ev.nodes):
                raise ValueError(
                    f"event at iter {ev.iter} names node(s) {ev.nodes} "
                    f"outside the current {part.n_nodes}-node partition "
                    f"(elastic recovery shrank the mesh)")
            if isinstance(ev, SDCEvent):
                # silent corruption: iteration ev.iter executes with the
                # corruption struck mid-iteration; nothing stops, nothing is
                # reported to the solver — only an invariant check can catch
                # it downstream
                with _tspan(tr, "event:sdc-inject", cat="event",
                            iter=ev.iter, nodes=list(ev.nodes),
                            target=ev.target):
                    # already-converged members are shielded: their B=1
                    # reference runs ended before the corruption struck, so
                    # neither the injected flip nor the injection
                    # iteration's step may disturb their frozen state
                    st_pre = st if batched else None
                    done_pre = (_vec_norm(st.pcg.r) < thresh_dev) \
                        if batched else None
                    st = _inject_sdc(problem, st, ev,
                                     T if strategy == "esrp" else (1 << 30),
                                     ops, b, resume_rr, gated, push)
                    if batched:
                        st = esrp.member_select(st_pre, st, done_pre)
                total_iters = int(st.pcg.j)
                push_ranges.append((ev.iter, ev.iter + 1))
                sdc_wait.append((ev.iter, ev.target))
                event_reports.append(EventReport(
                    iter=ev.iter, nodes=ev.nodes, target_iter=total_iters,
                    wasted_iters=0, recovery_s=0.0, inner_rel=float("nan"),
                    kind="sdc-inject", sdc_target=ev.target, tier=tier.name))
                # the landing count may itself be a check boundary (e.g. the
                # event struck a first-push iteration, so the very next
                # iteration star-captures and pushes again): run the check
                # NOW, before any dispatch commits the corrupted state to
                # storage — otherwise a later rollback would faithfully
                # restore the corruption
                at_check = _at_sdc_boundary(total_iters,
                                            sdc_policy.check_every, T,
                                            strategy == "esrp")
            else:
                failed = list(ev.nodes)
                ev_inner = float("nan")
                ev_pff = -1
                ev_reload = 0
                ev_src: tuple[int, ...] = ()
                ev_fetch = 0
                ev_fetch_s = 0.0
                # already-converged members are shielded from the event:
                # their B=1 reference run would have ended before it fired,
                # so injection + rollback must not disturb their frozen
                # state — the per-member select below restores it
                st_pre = st if batched else None
                done_pre = (_vec_norm(st.pcg.r) < thresh_dev) if batched \
                    else None
                with _tspan(tr, "event:fail-stop", cat="event",
                            iter=ev.iter, nodes=list(ev.nodes),
                            strategy=strategy) as ev_sp:
                    if strategy == "imcr":
                        st, ev_wasted, target, rec_t = _imcr_failure(
                            st, part, failed, phi, matvec, precond, b,
                            dot=dot, fruntime=failure_runtime, tracer=tr)
                    elif strategy == "none":
                        # no redundancy of any kind: nothing can rebuild the
                        # lost entries — cleanly restart from scratch,
                        # counting the work
                        st, ev_wasted, target, rec_t = _none_failure(
                            st, matvec, precond, b, dot=dot)
                    else:
                        (st, ev_wasted, target, ev_inner, rec_t, ev_pff,
                         ev_reload, ev_src) = _esrp_failure(
                            problem, plan, st, failed, T, ops, pff_precond,
                            fruntime=failure_runtime, push=push,
                            n_slabs=qsum_slabs, b=b, tracer=tr)
                        inner_rel = ev_inner
                        push_ranges.append((ev.iter, ev.iter + 1))  # prelude push
                        if target >= 0:
                            ev_fetch = tier.fetch_bytes(
                                max(1, nbatch) * len(failed) *
                                part.rows_per_node, itemsize)
                            ev_fetch_s = tier.read_s(ev_fetch)
                    if batched:
                        msel = (imcr.member_select if strategy == "imcr"
                                else esrp.member_select)
                        st = msel(st_pre, st, done_pre)
                    recovery_s += rec_t
                    wasted += ev_wasted
                    er = EventReport(
                        iter=ev.iter, nodes=ev.nodes, target_iter=target,
                        wasted_iters=ev_wasted, recovery_s=rec_t,
                        inner_rel=ev_inner, pff_iters=ev_pff,
                        precond_reload_bytes=ev_reload, queue_src_nodes=ev_src,
                        tier=tier.name, fetch_bytes=ev_fetch,
                        fetch_s_model=ev_fetch_s)
                    if elastic:
                        # no replacement node exists: re-partition the problem
                        # onto the surviving count and rebuild everything
                        # layout-bound (ops, plan, thresholds); the recovered
                        # state extends with exactly-consistent zero padding
                        # rows (core.elastic)
                        n_new = part.n_nodes - len(ev.nodes)
                        with _tspan(tr, "elastic_repartition", cat="recovery",
                                    n_nodes=n_new):
                            problem = elastic_mod.shrink_problem(problem, n_new)
                            part = problem.part
                            st = elastic_mod.remap_state(st, part.m,
                                                         part.n_nodes)
                            ops = problem.solver_ops(
                                backend, batch=nbatch,
                                fused=batched and batch_fused)
                            matvec, precond = ops.matvec, ops.precond
                            dot = getattr(ops, "dot", None)
                            # the solved RHS (incl. any rhs= override and the
                            # batched (B, M) rows) extends with the same
                            # decoupled-identity zero padding as the state —
                            # NOT problem.b, which would drop the override
                            b = elastic_mod._extend(b, part.m)
                            bnorm = float(jnp.linalg.norm(b))
                            if batched:
                                bnorm_v = _vec_norm(b)
                                thresh_dev = jnp.where(
                                    bnorm_v > 0, rtol * bnorm_v,
                                    jnp.inf).astype(b.dtype)
                                thresh = np.asarray(thresh_dev)
                            else:
                                thresh_dev = jnp.asarray(rtol * bnorm,
                                                         b.dtype)
                                thresh = float(thresh_dev)
                            plan = shrink_plan(plan, problem.a, part)
                            per_push = tier.push_bytes(plan, part.m, itemsize)
                            if qsum_slabs:
                                qsum_slabs = part.n_nodes
                            er.elastic_n_nodes = n_new
                        # the run/resume closures read ops/b/thresh_dev
                        # late-bound — rebinding the locals above re-targets
                        # them to the shrunk layout
                    if tr is not None:
                        if ev_fetch:
                            tr.add_counter("tier_fetch_bytes", ev_fetch,
                                           tier=tier.name)
                        ev_sp.args.update(
                            target_iter=target, wasted_iters=ev_wasted,
                            recovery_s=rec_t, fetch_bytes=ev_fetch)
                    event_reports.append(er)
                    total_iters = int(st.pcg.j)
                    resume_numeric_only = target >= 0

        if at_check:
            sdc_checks += 1
            with _tspan(tr, "sdc_check", cat="sdc",
                        iter=total_iters, from_halt=from_halt) as ck_sp:
                # converged members are excluded from detection: their B=1
                # reference runs already ended, so nothing about them may
                # fire a repair (zero-RHS padding is excluded inside
                # run_checks itself)
                live = (~(np.asarray(_vec_norm(st.pcg.r)) < thresh)
                        if batched else None)
                det = sdc.run_checks(ops, st, b, part, bnorm, sdc_policy,
                                     live=live)
                if ck_sp is not None:
                    ck_sp.args["fired"] = det is not None
            if det is None and from_halt:
                guard_skip = True
            if det is not None:
                sdc_repairs += 1
                if sdc_repairs > sdc_policy.max_repairs:
                    raise RuntimeError(
                        f"SDC repair fired {sdc_repairs} times without "
                        f"clearing the invariant violation "
                        f"({det.detector}: {det.violation:.3e} > "
                        f"{det.tol:.3e}) — corruption outside the "
                        f"recoverable state, or tolerances below the "
                        f"solver's noise floor")
                # detection-latency attribution: the oldest injection this
                # detector class can see (queue checksums see only queue
                # corruption; the state invariants see everything else)
                want_q = det.detector == "queue-checksum"
                attr = [i for i, tg in sdc_wait if (tg == "queue") == want_q]
                sdc_wait = [(i, tg) for i, tg in sdc_wait
                            if (tg == "queue") != want_q]
                latency = total_iters - attr[0] if attr else -1
                J = int(st.pcg.j)
                if tr is not None:
                    tr.instant("sdc_detect", cat="sdc",
                               detector=det.detector, iter=J,
                               latency=latency,
                               violation=float(det.violation),
                               tol=float(det.tol))
                ev_inner = float("nan")
                ev_pff = -1
                rec_t = 0.0
                ev_wasted = 0
                ev_src = ()
                ev_fetch = 0
                ev_fetch_s = 0.0
                with _tspan(tr, "event:sdc-repair", cat="event", iter=J,
                            detector=det.detector,
                            nodes=list(det.flagged)) as rp_sp:
                    # converged members are shielded from the rollback
                    # (their reference runs ended before this repair);
                    # queue invalidation is shared bookkeeping (slot axis)
                    # and needs no per-member select
                    st_pre = st if batched else None
                    done_pre = (_vec_norm(st.pcg.r) < thresh_dev) \
                        if batched else None
                    if want_q:
                        # the corrupted copies ARE the redundancy — nothing
                        # can rebuild them; invalidate their slot so no
                        # recovery ever reads them (the next push refreshes
                        # the queue). The live trajectory is untouched:
                        # queue corruption never feeds forward.
                        st = _invalidate_queue_slots(st, det)
                        target = J
                    elif strategy == "none":
                        st, ev_wasted, target, rec_t = _none_failure(
                            st, matvec, precond, b, dot=dot)
                    elif len(det.flagged) >= part.n_nodes:
                        # catastrophic (all slabs non-finite): no survivors
                        # to reconstruct from — restart clean
                        st = esrp.esrp_init(matvec, precond, b, dot=dot,
                                            n_slabs=qsum_slabs)
                        if failure_runtime is not None:
                            st = failure_runtime.init_queue(st, reset=True)
                        ev_wasted, target = J, -1
                    else:
                        (st, ev_wasted, target, ev_inner, rec_t, ev_pff, _,
                         ev_src) = _esrp_failure(
                            problem, plan, st, list(det.flagged), T, ops,
                            pff_precond, fruntime=failure_runtime, push=push,
                            sdc_mode=True, n_slabs=qsum_slabs, b=b,
                            tracer=tr)
                        inner_rel = ev_inner
                        if target >= 0:
                            ev_fetch = tier.fetch_bytes(
                                max(1, nbatch) * len(det.flagged) *
                                part.rows_per_node, itemsize)
                            ev_fetch_s = tier.read_s(ev_fetch)
                    if batched and not want_q:
                        st = esrp.member_select(st_pre, st, done_pre)
                    recovery_s += rec_t
                    wasted += ev_wasted
                    if tr is not None:
                        if ev_fetch:
                            tr.add_counter("tier_fetch_bytes", ev_fetch,
                                           tier=tier.name)
                        rp_sp.args.update(target_iter=target,
                                          wasted_iters=ev_wasted,
                                          latency=latency)
                    event_reports.append(EventReport(
                        iter=J, nodes=tuple(det.flagged), target_iter=target,
                        wasted_iters=ev_wasted, recovery_s=rec_t,
                        inner_rel=ev_inner, pff_iters=ev_pff,
                        queue_src_nodes=ev_src, kind="sdc-repair",
                        detector=det.detector, detect_iter=J,
                        detect_latency=latency, sdc_violation=det.violation,
                        sdc_tol=det.tol, tier=tier.name, fetch_bytes=ev_fetch,
                        fetch_s_model=ev_fetch_s))
                    total_iters = int(st.pcg.j)
                    resume_numeric_only = (not want_q) and target >= 0
    finally:
        if tr is not None:
            # close anything an exception unwound past, then the solve span
            tr.close(solve_sp, converged=converged, iters=total_iters,
                     recovery_s=recovery_s, wasted_iters=wasted,
                     run_calls=run_calls)
    runtime = time.perf_counter() - t0

    pcg = st.pcg
    jax.block_until_ready(pcg.x)
    nat_bytes = tot_bytes = 0
    if plan is not None:
        nat_bytes, tot_bytes = plan.bytes_per_aspmv(itemsize)
    push_count = 0
    if strategy == "esrp" and plan is not None:
        push_count = _count_pushes(push_ranges, T)
    if sdc_guard:
        # the guard evaluated one on-device check at every boundary the
        # executed stretches crossed; host confirmations (halts, post-inject
        # checks) were counted live into sdc_checks above
        sdc_checks += _count_checks(push_ranges, sdc_policy.check_every, T,
                                    strategy == "esrp")
    common = dict(
        strategy=strategy, T=T, phi=phi, runtime_s=runtime,
        recovery_s=recovery_s, wasted_iters=wasted, target_iter=target,
        inner_rel=inner_rel, aspmv_natural_bytes=nat_bytes,
        aspmv_total_bytes=tot_bytes, run_calls=run_calls,
        events=event_reports,
        precond_variant=getattr(ops, "variant", ""),
        precond_reload_bytes=sum(e.precond_reload_bytes
                                 for e in event_reports),
        tier=tier.name, push_count=push_count,
        push_bytes=push_count * per_push,
        push_s_model=push_count * (tier.write_s(per_push) if per_push
                                   else 0.0),
        fetch_s_model=sum(e.fetch_s_model for e in event_reports),
        sdc_checks=sdc_checks,
        sdc_check_every=sdc_policy.check_every if sdc_on else 0,
        final_n_nodes=part.n_nodes, trace=tr)
    if not batched:
        drift = float(residual_drift(matvec, b, pcg.x, pcg.r))
        rel = float(jnp.linalg.norm(pcg.r)) / float(jnp.linalg.norm(b))
        report = SolveReport(converged_iter=total_iters, rel_residual=rel,
                             drift=drift, converged=converged, x=pcg.x,
                             **common)
        if tr is not None:
            tr.record("solve_report", report.to_json())
        return report
    # batched: one SolveReport per member. Shared run accounting (runtime,
    # events, tier/push totals) repeats on every member — per-member fields
    # are the convergence count, residuals, drift, and the iterate itself.
    rel_v = np.asarray(_vec_norm(pcg.r))
    bn_v = np.asarray(_vec_norm(b))
    drift_v = np.asarray(residual_drift(matvec, b, pcg.x, pcg.r))
    reports = []
    for k in range(nbatch):
        ok = conv_iter[k] >= 0
        reports.append(SolveReport(
            converged_iter=int(conv_iter[k]) if ok else total_iters,
            rel_residual=(float(rel_v[k] / bn_v[k]) if bn_v[k] > 0 else 0.0),
            drift=float(drift_v[k]), converged=bool(ok or converged),
            batch_index=k, batch_size=nbatch, x=pcg.x[k], **common))
    if tr is not None:
        for r in reports:
            tr.record("solve_report", r.to_json())
    return reports


def _solver_rooflines_cached(problem: Problem, ops, b, backend: str) -> dict:
    """Roofline attribution of the SolverOps kernels, cached on the problem
    per (backend, variant, shape, dtype) — the HLO lowering+analysis runs
    once per distinct compiled program, like the jitted runners themselves."""
    from repro.obs.rooflines import solver_rooflines

    cache = getattr(problem, "_roofline_cache", None)
    if cache is None:
        cache = {}
        problem._roofline_cache = cache
    key = (backend, getattr(ops, "variant", ""), tuple(np.shape(b)),
           str(np.dtype(b.dtype)))
    if key not in cache:
        cache[key] = solver_rooflines(ops, b)
    return cache[key]


# --------------------------------------------------------------------------- #
def _at_sdc_boundary(j: int, check_every: int, T: int,
                     esrp_storage: bool) -> bool:
    """Is iteration count ``j`` an invariant-check point? The cadence, plus
    (ESRP) every storage iteration: a check right before each push/star
    commit guarantees the queue and the rollback anchor only ever hold
    verified state — which is what makes a later rollback-based repair
    sound."""
    if j % check_every == 0:
        return True
    return esrp_storage and j > 2 and (j % T == 0 or (j - 1) % T == 0)


def _next_sdc_boundary(j: int, check_every: int, T: int,
                       esrp_storage: bool) -> int:
    """Smallest check boundary strictly greater than ``j``."""
    nxt = (j // check_every + 1) * check_every
    if esrp_storage:
        for k in range(j + 1, nxt):
            if k > 2 and (k % T == 0 or (k - 1) % T == 0):
                return k
    return nxt


def _count_pushes(ranges: list[tuple[int, int]], T: int) -> int:
    """Replay the Alg. 3 storage schedule over the executed iteration
    stretches (rollback re-executes a stretch, so its pushes physically
    happen again)."""
    c = 0
    for base, end in ranges:
        for j in range(base, end):
            if j > 2 and (T == 1 or j % T == 0 or (j - 1) % T == 0):
                c += 1
    return c


def _count_checks(ranges: list[tuple[int, int]], check_every: int, T: int,
                  esrp_storage: bool) -> int:
    """Replay the invariant-check boundaries the on-device guard evaluated
    over the executed iteration stretches (guard mode runs the checks inside
    the scan, so the host loop never sees them — this recovers the
    ``sdc_checks`` accounting host mode counts directly)."""
    c = 0
    for base, end in ranges:
        for j in range(base, end):
            if j > 0 and _at_sdc_boundary(j, check_every, T, esrp_storage):
                c += 1
    return c


def _inject_sdc(problem: Problem, st: esrp.ESRPState, ev: SDCEvent, T: int,
                solver_ops, b, rr_every: int, gated: bool, push):
    """Execute iteration ``ev.iter`` with silent corruption struck
    mid-iteration. The storage prelude runs first and is CLEAN (the paper's
    injection point is right after the ASpMV — the push already carried the
    uncorrupted p), then the corruption lands:

      p/r/x/queue: flipped before the numeric update — the corrupted values
        feed this very iteration and silently propagate (queue corruption
        touches only the stored copy; the trajectory is unaffected).
      z: the carried z is recomputed and consumed into p = z + β·p_prev
        within the same fused update, so a plain pre-step flip of z would
        be a dead store and never observable. The physical event modeled is
        a flip landing between z's computation and its use: run the step
        cleanly, then apply the flip to z and its additive image to p.
    """
    st = jax.jit(esrp.esrp_prelude, static_argnums=(1, 2, 3))(st, T, True,
                                                              push)
    if ev.target == "z":
        st = st._replace(pcg=_resume_step(st.pcg, solver_ops, b, rr_every,
                                          gated))
        st = sdc.corrupt(st, ev, problem.part)
    else:
        st = sdc.corrupt(st, ev, problem.part)
        st = st._replace(pcg=_resume_step(st.pcg, solver_ops, b, rr_every,
                                          gated))
    return st


def _invalidate_queue_slots(st: esrp.ESRPState, det) -> esrp.ESRPState:
    """Queue-checksum repair: drop every slot holding a corrupted copy
    (tag := -1), zeroing its payload and checksums so later checks see a
    consistent empty slot. ``recovery_point`` will fall back to an older
    consecutive pair, or report unrecoverable until the next push."""
    for slot in sorted(set(det.queue_slots) | set(det.rq_slots)):
        st = st._replace(q=st.q.at[slot].set(0.0),
                         q_tags=st.q_tags.at[slot].set(-1))
        if not isinstance(st.q_sums, tuple):
            st = st._replace(q_sums=st.q_sums.at[slot].set(0.0))
        if not isinstance(st.rq, tuple):
            st = st._replace(rq=st.rq.at[slot].set(0.0))
            if not isinstance(st.rq_sums, tuple):
                st = st._replace(rq_sums=st.rq_sums.at[slot].set(0.0))
    return st


# --------------------------------------------------------------------------- #
def _none_failure(st: esrp.ESRPState, matvec, precond, b, dot=None):
    """strategy="none": no redundant copies, no checkpoints — every failure
    is a full restart with target_iter = -1 and J wasted iterations."""
    J = int(st.pcg.j)
    return esrp.esrp_init(matvec, precond, b, dot=dot), J, -1, 0.0


# --------------------------------------------------------------------------- #
def _esrp_failure(problem: Problem, plan: RedundancyPlan, st: esrp.ESRPState,
                  failed: list[int], T: int, solver_ops,
                  pff_precond: bool = True, fruntime=None, push=None,
                  sdc_mode: bool = False, n_slabs: int = 0, b=None,
                  tracer=None):
    """Failure strikes during iteration J right after its (A)SpMV: run the
    iteration-J storage prelude (including, on the sharded runtime, the
    physical redundancy sends that were already in flight), lose the failed
    nodes' dynamic data, then reconstruct (Alg. 2) and rebuild a consistent
    post-stage ESRP state.

    With ``fruntime`` (comm.shard.ShardedFailureRuntime) the whole failure
    path is device-resident: injection is a shard_map zeroing of the failed
    devices' shards only, and the p^(j-1)/p^(j) copies feeding Alg. 2 are
    read out of the *surviving devices'* queue shards (``ESRPState.rq``),
    never from a replicated array. Without it (the single-device simulator)
    the queue is the host-visible (3, M) array and injection is the
    replicated ``jnp.where`` of the paper's simulation protocol.

    ``sdc_mode`` repurposes the same machinery for detected silent
    corruption: nothing was physically lost — the flagged nodes' *live*
    vectors are untrustworthy, but their queue copies, held redundancy
    shards, and static data are intact (the check-before-store protocol
    plus read-time checksums guarantee it). So: no storage prelude (pushing
    the corrupted p would poison the queue), the discard zeroes live +
    starred state only, the redundancy survival analysis is skipped, and
    the p-pair reads straight from the host-visible queue. The rollback
    then discards EVERY live vector — survivors restore from the (clean)
    stars, flagged rows rebuild via Alg. 2 — so repair correctness never
    depends on how precisely the detector localized the corruption.
    """
    part = problem.part
    matvec, precond = solver_ops.matvec, solver_ops.precond
    # b: the RHS actually being solved (the batched driver passes its
    # (B, M) rhs; None keeps problem.b — the unbatched default)
    b_rhs = problem.b if b is None else b
    J = int(st.pcg.j)
    if not sdc_mode:
        st = jax.jit(esrp.esrp_prelude, static_argnums=(1, 2, 3))(st, T,
                                                                  True, push)

    # --- the failure: all dynamic data on failed nodes is lost -------------
    with _tspan(tracer, "inject", cat="recovery", nodes=list(failed),
                sdc_mode=sdc_mode):
        if sdc_mode and fruntime is not None:
            st = fruntime.lose_live(st, failed)
            reload_bytes = 0
        elif fruntime is not None:
            st = fruntime.lose_esrp(st, failed)
            reload_desc, reload_bytes = fruntime.precond_reload(failed)
            del reload_desc
        else:
            mask = failed_row_mask(part, failed)
            lose = lambda v: zero_failed(v, mask)
            pcg = st.pcg._replace(x=lose(st.pcg.x), r=lose(st.pcg.r),
                                  z=lose(st.pcg.z), p=lose(st.pcg.p))
            st = st._replace(pcg=pcg, x_s=lose(st.x_s), r_s=lose(st.r_s),
                             z_s=lose(st.z_s), p_s=lose(st.p_s))
            reload_bytes = 0
    pcg = st.pcg

    if not sdc_mode:
        # per-event φ-copy survival analysis: a redundant copy of every
        # failed tile must outlive this event's failed set (topology-aware,
        # so a lucky |failed| > φ set can still pass — see
        # RedundancyPlan.check_event). SDC loses no copies — skip.
        plan.check_event(failed)

    target, prev_slot, curr_slot = esrp.recovery_point(st, T)
    if target < 0:
        # before the first completed storage stage: restart from scratch
        st2 = esrp.esrp_init(matvec, precond, b_rhs, dot=solver_ops.dot,
                             n_slabs=n_slabs)
        if fruntime is not None:
            st2 = fruntime.init_queue(st2, reset=True)
        return st2, J, -1, float("nan"), 0.0, -1, reload_bytes, ()

    if T == 1:
        # ESR: no rollback — reconstruct the *live* iteration J from the
        # surviving r, x and the replicated scalar β^(J-1) (paper §2.3)
        r_surv, x_surv, z_surv, p_surv = pcg.r, pcg.x, pcg.z, pcg.p
        beta_prev = pcg.beta
        rz = pcg.rz          # replicated scalar — survives the failure
    else:
        r_surv, x_surv, z_surv, p_surv = st.r_s, st.x_s, st.z_s, st.p_s
        beta_prev = st.beta_s
        # r*ᵀz* was captured with the stars precisely so the rollback needs
        # no recompute from the (partly reconstructed) vectors: the stored
        # scalar is the exact value of the uncorrupted trajectory.
        rz = st.rz_s

    # the redundant p-copies Alg. 2 reads: on the sharded runtime the failed
    # rows are assembled from the surviving devices' physical queue shards
    # (the injection zeroed the failed rows of ``q`` itself); the simulator
    # reads the host-side queue directly. In sdc_mode nothing was wiped —
    # every node's own queue rows are intact and were checksum-verified by
    # this very check pass (the queue detector runs first), so the pair
    # reads straight from ``q`` on both runtimes.
    fetch_bytes = 2 * len(failed) * part.rows_per_node * \
        np.dtype(problem.b.dtype).itemsize * \
        (b_rhs.shape[0] if b_rhs.ndim == 2 else 1)
    with _tspan(tracer, "queue_fetch", cat="recovery",
                slots=[int(prev_slot), int(curr_slot)],
                bytes=int(fetch_bytes)) as qf_sp:
        if fruntime is not None and not sdc_mode:
            p_prev, p_curr, src_nodes = fruntime.assemble_pair(
                st, prev_slot, curr_slot, failed)
        else:
            p_prev, p_curr, src_nodes = st.q[prev_slot], st.q[curr_slot], ()
        if qf_sp is not None:
            jax.block_until_ready(p_curr)
            qf_sp.args["sources"] = list(src_nodes)

    # static-data reload (excluded from the recovery timing, paper §4) —
    # cached per (problem, failed-set) so repeated benchmark runs also reuse
    # the jitted inner solve (a C framework has no JIT warmup; timing it
    # would misattribute compilation to the paper's reconstruction cost)
    cache = getattr(problem, "_recon_cache", None)
    if cache is None:
        cache = {}
        problem._recon_cache = cache
    key = (tuple(failed), pff_precond)
    if key not in cache:
        with _tspan(tracer, "reconstruction_build", cat="build",
                    nodes=list(failed), pff_precond=pff_precond):
            ops = esr.ReconstructionOps.build(problem, failed,
                                              pff_precond=pff_precond)
            bf_warm = (None if b is None
                       else b_rhs[..., jnp.asarray(ops.f_rows)])
            # warm the jitted reconstruction (compile excluded from timing)
            esr.reconstruct(ops, p_prev=p_prev, p_curr=p_curr,
                            beta_prev=beta_prev, r_surv=r_surv,
                            x_surv=x_surv, b_f=bf_warm
                            )[0].block_until_ready()
        cache[key] = ops
    ops = cache[key]
    b_f = None if b is None else b_rhs[..., jnp.asarray(ops.f_rows)]
    t0 = time.perf_counter()
    x_f, r_f, z_f, inner_rel = esr.reconstruct(
        ops, p_prev=p_prev, p_curr=p_curr, beta_prev=beta_prev,
        r_surv=r_surv, x_surv=x_surv, b_f=b_f, tracer=tracer)
    with _tspan(tracer, "scatter", cat="recovery", target_iter=target):
        f_rows = jnp.asarray(ops.f_rows)
        x = x_surv.at[..., f_rows].set(x_f)
        r = r_surv.at[..., f_rows].set(r_f)
        z = z_surv.at[..., f_rows].set(z_f)
        p = p_surv.at[..., f_rows].set(p_curr[..., f_rows])
        jax.block_until_ready(x)
    rec_t = time.perf_counter() - t0

    new_pcg = PCGState(x=x, r=r, z=z, p=p, rz=rz, beta=beta_prev,
                       j=jnp.asarray(target, jnp.int32))
    empty = jnp.zeros_like(p)
    st2 = esrp.ESRPState(
        pcg=new_pcg,
        q=jnp.stack([empty, p_prev, p_curr]),
        q_tags=jnp.asarray([-1, target - 1, target], jnp.int32),
        x_s=x, r_s=r, z_s=z, p_s=p, beta_s=beta_prev, rz_s=rz,
        star_tag=jnp.asarray(target, jnp.int32))
    if not isinstance(st.q_sums, tuple):
        nsl = st.q_sums.shape[-1]
        # failed slabs were rebuilt (their content is fresh — recompute);
        # surviving slabs keep their STORED push-time checksums, so a copy
        # corrupted before this event keeps failing its checksum after the
        # restack instead of being laundered into a consistent one.
        # Batched: the per-member (B, nsl) rows broadcast against the
        # (nsl,) failed-slab mask — the failed node set is shared across
        # members (one event strikes every member's rows)
        fmask = jnp.zeros((nsl,), bool).at[jnp.asarray(failed)].set(True)
        st2 = st2._replace(q_sums=jnp.stack([
            jnp.zeros_like(st.q_sums[0]),
            jnp.where(fmask, sdc.slab_sums(p_prev, nsl),
                      st.q_sums[prev_slot]),
            jnp.where(fmask, sdc.slab_sums(p_curr, nsl),
                      st.q_sums[curr_slot])]))
    if fruntime is not None:
        # survivors keep their physical copies; the replacement's shard
        # stays empty (it was wiped) until the next storage push refreshes
        # every device's entry — tracked so a burst event cannot silently
        # read a stale copy. (sdc_mode: nothing was wiped — every holder's
        # copy is intact and stays readable.)
        st2 = st2._replace(rq=jnp.stack(
            [jnp.zeros_like(st.rq[0]), st.rq[prev_slot], st.rq[curr_slot]]))
        if not isinstance(st.rq_sums, tuple):
            st2 = st2._replace(rq_sums=jnp.stack(
                [jnp.zeros_like(st.rq_sums[0]), st.rq_sums[prev_slot],
                 st.rq_sums[curr_slot]]))
        if not sdc_mode:
            fruntime.mark_wiped(failed, target)
    pff_stats = getattr(ops.p_solve, "stats", None) if ops.p_solve else None
    pff_iters = pff_stats["iters"] if pff_stats else -1
    # batched line-8 rel is per-member — report the worst one
    return (st2, J - target, target, float(np.max(np.asarray(inner_rel))),
            rec_t, pff_iters, reload_bytes, src_nodes)


def _imcr_failure(st: imcr.IMCRState, part, failed: list[int], phi: int,
                  matvec, precond, b, dot=None, fruntime=None, tracer=None):
    """IMCR: zero the failed nodes' live data, then everyone rolls back to the
    last checkpoint (replacements fetch their parts from surviving buddies).

    The checkpoint state (``ck_*``, ``ck_tag``) is left untouched by
    recovery: it still holds the rolled-back-to iteration, so a *second*
    event striking before the next scheduled checkpoint finds a valid
    anchor and rolls back to the same tag again."""
    J = int(st.pcg.j)
    # per-event buddy-survival analysis (|failed| ≤ φ always passes; a
    # spread-out larger set may too — see imcr.check_survivable)
    imcr.check_survivable(failed, phi, part.n_nodes)
    if fruntime is not None:
        # sharded runtime: zero only the failed devices' shards (shard_map)
        st = st._replace(pcg=fruntime.lose_pcg(st.pcg, failed))
    else:
        mask = failed_row_mask(part, failed)
        lose = lambda v: zero_failed(v, mask)
        st = st._replace(pcg=st.pcg._replace(
            x=lose(st.pcg.x), r=lose(st.pcg.r), z=lose(st.pcg.z),
            p=lose(st.pcg.p)))
    tag = int(st.ck_tag)
    if tag < 0:                      # failure before the first checkpoint
        return imcr.imcr_init(matvec, precond, b, dot=dot), J, -1, 0.0
    t0 = time.perf_counter()
    with _tspan(tracer, "buddy_restore", cat="recovery", tag=tag,
                nodes=list(failed)):
        pcg = imcr.recover(st)       # fetch-from-buddy (restore the copies)
        jax.block_until_ready(pcg.x)
    rec_t = time.perf_counter() - t0
    return st._replace(pcg=pcg), J - tag, tag, rec_t
