"""Resilient-solve orchestration: run → (inject failure) → recover → converge.

Mirrors the paper's experimental protocol (§4-§5): one node-failure event per
run, injected at a marked iteration (the driver lands exactly on it), failed
nodes zero out all their dynamic data and then act as their own replacements.
Reported quantities match the paper's tables: total runtime, reconstruction
overhead, wasted iterations, converged iteration count, and residual drift
(Eq. 2).

The hot loop runs through a ``SolverOps`` bundle (repro.core.ops): Block-ELL
SpMV fused with the pᵀq dot, fused vector update, cond-gated storage
bookkeeping. Convergence uses a sync-free chunked protocol: each chunk
carries ||r|| as a done flag and freezes the state at first convergence, so
the driver never re-runs a chunk to land on the convergence iteration, and
the norm-record readback of chunk i overlaps with the dispatch of chunk i+1
instead of blocking between chunks.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import esr, esrp, imcr
from repro.core.aspmv import RedundancyPlan, build_plan
from repro.core.failures import failed_row_mask, zero_failed
from repro.core.ops import SolverOps, make_closure_ops
from repro.core.pcg import PCGState, pcg_iterate_ops, residual_drift
from repro.sparse.matrices import Problem


@dataclasses.dataclass
class SolveReport:
    strategy: str
    T: int
    phi: int
    converged_iter: int
    rel_residual: float
    runtime_s: float
    recovery_s: float            # reconstruction ops only (paper's metric)
    wasted_iters: int            # rollback distance
    target_iter: int             # reconstruction point (-1 = restart)
    inner_rel: float             # Alg.2 line-8 inner-solve relative residual
    drift: float                 # paper Eq. (2)
    aspmv_natural_bytes: int = 0
    aspmv_total_bytes: int = 0
    run_calls: int = 0           # chunk dispatches (no final-chunk re-run)


def _find_convergence(norms: np.ndarray, thresh: float) -> int:
    """Index of first iteration with ||r|| < thresh, or -1."""
    below = np.nonzero(norms < thresh)[0]
    return int(below[0]) if below.size else -1


# module-level so the trace cache survives across solves (a fresh jit wrapper
# per resume would recompile the same iteration every failure run)
_resume_iterate = jax.jit(pcg_iterate_ops, static_argnums=1)


def solve_resilient(
    problem: Problem,
    strategy: str = "esrp",            # "esrp" | "imcr" | "none"
    T: int = 20,
    phi: int = 1,
    rtol: float = 1e-8,
    max_iters: int = 100_000,
    fail_at: Optional[int] = None,     # iteration J struck by the failure
    failed_nodes: Optional[list[int]] = None,
    matvec: Optional[Callable] = None,
    chunk: int = 64,
    rr_every: int = 0,                 # residual replacement period (0 = off)
    backend: str = "auto",             # SolverOps backend for the hot loop
    ops: Optional[SolverOps] = None,   # explicit bundle (overrides backend)
    gated: bool = True,                # cond-gated storage/rr bookkeeping
) -> SolveReport:
    if ops is None:
        if matvec is not None:
            # cache the closure bundle on the problem so repeated solves with
            # the same matvec reuse the jitted chunk runners (the bundle is
            # their static argument), without pinning the problem in a
            # module-global cache
            cache = getattr(problem, "_closure_ops_cache", None)
            if cache is None:
                cache = {}
                problem._closure_ops_cache = cache
            key = (matvec, problem.apply_precond)
            if key not in cache:
                cache[key] = make_closure_ops(*key)
            ops = cache[key]
        else:
            ops = problem.solver_ops(backend)
    matvec = ops.matvec
    precond = ops.precond
    b = problem.b
    thresh_dev = jnp.asarray(rtol * float(jnp.linalg.norm(b)), b.dtype)
    # host-side scans must compare against the *same* value the chunk
    # runner's freeze uses, or (in f32) a norm between the two would freeze
    # the device state without the host ever declaring convergence
    thresh = float(thresh_dev)
    part = problem.part

    plan: Optional[RedundancyPlan] = None
    if strategy == "esrp":
        plan = build_plan(problem.a, part, phi)   # static, verified φ+1 copies

    if strategy == "imcr":
        st = imcr.imcr_init(matvec, precond, b)
        run = lambda s, n: imcr.run_chunk(s, ops, T, phi,
                                          part.rows_per_node, n,
                                          thresh_dev, gated)
    elif strategy == "esrp":
        st = esrp.esrp_init(matvec, precond, b)
        run = lambda s, n: esrp.run_chunk(s, ops, T, n, thresh_dev,
                                          rr_every, gated, b)
    elif strategy == "none":
        st = esrp.esrp_init(matvec, precond, b)   # T=max => never stores
        run = lambda s, n: esrp.run_chunk(s, ops, 1 << 30, n, thresh_dev,
                                          rr_every, gated, b)
    else:
        raise ValueError(strategy)

    recovery_s = 0.0
    wasted = 0
    target = -2
    inner_rel = float("nan")
    pending_fail = fail_at is not None

    t0 = time.perf_counter()
    total_iters = 0
    run_calls = 0
    resume_numeric_only = False
    converged = False
    # one chunk's norm record kept in flight: (device norms, start iteration).
    # Readback (the host sync) happens only after the *next* chunk has been
    # dispatched, so device compute and host bookkeeping overlap.
    inflight: Optional[tuple[jax.Array, int]] = None

    def settle(entry) -> bool:
        """Block on one chunk's norm record; True iff it converged. The
        chunk runner froze the state at first convergence, so on a hit the
        live ``st`` already is the state at iteration base + hit + 1 — no
        re-run needed, only the count is fixed up."""
        nonlocal total_iters, converged
        norms, base = entry
        hit = _find_convergence(np.asarray(norms), thresh)
        if hit >= 0:
            total_iters = base + hit + 1
            converged = True
        return converged

    while not converged:
        if resume_numeric_only:
            # post-recovery: re-run the reconstruction-point iteration without
            # its storage prelude (its push already happened pre-failure).
            # Jitted so the jnp backend fuses exactly like inside run_chunk —
            # keeps the cross-backend trajectory bit-identity through recovery.
            pcg = _resume_iterate(st.pcg, ops)
            st = st._replace(pcg=pcg)
            total_iters = int(pcg.j)
            resume_numeric_only = False
            if float(jnp.linalg.norm(pcg.r)) < thresh:
                break
            continue

        n = chunk
        if pending_fail:
            n = min(n, fail_at - total_iters)
        entry = None
        if n > 0:
            st, norms = run(st, n)               # async dispatch
            run_calls += 1
            entry = (norms, total_iters)
            total_iters += n

        if inflight is not None:
            prev, inflight = inflight, None
            if settle(prev):
                break                            # entry (if any) discarded:
                #                                  the state is frozen past
                #                                  convergence by construction
        at_fail = pending_fail and total_iters == fail_at
        if entry is not None:
            if at_fail or total_iters >= max_iters:
                if settle(entry):
                    break
            else:
                inflight = entry                 # overlap with next dispatch
        if total_iters >= max_iters:
            break

        if at_fail:
            pending_fail = False
            failed = sorted(failed_nodes or [0])
            if strategy == "imcr":
                st, wasted, target, rec_t = _imcr_failure(
                    st, part, failed, phi, matvec, precond, b)
            else:
                st, wasted, target, inner_rel, rec_t = _esrp_failure(
                    problem, plan, st, failed, T, matvec, precond)
            recovery_s += rec_t
            total_iters = int(st.pcg.j)
            resume_numeric_only = target >= 0
    runtime = time.perf_counter() - t0

    pcg = st.pcg
    jax.block_until_ready(pcg.x)
    drift = float(residual_drift(matvec, b, pcg.x, pcg.r))
    rel = float(jnp.linalg.norm(pcg.r)) / float(jnp.linalg.norm(b))
    nat_bytes = tot_bytes = 0
    if plan is not None:
        nat_bytes, tot_bytes = plan.bytes_per_aspmv(np.dtype(problem.b.dtype).itemsize)
    return SolveReport(
        strategy=strategy, T=T, phi=phi, converged_iter=total_iters,
        rel_residual=rel, runtime_s=runtime, recovery_s=recovery_s,
        wasted_iters=wasted, target_iter=target, inner_rel=inner_rel,
        drift=drift, aspmv_natural_bytes=nat_bytes,
        aspmv_total_bytes=tot_bytes, run_calls=run_calls)


# --------------------------------------------------------------------------- #
def _esrp_failure(problem: Problem, plan: RedundancyPlan, st: esrp.ESRPState,
                  failed: list[int], T: int, matvec, precond):
    """Failure strikes during iteration J right after its (A)SpMV: run the
    iteration-J storage prelude, zero the failed nodes' dynamic data, then
    reconstruct (Alg. 2) and rebuild a consistent post-stage ESRP state."""
    part = problem.part
    J = int(st.pcg.j)
    st = jax.jit(esrp.esrp_prelude, static_argnums=(1, 2))(st, T, True)

    # --- the failure: all dynamic data on failed nodes is lost -------------
    mask = failed_row_mask(part, failed)
    lose = lambda v: zero_failed(v, mask)
    pcg = st.pcg._replace(x=lose(st.pcg.x), r=lose(st.pcg.r),
                          z=lose(st.pcg.z), p=lose(st.pcg.p))
    st = st._replace(pcg=pcg, x_s=lose(st.x_s), r_s=lose(st.r_s),
                     z_s=lose(st.z_s), p_s=lose(st.p_s))

    # redundant copies survive iff a holder outlives the failure
    col_tiles = np.unique(np.concatenate(
        [np.arange(*part.node_col_tiles(s)) for s in failed]))
    if not plan.survives(np.array(failed))[col_tiles].all():
        raise RuntimeError(
            f"{len(failed)} simultaneous failures exceed phi={plan.phi}")

    target, prev_slot, curr_slot = esrp.recovery_point(st, T)
    if target < 0:
        # before the first completed storage stage: restart from scratch
        st2 = esrp.esrp_init(matvec, precond, problem.b)
        return st2, J, -1, float("nan"), 0.0

    if T == 1:
        # ESR: no rollback — reconstruct the *live* iteration J from the
        # surviving r, x and the replicated scalar β^(J-1) (paper §2.3)
        r_surv, x_surv, z_surv, p_surv = pcg.r, pcg.x, pcg.z, pcg.p
        beta_prev = pcg.beta
        rz = pcg.rz          # replicated scalar — survives the failure
    else:
        r_surv, x_surv, z_surv, p_surv = st.r_s, st.x_s, st.z_s, st.p_s
        beta_prev = st.beta_s
        # r*ᵀz* was captured with the stars precisely so the rollback needs
        # no recompute from the (partly reconstructed) vectors: the stored
        # scalar is the exact value of the uncorrupted trajectory.
        rz = st.rz_s

    # static-data reload (excluded from the recovery timing, paper §4) —
    # cached per (problem, failed-set) so repeated benchmark runs also reuse
    # the jitted inner solve (a C framework has no JIT warmup; timing it
    # would misattribute compilation to the paper's reconstruction cost)
    cache = getattr(problem, "_recon_cache", None)
    if cache is None:
        cache = {}
        problem._recon_cache = cache
    key = tuple(failed)
    if key not in cache:
        ops = esr.ReconstructionOps.build(problem, failed)
        # warm the jitted reconstruction (compile excluded from timing)
        esr.reconstruct(ops, p_prev=st.q[prev_slot], p_curr=st.q[curr_slot],
                        beta_prev=beta_prev, r_surv=r_surv, x_surv=x_surv
                        )[0].block_until_ready()
        cache[key] = ops
    ops = cache[key]
    t0 = time.perf_counter()
    x_f, r_f, z_f, inner_rel = esr.reconstruct(
        ops, p_prev=st.q[prev_slot], p_curr=st.q[curr_slot],
        beta_prev=beta_prev, r_surv=r_surv, x_surv=x_surv)
    f_rows = jnp.asarray(ops.f_rows)
    x = x_surv.at[f_rows].set(x_f)
    r = r_surv.at[f_rows].set(r_f)
    z = z_surv.at[f_rows].set(z_f)
    p = p_surv.at[f_rows].set(st.q[curr_slot][f_rows])
    jax.block_until_ready(x)
    rec_t = time.perf_counter() - t0

    new_pcg = PCGState(x=x, r=r, z=z, p=p, rz=rz, beta=beta_prev,
                       j=jnp.asarray(target, jnp.int32))
    empty = jnp.zeros_like(p)
    st2 = esrp.ESRPState(
        pcg=new_pcg,
        q=jnp.stack([empty, st.q[prev_slot], st.q[curr_slot]]),
        q_tags=jnp.asarray([-1, target - 1, target], jnp.int32),
        x_s=x, r_s=r, z_s=z, p_s=p, beta_s=beta_prev, rz_s=rz,
        star_tag=jnp.asarray(target, jnp.int32))
    return st2, J - target, target, float(inner_rel), rec_t


def _imcr_failure(st: imcr.IMCRState, part, failed: list[int], phi: int,
                  matvec, precond, b):
    """IMCR: zero the failed nodes' live data, then everyone rolls back to the
    last checkpoint (replacements fetch their parts from surviving buddies)."""
    J = int(st.pcg.j)
    if len(failed) > phi:
        raise RuntimeError(f"{len(failed)} failures exceed phi={phi}")
    mask = failed_row_mask(part, failed)
    lose = lambda v: zero_failed(v, mask)
    st = st._replace(pcg=st.pcg._replace(
        x=lose(st.pcg.x), r=lose(st.pcg.r), z=lose(st.pcg.z), p=lose(st.pcg.p)))
    tag = int(st.ck_tag)
    if tag < 0:                      # failure before the first checkpoint
        return imcr.imcr_init(matvec, precond, b), J, -1, 0.0
    t0 = time.perf_counter()
    pcg = imcr.recover(st)           # fetch-from-buddy (restore the copies)
    jax.block_until_ready(pcg.x)
    rec_t = time.perf_counter() - t0
    return st._replace(pcg=pcg), J - tag, tag, rec_t
