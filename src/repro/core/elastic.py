"""Elastic shrunk-mesh recovery: continue on N − |failed| nodes.

The paper's protocol assumes a same-size replacement rejoins (the failed
nodes "act as their own replacements", §4). When no replacement exists, the
only alternative to aborting is *elastic* recovery: reconstruct the lost
state exactly as before (Alg. 2 on the original partition — the queue
copies and the plan are laid out for N nodes and stay valid through the
reconstruction), then re-partition the problem onto the surviving node
count and continue there.

Re-partitioning must not perturb the trajectory's mathematics. The shrunk
partition needs M divisible by ``n_new · lcm(bm, bn, precond_block)``, so
the problem is re-padded with *decoupled identity rows* (A_ii = 1, b_i = 0
— the same padding rule ``build_problem`` uses) and every state vector is
extended with zeros. The extension is exactly consistent: on a padding row
r = b − Ax = 0 − x = 0, z = (P r)_i = 0 (the row is decoupled, every
preconditioner's apply reduces to the identity there), p = z + βp = 0, and
all inner products are unchanged (zero contributions). The continued run
therefore computes the *same* iterates on the first M entries — up to
reduction-order rounding, since longer arrays may sum in a different
association, which is why the rejoin assertion is norm-wise, not bitwise.

The ASpMV redundancy plan, the P_ff recovery operators, and the solver ops
are all layout-dependent and are rebuilt from the re-padded matrix (the
static data lives in safe storage — rebuilding it is the same Alg. 2 line 1
reload a replacement node performs, just for a new layout).
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.sparse.matrices import Problem
from repro.sparse.partition import shrunk_partition
from repro.sparse.blockell import BlockEll
from repro.precond.jacobi import block_jacobi_blocks, invert_blocks


def shrink_problem(problem: Problem, n_new: int) -> Problem:
    """Re-partition ``problem`` onto ``n_new`` nodes (cached per n_new).

    Appends decoupled identity rows up to the new partition unit, re-packs
    the Block-ELL matrix, and rebuilds the preconditioner from the same COO
    with the same name/block/options — everything a shrunk mesh needs to
    keep solving the *same* linear system.
    """
    if not 1 <= n_new < problem.part.n_nodes:
        raise ValueError(
            f"elastic shrink needs 1 <= n_new < {problem.part.n_nodes}, "
            f"got {n_new}")
    cache = getattr(problem, "_elastic_cache", None)
    if cache is None:
        cache = {}
        problem._elastic_cache = cache
    if n_new in cache:
        return cache[n_new]

    part = problem.part
    part_new = shrunk_partition(part, n_new, problem.precond_block)
    m_new = part_new.m
    rows, cols, vals = problem.coo
    if m_new != part.m:
        pad = np.arange(part.m, m_new)
        rows = np.concatenate([rows, pad])
        cols = np.concatenate([cols, pad])
        vals = np.concatenate([vals, np.ones(pad.size, vals.dtype)])
    dtype = problem.b.dtype
    a = BlockEll.from_coo(rows, cols, vals, m_new, part.bm, part.bn,
                          dtype=dtype)
    diag = block_jacobi_blocks(rows, cols, vals, m_new,
                               problem.precond_block, dtype)
    pinv = invert_blocks(diag)
    from repro import precond as precond_pkg
    name = problem.precond_name
    opts = {}
    for opt in ("omega", "degree", "sweep_mode"):
        val = getattr(problem.precond, opt, None)
        if val is not None:
            opts[opt] = val
    pc = precond_pkg.build(name, coo=(rows, cols, vals), m=m_new,
                           block=problem.precond_block, dtype=dtype, a=a,
                           diag_blocks=diag, pinv_blocks=pinv, **opts)
    b = jnp.zeros((m_new,), dtype).at[:part.m].set(problem.b)
    shrunk = Problem(a=a, part=part_new, b=b, pinv_blocks=jnp.asarray(pinv),
                     diag_blocks=jnp.asarray(diag),
                     precond_block=problem.precond_block,
                     coo=(rows, cols, vals), precond=pc)
    cache[n_new] = shrunk
    return shrunk


def _extend(v: jnp.ndarray, m_new: int) -> jnp.ndarray:
    """Zero-pad the trailing (row) axis to ``m_new``, preserving any leading
    axes — (M,), (B, M), (3, M) queue stacks, and (3, B, M) batched stacks
    all extend the same way."""
    return (jnp.zeros(v.shape[:-1] + (m_new,), v.dtype)
            .at[..., :v.shape[-1]].set(v))


def remap_state(st, m_new: int, n_slabs: int):
    """Extend a (recovered, full-length-M) ESRPState onto the re-padded
    length ``m_new``: live vectors, queue copies, and starred locals get
    zero padding rows (exactly consistent — see module docstring); the
    per-slab queue checksums are recomputed for the new slab count (the
    underlying copies did not change, only the slab boundaries did)."""
    pcg = st.pcg._replace(x=_extend(st.pcg.x, m_new),
                          r=_extend(st.pcg.r, m_new),
                          z=_extend(st.pcg.z, m_new),
                          p=_extend(st.pcg.p, m_new))
    st = st._replace(pcg=pcg, q=_extend(st.q, m_new),
                     x_s=_extend(st.x_s, m_new), r_s=_extend(st.r_s, m_new),
                     z_s=_extend(st.z_s, m_new), p_s=_extend(st.p_s, m_new))
    if not isinstance(st.q_sums, tuple):
        sums = st.q.reshape(st.q.shape[:-1] + (n_slabs, -1)).sum(axis=-1)
        # empty slots keep checksum 0 (their content is all-zero anyway)
        valid = (st.q_tags >= 0).reshape((3,) + (1,) * (sums.ndim - 1))
        st = st._replace(q_sums=jnp.where(valid, sums,
                                          jnp.zeros_like(sums)))
    return st
