"""Silent-data-corruption injection, invariant checks, and localization.

Fail-stop failures announce themselves; SDC does not. Following the
algorithmic-redundancy line of arXiv:1309.0212 (redundant computation makes
corrupted iterates *detectable and repairable*), the driver evaluates cheap
solver invariants on a fixed cadence and, on a violation, routes the run
through the same Alg. 2 reconstruction a fail-stop uses — rolling everyone
back to the clean stored stage and rebuilding the flagged nodes' entries
from the redundancy queue.

The detectors (evaluated every ``check_every`` iterations, one extra SpMV +
one preconditioner apply per check):

  residual       ‖r − (b − A·x)‖ / ‖b‖ — the recurrence residual must track
                 the true residual (van der Vorst/Ye drift, paper Eq. 2).
                 Catches corruption of x or r: a consistent CG update leaves
                 the deviation vector d = r − (b − A x) *invariant*, so an
                 injected e_x (d = −A e_x) or e_r (d = e_r) persists until
                 checked, and its per-node-slab norms localize the corrupted
                 node (± one halo for e_x).
  orthogonality  |rᵀp − rz| / (‖r‖·‖p‖) — entering an iteration, CG's local
                 orthogonality gives rᵀp = rᵀz exactly (p = z + β·p_prev,
                 rᵀp_prev = O(ε)). A corrupted direction p breaks the
                 identity; the violation persists for the following
                 iterations (the Krylov structure is broken), so a check
                 period away it is still visible. NOTE corruption of p does
                 NOT break the residual detector — x and r are updated with
                 the *same* corrupted direction, so r ≡ b − A x is
                 preserved; this second invariant is what catches it.
  z-invariant    ‖z − P·r‖ / ‖z‖ — the carried z must be the preconditioned
                 residual. Catches a bit flip landing in z between its
                 computation and its use in p = z + β·p_prev (the injection
                 model for target="z"; see ``corrupt``). Localizes exactly
                 (P·r is recomputed clean).
  queue-checksum per-push per-node-slab checksums carried in the state
                 (``ESRPState.q_sums`` / ``rq_sums``, written at push time
                 inside the same ``lax.cond``) vs a recompute. Catches
                 corruption of the redundancy copies themselves — which
                 never perturbs the trajectory but would poison a later
                 Alg. 2 read; the same checksums are verified at read time
                 in ``comm.shard.ShardedFailureRuntime.assemble_pair``.

Tolerances are relative, recorded in the reports, and define the detection
floor: a flip below the invariant noise (low-order mantissa bits) is
undetectable but also numerically harmless at that tolerance. All
comparisons are written NaN-safe (``not (v <= tol)``): an exponent-bit flip
that drives the state to inf/NaN *fires* the detectors rather than
vacuously passing them.

Everything here is batch-polymorphic: a batched solve carries (B, M) live
vectors and (3, B, n_slabs) queue checksums, the invariants evaluate
per member, and detection fires when any *live* member violates. Members
whose RHS row is all-zero (the micro-batcher's padding) and members already
converged are excluded — their B=1 reference runs either never existed or
already ended, so nothing about them may fire a repair.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.failures import SDCEvent
from repro.core.pcg import _vec_norm
from repro.sparse.partition import Partition


@dataclasses.dataclass(frozen=True)
class SDCPolicy:
    """Invariant-check cadence and the (recorded) detection tolerances."""

    check_every: int = 16        # invariant-check period (iterations)
    res_rtol: float = 1e-7       # ‖r − (b − Ax)‖ / ‖b‖
    orth_rtol: float = 1e-8      # |rᵀp − rz| / (‖r‖·‖p‖)
    z_rtol: float = 1e-8         # ‖z − P r‖ / ‖z‖
    queue_rtol: float = 1e-9     # per-slab checksum relative mismatch
    flag_frac: float = 0.05      # slab is flagged when its deviation norm
    #                              exceeds this fraction of the max slab
    max_repairs: int = 8         # hard stop against a repair loop that
    #                              cannot clear the violation

    def __post_init__(self):
        if self.check_every < 1:
            raise ValueError(
                f"SDCPolicy.check_every must be >= 1, got {self.check_every}")


@dataclasses.dataclass
class Detection:
    """One fired invariant check (input to the driver's repair routing)."""

    detector: str                # "residual" | "orthogonality" |
    #                              "z-invariant" | "queue-checksum"
    violation: float             # the relative violation that fired
    tol: float                   # the tolerance it was compared against
    flagged: tuple[int, ...]     # localized node set (repair reconstructs
    #                              these; rollback cleans everything else)
    queue_slots: tuple[int, ...] = ()   # queue-checksum: corrupted q slots
    rq_slots: tuple[int, ...] = ()      # queue-checksum: corrupted rq slots


def slab_sums(v: jax.Array, n_slabs: int) -> jax.Array:
    """Per-node-slab checksum of a distributed vector (plain slab sum; the
    push-time and check-time values go through this same helper so a
    mismatch beyond reduction-order noise means the stored copy changed).
    Batch-polymorphic: slabs live on the last axis, so an (M,) vector gives
    (n_slabs,) and a batched (B, M) vector gives per-member (B, n_slabs)."""
    return v.reshape(v.shape[:-1] + (n_slabs, -1)).sum(axis=-1)


# --------------------------------------------------------------------------- #
# injection
# --------------------------------------------------------------------------- #
def _uint_dtype(dtype) -> tuple[object, int]:
    itemsize = jnp.dtype(dtype).itemsize
    return {8: jnp.uint64, 4: jnp.uint32, 2: jnp.uint16}[itemsize], \
        itemsize * 8

def _flip(v: jax.Array, idx: np.ndarray, bit: int) -> jax.Array:
    """XOR bit ``bit`` of the entries at last-axis indices ``idx``.
    Elementwise on the (possibly sharded) array — under the mesh each device
    flips only the entries its own shard holds; on a batched (B, M) vector
    the same columns flip in every member's row (one physical event strikes
    all B members, like fail-stop injection)."""
    ut, nbits = _uint_dtype(v.dtype)
    iv = jax.lax.bitcast_convert_type(v, ut)
    mask = jnp.zeros_like(iv).at[..., jnp.asarray(idx)].set(
        ut(1) << ut(min(bit, nbits - 1)))
    return jax.lax.bitcast_convert_type(iv ^ mask, v.dtype)


def _corrupt_values(v: jax.Array, idx: np.ndarray, ev: SDCEvent) -> jax.Array:
    if ev.kind == "bitflip":
        return _flip(v, idx, ev.bit)
    # perturb scale is per member (max over the member's own row), so a
    # batched member's bump is bit-identical to its B=1 run's
    bump = (ev.scale * jnp.max(jnp.abs(v)) if v.ndim == 1
            else ev.scale * jnp.max(jnp.abs(v), axis=-1, keepdims=True))
    return v.at[..., jnp.asarray(idx)].add(bump)


def _entry_indices(part: Partition, node: int, ev: SDCEvent) -> np.ndarray:
    """Deterministic corrupted-entry choice inside one node's slab."""
    lo, hi = part.node_rows(node)
    rng = np.random.default_rng((ev.seed, ev.iter, node))
    return rng.integers(lo, hi, size=ev.count)


def corrupt(st, ev: SDCEvent, part: Partition):
    """Apply one SDCEvent to an ESRPState (mid-iteration, after the storage
    prelude — the same injection point fail-stop events use).

    target p/r/x: flip entries of the live vector entering the iteration
    (the corrupted values feed the iteration's own update and silently
    propagate). target z: the carried z is consumed into p = z + β·p_prev
    within the same fused update, so a flip landing on z between compute
    and use corrupts *both* — the injection applies the flip to z and adds
    the identical value delta to p (its additive image through the p
    update). target "queue": flip entries of the newest valid redundancy
    copy — the host-visible ``q`` slot slab, and, on the mesh runtime, the
    listed *holder* devices' physical ``rq`` rows; the live trajectory is
    untouched, only a later recovery read would be poisoned.
    """
    idx = np.concatenate([_entry_indices(part, s, ev) for s in ev.nodes])
    pcg = st.pcg
    if ev.target == "p":
        return st._replace(pcg=pcg._replace(p=_corrupt_values(pcg.p, idx, ev)))
    if ev.target == "r":
        return st._replace(pcg=pcg._replace(r=_corrupt_values(pcg.r, idx, ev)))
    if ev.target == "x":
        return st._replace(pcg=pcg._replace(x=_corrupt_values(pcg.x, idx, ev)))
    if ev.target == "z":
        z_bad = _corrupt_values(pcg.z, idx, ev)
        delta = z_bad - pcg.z
        return st._replace(pcg=pcg._replace(z=z_bad, p=pcg.p + delta))
    # target == "queue"
    tags = np.asarray(st.q_tags)
    valid = np.nonzero(tags >= 0)[0]
    slot = int(valid[-1]) if valid.size else 2
    st = st._replace(q=st.q.at[slot].set(_corrupt_values(st.q[slot], idx, ev)))
    if not isinstance(st.rq, tuple):
        # the physical device-resident copies: flip inside the listed holder
        # devices' (width, bn) queue rows — on the batched runtime the same
        # holder rows flip for every member
        w, bn = st.rq.shape[-2], st.rq.shape[-1]
        for d in ev.nodes:
            rng = np.random.default_rng((ev.seed, ev.iter, d, 1))
            flat = rng.integers(0, w * bn, size=ev.count)
            if st.rq.ndim == 5:
                row = st.rq[slot, :, d].reshape(st.rq.shape[1], -1)
                st = st._replace(rq=st.rq.at[slot, :, d].set(
                    _corrupt_values(row, flat, ev).reshape(-1, w, bn)))
            else:
                row = st.rq[slot, d].reshape(-1)
                st = st._replace(rq=st.rq.at[slot, d].set(
                    _corrupt_values(row, flat, ev).reshape(w, bn)))
    return st


# --------------------------------------------------------------------------- #
# invariant evaluation
# --------------------------------------------------------------------------- #
@functools.partial(jax.jit, static_argnums=(0, 3))
def _invariant_values(ops, pcg, b, n_slabs):
    """Device computation for one check: the residual-deviation slab norms,
    the orthogonality violation and its slab partials, the z-invariant slab
    norms, and the norms the relative tolerances divide by. Batched (B, M)
    states yield per-member rows ((B, n_slabs) slab profiles, (B,) norms)."""
    d = pcg.r - (b - ops.matvec(pcg.x))
    shp = d.shape[:-1] + (n_slabs, -1)
    dev_slab = jnp.linalg.norm(d.reshape(shp), axis=-1)
    if pcg.r.ndim == 1:
        rp = (pcg.r @ pcg.p if ops.dot is None else ops.dot(pcg.r, pcg.p))
    else:
        rp = (jnp.sum(pcg.r * pcg.p, axis=-1) if ops.dot is None
              else ops.dot(pcg.r, pcg.p))
    orth_slab = (pcg.r * (pcg.p - pcg.z)).reshape(shp).sum(axis=-1)
    dz = pcg.z - ops.precond(pcg.r)
    z_slab = jnp.linalg.norm(dz.reshape(shp), axis=-1)
    return (dev_slab, jnp.abs(rp - pcg.rz), orth_slab, z_slab,
            _vec_norm(pcg.r), _vec_norm(pcg.p), _vec_norm(pcg.z))


def _flag_slabs(slab: np.ndarray, frac: float) -> tuple[int, ...]:
    top = np.nanmax(slab) if np.isfinite(slab).any() else np.inf
    if not np.isfinite(top):
        # inf/NaN deviation: every non-finite slab is suspect
        return tuple(int(s) for s in np.nonzero(~np.isfinite(slab))[0])
    return tuple(int(s) for s in np.nonzero(slab >= frac * top)[0])


def _queue_mismatch(stored, arrays, n_slabs, rtol, reducer):
    """Corrupted (slot, node) pairs among the slots with a valid tag. The
    per-slot comparison is batch-polymorphic: a batched (B, n) checksum row
    flags a node when ANY member's checksum for it mismatches."""
    bad = []
    for slot, tag, stored_row in arrays:
        if tag < 0:
            continue
        actual = np.asarray(reducer(slot))
        ref = np.asarray(stored_row)
        scale = np.abs(ref) + 1.0
        mism = ~(np.abs(actual - ref) <= rtol * scale)    # NaN-safe
        mism = mism.reshape(-1, mism.shape[-1]).any(axis=0)
        for node in np.nonzero(mism)[0]:
            bad.append((slot, int(node)))
    return bad


def _worst_member(vals: np.ndarray, viol: np.ndarray) -> int:
    """Index of the worst violating member (NaN counts as worst-possible)."""
    v = np.where(viol, vals, -np.inf)
    v = np.where(np.isnan(v), np.inf, v)
    return int(np.argmax(v))


def _flag_union(slab2: np.ndarray, viol: np.ndarray,
                frac: float) -> tuple[int, ...]:
    """Union of the violating members' flagged slabs (one member: exactly
    ``_flag_slabs`` of its profile — the unbatched behaviour)."""
    out: set[int] = set()
    for m in np.nonzero(viol)[0]:
        out.update(_flag_slabs(slab2[m], frac))
    return tuple(sorted(out))


def run_checks(ops, st, b, part: Partition, bnorm,
               policy: SDCPolicy, live=None) -> Detection | None:
    """Evaluate every invariant on the current state; return the
    most-localizable fired Detection (queue checksums first — exact
    localization, no rollback needed — then residual, z-invariant,
    orthogonality), or None when all invariants hold.

    Batched states evaluate every relative invariant per member. ``bnorm``
    may be a scalar (unbatched) or a (B,) per-member array; batched runs
    always re-derive the per-member ‖b‖ from ``b`` so zero-RHS padding
    members are excluded from detection even when the caller passed a flat
    norm. ``live`` (optional (B,) bool) further restricts detection to
    members still iterating — a member that already converged ended its
    B=1 reference run before this check existed, so it must not fire one.
    """
    n = part.n_nodes
    q_sums = getattr(st, "q_sums", ())
    rq_sums = getattr(st, "rq_sums", ())

    if not isinstance(q_sums, tuple):
        tags = np.asarray(st.q_tags)
        bad_q = _queue_mismatch(
            q_sums, [(s, int(tags[s]), q_sums[s]) for s in range(3)],
            n, policy.queue_rtol,
            lambda s: slab_sums(st.q[s], n))
        bad_rq = []
        if not isinstance(rq_sums, tuple):
            bad_rq = _queue_mismatch(
                rq_sums, [(s, int(tags[s]), rq_sums[s]) for s in range(3)],
                n, policy.queue_rtol,
                lambda s: st.rq[s].sum(axis=(-2, -1)))
        if bad_q or bad_rq:
            nodes = tuple(sorted({d for _, d in bad_q + bad_rq}))
            return Detection(
                detector="queue-checksum", violation=float("nan"),
                tol=policy.queue_rtol, flagged=nodes,
                queue_slots=tuple(sorted({s for s, _ in bad_q})),
                rq_slots=tuple(sorted({s for s, _ in bad_rq})))

    batched = st.pcg.x.ndim == 2
    (dev_slab, orth, orth_slab, z_slab, rnorm, pnorm,
     znorm) = jax.device_get(_invariant_values(ops, st.pcg, b, n))
    tiny = np.finfo(np.float64).tiny

    # normalize to per-member rows: (B, n_slabs) profiles, (B,) norms —
    # the unbatched state is one member (B = 1, bitwise the legacy values)
    dev2 = np.atleast_2d(np.asarray(dev_slab, np.float64))
    z2 = np.atleast_2d(np.asarray(z_slab, np.float64))
    orth2 = np.atleast_2d(np.asarray(orth_slab, np.float64))
    ov = np.atleast_1d(np.asarray(orth, np.float64))
    rn = np.atleast_1d(np.asarray(rnorm, np.float64))
    pn = np.atleast_1d(np.asarray(pnorm, np.float64))
    zn = np.atleast_1d(np.asarray(znorm, np.float64))
    if batched:
        bn = np.linalg.norm(np.asarray(jax.device_get(b), np.float64),
                            axis=-1)
    else:
        bn = np.atleast_1d(np.asarray(bnorm, np.float64))
    lv = np.ones(dev2.shape[0], bool) if live is None \
        else np.asarray(live, bool).reshape(-1)
    lv = lv & (bn > 0)     # zero-RHS members: frozen padding, never flagged
    # NaN/inf in a corrupted member's profile is a *signal* here (the
    # NaN-safe comparisons below turn it into a fired detector), not an
    # arithmetic error worth a warning
    with np.errstate(invalid="ignore", over="ignore", divide="ignore"):
        return _state_checks(dev2, z2, orth2, ov, rn, pn, zn, bn, lv,
                             policy, tiny)


def _state_checks(dev2, z2, orth2, ov, rn, pn, zn, bn, lv,
                  policy: SDCPolicy, tiny: float) -> Detection | None:
    res_rel = np.linalg.norm(dev2, axis=-1) / np.maximum(bn, tiny)
    viol = ~(res_rel <= policy.res_rtol) & lv              # NaN-safe
    if viol.any():
        k = _worst_member(res_rel, viol)
        return Detection(detector="residual", violation=float(res_rel[k]),
                         tol=policy.res_rtol,
                         flagged=_flag_union(dev2, viol, policy.flag_frac))

    z_rel = np.linalg.norm(z2, axis=-1) / np.maximum(zn, tiny)
    viol = ~(z_rel <= policy.z_rtol) & lv
    if viol.any():
        k = _worst_member(z_rel, viol)
        return Detection(detector="z-invariant", violation=float(z_rel[k]),
                         tol=policy.z_rtol,
                         flagged=_flag_union(z2, viol, policy.flag_frac))

    denom = rn * pn
    orth_rel = ov / np.maximum(denom, tiny)
    # ‖r‖·‖p‖ overflowed (r passed the residual check, so this is ‖p‖):
    # a clean finite direction cannot — the ratio that would hide the
    # violation (huge/inf → 0) is an overflow artifact, not a pass
    orth_rel = np.where(np.isfinite(denom), orth_rel, np.inf)
    viol = ~(orth_rel <= policy.orth_rtol) & lv
    if viol.any():
        # a corrupted direction contaminates every slab through the global
        # α/β scalars — no sound per-slab localization exists. Flag each
        # violating member's largest |rᵀ(p − z)| partial (the corrupted
        # entries dominate it for the flips above the detection floor);
        # repair correctness never depends on the guess, because the
        # rollback discards ALL live vectors and rebuilds from clean
        # storage.
        k = _worst_member(orth_rel, viol)
        flags: set[int] = set()
        for m in np.nonzero(viol)[0]:
            a = np.abs(orth2[m])
            a = np.where(np.isfinite(a), a, np.inf)
            flags.add(int(np.argmax(a)))
        return Detection(detector="orthogonality",
                         violation=float(orth_rel[k]),
                         tol=policy.orth_rtol,
                         flagged=tuple(sorted(flags)))
    return None


def device_violation(ops, st, b, thresh, policy: SDCPolicy,
                     rnorm=None) -> jax.Array:
    """On-device boolean: does any live member violate a state invariant or
    a queue checksum at the current iterate? This is the chunk-tail guard
    (``esrp.run_chunk(sdc_check=...)``): a fire halts the chunk at the exact
    check boundary — before the iteration's storage prelude can commit
    corrupted state — and the host then runs the authoritative
    ``run_checks`` localization on the halted state. Thresholds and member
    exclusions mirror ``run_checks``; the two may disagree only within a
    ulp of the tolerance, which is orders below any injected corruption.
    """
    pcg = st.pcg
    tiny = jnp.asarray(jnp.finfo(b.dtype).tiny, b.dtype)
    bn = _vec_norm(b)
    rn = _vec_norm(pcg.r) if rnorm is None else rnorm
    # NaN-safe liveness: a member whose ‖r‖ went NaN is the OPPOSITE of
    # converged — ~(rn < thresh) keeps it live where (rn >= thresh) would
    # silently mask it from every detector
    live = ~(rn < thresh) & (bn > 0)
    d = pcg.r - (b - ops.matvec(pcg.x))
    res = _vec_norm(d) / jnp.maximum(bn, tiny)
    dz = pcg.z - ops.precond(pcg.r)
    zrel = _vec_norm(dz) / jnp.maximum(_vec_norm(pcg.z), tiny)
    rp = (pcg.r @ pcg.p if pcg.r.ndim == 1
          else jnp.sum(pcg.r * pcg.p, axis=-1))
    denom = rn * _vec_norm(pcg.p)
    orth = jnp.abs(rp - pcg.rz) / jnp.maximum(denom, tiny)
    orth = jnp.where(jnp.isfinite(denom), orth, jnp.inf)
    bad = (~(res <= policy.res_rtol) | ~(zrel <= policy.z_rtol)
           | ~(orth <= policy.orth_rtol))                  # NaN-safe
    fired = jnp.any(bad & live)
    if not isinstance(st.q_sums, tuple):
        nsl = st.q_sums.shape[-1]
        sums = jnp.stack([slab_sums(st.q[s], nsl) for s in range(3)])
        mism = ~(jnp.abs(sums - st.q_sums)
                 <= policy.queue_rtol * (jnp.abs(st.q_sums) + 1.0))
        valid = (st.q_tags >= 0).reshape((3,) + (1,) * (mism.ndim - 1))
        fired = fired | jnp.any(mism & valid)
    if not isinstance(st.rq_sums, tuple):
        rsums = st.rq.sum(axis=(-2, -1))
        mism = ~(jnp.abs(rsums - st.rq_sums)
                 <= policy.queue_rtol * (jnp.abs(st.rq_sums) + 1.0))
        valid = (st.q_tags >= 0).reshape((3,) + (1,) * (mism.ndim - 1))
        fired = fired | jnp.any(mism & valid)
    return fired
