"""Silent-data-corruption injection, invariant checks, and localization.

Fail-stop failures announce themselves; SDC does not. Following the
algorithmic-redundancy line of arXiv:1309.0212 (redundant computation makes
corrupted iterates *detectable and repairable*), the driver evaluates cheap
solver invariants on a fixed cadence and, on a violation, routes the run
through the same Alg. 2 reconstruction a fail-stop uses — rolling everyone
back to the clean stored stage and rebuilding the flagged nodes' entries
from the redundancy queue.

The detectors (evaluated every ``check_every`` iterations, one extra SpMV +
one preconditioner apply per check):

  residual       ‖r − (b − A·x)‖ / ‖b‖ — the recurrence residual must track
                 the true residual (van der Vorst/Ye drift, paper Eq. 2).
                 Catches corruption of x or r: a consistent CG update leaves
                 the deviation vector d = r − (b − A x) *invariant*, so an
                 injected e_x (d = −A e_x) or e_r (d = e_r) persists until
                 checked, and its per-node-slab norms localize the corrupted
                 node (± one halo for e_x).
  orthogonality  |rᵀp − rz| / (‖r‖·‖p‖) — entering an iteration, CG's local
                 orthogonality gives rᵀp = rᵀz exactly (p = z + β·p_prev,
                 rᵀp_prev = O(ε)). A corrupted direction p breaks the
                 identity; the violation persists for the following
                 iterations (the Krylov structure is broken), so a check
                 period away it is still visible. NOTE corruption of p does
                 NOT break the residual detector — x and r are updated with
                 the *same* corrupted direction, so r ≡ b − A x is
                 preserved; this second invariant is what catches it.
  z-invariant    ‖z − P·r‖ / ‖z‖ — the carried z must be the preconditioned
                 residual. Catches a bit flip landing in z between its
                 computation and its use in p = z + β·p_prev (the injection
                 model for target="z"; see ``corrupt``). Localizes exactly
                 (P·r is recomputed clean).
  queue-checksum per-push per-node-slab checksums carried in the state
                 (``ESRPState.q_sums`` / ``rq_sums``, written at push time
                 inside the same ``lax.cond``) vs a recompute. Catches
                 corruption of the redundancy copies themselves — which
                 never perturbs the trajectory but would poison a later
                 Alg. 2 read; the same checksums are verified at read time
                 in ``comm.shard.ShardedFailureRuntime.assemble_pair``.

Tolerances are relative, recorded in the reports, and define the detection
floor: a flip below the invariant noise (low-order mantissa bits) is
undetectable but also numerically harmless at that tolerance. All
comparisons are written NaN-safe (``not (v <= tol)``): an exponent-bit flip
that drives the state to inf/NaN *fires* the detectors rather than
vacuously passing them.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.failures import SDCEvent
from repro.sparse.partition import Partition


@dataclasses.dataclass(frozen=True)
class SDCPolicy:
    """Invariant-check cadence and the (recorded) detection tolerances."""

    check_every: int = 16        # invariant-check period (iterations)
    res_rtol: float = 1e-7       # ‖r − (b − Ax)‖ / ‖b‖
    orth_rtol: float = 1e-8      # |rᵀp − rz| / (‖r‖·‖p‖)
    z_rtol: float = 1e-8         # ‖z − P r‖ / ‖z‖
    queue_rtol: float = 1e-9     # per-slab checksum relative mismatch
    flag_frac: float = 0.05      # slab is flagged when its deviation norm
    #                              exceeds this fraction of the max slab
    max_repairs: int = 8         # hard stop against a repair loop that
    #                              cannot clear the violation

    def __post_init__(self):
        if self.check_every < 1:
            raise ValueError(
                f"SDCPolicy.check_every must be >= 1, got {self.check_every}")


@dataclasses.dataclass
class Detection:
    """One fired invariant check (input to the driver's repair routing)."""

    detector: str                # "residual" | "orthogonality" |
    #                              "z-invariant" | "queue-checksum"
    violation: float             # the relative violation that fired
    tol: float                   # the tolerance it was compared against
    flagged: tuple[int, ...]     # localized node set (repair reconstructs
    #                              these; rollback cleans everything else)
    queue_slots: tuple[int, ...] = ()   # queue-checksum: corrupted q slots
    rq_slots: tuple[int, ...] = ()      # queue-checksum: corrupted rq slots


def slab_sums(v: jax.Array, n_slabs: int) -> jax.Array:
    """Per-node-slab checksum of a distributed vector (plain slab sum; the
    push-time and check-time values go through this same helper so a
    mismatch beyond reduction-order noise means the stored copy changed)."""
    return v.reshape(n_slabs, -1).sum(axis=1)


# --------------------------------------------------------------------------- #
# injection
# --------------------------------------------------------------------------- #
def _uint_dtype(dtype) -> tuple[object, int]:
    itemsize = jnp.dtype(dtype).itemsize
    return {8: jnp.uint64, 4: jnp.uint32, 2: jnp.uint16}[itemsize], \
        itemsize * 8

def _flip(v: jax.Array, idx: np.ndarray, bit: int) -> jax.Array:
    """XOR bit ``bit`` of the entries at flat indices ``idx``. Elementwise
    on the (possibly sharded) array — under the mesh each device flips only
    the entries its own shard holds."""
    ut, nbits = _uint_dtype(v.dtype)
    iv = jax.lax.bitcast_convert_type(v, ut)
    mask = jnp.zeros_like(iv).at[jnp.asarray(idx)].set(
        ut(1) << ut(min(bit, nbits - 1)))
    return jax.lax.bitcast_convert_type(iv ^ mask, v.dtype)


def _corrupt_values(v: jax.Array, idx: np.ndarray, ev: SDCEvent) -> jax.Array:
    if ev.kind == "bitflip":
        return _flip(v, idx, ev.bit)
    bump = ev.scale * jnp.max(jnp.abs(v))
    return v.at[jnp.asarray(idx)].add(bump)


def _entry_indices(part: Partition, node: int, ev: SDCEvent) -> np.ndarray:
    """Deterministic corrupted-entry choice inside one node's slab."""
    lo, hi = part.node_rows(node)
    rng = np.random.default_rng((ev.seed, ev.iter, node))
    return rng.integers(lo, hi, size=ev.count)


def corrupt(st, ev: SDCEvent, part: Partition):
    """Apply one SDCEvent to an ESRPState (mid-iteration, after the storage
    prelude — the same injection point fail-stop events use).

    target p/r/x: flip entries of the live vector entering the iteration
    (the corrupted values feed the iteration's own update and silently
    propagate). target z: the carried z is consumed into p = z + β·p_prev
    within the same fused update, so a flip landing on z between compute
    and use corrupts *both* — the injection applies the flip to z and adds
    the identical value delta to p (its additive image through the p
    update). target "queue": flip entries of the newest valid redundancy
    copy — the host-visible ``q`` slot slab, and, on the mesh runtime, the
    listed *holder* devices' physical ``rq`` rows; the live trajectory is
    untouched, only a later recovery read would be poisoned.
    """
    idx = np.concatenate([_entry_indices(part, s, ev) for s in ev.nodes])
    pcg = st.pcg
    if ev.target == "p":
        return st._replace(pcg=pcg._replace(p=_corrupt_values(pcg.p, idx, ev)))
    if ev.target == "r":
        return st._replace(pcg=pcg._replace(r=_corrupt_values(pcg.r, idx, ev)))
    if ev.target == "x":
        return st._replace(pcg=pcg._replace(x=_corrupt_values(pcg.x, idx, ev)))
    if ev.target == "z":
        z_bad = _corrupt_values(pcg.z, idx, ev)
        delta = z_bad - pcg.z
        return st._replace(pcg=pcg._replace(z=z_bad, p=pcg.p + delta))
    # target == "queue"
    tags = np.asarray(st.q_tags)
    valid = np.nonzero(tags >= 0)[0]
    slot = int(valid[-1]) if valid.size else 2
    st = st._replace(q=st.q.at[slot].set(_corrupt_values(st.q[slot], idx, ev)))
    if not isinstance(st.rq, tuple):
        # the physical device-resident copies: flip inside the listed holder
        # devices' (width, bn) queue rows
        w, bn = st.rq.shape[2], st.rq.shape[3]
        for d in ev.nodes:
            rng = np.random.default_rng((ev.seed, ev.iter, d, 1))
            flat = rng.integers(0, w * bn, size=ev.count)
            row = st.rq[slot, d].reshape(-1)
            st = st._replace(rq=st.rq.at[slot, d].set(
                _corrupt_values(row, flat, ev).reshape(w, bn)))
    return st


# --------------------------------------------------------------------------- #
# invariant evaluation
# --------------------------------------------------------------------------- #
@functools.partial(jax.jit, static_argnums=(0, 3))
def _invariant_values(ops, pcg, b, n_slabs):
    """Device computation for one check: the residual-deviation slab norms,
    the orthogonality violation and its slab partials, the z-invariant slab
    norms, and the norms the relative tolerances divide by."""
    d = pcg.r - (b - ops.matvec(pcg.x))
    dev_slab = jnp.linalg.norm(d.reshape(n_slabs, -1), axis=1)
    rp = (pcg.r @ pcg.p if ops.dot is None else ops.dot(pcg.r, pcg.p))
    orth_slab = (pcg.r * (pcg.p - pcg.z)).reshape(n_slabs, -1).sum(axis=1)
    dz = pcg.z - ops.precond(pcg.r)
    z_slab = jnp.linalg.norm(dz.reshape(n_slabs, -1), axis=1)
    return (dev_slab, jnp.abs(rp - pcg.rz), orth_slab, z_slab,
            jnp.linalg.norm(pcg.r), jnp.linalg.norm(pcg.p),
            jnp.linalg.norm(pcg.z))


def _flag_slabs(slab: np.ndarray, frac: float) -> tuple[int, ...]:
    top = np.nanmax(slab) if np.isfinite(slab).any() else np.inf
    if not np.isfinite(top):
        # inf/NaN deviation: every non-finite slab is suspect
        return tuple(int(s) for s in np.nonzero(~np.isfinite(slab))[0])
    return tuple(int(s) for s in np.nonzero(slab >= frac * top)[0])


def _queue_mismatch(stored, arrays, n_slabs, rtol, reducer):
    """Corrupted (slot, node) pairs among the slots with a valid tag."""
    bad = []
    for slot, tag, stored_row in arrays:
        if tag < 0:
            continue
        actual = np.asarray(reducer(slot))
        ref = np.asarray(stored_row)
        scale = np.abs(ref) + 1.0
        mism = ~(np.abs(actual - ref) <= rtol * scale)    # NaN-safe
        for node in np.nonzero(mism)[0]:
            bad.append((slot, int(node)))
    return bad


def run_checks(ops, st, b, part: Partition, bnorm: float,
               policy: SDCPolicy) -> Detection | None:
    """Evaluate every invariant on the current state; return the
    most-localizable fired Detection (queue checksums first — exact
    localization, no rollback needed — then residual, z-invariant,
    orthogonality), or None when all invariants hold."""
    n = part.n_nodes
    q_sums = getattr(st, "q_sums", ())
    rq_sums = getattr(st, "rq_sums", ())

    if not isinstance(q_sums, tuple):
        tags = np.asarray(st.q_tags)
        bad_q = _queue_mismatch(
            q_sums, [(s, int(tags[s]), q_sums[s]) for s in range(3)],
            n, policy.queue_rtol,
            lambda s: slab_sums(st.q[s], n))
        bad_rq = []
        if not isinstance(rq_sums, tuple):
            bad_rq = _queue_mismatch(
                rq_sums, [(s, int(tags[s]), rq_sums[s]) for s in range(3)],
                n, policy.queue_rtol,
                lambda s: st.rq[s].sum(axis=(1, 2)))
        if bad_q or bad_rq:
            nodes = tuple(sorted({d for _, d in bad_q + bad_rq}))
            return Detection(
                detector="queue-checksum", violation=float("nan"),
                tol=policy.queue_rtol, flagged=nodes,
                queue_slots=tuple(sorted({s for s, _ in bad_q})),
                rq_slots=tuple(sorted({s for s, _ in bad_rq})))

    (dev_slab, orth, orth_slab, z_slab, rnorm, pnorm,
     znorm) = jax.device_get(_invariant_values(ops, st.pcg, b, n))
    tiny = np.finfo(np.asarray(bnorm).dtype if hasattr(bnorm, "dtype")
                    else np.float64).tiny

    res_rel = float(np.linalg.norm(dev_slab)) / max(float(bnorm), tiny)
    if not (res_rel <= policy.res_rtol):                   # NaN-safe
        return Detection(detector="residual", violation=res_rel,
                         tol=policy.res_rtol,
                         flagged=_flag_slabs(dev_slab, policy.flag_frac))

    z_rel = float(np.linalg.norm(z_slab)) / max(float(znorm), tiny)
    if not (z_rel <= policy.z_rtol):
        return Detection(detector="z-invariant", violation=z_rel,
                         tol=policy.z_rtol,
                         flagged=_flag_slabs(z_slab, policy.flag_frac))

    denom = float(rnorm) * float(pnorm)
    orth_rel = float(orth) / max(denom, tiny)
    if not np.isfinite(denom):
        # ‖r‖·‖p‖ overflowed (r passed the residual check, so this is ‖p‖):
        # a clean finite direction cannot — the ratio that would hide the
        # violation (huge/inf → 0) is an overflow artifact, not a pass
        orth_rel = float("inf")
    if not (orth_rel <= policy.orth_rtol):
        # a corrupted direction contaminates every slab through the global
        # α/β scalars — no sound per-slab localization exists. Flag the slab
        # with the largest |rᵀ(p − z)| partial (the corrupted entries
        # dominate it for the flips above the detection floor); repair
        # correctness never depends on the guess, because the rollback
        # discards ALL live vectors and rebuilds from clean storage.
        a = np.abs(orth_slab)
        a = np.where(np.isfinite(a), a, np.inf)
        return Detection(detector="orthogonality", violation=orth_rel,
                         tol=policy.orth_rtol,
                         flagged=(int(np.argmax(a)),))
    return None
