"""In-memory buddy checkpoint-restart (IMCR) — paper §3.1.

Every T iterations each node sends a complete copy of its local parts of all
dynamic vectors (x, r, z, p) plus the replicated scalars to its φ buddy
neighbours (same neighbour function as ASpMV, Eq. 1). Recovery: replacements
fetch their parts from a buddy; survivors roll back to their own local copy.
Unlike ESR/ESRP this introduces a brand-new round of communication per
checkpoint (4 full local vectors × φ buddies) instead of piggybacking on the
SpMV — the communication-volume asymmetry the paper highlights.
"""
from __future__ import annotations

import functools
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.pcg import PCGState, pcg_init, pcg_step


class IMCRState(NamedTuple):
    pcg: PCGState
    ck_x: jax.Array
    ck_r: jax.Array
    ck_z: jax.Array
    ck_p: jax.Array
    ck_beta: jax.Array
    ck_rz: jax.Array
    ck_tag: jax.Array      # iteration of the checkpoint, -1 = none
    # simulated buddy traffic: checksum of the rolled (sent) buffers keeps the
    # data movement alive in the compiled graph so failure-free timing on this
    # single-device simulator includes the checkpoint sends.
    traffic: jax.Array


def imcr_init(matvec: Callable, precond: Callable, b: jax.Array,
              x0: jax.Array | None = None) -> IMCRState:
    pcg = pcg_init(matvec, precond, b, x0)
    z = jnp.zeros_like(b)
    zero = jnp.zeros((), b.dtype)
    return IMCRState(pcg=pcg, ck_x=z, ck_r=z, ck_z=z, ck_p=z,
                     ck_beta=zero, ck_rz=zero,
                     ck_tag=jnp.full((), -1, jnp.int32), traffic=zero)


def checkpoint(st: IMCRState, phi: int, rows_per_node: int) -> IMCRState:
    """Push local state copies to φ buddies (simulated as ring rolls)."""
    p = st.pcg
    traffic = st.traffic
    stacked = jnp.stack([p.x, p.r, p.z, p.p])
    for k in range(1, phi + 1):
        shift = ((k + 1) // 2) * rows_per_node * (1 if k % 2 else -1)
        traffic = traffic + jnp.sum(jnp.roll(stacked, shift, axis=1)) * 0.0
    return st._replace(ck_x=p.x, ck_r=p.r, ck_z=p.z, ck_p=p.p,
                       ck_beta=p.beta, ck_rz=p.rz, ck_tag=p.j,
                       traffic=traffic)


def imcr_step(st: IMCRState, matvec: Callable, precond: Callable, T: int,
              phi: int, rows_per_node: int) -> IMCRState:
    j = st.pcg.j
    do_ck = (j % T == 0) & (j > 2)
    st = jax.tree.map(lambda a, b: jnp.where(do_ck, a, b),
                      checkpoint(st, phi, rows_per_node), st)
    return st._replace(pcg=pcg_step(st.pcg, matvec, precond))


@functools.partial(jax.jit, static_argnums=(1, 2, 3, 4, 5, 6))
def run_chunk(st: IMCRState, matvec: Callable, precond: Callable, T: int,
              phi: int, rows_per_node: int, n_iters: int):
    def body(s, _):
        s = imcr_step(s, matvec, precond, T, phi, rows_per_node)
        return s, jnp.linalg.norm(s.pcg.r)

    return jax.lax.scan(body, st, None, length=n_iters)


def recover(st: IMCRState) -> PCGState:
    """Roll everyone back to the checkpoint (replacements fetch from buddies,
    survivors restore their own copy — in the simulator both are the stored
    full vectors, valid because buddies of the ≤ φ failed nodes survive)."""
    return PCGState(x=st.ck_x, r=st.ck_r, z=st.ck_z, p=st.ck_p,
                    rz=st.ck_rz, beta=st.ck_beta, j=st.ck_tag)
