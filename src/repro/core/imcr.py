"""In-memory buddy checkpoint-restart (IMCR) — paper §3.1.

Every T iterations each node sends a complete copy of its local parts of all
dynamic vectors (x, r, z, p) plus the replicated scalars to its φ buddy
neighbours (same neighbour function as ASpMV, Eq. 1). Recovery: replacements
fetch their parts from a buddy; survivors roll back to their own local copy.
Unlike ESR/ESRP this introduces a brand-new round of communication per
checkpoint (4 full local vectors × φ buddies) instead of piggybacking on the
SpMV — the communication-volume asymmetry the paper highlights.

The checkpoint copy (4 full vectors + scalars) is ``lax.cond``-gated on the
schedule, like ESRP's queue push: on the T-1 non-checkpoint iterations of
each period nothing is copied, and the numeric update runs through the same
``SolverOps`` bundle as ESRP/plain PCG.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.analysis.marks import sync_free
from repro.core.ops import SolverOps
from repro.core.pcg import (METRIC_FIELDS, PCGState, _vec_norm, freeze_pcg,
                            iteration_metrics, pcg_init, pcg_iterate_ops,
                            scan_with_convergence_freeze)


class IMCRState(NamedTuple):
    pcg: PCGState
    ck_x: jax.Array
    ck_r: jax.Array
    ck_z: jax.Array
    ck_p: jax.Array
    ck_beta: jax.Array
    ck_rz: jax.Array
    ck_tag: jax.Array      # iteration of the checkpoint, -1 = none
    # simulated buddy traffic: checksum of the rolled (sent) buffers keeps the
    # data movement alive in the compiled graph so failure-free timing on this
    # single-device simulator includes the checkpoint sends.
    traffic: jax.Array


def imcr_init(matvec, precond, b: jax.Array,
              x0: jax.Array | None = None, dot=None) -> IMCRState:
    pcg = pcg_init(matvec, precond, b, x0, dot)
    z = jnp.zeros_like(b)
    zero = jnp.zeros(b.shape[:-1], b.dtype)   # () unbatched, (B,) batched
    return IMCRState(pcg=pcg, ck_x=z, ck_r=z, ck_z=z, ck_p=z,
                     ck_beta=zero, ck_rz=zero,
                     ck_tag=jnp.full((), -1, jnp.int32),
                     traffic=jnp.zeros((), b.dtype))


def checkpoint(st: IMCRState, phi: int, rows_per_node: int) -> IMCRState:
    """Push local state copies to φ buddies (simulated as ring rolls)."""
    p = st.pcg
    traffic = st.traffic
    stacked = jnp.stack([p.x, p.r, p.z, p.p])
    for k in range(1, phi + 1):
        shift = ((k + 1) // 2) * rows_per_node * (1 if k % 2 else -1)
        # roll along the row axis (last): batched stacks are (4, B, M)
        traffic = traffic + jnp.sum(
            jnp.roll(stacked, shift, axis=stacked.ndim - 1)) * 0.0
    return st._replace(ck_x=p.x, ck_r=p.r, ck_z=p.z, ck_p=p.p,
                       ck_beta=p.beta, ck_rz=p.rz, ck_tag=p.j,
                       traffic=traffic)


def member_select(old: IMCRState, new: IMCRState,
                  done: jax.Array) -> IMCRState:
    """Per-member freeze for the batched state (see esrp.member_select):
    converged members keep their pcg leaves and checkpoint copies; the
    shared iteration counter / checkpoint tag / simulated traffic follow
    the global schedule."""
    col = done[:, None]
    return new._replace(
        pcg=freeze_pcg(old.pcg, new.pcg, done),
        ck_x=jnp.where(col, old.ck_x, new.ck_x),
        ck_r=jnp.where(col, old.ck_r, new.ck_r),
        ck_z=jnp.where(col, old.ck_z, new.ck_z),
        ck_p=jnp.where(col, old.ck_p, new.ck_p),
        ck_beta=jnp.where(done, old.ck_beta, new.ck_beta),
        ck_rz=jnp.where(done, old.ck_rz, new.ck_rz))


def imcr_step(st: IMCRState, ops: SolverOps, T: int, phi: int,
              rows_per_node: int, gated: bool = True) -> IMCRState:
    j = st.pcg.j
    do_ck = (j % T == 0) & (j > 2)
    if gated:
        st = jax.lax.cond(do_ck,
                          lambda s: checkpoint(s, phi, rows_per_node),
                          lambda s: s, st)
    else:
        st = jax.tree.map(lambda a, b: jnp.where(do_ck, a, b),
                          checkpoint(st, phi, rows_per_node), st)
    return st._replace(pcg=pcg_iterate_ops(st.pcg, ops))


@functools.partial(jax.jit, static_argnums=(1, 2, 3, 4, 5, 7, 8))
@sync_free
def run_chunk(st: IMCRState, ops: SolverOps, T: int, phi: int,
              rows_per_node: int, n_iters: int,
              thresh: jax.Array | None = None, gated: bool = True,
              metrics: bool = False):
    """Run n_iters IMCR iterations, recording ||r|| after each. Same
    convergence-freeze protocol as esrp.run_chunk (shared via
    ``pcg.scan_with_convergence_freeze``): once the carried ||r|| drops
    below ``thresh`` the remaining iterations pass the state through, so
    the driver never re-runs the final chunk.

    ``metrics`` (static) arms the same on-device metrics ring as
    esrp.run_chunk — here the "push" column records the buddy-checkpoint
    schedule (j % T == 0, j > 2) and "star" is always 0 (IMCR has no
    starred-locals anchor)."""

    def step(s):
        s2 = imcr_step(s, ops, T, phi, rows_per_node, gated)
        rnorm = _vec_norm(s2.pcg.r)
        if not metrics:
            return s2, rnorm
        do_ck = (s.pcg.j % T == 0) & (s.pcg.j > 2)
        return s2, rnorm, iteration_metrics(s2.pcg, do_ck,
                                            jnp.zeros((), bool))

    aux0 = (jnp.zeros((len(METRIC_FIELDS),) + st.pcg.rz.shape,
                      st.pcg.rz.dtype) if metrics else None)
    batched = st.pcg.x.ndim == 2
    return scan_with_convergence_freeze(
        st, step, _vec_norm(st.pcg.r), n_iters, thresh, aux0,
        freeze=member_select if batched else None)


def check_survivable(failed: list[int], phi: int, n_nodes: int) -> None:
    """Per-event recoverability check (buddy-copy survival analysis).

    Each node ships its checkpoint to its φ ring buddies (Eq. 1 neighbour
    function), so a failed node is recoverable iff at least one of its φ
    buddies survives. For |failed| ≤ φ that is automatic: killing node s
    *and* all φ of its buddies takes φ+1 failures. |failed| > φ may still
    be survivable for a lucky (spread-out) failed set — mirrored on the
    ESRP side by ``RedundancyPlan.survives`` — so the check walks the
    actual buddy sets instead of hard-failing on the count.
    """
    from repro.sparse.partition import neighbors

    failed_set = set(failed)
    for s in failed:
        if not set(neighbors(s, phi, n_nodes)) - failed_set:
            raise RuntimeError(
                f"node {s} and all phi={phi} of its checkpoint buddies "
                f"failed together ({sorted(failed_set)}) — no surviving "
                f"copy to fetch from")


def recover(st: IMCRState) -> PCGState:
    """Roll everyone back to the checkpoint (replacements fetch from buddies,
    survivors restore their own copy — in the simulator both are the stored
    full vectors, valid because buddies of the ≤ φ failed nodes survive)."""
    return PCGState(x=st.ck_x, r=st.ck_r, z=st.ck_z, p=st.ck_p,
                    rz=st.ck_rz, beta=st.ck_beta, j=st.ck_tag)
