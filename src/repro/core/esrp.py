"""ESR with periodic storage (ESRP) — paper §3, Alg. 3.

The storage stage runs the augmented SpMV in two consecutive iterations every
T iterations (j ≡ 0 and j ≡ 1 mod T, j > 2) and pushes the current search
direction into a queue of THREE redundant copies, so that a failure landing
after the first push of a stage still finds two *consecutive* directions from
the previous stage (Fig. 1). At the second push each node also duplicates its
local x, r, z, p and the replicated β — the rollback anchor for survivors.

Implementation notes (vs. the paper listing):
  * The SpMV and ASpMV produce the *same numbers*; ASpMV only adds redundancy
    traffic. We therefore always compute q = A·p once and gate only the
    bookkeeping on the schedule — the failure-free trajectory is bit-identical
    to plain PCG (the paper's trajectory-identity property, tested).
  * Storage bookkeeping is ``jax.lax.cond``-gated: the (3, M) redundancy
    queue rotation and the starred-locals duplication execute *only* on
    storage iterations. (The seed's ``jnp.where`` over the whole state tree
    copied the queue every iteration — pure overhead on the T-2 non-storage
    iterations of each period. ``gated=False`` keeps that path for the
    before/after microbenchmark in benchmarks/run.py.)
  * β capture: the paper stages β through β** (line 6) and commits at line 10.
    Entering the *second* storage iteration j₀+1, the live β variable already
    holds β^(j₀) — exactly the value Alg. 2 needs to reconstruct iteration
    j₀+1 — so we capture β* := β directly at the second push. This is
    equivalent to the β**/β* two-phase dance (the paper needs it only because
    its listing captures *before* the iteration-j₀ β update) and is covered by
    the mid-stage failure tests.
  * With T = 1 both schedule conditions hold every iteration; only the push
    branch runs and recovery reads the *live* state — that is exactly ESR
    (paper §3: "For T = 1 ... corresponds to regular ESR").
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.analysis.marks import sync_free
from repro.core.ops import SolverOps
from repro.core.pcg import (METRIC_FIELDS, PCGState, _vec_norm, freeze_pcg,
                            iteration_metrics, pcg_init, pcg_iterate_ops,
                            scan_with_convergence_freeze,
                            scan_with_halt_guard)


class ESRPState(NamedTuple):
    pcg: PCGState
    q: jax.Array          # (3, M) redundant copies of p (newest = slot 2).
    #                       Block-row placement: each node's rows are its OWN
    #                       pushed history (survivor anchor); the copies a
    #                       failure recovers from live in ``rq``.
    q_tags: jax.Array     # (3,) int32 iteration of each copy, -1 = empty
    x_s: jax.Array        # starred locals (rollback anchor), iteration j*
    r_s: jax.Array
    z_s: jax.Array
    p_s: jax.Array
    beta_s: jax.Array     # β* = β^(j*-1)
    rz_s: jax.Array       # r*ᵀz* (avoids a recompute on rollback)
    star_tag: jax.Array   # j*, -1 = none
    rq: jax.Array | tuple = ()   # device-resident redundancy-queue copies:
    #                       (3, n_nodes, width, bn), node axis sharded over
    #                       the mesh — row d holds the tile values node d
    #                       received at each storage push (paper §2.2.1's
    #                       queue entry on the designated neighbours). Empty
    #                       tuple on the single-device simulator. Tags are
    #                       shared with ``q_tags``.
    q_sums: jax.Array | tuple = ()   # (3, n_slabs) per-node-slab checksums
    #                       of each q copy, written at push time under the
    #                       same lax.cond — the SDC detector and the
    #                       recovery read recompute and compare (a mismatch
    #                       means the stored copy was corrupted after the
    #                       push). Empty tuple = checksums disabled.
    rq_sums: jax.Array | tuple = ()  # (3, n_nodes) per-holder-device
    #                       checksums of each rq entry (same protocol).


def esrp_init(matvec, precond, b: jax.Array,
              x0: jax.Array | None = None,
              dot=None, n_slabs: int = 0) -> ESRPState:
    """n_slabs > 0 enables the per-push queue checksums (one slab sum per
    node); 0 keeps them off (legacy callers, microbenchmarks)."""
    pcg = pcg_init(matvec, precond, b, x0, dot)
    z = jnp.zeros_like(b)
    return ESRPState(
        pcg=pcg,
        q=jnp.zeros((3,) + b.shape, b.dtype),
        q_tags=jnp.full((3,), -1, jnp.int32),
        x_s=z, r_s=z, z_s=z, p_s=z,
        beta_s=jnp.zeros(b.shape[:-1], b.dtype),
        rz_s=jnp.zeros(b.shape[:-1], b.dtype),
        star_tag=jnp.full((), -1, jnp.int32),
        # checksum rows follow the batch layout of b: (3, n_slabs) for (M,)
        # rhs, (3, B, n_slabs) for (B, M) — one slab-sum row per member
        q_sums=(jnp.zeros((3,) + b.shape[:-1] + (n_slabs,), b.dtype)
                if n_slabs > 0 else ()))


def storage_flags(j: jax.Array, T: int):
    """(push?, star?) for iteration j — Alg. 3 lines 4/7 schedule."""
    if T == 1:                      # ESR: push every iteration, no stars
        return j > 2, jnp.zeros((), bool)
    push1 = (j % T == 0) & (j > 2)
    push2 = ((j - 1) % T == 0) & (j > 2)
    return push1 | push2, push2


def push_queue(st: ESRPState, tag: jax.Array, push=None) -> ESRPState:
    """ASpMV side effect: rotate the queue-of-3, newest copy = current p.

    ``push`` (comm.shard.redundancy_queue) is the *physical* redundancy
    send: it ppermutes/retains the current p's column tiles onto their
    designated holder devices and the received payload rotates into ``rq``
    — the device-resident queue entry recovery reads on the mesh."""
    q = jnp.concatenate([st.q[1:], st.pcg.p[None]], axis=0)
    tags = jnp.concatenate([st.q_tags[1:], tag[None]])
    st = st._replace(q=q, q_tags=tags)
    if not isinstance(st.q_sums, tuple):
        n_slabs = st.q_sums.shape[-1]
        p = st.pcg.p
        s = p.reshape(p.shape[:-1] + (n_slabs, -1)).sum(axis=-1)
        st = st._replace(
            q_sums=jnp.concatenate([st.q_sums[1:], s[None]], axis=0))
    if push is not None:
        entry = push(st.pcg.p)        # (n_nodes, width, bn), (B, ...) batched
        st = st._replace(rq=jnp.concatenate([st.rq[1:], entry[None]], axis=0))
        if not isinstance(st.rq_sums, tuple):
            es = entry.sum(axis=(-2, -1))
            st = st._replace(
                rq_sums=jnp.concatenate([st.rq_sums[1:], es[None]], axis=0))
    return st


def capture_stars(st: ESRPState, tag: jax.Array) -> ESRPState:
    """Second storage iteration: duplicate locals (Alg. 3 lines 9-10).

    Entering iteration j the live fields are x^(j), r^(j), z^(j), p^(j) and
    beta = β^(j-1) — precisely the reconstruction point's requirements.
    """
    p = st.pcg
    return st._replace(x_s=p.x, r_s=p.r, z_s=p.z, p_s=p.p,
                       beta_s=p.beta, rz_s=p.rz, star_tag=tag)


def member_select(old: ESRPState, new: ESRPState,
                  done: jax.Array) -> ESRPState:
    """Per-member freeze for the batched state: members with done=True keep
    every per-member leaf (pcg vectors/scalars, their queue rows, starred
    locals, checksums) from ``old``; shared bookkeeping — the iteration
    counter, queue tags, star tag — always advances with the global
    schedule. This is the ``freeze`` callback the batched chunk scan and
    the driver's converged-member restore both use."""
    col = done[:, None]
    st = new._replace(
        pcg=freeze_pcg(old.pcg, new.pcg, done),
        q=jnp.where(done[None, :, None], old.q, new.q),
        x_s=jnp.where(col, old.x_s, new.x_s),
        r_s=jnp.where(col, old.r_s, new.r_s),
        z_s=jnp.where(col, old.z_s, new.z_s),
        p_s=jnp.where(col, old.p_s, new.p_s),
        beta_s=jnp.where(done, old.beta_s, new.beta_s),
        rz_s=jnp.where(done, old.rz_s, new.rz_s))
    if not isinstance(new.rq, tuple):
        mask = done.reshape((1, -1) + (1,) * (new.rq.ndim - 2))
        st = st._replace(rq=jnp.where(mask, old.rq, new.rq))
    if not isinstance(new.q_sums, tuple):
        st = st._replace(
            q_sums=jnp.where(done[None, :, None], old.q_sums, new.q_sums))
    if not isinstance(new.rq_sums, tuple):
        st = st._replace(
            rq_sums=jnp.where(done[None, :, None], old.rq_sums, new.rq_sums))
    return st


def esrp_prelude(st: ESRPState, T: int, gated: bool = True,
                 push=None) -> ESRPState:
    """The storage bookkeeping of iteration j (everything that happens at the
    (A)SpMV point, *before* the numeric update). Split out so the failure
    driver can inject a failure exactly mid-iteration, after the push.

    gated=True executes the push/star branches under ``lax.cond`` — on the
    non-storage iterations of the period nothing is copied *and no
    redundancy traffic moves* (``push``'s ppermutes run only on storage
    iterations, like the paper's ASpMV swap-in). gated=False is the seed's
    ``jnp.where``-over-the-state-tree (copies the queue every iteration;
    kept for the microbenchmark comparison).
    """
    j = st.pcg.j
    do_push, star = storage_flags(j, T)
    if gated:
        st = jax.lax.cond(do_push, lambda s: push_queue(s, j, push),
                          lambda s: s, st)
        st = jax.lax.cond(star, lambda s: capture_stars(s, j), lambda s: s,
                          st)
    else:
        st = jax.tree.map(
            lambda a, b: jnp.where(do_push, a, b), push_queue(st, j, push),
            st)
        st = jax.tree.map(
            lambda a, b: jnp.where(star, a, b), capture_stars(st, j), st)
    return st


def numeric_step(pcg: PCGState, ops: SolverOps,
                 b: jax.Array | None = None, rr_every: int = 0,
                 gated: bool = True) -> PCGState:
    """The PCG update plus the residual-replacement gate — everything of an
    ESRP iteration *except* the storage prelude.

    rr_every > 0 enables *residual replacement* [van der Vorst & Ye '00 —
    the drift mechanism the paper's Eq. 2 measures]: every rr_every
    iterations the recursive residual is replaced by the true b - A x (and
    z, rz, p's conjugation base refresh accordingly), keeping the Eq. 2
    drift near zero at the cost of one extra SpMV per period. Extension
    beyond the paper (its §"Accuracy of the experiments" discusses but does
    not implement replacement). With gated=True the replacement SpMV +
    precond run under ``lax.cond`` — no extra SpMV executes on the other
    rr_every - 1 iterations of each period.

    This is also the driver's post-recovery resume step: re-running the
    reconstruction-point iteration must skip its storage prelude (the push
    already happened pre-failure) but NOT the replacement gate — a bare
    ``pcg_iterate_ops`` would silently drop a replacement landing on the
    resume iteration and fork the post-recovery trajectory off the
    failure-free one.
    """
    pcg = pcg_iterate_ops(pcg, ops)
    if rr_every > 0 and b is not None:
        do = (pcg.j % rr_every == 0) & (pcg.j > 0)

        def replace(s: PCGState) -> PCGState:
            r_true = b - ops.matvec(s.x)
            z_true = ops.precond(r_true)
            rz = (r_true @ z_true if ops.dot is None
                  else ops.dot(r_true, z_true))
            return s._replace(r=r_true, z=z_true, rz=rz)

        if gated:
            pcg = jax.lax.cond(do, replace, lambda s: s, pcg)
        else:
            pcg = jax.tree.map(lambda a_, b_: jnp.where(do, a_, b_),
                               replace(pcg), pcg)
    return pcg


def esrp_step(st: ESRPState, ops: SolverOps, T: int,
              b: jax.Array | None = None, rr_every: int = 0,
              gated: bool = True, push=None) -> ESRPState:
    """One full ESRP iteration: bookkeeping + the PCG update (Alg. 3 body).
    See ``numeric_step`` for the residual-replacement semantics."""
    st = esrp_prelude(st, T, gated, push)
    return st._replace(pcg=numeric_step(st.pcg, ops, b, rr_every, gated))


@functools.partial(jax.jit, static_argnums=(1, 2, 3, 5, 6, 8, 9, 10))
@sync_free
def run_chunk(st: ESRPState, ops: SolverOps, T: int, n_iters: int,
              thresh: jax.Array | None = None,
              rr_every: int = 0, gated: bool = True,
              b: jax.Array | None = None, push=None,
              metrics: bool = False, sdc_check=None):
    """Run n_iters ESRP iterations, recording ||r|| after each (the paper
    checks convergence every iteration; the driver scans the record).

    ``thresh`` (dynamic) arms the sync-free convergence protocol (see
    ``pcg.scan_with_convergence_freeze``): the driver never has to re-run a
    chunk to land exactly on the convergence iteration — the returned state
    *is* the state at first convergence — and can overlap the norm-record
    readback of one chunk with the dispatch of the next. thresh=None runs
    all n_iters unconditionally.

    ``metrics`` (static, obs=on) extends the scan record with the on-device
    metrics ring: the return becomes (state, (norms, aux)) with one
    ``pcg.METRIC_FIELDS`` row per iteration (the executed iteration's
    storage flags + the post-iteration rz / orthogonality residual), read
    back together with the norm record. metrics=False compiles to exactly
    the pre-telemetry jaxpr (tested).

    ``sdc_check`` (static, a hashable ``sdc.SDCPolicy``) arms the on-device
    invariant guard: at every check boundary (the cadence, plus every
    storage iteration — the check-before-store protocol) the entering state
    is verified by ``sdc.device_violation`` inside the scan; a violation
    halts the chunk *at* that boundary, before the boundary iteration's
    storage prelude could commit corrupted state. The record gains a
    per-iteration halted flag ((norms, halted) / (norms, aux, halted)) and
    detection latency is bounded by the check cadence regardless of chunk
    length. sdc_check=None keeps the exact guard-free scan (the
    jaxpr-identity tests compare against this path).
    """

    def step(s):
        s2 = esrp_step(s, ops, T, b=b, rr_every=rr_every, gated=gated,
                       push=push)
        rnorm = _vec_norm(s2.pcg.r)
        if not metrics:
            return s2, rnorm
        do_push, star = storage_flags(s.pcg.j, T)
        return s2, rnorm, iteration_metrics(s2.pcg, do_push, star)

    aux0 = (jnp.zeros((len(METRIC_FIELDS),) + st.pcg.rz.shape,
                      st.pcg.rz.dtype) if metrics else None)
    batched = st.pcg.x.ndim == 2
    freeze = member_select if batched else None
    if sdc_check is None:
        return scan_with_convergence_freeze(
            st, step, _vec_norm(st.pcg.r), n_iters, thresh, aux0,
            freeze=freeze)

    from repro.core import sdc as sdc_mod

    def guard(s, rnorm):
        j = s.pcg.j
        at = (j > 0) & (j % sdc_check.check_every == 0)
        if T < (1 << 29):
            # ESRP storage iterations are check boundaries too (the driver's
            # check-before-store protocol); the "none" runner's T = 1 << 30
            # sentinel stores nothing, so only the cadence applies there
            at = at | ((j > 2) & ((j % T == 0) | ((j - 1) % T == 0)))
        th = -jnp.inf if thresh is None else thresh
        return jax.lax.cond(
            at,
            lambda: sdc_mod.device_violation(ops, s, b, th, sdc_check,
                                             rnorm=rnorm),
            lambda: jnp.zeros((), bool))

    return scan_with_halt_guard(
        st, step, _vec_norm(st.pcg.r), n_iters, thresh, aux0,
        freeze=freeze, guard=guard)


def recovery_point(st: ESRPState, T: int):
    """Which iteration can be reconstructed from the queue?

    Returns (target_iter, prev_slot, curr_slot); target -1 if unrecoverable
    (failure before the first completed storage stage — driver restarts).
    Newest consecutive pair wins: (1,2) if tags[2] == tags[1]+1 else (0,1)
    if tags[1] == tags[0]+1 (the Fig. 1 queue states, incl. the mid-stage
    case where the newest copy has no consecutive partner yet).
    """
    t = [int(x) for x in st.q_tags]
    if t[2] >= 0 and t[2] == t[1] + 1:
        return t[2], 1, 2
    if t[1] >= 0 and t[1] == t[0] + 1:
        return t[1], 0, 1
    return -1, -1, -1
