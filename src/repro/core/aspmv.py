"""Augmented sparse matrix-vector product (ASpMV) redundancy planning.

Implements §2.2/§2.2.1 of the paper at column-tile granularity (the TPU
adaptation: ownership and sends are per (bn)-wide tile, matching the Block-ELL
layout and the ``ppermute`` halo exchange; see DESIGN.md §3).

Definitions (paper notation, tile-granular):
  I_{s,l}  — tiles owned by node s whose data node l needs to compute A·p
             (derived from the sparsity structure: l's rows reference them).
  m(t)     — multiplicity: #nodes that tile t is sent to naturally.
  d_{s,k}  — designated redundancy destinations, Eq. (1) (ring neighbours).
  g(t)     — #designated destinations that already receive t naturally.
  R^c_{s,k}— extra sends: t goes to d_{s,k} iff t ∉ I_{s,d_{s,k}} and
             m(t) − g(t) ≤ φ − k.

ERRATUM NOTE: the paper prints the condition as strict ``m−g < φ−k``; for
φ = 1, k = 1 that sends *nothing* (m−g ≥ 0 always), contradicting §2.2's own
prose ("entries that would not have been sent to any node ... are transferred
to a neighbor anyway"). The intended non-strict form ``m−g ≤ φ−k`` restores
the φ+1-copies invariant, which ``verify`` checks and the property tests
sweep.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.sparse.blockell import BlockEll
from repro.sparse.partition import Partition, neighbors


@dataclasses.dataclass
class RedundancyPlan:
    """Static ASpMV plan for one (matrix, partition, φ).

    holders:    (col_tiles, n_nodes) bool — holders[t, n] ⇔ node n holds a
                copy of tile t after one ASpMV (owner included).
    extra_sends:list over nodes s of list over k (1..φ) of np arrays of tile
                ids pushed to d_{s,k} beyond the natural SpMV traffic.
    natural_bytes / augmented_bytes: per-ASpMV communication volume (element
                count × itemsize) — the overhead the paper discusses in §2.2.1.
    """

    part: Partition
    phi: int
    holders: np.ndarray
    extra_sends: list[list[np.ndarray]]
    natural_tiles_sent: int
    extra_tiles_sent: int

    @property
    def n_nodes(self) -> int:
        return self.part.n_nodes

    def bytes_per_aspmv(self, itemsize: int = 8) -> tuple[int, int]:
        per_tile = self.part.bn * itemsize
        return (self.natural_tiles_sent * per_tile,
                (self.natural_tiles_sent + self.extra_tiles_sent) * per_tile)

    def verify(self) -> None:
        """φ+1-copies invariant (paper §2.2.1, last paragraph)."""
        n_copies = self.holders.sum(axis=1)
        if int(n_copies.min()) < self.phi + 1:
            t = int(np.argmin(n_copies))
            raise AssertionError(
                f"tile {t} has {int(n_copies[t])} copies < phi+1={self.phi + 1}")

    def survives(self, failed: np.ndarray) -> np.ndarray:
        """(col_tiles,) bool — a redundant copy of tile t survives iff some
        holder is not in the failed set."""
        alive = np.ones(self.n_nodes, bool)
        alive[np.asarray(failed)] = False
        return (self.holders & alive[None, :]).any(axis=1)

    def copy_sources(self, failed: list[int],
                     valid: np.ndarray | None = None
                     ) -> tuple[np.ndarray, np.ndarray]:
        """Device-resident copy sourcing: for every column tile owned by a
        failed node, pick the surviving holder whose *physical* queue shard
        the replacement will read (preferring the designated ring
        neighbours d_{s,k}, the paper's recovery senders).

        ``valid[d] = False`` marks devices whose held copies are stale —
        zeroed by an earlier failure event and not yet refreshed by a
        storage push (``ShardedFailureRuntime`` tracks this). That is
        exactly the gap between the static plan's ``check_event`` and
        surviving *device state*: a scenario the plan calls survivable can
        still be physically unrecoverable until the next push.

        Returns (tiles, sources) — ascending failed tiles and the device
        each copy is read from; raises when a tile has no live fresh copy.
        """
        from repro.sparse.partition import neighbors

        n = self.n_nodes
        ok = np.ones(n, bool)
        ok[np.asarray(list(failed))] = False
        if valid is not None:
            ok &= np.asarray(valid, bool)
        tiles = np.concatenate(
            [np.arange(*self.part.node_col_tiles(s)) for s in sorted(failed)])
        src = np.empty(tiles.size, np.int32)
        for i, t in enumerate(tiles):
            owner = int(self.part.owner_of_col_tile(t))
            cands = np.nonzero(self.holders[t] & ok)[0]
            cands = cands[cands != owner]
            if cands.size == 0:
                holders = np.nonzero(self.holders[t])[0].tolist()
                raise RuntimeError(
                    f"tile {t} (owner {owner}): every physical redundancy "
                    f"copy is dead or stale — holders {holders}, failed "
                    f"{sorted(failed)}; a copy wiped by an earlier event "
                    f"only revives at the next storage push")
            des = [d for d in neighbors(owner, self.phi, n) if d in cands]
            src[i] = des[0] if des else int(cands[0])
        return tiles, src

    def check_event(self, failed: list[int]) -> None:
        """Per-event φ-copy survival analysis: every tile owned by a failed
        node must keep at least one copy on a survivor, or the event is
        unrecoverable (Alg. 2 has no p^(j-1)/p^(j) to read).

        The φ+1-copies invariant guarantees this for |failed| ≤ φ; larger
        failed sets may *still* survive when the holders happen to be spread
        out (arXiv:1907.13077 §4's observation) — so the check is against
        the actual holder topology, not the count.
        """
        tiles = np.unique(np.concatenate(
            [np.arange(*self.part.node_col_tiles(s)) for s in failed]))
        alive = self.survives(np.asarray(failed))[tiles]
        if not alive.all():
            lost = tiles[~alive]
            raise RuntimeError(
                f"{len(failed)} simultaneous failures {sorted(failed)} "
                f"exceed the phi={self.phi} redundancy: "
                f"{lost.size} tile(s) lost all copies (first: {lost[:4]})")


def build_plan(a: BlockEll, part: Partition, phi: int) -> RedundancyPlan:
    if phi >= part.n_nodes:
        raise ValueError(f"phi={phi} must be < n_nodes={part.n_nodes}")
    ct = part.col_tiles
    n = part.n_nodes

    # receives[t, l]: node l needs tile t for its local rows (I_{s,l} union).
    receives = np.zeros((ct, n), bool)
    for l, tiles in enumerate(a.needed_col_tiles(part)):
        receives[tiles, l] = True
    owner = part.owner_of_col_tile(np.arange(ct))
    receives[np.arange(ct), owner] = False          # I_{s,s} := ∅ (paper §2.2.1)

    m = receives.sum(axis=1)                        # multiplicity m(t)
    holders = receives.copy()
    holders[np.arange(ct), owner] = True            # owner's own copy

    extra_sends: list[list[np.ndarray]] = []
    extra_total = 0
    for s in range(n):
        lo, hi = part.node_col_tiles(s)
        tiles = np.arange(lo, hi)
        dests = neighbors(s, phi, n)
        g = np.zeros(hi - lo, np.int64)
        for d in set(dests):
            g += receives[tiles, d]
        per_k = []
        for k in range(1, phi + 1):
            d = dests[k - 1]
            sel = (~receives[tiles, d]) & (d != s) & (m[tiles] - g <= phi - k)
            extra = tiles[sel]
            per_k.append(extra)
            holders[extra, d] = True
            extra_total += extra.size
        extra_sends.append(per_k)

    plan = RedundancyPlan(part=part, phi=phi, holders=holders,
                          extra_sends=extra_sends,
                          natural_tiles_sent=int(receives.sum()),
                          extra_tiles_sent=extra_total)
    plan.verify()
    return plan


def shrink_plan(plan: RedundancyPlan, a_new: BlockEll,
                part_new) -> RedundancyPlan:
    """Elastic continuation: rebuild the redundancy plan for the shrunk
    partition, clamping φ below the new node count (φ copies need φ + 1
    distinct holders; a 2-node mesh can sustain at most φ = 1)."""
    phi = min(plan.phi, part_new.n_nodes - 1)
    return build_plan(a_new, part_new, phi)
