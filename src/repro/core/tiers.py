"""Storage tiers behind the redundancy queue (cost-model layer).

The paper's queue-of-3 lives on the neighbour nodes' memory — the ASpMV
piggyback makes its push nearly free and its recovery read run at
interconnect speed. The NVRAM recovery literature (arXiv:2204.11584) shows
the interesting axis is *where* that redundant state lives: recovery cost is
dominated by the tier's bandwidth/latency, not by the reconstruction math.

``StorageTier`` abstracts that placement. The data path of the solver is
unchanged — the queue arrays stay device-resident so the trajectory is
bit-identical across tiers — but each tier carries a distinct bandwidth/
latency cost model and a distinct push volume:

  device-neighbour   today's ``ESRPState.rq`` ppermute path: pushes move
                     only the plan's *extra* tiles (beyond natural SpMV
                     traffic), reads run at interconnect speed.
  replicated-host    every node mirrors its p-slab into host memory each
                     push (PCIe-class bandwidth); recovery fetches the
                     failed rows back over the same link.
  simulated-nvram    same full-slab push, but persistent-memory bandwidth
                     (asymmetric: writes slower than reads) plus a device
                     latency floor.

The driver threads the chosen tier through ``EventReport`` (per-event fetch
bytes + modeled fetch seconds) and ``SolveReport`` (push count/bytes/modeled
seconds), and ``benchmarks/run.py --only failures --tiers`` sweeps recovery
time vs tier × φ × T from the same measured runs.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class StorageTier:
    """One redundancy-storage placement with its cost model.

    read_gbps / write_gbps: sustained bandwidth of the recovery read and
    the storage push (GB/s); latency_s: per-transfer latency floor;
    full_slab_push: True when a push writes each node's whole p-slab to the
    tier (host/NVRAM mirroring) rather than only the plan's extra redundant
    tiles (the device-neighbour ASpMV piggyback).
    """

    name: str
    read_gbps: float
    write_gbps: float
    latency_s: float
    full_slab_push: bool
    # where the constants came from: "placeholder" (order-of-magnitude
    # class numbers) or a measured record written by
    # scripts/calibrate_tiers.py ("measured host=... date=..."), loaded via
    # REPRO_TIER_CALIBRATION. Rides into BENCH_*.json tier sections so a
    # recorded sweep states whether its recovery model was calibrated.
    provenance: str = "placeholder"

    def read_s(self, nbytes: int) -> float:
        """Modeled seconds to fetch ``nbytes`` from this tier."""
        return self.latency_s + nbytes / (self.read_gbps * 1e9)

    def write_s(self, nbytes: int) -> float:
        """Modeled seconds to push ``nbytes`` into this tier."""
        return self.latency_s + nbytes / (self.write_gbps * 1e9)

    def push_bytes(self, plan, m: int, itemsize: int) -> int:
        """Bytes one storage push moves into this tier.

        Device-neighbour: only the extra redundant tiles beyond the natural
        SpMV traffic (the ASpMV piggyback — paper §2.2.1); the natural tiles
        move with the SpMV whether or not redundancy is on. Full-slab tiers
        mirror the entire length-``m`` direction vector.
        """
        if self.full_slab_push or plan is None:
            return m * itemsize
        nat, tot = plan.bytes_per_aspmv(itemsize)
        return tot - nat

    def fetch_bytes(self, n_failed_rows: int, itemsize: int) -> int:
        """Bytes a recovery fetches: the p^(j-1)/p^(j) pair restricted to
        the failed rows (Alg. 2's inputs; static data reloads are accounted
        separately via ``EventReport.precond_reload_bytes``)."""
        return 2 * n_failed_rows * itemsize


# Bandwidth/latency figures are order-of-magnitude class numbers for the
# three placements (interconnect / PCIe host copy / persistent memory with
# asymmetric write bandwidth); the sweep compares tiers relative to each
# other, not against a specific part.
DEVICE_NEIGHBOUR = StorageTier("device-neighbour", read_gbps=100.0,
                               write_gbps=100.0, latency_s=2e-6,
                               full_slab_push=False)
REPLICATED_HOST = StorageTier("replicated-host", read_gbps=12.0,
                              write_gbps=12.0, latency_s=2e-5,
                              full_slab_push=True)
SIMULATED_NVRAM = StorageTier("simulated-nvram", read_gbps=6.0,
                              write_gbps=2.0, latency_s=1e-4,
                              full_slab_push=True)

TIERS: dict[str, StorageTier] = {t.name: t for t in
                                 (DEVICE_NEIGHBOUR, REPLICATED_HOST,
                                  SIMULATED_NVRAM)}


def load_calibration(path: str) -> dict[str, StorageTier]:
    """Parse a ``scripts/calibrate_tiers.py`` record into StorageTiers.

    The JSON carries per-tier ``read_gbps``/``write_gbps``/``latency_s``
    plus a measurement provenance block; the tier's structural field
    (``full_slab_push``) always comes from the builtin definition — the
    calibration overwrites *constants*, not placement semantics."""
    import json

    with open(path) as f:
        doc = json.load(f)
    prov = doc.get("provenance", {})
    tag = ("measured host={host} backend={backend} date={date}"
           .format(host=prov.get("host", "?"),
                   backend=prov.get("backend", "?"),
                   date=prov.get("date", "?")))
    out = {}
    for name, rec in doc["tiers"].items():
        base = TIERS.get(name)
        if base is None:
            raise ValueError(f"calibration names unknown tier {name!r}; "
                             f"known: {sorted(TIERS)}")
        out[name] = dataclasses.replace(
            base, read_gbps=float(rec["read_gbps"]),
            write_gbps=float(rec["write_gbps"]),
            latency_s=float(rec["latency_s"]),
            provenance=rec.get("provenance", tag))
    return out


def _apply_calibration_env() -> None:
    """REPRO_TIER_CALIBRATION=<path> overwrites the placeholder constants
    at import time (the ROADMAP "calibrate the tier constants" remainder).
    Unset or missing file -> placeholders stand, exactly as before."""
    import os

    path = os.environ.get("REPRO_TIER_CALIBRATION")
    if not path or not os.path.exists(path):
        return
    TIERS.update(load_calibration(path))


_apply_calibration_env()


def resolve_tier(tier) -> StorageTier:
    """Accept a tier name or a StorageTier instance."""
    if isinstance(tier, StorageTier):
        return tier
    if tier in TIERS:
        return TIERS[tier]
    raise ValueError(
        f"unknown storage tier {tier!r}; known: {sorted(TIERS)} "
        f"(or pass a StorageTier instance)")
