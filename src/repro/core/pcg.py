"""Preconditioned conjugate gradient (paper Alg. 1, [Saad'03 Alg. 9.1]).

Operator-based and fully jittable: ``matvec`` and ``precond`` are closures
(Block-ELL SpMV / block-Jacobi apply in production, dense ops in tests). The
same routine powers the outer solver and the *inner* reconstruction solves of
Alg. 2 (lines 6/8), which the paper runs to rtol 1e-14.
"""
from __future__ import annotations

import functools
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


class PCGState(NamedTuple):
    """Dynamic solver state (paper §1.1: vectors + scalars changing per iter).

    Entering iteration j the fields hold: x = x^(j), r = r^(j), z = z^(j),
    p = p^(j), rz = r^(j)ᵀz^(j), beta = β^(j-1), j = j.
    """
    x: jax.Array
    r: jax.Array
    z: jax.Array
    p: jax.Array
    rz: jax.Array
    beta: jax.Array
    j: jax.Array


def pcg_init(matvec: Callable, precond: Callable, b: jax.Array,
             x0: jax.Array | None = None) -> PCGState:
    x0 = jnp.zeros_like(b) if x0 is None else x0
    r0 = b - matvec(x0)
    z0 = precond(r0)
    return PCGState(x=x0, r=r0, z=z0, p=z0, rz=r0 @ z0,
                    beta=jnp.zeros((), b.dtype), j=jnp.zeros((), jnp.int32))


def pcg_iterate(state: PCGState, q: jax.Array,
                precond: Callable) -> PCGState:
    """One PCG iteration *given* q = A·p^(j) (lines 3-8 of Alg. 1).

    The SpMV is split out so ESRP can swap SpMV ↔ ASpMV (Alg. 3) without
    touching the numerics — the failure-free trajectory is bit-identical to
    plain PCG by construction, which is the paper's trajectory-identity
    property.
    """
    alpha = state.rz / (state.p @ q)
    x = state.x + alpha * state.p
    r = state.r - alpha * q
    z = precond(r)
    rz = r @ z
    beta = rz / state.rz
    p = z + beta * state.p
    return PCGState(x=x, r=r, z=z, p=p, rz=rz, beta=beta, j=state.j + 1)


def pcg_step(state: PCGState, matvec: Callable,
             precond: Callable) -> PCGState:
    return pcg_iterate(state, matvec(state.p), precond)


@functools.partial(jax.jit, static_argnums=(0, 1, 3, 4))
def run_pcg(matvec: Callable, precond: Callable, b: jax.Array,
            rtol: float = 1e-8, max_iters: int = 100_000,
            x0: jax.Array | None = None) -> tuple[PCGState, jax.Array]:
    """Solve to ||r||/||b|| < rtol. Returns (state, relative residual)."""
    state = pcg_init(matvec, precond, b, x0)
    bnorm = jnp.linalg.norm(b)
    thresh = rtol * bnorm

    def cond(carry):
        s, _ = carry
        return (jnp.linalg.norm(s.r) >= thresh) & (s.j < max_iters)

    def body(carry):
        s, _ = carry
        s = pcg_step(s, matvec, precond)
        return s, jnp.linalg.norm(s.r) / bnorm

    state, rel = jax.lax.while_loop(
        cond, body, (state, jnp.linalg.norm(state.r) / bnorm))
    return state, rel


def residual_drift(matvec: Callable, b: jax.Array, x_end: jax.Array,
                   r_end: jax.Array) -> jax.Array:
    """Paper Eq. (2): (||r_end|| - ||b - A x_end||) / ||b - A x_end||."""
    true_res = jnp.linalg.norm(b - matvec(x_end))
    return (jnp.linalg.norm(r_end) - true_res) / true_res
