"""Preconditioned conjugate gradient (paper Alg. 1, [Saad'03 Alg. 9.1]).

Operator-based and fully jittable. The hot path runs through a ``SolverOps``
bundle (repro.core.ops): the SpMV and the pᵀq dot fuse into one pass, and
lines 4-7 of Alg. 1 fuse into a single vector pass (kernels/fused_pcg), with
a pure-jnp reference backend that is bit-identical in f64. The closure-based
entry points (``pcg_step``, ``run_pcg``) wrap arbitrary (matvec, precond)
pairs — they power the dense test operators and the *inner* reconstruction
solves of Alg. 2 (lines 6/8), which the paper runs to rtol 1e-14.
"""
from __future__ import annotations

import functools
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.analysis.marks import sync_free
from repro.core.ops import SolverOps, batch_ops, make_closure_ops


def _expand(s: jax.Array, v: jax.Array) -> jax.Array:
    """Broadcast a per-member scalar against per-member vectors: with
    unbatched (M,) vectors the scalar passes through untouched (the
    pre-batch expression, bit-for-bit); with batched (B, M) vectors the
    (B,) scalar gains a trailing axis."""
    return s[..., None] if v.ndim == 2 else s


def _vec_norm(r: jax.Array) -> jax.Array:
    """||r|| per member: flat norm for (M,), per-row norm for (B, M).
    The row-wise reduce is bit-identical in f64 to the flat norm of each
    row (asserted in tests/test_batched.py)."""
    return jnp.linalg.norm(r) if r.ndim == 1 else jnp.linalg.norm(r, axis=-1)


def freeze_pcg(old: "PCGState", new: "PCGState", done: jax.Array) -> "PCGState":
    """Per-member freeze: members with done=True keep their old per-member
    leaves; the shared iteration counter always advances (it tracks the
    global schedule, not any one member)."""
    col = done[:, None]
    return PCGState(x=jnp.where(col, old.x, new.x),
                    r=jnp.where(col, old.r, new.r),
                    z=jnp.where(col, old.z, new.z),
                    p=jnp.where(col, old.p, new.p),
                    rz=jnp.where(done, old.rz, new.rz),
                    beta=jnp.where(done, old.beta, new.beta),
                    j=new.j)


class PCGState(NamedTuple):
    """Dynamic solver state (paper §1.1: vectors + scalars changing per iter).

    Entering iteration j the fields hold: x = x^(j), r = r^(j), z = z^(j),
    p = p^(j), rz = r^(j)ᵀz^(j), beta = β^(j-1), j = j.
    """
    x: jax.Array
    r: jax.Array
    z: jax.Array
    p: jax.Array
    rz: jax.Array
    beta: jax.Array
    j: jax.Array


def pcg_init(matvec: Callable, precond: Callable, b: jax.Array,
             x0: jax.Array | None = None,
             dot: Callable | None = None) -> PCGState:
    """``dot`` overrides the r₀ᵀz₀ reduction (SolverOps.dot): the sharded
    runtime's per-node partial sums and its single-device mesh mirror must
    agree bitwise from iteration 0, which a flat ``@`` would break."""
    x0 = jnp.zeros_like(b) if x0 is None else x0
    r0 = b - matvec(x0)
    z0 = precond(r0)
    rz0 = r0 @ z0 if dot is None else dot(r0, z0)
    # beta shape follows the batch layout: () for (M,) b, (B,) for (B, M)
    return PCGState(x=x0, r=r0, z=z0, p=z0, rz=rz0,
                    beta=jnp.zeros(b.shape[:-1], b.dtype),
                    j=jnp.zeros((), jnp.int32))


def pcg_iterate_ops(state: PCGState, ops: SolverOps) -> PCGState:
    """One PCG iteration through the SolverOps bundle (Alg. 1 lines 3-8).

    The SpMV produces pᵀq in the same pass (α without re-reading p, q) and
    the x/r/z/rz updates run as one fused sweep. ESRP's storage bookkeeping
    happens *before* this call (Alg. 3 swaps SpMV ↔ ASpMV without touching
    the numerics), so the failure-free trajectory is bit-identical to plain
    PCG — the paper's trajectory-identity property.
    """
    q, pq = ops.matvec_dot(state.p)
    alpha = state.rz / pq
    x, r, z, rz = ops.update(alpha, state.x, state.r, state.p, q)
    beta = rz / state.rz
    p = z + _expand(beta, z) * state.p
    return PCGState(x=x, r=r, z=z, p=p, rz=rz, beta=beta, j=state.j + 1)


def pcg_iterate(state: PCGState, q: jax.Array,
                precond: Callable) -> PCGState:
    """One PCG iteration *given* q = A·p^(j) — the unfused reference form
    (kept for callers that computed q themselves)."""
    alpha = state.rz / (state.p @ q)
    x = state.x + alpha * state.p
    r = state.r - alpha * q
    z = precond(r)
    rz = r @ z
    beta = rz / state.rz
    p = z + beta * state.p
    return PCGState(x=x, r=r, z=z, p=p, rz=rz, beta=beta, j=state.j + 1)


def pcg_step(state: PCGState, matvec: Callable,
             precond: Callable) -> PCGState:
    return pcg_iterate_ops(state, make_closure_ops(matvec, precond))


# Per-iteration telemetry columns of the on-device metrics ring (obs=on):
# the iteration's rz, its storage push/star flags, and the orthogonality
# invariant residual |r^T p - rz| — the same signal core.sdc's host-side
# orthogonality check thresholds, here recorded every iteration.
METRIC_FIELDS = ("rz", "push", "star", "orth")


def iteration_metrics(pcg, push, star) -> jax.Array:
    """One (len(METRIC_FIELDS),) on-device metrics row for the iteration
    that just produced ``pcg``. Stacked into a single small vector so the
    chunk scan carries one extra row per iteration next to the ||r|| record
    and the whole ring reads back with the existing chunk readback (zero
    extra dispatches)."""
    dt = pcg.rz.dtype
    if pcg.r.ndim == 1:
        orth = jnp.abs(pcg.r @ pcg.p - pcg.rz)
        return jnp.stack([pcg.rz, jnp.asarray(push).astype(dt),
                          jnp.asarray(star).astype(dt), orth])
    # batched: one (len(METRIC_FIELDS), B) row — per-member rz/orth columns,
    # the shared push/star flags broadcast across members
    ones = jnp.ones(pcg.rz.shape, dt)
    orth = jnp.abs(jnp.sum(pcg.r * pcg.p, axis=-1) - pcg.rz)
    return jnp.stack([pcg.rz, ones * jnp.asarray(push).astype(dt),
                      ones * jnp.asarray(star).astype(dt), orth])


@sync_free
def scan_with_convergence_freeze(st, step: Callable, rnorm0: jax.Array,
                                 n_iters: int,
                                 thresh: jax.Array | None,
                                 aux0: jax.Array | None = None,
                                 freeze: Callable | None = None):
    """Scan ``n_iters`` of ``step`` (state -> (state, ||r||)), recording
    ||r|| after each iteration — the chunked-convergence protocol shared by
    the ESRP and IMCR chunk runners.

    With ``thresh`` set (dynamic), the carried ||r|| doubles as a done flag:
    once it drops below thresh the remaining iterations pass the state
    through untouched (``lax.cond``), so the caller's returned state *is*
    the state at first convergence and no chunk ever needs re-running.
    thresh=None runs all n_iters unconditionally.

    ``aux0`` arms the metrics ring (obs=on): ``step`` then returns
    (state, ||r||, aux) and the record becomes ``(norms, auxes)`` — frozen
    iterations repeat the carried aux row, which the driver trims away with
    the executed count. aux0=None keeps the exact pre-telemetry trace (the
    jaxpr-identity tests compare against this path).

    Batched (rnorm0 of shape (B,), thresh (B,)): the freeze becomes
    **per-member** (continuous batching). Each iteration steps the whole
    batch, then ``freeze(old_state, new_state, done)`` re-selects the old
    per-member leaves for converged members (``done`` = (B,) bool) — the
    caller supplies it because only the strategy knows which state leaves
    carry the batch axis where. A converged member's state is therefore
    exactly its state at first convergence, bit-for-bit, while stragglers
    advance; a global ``lax.cond`` still skips the whole body once every
    member is done. The recorded norms become (n_iters, B).
    """
    batched = thresh is not None and getattr(rnorm0, "ndim", 0) > 0
    if batched and freeze is None:
        raise ValueError("batched convergence freeze needs the per-member "
                         "freeze(old, new, done) callback")
    if batched:
        if aux0 is not None:
            def advance_aux(carry):
                s, rnorm, aux = carry
                s2, rn2, aux2 = step(s)
                done = rnorm < thresh
                return (freeze(s, s2, done), jnp.where(done, rnorm, rn2),
                        jnp.where(done[None, :], aux, aux2))

            def body_aux(carry, _):
                carry = jax.lax.cond(jnp.all(carry[1] < thresh),
                                     lambda c: c, advance_aux, carry)
                return carry, (carry[1], carry[2])

            (st, _, _), record = jax.lax.scan(
                body_aux, (st, rnorm0, aux0), None, length=n_iters)
            return st, record

        def advance(carry):
            s, rnorm = carry
            s2, rn2 = step(s)
            done = rnorm < thresh
            return freeze(s, s2, done), jnp.where(done, rnorm, rn2)

        def body(carry, _):
            carry = jax.lax.cond(jnp.all(carry[1] < thresh),
                                 lambda c: c, advance, carry)
            return carry, carry[1]

        (st, _), norms = jax.lax.scan(body, (st, rnorm0), None,
                                      length=n_iters)
        return st, norms

    if aux0 is not None:
        def body_aux(carry, _):
            s, rnorm, aux = carry
            if thresh is None:
                s, rnorm, aux = step(s)
            else:
                s, rnorm, aux = jax.lax.cond(
                    rnorm < thresh, lambda s: (s, rnorm, aux), step, s)
            return (s, rnorm, aux), (rnorm, aux)

        (st, _, _), record = jax.lax.scan(body_aux, (st, rnorm0, aux0), None,
                                          length=n_iters)
        return st, record

    def body(carry, _):
        s, rnorm = carry
        if thresh is None:
            s, rnorm = step(s)
        else:
            s, rnorm = jax.lax.cond(
                rnorm < thresh, lambda s: (s, rnorm), step, s)
        return (s, rnorm), rnorm

    (st, _), norms = jax.lax.scan(body, (st, rnorm0), None, length=n_iters)
    return st, norms


@sync_free
def scan_with_halt_guard(st, step: Callable, rnorm0: jax.Array,
                         n_iters: int,
                         thresh: jax.Array | None,
                         aux0: jax.Array | None = None,
                         freeze: Callable | None = None,
                         guard: Callable | None = None):
    """``scan_with_convergence_freeze`` plus an on-device *halt guard*: before
    each iteration executes, ``guard(state, ||r||)`` is evaluated on the
    entering state; once it fires the remaining iterations of the chunk pass
    the state through untouched. This is how the SDC invariants ride inside
    the chunk (ROADMAP: detection latency bounded by ``check_every`` even
    when chunks are long): the guard fires at a check boundary, the chunk
    freezes *at* that boundary — before the boundary iteration's storage
    prelude can commit corrupted state — and the host runs the authoritative
    localization on the returned state.

    The record gains a per-iteration halted flag: ``halted[i] = True`` means
    iteration i did NOT execute (the state returned is the state entering
    it). Convergence/freeze semantics are identical to
    ``scan_with_convergence_freeze`` — a fired guard simply acts like
    all-members-converged from that iteration on.
    """
    batched = thresh is not None and getattr(rnorm0, "ndim", 0) > 0
    if batched and freeze is None:
        raise ValueError("batched convergence freeze needs the per-member "
                         "freeze(old, new, done) callback")
    if guard is None:
        raise ValueError("scan_with_halt_guard needs a guard callback")
    h0 = jnp.zeros((), bool)

    def all_done(rnorm):
        if thresh is None:
            return jnp.zeros((), bool)
        return jnp.all(rnorm < thresh) if batched else rnorm < thresh

    def body(carry, _):
        s, rnorm, aux, halted = carry
        # once halted (j pinned at the boundary) or fully converged the guard
        # is skipped — the remaining iterations are pure passthrough
        halted = halted | jax.lax.cond(
            halted | all_done(rnorm), lambda: jnp.zeros((), bool),
            lambda: guard(s, rnorm))

        def advance(c):
            s, rnorm, aux, halted = c
            if aux is None:
                s2, rn2 = step(s)
                aux2 = None
            else:
                s2, rn2, aux2 = step(s)
            if batched:
                done = rnorm < thresh
                s2 = freeze(s, s2, done)
                rn2 = jnp.where(done, rnorm, rn2)
                if aux is not None:
                    aux2 = jnp.where(done[None, :], aux, aux2)
            return (s2, rn2, aux2, halted)

        carry = jax.lax.cond(halted | all_done(rnorm), lambda c: c,
                             advance, (s, rnorm, aux, halted))
        rec = ((carry[1], carry[3]) if aux0 is None
               else (carry[1], carry[2], carry[3]))
        return carry, rec

    (st, _, _, _), record = jax.lax.scan(
        body, (st, rnorm0, aux0, h0), None, length=n_iters)
    return st, record


@functools.partial(jax.jit, static_argnums=(0, 1, 3, 4))
def run_pcg(matvec: Callable, precond: Callable, b: jax.Array,
            rtol: float = 1e-8, max_iters: int = 100_000,
            x0: jax.Array | None = None) -> tuple[PCGState, jax.Array]:
    """Solve to ||r||/||b|| < rtol. Returns (state, relative residual).

    ||r|| is carried in the loop state: computed once per iteration (in the
    body, after the step) instead of once in ``cond`` and again in ``body``.

    b = 0 returns x = 0 with relative residual 0.0 exactly: without the
    guard, thresh = rtol·||b|| = 0 never beats ||r|| = 0 (the ≥ keeps
    looping), α = rz/pᵀq = 0/0 poisons the state with NaN, and rel =
    0/0 = NaN — which the Alg. 2 line-6/8 inner solves would then scatter
    into a reconstructed state (a zero RHS there is a legal input: e.g. a
    failed block whose residual strip is exactly zero).
    """
    ops = make_closure_ops(matvec, precond)
    state = pcg_init(matvec, precond, b, x0)
    bnorm = jnp.linalg.norm(b)
    thresh = rtol * bnorm
    nonzero = bnorm > 0

    def cond(carry):
        s, rnorm = carry
        return (rnorm >= thresh) & (s.j < max_iters) & nonzero

    def body(carry):
        s, _ = carry
        s = pcg_iterate_ops(s, ops)
        return s, jnp.linalg.norm(s.r)

    state, rnorm = jax.lax.while_loop(
        cond, body, (state, jnp.linalg.norm(state.r)))
    # b = 0 ⇒ the exact solution is x = 0 whatever x0 was; rebuild the
    # consistent state rather than handing back the untouched initial guess
    state = jax.tree.map(
        lambda a: jnp.where(nonzero, a, jnp.zeros_like(a)), state)
    return state, jnp.where(nonzero, rnorm / jnp.where(nonzero, bnorm, 1.0),
                            jnp.zeros_like(rnorm))


@functools.partial(jax.jit, static_argnums=(0, 1, 3, 4))
def run_pcg_batched(matvec: Callable, precond: Callable, b: jax.Array,
                    rtol: float = 1e-8, max_iters: int = 100_000,
                    x0: jax.Array | None = None
                    ) -> tuple[PCGState, jax.Array]:
    """Batched ``run_pcg``: solve B systems with the *same* operator to
    per-member tolerance. b: (B, M); matvec/precond are the unbatched
    closures, applied per member through ``batch_ops``.

    ``jax.vmap`` of the while_loop would keep stepping converged members
    until the last straggler finishes (vmap has no per-member freeze), which
    breaks the per-member trajectory identity. Here the loop runs while any
    member is active and ``freeze_pcg`` pins converged members at their
    first-convergence state — member i's final (state, rel) is bit-identical
    in f64 to ``run_pcg(matvec, precond, b[i], ...)``. Zero-RHS members
    (the micro-batcher's padding) resolve to x = 0 / rel = 0 at iteration 0,
    exactly like the unbatched guard."""
    nb = b.shape[0]
    ops = batch_ops(make_closure_ops(matvec, precond), nb)
    state = pcg_init(ops.matvec, ops.precond, b, x0, dot=ops.dot)
    bnorm = jnp.linalg.norm(b, axis=-1)
    thresh = rtol * bnorm
    nonzero = bnorm > 0

    def cond(carry):
        s, rnorm = carry
        return jnp.any((rnorm >= thresh) & nonzero) & (s.j < max_iters)

    def body(carry):
        s, rnorm = carry
        s2 = pcg_iterate_ops(s, ops)
        rn2 = jnp.linalg.norm(s2.r, axis=-1)
        done = (rnorm < thresh) | ~nonzero
        return freeze_pcg(s, s2, done), jnp.where(done, rnorm, rn2)

    state, rnorm = jax.lax.while_loop(
        cond, body, (state, jnp.linalg.norm(state.r, axis=-1)))
    live = nonzero if state.x.ndim == 1 else nonzero[:, None]
    state = PCGState(
        x=jnp.where(live, state.x, 0.0), r=jnp.where(live, state.r, 0.0),
        z=jnp.where(live, state.z, 0.0), p=jnp.where(live, state.p, 0.0),
        rz=jnp.where(nonzero, state.rz, 0.0),
        beta=jnp.where(nonzero, state.beta, 0.0), j=state.j)
    return state, jnp.where(nonzero, rnorm / jnp.where(nonzero, bnorm, 1.0),
                            jnp.zeros_like(rnorm))


def residual_drift(matvec: Callable, b: jax.Array, x_end: jax.Array,
                   r_end: jax.Array) -> jax.Array:
    """Paper Eq. (2): (||r_end|| - ||b - A x_end||) / ||b - A x_end||.
    Batch-polymorphic: (B, M) inputs give a (B,) per-member drift."""
    true_res = _vec_norm(b - matvec(x_end))
    return (_vec_norm(r_end) - true_res) / true_res
