"""Preconditioned conjugate gradient (paper Alg. 1, [Saad'03 Alg. 9.1]).

Operator-based and fully jittable. The hot path runs through a ``SolverOps``
bundle (repro.core.ops): the SpMV and the pᵀq dot fuse into one pass, and
lines 4-7 of Alg. 1 fuse into a single vector pass (kernels/fused_pcg), with
a pure-jnp reference backend that is bit-identical in f64. The closure-based
entry points (``pcg_step``, ``run_pcg``) wrap arbitrary (matvec, precond)
pairs — they power the dense test operators and the *inner* reconstruction
solves of Alg. 2 (lines 6/8), which the paper runs to rtol 1e-14.
"""
from __future__ import annotations

import functools
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.ops import SolverOps, make_closure_ops


class PCGState(NamedTuple):
    """Dynamic solver state (paper §1.1: vectors + scalars changing per iter).

    Entering iteration j the fields hold: x = x^(j), r = r^(j), z = z^(j),
    p = p^(j), rz = r^(j)ᵀz^(j), beta = β^(j-1), j = j.
    """
    x: jax.Array
    r: jax.Array
    z: jax.Array
    p: jax.Array
    rz: jax.Array
    beta: jax.Array
    j: jax.Array


def pcg_init(matvec: Callable, precond: Callable, b: jax.Array,
             x0: jax.Array | None = None,
             dot: Callable | None = None) -> PCGState:
    """``dot`` overrides the r₀ᵀz₀ reduction (SolverOps.dot): the sharded
    runtime's per-node partial sums and its single-device mesh mirror must
    agree bitwise from iteration 0, which a flat ``@`` would break."""
    x0 = jnp.zeros_like(b) if x0 is None else x0
    r0 = b - matvec(x0)
    z0 = precond(r0)
    rz0 = r0 @ z0 if dot is None else dot(r0, z0)
    return PCGState(x=x0, r=r0, z=z0, p=z0, rz=rz0,
                    beta=jnp.zeros((), b.dtype), j=jnp.zeros((), jnp.int32))


def pcg_iterate_ops(state: PCGState, ops: SolverOps) -> PCGState:
    """One PCG iteration through the SolverOps bundle (Alg. 1 lines 3-8).

    The SpMV produces pᵀq in the same pass (α without re-reading p, q) and
    the x/r/z/rz updates run as one fused sweep. ESRP's storage bookkeeping
    happens *before* this call (Alg. 3 swaps SpMV ↔ ASpMV without touching
    the numerics), so the failure-free trajectory is bit-identical to plain
    PCG — the paper's trajectory-identity property.
    """
    q, pq = ops.matvec_dot(state.p)
    alpha = state.rz / pq
    x, r, z, rz = ops.update(alpha, state.x, state.r, state.p, q)
    beta = rz / state.rz
    p = z + beta * state.p
    return PCGState(x=x, r=r, z=z, p=p, rz=rz, beta=beta, j=state.j + 1)


def pcg_iterate(state: PCGState, q: jax.Array,
                precond: Callable) -> PCGState:
    """One PCG iteration *given* q = A·p^(j) — the unfused reference form
    (kept for callers that computed q themselves)."""
    alpha = state.rz / (state.p @ q)
    x = state.x + alpha * state.p
    r = state.r - alpha * q
    z = precond(r)
    rz = r @ z
    beta = rz / state.rz
    p = z + beta * state.p
    return PCGState(x=x, r=r, z=z, p=p, rz=rz, beta=beta, j=state.j + 1)


def pcg_step(state: PCGState, matvec: Callable,
             precond: Callable) -> PCGState:
    return pcg_iterate_ops(state, make_closure_ops(matvec, precond))


# Per-iteration telemetry columns of the on-device metrics ring (obs=on):
# the iteration's rz, its storage push/star flags, and the orthogonality
# invariant residual |r^T p - rz| — the same signal core.sdc's host-side
# orthogonality check thresholds, here recorded every iteration.
METRIC_FIELDS = ("rz", "push", "star", "orth")


def iteration_metrics(pcg, push, star) -> jax.Array:
    """One (len(METRIC_FIELDS),) on-device metrics row for the iteration
    that just produced ``pcg``. Stacked into a single small vector so the
    chunk scan carries one extra row per iteration next to the ||r|| record
    and the whole ring reads back with the existing chunk readback (zero
    extra dispatches)."""
    dt = pcg.rz.dtype
    orth = jnp.abs(pcg.r @ pcg.p - pcg.rz)
    return jnp.stack([pcg.rz, jnp.asarray(push).astype(dt),
                      jnp.asarray(star).astype(dt), orth])


def scan_with_convergence_freeze(st, step: Callable, rnorm0: jax.Array,
                                 n_iters: int,
                                 thresh: jax.Array | None,
                                 aux0: jax.Array | None = None):
    """Scan ``n_iters`` of ``step`` (state -> (state, ||r||)), recording
    ||r|| after each iteration — the chunked-convergence protocol shared by
    the ESRP and IMCR chunk runners.

    With ``thresh`` set (dynamic), the carried ||r|| doubles as a done flag:
    once it drops below thresh the remaining iterations pass the state
    through untouched (``lax.cond``), so the caller's returned state *is*
    the state at first convergence and no chunk ever needs re-running.
    thresh=None runs all n_iters unconditionally.

    ``aux0`` arms the metrics ring (obs=on): ``step`` then returns
    (state, ||r||, aux) and the record becomes ``(norms, auxes)`` — frozen
    iterations repeat the carried aux row, which the driver trims away with
    the executed count. aux0=None keeps the exact pre-telemetry trace (the
    jaxpr-identity tests compare against this path).
    """
    if aux0 is not None:
        def body_aux(carry, _):
            s, rnorm, aux = carry
            if thresh is None:
                s, rnorm, aux = step(s)
            else:
                s, rnorm, aux = jax.lax.cond(
                    rnorm < thresh, lambda s: (s, rnorm, aux), step, s)
            return (s, rnorm, aux), (rnorm, aux)

        (st, _, _), record = jax.lax.scan(body_aux, (st, rnorm0, aux0), None,
                                          length=n_iters)
        return st, record

    def body(carry, _):
        s, rnorm = carry
        if thresh is None:
            s, rnorm = step(s)
        else:
            s, rnorm = jax.lax.cond(
                rnorm < thresh, lambda s: (s, rnorm), step, s)
        return (s, rnorm), rnorm

    (st, _), norms = jax.lax.scan(body, (st, rnorm0), None, length=n_iters)
    return st, norms


@functools.partial(jax.jit, static_argnums=(0, 1, 3, 4))
def run_pcg(matvec: Callable, precond: Callable, b: jax.Array,
            rtol: float = 1e-8, max_iters: int = 100_000,
            x0: jax.Array | None = None) -> tuple[PCGState, jax.Array]:
    """Solve to ||r||/||b|| < rtol. Returns (state, relative residual).

    ||r|| is carried in the loop state: computed once per iteration (in the
    body, after the step) instead of once in ``cond`` and again in ``body``.

    b = 0 returns x = 0 with relative residual 0.0 exactly: without the
    guard, thresh = rtol·||b|| = 0 never beats ||r|| = 0 (the ≥ keeps
    looping), α = rz/pᵀq = 0/0 poisons the state with NaN, and rel =
    0/0 = NaN — which the Alg. 2 line-6/8 inner solves would then scatter
    into a reconstructed state (a zero RHS there is a legal input: e.g. a
    failed block whose residual strip is exactly zero).
    """
    ops = make_closure_ops(matvec, precond)
    state = pcg_init(matvec, precond, b, x0)
    bnorm = jnp.linalg.norm(b)
    thresh = rtol * bnorm
    nonzero = bnorm > 0

    def cond(carry):
        s, rnorm = carry
        return (rnorm >= thresh) & (s.j < max_iters) & nonzero

    def body(carry):
        s, _ = carry
        s = pcg_iterate_ops(s, ops)
        return s, jnp.linalg.norm(s.r)

    state, rnorm = jax.lax.while_loop(
        cond, body, (state, jnp.linalg.norm(state.r)))
    # b = 0 ⇒ the exact solution is x = 0 whatever x0 was; rebuild the
    # consistent state rather than handing back the untouched initial guess
    state = jax.tree.map(
        lambda a: jnp.where(nonzero, a, jnp.zeros_like(a)), state)
    return state, jnp.where(nonzero, rnorm / jnp.where(nonzero, bnorm, 1.0),
                            jnp.zeros_like(rnorm))


def residual_drift(matvec: Callable, b: jax.Array, x_end: jax.Array,
                   r_end: jax.Array) -> jax.Array:
    """Paper Eq. (2): (||r_end|| - ||b - A x_end||) / ||b - A x_end||."""
    true_res = jnp.linalg.norm(b - matvec(x_end))
    return (jnp.linalg.norm(r_end) - true_res) / true_res
